"""Unit tests for the NumPy oracle (mirrors rust/src/formats tests, so both
sides of the golden contract are independently pinned)."""

import numpy as np
import pytest

from compile.kernels import ref


def test_e2m1_levels_match_ocp_fp4():
    lv = ref.levels(2, 1)
    assert lv.tolist() == [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]


def test_bfp4_levels_integer_grid():
    assert ref.levels(0, 3).tolist() == [0, 1, 2, 3, 4, 5, 6, 7]


def test_e4m3_max_448_no_nan():
    lv = ref.levels(4, 3)
    assert lv[-1] == 448.0
    assert len(lv) == 127


def test_offsets():
    assert ref.scale_exp_offset(2, 1) == -2
    assert ref.scale_exp_offset(0, 3) == -2
    assert ref.scale_exp_offset(2, 3) == -2
    assert ref.scale_exp_offset(3, 2) == -4


def test_project_ties_to_even():
    lv = ref.levels(2, 1)
    assert ref.project_magnitude(lv, np.float32(0.25)) == 0
    assert ref.project_magnitude(lv, np.float32(1.25)) == 2
    assert ref.project_magnitude(lv, np.float32(2.5)) == 4
    assert ref.project_magnitude(lv, np.float32(5.0)) == 6
    assert ref.project_magnitude(lv, np.float32(100.0)) == 7


def test_fig4_nanomantissa_example():
    v = np.array([-7.4, 2.0, 1.0, 0.5, -1.5, 3.0, 0.0, 1.0], dtype=np.float32)
    plain = ref.fake_quant(v, ref.NxConfig.mxfp(4))
    assert plain[0] == -6.0
    nm = ref.fake_quant(v, ref.NxConfig.nxfp_nm(4))
    assert abs(nm[0] - -7.5) < 1e-6


def test_recycle_half_min():
    bf = ref.block_format(ref.NxConfig.nxfp(4), mx_path=True)
    assert ref.decode(bf, 0b1000) == np.float32(-0.25)
    bfb = ref.block_format(ref.NxConfig.nxfp(4), mx_path=False)
    assert ref.decode(bfb, 0b1000) == np.float32(-0.5)


def test_minus_zero_canonical_without_cr():
    bf = ref.block_format(ref.NxConfig.mxfp(4), mx_path=True)
    assert ref.encode(bf, np.float32(-0.01)) == 0
    assert ref.decode(bf, 0b1000) == 0.0


@pytest.mark.parametrize("bits", [4, 5, 6])
def test_techniques_monotone_mse(bits):
    rng = np.random.default_rng(5)
    v = rng.normal(0, 1.5, size=32 * 64).astype(np.float32)

    def m(cfg):
        q = ref.fake_quant(v, cfg)
        return float(np.mean((v - q) ** 2))

    base = m(ref.NxConfig.mxfp(bits))
    nm = m(ref.NxConfig.nxfp_nm(bits))
    nm_am = m(ref.NxConfig.nxfp_nm_am(bits))
    full = m(ref.NxConfig.nxfp(bits))
    assert nm <= base + 1e-12
    assert nm_am <= nm + 1e-12
    assert full <= nm_am + 1e-12


def test_all_zero_block():
    v = np.zeros(32, dtype=np.float32)
    for cfg in [ref.NxConfig.bfp(4), ref.NxConfig.mxfp(4), ref.NxConfig.nxfp(4)]:
        assert np.all(ref.fake_quant(v, cfg) == 0.0)


def test_footprint_matches_paper_numbers():
    assert ref.footprint_bits(ref.NxConfig.nxfp(5), 32) == 171
    assert ref.footprint_bits(ref.NxConfig.mxfp(6), 32) == 200


def test_partial_tail_block():
    rng = np.random.default_rng(6)
    v = rng.normal(size=45).astype(np.float32)
    out = ref.fake_quant(v, ref.NxConfig.nxfp(4))
    assert out.shape == (45,)
    assert np.isfinite(out).all()


def test_exp2i_exact():
    for e in range(-140, 128):
        assert ref.exp2i(e) == np.float32(2.0 ** e), e


def test_floor_log2():
    assert ref.floor_log2(1.0) == 0
    assert ref.floor_log2(1.5) == 0
    assert ref.floor_log2(2.0) == 1
    assert ref.floor_log2(0.75) == -1
    assert ref.floor_log2(-6.0) == 2
    assert ref.floor_log2(0.0) is None
