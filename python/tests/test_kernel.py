"""L1 kernel correctness: the Pallas fake-quant kernel vs the NumPy oracle.

The CORE correctness signal for the compute hot-spot. The kernel accumulates
the Algorithm-1 SSE in f32 with XLA reduction order, so on knife-edge blocks
the AM/NM *choice* may differ from the oracle's sequential-f64 choice; a
block is accepted if its values match the oracle OR its MSE is at least as
good (both choices are then valid minimizers up to float rounding).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fakequant, ref

CONFIGS = {
    "bfp4": ref.NxConfig.bfp(4),
    "mxfp4": ref.NxConfig.mxfp(4),
    "mxfp6": ref.NxConfig.mxfp(6),
    "nxfp4": ref.NxConfig.nxfp(4),
    "nxfp5": ref.NxConfig.nxfp(5),
    "nxfp4_nm": ref.NxConfig.nxfp_nm(4),
    "nxfp4_nm_am": ref.NxConfig.nxfp_nm_am(4),
}


def oracle_blocks(x, cfg):
    return np.stack([ref.fake_quant(row, cfg) for row in x])


def assert_blocks_equivalent(x, got, want, cfg, atol=1e-6):
    """Per-block: bitwise match, or equal-or-better MSE within tolerance."""
    for b in range(x.shape[0]):
        if np.array_equal(got[b], want[b]):
            continue
        mse_got = float(np.mean((x[b] - got[b]) ** 2))
        mse_want = float(np.mean((x[b] - want[b]) ** 2))
        assert mse_got <= mse_want * (1 + 1e-4) + atol, (
            f"block {b}: kernel mse {mse_got} worse than oracle {mse_want}\n"
            f"in={x[b]}\ngot={got[b]}\nwant={want[b]}"
        )


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_kernel_matches_oracle_gaussian(name):
    cfg = CONFIGS[name]
    rng = np.random.default_rng(42)
    x = rng.normal(0, 1.3, size=(64, 32)).astype(np.float32)
    got = np.asarray(fakequant.fakequant_blocks(jnp.asarray(x), cfg))
    want = oracle_blocks(x, cfg)
    assert_blocks_equivalent(x, got, want, cfg)


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_kernel_wide_dynamic_range(name):
    cfg = CONFIGS[name]
    rng = np.random.default_rng(7)
    scales = 2.0 ** rng.integers(-20, 20, size=(64, 1))
    x = (rng.normal(size=(64, 32)) * scales).astype(np.float32)
    got = np.asarray(fakequant.fakequant_blocks(jnp.asarray(x), cfg))
    want = oracle_blocks(x, cfg)
    assert_blocks_equivalent(x, got, want, cfg)


def test_kernel_zero_blocks():
    x = np.zeros((64, 32), dtype=np.float32)
    for cfg in CONFIGS.values():
        got = np.asarray(fakequant.fakequant_blocks(jnp.asarray(x), cfg))
        assert np.all(got == 0.0)


def test_kernel_heavy_tails():
    cfg = ref.NxConfig.nxfp(4)
    rng = np.random.default_rng(3)
    x = rng.standard_t(2, size=(64, 32)).astype(np.float32)
    got = np.asarray(fakequant.fakequant_blocks(jnp.asarray(x), cfg))
    want = oracle_blocks(x, cfg)
    assert_blocks_equivalent(x, got, want, cfg)


def test_pallas_and_pure_jnp_paths_agree():
    rng = np.random.default_rng(11)
    x = rng.normal(size=(8, 16, 64)).astype(np.float32)
    for cfg in [ref.NxConfig.mxfp(4), ref.NxConfig.nxfp(4)]:
        a = np.asarray(fakequant.fakequant_tensor(jnp.asarray(x), cfg))
        b = np.asarray(fakequant.fakequant_ref_jnp(jnp.asarray(x), cfg))
        np.testing.assert_array_equal(a, b)


def test_fakequant_tensor_shape_and_padding():
    # 3 blocks per row * 5 rows = 15 blocks -> padded to tile multiple
    rng = np.random.default_rng(12)
    x = rng.normal(size=(5, 96)).astype(np.float32)
    cfg = ref.NxConfig.nxfp(4)
    out = np.asarray(fakequant.fakequant_tensor(jnp.asarray(x), cfg))
    assert out.shape == x.shape
    want = np.stack([ref.fake_quant(r, cfg) for r in x])
    assert_blocks_equivalent(
        x.reshape(-1, 32), out.reshape(-1, 32), want.reshape(-1, 32), cfg
    )


def test_rejects_non_multiple_block():
    with pytest.raises(ValueError):
        fakequant.fakequant_tensor(jnp.zeros((4, 33)), ref.NxConfig.nxfp(4))


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n_rows=st.sampled_from([1, 2, 64]),
    log_scale=st.integers(-30, 30),
    cfg_name=st.sampled_from(sorted(CONFIGS)),
)
def test_kernel_matches_oracle_hypothesis(seed, n_rows, log_scale, cfg_name):
    """Property sweep over shapes, dynamic ranges and configs."""
    cfg = CONFIGS[cfg_name]
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(n_rows, 32)) * 2.0 ** log_scale).astype(np.float32)
    got = np.asarray(fakequant.fakequant_blocks(jnp.asarray(x), cfg))
    want = oracle_blocks(x, cfg)
    assert_blocks_equivalent(x, got, want, cfg)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_kernel_error_bounded(seed):
    """|fakequant(x) - x| is bounded by the block's worst-case step."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(16, 32)).astype(np.float32)
    cfg = ref.NxConfig.nxfp(4)
    got = np.asarray(fakequant.fakequant_blocks(jnp.asarray(x), cfg))
    maxabs = np.max(np.abs(x), axis=1, keepdims=True)
    assert np.all(np.abs(got - x) <= maxabs / 2.0 + 1e-30)


def test_vmem_estimate_reasonable():
    # the tile must fit VMEM (~16 MB) with huge headroom
    for cfg in CONFIGS.values():
        assert fakequant.vmem_estimate_bytes(cfg) < 2 * 1024 * 1024
