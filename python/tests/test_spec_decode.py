"""Executable mirror of the precision-speculative decoding round
arithmetic (rust/src/spec/mod.rs).

The container has no cargo toolchain, so the Rust side is desk-checked;
this file re-implements the draft/verify/commit state machine —
provisional proposals, accepted-prefix scan, correction push, bonus
token, the clamp at the token/context budget edge, draft KV rollback,
verifier catch-up, and the accept/reject/forced counters — over a
deterministic toy next-token model, and pins the invariants the Rust
tests assert:

* speculative output is **bit-identical** to verifier-alone greedy decode
  for every draft depth k and every draft-divergence rate (the draft only
  ever proposes; the verifier always decides);
* ``accepted + rejected + forced == tokens_generated`` telescopes,
  including the budget-edge clamp that drops the bonus token;
* each reject rolls exactly ``m - a - 1`` provisional rows off the draft
  (never a prompt or confirmed row), bounded by ``rejected * (k - 1)``;
* the round bookkeeping invariants: zero provisional tokens after every
  round, draft fill ``== len(output) - 1``, verifier fill ``== F`` at the
  next round's feed position;
* one macro round confirms up to ``k + 1`` tokens, so an agreeable draft
  takes strictly fewer rounds per token at ``k > 1`` than at ``k = 1`` —
  the direction the scheduler-step bench metric asserts;
* ``hash01`` and last-max-wins ``greedy_argmax`` match the Rust synthetic
  backend bit-for-bit (constants pinned on both sides, see
  ``hash01_pins_cross_language_constants`` in rust/src/coordinator/mod.rs).

The toy model differs from the Rust SynthBackend (no float lanes here) —
what is pinned is the round arithmetic, whose invariants must hold for
every deterministic model.
"""

M32 = 0xFFFFFFFF
VOCAB = 64


def h24(x):
    """The Rust hash01 pipeline up to its 24-bit integer core."""
    h = (x * 0x9E3779B9) & M32
    h ^= h >> 16
    h = (h * 0x21F0AAAD) & M32
    h ^= h >> 15
    return h >> 8


def hash01(x):
    """Integer hash -> float in [-1, 1). Every step is exact in f32 (the
    mantissa never exceeds 24 bits), so Python's f64 arithmetic produces
    the identical value the Rust f32 path does."""
    return h24(x) * (2.0 / (1 << 24)) - 1.0


def greedy_argmax(row):
    """Last-max-wins, exactly like Rust's ``max_by`` reduction."""
    best, arg = None, -1
    for i, x in enumerate(row):
        if best is None or x >= best:
            best, arg = x, i
    return arg


def test_hash01_pins_cross_language_constants():
    # the same table is asserted by coordinator::tests in Rust
    assert h24(0) == 0
    assert h24(1) == 7_252_763
    assert h24(42) == 5_672_153
    assert h24(97) == 2_100_070
    assert h24(0xDEADBEEF) == 4_914_951
    assert hash01(0) == -1.0
    assert -1.0 <= hash01(0xDEADBEEF) < 1.0


def test_greedy_argmax_keeps_the_last_of_equal_maxima():
    assert greedy_argmax([1.0, 3.0, 2.0, 3.0]) == 3
    assert greedy_argmax([5.0]) == 0
    assert greedy_argmax([2.0, 2.0, 2.0]) == 2


# ---- the toy model -------------------------------------------------------
#
# The verifier's next token is a pure function of the context; the draft
# equals the verifier except where a seeded gate forces a divergence at
# rate `disagree` — the knob that sweeps the acceptance rate from 1.0
# (perfect draft) toward 0.0 (useless draft).


def verifier_next(ctx):
    h = 0
    for t in ctx[-3:]:
        h = h24((h * 31 + t + 1) & M32) & M32
    return h24((h + len(ctx)) & M32) % VOCAB


def draft_next(ctx, disagree):
    v = verifier_next(ctx)
    gate = h24((len(ctx) * 0x9E3779B1 + ctx[-1]) & M32) / float(1 << 24)
    if gate < disagree:
        return (v + 1 + h24(len(ctx) & M32) % 5) % VOCAB
    return v


def plain_decode(prompt, max_new, seq_len):
    """Verifier-alone greedy reference: the bit-identity target."""
    out = list(prompt)
    g = 0
    while g < max_new and len(prompt) + g < seq_len:
        out.append(verifier_next(out))
        g += 1
    return out


class SpecSim:
    """One request through the rust/src/spec round state machine."""

    def __init__(self, prompt, max_new, seq_len, k, disagree):
        assert k >= 1 and len(prompt) >= 1
        self.out = list(prompt)
        self.P = len(prompt)
        self.max_new = max_new
        self.seq_len = seq_len
        self.k = k
        self.disagree = disagree
        self.g = 0  # confirmed generations
        self.fill = self.P - 1  # draft rows (prefill never feeds the last)
        self.vfill = 0  # verifier rows
        self.catch_up_rows = 0
        self.accepted = self.rejected = self.forced = 0
        self.rollback_rows = self.rounds = self.tokens_generated = 0
        self.clamped = 0  # all-accept rounds whose bonus hit the budget edge

    def prov(self):
        return len(self.out) - self.P - self.g

    def round_target(self):
        rem = min(self.max_new - self.g, self.seq_len - self.P - self.g)
        assert rem >= 1, "unfinished request with no remaining budget"
        return min(self.k, rem)

    def draft(self):
        # micro-steps: each feeds the newest token and proposes the next
        while self.prov() < self.round_target():
            self.out.append(draft_next(self.out, self.disagree))
            self.fill += 1
            assert self.fill == len(self.out) - 1

    def verify(self):
        F = self.P + self.g - 1  # feed position of last confirmed token
        m = self.prov()
        rem = min(self.max_new - self.g, self.seq_len - self.P - self.g)
        assert 1 <= m <= rem
        assert self.fill == F + m, "draft fill out of sync with proposals"
        if self.vfill < F:  # catch-up: confirmed history, no sampling
            self.catch_up_rows += F - self.vfill
            self.vfill = F
        # judge: feeding out[F + i] yields the verifier's token for
        # output index P + g + i (== out[F + i + 1] when it matched)
        a = 0
        while a < m and self.out[F + a + 1] == verifier_next(self.out[: F + a + 1]):
            a += 1
        y = verifier_next(self.out[: F + a + 1])
        if a < m:
            # reject: drop the divergent tail, take the correction
            rolled = self.fill - (F + a + 1)
            assert rolled == m - a - 1
            del self.out[self.P + self.g + a:]
            self.out.append(y)
            self.fill = F + a + 1
            self.vfill = F + a + 1
            emitted = a + 1
            self.accepted += a
            self.rejected += 1
            self.rollback_rows += rolled
        elif m < rem:
            # all accepted: the bonus token rides along free and the
            # draft adopts the verifier's row for position F + m
            self.out.append(y)
            self.fill = F + m + 1
            self.vfill = F + m + 1
            emitted = m + 1
            self.accepted += m
            self.forced += 1
        else:
            # all accepted at the exact budget edge: plain greedy stops
            # at rem tokens, so the bonus is dropped
            self.vfill = F + m + 1
            emitted = m
            self.accepted += m
            self.clamped += 1
        self.rounds += 1
        self.tokens_generated += emitted
        self.g += emitted
        # post-round invariants (the Rust debug_asserts)
        assert self.prov() == 0
        assert self.fill == len(self.out) - 1
        done = self.g >= self.max_new or self.P + self.g >= self.seq_len
        if not done:
            assert self.vfill == self.P + self.g - 1, "verifier out of feed position"
        return done

    def run(self):
        while True:
            self.draft()
            if self.verify():
                return self.out


PROMPTS = [[3, 9, 4], [7, 1], [5, 2, 8, 2, 8, 1], [11]]


def test_speculative_output_is_bit_identical_for_every_k_and_fidelity():
    for disagree in [0.0, 0.2, 0.5, 0.9]:
        for k in [1, 2, 4, 8]:
            for prompt in PROMPTS:
                for max_new, seq_len in [(8, 64), (64, 16), (5, 1000)]:
                    want = plain_decode(prompt, max_new, seq_len)
                    sim = SpecSim(prompt, max_new, seq_len, k, disagree)
                    got = sim.run()
                    assert got == want, (
                        f"diverged: k={k} disagree={disagree} prompt={prompt} "
                        f"max_new={max_new} seq_len={seq_len}"
                    )


def test_counters_telescope_and_rollback_is_bounded():
    saw_reject = saw_forced = saw_clamp = False
    for disagree in [0.0, 0.3, 0.7]:
        for k in [1, 2, 4, 8]:
            for prompt in PROMPTS:
                sim = SpecSim(prompt, 10, 64, k, disagree)
                sim.run()
                assert (
                    sim.accepted + sim.rejected + sim.forced == sim.tokens_generated
                ), "accept/reject/bonus counters must telescope"
                assert sim.tokens_generated == sim.g == 10
                assert sim.rollback_rows <= sim.rejected * (k - 1)
                # verifier caught up over exactly the prompt prefix, once
                assert sim.catch_up_rows == len(prompt) - 1
                saw_reject |= sim.rejected > 0
                saw_forced |= sim.forced > 0
                # context-capped run: the clamp drops the final bonus
                cap = SpecSim(prompt, 64, len(prompt) + 6, k, disagree)
                cap.run()
                assert cap.g == 6
                assert cap.accepted + cap.rejected + cap.forced == cap.g
                saw_clamp |= cap.clamped > 0
    assert saw_reject and saw_forced and saw_clamp


def test_draft_gate_actually_sweeps_acceptance():
    # the fidelity knob must produce both regimes, or the matrix above
    # silently stops exercising the reject path
    perfect = SpecSim([3, 9, 4], 30, 64, 4, 0.0)
    perfect.run()
    assert perfect.rejected == 0 and perfect.forced > 0
    lossy = SpecSim([3, 9, 4], 30, 64, 4, 0.9)
    lossy.run()
    assert lossy.rejected > 0


def test_deeper_draft_takes_fewer_rounds_per_token():
    # one verify round per macro scheduler step: with an agreeable draft,
    # k > 1 must confirm the same tokens in strictly fewer rounds than
    # k = 1 — the direction the hotpath bench asserts on steps_per_token
    rounds = {}
    for k in [1, 2, 4, 8]:
        sim = SpecSim([3, 9, 4], 24, 256, k, 0.0)
        sim.run()
        assert sim.g == 24
        rounds[k] = sim.rounds
    assert rounds[2] < rounds[1]
    assert rounds[4] < rounds[2]
    assert rounds[8] < rounds[4]
