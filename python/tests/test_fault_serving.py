"""Executable mirror of the Rust fault-domain serving logic
(rust/src/coordinator/fault.rs + the containment paths in mod.rs and the
policy knobs in scheduler.rs/server.rs).

The container has no cargo toolchain, so the Rust side is desk-checked;
this file re-implements the serving tier's fault state machine — seeded
transient/NaN injection, bounded in-place retry, retire-and-requeue from
the queue front, requeue budgets, queue caps (shed), queue-step deadlines,
and graceful drain — over a deterministic per-slot-pure toy backend and a
refcounted page pool, and drives it through the same scenarios
rust/tests/fault_recovery.rs pins:

* transient faults are invisible: completed outputs are bit-identical to a
  fault-free run, on both the retry and the requeue path;
* engine fault counters match the injector's ground truth exactly;
* an exhausted requeue budget fails only the affected request
  (BackendError) while the engine keeps serving;
* NaN logits are caught before sampling and only the poisoned lane dies
  on exhaustion (clean lanes commit, per-slot purity);
* overload sheds and queue-step deadlines classify, never lose, requests;
* the page pool drains to zero after fault churn;
* drain stops admission, finishes in-flight work, sheds the rest.

The fault *schedules* differ across languages (different RNGs) — what is
pinned is the state machine, whose invariants must hold for every seed.
"""

import random
from collections import deque

COMPLETED, REJECTED, SHED, DEADLINE, BACKEND_ERROR = (
    "completed", "rejected", "shed", "deadline", "backend_error",
)

VOCAB = 97
PAGE_ROWS = 4


def step_token(prompt, output):
    """Per-slot-pure next token: a function of the slot's own history only
    (mirror of SynthBackend's KV-sensitive hash)."""
    acc = len(prompt) * 7
    for t in prompt + output:
        acc = (acc * 31 + t + 1) % 100003
    return acc % VOCAB


class TransientFault(Exception):
    pass


class FatalFault(Exception):
    pass


class FaultyBackend:
    """Mirror of fault.rs FaultBackend: one seeded stream, fixed gate
    order per call, counters as ground truth."""

    def __init__(self, seed, step_rate=0.0, nan_rate=0.0, fatal_at_step=None):
        self.rng = random.Random(seed)
        self.step_rate = step_rate
        self.nan_rate = nan_rate
        self.fatal_at_step = fatal_at_step
        self.calls = 0
        self.step_errors = 0
        self.nan_steps = 0
        self.fatal_errors = 0

    def step(self, lanes):
        """One batched call over the occupied lanes. Returns
        {lane: token_or_nan}; raises on injected errors."""
        self.calls += 1
        if self.fatal_at_step is not None and self.calls == self.fatal_at_step:
            self.fatal_errors += 1
            raise FatalFault(f"injected fatal at call {self.calls}")
        # fixed gate order so the schedule is a pure function of
        # (seed, call sequence): step_err, nan, nan_lane
        step_err = self.rng.random() < self.step_rate
        nan = self.rng.random() < self.nan_rate
        nan_lane = self.rng.randrange(max(len(lanes), 1))
        if step_err:
            self.step_errors += 1
            raise TransientFault(f"injected step error at call {self.calls}")
        out = {b: step_token(sl["prompt"], sl["output"]) for b, sl in lanes.items()}
        if nan:
            self.nan_steps += 1
            # the drawn lane may be empty — the injection still counts,
            # exactly like poisoning an unoccupied lane's logits in Rust
            lane_ids = sorted(lanes)
            if nan_lane < len(lane_ids):
                out[lane_ids[nan_lane]] = float("nan")
        return out


class PagePool:
    def __init__(self):
        self.refs = {}
        self.next_id = 0

    def alloc(self):
        pid = self.next_id
        self.next_id += 1
        self.refs[pid] = 1
        return pid

    def release(self, pid):
        self.refs[pid] -= 1
        if self.refs[pid] == 0:
            del self.refs[pid]

    def live_pages(self):
        return len(self.refs)


class Engine:
    """Mirror of DecodeEngine + Scheduler + the server's admission policy,
    collapsed to the fault-relevant state machine."""

    def __init__(self, backend, lanes=2, retry_max=3, requeue_max=8,
                 queue_cap=None, max_queue_steps=None):
        self.backend = backend
        self.n_lanes = lanes
        self.retry_max = retry_max
        self.requeue_max = requeue_max
        self.queue_cap = queue_cap
        self.max_queue_steps = max_queue_steps
        self.pool = PagePool()
        self.queue = deque()
        self.slots = {}
        self.step_count = 0
        self.draining = False
        self.done = []
        self.counters = dict(step_faults=0, nan_faults=0, retries=0,
                             requeued=0, backend_failed=0, shed=0,
                             deadline_expired=0)

    # ---- admission -----------------------------------------------------
    def submit(self, req):
        if self.draining:
            self.counters["shed"] += 1
            self.done.append((req["id"], None, SHED))
            return False
        if not req["prompt"]:
            self.done.append((req["id"], None, REJECTED))
            return False
        if self.queue_cap is not None and len(self.queue) >= self.queue_cap:
            self.counters["shed"] += 1
            self.done.append((req["id"], None, SHED))
            return False
        self.queue.append({"req": req, "enq_step": self.step_count, "requeues": 0})
        return True

    def _requeue(self, entry):
        # queue *front*: a faulted request re-admits before fresh arrivals
        entry["enq_step"] = self.step_count
        self.queue.appendleft(entry)

    def _admit(self):
        while len(self.slots) < self.n_lanes and self.queue:
            q = self.queue.popleft()
            waited = self.step_count - q["enq_step"]
            if self.max_queue_steps is not None and waited > self.max_queue_steps:
                self.counters["deadline_expired"] += 1
                self.done.append((q["req"]["id"], None, DEADLINE))
                continue
            lane = min(set(range(self.n_lanes)) - set(self.slots))
            sl = {
                "req": q["req"], "prompt": list(q["req"]["prompt"]),
                "output": [], "requeues": q["requeues"], "pages": [],
            }
            # prefill: packed pages cover the prompt rows immediately
            self._grow_pages(sl)
            self.slots[lane] = sl

    def _grow_pages(self, sl):
        rows = len(sl["prompt"]) + len(sl["output"])
        while len(sl["pages"]) * PAGE_ROWS < rows:
            sl["pages"].append(self.pool.alloc())

    # ---- fault containment ---------------------------------------------
    def _retire(self, lane, reason):
        sl = self.slots.pop(lane)
        for pid in sl["pages"]:
            self.pool.release(pid)
        if reason == "requeue" and sl["requeues"] < self.requeue_max:
            self.counters["requeued"] += 1
            self._requeue({"req": sl["req"], "enq_step": self.step_count,
                           "requeues": sl["requeues"] + 1})
        else:
            self.counters["backend_failed"] += 1
            self.done.append((sl["req"]["id"], list(sl["output"]), BACKEND_ERROR))

    def _step_with_retry(self):
        """Mirror of step_with_retry + the pre-sampling NaN scan: returns
        {lane: token} or None if the step was abandoned (slots retired)."""
        attempt = 0
        nan_attempts = 0
        while True:
            try:
                out = self.backend.step(self.slots)
            except TransientFault:
                self.counters["step_faults"] += 1
                attempt += 1
                if attempt > self.retry_max:
                    # exhausted: every occupied slot retires into requeue
                    for lane in sorted(self.slots):
                        self._retire(lane, "requeue")
                    return None
                self.counters["retries"] += 1
                continue
            except FatalFault:
                # fatal: fail the affected slots, keep the engine alive
                for lane in sorted(self.slots):
                    self._retire(lane, "fatal")
                return None
            poisoned = [b for b, t in out.items() if t != t]  # NaN check
            if not poisoned:
                return out
            self.counters["nan_faults"] += 1
            nan_attempts += 1
            if nan_attempts <= self.retry_max:
                self.counters["retries"] += 1
                continue
            # exhausted: only the poisoned lanes die; clean lanes commit
            # (per-slot purity makes the re-run identical for them)
            for lane in poisoned:
                self._retire(lane, "requeue")
            return {b: t for b, t in out.items() if b not in poisoned}

    # ---- the serve loop ------------------------------------------------
    def step(self):
        self._admit()
        if not self.slots:
            self.step_count += 1  # mirror of Scheduler::tick at step end
            return
        out = self._step_with_retry()
        self.step_count += 1
        if not out:
            return
        for lane, tok in sorted(out.items()):
            sl = self.slots[lane]
            sl["output"].append(tok)
            self._grow_pages(sl)
            if len(sl["output"]) >= sl["req"]["max_new"]:
                done = self.slots.pop(lane)
                for pid in done["pages"]:
                    self.pool.release(pid)
                self.done.append((done["req"]["id"], done["output"], COMPLETED))

    def has_work(self):
        return bool(self.queue or self.slots)

    def serve(self):
        while self.has_work():
            self.step()
        return sorted(self.done)

    def drain(self):
        """Mirror of ServerHandle::drain: stop admitting (submit sheds),
        finish everything already accepted."""
        self.draining = True
        return self.serve()


def requests(n=6):
    return [
        {"id": i, "prompt": [1, 2, 3, 4, 5 + i] if i % 2 == 0 else [7 + i, 9],
         "max_new": 3 + i % 3}
        for i in range(n)
    ]


def clean_run():
    eng = Engine(FaultyBackend(seed=0))
    for r in requests():
        assert eng.submit(r)
    return eng.serve()


# ------------------------------------------------------------- scenarios


def test_transient_faults_bit_identical_on_the_retry_path():
    want = clean_run()
    for seed in range(5):
        be = FaultyBackend(seed, step_rate=0.25)
        eng = Engine(be, retry_max=6)
        for r in requests():
            assert eng.submit(r)
        got = eng.serve()
        assert got == want, f"seed {seed} diverged under faults"
        # counter exactness: engine vs injector ground truth
        assert eng.counters["step_faults"] == be.step_errors
        assert eng.counters["backend_failed"] == 0
        assert eng.counters["requeued"] == 0


def test_requeue_path_replays_bit_identically():
    want = clean_run()
    fired = False
    for seed in range(5):
        be = FaultyBackend(seed, step_rate=0.15)
        eng = Engine(be, retry_max=0, requeue_max=10_000)
        for r in requests():
            assert eng.submit(r)
        got = eng.serve()
        assert got == want, f"seed {seed} diverged through requeue"
        assert eng.counters["step_faults"] == be.step_errors
        assert eng.counters["backend_failed"] == 0
        if be.step_errors:
            assert eng.counters["requeued"] > 0
            fired = True
    assert fired


def test_nan_faults_are_caught_before_sampling():
    want = clean_run()
    fired = False
    for seed in range(5):
        be = FaultyBackend(seed, nan_rate=0.2)
        eng = Engine(be, retry_max=6)
        for r in requests():
            assert eng.submit(r)
        got = eng.serve()
        assert got == want
        assert eng.counters["nan_faults"] == be.nan_steps
        fired = fired or be.nan_steps > 0
    assert fired
    # NaN never enters an output stream
    for _, toks, _ in want:
        assert all(isinstance(t, int) for t in toks)


def test_exhausted_requeue_budget_fails_requests_not_the_engine():
    be = FaultyBackend(seed=1, step_rate=1.0)  # every call faults
    eng = Engine(be, retry_max=0, requeue_max=1)
    for r in requests():
        assert eng.submit(r)
    got = eng.serve()
    assert len(got) == len(requests())
    assert all(reason == BACKEND_ERROR for _, _, reason in got)
    # exactly one requeue per request before the budget trips
    assert eng.counters["requeued"] == len(requests())
    assert eng.counters["backend_failed"] == len(requests())
    # fault churn leaked nothing
    assert eng.pool.live_pages() == 0
    # the engine still serves: swap in a clean backend, same instance
    eng.backend = FaultyBackend(seed=0)
    assert eng.submit({"id": 99, "prompt": [1, 2], "max_new": 2})
    more = eng.serve()
    assert any(i == 99 and reason == COMPLETED for i, _, reason in more)


def test_fatal_fault_fails_only_the_affected_slots():
    be = FaultyBackend(seed=0, fatal_at_step=4)
    eng = Engine(be)
    for r in requests():
        assert eng.submit(r)
    got = eng.serve()
    assert be.fatal_errors == 1
    assert len(got) == len(requests())
    failed = sum(1 for _, _, reason in got if reason == BACKEND_ERROR)
    completed = sum(1 for _, _, reason in got if reason == COMPLETED)
    assert failed >= 1 and completed >= 1
    assert failed + completed == len(got)
    # the completed ones match the clean run exactly
    clean = dict((i, t) for i, t, _ in clean_run())
    for i, toks, reason in got:
        if reason == COMPLETED:
            assert toks == clean[i]


def test_queue_cap_sheds_overflow_without_losing_requests():
    eng = Engine(FaultyBackend(seed=0), queue_cap=2)
    accepted = sum(1 for r in requests() if eng.submit(r))
    assert accepted == 2
    assert eng.counters["shed"] == 4
    got = eng.serve()
    assert len(got) == len(requests())  # every request answered
    assert sum(1 for _, _, r in got if r == SHED) == 4
    assert sum(1 for _, _, r in got if r == COMPLETED) == 2


def test_queue_steps_deadline_expires_only_the_stale_tail():
    eng = Engine(FaultyBackend(seed=0), max_queue_steps=0)
    for r in requests():
        assert eng.submit(r)
    got = eng.serve()
    assert len(got) == len(requests())
    expired = sum(1 for _, _, r in got if r == DEADLINE)
    completed = sum(1 for _, _, r in got if r == COMPLETED)
    assert expired + completed == len(got)
    assert completed >= 2, "the head of the queue admits fresh"
    assert expired >= 1, "the waiting tail must expire"
    assert eng.counters["deadline_expired"] == expired


def test_drain_finishes_in_flight_and_sheds_new_submits():
    eng = Engine(FaultyBackend(seed=0, step_rate=0.2), retry_max=6)
    for r in requests(4):
        assert eng.submit(r)
    # a few steps in, drain: accepted work must still complete
    eng.step()
    eng.step()
    eng.drain()
    assert not eng.submit({"id": 51, "prompt": [3], "max_new": 1})
    got = sorted(eng.done)
    by_id = {i: reason for i, _, reason in got}
    for r in requests(4):
        assert by_id[r["id"]] == COMPLETED
    assert by_id[51] == SHED
    assert eng.pool.live_pages() == 0


def test_rejected_requests_never_queue():
    eng = Engine(FaultyBackend(seed=0))
    assert not eng.submit({"id": 0, "prompt": [], "max_new": 3})
    assert eng.done == [(0, None, REJECTED)]
    assert not eng.has_work()
