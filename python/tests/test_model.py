"""L2 model tests: shapes, loss behaviour, the train/eval/score/decode step
contracts, and decode-vs-forward consistency (the KV-cache path must compute
the same logits as full-sequence attention)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

SPEC = model.LmSpec.tiny()


def init_params(spec, seed=0):
    """Same initializer family as rust Checkpoint::init (scaled normal,
    ones for norms)."""
    rng = np.random.default_rng(seed)
    out = []
    shapes = model.param_shapes(spec)
    for name in model.param_names(spec):
        r, c = shapes[name]
        if r == 1:
            out.append(jnp.ones((r, c), jnp.float32))
        else:
            std = min(0.02, (2.0 / (r + c)) ** 0.5)
            out.append(jnp.asarray(rng.normal(0, std, size=(r, c)).astype(np.float32)))
    return out


def random_tokens(spec, batch, seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, spec.vocab, size=(batch, spec.seq_len + 1), dtype=np.int32))


def test_param_names_order_contract():
    names = model.param_names(SPEC)
    assert names[0] == "embed"
    assert names[1] == "pos_embed"
    assert names[2] == "l0.ln1"
    assert names[-1] == "unembed"
    assert len(names) == 2 + 8 * SPEC.n_layers + 2


def test_forward_shapes():
    params = init_params(SPEC)
    toks = random_tokens(SPEC, 2)[:, :-1]
    logits = model.forward(SPEC, params, toks)
    assert logits.shape == (2, SPEC.seq_len, SPEC.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform():
    params = init_params(SPEC)
    toks = random_tokens(SPEC, 4)
    loss = float(model.loss_fn(SPEC, params, toks))
    assert abs(loss - np.log(SPEC.vocab)) < 0.5


def test_causality():
    """Changing a future token must not affect earlier logits."""
    params = init_params(SPEC)
    toks = np.asarray(random_tokens(SPEC, 1)[:, :-1])
    logits1 = model.forward(SPEC, params, jnp.asarray(toks))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % SPEC.vocab
    logits2 = model.forward(SPEC, params, jnp.asarray(toks2))
    np.testing.assert_allclose(
        np.asarray(logits1[0, :-1]), np.asarray(logits2[0, :-1]), rtol=1e-5, atol=1e-5
    )


def test_train_step_reduces_loss_on_fixed_batch():
    params = init_params(SPEC)
    n = len(params)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    toks = random_tokens(SPEC, 4)
    step = jax.jit(model.make_train_step(SPEC))
    losses = []
    for t in range(1, 31):
        out = step(*params, *m, *v, jnp.float32(t), toks)
        params, m, v = list(out[:n]), list(out[n:2 * n]), list(out[2 * n:3 * n])
        losses.append(float(out[3 * n]))
    assert losses[-1] < losses[0] - 1.0, f"{losses[0]} -> {losses[-1]}"
    assert all(np.isfinite(l) for l in losses)


def test_eval_step_counts():
    params = init_params(SPEC)
    toks = random_tokens(SPEC, 2)
    sum_nll, count = model.make_eval_step(SPEC)(*params, toks)
    assert int(count) == 2 * SPEC.seq_len
    assert float(sum_nll) / float(count) == pytest.approx(np.log(SPEC.vocab), rel=0.15)


def test_score_step_matches_eval_step():
    params = init_params(SPEC)
    toks = random_tokens(SPEC, 2)
    (nll,) = model.make_score_step(SPEC)(*params, toks)
    sum_nll, count = model.make_eval_step(SPEC)(*params, toks)
    assert nll.shape == (2, SPEC.seq_len)
    assert float(jnp.sum(nll)) == pytest.approx(float(sum_nll), rel=1e-5)


def test_eval_step_kvq_degrades_gracefully():
    params = init_params(SPEC)
    toks = random_tokens(SPEC, 2)
    base, _ = model.make_eval_step(SPEC)(*params, toks)
    # head_dim=16 < block 32 would straddle heads; use block 16 for tiny spec
    cfg4 = ref.NxConfig(**{**ref.NxConfig.nxfp(4).__dict__, "block_size": 16})
    cfg8 = ref.NxConfig(bits=8, elem_mx=(4, 3), base_mx=True, block_size=16)
    q4, _ = model.make_eval_step(SPEC, kv_cfg=cfg4)(*params, toks)
    q8, _ = model.make_eval_step(SPEC, kv_cfg=cfg8)(*params, toks)
    # 8-bit KV ~ lossless; 4-bit worse than 8-bit on an untrained net is not
    # guaranteed, but both must stay finite and close to base
    assert abs(float(q8) - float(base)) / float(base) < 0.02
    assert abs(float(q4) - float(base)) / float(base) < 0.30


def test_decode_step_matches_forward():
    """Teacher-forced decode through the KV cache must reproduce the
    full-sequence forward logits position by position."""
    spec = SPEC
    params = init_params(spec)
    b = 2
    rng = np.random.default_rng(9)
    toks = rng.integers(0, spec.vocab, size=(b, 8), dtype=np.int32)
    full_logits = np.asarray(model.forward(spec, params, jnp.asarray(toks)))

    decode = jax.jit(model.make_decode_step(spec))
    L, S, D = spec.n_layers, spec.seq_len, spec.d_model
    k_cache = jnp.zeros((b, L, S, D), jnp.float32)
    v_cache = jnp.zeros((b, L, S, D), jnp.float32)
    for pos in range(8):
        tok = jnp.asarray(toks[:, pos])
        posv = jnp.full((b,), pos, jnp.int32)
        logits, k_new, v_new = decode(*params, tok, posv, k_cache, v_cache)
        np.testing.assert_allclose(
            np.asarray(logits), full_logits[:, pos], rtol=2e-4, atol=2e-4
        )
        # rust appends the returned row at index pos; emulate
        k_cache = k_cache.at[:, :, pos].set(k_new)
        v_cache = v_cache.at[:, :, pos].set(v_new)
