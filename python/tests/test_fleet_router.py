"""Executable mirror of the fleet serving tier (rust/src/coordinator/
router.rs, the LRU PrefixCache in scheduler.rs, and the ServingMetrics
rollup in metrics.rs).

The container has no cargo toolchain, so the Rust side is desk-checked;
this file re-implements the three novel pieces of the multi-replica PR in
plain Python and drives them through the same scenarios the Rust unit and
integration tests pin:

- the prefix-affinity router: least-loaded dispatch with a radix tree over
  dispatched prompts, a slack window that lets affinity override load, and
  owner-preserving edge splits ("first dispatcher owns the prefix");
- the LRU radix prefix cache that replaced the PR 6 epoch reset: per-entry
  logical-clock touches, least-recently-used eviction releasing page refs,
  and deepest-first path repair keeping the tree consistent under churn;
- the log-bucketed histogram merge and fleet rollup: counter sums are
  exact, geometry mismatches surface as errors (never panics), and a
  mismatch on one histogram does not corrupt the others.

A divergence between the two implementations shows up here as a failure
against the numbers documented in rust/src/coordinator/router.rs and
rust/tests/fleet_router.rs.
"""

import math

import pytest

# ---------------------------------------------------------------- router

MAX_AFF_NODES = 4096
DEFAULT_MIN_AFFINITY = 8


class Router:
    """Mirror of rust `coordinator::router::Router`."""

    def __init__(self, n_replicas, slack):
        assert n_replicas > 0
        # nodes[0] is the sentinel root: (edge, replica, children)
        self.nodes = [([], 0, [])]
        self.outstanding = [0] * n_replicas
        self.routable = [True] * n_replicas
        self.min_affinity = DEFAULT_MIN_AFFINITY
        self.slack = max(slack, 1)

    def least_loaded(self):
        live = [(o, i) for i, o in enumerate(self.outstanding) if self.routable[i]]
        if not live:
            live = list(zip(self.outstanding, range(len(self.outstanding))))
        return min(live)[1]

    def affinity(self, prompt):
        node, depth, best = 0, 0, None
        while True:
            nxt = next(
                (
                    c
                    for c in self.nodes[node][2]
                    if self.nodes[c][0][:1] == list(prompt[depth : depth + 1])
                ),
                None,
            )
            if nxt is None:
                break
            edge = self.nodes[nxt][0]
            m = 0
            while m < len(edge) and depth + m < len(prompt) and edge[m] == prompt[depth + m]:
                m += 1
            if m > 0:
                best = (depth + m, self.nodes[nxt][1])
            if m < len(edge) or depth + m >= len(prompt):
                break
            depth += m
            node = nxt
        if best and best[0] >= self.min_affinity:
            return best[1]
        return None

    def register(self, prompt, replica):
        if len(self.nodes) >= MAX_AFF_NODES:
            return
        node, depth = 0, 0
        while depth < len(prompt):
            nxt = next(
                (c for c in self.nodes[node][2] if self.nodes[c][0][:1] == [prompt[depth]]),
                None,
            )
            if nxt is None:
                self.nodes.append((list(prompt[depth:]), replica, []))
                self.nodes[node][2].append(len(self.nodes) - 1)
                return
            edge = self.nodes[nxt][0]
            m = 0
            while m < len(edge) and depth + m < len(prompt) and edge[m] == prompt[depth + m]:
                m += 1
            if m == len(edge):
                node, depth = nxt, depth + m
                continue
            # split: the mid node inherits the deeper node's owner — the
            # first dispatcher keeps owning the shared prefix
            tail = edge[m:]
            self.nodes[nxt] = (tail, self.nodes[nxt][1], self.nodes[nxt][2])
            mid = (edge[:m], self.nodes[nxt][1], [nxt])
            self.nodes.append(mid)
            kids = self.nodes[node][2]
            kids[kids.index(nxt)] = len(self.nodes) - 1
            node, depth = len(self.nodes) - 1, depth + m

    def route(self, prompt):
        least = self.least_loaded()
        aff = self.affinity(prompt)
        if (
            aff is not None
            and self.routable[aff]
            and self.outstanding[aff] < self.outstanding[least] + self.slack
        ):
            choice = aff
        else:
            choice = least
        self.outstanding[choice] += 1
        self.register(prompt, choice)
        return choice

    def complete(self, replica):
        self.outstanding[replica] = max(0, self.outstanding[replica] - 1)


def sys_prompt(tag, n=12):
    return [(tag * 11 + t * 3) % 47 for t in range(n)]


def test_least_loaded_breaks_ties_low_and_skips_unroutable():
    r = Router(3, 1)
    assert r.least_loaded() == 0
    r.outstanding = [2, 1, 1]
    assert r.least_loaded() == 1
    r.routable[1] = False
    assert r.least_loaded() == 2


def test_affinity_sticks_within_slack_then_spills():
    r = Router(2, 2)
    p = lambda sfx: sys_prompt(0) + [sfx]
    assert r.route(p(1)) == 0  # no affinity yet: least-loaded
    assert r.route(p(2)) == 0  # affinity holds within slack
    # outstanding [2, 0]: the guard 2 < 0 + 2 fails, so the router spills
    assert r.route(p(3)) == 1
    r.complete(0)
    r.complete(0)
    assert r.route(p(4)) == 0  # load drained: affinity resumes
    # a 2-token match is below min_affinity: least-loaded wins
    short = Router(2, 2)
    short.route(sys_prompt(0))
    short.outstanding = [1, 0]
    assert short.route(sys_prompt(0)[:2] + [99] * 6) == 1


def test_affinity_owner_survives_edge_splits():
    r = Router(3, 8)
    base = sys_prompt(1, 16)
    assert r.route(base) == 0
    # a prompt diverging at token 10 splits the edge; the mid node must
    # keep replica 0 as owner, so the original prefix still routes home
    r.outstanding = [0, 0, 0]
    r.route(base[:10] + [99] * 6)
    r.outstanding = [1, 1, 0]  # least-loaded would say 2
    assert r.affinity(base) == 0


def test_unroutable_affinity_falls_through_to_least_loaded():
    r = Router(2, 8)
    p = sys_prompt(2) + [7]
    assert r.route(p) == 0
    r.routable[0] = False
    assert r.route(sys_prompt(2) + [8]) == 1


def xorshift32(seed):
    x = seed or 1

    def step():
        nonlocal x
        x ^= (x << 13) & 0xFFFFFFFF
        x ^= x >> 17
        x ^= (x << 5) & 0xFFFFFFFF
        return x

    return step


def test_dispatch_is_deterministic_for_a_seeded_arrival_order():
    def run():
        rng = xorshift32(0xC0FFEE)
        r = Router(4, 2)
        routes = []
        for _ in range(64):
            fam = rng() % 3
            prompt = sys_prompt(fam) + [rng() % 40, rng() % 40]
            routes.append(r.route(prompt))
            if rng() % 4 == 0 and any(r.outstanding):
                busy = max(range(4), key=lambda i: r.outstanding[i])
                r.complete(busy)
        return routes

    a, b = run(), run()
    assert a == b
    assert len(set(a)) > 1  # the workload actually spread across replicas


def test_node_cap_degrades_to_least_loaded_not_failure():
    r = Router(2, 1)
    r.nodes = r.nodes * MAX_AFF_NODES  # saturate the tree
    assert r.route(sys_prompt(3) + [1]) in (0, 1)
    assert sum(r.outstanding) == 1  # routed fine, just unregistered


# ------------------------------------------------- LRU radix prefix cache


class PagePool:
    """Refcounted page pool, as in test_paged_kv.py but tracking live ids."""

    def __init__(self):
        self.refs = {}
        self.next_id = 0

    def alloc(self):
        pid = self.next_id
        self.next_id += 1
        self.refs[pid] = 1
        return pid

    def retain(self, pid):
        self.refs[pid] += 1

    def release(self, pid):
        self.refs[pid] -= 1
        if self.refs[pid] == 0:
            del self.refs[pid]


class PrefixCache:
    """Mirror of the LRU `PrefixCache` in rust coordinator/scheduler.rs.

    Entries live in a slab (evicted slots are reused so node->entry
    indices stay stable); every lookup/registration touch bumps a logical
    clock; at capacity the least-recently-touched entry is evicted,
    releasing its page refs and repairing the radix path deepest-first.
    """

    def __init__(self, pool, max_entries):
        self.pool = pool
        self.nodes = []  # [edge, entry, children]
        self.entries = []  # slab of dict|None
        self.free_entries = []
        self.free_nodes = []
        self.clock = 0
        self.max_entries = max(max_entries, 1)

    def live_entries(self):
        return len(self.entries) - len(self.free_entries)

    def touch(self, entry):
        self.clock += 1
        if self.entries[entry] is not None:
            self.entries[entry]["last_used"] = self.clock

    def lookup(self, prompt):
        if not self.nodes:
            return None
        node, depth, best = 0, 0, None
        while True:
            nxt = next(
                (
                    c
                    for c in self.nodes[node][2]
                    if self.nodes[c][0][:1] == list(prompt[depth : depth + 1])
                ),
                None,
            )
            if nxt is None:
                break
            edge = self.nodes[nxt][0]
            m = 0
            while m < len(edge) and depth + m < len(prompt) and edge[m] == prompt[depth + m]:
                m += 1
            depth += m
            best = (depth, self.nodes[nxt][1])
            if m < len(edge) or depth == len(prompt):
                break
            node = nxt
        if best:
            self.touch(best[1])
        return best

    def register(self, prompt, pages):
        if not prompt:
            return
        hit = self.lookup(prompt)
        if hit and hit[0] == len(prompt):
            return
        if self.live_entries() >= self.max_entries:
            self.evict_lru()
        for pid in pages:
            self.pool.retain(pid)
        self.clock += 1
        e = {"pages": list(pages), "prompt": list(prompt), "last_used": self.clock}
        if self.free_entries:
            entry = self.free_entries.pop()
            self.entries[entry] = e
        else:
            self.entries.append(e)
            entry = len(self.entries) - 1
        self.insert(prompt, entry)

    def evict_lru(self):
        live = [(e["last_used"], i) for i, e in enumerate(self.entries) if e is not None]
        if not live:
            return
        victim = min(live)[1]
        e = self.entries[victim]
        self.entries[victim] = None
        self.free_entries.append(victim)
        for pid in e["pages"]:
            self.pool.release(pid)
        self.repair_path(e["prompt"], victim)

    def repair_path(self, prompt, victim):
        if not self.nodes:
            return
        path, node, depth = [0], 0, 0
        while depth < len(prompt):
            nxt = next(
                (c for c in self.nodes[node][2] if self.nodes[c][0][:1] == [prompt[depth]]),
                None,
            )
            if nxt is None:
                break
            edge_len = len(self.nodes[nxt][0])
            if len(prompt) - depth < edge_len:
                break
            path.append(nxt)
            depth += edge_len
            node = nxt
        for i in reversed(range(len(path))):
            n = path[i]
            if self.nodes[n][1] != victim:
                continue
            if self.nodes[n][2]:
                self.nodes[n][1] = self.nodes[self.nodes[n][2][0]][1]
            elif i == 0:
                self.nodes = []
                self.free_nodes = []
            else:
                parent = path[i - 1]
                self.nodes[parent][2].remove(n)
                self.nodes[n][0] = []
                self.free_nodes.append(n)

    def new_node(self, edge, entry, children):
        n = [list(edge), entry, children]
        if self.free_nodes:
            i = self.free_nodes.pop()
            self.nodes[i] = n
            return i
        self.nodes.append(n)
        return len(self.nodes) - 1

    def insert(self, prompt, entry):
        if not self.nodes:
            self.nodes.append([[], entry, []])
        node, depth = 0, 0
        while True:
            nxt = next(
                (
                    c
                    for c in self.nodes[node][2]
                    if self.nodes[c][0][:1] == list(prompt[depth : depth + 1])
                ),
                None,
            )
            if nxt is None:
                if depth < len(prompt):
                    leaf = self.new_node(prompt[depth:], entry, [])
                    self.nodes[node][2].append(leaf)
                return
            edge = self.nodes[nxt][0]
            m = 0
            while m < len(edge) and depth + m < len(prompt) and edge[m] == prompt[depth + m]:
                m += 1
            if m == len(edge):
                depth += m
                if depth == len(prompt):
                    return  # existing path already spells the prompt
                node = nxt
                continue
            # edge diverges at m: split with a mid node inheriting nxt's
            # entry (that entry's prompt runs through it)
            tail = edge[m:]
            self.nodes[nxt][0] = tail
            mid = self.new_node(edge[:m], self.nodes[nxt][1], [nxt])
            kids = self.nodes[node][2]
            kids[kids.index(nxt)] = mid
            if depth + m < len(prompt):
                leaf = self.new_node(prompt[depth + m :], entry, [])
                self.nodes[mid][2].append(leaf)
            return


def test_lru_evicts_cold_entry_and_releases_its_pages():
    pool = PagePool()
    cache = PrefixCache(pool, max_entries=2)
    pa, pb, pc = pool.alloc(), pool.alloc(), pool.alloc()
    cache.register([1, 2, 3], [pa])
    cache.register([4, 5, 6], [pb])
    assert pool.refs[pa] == 2 and pool.refs[pb] == 2
    cache.lookup([1, 2, 3])  # touch A: B becomes the LRU victim
    cache.register([7, 8, 9], [pc])
    assert cache.live_entries() == 2
    assert pool.refs[pa] == 2 and pool.refs[pc] == 2
    assert pool.refs[pb] == 1  # cache ref released, original holder remains
    assert cache.lookup([4, 5, 6]) is None
    assert cache.lookup([1, 2, 3]) is not None


def test_eviction_repairs_split_paths_and_reuses_slots():
    pool = PagePool()
    cache = PrefixCache(pool, max_entries=2)
    pages = [pool.alloc() for _ in range(3)]
    cache.register([1, 2, 3, 4, 5, 6], [pages[0]])
    # shares [1,2,3]: splits the first entry's edge
    cache.register([1, 2, 3, 9, 9, 9], [pages[1]])
    cache.lookup([1, 2, 3, 9, 9, 9])  # victim will be the first entry
    cache.register([8, 8, 8], [pages[2]])
    # the split survivor still resolves through the repaired mid node
    hit = cache.lookup([1, 2, 3, 9, 9, 9])
    assert hit is not None and hit[0] == 6
    assert cache.lookup([1, 2, 3, 4, 5, 6])[0] == 3  # only the shared part
    # slab churn: evicted entry/node slots are reused, not leaked
    assert len(cache.free_entries) + cache.live_entries() == len(cache.entries)
    before = len(cache.nodes)
    cache.register([1, 2, 3, 4, 0, 0], [pool.alloc()])
    assert len(cache.nodes) <= before + 2


def test_churn_never_leaks_page_refs():
    pool = PagePool()
    cache = PrefixCache(pool, max_entries=4)
    owned = []
    for i in range(64):
        pid = pool.alloc()
        owned.append(pid)
        cache.register([i % 8, i % 5, i, i + 1], [pid])
    live_cache_refs = sum(pool.refs[p] - 1 for p in owned if p in pool.refs)
    assert cache.live_entries() <= 4
    assert live_cache_refs == sum(
        len(e["pages"]) for e in cache.entries if e is not None
    )


# --------------------------------------- histogram merge and fleet rollup


class Histogram:
    """Mirror of rust `coordinator::metrics::Histogram` (+ merge)."""

    def __init__(self, lo, hi, buckets):
        assert lo > 0 and hi > lo and buckets >= 2
        self.lo = lo
        self.growth = (hi / lo) ** (1.0 / buckets)
        self.counts = [0] * buckets
        self.total = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def geometry(self):
        return (self.lo, self.growth, len(self.counts))

    def bucket(self, v):
        if v <= self.lo:
            return 0
        return min(int(math.log(v / self.lo) / math.log(self.growth)), len(self.counts) - 1)

    def record(self, v):
        v = v if (math.isfinite(v) and v > 0) else 0.0
        self.counts[self.bucket(v)] += 1
        self.total += 1
        self.sum += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)

    def merge(self, other):
        if self.geometry() != other.geometry():
            raise ValueError(
                f"histogram geometry mismatch: {self.geometry()} vs {other.geometry()}"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.total += other.total
        self.sum += other.sum
        if other.total:
            self.min = min(self.min, other.min)
            self.max = max(self.max, other.max)


COUNTERS = (
    "admitted",
    "promoted",
    "rejected",
    "prefix_hits",
    "prefix_misses",
    "step_faults",
    "chunk_faults",
    "nan_faults",
    "retries",
    "requeued",
    "backend_failed",
    "shed",
    "deadline_expired",
)


class ServingMetrics:
    """Counters + named histograms, with the rollup-merge contract."""

    def __init__(self, latency_geom=(1e-6, 1e3, 162)):
        for c in COUNTERS:
            setattr(self, c, 0)
        self.hists = {
            "latency": Histogram(*latency_geom),
            "ttft": Histogram(1e-6, 1e3, 162),
            "wait_steps": Histogram(1.0, 1e6, 108),
        }

    def merge(self, other):
        """Counter sums are exact and unconditional; histogram geometry
        mismatches are collected as errors, mirroring the Rust behavior of
        `ServingMetrics::merge` returning `Err` instead of panicking."""
        for c in COUNTERS:
            setattr(self, c, getattr(self, c) + getattr(other, c))
        errs = []
        for name, h in self.hists.items():
            try:
                h.merge(other.hists[name])
            except ValueError as e:
                errs.append(f"{name}: {e}")
        return errs


def test_merge_sums_counters_and_buckets_exactly():
    a, b = ServingMetrics(), ServingMetrics()
    a.admitted, b.admitted = 3, 5
    a.prefix_hits, b.prefix_hits = 2, 9
    for v in (0.001, 0.25):
        a.hists["latency"].record(v)
    b.hists["latency"].record(40.0)
    assert a.merge(b) == []
    assert a.admitted == 8 and a.prefix_hits == 11
    assert a.hists["latency"].total == 3
    assert a.hists["latency"].min == 0.001 and a.hists["latency"].max == 40.0
    assert sum(a.hists["latency"].counts) == 3


def test_geometry_mismatch_is_an_error_with_exact_counters():
    a = ServingMetrics()
    b = ServingMetrics(latency_geom=(1e-3, 1e2, 50))
    a.shed, b.shed = 1, 2
    b.hists["latency"].record(0.5)
    b.hists["ttft"].record(0.1)
    errs = a.merge(b)
    # exactly the mismatched histogram errors; the others merged fine
    assert len(errs) == 1 and errs[0].startswith("latency:"), errs
    assert "geometry mismatch" in errs[0]
    assert a.shed == 3  # counters summed despite the error
    assert a.hists["latency"].total == 0  # mismatched hist left untouched
    assert a.hists["ttft"].total == 1  # disjoint histograms unaffected


def test_fleet_rollup_equals_per_replica_sums():
    replicas = []
    for i in range(4):
        s = ServingMetrics()
        s.admitted = 7 + i
        s.requeued = i
        for k in range(i + 1):
            s.hists["latency"].record(0.01 * (k + 1))
        replicas.append(s)
    rollup = ServingMetrics()
    errors = []
    for i, r in enumerate(replicas):
        errors.extend(f"replica {i}: {e}" for e in rollup.merge(r))
    assert errors == []
    assert rollup.admitted == sum(r.admitted for r in replicas)
    assert rollup.requeued == sum(r.requeued for r in replicas)
    assert rollup.hists["latency"].total == sum(
        r.hists["latency"].total for r in replicas
    )


if __name__ == "__main__":
    raise SystemExit(pytest.main([__file__, "-q"]))
