"""Executable mirror of the Rust paged-KV machinery (rust/src/quant/page.rs,
kv_cache.rs Stream paging, coordinator/scheduler.rs PrefixCache).

The container has no cargo toolchain, so the Rust side is desk-checked; this
file re-implements the page pool, COW append rule, radix prefix cache, and
dedup accounting in ~100 lines of Python and drives them through the same
scenarios the Rust unit/integration tests pin (same geometries, same
expected refcounts, same dedup factor). A divergence between the two
implementations shows up as a failure here against the numbers documented
in rust/tests/prefix_sharing.rs.

Also pins the cross-language artifact-name contract: `nxfp eval` (rust
kvq_layered_artifact_name) and `aot.py --kvq-layers` must derive the same
FNV-1a hash from the same format tokens, or eval loads a missing artifact.
"""

import numpy as np
import pytest

from compile import model
from compile.kernels import ref

aot = pytest.importorskip("compile.aot")


# ---------------------------------------------------------------- mirrors


class PagePool:
    """Mirror of rust `quant::page::PagePool`: refcounted fixed-size pages."""

    def __init__(self, page_rows):
        self.page_rows = page_rows
        self.entries = {}  # id -> [rows(list), refs, accounted]
        self.next_id = 0
        self.cow_copies = 0

    def alloc(self):
        pid = self.next_id
        self.next_id += 1
        self.entries[pid] = [[], 1, False]
        return pid

    def retain(self, pid):
        self.entries[pid][1] += 1

    def release(self, pid):
        e = self.entries[pid]
        e[1] -= 1
        if e[1] == 0:
            del self.entries[pid]

    def refs(self, pid):
        return self.entries[pid][1]

    def rows(self, pid):
        return self.entries[pid][0]

    def cow(self, pid, keep_rows):
        """Copy the first keep_rows into a fresh exclusive page and drop
        one reference on the shared original."""
        new = self.alloc()
        self.entries[new][0] = list(self.entries[pid][0][:keep_rows])
        self.release(pid)
        self.cow_copies += 1
        return new

    def live_pages(self):
        return len(self.entries)

    def shared_pages(self):
        return sum(1 for e in self.entries.values() if e[1] > 1)


class Stream:
    """Mirror of one packed KV stream (rust kv_cache.rs `Stream`): a page
    table over the pool, COW-on-first-divergent-append."""

    def __init__(self, pool):
        self.pool = pool
        self.pages = []
        self.fill = 0

    def adopt(self, rows, page_ids):
        assert self.fill == 0 and not self.pages
        for pid in page_ids:
            self.pool.retain(pid)
        self.pages = list(page_ids)
        self.fill = rows

    def append(self, row):
        local = self.fill % self.pool.page_rows
        if local == 0 and self.fill == len(self.pages) * self.pool.page_rows:
            self.pages.append(self.pool.alloc())
        tail = self.pages[-1]
        if self.pool.refs(tail) > 1:
            tail = self.pool.cow(tail, local)
            self.pages[-1] = tail
        rows = self.pool.rows(tail)
        del rows[local:]  # adopted tail may hold rows past our fill
        rows.append(row)
        self.fill += 1

    def logical(self):
        out = []
        for i, pid in enumerate(self.pages):
            take = min(self.fill - i * self.pool.page_rows, self.pool.page_rows)
            out.extend(self.pool.rows(pid)[:take])
        return out

    def take_dedup_rows(self):
        """Mirror of take_dedup_bits in row units: charge each page once
        across all completed streams."""
        total = 0
        for i, pid in enumerate(self.pages):
            e = self.pool.entries[pid]
            if not e[2]:
                e[2] = True
                total += min(self.fill - i * self.pool.page_rows, self.pool.page_rows)
        return total

    def drop(self):
        for pid in self.pages:
            self.pool.release(pid)
        self.pages, self.fill = [], 0


class PrefixCache:
    """Mirror of the scheduler's radix tree: nodes = (edge, entry, children)."""

    def __init__(self):
        self.nodes = [[[], None, []]]
        self.entries = []  # (rows, page_ids); pool refs elided in the mirror

    def lookup(self, prompt):
        node, depth, best = 0, 0, None
        while depth < len(prompt):
            nxt = next(
                (c for c in self.nodes[node][2]
                 if self.nodes[c][0][0] == prompt[depth]),
                None,
            )
            if nxt is None:
                break
            edge = self.nodes[nxt][0]
            m = 0
            while m < len(edge) and depth + m < len(prompt) and edge[m] == prompt[depth + m]:
                m += 1
            depth += m
            best = (depth, self.nodes[nxt][1])
            if m < len(edge):
                break
            node = nxt
        return best

    def register(self, prompt, rows, page_ids):
        if not prompt:
            return
        hit = self.lookup(prompt)
        if hit and hit[0] == len(prompt):
            return
        entry = len(self.entries)
        self.entries.append((rows, page_ids))
        node, depth = 0, 0
        while True:
            nxt = next(
                (c for c in self.nodes[node][2]
                 if self.nodes[c][0][0] == prompt[depth]),
                None,
            )
            if nxt is None:
                self.nodes.append([list(prompt[depth:]), entry, []])
                self.nodes[node][2].append(len(self.nodes) - 1)
                return
            edge = self.nodes[nxt][0]
            m = 0
            while m < len(edge) and depth + m < len(prompt) and edge[m] == prompt[depth + m]:
                m += 1
            if m == len(edge):
                depth += m
                node = nxt
                if depth == len(prompt):
                    self.nodes[nxt][1] = entry
                    return
                continue
            # split the edge at m: intermediate node inherits the child
            head, tail = edge[:m], edge[m:]
            self.nodes[nxt][0] = tail
            mid = len(self.nodes)
            self.nodes.append([head, self.nodes[nxt][1], [nxt]])
            self.nodes[node][2] = [mid if c == nxt else c for c in self.nodes[node][2]]
            depth += m
            if depth == len(prompt):
                self.nodes[mid][1] = entry
            else:
                self.nodes.append([list(prompt[depth:]), entry, []])
                self.nodes[mid][2].append(len(self.nodes) - 1)
            return


# ------------------------------------------------------- mirror scenarios


def test_radix_longest_prefix_matches_rust_unit_test():
    """Same prompts and expectations as scheduler.rs
    radix_lookup_finds_longest_registered_prefix."""
    pc = PrefixCache()
    pc.register([1, 2, 3, 4], 4, [])
    pc.register([1, 2, 9], 3, [])
    assert pc.lookup([1, 2, 3, 4]) == (4, 0)
    assert pc.lookup([1, 2, 3, 7]) == (3, 0)  # partial edge
    assert pc.lookup([1, 2, 9, 5]) == (3, 1)
    assert pc.lookup([1, 2, 5]) == (2, 0)  # stops at the split point
    assert pc.lookup([7, 1]) is None
    pc.register([1, 2, 3, 4], 4, [])  # covered: no new entry
    assert len(pc.entries) == 2


def test_cow_preserves_the_donor_and_diverges_the_adopter():
    pool = PagePool(4)
    donor = Stream(pool)
    for r in range(6):
        donor.append(("d", r))
    # register rows 0..4 (one full page) the way the scheduler would
    shared = donor.pages[:1]
    for pid in shared:
        pool.retain(pid)

    adopter = Stream(pool)
    adopter.adopt(4, shared)
    assert pool.refs(shared[0]) == 3  # donor + cache + adopter
    adopter.append(("a", 4))
    # divergence is in a fresh page; the shared page is untouched
    assert pool.refs(shared[0]) == 3
    assert adopter.logical() == [("d", 0), ("d", 1), ("d", 2), ("d", 3), ("a", 4)]
    assert donor.logical() == [("d", r) for r in range(6)]
    assert pool.cow_copies == 0  # page-aligned adoption never copies

    donor.drop()
    adopter.drop()
    assert pool.refs(shared[0]) == 1  # cache ref survives
    for pid in shared:
        pool.release(pid)
    assert pool.live_pages() == 0


def test_partial_tail_cow_at_every_split_point():
    """Mirror of prefix_sharing.rs cow_divergence_is_bit_identical_at_every
    split point: adopt L rows for every page-local offset, then diverge."""
    for l in range(5, 13):
        pool = PagePool(4)
        donor = Stream(pool)
        for r in range(13):
            donor.append(("d", r))
        n_pages = -(-l // 4)
        shared = donor.pages[:n_pages]
        for pid in shared:
            pool.retain(pid)

        adopter = Stream(pool)
        adopter.adopt(l, shared)
        before = donor.logical()
        for r in range(l, 15):
            adopter.append(("a", r))
        assert donor.logical() == before, f"split {l}: donor mutated"
        assert adopter.logical() == [("d", r) for r in range(l)] + [
            ("a", r) for r in range(l, 15)
        ], f"split {l}"
        # a mid-page split must have COWed the shared tail exactly once
        assert pool.cow_copies == (1 if l % 4 else 0), f"split {l}"
        donor.drop()
        adopter.drop()
        for pid in shared:
            pool.release(pid)
        assert pool.live_pages() == 0, f"split {l}: leak"


def test_dedup_factor_closes_to_exactly_two():
    """The symmetric workload pinned by prefix_sharing.rs
    dedup_footprint_math_is_pinned_exactly: 4 requests x 18 rows, 12
    shared -> packed 72 row-units, dedup 18 + 3*6 = 36."""
    pool = PagePool(4)
    donor = Stream(pool)
    for r in range(18):
        donor.append(("sys", r) if r < 12 else ("d0", r))
    shared = donor.pages[:3]  # rows 0..12
    for pid in shared:
        pool.retain(pid)

    packed = dedup = 0
    packed += donor.fill
    dedup += donor.take_dedup_rows()
    donor.drop()
    for i in range(1, 4):
        s = Stream(pool)
        s.adopt(12, shared)
        for r in range(12, 18):
            s.append((f"d{i}", r))
        packed += s.fill
        dedup += s.take_dedup_rows()
        s.drop()
    assert (packed, dedup) == (72, 36)
    assert packed / dedup == 2.0

    for pid in shared:
        pool.release(pid)
    assert pool.live_pages() == 0


# ------------------------------------------- cross-language artifact names


def test_layered_artifact_names_pin_the_rust_hashes():
    """Must match rust/src/main.rs layered_kvq_artifact_names_pin_the_token
    hash — both sides FNV-1a the same comma-joined tokens."""
    cases = {
        "nxfp5,mxfp4,nxfp5,mxfp4": "eval_step_kvq_layers_c83f63",
        "mxfp6,fp16,nxfp4,fp16": "eval_step_kvq_layers_a4b3ae",
        "nxfp4,nxfp4": "eval_step_kvq_layers_619c6b",
    }
    for joined, want in cases.items():
        assert aot.kvq_layered_artifact_name(joined.split(",")) == want


def test_parse_kvq_layers_validation():
    tokens, layers = aot.parse_kvq_layers("nxfp5,mxfp4,fp16,fp16", 2)
    assert tokens == ["nxfp5", "mxfp4", "fp16", "fp16"]
    assert layers[0][0].bits == 5 and layers[0][1].bits == 4
    assert layers[1] == (None, None)
    with pytest.raises(ValueError, match="wants 4 tokens"):
        aot.parse_kvq_layers("nxfp5,mxfp4", 2)
    with pytest.raises(ValueError, match="unknown KV format"):
        aot.parse_kvq_layers("nxfp5,mxfp4,fp16,int8", 2)
    with pytest.raises(ValueError, match="all fp16"):
        aot.parse_kvq_layers("fp16,fp16,fp16,fp16", 2)


# ----------------------------------------------------- kv_layers lowering


SPEC = model.LmSpec.tiny()


def _init_params(spec, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    shapes = model.param_shapes(spec)
    for name in model.param_names(spec):
        r, c = shapes[name]
        if r == 1:
            out.append(np.ones((r, c), np.float32))
        else:
            std = min(0.02, (2.0 / (r + c)) ** 0.5)
            out.append(rng.normal(0, std, size=(r, c)).astype(np.float32))
    return out


def _tokens(spec, batch=2, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(0, spec.vocab, size=(batch, spec.seq_len + 1), dtype=np.int32)


def test_kv_layers_uniform_agrees_with_kv_cfg():
    params, toks = _init_params(SPEC), _tokens(SPEC)
    cfg = ref.NxConfig(**{**ref.NxConfig.nxfp(4).__dict__, "block_size": 16})
    uniform, _ = model.make_eval_step(SPEC, kv_cfg=cfg, use_pallas=False)(*params, toks)
    layered, _ = model.make_eval_step(
        SPEC, kv_layers=[(cfg, cfg)] * SPEC.n_layers, use_pallas=False
    )(*params, toks)
    assert float(uniform) == float(layered)


def test_kv_layers_none_entries_stay_fp16():
    params, toks = _init_params(SPEC), _tokens(SPEC)
    base, _ = model.make_eval_step(SPEC)(*params, toks)
    noop, _ = model.make_eval_step(
        SPEC, kv_layers=[(None, None)] * SPEC.n_layers, use_pallas=False
    )(*params, toks)
    assert float(noop) == float(base)
    # quantizing only layer 0's K stream perturbs the loss but keeps it sane
    cfg = ref.NxConfig(**{**ref.NxConfig.nxfp(4).__dict__, "block_size": 16})
    kv_layers = [(cfg, None)] + [(None, None)] * (SPEC.n_layers - 1)
    mixed, _ = model.make_eval_step(SPEC, kv_layers=kv_layers, use_pallas=False)(
        *params, toks
    )
    assert float(mixed) != float(base)
    assert abs(float(mixed) - float(base)) / float(base) < 0.30


def test_kv_cfg_and_kv_layers_are_mutually_exclusive():
    cfg = ref.NxConfig.nxfp(4)
    with pytest.raises(ValueError, match="not both"):
        model.make_eval_step(SPEC, kv_cfg=cfg, kv_layers=[(cfg, cfg)] * SPEC.n_layers)
    with pytest.raises(ValueError, match="entries"):
        model.make_eval_step(SPEC, kv_layers=[(cfg, cfg)])
