"""Python mirror of the serving-tier observability probes.

Two independent contracts are pinned here, against the same NumPy oracle
(`compile.kernels.ref`) that pins the encode golden vectors:

1. **CodeOccupancy** — `rust/src/obs/occupancy.rs` re-derives the
   per-block scale from encoder metadata (`(1 + nano/4) * 2^(e+offset)`)
   and counts clipped elements, per-code hits, vacant levels, and
   recycled-code hits. `PyCodeOccupancy` below performs the identical
   arithmetic on top of `ref.quantize_block`, and the exact integer
   counters for nxfp4 / mxfp4 / mxfp6 on a deterministic LCG tensor are
   pinned. The LCG matches the Rust tests in occupancy.rs bit for bit
   (same multiplier/increment, wrapping u64), so both sides observe the
   same tensor.

2. **Prometheus text shape** — `rust/src/obs/export.rs` renders
   `ServingMetrics` + occupancy tables in Prometheus text format. The
   validator below checks the structural invariants every conforming
   exposition must satisfy (TYPE declarations, cumulative histogram
   buckets, `+Inf` == `_count`, `_sum`/`_count` terminators, labeled
   occupancy series) against a handcrafted sample mirroring the Rust
   renderer, and — when `NXFP_METRICS_PROM` points at a real file
   written by `serve --metrics-out` or the bench artifact step — against
   actual Rust output.
"""

import math
import os
import re

import numpy as np
import pytest

from compile.kernels import ref

MASK = (1 << 64) - 1
LCG_MUL = 6364136223846793005
LCG_INC = 1442695040888963407


def lcg_tensor(n, seed):
    """Bit-exact mirror of `lcg_tensor` in rust/src/obs/occupancy.rs."""
    s = (seed * LCG_MUL + 1) & MASK
    out = np.empty(n, dtype=np.float32)
    for i in range(n):
        s = (s * LCG_MUL + LCG_INC) & MASK
        out[i] = np.float32((s >> 33) / np.float32(1 << 31)) * np.float32(2.0) - np.float32(1.0)
    return out


class PyCodeOccupancy:
    """Mirror of CodeOccupancy::observe_row on top of ref.quantize_block."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.counts = np.zeros(1 << cfg.bits, dtype=np.int64)
        self.clipped = 0
        self.total = 0
        self.recycle_enabled = cfg.enable_cr

    @property
    def recycle_code(self):
        return 1 << (self.cfg.bits - 1)

    def observe(self, v):
        k = self.cfg.block_size
        assert len(v) % k == 0
        for start in range(0, len(v), k):
            blk = v[start : start + k]
            q = ref.quantize_block(blk, self.cfg)
            bf = ref.block_format(self.cfg, q["fmt_mx"])
            # same scale arithmetic as the encoder and the Rust probe; an
            # all-zero block underflows scale to 0 -> inv=inf -> 0*inf=NaN,
            # and NaN compares false under the strict > just like Rust
            with np.errstate(over="ignore", invalid="ignore"):
                scale = np.float32(
                    np.float32(1.0 + q["nano"] / 4.0) * ref.exp2i(q["e"] + bf.offset)
                )
                inv = np.float32(np.float32(1.0) / scale)
                for x, c in zip(blk, q["codes"]):
                    if abs(np.float32(np.float32(x) * inv)) > bf.top:  # strict, NaN-safe
                        self.clipped += 1
                    self.counts[int(c)] += 1
            self.total += len(blk)

    def merge(self, other):
        self.counts += other.counts
        self.clipped += other.clipped
        self.total += other.total

    def clip_rate(self):
        return self.clipped / self.total if self.total else 0.0

    def vacant_fraction(self):
        return int((self.counts == 0).sum()) / len(self.counts)

    def recycle_rate(self):
        return int(self.counts[self.recycle_code]) / self.total if self.total else 0.0


def observe_tensor(cfg, v):
    occ = PyCodeOccupancy(cfg)
    occ.observe(v)
    return occ


# ---------------------------------------------------------------------------
# CodeOccupancy pins: exact integer counters on lcg_tensor(256, 7).
# If any of these move, the encode arithmetic itself moved — that is a
# golden-contract break, not a tolerance issue.
# ---------------------------------------------------------------------------

OCC_PINS = {
    # name -> (cfg factory, clipped, vacant_levels, recycle_hits, n_levels)
    "nxfp4": (lambda: ref.NxConfig.nxfp(4), 18, 0, 7, 16),
    "mxfp4": (lambda: ref.NxConfig.mxfp(4), 63, 1, 0, 16),
    "mxfp6": (lambda: ref.NxConfig.mxfp(6), 14, 3, 0, 64),
}


@pytest.mark.parametrize("name", sorted(OCC_PINS))
def test_occupancy_counters_pin_against_oracle(name):
    factory, clipped, vacant, recycle_hits, n_levels = OCC_PINS[name]
    cfg = factory()
    occ = observe_tensor(cfg, lcg_tensor(256, 7))
    assert occ.total == 256
    assert int(occ.counts.sum()) == 256, "every element lands on exactly one code"
    assert len(occ.counts) == n_levels
    assert occ.clipped == clipped
    assert int((occ.counts == 0).sum()) == vacant
    assert int(occ.counts[occ.recycle_code]) == recycle_hits
    # the derived rates surfaced in metrics export follow from the pins
    assert occ.clip_rate() == pytest.approx(clipped / 256)
    assert occ.vacant_fraction() == pytest.approx(vacant / n_levels)
    assert occ.recycle_rate() == pytest.approx(recycle_hits / 256)


def test_recycled_code_fires_only_with_code_recycling():
    # nxfp4 recycles the packed -0 code into an extra top level; mxfp4
    # never emits it, so for MX the recycle code IS the vacant level.
    nx = observe_tensor(ref.NxConfig.nxfp(4), lcg_tensor(256, 7))
    mx = observe_tensor(ref.NxConfig.mxfp(4), lcg_tensor(256, 7))
    assert nx.recycle_enabled and nx.counts[nx.recycle_code] > 0
    assert not mx.recycle_enabled and mx.counts[mx.recycle_code] == 0
    assert mx.recycle_rate() == 0.0
    vacant_codes = np.flatnonzero(mx.counts == 0)
    assert vacant_codes.tolist() == [mx.recycle_code]


def test_block_outlier_absorbs_headroom_so_nothing_clips():
    # one huge outlier per block forces the shared scale up: the outlier
    # saturates exactly at the top level (strictly-greater test fails)
    # and everything else lands inside the grid — mirrors the Rust
    # outliers_clip_and_recycling_fires_only_when_enabled test.
    cfg = ref.NxConfig.nxfp(4)
    v = lcg_tensor(128, 9)
    for b in range(len(v) // cfg.block_size):
        v[b * cfg.block_size] = np.float32(300.0)
    occ = observe_tensor(cfg, v)
    assert occ.total == 128
    assert occ.clipped == 0
    assert occ.clip_rate() == 0.0


def test_zero_tensor_and_empty_table_edge_cases():
    cfg = ref.NxConfig.nxfp(4)
    empty = PyCodeOccupancy(cfg)
    assert empty.clip_rate() == 0.0
    assert empty.recycle_rate() == 0.0
    assert empty.vacant_fraction() == 1.0
    occ = observe_tensor(cfg, np.zeros(cfg.block_size * 2, dtype=np.float32))
    assert int(occ.counts[0]) == cfg.block_size * 2
    assert occ.vacant_fraction() == (len(occ.counts) - 1) / len(occ.counts)


def test_merge_sums_counters():
    cfg = ref.NxConfig.nxfp(4)
    v = lcg_tensor(128, 3)
    a = observe_tensor(cfg, v)
    b = observe_tensor(cfg, v)
    clip = a.clipped
    a.merge(b)
    assert a.total == 256
    assert a.clipped == 2 * clip
    assert int(a.counts.sum()) == 256


# ---------------------------------------------------------------------------
# Prometheus text-format shape validation.
# ---------------------------------------------------------------------------

METRIC_LINE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)(?P<labels>\{[^}]*\})? (?P<value>\S+)$"
)


def validate_prometheus(text):
    """Structural validation of a Prometheus text exposition.

    Checks the invariants the Rust renderer promises: every sample is
    preceded by a # TYPE for its family, histogram buckets are cumulative
    and non-decreasing with sorted finite bounds, le="+Inf" equals
    `_count`, and `_sum`/`_count` are present for every histogram.
    Returns {family: type} for the caller to assert on coverage.
    """
    types = {}
    samples = []  # (name, labels, value)
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP"):
            continue
        if line.startswith("# TYPE"):
            _, _, fam, kind = line.split(maxsplit=3)
            assert kind in ("counter", "gauge", "histogram"), f"line {ln}: bad type {kind}"
            types[fam] = kind
            continue
        m = METRIC_LINE.match(line)
        assert m, f"line {ln}: unparseable sample {line!r}"
        val = float(m.group("value")) if m.group("value") != "+Inf" else math.inf
        samples.append((m.group("name"), m.group("labels") or "", val))

    def family(name):
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in types:
                return name[: -len(suffix)]
        return name

    hist = {}  # family -> {"buckets": [(le, cum)], "sum": v, "count": v}
    for name, labels, value in samples:
        fam = family(name)
        assert fam in types, f"sample {name} has no # TYPE declaration"
        kind = types[fam]
        if kind == "counter":
            assert value >= 0 and value == int(value), f"{name}: counter must be a whole number"
        elif kind == "histogram":
            h = hist.setdefault(fam, {"buckets": [], "sum": None, "count": None})
            if name.endswith("_bucket"):
                le = re.search(r'le="([^"]+)"', labels)
                assert le, f"{name}: bucket without le label"
                bound = math.inf if le.group(1) == "+Inf" else float(le.group(1))
                h["buckets"].append((bound, value))
            elif name.endswith("_sum"):
                h["sum"] = value
            elif name.endswith("_count"):
                h["count"] = value
    for fam, h in hist.items():
        assert h["sum"] is not None and h["count"] is not None, f"{fam}: missing _sum/_count"
        bounds = [b for b, _ in h["buckets"]]
        cums = [c for _, c in h["buckets"]]
        assert bounds == sorted(bounds), f"{fam}: bucket bounds not sorted"
        assert bounds and bounds[-1] == math.inf, f"{fam}: missing le=+Inf bucket"
        assert cums == sorted(cums), f"{fam}: bucket counts not cumulative"
        assert cums[-1] == h["count"], f"{fam}: +Inf bucket != _count"
    return types


# Handcrafted sample mirroring rust/src/obs/export.rs output shape:
# counters as nxfp_*_total, bare gauges, histograms with zero-count
# buckets elided and {:.6e} bounds, labeled occupancy series.
SAMPLE_PROM = """\
# HELP nxfp_requests_total requests completed
# TYPE nxfp_requests_total counter
nxfp_requests_total 6
# HELP nxfp_tokens_per_sec decode throughput
# TYPE nxfp_tokens_per_sec gauge
nxfp_tokens_per_sec 811.25
# HELP nxfp_admitted_total requests admitted to a lane
# TYPE nxfp_admitted_total counter
nxfp_admitted_total 6
# HELP nxfp_latency_seconds end-to-end request latency
# TYPE nxfp_latency_seconds histogram
nxfp_latency_seconds_bucket{le="1.000000e-3"} 2
nxfp_latency_seconds_bucket{le="1.600000e-2"} 5
nxfp_latency_seconds_bucket{le="+Inf"} 6
nxfp_latency_seconds_sum 0.0421
nxfp_latency_seconds_count 6
# TYPE nxfp_occupancy_elements_total counter
nxfp_occupancy_elements_total{config="NxFP4 k=32 nano+amx+cr"} 4096
# TYPE nxfp_occupancy_clipped_total counter
nxfp_occupancy_clipped_total{config="NxFP4 k=32 nano+amx+cr"} 288
# TYPE nxfp_occupancy_clip_rate gauge
nxfp_occupancy_clip_rate{config="NxFP4 k=32 nano+amx+cr"} 0.0703125
# TYPE nxfp_occupancy_vacant_fraction gauge
nxfp_occupancy_vacant_fraction{config="NxFP4 k=32 nano+amx+cr"} 0
# TYPE nxfp_occupancy_recycle_rate gauge
nxfp_occupancy_recycle_rate{config="NxFP4 k=32 nano+amx+cr"} 0.027
"""


def test_prometheus_validator_accepts_conforming_exposition():
    types = validate_prometheus(SAMPLE_PROM)
    assert types["nxfp_requests_total"] == "counter"
    assert types["nxfp_latency_seconds"] == "histogram"
    assert types["nxfp_occupancy_clip_rate"] == "gauge"


@pytest.mark.parametrize(
    "mutation",
    [
        # non-cumulative buckets
        ('nxfp_latency_seconds_bucket{le="1.600000e-2"} 5', 'nxfp_latency_seconds_bucket{le="1.600000e-2"} 1'),
        # +Inf bucket disagrees with _count
        ('nxfp_latency_seconds_bucket{le="+Inf"} 6', 'nxfp_latency_seconds_bucket{le="+Inf"} 7'),
        # histogram loses its terminator
        ("nxfp_latency_seconds_count 6", ""),
        # sample with no TYPE declaration
        ("nxfp_requests_total 6", "nxfp_mystery_total 6"),
        # fractional counter
        ("nxfp_admitted_total 6", "nxfp_admitted_total 6.5"),
    ],
)
def test_prometheus_validator_rejects_malformed_expositions(mutation):
    old, new = mutation
    assert old in SAMPLE_PROM
    with pytest.raises(AssertionError):
        validate_prometheus(SAMPLE_PROM.replace(old, new))


def test_real_metrics_file_when_available():
    """Validate actual Rust renderer output when CI (or a human) points
    NXFP_METRICS_PROM at a file written by `serve --metrics-out` or the
    NXFP_OBS_OUT bench artifact step."""
    path = os.environ.get("NXFP_METRICS_PROM", "")
    if not path or not os.path.exists(path):
        pytest.skip("NXFP_METRICS_PROM not set / file absent")
    with open(path) as f:
        types = validate_prometheus(f.read())
    assert types.get("nxfp_requests_total") == "counter"
    assert types.get("nxfp_latency_seconds") == "histogram"
    # the bench artifact runs with occupancy probes on
    assert types.get("nxfp_occupancy_clip_rate") == "gauge"
