"""L1 Pallas kernel: block fake-quantization (quantize + on-the-fly
dequantize) for BFP / MxFP / NxFP, used inside the L2 graph to quantize the
KV cache (paper §7.4) and exported standalone for kernel benchmarking.

TPU mapping of the paper's GPU/off-the-shelf decode flow (DESIGN.md §7):

* one VMEM tile holds ``(block_rows, k)`` values — the shared-exponent max
  is a lane reduction over the k axis (no warp shuffles needed);
* element projection is **arithmetic RTNE** (exponent-field extraction +
  scale-round-rescale), not a table lookup: no gathers, no L-wide
  broadcasts, pure VPU element ops;
* the dequantized tile feeds the MXU matmul downstream (step ⑥ of Fig. 7).

Must be lowered with ``interpret=True``: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.

IMPORTANT compile-target note: an earlier table-based projection
(|a - L| argmin + gather) executed correctly under jaxlib but was
**miscompiled by xla_extension 0.5.1** (the PJRT the Rust runtime binds)
for tables with ≥16 entries. The arithmetic form below avoids the
offending argmax/gather pattern entirely and is verified against the
oracle both under jaxlib (pytest) and under 0.5.1 (rust e2e test).

Numerics: identical algorithm to ``ref.py`` (and the Rust crate), except
SSE accumulation for the Algorithm-1 candidate search runs in f32 with
XLA's reduction order, so the AM/NM *choice* can flip on knife-edge blocks;
the pytest comparator treats a block as correct if its values match the
oracle OR its block MSE is as good (see python/tests/test_kernel.py).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

# rows of blocks processed per pallas grid step
BLOCK_ROWS = 64


def _candidates(cfg: ref.NxConfig):
    """Static (fmt_mx, BlockFormat) candidate list for a config."""
    fmts = [True, False] if cfg.enable_am else [cfg.base_mx]
    return [(f, ref.block_format(cfg, f)) for f in fmts]


def _exp2i(e):
    """2^e for integer e in [-126, 127], exact, via bit assembly."""
    e = jnp.clip(e, -126, 127)
    return jax.lax.bitcast_convert_type(((e + 127) << 23).astype(jnp.int32), jnp.float32)


def _floor_log2(x):
    """floor(log2(x)) for positive normal f32 via exponent-field extraction
    (safe where jnp.floor(jnp.log2(x)) misrounds near powers of two)."""
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _project(a, bf: ref.BlockFormat):
    """Map scaled values `a` to the nearest representable element value —
    round-to-nearest, ties to even mantissa code, saturating at the top
    level. Arithmetic mirror of ref.project_magnitude (jnp.round is RTNE,
    and even integer mantissas are exactly the even level indices).

    The recycled level (if any) competes with a strict `<`, losing ties to
    the grid (same rule as the oracle/rust).
    """
    ebits, mbits = _elem_of(bf)
    top = jnp.float32(bf.top)
    if ebits == 0:
        # BFP: integer grid with RTNE, saturate at ±top
        val = jnp.clip(jnp.round(a), -top, top)
    else:
        bias = (1 << (ebits - 1)) - 1
        absa = jnp.abs(a)
        # element exponent clamped to the subnormal floor
        e = _floor_log2(jnp.maximum(absa, jnp.float32(1e-30)))
        e = jnp.maximum(e, 1 - bias)
        step = _exp2i(e - mbits)          # grid step within this binade
        inv_step = _exp2i(mbits - e)
        mag = jnp.round(absa * inv_step) * step
        mag = jnp.minimum(mag, top)       # saturate (covers E4M3/E5M2 too)
        val = jnp.where(a < 0.0, -mag, mag)
    if bf.recycle is not None:
        r = jnp.float32(bf.recycle)
        val = jnp.where(jnp.abs(a - r) < jnp.abs(a - val), r, val)
    return val


def _elem_of(bf: ref.BlockFormat):
    """Recover (ebits, mbits) from a BlockFormat (static python ints)."""
    n = len(bf.lv)
    if bf.lv[1] == 1.0 and bf.lv[-1] == np.float32(n - 1):
        # integer grid -> BFP element
        return 0, int(np.log2(n))
    # minifloat: levels per binade = 2^mbits; bits = log2(#codes incl. specials)
    for ebits in range(1, 6):
        for mbits in range(0, 4):
            cand = ref.levels(ebits, mbits)
            if len(cand) == n and np.array_equal(cand, bf.lv):
                return ebits, mbits
    raise ValueError("unrecognized level table")


def _fakequant_math(v, cfg: ref.NxConfig):
    """Shared math for the pallas kernel body and the pure-jnp path:
    fake-quantize rows of `v` (…, k) as independent blocks."""
    maxabs = jnp.max(jnp.abs(v), axis=-1, keepdims=True)
    nonzero = maxabs > 0.0
    safe_max = jnp.where(nonzero, maxabs, 1.0)
    e = jnp.clip(_floor_log2(safe_max), ref.E_SHARED_MIN, ref.E_SHARED_MAX)
    best_sse = jnp.full(v.shape[:-1] + (1,), jnp.inf, dtype=jnp.float32)
    best_back = jnp.zeros_like(v)
    for fmt_mx, bf in _candidates(cfg):
        x_scale = _exp2i(e + bf.offset)
        if cfg.enable_nm:
            cap = jnp.float32(bf.top) * x_scale
            ratio = safe_max / cap
            m_cand = jnp.clip(jnp.floor((ratio - 1.0) * 4.0 + 0.5), 0.0, 3.0)
            m_cand = jnp.where(ratio > 1.0, m_cand, 0.0)
            nanos = [m_cand, jnp.zeros_like(m_cand)]
        else:
            nanos = [jnp.zeros_like(x_scale)]
        for nano in nanos:
            scale = (1.0 + nano / 4.0) * x_scale
            inv = 1.0 / scale
            back = _project(v * inv, bf) * scale
            sse = jnp.sum(jnp.square(v - back), axis=-1, keepdims=True)
            take = sse < best_sse
            best_sse = jnp.where(take, sse, best_sse)
            best_back = jnp.where(take, back, best_back)
    return jnp.where(nonzero, best_back, 0.0)


def _fakequant_kernel(x_ref, o_ref, *, cfg: ref.NxConfig):
    """Pallas kernel body: tile (BLOCK_ROWS, k) of independent blocks."""
    o_ref[...] = _fakequant_math(x_ref[...], cfg)


def fakequant_blocks(x, cfg: ref.NxConfig):
    """Fake-quantize `x` of shape (n_blocks, k) row-wise via the Pallas
    kernel (interpret mode). n_blocks must be a multiple of BLOCK_ROWS or
    smaller than it (pad upstream with zeros — zero blocks are exact)."""
    n, k = x.shape
    rows = min(BLOCK_ROWS, n)
    if n % rows != 0:
        raise ValueError(f"n_blocks {n} not a multiple of tile rows {rows}")
    kernel = functools.partial(_fakequant_kernel, cfg=cfg)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        grid=(n // rows,),
        in_specs=[pl.BlockSpec((rows, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, k), lambda i: (i, 0)),
        interpret=True,
    )(x)


def fakequant_tensor(x, cfg: ref.NxConfig):
    """Fake-quantize an arbitrary-shaped tensor whose last dimension is a
    multiple of the block size (blocks never straddle the last dim)."""
    k = cfg.block_size
    shape = x.shape
    if shape[-1] % k != 0:
        raise ValueError(f"last dim {shape[-1]} not a multiple of block {k}")
    flat = x.reshape(-1, k)
    # pad the block count up to a tile multiple with zero blocks (exact)
    n = flat.shape[0]
    rows = min(BLOCK_ROWS, n)
    pad = (-n) % rows
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad, k), jnp.float32)], axis=0)
    out = fakequant_blocks(flat, cfg)
    if pad:
        out = out[:n]
    return out.reshape(shape)


def fakequant_ref_jnp(x, cfg: ref.NxConfig):
    """Pure-jnp (non-pallas) version of the same computation, used as a
    tracing cross-check in tests."""
    k = cfg.block_size
    shape = x.shape
    return _fakequant_math(x.reshape(-1, k), cfg).reshape(shape)


def vmem_estimate_bytes(cfg: ref.NxConfig, k: int = 32) -> int:
    """Static VMEM footprint estimate of one kernel tile (DESIGN.md §7):
    input + output tiles plus ~6 tile-sized temporaries for the widest
    candidate path (arithmetic projection needs no level table)."""
    tile = BLOCK_ROWS * k * 4
    return 8 * tile


if __name__ == "__main__":
    # smoke: all 4/5/6-bit formats on random data, compare against the oracle
    rng = np.random.default_rng(0)
    x = rng.normal(0, 2.0, size=(128, 32)).astype(np.float32)
    for bits in (4, 5, 6):
        for cfg in (ref.NxConfig.bfp(bits), ref.NxConfig.mxfp(bits), ref.NxConfig.nxfp(bits)):
            got = np.asarray(fakequant_blocks(jnp.asarray(x), cfg))
            want = np.stack([ref.fake_quant(r, cfg) for r in x])
            print(f"{cfg.name():<18} max |pallas - oracle|: {np.abs(got - want).max()}")
