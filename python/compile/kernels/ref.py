"""Pure-NumPy oracle for the NxFP / MxFP / BFP block formats.

This file is a **bit-exact mirror of the Rust implementation**
(`rust/src/formats/`): same level tables, same round-to-nearest-ties-to-even
-index projection, same NanoMantissa candidate rule, same Adaptive
Microexponent / Code Recycling semantics, and the same f32 arithmetic with
sequential f64 SSE accumulation for the Algorithm-1 candidate search.
`aot.py` dumps golden vectors from this oracle that the Rust test suite
(`rust/tests/golden_cross_check.rs`) verifies bit-for-bit, and the Pallas
kernel (`fakequant.py`) is validated against it by pytest.
"""

from dataclasses import dataclass, replace
from typing import Optional

import numpy as np

E_SHARED_MIN = -127
E_SHARED_MAX = 127


def levels(ebits: int, mbits: int) -> np.ndarray:
    """Sorted positive magnitudes of the element format (float32).

    ``ebits == 0`` denotes the BFP all-mantissa element (integer grid).
    Non-finite codes (E4M3 NaN, E5M2 inf/NaN) are excluded.
    """
    if ebits == 0:
        return np.arange(1 << mbits, dtype=np.float32)
    bias = (1 << (ebits - 1)) - 1
    out = []
    for code in range(1 << (ebits + mbits)):
        exp_field = code >> mbits
        m_field = code & ((1 << mbits) - 1)
        frac = np.float32(m_field) / np.float32(1 << mbits)
        if ebits == 4 and mbits == 3 and code == (1 << (ebits + mbits)) - 1:
            break  # OCP E4M3 NaN code
        if ebits == 5 and exp_field == (1 << ebits) - 1:
            break  # E5M2 inf/NaN codes
        if exp_field == 0:
            v = frac * np.float32(2.0 ** (1 - bias))
        else:
            v = (np.float32(1.0) + frac) * np.float32(2.0 ** (exp_field - bias))
        out.append(np.float32(v))
    return np.array(out, dtype=np.float32)


def mx_default_elem(bits: int) -> tuple:
    """OCP default minifloat (ebits, mbits) per total bitwidth."""
    return {3: (2, 0), 4: (2, 1), 5: (2, 2), 6: (2, 3), 7: (3, 3), 8: (4, 3)}[bits]


def scale_exp_offset(ebits: int, mbits: int) -> int:
    """Block scale is 2^(E_shared + offset); mirror of rust."""
    if ebits == 0:
        return 1 - mbits
    top = levels(ebits, mbits)[-1]
    return -int(np.floor(np.log2(float(top))))


@dataclass(frozen=True)
class NxConfig:
    """Mirror of rust `NxConfig` (the subset the oracle/kernels need)."""

    bits: int
    elem_mx: tuple  # (ebits, mbits) of the Mx path
    base_mx: bool   # base format when AM disabled
    block_size: int = 32
    enable_nm: bool = False
    enable_am: bool = False
    enable_cr: bool = False
    # recycle target: "half_min", ("mid_pair", i), or a float (scaled domain)
    recycle: object = "half_min"

    @staticmethod
    def bfp(bits: int) -> "NxConfig":
        return NxConfig(bits=bits, elem_mx=mx_default_elem(max(bits, 3)), base_mx=False)

    @staticmethod
    def mxfp(bits: int) -> "NxConfig":
        return NxConfig(bits=bits, elem_mx=mx_default_elem(bits), base_mx=True)

    @staticmethod
    def nxfp(bits: int) -> "NxConfig":
        return replace(NxConfig.mxfp(bits), enable_nm=True, enable_am=True, enable_cr=True)

    @staticmethod
    def nxfp_nm(bits: int) -> "NxConfig":
        return replace(NxConfig.mxfp(bits), enable_nm=True)

    @staticmethod
    def nxfp_nm_am(bits: int) -> "NxConfig":
        return replace(NxConfig.mxfp(bits), enable_nm=True, enable_am=True)

    def name(self) -> str:
        if not (self.enable_nm or self.enable_am or self.enable_cr):
            return f"MxFP{self.bits}" if self.base_mx else f"BFP{self.bits}"
        techs = [t for t, on in
                 [("NM", self.enable_nm), ("AM", self.enable_am), ("CR", self.enable_cr)] if on]
        return f"NxFP{self.bits} ({'+'.join(techs)})"


def resolve_recycle(target, lv: np.ndarray) -> np.float32:
    """Signed scaled-domain value decoded for the recycled -0 code."""
    if target == "half_min":
        return np.float32(-(lv[1] / np.float32(2.0)))
    if isinstance(target, tuple) and target[0] == "mid_pair":
        i = target[1]
        return np.float32(-((lv[i] + lv[i + 1]) / np.float32(2.0)))
    return np.float32(target)


@dataclass
class BlockFormat:
    lv: np.ndarray
    offset: int
    bits: int
    recycle: Optional[np.float32]

    @property
    def top(self) -> np.float32:
        return self.lv[-1]


def block_format(cfg: NxConfig, mx_path: bool) -> BlockFormat:
    if mx_path:
        e, m = cfg.elem_mx
    else:
        e, m = 0, cfg.bits - 1
    lv = levels(e, m)
    rec = resolve_recycle(cfg.recycle, lv) if cfg.enable_cr else None
    return BlockFormat(lv=lv, offset=scale_exp_offset(e, m), bits=1 + e + m, recycle=rec)


def exp2i(e: int) -> np.float32:
    """2^e as f32 with gradual underflow (mirror of rust `util::exp2i`)."""
    if -126 <= e <= 127:
        return np.uint32((e + 127) << 23).view(np.float32)
    if e < -126:
        if e < -149:
            return np.float32(0.0)
        return np.uint32(1 << (e + 149)).view(np.float32)
    return np.float32(np.inf)


def floor_log2(x: float) -> Optional[int]:
    """floor(log2(|x|)) — exact via frexp, handles subnormals."""
    a = abs(float(x))
    if a == 0.0 or not np.isfinite(a):
        return None
    _, e = np.frexp(a)  # a = m * 2^e with m in [0.5, 1)
    return int(e) - 1


def project_magnitude(lv: np.ndarray, a: np.float32) -> int:
    """Nearest level index, ties to even index, saturating (mirror of rust)."""
    if np.isnan(a):
        return len(lv) - 1
    i = int(np.searchsorted(lv, a, side="left"))  # first idx with lv[i] >= a
    if i == 0:
        return 0
    if i == len(lv):
        return len(lv) - 1
    dl = np.float32(a - lv[i - 1])
    dh = np.float32(lv[i] - a)
    if dl < dh:
        return i - 1
    if dh < dl:
        return i
    return i - 1 if (i - 1) % 2 == 0 else i


def encode(bf: BlockFormat, a: np.float32) -> int:
    """Scaled-domain value -> sign-magnitude code (mirror of rust)."""
    sign = bool(a < 0.0)
    idx = project_magnitude(bf.lv, np.float32(abs(a)))
    grid = np.float32(-bf.lv[idx]) if sign else bf.lv[idx]
    if bf.recycle is not None:
        if abs(np.float32(a - bf.recycle)) < abs(np.float32(a - grid)):
            return 1 << (bf.bits - 1)  # sign=1, magnitude=0
    if idx == 0:
        return 0
    return (int(sign) << (bf.bits - 1)) | idx


def decode(bf: BlockFormat, code: int) -> np.float32:
    sign_bit = 1 << (bf.bits - 1)
    idx = code & (sign_bit - 1)
    neg = bool(code & sign_bit)
    if neg and idx == 0:
        return bf.recycle if bf.recycle is not None else np.float32(0.0)
    idx = min(idx, len(bf.lv) - 1)
    v = bf.lv[idx]
    return np.float32(-v) if neg else v


def shared_exponent(v: np.ndarray) -> Optional[int]:
    finite = v[np.isfinite(v)]
    if finite.size == 0:
        return None
    e = floor_log2(float(np.max(np.abs(finite))))
    if e is None:
        return None
    return max(E_SHARED_MIN, min(E_SHARED_MAX, e))


def nano_candidate(vmax: np.float32, bf: BlockFormat, e_shared: int) -> int:
    """Fig. 4 rule: round the block max against the format's top level.

    All arithmetic is f32 to match rust bit-for-bit.
    """
    cap = np.float32(bf.top * exp2i(e_shared + bf.offset))
    if cap <= 0.0 or not np.isfinite(cap):
        return 0
    ratio = np.float32(np.float32(vmax) / cap)
    if ratio <= np.float32(1.0):
        return 0
    # rust f32::round is half-away-from-zero; ratio > 1 here so +0.5/floor
    r = np.float32((ratio - np.float32(1.0)) * np.float32(4.0))
    return max(0, min(3, int(np.floor(float(r) + 0.5))))


def quantize_block_fixed(v: np.ndarray, bf: BlockFormat, e_shared: int, nano: int):
    """Returns (codes, back, sse): f32 element math, sequential f64 SSE —
    exactly like rust ``quantize_block_fixed``."""
    scale = np.float32(np.float32(1.0 + nano / 4.0) * exp2i(e_shared + bf.offset))
    inv = np.float32(np.float32(1.0) / scale)
    codes = np.zeros(len(v), dtype=np.uint8)
    back = np.zeros(len(v), dtype=np.float32)
    sse = 0.0
    for i, x in enumerate(np.asarray(v, dtype=np.float32)):
        c = encode(bf, np.float32(x * inv))
        b = np.float32(decode(bf, c) * scale)
        codes[i] = c
        back[i] = b
        d = float(np.float32(x - b))
        sse += d * d
    return codes, back, sse


def quantize_block(v: np.ndarray, cfg: NxConfig):
    """Algorithm 1 (generalized to the ablation toggles); mirror of rust
    ``quantize_block``. Returns dict(e, nano, fmt_mx, codes, back, sse)."""
    v = np.asarray(v, dtype=np.float32)
    e = shared_exponent(v)
    if e is None:
        return dict(e=E_SHARED_MIN, nano=0, fmt_mx=cfg.base_mx or cfg.enable_am,
                    codes=np.zeros(len(v), np.uint8),
                    back=np.zeros(len(v), np.float32), sse=0.0)
    vmax = np.float32(np.max(np.abs(v[np.isfinite(v)])))
    fmts = [True, False] if cfg.enable_am else [cfg.base_mx]
    best = None
    for fmt_mx in fmts:
        bf = block_format(cfg, fmt_mx)
        if cfg.enable_nm:
            m = nano_candidate(vmax, bf, e)
            nanos = [m, 0] if m != 0 else [0]
        else:
            nanos = [0]
        for nano in nanos:
            codes, back, sse = quantize_block_fixed(v, bf, e, nano)
            if best is None or sse < best["sse"]:
                best = dict(e=e, nano=nano, fmt_mx=fmt_mx, codes=codes, back=back, sse=sse)
    return best


def fake_quant(v: np.ndarray, cfg: NxConfig) -> np.ndarray:
    """Quantize-dequantize a 1-D array block-by-block (oracle version of
    rust ``quant::fake_quant``)."""
    v = np.asarray(v, dtype=np.float32)
    out = np.zeros_like(v)
    k = cfg.block_size
    for start in range(0, len(v), k):
        out[start:start + k] = quantize_block(v[start:start + k], cfg)["back"]
    return out


def footprint_bits(cfg: NxConfig, n: int) -> int:
    """Bit-true storage cost (mirror of rust ``NxConfig::footprint_bits``)."""
    k = cfg.block_size
    blocks = (n + k - 1) // k
    overhead = 8 + (2 if cfg.enable_nm else 0) + (1 if cfg.enable_am else 0)
    return blocks * overhead + n * cfg.bits
