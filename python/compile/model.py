"""L2: the in-repo transformer LM (decoder-only, pre-LN, learned positions)
and its train / eval / score / kv-quantized-eval / decode step functions.

**Contract with the Rust L3 driver** (see `rust/src/models/transformer.rs`
`LmSpec::param_specs` and `rust/src/train`): parameters travel as a flat
list of 2-D f32 arrays in the order produced by `param_names(spec)`; norm
gains are shaped (1, d). Step signatures:

* train_step(params…, m…, v…, t, tokens[B,S+1]) -> (params…, m…, v…, loss)
* eval_step(params…, tokens[B,S+1])             -> (sum_nll, count)
* score_step(params…, tokens[B,S+1])            -> (nll[B,S],)
* eval_step_kvq_<fmt>(params…, tokens[B,S+1])   -> (sum_nll, count)
* eval_step_kvq_layers_<hash>(params…, tokens[B,S+1]) -> (sum_nll, count)
  (mixed per-layer K/V formats; <hash> = FNV-1a over the format tokens,
  computed identically by `aot.kvq_layered_artifact_name` and rust
  `kvq_layered_artifact_name`)
* decode_step(params…, tok[B], pos[B], k_cache[B,L,S,D], v_cache[B,L,S,D])
    -> (logits[B,V], k_new[B,L,D], v_new[B,L,D])

Python never runs at serving/training time — these functions exist to be
AOT-lowered to HLO text by `aot.py`.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import fakequant, ref


@dataclass(frozen=True)
class LmSpec:
    """Mirror of rust `LmSpec`."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seq_len: int = 128

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @staticmethod
    def small() -> "LmSpec":
        return LmSpec()

    @staticmethod
    def tiny() -> "LmSpec":
        return LmSpec(vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64, seq_len=16)


# Adam hyperparameters (traced into the artifact)
LR = 2.5e-3
WARMUP = 30.0
BETA1, BETA2, EPS = 0.9, 0.95, 1e-9


def param_names(spec: LmSpec):
    """Flattening order — must equal rust `LmSpec::param_specs`."""
    names = ["embed", "pos_embed"]
    for l in range(spec.n_layers):
        names += [f"l{l}.ln1", f"l{l}.wq", f"l{l}.wk", f"l{l}.wv",
                  f"l{l}.wo", f"l{l}.ln2", f"l{l}.w1", f"l{l}.w2"]
    names += ["lnf", "unembed"]
    return names


def param_shapes(spec: LmSpec):
    d, v, f, s = spec.d_model, spec.vocab, spec.d_ff, spec.seq_len
    shapes = {"embed": (v, d), "pos_embed": (s, d), "lnf": (1, d), "unembed": (d, v)}
    for l in range(spec.n_layers):
        shapes[f"l{l}.ln1"] = (1, d)
        shapes[f"l{l}.ln2"] = (1, d)
        for w in ["wq", "wk", "wv", "wo"]:
            shapes[f"l{l}.{w}"] = (d, d)
        shapes[f"l{l}.w1"] = (d, f)
        shapes[f"l{l}.w2"] = (f, d)
    return shapes


def unflatten(spec: LmSpec, flat):
    return dict(zip(param_names(spec), flat))


def _rmsnorm(x, g):
    # g is (1, d)
    return x * g[0] * jax.lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + 1e-6)


def _attention(spec: LmSpec, p, l, x, kv_quant=None):
    """Causal self-attention over a full sequence. `kv_quant(x, l, stream)`
    optionally fake-quantizes K and V (the paper's KV-cache compression)
    via the L1 Pallas kernel; the layer index and stream ("k"/"v") let a
    mixed policy pick a different format per stream."""
    b, s, d = x.shape
    h, hd = spec.n_heads, spec.head_dim
    q = x @ p[f"l{l}.wq"]
    k = x @ p[f"l{l}.wk"]
    v = x @ p[f"l{l}.wv"]
    if kv_quant is not None:
        k = kv_quant(k, l, "k")
        v = kv_quant(v, l, "v")
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[f"l{l}.wo"]


def forward(spec: LmSpec, flat_params, tokens, kv_quant=None):
    """Token ids (B, S) -> logits (B, S, V)."""
    p = unflatten(spec, flat_params)
    b, s = tokens.shape
    x = p["embed"][tokens] + p["pos_embed"][None, :s]
    for l in range(spec.n_layers):
        x = x + _attention(spec, p, l, _rmsnorm(x, p[f"l{l}.ln1"]), kv_quant)
        hmid = jax.nn.gelu(_rmsnorm(x, p[f"l{l}.ln2"]) @ p[f"l{l}.w1"])
        x = x + hmid @ p[f"l{l}.w2"]
    return _rmsnorm(x, p["lnf"]) @ p["unembed"]


def _nll(spec: LmSpec, flat_params, tokens, kv_quant=None):
    """Per-position negative log-likelihood (B, S) of predicting
    tokens[:, 1:] from tokens[:, :-1]."""
    x, y = tokens[:, :-1], tokens[:, 1:]
    logits = forward(spec, flat_params, x, kv_quant)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]


def loss_fn(spec: LmSpec, flat_params, tokens):
    return jnp.mean(_nll(spec, flat_params, tokens))


def make_train_step(spec: LmSpec):
    """(params…, m…, v…, t, tokens) -> (params…, m…, v…, loss) with AdamW
    (no decay) and linear warmup. Flat-list in/out, tuple-returned."""

    n = len(param_names(spec))

    def train_step(*args):
        params = list(args[:n])
        m = list(args[n:2 * n])
        v = list(args[2 * n:3 * n])
        t = args[3 * n]
        tokens = args[3 * n + 1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(spec, ps, tokens))(params)
        lr = LR * jnp.minimum(1.0, t / WARMUP)
        new_p, new_m, new_v = [], [], []
        for pi, gi, mi, vi in zip(params, grads, m, v):
            mi = BETA1 * mi + (1.0 - BETA1) * gi
            vi = BETA2 * vi + (1.0 - BETA2) * jnp.square(gi)
            mhat = mi / (1.0 - BETA1 ** t)
            vhat = vi / (1.0 - BETA2 ** t)
            new_p.append(pi - lr * mhat / (jnp.sqrt(vhat) + EPS))
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (loss,)

    return train_step


def make_eval_step(spec: LmSpec, kv_cfg: ref.NxConfig = None, kv_layers=None,
                   use_pallas=True):
    """(params…, tokens) -> (sum_nll, count). With `kv_cfg`, K/V activations
    are fake-quantized through the Pallas kernel (the paper's weight+KV
    setting — weights are quantized on the Rust side before being fed).

    `kv_layers` lowers a *mixed* KV policy instead: a list of `(k_cfg,
    v_cfg)` pairs, one per layer, where a `None` entry leaves that stream
    at fp16. Mutually exclusive with `kv_cfg` (which is the uniform
    special case: `kv_layers=[(cfg, cfg)] * n_layers`)."""

    n = len(param_names(spec))
    if kv_cfg is not None and kv_layers is not None:
        raise ValueError("pass kv_cfg or kv_layers, not both")
    fq = fakequant.fakequant_tensor if use_pallas else fakequant.fakequant_ref_jnp
    kv_quant = None
    if kv_cfg is not None:
        kv_quant = lambda x, l, stream: fq(x, kv_cfg)
    elif kv_layers is not None:
        if len(kv_layers) != spec.n_layers:
            raise ValueError(
                f"kv_layers has {len(kv_layers)} entries for {spec.n_layers} layers")

        def kv_quant(x, l, stream):
            cfg = kv_layers[l][0 if stream == "k" else 1]
            return x if cfg is None else fq(x, cfg)

    def eval_step(*args):
        params = list(args[:n])
        tokens = args[n]
        nll = _nll(spec, params, tokens, kv_quant)
        return (jnp.sum(nll), jnp.float32(nll.size))

    return eval_step


def make_score_step(spec: LmSpec):
    """(params…, tokens) -> (nll[B, S],) for multiple-choice scoring."""

    n = len(param_names(spec))

    def score_step(*args):
        params = list(args[:n])
        tokens = args[n]
        return (_nll(spec, params, tokens),)

    return score_step


def make_decode_step(spec: LmSpec):
    """Single-token decode with an external KV cache (owned, quantized and
    dequantized by the Rust coordinator — paper §6 deployment).

    (params…, tok[B], pos[B], k_cache[B,L,S,D], v_cache[B,L,S,D])
      -> (logits[B,V], k_new[B,L,D], v_new[B,L,D])

    Attention covers cache rows `< pos[b]` plus the current token.
    """

    n = len(param_names(spec))
    L, S, D = spec.n_layers, spec.seq_len, spec.d_model
    h, hd = spec.n_heads, spec.head_dim

    def decode_step(*args):
        params = list(args[:n])
        tok, pos, k_cache, v_cache = args[n], args[n + 1], args[n + 2], args[n + 3]
        p = unflatten(spec, params)
        b = tok.shape[0]
        x = p["embed"][tok] + p["pos_embed"][jnp.clip(pos, 0, S - 1)]
        k_rows, v_rows = [], []
        for l in range(L):
            xn = _rmsnorm(x, p[f"l{l}.ln1"])
            q = xn @ p[f"l{l}.wq"]
            k = xn @ p[f"l{l}.wk"]
            v = xn @ p[f"l{l}.wv"]
            k_rows.append(k)
            v_rows.append(v)
            qh = q.reshape(b, h, hd)
            kh_c = k_cache[:, l].reshape(b, S, h, hd).transpose(0, 2, 1, 3)
            vh_c = v_cache[:, l].reshape(b, S, h, hd).transpose(0, 2, 1, 3)
            scores_c = jnp.einsum("bhd,bhsd->bhs", qh, kh_c) / jnp.sqrt(jnp.float32(hd))
            mask = jnp.arange(S)[None, :] < pos[:, None]          # (b, S)
            scores_c = jnp.where(mask[:, None, :], scores_c, -1e30)
            kh = k.reshape(b, h, hd)
            vh = v.reshape(b, h, hd)
            score_self = jnp.einsum("bhd,bhd->bh", qh, kh)[..., None] / jnp.sqrt(
                jnp.float32(hd))
            scores = jnp.concatenate([scores_c, score_self], axis=-1)  # (b,h,S+1)
            probs = jax.nn.softmax(scores, axis=-1)
            ctx = jnp.einsum("bhs,bhsd->bhd", probs[..., :S], vh_c) \
                + probs[..., S:] * vh
            attn = ctx.reshape(b, D) @ p[f"l{l}.wo"]
            x = x + attn
            hmid = jax.nn.gelu(_rmsnorm(x, p[f"l{l}.ln2"]) @ p[f"l{l}.w1"])
            x = x + hmid @ p[f"l{l}.w2"]
        logits = _rmsnorm(x, p["lnf"]) @ p["unembed"]
        k_new = jnp.stack(k_rows, axis=1)  # (b, L, D)
        v_new = jnp.stack(v_rows, axis=1)
        return (logits, k_new, v_new)

    return decode_step
