"""AOT lowering: jit every step function, lower to HLO **text**, write
artifacts/*.hlo.txt, and dump golden cross-check vectors from the NumPy
oracle for the Rust test suite.

HLO text (not `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the rust `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Usage: python -m compile.aot --out-dir ../artifacts [--tiny] [--skip-kvq]
           [--kvq-layers nxfp5,mxfp4,... (2*n_layers tokens, repeatable)]
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# batch sizes baked into each artifact (the rust drivers must match;
# rust reads these from artifacts/manifest.txt)
TRAIN_BATCH = 16
EVAL_BATCH = 8
DECODE_BATCH = 4

KVQ_CONFIGS = {
    "bfp4": ref.NxConfig.bfp(4),
    "mxfp4": ref.NxConfig.mxfp(4),
    "nxfp4": ref.NxConfig.nxfp(4),
    "bfp5": ref.NxConfig.bfp(5),
    "mxfp5": ref.NxConfig.mxfp(5),
    "nxfp5": ref.NxConfig.nxfp(5),
    "bfp6": ref.NxConfig.bfp(6),
    "mxfp6": ref.NxConfig.mxfp(6),
    "nxfp6": ref.NxConfig.nxfp(6),
}

# configs exercised by the golden cross-check (rust <-> numpy oracle)
GOLDEN_CONFIGS = {
    "bfp4": ref.NxConfig.bfp(4),
    "bfp5": ref.NxConfig.bfp(5),
    "bfp6": ref.NxConfig.bfp(6),
    "mxfp4": ref.NxConfig.mxfp(4),
    "mxfp5": ref.NxConfig.mxfp(5),
    "mxfp6": ref.NxConfig.mxfp(6),
    "nxfp4": ref.NxConfig.nxfp(4),
    "nxfp5": ref.NxConfig.nxfp(5),
    "nxfp6": ref.NxConfig.nxfp(6),
    "nxfp4_nm": ref.NxConfig.nxfp_nm(4),
    "nxfp4_nm_am": ref.NxConfig.nxfp_nm_am(4),
    "mxfp8": ref.NxConfig(bits=8, elem_mx=(4, 3), base_mx=True),
}


def kvq_layered_artifact_name(tokens) -> str:
    """Mirror of rust `kvq_layered_artifact_name` (rust/src/main.rs): FNV-1a
    64-bit over the comma-joined canonical format tokens (layer order, K
    before V, "fp16" for unquantized streams), truncated to 24 bits. The
    two sides must agree bit-for-bit or `nxfp eval` loads a missing
    artifact — the hash is pinned by tests on both sides."""
    h = 0xCBF29CE484222325
    for b in ",".join(tokens).encode():
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return f"eval_step_kvq_layers_{h & 0xFFFFFF:06x}"


def parse_kvq_layers(arg: str, n_layers: int):
    """`--kvq-layers` value -> (tokens, [(k_cfg, v_cfg)] per layer).
    The value is 2*n_layers comma-separated tokens, layer order with K
    before V; "fp16" leaves a stream unquantized."""
    tokens = [t.strip() for t in arg.split(",")]
    if len(tokens) != 2 * n_layers:
        raise ValueError(
            f"--kvq-layers wants {2 * n_layers} tokens (K,V per layer), got {len(tokens)}")
    bad = sorted(set(t for t in tokens if t != "fp16" and t not in KVQ_CONFIGS))
    if bad:
        raise ValueError(f"unknown KV format tokens {bad} (known: fp16, {' '.join(KVQ_CONFIGS)})")
    if all(t == "fp16" for t in tokens):
        raise ValueError("--kvq-layers is all fp16: that is plain eval_step")
    cfg = lambda t: None if t == "fp16" else KVQ_CONFIGS[t]
    layers = [(cfg(tokens[2 * l]), cfg(tokens[2 * l + 1])) for l in range(n_layers)]
    return tokens, layers


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_structs(spec: model.LmSpec):
    shapes = model.param_shapes(spec)
    return [jax.ShapeDtypeStruct(shapes[n], jnp.float32) for n in model.param_names(spec)]


def lower_and_write(name, fn, args, out_dir):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(jax.jit(fn).lower(*args))
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text")


def write_golden(out_dir, n_blocks=24, ks=(32, 17, 1, 64)):
    """Golden fake-quant vectors from the NumPy oracle. Format per line:
    `<cfg_id> <k> <in_hex...> <out_hex...>` with f32 little-endian hex words.
    Verified bit-for-bit by rust/tests/golden_cross_check.rs."""
    rng = np.random.default_rng(20240713)
    path = os.path.join(out_dir, "golden_fakequant.txt")
    lines = []
    for cfg_id, cfg in GOLDEN_CONFIGS.items():
        for k in ks:
            for i in range(n_blocks):
                # vary dynamic range and shape of the distribution
                scale = np.float32(2.0 ** rng.integers(-12, 12))
                if i % 4 == 3:
                    v = (rng.standard_t(2, size=k) * scale).astype(np.float32)
                else:
                    v = rng.normal(0, scale, size=k).astype(np.float32)
                if i % 7 == 0:
                    v[rng.integers(0, k)] = 0.0
                if i == 5:
                    v[:] = 0.0
                cfg_k = ref.NxConfig(**{**cfg.__dict__, "block_size": k})
                out = ref.fake_quant(v, cfg_k)
                ih = "".join(f"{w:08x}" for w in v.view(np.uint32))
                oh = "".join(f"{w:08x}" for w in out.view(np.uint32))
                lines.append(f"{cfg_id} {k} {ih} {oh}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"  golden_fakequant.txt: {len(lines)} vectors")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--tiny", action="store_true", help="tiny spec (fast tests)")
    ap.add_argument("--skip-kvq", action="store_true")
    ap.add_argument("--kvq-layers", action="append", default=[],
                    help="lower one mixed-KV eval step: 2*n_layers comma-"
                         "separated format tokens (layer order, K before V; "
                         "'fp16' leaves a stream unquantized), e.g. "
                         "nxfp5,mxfp4,nxfp5,mxfp4 — repeatable; artifact "
                         "names come from the same FNV hash `nxfp eval` "
                         "derives from its --kv policy")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    spec = model.LmSpec.tiny() if args.tiny else model.LmSpec.small()
    n = len(model.param_names(spec))
    params = param_structs(spec)
    f32 = jnp.float32
    i32 = jnp.int32
    tok_train = jax.ShapeDtypeStruct((TRAIN_BATCH, spec.seq_len + 1), i32)
    tok_eval = jax.ShapeDtypeStruct((EVAL_BATCH, spec.seq_len + 1), i32)
    scalar = jax.ShapeDtypeStruct((), f32)

    print(f"lowering artifacts to {out_dir} (spec={spec})")
    lower_and_write("train_step", model.make_train_step(spec),
                    params + params + params + [scalar, tok_train], out_dir)
    lower_and_write("eval_step", model.make_eval_step(spec),
                    params + [tok_eval], out_dir)
    lower_and_write("score_step", model.make_score_step(spec),
                    params + [tok_eval], out_dir)
    if not args.skip_kvq:
        for fname, cfg in KVQ_CONFIGS.items():
            lower_and_write(f"eval_step_kvq_{fname}",
                            model.make_eval_step(spec, kv_cfg=cfg),
                            params + [tok_eval], out_dir)
    kvq_layer_lines = []
    for arg in args.kvq_layers:
        tokens, kv_layers = parse_kvq_layers(arg, spec.n_layers)
        name = kvq_layered_artifact_name(tokens)
        lower_and_write(name, model.make_eval_step(spec, kv_layers=kv_layers),
                        params + [tok_eval], out_dir)
        kvq_layer_lines.append(f"kvq_layers {name} {','.join(tokens)}\n")
    L, S, D = spec.n_layers, spec.seq_len, spec.d_model
    decode_args = params + [
        jax.ShapeDtypeStruct((DECODE_BATCH,), i32),
        jax.ShapeDtypeStruct((DECODE_BATCH,), i32),
        jax.ShapeDtypeStruct((DECODE_BATCH, L, S, D), f32),
        jax.ShapeDtypeStruct((DECODE_BATCH, L, S, D), f32),
    ]
    lower_and_write("decode_step", model.make_decode_step(spec), decode_args, out_dir)

    write_golden(out_dir)

    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(f"spec vocab={spec.vocab} d_model={spec.d_model} "
                f"n_layers={spec.n_layers} n_heads={spec.n_heads} "
                f"d_ff={spec.d_ff} seq_len={spec.seq_len}\n")
        f.write(f"train_batch {TRAIN_BATCH}\neval_batch {EVAL_BATCH}\n"
                f"decode_batch {DECODE_BATCH}\n")
        f.write(f"params {n}\n")
        f.write("kvq " + " ".join(KVQ_CONFIGS) + "\n")
        for line in kvq_layer_lines:
            f.write(line)
    print("  manifest.txt written")


if __name__ == "__main__":
    main()
