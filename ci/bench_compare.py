#!/usr/bin/env python3
"""Non-failing bench-trajectory report: compare this run's BENCH_*.json
records against the previous CI run's uploaded artifact.

Usage: bench_compare.py <prev_dir> <curr_dir>

Each BENCH_<bench>.json is a file of JSON lines emitted by
`nxfp::bench_util::emit_bench_json` (one record per bench configuration:
{"bench","name","config","smoke",<numeric fields...>}). Records are keyed
by (bench, name, config, smoke); when a file contains several records for
one key (re-runs appended to the same artifact dir) the *last* one wins.
Compared fields: every numeric field present in both records, with tok/s
treated as higher-is-better and latency/step fields as lower-is-better.

This script never fails the build: perf on shared CI runners is noisy, so
the report is informational — the trajectory accumulates in the uploaded
artifacts and regressions show up as a trend, not a single red build.
"""

import json
import os
import sys

# substrings that mark a lower-is-better metric; anything else (tok_s,
# blocks_s, speedup...) is reported as higher-is-better. "growth" is
# hotpath_serving's per-step-cost flatness ratio (~1.0 flat, >1 means
# decode work grows with cache fill) — lower is better there too.
LOWER_IS_BETTER = ("_ms", "_steps", "steps", "p50", "p95", "p99", "growth")


def load(d):
    recs = {}
    if not os.path.isdir(d):
        return recs
    for fn in sorted(os.listdir(d)):
        if not (fn.startswith("BENCH_") and fn.endswith(".json")):
            continue
        with open(os.path.join(d, fn)) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                key = (r.get("bench"), r.get("name"), r.get("config"), r.get("smoke"))
                recs[key] = r  # last record wins
    return recs


def fmt_delta(field, old, new):
    if old in (None, 0) or new is None:
        return "n/a"
    pct = 100.0 * (new - old) / abs(old)
    lower_better = any(t in field for t in LOWER_IS_BETTER)
    improved = pct < 0 if lower_better else pct > 0
    arrow = "+" if pct >= 0 else ""
    mark = "(better)" if improved else ("(worse)" if abs(pct) > 1e-9 else "")
    return f"{arrow}{pct:.1f}% {mark}".strip()


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    prev, curr = load(sys.argv[1]), load(sys.argv[2])
    if not curr:
        print(f"[bench-compare] no records in {sys.argv[2]}; nothing to report")
        return 0
    if not prev:
        print(
            f"[bench-compare] no previous artifact in {sys.argv[1]} — first "
            f"trajectory point ({len(curr)} records recorded, nothing to compare)"
        )
        return 0
    print(f"[bench-compare] {len(curr)} current records vs {len(prev)} previous\n")
    width = 52
    for key in sorted(curr, key=str):
        bench, name, config, smoke = key
        label = f"{bench}/{name} [{config}]" + (" (smoke)" if smoke else "")
        old = prev.get(key)
        if old is None:
            print(f"{label:<{width}} new scenario (no previous record)")
            continue
        fields = [
            k
            for k, v in curr[key].items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
            and isinstance(old.get(k), (int, float)) and not isinstance(old.get(k), bool)
        ]
        parts = []
        for f in sorted(fields):
            parts.append(f"{f} {old[f]:.4g}->{curr[key][f]:.4g} ({fmt_delta(f, old[f], curr[key][f])})")
        print(f"{label:<{width}} " + "; ".join(parts))
    gone = sorted(set(prev) - set(curr), key=str)
    for key in gone:
        print(f"{key}: present in previous run only")
    return 0


if __name__ == "__main__":
    sys.exit(main())
