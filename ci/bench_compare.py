#!/usr/bin/env python3
"""Non-failing bench-trajectory report: compare this run's BENCH_*.json
records against the previous CI run's uploaded artifact.

Usage: bench_compare.py <prev_dir> <curr_dir>
       bench_compare.py --selftest

Each BENCH_<bench>.json is a file of JSON lines emitted by
`nxfp::bench_util::emit_bench_json` (one record per bench configuration:
{"bench","name","config","policy","smoke",<numeric fields...>}). Records
are keyed by (bench, name, config, policy, smoke) — `policy` is the
quantization-policy name, so mixed-precision runs never collide with
uniform ones; older records without the field key on policy=None. When a
file contains several records for one key (re-runs appended to the same
artifact dir) the *last* one wins. Compared fields: every numeric field
present in both records, with tok/s treated as higher-is-better and
latency/step/bits fields as lower-is-better.

Perf never fails the build: throughput on shared CI runners is noisy, so
the report is informational — the trajectory accumulates in the uploaded
artifacts and regressions show up as a trend, not a single red build.
Two escalations exist:

- a **>2x regression on a non-smoke record** is promoted to a GitHub
  `::warning::` annotation so it surfaces in the PR summary instead of
  scrolling by as prose (smoke records run at toy sizes where a 2x swing
  is routine scheduler noise, so they stay prose);
- a **nonzero `lost_requests` field on any current record** is a
  correctness failure, not a perf delta: the fault-injection sweep
  asserts every submitted request comes back, so a lost request means
  the serving tier dropped work. That emits `::error::` and exits
  nonzero — no previous artifact needed.

Fault-injection sweeps encode their fault mode in `config` (e.g.
`step=0.01`), so each fault rate is its own trajectory key and a faulted
run is never compared against a fault-free one.
"""

import json
import os
import sys

# substrings that mark a lower-is-better metric; anything else (tok_s,
# blocks_s, speedup, dedup_factor, prefix_hit_rate...) is reported as
# higher-is-better. "growth" is hotpath_serving's per-step-cost flatness
# ratio (~1.0 flat, >1 means decode work grows with cache fill) — lower
# is better there too, as are "bits" (effective storage bits per element)
# and "_kib" (absolute footprints, e.g. the dedup-aware packed-KV bytes).
LOWER_IS_BETTER = ("_ms", "_steps", "steps", "p50", "p95", "p99", "growth", "bits", "_kib")

# Non-smoke regressions worse than this factor become ::warning::
# annotations in the PR summary.
WARN_FACTOR = 2.0

# Fields that are correctness gates, not perf metrics: any current record
# carrying a positive value for one of these fails the build outright.
MUST_BE_ZERO = ("lost_requests",)

# Record-layout metadata, not measurements: emitted since schema_version 1
# (older baseline artifacts predate them, so both sides are optional).
# Excluded from the numeric diff — run_seq in particular is an emission
# counter that would otherwise read as a fake perf delta.
META_FIELDS = ("schema_version", "run_seq")


def record_key(r):
    # records predating the policy field key as policy == config, which is
    # exactly what uniform-policy benches emit — the accumulated trajectory
    # keeps comparing across the transition instead of resetting
    policy = r.get("policy") or r.get("config")
    return (r.get("bench"), r.get("name"), r.get("config"), policy, r.get("smoke"))


def load(d):
    recs = {}
    if not os.path.isdir(d):
        return recs
    for fn in sorted(os.listdir(d)):
        if not (fn.startswith("BENCH_") and fn.endswith(".json")):
            continue
        with open(os.path.join(d, fn)) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    r = json.loads(line)
                except json.JSONDecodeError:
                    continue
                recs[record_key(r)] = r  # last record wins
    return recs


def is_lower_better(field):
    return any(t in field for t in LOWER_IS_BETTER)


def fmt_delta(field, old, new):
    if old in (None, 0) or new is None:
        return "n/a"
    pct = 100.0 * (new - old) / abs(old)
    improved = pct < 0 if is_lower_better(field) else pct > 0
    arrow = "+" if pct >= 0 else ""
    mark = "(better)" if improved else ("(worse)" if abs(pct) > 1e-9 else "")
    return f"{arrow}{pct:.1f}% {mark}".strip()


def regression_factor(field, old, new):
    """How many times *worse* the new value is (None when not comparable
    or not a regression). >1 means regressed; e.g. tok/s 100 -> 40 or
    p95 10 -> 25 both return 2.5."""
    if old is None or new is None:
        return None
    if not isinstance(old, (int, float)) or isinstance(old, bool):
        return None
    if not isinstance(new, (int, float)) or isinstance(new, bool):
        return None
    if old <= 0 or new <= 0:
        return None
    factor = new / old if is_lower_better(field) else old / new
    return factor if factor > 1.0 else None


def numeric_fields(old, new):
    def ok(v):
        return isinstance(v, (int, float)) and not isinstance(v, bool)

    return sorted(
        k for k, v in new.items() if k not in META_FIELDS and ok(v) and ok(old.get(k))
    )


def key_label(key):
    bench, name, config, policy, smoke = key
    label = f"{bench}/{name} [{config}]"
    if policy and policy != config:
        label += f" policy={policy}"
    if smoke:
        label += " (smoke)"
    return label


def correctness_errors(curr):
    """`::error::` lines for MUST_BE_ZERO violations in the current run.
    Checked against `curr` alone — a first trajectory point with lost
    requests fails even though there is nothing to compare against."""
    errors = []
    for key in sorted(curr, key=str):
        r = curr[key]
        for f in MUST_BE_ZERO:
            v = r.get(f)
            if isinstance(v, (int, float)) and not isinstance(v, bool) and v > 0:
                errors.append(
                    f"::error title=lost requests::{key_label(key)}: {f}={v:g} "
                    f"(must be 0 — the serving tier dropped requests)"
                )
    return errors


def compare(prev, curr):
    """Pure comparison: returns (report_lines, warning_lines, error_lines)."""
    lines, warnings = [], []
    errors = correctness_errors(curr)
    if not curr:
        return (["[bench-compare] no current records; nothing to report"], [], errors)
    if not prev:
        lines.append(
            f"[bench-compare] no previous records — first trajectory point "
            f"({len(curr)} records recorded, nothing to compare)"
        )
        return (lines, [], errors)
    lines.append(f"[bench-compare] {len(curr)} current records vs {len(prev)} previous\n")
    width = 52
    for key in sorted(curr, key=str):
        label = key_label(key)
        smoke = key[4]
        old = prev.get(key)
        if old is None:
            lines.append(f"{label:<{width}} new scenario (no previous record)")
            continue
        new = curr[key]
        parts = []
        for f in numeric_fields(old, new):
            parts.append(f"{f} {old[f]:.4g}->{new[f]:.4g} ({fmt_delta(f, old[f], new[f])})")
            factor = regression_factor(f, old[f], new[f])
            if factor is not None and factor > WARN_FACTOR and not smoke:
                warnings.append(
                    f"::warning title=bench regression::{label}: {f} regressed "
                    f"{factor:.1f}x ({old[f]:.4g} -> {new[f]:.4g})"
                )
        lines.append(f"{label:<{width}} " + "; ".join(parts))
    for key in sorted(set(prev) - set(curr), key=str):
        lines.append(f"{key}: present in previous run only")
    return (lines, warnings, errors)


def selftest():
    """Unit-test the threshold/warning logic with synthetic records."""
    rec = lambda name, smoke=False, **fields: dict(
        bench="b", name=name, config="c", policy="p", smoke=smoke, **fields
    )
    key = lambda r: record_key(r)

    # direction handling
    assert regression_factor("tok_s", 100.0, 40.0) == 100.0 / 40.0  # higher-better drop
    assert regression_factor("tok_s", 100.0, 120.0) is None  # improvement
    assert regression_factor("p95_ms", 10.0, 25.0) == 2.5  # lower-better rise
    assert regression_factor("p95_ms", 10.0, 9.0) is None
    assert regression_factor("effective_bits", 4.0, 9.0) == 2.25  # "bits" is lower-better
    # prefix-cache metrics: dedup_factor and prefix_hit_rate are
    # higher-is-better (a collapse to 1x sharing is the regression);
    # the dedup-aware footprint in KiB and TTFT-in-steps are lower-is-better
    assert regression_factor("dedup_factor", 2.0, 0.8) == 2.5
    assert regression_factor("dedup_factor", 1.2, 2.4) is None  # more sharing: improvement
    assert regression_factor("prefix_hit_rate", 0.9, 0.3) == 3.0
    assert regression_factor("kv_unique_kib", 100.0, 250.0) == 2.5
    assert regression_factor("ttft_mean_steps", 4.0, 10.0) == 2.5
    # speculative decoding: accept_rate is higher-is-better (a collapsing
    # draft is the regression); steps_per_token matches the "steps"
    # substring, so more macro rounds per generated token regresses
    assert regression_factor("accept_rate", 0.9, 0.3) == 3.0
    assert regression_factor("accept_rate", 0.3, 0.9) is None
    assert regression_factor("steps_per_token", 0.3, 0.9) == 3.0
    assert regression_factor("steps_per_token", 0.9, 0.3) is None
    # non-comparable inputs
    assert regression_factor("tok_s", None, 5.0) is None
    assert regression_factor("tok_s", 0, 5.0) is None
    assert regression_factor("tok_s", True, 5.0) is None

    # a 2.5x non-smoke regression becomes exactly one ::warning::
    prev = {key(r): r for r in [rec("slow", tok_s=100.0)]}
    curr = {key(r): r for r in [rec("slow", tok_s=40.0)]}
    _, warns, errs = compare(prev, curr)
    assert len(warns) == 1 and "::warning" in warns[0] and "2.5x" in warns[0], warns
    assert errs == [], errs

    # exactly-2x is NOT promoted (threshold is strict)
    curr2 = {key(r): r for r in [rec("slow", tok_s=50.0)]}
    _, warns, _ = compare(prev, curr2)
    assert warns == [], warns

    # the same regression on a smoke record stays prose
    prev_s = {key(r): r for r in [rec("slow", smoke=True, tok_s=100.0)]}
    curr_s = {key(r): r for r in [rec("slow", smoke=True, tok_s=10.0)]}
    lines, warns, _ = compare(prev_s, curr_s)
    assert warns == [] and any("worse" in l for l in lines), (lines, warns)

    # improvements and sub-threshold noise never warn
    prev3 = {key(r): r for r in [rec("ok", tok_s=100.0, p95_ms=10.0)]}
    curr3 = {key(r): r for r in [rec("ok", tok_s=130.0, p95_ms=14.0)]}
    _, warns, _ = compare(prev3, curr3)
    assert warns == [], warns

    # policy participates in the key: same (bench,name,config) under a
    # different policy is a new scenario, not a comparison
    prev4 = {key(r): r for r in [rec("mixed", tok_s=100.0)]}
    moved = rec("mixed", tok_s=10.0)
    moved["policy"] = "kv.k=nxfp5,kv.v=mxfp4"
    curr4 = {record_key(moved): moved}
    lines, warns, _ = compare(prev4, curr4)
    assert warns == [] and any("new scenario" in l for l in lines), (lines, warns)

    # legacy records (no policy field) keep comparing against new uniform
    # records whose policy == config — the trajectory must not reset (and
    # a >2x regression across the transition still warns)
    legacy = {"bench": "b", "name": "slow", "config": "c", "smoke": False, "tok_s": 100.0}
    prev6 = {record_key(legacy): legacy}
    uniform = rec("slow", tok_s=40.0)
    uniform["policy"] = "c"  # uniform benches emit policy == config
    curr6 = {record_key(uniform): uniform}
    _, warns, _ = compare(prev6, curr6)
    assert len(warns) == 1 and "2.5x" in warns[0], warns

    # multiple fields regressing on one record produce one warning each
    prev5 = {key(r): r for r in [rec("multi", tok_s=100.0, p95_ms=10.0)]}
    curr5 = {key(r): r for r in [rec("multi", tok_s=30.0, p95_ms=50.0)]}
    _, warns, _ = compare(prev5, curr5)
    assert len(warns) == 2, warns

    # lost_requests == 0 is healthy: no error, and the field is reported
    # as ordinary prose like any other numeric column
    prev7 = {key(r): r for r in [rec("fault", tok_s=100.0, lost_requests=0)]}
    curr7 = {key(r): r for r in [rec("fault", tok_s=95.0, lost_requests=0)]}
    lines, warns, errs = compare(prev7, curr7)
    assert errs == [] and warns == [], (errs, warns)
    assert any("lost_requests" in l for l in lines), lines

    # lost_requests > 0 fails the run: exactly one ::error:: per violating
    # record, and it is an error — never a ::warning:: perf annotation
    curr8 = {key(r): r for r in [rec("fault", tok_s=95.0, lost_requests=2)]}
    _, warns, errs = compare(prev7, curr8)
    assert len(errs) == 1 and "::error" in errs[0] and "lost_requests=2" in errs[0], errs
    assert not any("lost_requests" in w for w in warns), warns

    # the gate needs no previous artifact: a first trajectory point with
    # lost requests still errors (fault sweeps must fail on day one)
    _, _, errs = compare({}, curr8)
    assert len(errs) == 1 and "::error" in errs[0], errs

    # smoke records get no exemption from the correctness gate
    smoke_lost = rec("fault", smoke=True, tok_s=5.0, lost_requests=1)
    _, _, errs = compare({}, {key(smoke_lost): smoke_lost})
    assert len(errs) == 1, errs

    # schema metadata never participates in the diff: a versioned record
    # (schema_version/run_seq present) compares cleanly against an
    # unversioned baseline, and a run_seq drop is not a regression
    legacy9 = {"bench": "b", "name": "v", "config": "c", "smoke": False, "tok_s": 100.0}
    vers9 = rec("v", tok_s=98.0, schema_version=1, run_seq=7)
    vers9["policy"] = "c"  # uniform policy == config, matching the legacy key
    lines, warns, errs = compare({record_key(legacy9): legacy9}, {key(vers9): vers9})
    assert warns == [] and errs == [], (warns, errs)
    assert any("tok_s" in l for l in lines), lines
    assert not any("schema_version" in l or "run_seq" in l for l in lines), lines
    # both sides versioned, run_seq 9 -> 0 (fresh process): still silent
    prev9 = {key(r): r for r in [rec("v", tok_s=100.0, schema_version=1, run_seq=9)]}
    curr9 = {key(r): r for r in [rec("v", tok_s=100.0, schema_version=1, run_seq=0)]}
    lines, warns, _ = compare(prev9, curr9)
    assert warns == [] and not any("run_seq" in l for l in lines), (lines, warns)

    # fault modes key on config: step=0.05 never compares against the
    # fault-free step=0 record
    base = rec("fault-sweep", tok_s=100.0)
    base["config"] = "step=0"
    faulted = rec("fault-sweep", tok_s=30.0)
    faulted["config"] = "step=0.05"
    lines, warns, _ = compare({record_key(base): base}, {record_key(faulted): faulted})
    assert warns == [] and any("new scenario" in l for l in lines), (lines, warns)

    # fleet sizes key on config the same way: a replicas=4 record is its
    # own trajectory, never compared against the replicas=2 one even when
    # bench/name/policy all match
    fleet = lambda n, **fields: dict(
        bench="fleet",
        name="shared-prefix-drain",
        config=f"replicas={n}",
        policy="p",
        smoke=False,
        **fields,
    )
    prev_f = {record_key(fleet(2, tok_s=200.0, lost_requests=0)): fleet(2, tok_s=200.0)}
    curr_f = {record_key(fleet(4, tok_s=60.0, lost_requests=0)): fleet(4, tok_s=60.0)}
    lines, warns, errs = compare(prev_f, curr_f)
    assert warns == [] and errs == [], (warns, errs)
    assert any("new scenario" in l for l in lines), lines
    # same size compares as a normal trajectory (and can warn)
    prev_f4 = {record_key(fleet(4, tok_s=200.0)): fleet(4, tok_s=200.0)}
    _, warns, _ = compare(prev_f4, curr_f)
    assert len(warns) == 1 and "3.3x" in warns[0], warns

    # the lost_requests gate covers fleet records like any other bench:
    # a dropped request through a drain/kill fails the run outright
    lost_f = fleet(4, tok_s=60.0, lost_requests=1)
    _, _, errs = compare({}, {record_key(lost_f): lost_f})
    assert len(errs) == 1 and "::error" in errs[0] and "fleet" in errs[0], errs

    print("[bench-compare] selftest OK")
    return 0


def main():
    if len(sys.argv) == 2 and sys.argv[1] == "--selftest":
        return selftest()
    if len(sys.argv) != 3:
        print(__doc__)
        return 0
    lines, warnings, errors = compare(load(sys.argv[1]), load(sys.argv[2]))
    for line in lines:
        print(line)
    for w in warnings:
        print(w)
    for e in errors:
        print(e)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
