//! END-TO-END DRIVER (deliverable (b) / EXPERIMENTS.md §E2E).
//!
//! Proves all three layers compose on a real workload:
//!
//! 1. **Train** the in-repo transformer LM (~3.4M params) for a few hundred
//!    steps on the synthetic grammar corpus, driving the AOT-compiled JAX
//!    `train_step` from Rust and logging the loss curve.
//! 2. **Direct-cast quantize** the trained weights into BFP / MxFP / NxFP at
//!    4/5/6 bits (the paper's Table 1 setting) with the Rust quantizer.
//! 3. **Evaluate** held-out perplexity for every format through the AOT
//!    `eval_step`, and weight+KV perplexity through the Pallas-backed
//!    `eval_step_kvq_*` artifacts.
//!
//! The trained checkpoint is saved to `artifacts/model.ckpt` and reused by
//! the paper-figure benches. Run: `cargo run --release --example train_and_quantize`
//! (optionally `NXFP_TRAIN_STEPS=400`).

use anyhow::Result;
use std::path::Path;

use nxfp::bench_util::Table;
use nxfp::eval::{perplexity, quantize_checkpoint};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::{Checkpoint, Corpus, GrammarSpec, LmSpec};
use nxfp::runtime::Runtime;
use nxfp::train::{TrainConfig, Trainer};

fn main() -> Result<()> {
    let spec = LmSpec::small();
    let steps: u32 = std::env::var("NXFP_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let corpus =
        Corpus::generate(GrammarSpec::default_for_vocab(spec.vocab), 400_000, 40_000, 1234);
    let mut rt = Runtime::cpu("artifacts")?;
    println!("== nxfp end-to-end driver ==");
    println!("platform      : {}", rt.platform());
    println!(
        "model         : {} params ({} layers, d={})",
        spec.param_count(),
        spec.n_layers,
        spec.d_model
    );
    println!("corpus        : {} train / {} eval tokens", corpus.train.len(), corpus.eval.len());
    println!("train steps   : {steps}");

    // ---- 1. train ----------------------------------------------------
    let ckpt_path = Path::new("artifacts/model.ckpt");
    let ck = if ckpt_path.exists() && std::env::var("NXFP_RETRAIN").is_err() {
        println!("\n[1/3] checkpoint exists, skipping training (set NXFP_RETRAIN=1 to retrain)");
        Checkpoint::load(ckpt_path)?
    } else {
        println!("\n[1/3] training (loss curve):");
        let cfg = TrainConfig { batch: 16, steps, log_every: 10, seed: 42 };
        let t0 = std::time::Instant::now();
        let init = Checkpoint::init(&spec, cfg.seed);
        let mut trainer = Trainer::new(&mut rt, spec, &init, &cfg)?;
        trainer.train(&corpus, &cfg, |step, loss| {
            println!("  step {step:>5}  loss {loss:.4}");
        })?;
        let ck = trainer.checkpoint()?;
        ck.save(ckpt_path)?;
        println!(
            "  trained {} steps in {:.1?} ({:.2} steps/s), saved to {ckpt_path:?}",
            steps,
            t0.elapsed(),
            steps as f64 / t0.elapsed().as_secs_f64()
        );
        ck
    };

    // ---- 2+3. quantize every format and evaluate ----------------------
    println!("\n[2/3] direct-cast quantization + held-out perplexity (weight-only):");
    let eval_step = rt.load("eval_step")?;
    let quantizable = spec.quantizable();
    let fp16 = perplexity(&eval_step, &ck, &corpus, spec.seq_len, 8)?;
    let mut table = Table::new(&["bits", "format", "ppl", "Δ vs FP16", "eff.bits"]);
    table.row(&[
        "16".into(),
        "FP16".into(),
        format!("{:.4}", fp16.ppl()),
        "—".into(),
        "16".into(),
    ]);
    let mut results = vec![("FP16".to_string(), 16.0, fp16.ppl())];
    for bits in [6u8, 5, 4] {
        for cfg in [
            NxConfig::bfp(bits),
            NxConfig::mxfp(bits),
            NxConfig::nxfp_nm(bits),
            NxConfig::nxfp_nm_am(bits),
            NxConfig::nxfp(bits),
        ] {
            let qck = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
            let p = perplexity(&eval_step, &qck, &corpus, spec.seq_len, 8)?;
            table.row(&[
                bits.to_string(),
                cfg.name(),
                format!("{:.4}", p.ppl()),
                format!("{:+.4}", p.ppl() - fp16.ppl()),
                format!("{:.2}", cfg.effective_bits()),
            ]);
            results.push((cfg.name(), cfg.effective_bits(), p.ppl()));
        }
    }
    table.print();

    println!("\n[3/3] weight + KV-cache quantization (Pallas kvq artifacts):");
    let mut kv_table = Table::new(&["bits", "format", "ppl (W+KV)", "Δ vs FP16"]);
    for bits in [6u8, 5, 4] {
        for (label, artifact, cfg) in [
            ("BFP", format!("eval_step_kvq_bfp{bits}"), NxConfig::bfp(bits)),
            ("MxFP", format!("eval_step_kvq_mxfp{bits}"), NxConfig::mxfp(bits)),
            ("NxFP", format!("eval_step_kvq_nxfp{bits}"), NxConfig::nxfp(bits)),
        ] {
            let step = rt.load(&artifact)?;
            let qck = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
            let p = perplexity(&step, &qck, &corpus, spec.seq_len, 8)?;
            kv_table.row(&[
                bits.to_string(),
                format!("{label}{bits}"),
                format!("{:.4}", p.ppl()),
                format!("{:+.4}", p.ppl() - fp16.ppl()),
            ]);
        }
    }
    kv_table.print();

    // sanity summary for EXPERIMENTS.md
    let get = |name: &str| results.iter().find(|(n, ..)| n.contains(name)).map(|r| r.2);
    if let (Some(mx4), Some(nx4)) = (get("MxFP4"), get("NxFP4 (NM+AM+CR)")) {
        println!(
            "\nheadline: NxFP4 improves ppl by {:.3} over MxFP4 (paper: up to 0.64)",
            mx4 - nx4
        );
    }
    println!("done.");
    Ok(())
}
