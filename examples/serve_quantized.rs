//! Serving demo: the threaded coordinator answers batched generation
//! requests through the AOT `decode_step`, with the KV cache stored in
//! packed NxFP4 between steps and dequantized on the fly (paper §6).
//! Compares KV-format footprints and reports latency/throughput.
//!
//! Requires `artifacts/model.ckpt` (run the train_and_quantize example
//! first). Run: `cargo run --release --example serve_quantized`

use anyhow::Result;
use std::path::{Path, PathBuf};

use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::GenRequest;
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::corpus::Probe;
use nxfp::models::{Checkpoint, GrammarSpec, LmSpec};

fn main() -> Result<()> {
    let spec = LmSpec::small();
    let ckpt_path = Path::new("artifacts/model.ckpt");
    anyhow::ensure!(
        ckpt_path.exists(),
        "artifacts/model.ckpt missing — run `cargo run --release --example train_and_quantize` first"
    );
    let ck = Checkpoint::load(ckpt_path)?;
    let gspec = GrammarSpec::default_for_vocab(spec.vocab);
    let probes = Probe::generate(&gspec, 12, 2024);

    for (label, kv) in [
        ("KV FP32 (baseline)", QuantPolicy::fp16()),
        ("KV NxFP5", QuantPolicy::uniform(NxConfig::nxfp(5))),
        ("KV NxFP4", QuantPolicy::uniform(NxConfig::nxfp(4))),
        // mixed precision: keys keep a NanoMantissa bit, values go 4-bit
        ("KV K=NxFP5 / V=MxFP4", QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4")?),
    ] {
        println!("\n== {label} ==");
        // defaults: continuous scheduling with chunked prefill (budget 64
        // tokens/step) — set prefill_budget: 1 to see the legacy
        // token-at-a-time prefill schedule
        let server = ServerHandle::spawn(
            PathBuf::from("artifacts"),
            spec,
            ck.clone(),
            kv,
            ServeOpts::default(),
        );
        let t0 = std::time::Instant::now();
        for (i, p) in probes.iter().enumerate() {
            server.submit(GenRequest { id: i as u64, prompt: p.prompt.clone(), max_new: 24 });
        }
        let mut latencies = Vec::new();
        for _ in 0..probes.len() {
            let resp = server.recv().expect("server dropped");
            latencies.push(resp.latency);
        }
        let wall = t0.elapsed();
        let report = server.shutdown()?;
        let m = report.metrics;
        latencies.sort();
        println!(
            "  {} requests, {} tokens in {:.2?}  ({:.1} tok/s, {} decode steps)",
            m.requests,
            m.tokens_generated,
            wall,
            m.tokens_generated as f64 / wall.as_secs_f64(),
            m.decode_steps
        );
        println!(
            "  latency p50 {:?}  p99 {:?}",
            latencies[latencies.len() / 2],
            latencies[latencies.len() - 1]
        );
        if m.kv_bits_fp16 > 0 {
            println!(
                "  KV footprint: {} KiB packed vs {} KiB FP16 ({:.1}% saved)",
                m.kv_bits_packed / 8 / 1024,
                m.kv_bits_fp16 / 8 / 1024,
                m.kv_savings() * 100.0
            );
            if m.kv_bits_packed_k != m.kv_bits_packed_v {
                println!(
                    "  per-class split: K {} KiB, V {} KiB",
                    m.kv_bits_packed_k / 8 / 1024,
                    m.kv_bits_packed_v / 8 / 1024
                );
            }
        }
        println!("  {}", report.serving.summary());
    }
    Ok(())
}
