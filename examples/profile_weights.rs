//! Reproduce the paper's §3 profiling story (Fig. 3): histogram the
//! E_shared-scaled weights of each synthetic model profile and quantify the
//! three low-bit MxFP pathologies — untracked outliers above the top level,
//! the vacant band between the top two levels, and the near-zero mass where
//! the wasted −0 code matters.
//!
//! Run: `cargo run --release --example profile_weights`

use nxfp::formats::NxConfig;
use nxfp::models::{synth_weights, ModelProfile};
use nxfp::profile::profile_scaled;

fn main() {
    let cfg = NxConfig::mxfp(4);
    println!("== Fig. 3 — weights scaled by E_shared (block 32, MxFP4 domain) ==\n");
    for p in ModelProfile::all() {
        let w = synth_weights(&p, 192, 2048);
        let prof = profile_scaled(&w, &cfg);
        println!(
            "{:<12}  n={}  above-top(|v|>6): {:.3}%  vacant band (4.5..5.5): {:.3}%  near-zero: {:.1}%",
            p.name,
            prof.n,
            prof.above_top * 100.0,
            prof.vacant_band * 100.0,
            prof.near_zero * 100.0
        );
    }

    // detailed histogram for the lead model (the paper's Fig. 3 panels)
    let p = ModelProfile::by_name("Llama3-8B").unwrap();
    let w = synth_weights(&p, 192, 2048);
    let prof = profile_scaled(&w, &cfg);
    println!(
        "\nLlama3-8B scaled-weight histogram (quantization levels at ±{{0.5,1,1.5,2,3,4,6}}):\n"
    );
    print!("{}", prof.hist.render(64));
}
