//! Quickstart: the NxFP public API in five minutes.
//!
//! Covers: configuring formats, direct-cast quantization of a tensor,
//! per-technique error ablation, packed storage + footprint accounting,
//! and the on-the-fly dequantization hot path (LUT + fused GEMV).
//!
//! Run: `cargo run --release --example quickstart`

use nxfp::dequant::{dequantize_packed, gemv_packed, DequantLut};
use nxfp::formats::{BaseFormat, NxConfig};
use nxfp::models::{synth_weights, ModelProfile};
use nxfp::quant::{fake_quant, quantize_matrix};
use nxfp::tensor::stats::{mse, sqnr_db};
use nxfp::util::rng::Rng;

fn main() {
    println!("== nxfp quickstart ==\n");

    // 1. Make some LLM-like weights (Llama3-profile synthetic tensor).
    let profile = ModelProfile::by_name("Llama3-8B").unwrap();
    let w = synth_weights(&profile, 64, 1024);
    println!("weights: {}x{} (synthetic {} profile)", w.rows, w.cols, profile.name);

    // 2. Direct-cast one row under different formats and compare error.
    println!("\nper-format quantization error on one row:");
    let row = w.row(0);
    for cfg in [
        NxConfig::bfp(4),
        NxConfig::mxfp(4),
        NxConfig::nxfp_nm(4),
        NxConfig::nxfp_nm_am(4),
        NxConfig::nxfp(4), // NM + AM + CR
        NxConfig::mxfp(6),
    ] {
        let q = fake_quant(row, &cfg);
        println!(
            "  {:<18} mse {:.3e}   sqnr {:>5.1} dB   eff bits {:.2}",
            cfg.name(),
            mse(row, &q),
            sqnr_db(row, &q),
            cfg.effective_bits()
        );
    }

    // 3. Quantize the whole matrix (allocation-free engine, flat
    //    BlockStore) and pack it for deployment.
    let cfg = NxConfig::nxfp(4);
    let q = quantize_matrix(&w, &cfg);
    let packed = q.pack(&cfg);
    let fp16_bytes = w.len() * 2;
    println!(
        "\npacked {} : {} B (FP16 would be {} B -> {:.1}% footprint)",
        cfg.name(),
        packed.footprint_bytes(),
        fp16_bytes,
        100.0 * packed.footprint_bytes() as f64 / fp16_bytes as f64
    );

    // 4. On-the-fly dequantization (Fig. 7): LUT decode of the packed form.
    let lut = DequantLut::new(&cfg);
    let back = dequantize_packed(&packed, &lut, cfg.base == BaseFormat::Mx);
    println!("dequantized tensor mse: {:.3e}", mse(&w.data, &back.data));

    // 5. Fused dequant+GEMV — weights never materialize in f32.
    let mut rng = Rng::seeded(1);
    let x: Vec<f32> = (0..w.cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut y = vec![0.0f32; w.rows];
    gemv_packed(&packed, &lut, cfg.base == BaseFormat::Mx, &x, &mut y);
    let mut y_ref = vec![0.0f32; w.rows];
    for r in 0..w.rows {
        y_ref[r] = back.row(r).iter().zip(&x).map(|(&a, &b)| a * b).sum();
    }
    println!("fused gemv vs dequant-then-gemv mse: {:.3e}", mse(&y, &y_ref));

    println!("\nnext: `cargo run --release --example train_and_quantize` for the full pipeline");
}
