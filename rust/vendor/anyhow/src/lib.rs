//! Minimal offline stand-in for the `anyhow` crate (vendored: the build
//! image has no network, so the real crate cannot be fetched). Implements
//! exactly the subset this workspace uses: [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` / `ensure!` macros, with
//! anyhow-compatible `{:#}` context-chain display and `downcast_ref` to
//! the original typed error.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Boxed error carrying a stack of human-readable context lines
/// (outermost first) over the original typed error.
pub struct Error {
    context: Vec<String>,
    root: Box<dyn StdError + Send + Sync + 'static>,
}

#[derive(Debug)]
struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

impl Error {
    /// Build an error from a display-able message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { context: Vec::new(), root: Box::new(Message(m.to_string())) }
    }

    /// Prepend a context line (becomes the outermost message).
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.context.insert(0, c.to_string());
        self
    }

    /// Borrow the original error if it is of type `T`.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        self.root.downcast_ref::<T>()
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { context: Vec::new(), root: Box::new(e) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain: "outer: inner: root"
            for c in &self.context {
                write!(f, "{c}: ")?;
            }
            return write!(f, "{}", self.root);
        }
        match self.context.first() {
            Some(c) => f.write_str(c),
            None => write!(f, "{}", self.root),
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:#}")
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;

    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("leaf failure")
        }
    }

    impl StdError for Leaf {}

    fn fails() -> Result<()> {
        Err(Leaf).context("opening widget")
    }

    #[test]
    fn context_chain_display() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "opening widget");
        assert_eq!(format!("{e:#}"), "opening widget: leaf failure");
    }

    #[test]
    fn downcast_to_root() {
        let e = fails().unwrap_err();
        assert!(e.downcast_ref::<Leaf>().is_some());
        assert!(e.downcast_ref::<Message>().is_none());
    }

    #[test]
    fn macros_compose() {
        fn inner(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("x too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(inner(5).unwrap(), 5);
        assert_eq!(format!("{}", inner(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", inner(101).unwrap_err()), "x too big: 101");
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("empty literal").unwrap_err();
        assert_eq!(format!("{e}"), "empty literal");
    }
}
