//! Offline stub of the `xla` (xla_extension / PJRT) bindings used by the
//! runtime layer. The [`Literal`] container is fully functional — typed
//! host-side buffers with a shape — so checkpoint/trainer plumbing and all
//! unit tests work without the native library. Compiling or executing an
//! HLO module requires the real PJRT backend and returns a clear error
//! here; swap the `xla` path dependency in `rust/Cargo.toml` for the real
//! bindings to run AOT artifacts (see `rust/src/runtime/mod.rs`).

use std::borrow::Borrow;
use std::fmt;

/// Stub error type; call sites format it with `{:?}`.
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!("{what}: XLA PJRT backend unavailable in this offline stub build"))
}

/// Element storage for a [`Literal`].
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Data;
    #[doc(hidden)]
    fn extract(d: &Data) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }

    fn extract(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(v: Vec<Self>) -> Data {
        Data::I32(v)
    }

    fn extract(d: &Data) -> Option<Vec<Self>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A typed host-side buffer with a shape (row-major).
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        Literal { data: T::wrap(vec![x]), dims: Vec::new() }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Same data under a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let len = self.element_count() as i64;
        if n != len {
            return Err(Error(format!("reshape to {dims:?}: {len} elements present")));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Copy out the elements (row-major), checking the element type.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Flatten a tuple literal. The stub never produces tuples (they only
    /// come out of `execute`, which requires the real backend).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error("not a tuple literal (offline stub)".into()))
    }
}

/// Stub PJRT client: constructible so drivers can start up, but any
/// compilation reports the backend as unavailable.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub (PJRT unavailable)".to_string()
    }

    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Parsed HLO module text (held verbatim; the real parser lives in the
/// native bindings).
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        std::fs::read_to_string(path)
            .map(|text| HloModuleProto { _text: text })
            .map_err(|e| Error(format!("read {path}: {e}")))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = l.reshape(&[2, 3]).unwrap();
        assert_eq!(l.dims(), &[2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7, 1]).is_err());
    }

    #[test]
    fn literal_scalar_i32() {
        let l = Literal::scalar(42i32);
        assert_eq!(l.element_count(), 1);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![42]);
    }

    #[test]
    fn compile_reports_unavailable() {
        let c = PjRtClient::cpu().unwrap();
        let err = c.compile(&XlaComputation).unwrap_err();
        assert!(format!("{err:?}").contains("unavailable"));
    }
}
