//! Fig. 3 — profile of weights scaled by E_shared (block 32) on the five
//! modern-LLM synthetic profiles, quantifying the three low-bit MxFP
//! challenges: outliers above the top level, the vacant level band, and
//! near-zero mass (wasted −0 code).

use nxfp::bench_util::{banner, Table};
use nxfp::formats::NxConfig;
use nxfp::models::{synth_weights, ModelProfile};
use nxfp::profile::profile_scaled;

fn main() {
    banner("Fig.3", "scaled-weight distribution profile (MxFP4 domain)");
    let cfg = NxConfig::mxfp(4);
    let mut t = Table::new(&[
        "model", "elements", "above top (>6)", "vacant band", "near zero",
    ]);
    for p in ModelProfile::all() {
        let w = synth_weights(&p, 192, 2048);
        let prof = profile_scaled(&w, &cfg);
        t.row(&[
            p.name.to_string(),
            prof.n.to_string(),
            format!("{:.3}%", prof.above_top * 100.0),
            format!("{:.3}%", prof.vacant_band * 100.0),
            format!("{:.1}%", prof.near_zero * 100.0),
        ]);
    }
    t.print();

    println!(
        "\nLlama3-8B histogram (paper Fig. 3 top-left; MxFP4 levels ±{{0.5,1,1.5,2,3,4,6}}):"
    );
    let p = ModelProfile::by_name("Llama3-8B").unwrap();
    let prof = profile_scaled(&synth_weights(&p, 192, 2048), &cfg);
    print!("{}", prof.hist.render(56));
}
