//! Wave vs continuous scheduling under bursty, mixed-length traffic
//! (paper §6 deployment at serving scale; ROADMAP "continuous batching").
//!
//! Runs without the PJRT runtime or artifacts: both schedulers drive the
//! real `DecodeEngine` — persistent step slabs, per-slot `SlotKv` packed
//! caches with incremental lane sync, greedy sampling — over the
//! deterministic `SynthBackend`, whose per-step cost is fixed-shape
//! `[B, L, S, D]` like the artifact. That makes the comparison purely
//! about *scheduling*: a wave holds every lane until its longest request
//! drains, while the continuous scheduler admits the next queued request
//! into a lane the step it frees, so mixed-length bursts keep all lanes
//! generating.
//!
//! Reports tok/s and per-request p50/p95 completion latency (arrival →
//! response, queue wait included for both modes). A second, prefill-heavy
//! scenario sweeps the chunked-prefill budget (1 = unchunked vs 16/64):
//! long prompts with short answers are where per-token prefill inflates
//! TTFT, and the sweep reports wall-clock latency plus deterministic
//! TTFT-in-steps. A third scenario runs shared-system-prompt traffic
//! through the paged-KV prefix cache (on vs off) and gates on
//! bit-identical generations, dedup factor > 1, and strictly fewer
//! steps. A final observability scenario gates the tracing overhead
//! contract (bit-identical generations with the trace sink on) and
//! reports the code-occupancy probe rates; with `NXFP_OBS_OUT=<dir>` it
//! also writes `trace.jsonl` / `metrics.prom` / `metrics.json` artifacts
//! from a traced fault run and validates the trace in-process. A fleet
//! scenario serves the same shared-prefix burst through 1/2/4 router-fronted
//! replicas with a mid-run graceful drain, gating on zero lost requests,
//! bit-identical generations, exact rollup sums, and per-replica prefix
//! hits. A speculative-decoding scenario sweeps the draft depth k=1/2/4/8
//! with the serving nxfp4 engine drafting for an fp16 verifier lane,
//! gating on bit-identical generations versus the verifier-alone run, a
//! nonzero acceptance rate, and strictly fewer scheduler macro steps per
//! generated token at every k > 1 than at k = 1.
//! With `NXFP_BENCH_JSON=<dir>`, appends records to
//! `BENCH_scheduler.json` (fleet rows go to `BENCH_fleet.json`, keyed
//! `replicas=N`). Set `NXFP_BENCH_SMOKE=1` for a seconds-scale CI smoke run.

use nxfp::bench_util::{banner, emit_bench_json, quantile_duration, smoke_env, StepTtft, Table};
use nxfp::coordinator::fault::FaultPlan;
use nxfp::coordinator::router::FleetHandle;
use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::server::ServeOpts;
use nxfp::coordinator::{DecodeEngine, FinishReason, GenRequest, GenResponse, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;
use nxfp::obs::{
    check_trace, read_jsonl, write_metrics, Trace, TraceSink, TraceSummary, DEFAULT_TRACE_CAP,
};
use nxfp::spec::{SpecEngine, SpecPolicy};
use nxfp::util::rng::Rng;
use std::time::{Duration, Instant};

const MAX_BATCH: usize = 4;

fn spec(seq_len: usize) -> LmSpec {
    LmSpec { vocab: 64, d_model: 64, n_layers: 4, n_heads: 4, d_ff: 256, seq_len }
}

/// Bursty, mixed-length traffic: `bursts` batches of requests, each burst
/// mixing short chats (short prompt, few tokens) with long generations.
/// The mix is the adversarial case for wave scheduling: every wave that
/// pairs a short and a long request idles lanes.
fn traffic(bursts: usize, per_burst: usize, s: usize, rng: &mut Rng) -> Vec<GenRequest> {
    let mut reqs = Vec::new();
    for b in 0..bursts {
        for i in 0..per_burst {
            let id = (b * per_burst + i) as u64;
            let long = rng.below(2) == 1;
            let (plen, max_new) = if long {
                (s / 3, (s / 2).min(s - s / 3 - 2))
            } else {
                (2 + rng.below(3), 3 + rng.below(4))
            };
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(60) as i32 + 1).collect();
            reqs.push(GenRequest { id, prompt, max_new });
        }
    }
    reqs
}

fn engine(seq_len: usize, kv: &QuantPolicy) -> DecodeEngine {
    let sp = spec(seq_len);
    DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), kv, MAX_BATCH)
}

/// Wave mode: requests form FIFO waves of `MAX_BATCH`; each wave runs to
/// completion. Per-request latency counts from the burst start (`t0`),
/// like the continuous path, so queue wait is included for both.
fn run_wave(engine: &mut DecodeEngine, reqs: &[GenRequest]) -> Vec<Duration> {
    let t0 = Instant::now();
    let mut lats = Vec::new();
    for wave in reqs.chunks(MAX_BATCH) {
        let waited = t0.elapsed();
        for resp in engine.serve_wave(wave.to_vec()).expect("wave failed") {
            lats.push(waited + resp.latency);
        }
    }
    lats
}

/// Continuous mode: everything enqueued at burst start; the scheduler
/// backfills lanes as slots finish. `GenResponse::latency` already counts
/// from enqueue.
fn run_continuous(engine: &mut DecodeEngine, reqs: &[GenRequest]) -> Vec<Duration> {
    let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
    for r in reqs {
        sched.enqueue(r.clone());
    }
    engine
        .serve_continuous(&mut sched)
        .expect("continuous failed")
        .iter()
        .map(|r: &GenResponse| r.latency)
        .collect()
}

/// Prefill-heavy bursty traffic: prompts fill one-half to three-quarters
/// of the context window and answers are short — the regime where feeding
/// one prompt token per step makes everyone's TTFT pay for the longest
/// prompt in the batch.
fn prefill_heavy_traffic(
    bursts: usize,
    per_burst: usize,
    s: usize,
    rng: &mut Rng,
) -> Vec<GenRequest> {
    let mut reqs = Vec::new();
    for b in 0..bursts {
        for i in 0..per_burst {
            let plen = s / 2 + rng.below(s / 4);
            let max_new = (2 + rng.below(4)).min(s - plen - 1);
            reqs.push(GenRequest {
                id: (b * per_burst + i) as u64,
                prompt: (0..plen).map(|_| rng.below(60) as i32 + 1).collect(),
                max_new,
            });
        }
    }
    reqs
}

/// Shared-system-prompt traffic: every request opens with the same
/// `sys_len`-token system prompt and differs only in a short user suffix
/// — the regime the paged-KV prefix cache targets (one packed copy of
/// the shared prefix, per-request pages only for the suffixes).
fn shared_prefix_traffic(n: usize, sys_len: usize, rng: &mut Rng) -> Vec<GenRequest> {
    let sys: Vec<i32> = (0..sys_len).map(|_| rng.below(60) as i32 + 1).collect();
    (0..n)
        .map(|i| {
            let mut prompt = sys.clone();
            prompt.extend((0..4).map(|_| rng.below(60) as i32 + 1));
            GenRequest { id: i as u64, prompt, max_new: 4 }
        })
        .collect()
}

/// Fleet traffic: `n` requests cycling over four *distinct* `sys_len`-token
/// system prompts with short user suffixes — multiple prefix families so
/// affinity routing has real placement decisions to make (a single family
/// would pin everything to one replica).
fn fleet_shared_traffic(n: usize, sys_len: usize, rng: &mut Rng) -> Vec<GenRequest> {
    let sys: Vec<Vec<i32>> = (0..4)
        .map(|_| (0..sys_len).map(|_| rng.below(60) as i32 + 1).collect())
        .collect();
    (0..n)
        .map(|i| {
            let mut prompt = sys[i % 4].clone();
            prompt.extend((0..4).map(|_| rng.below(60) as i32 + 1));
            GenRequest { id: i as u64, prompt, max_new: 4 }
        })
        .collect()
}

/// Decode-heavy traffic for the speculative sweep: short prompts, long
/// generations. Rounds are dominated by draft/verify decode, so the
/// macro-step savings of deeper drafts stand clear of prefill, which
/// costs the same number of steps at every k.
fn spec_traffic(n: usize, s: usize, rng: &mut Rng) -> Vec<GenRequest> {
    (0..n)
        .map(|i| {
            let plen = 2 + rng.below(4);
            GenRequest {
                id: i as u64,
                prompt: (0..plen).map(|_| rng.below(60) as i32 + 1).collect(),
                max_new: s / 2,
            }
        })
        .collect()
}

/// Continuous run with the prefix cache on or off, tracking the
/// deterministic TTFT-in-steps alongside the responses.
fn run_prefix(
    engine: &mut DecodeEngine,
    reqs: &[GenRequest],
    budget: usize,
    cache: bool,
) -> (Vec<GenResponse>, StepTtft, u64) {
    engine.set_prefill_budget(budget);
    let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_prefill_budget(budget);
    if cache {
        sched.enable_prefix_cache(engine.page_pool(), Scheduler::DEFAULT_PREFIX_ENTRIES);
    }
    for r in reqs {
        sched.enqueue(r.clone());
    }
    let mut out = Vec::new();
    let mut ttft = StepTtft::new();
    let mut step = 0u64;
    while sched.has_work() {
        let done = engine.step_continuous(&mut sched).expect("prefix step failed");
        step += 1;
        ttft.observe(step, sched.slots());
        ttft.observe_done(step, &done);
        out.extend(done);
    }
    (out, ttft, step)
}

/// Continuous run at a prefill budget, tracking deterministic
/// TTFT-in-steps next to the wall-clock latencies.
fn run_budgeted(
    engine: &mut DecodeEngine,
    reqs: &[GenRequest],
    budget: usize,
) -> (Vec<Duration>, StepTtft, u64) {
    engine.set_prefill_budget(budget);
    let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_prefill_budget(budget);
    for r in reqs {
        sched.enqueue(r.clone());
    }
    let mut lats = Vec::new();
    let mut ttft = StepTtft::new();
    let mut step = 0u64;
    while sched.has_work() {
        let done = engine.step_continuous(&mut sched).expect("budgeted step failed");
        step += 1;
        ttft.observe(step, sched.slots());
        ttft.observe_done(step, &done);
        lats.extend(done.iter().map(|r| r.latency));
    }
    (lats, ttft, step)
}

fn main() {
    banner("HotpathScheduler", "wave vs continuous batching under bursty traffic");
    let (seq, bursts, per_burst) = if smoke_env() { (32, 2, 8) } else { (128, 4, 24) };
    let kv = QuantPolicy::uniform(NxConfig::nxfp(4));
    let kv_bits = NxConfig::nxfp(4).effective_bits();
    let mut rng = Rng::seeded(41);
    let reqs = traffic(bursts, per_burst, seq, &mut rng);
    println!(
        "traffic: {} requests in {bursts} bursts, B={MAX_BATCH} L=4 S={seq} D=64, KV {}\n",
        reqs.len(),
        kv.name()
    );

    let mut t = Table::new(&[
        "scheduler", "tok/s", "steps", "tokens", "p50 lat ms", "p95 lat ms", "kv savings",
    ]);
    let mut results = Vec::new();
    for (label, continuous) in [("wave", false), ("continuous", true)] {
        let mut eng = engine(seq, &kv);
        let lats = if continuous {
            run_continuous(&mut eng, &reqs)
        } else {
            run_wave(&mut eng, &reqs)
        };
        assert_eq!(lats.len(), reqs.len(), "{label}: lost responses");
        let m = eng.metrics;
        let (p50, p95) = (quantile_duration(&lats, 0.5), quantile_duration(&lats, 0.95));
        t.row(&[
            label.to_string(),
            format!("{:.0}", m.tokens_per_sec()),
            format!("{}", m.decode_steps),
            format!("{}", m.tokens_generated),
            format!("{:.2}", p50.as_secs_f64() * 1e3),
            format!("{:.2}", p95.as_secs_f64() * 1e3),
            format!("{:.1}%", m.kv_savings() * 100.0),
        ]);
        emit_bench_json(
            "scheduler",
            label,
            &kv.name(),
            &kv.name(),
            &[
                ("tok_s", m.tokens_per_sec()),
                ("p50_ms", p50.as_secs_f64() * 1e3),
                ("p95_ms", p95.as_secs_f64() * 1e3),
                ("decode_steps", m.decode_steps as f64),
                ("tokens", m.tokens_generated as f64),
                ("effective_bits", kv_bits),
            ],
        );
        results.push((label, m.tokens_per_sec(), m.decode_steps));
    }
    t.print();

    let (wave_tps, cont_tps) = (results[0].1, results[1].1);
    println!(
        "\ncontinuous serves the same {} requests in {} steps vs {} (wave), \
         {:.2}x tok/s (acceptance: >= 1x on mixed-length bursty traffic)",
        reqs.len(),
        results[1].2,
        results[0].2,
        cont_tps / wave_tps
    );

    // ---- chunked-prefill budget sweep on prefill-heavy bursty traffic ----
    banner("HotpathScheduler", "chunked prefill budget sweep, prefill-heavy bursts");
    let mut rng = Rng::seeded(42);
    let reqs = prefill_heavy_traffic(bursts, per_burst, seq, &mut rng);
    println!(
        "traffic: {} requests, prompts ~{}..{} tokens of S={seq}, short answers\n",
        reqs.len(),
        seq / 2,
        3 * seq / 4
    );
    let mut t = Table::new(&[
        "budget", "tok/s", "steps", "ttft p50 steps", "p50 lat ms", "p95 lat ms", "kv savings",
    ]);
    let mut sweep = Vec::new();
    for budget in [1usize, 16, 64] {
        let mut eng = engine(seq, &kv);
        let (lats, ttft, steps) = run_budgeted(&mut eng, &reqs, budget);
        assert_eq!(lats.len(), reqs.len(), "budget {budget}: lost responses");
        let m = eng.metrics;
        let (p50, p95) = (quantile_duration(&lats, 0.5), quantile_duration(&lats, 0.95));
        t.row(&[
            format!("{budget}"),
            format!("{:.0}", m.tokens_per_sec()),
            format!("{steps}"),
            format!("{}", ttft.quantile(0.5)),
            format!("{:.2}", p50.as_secs_f64() * 1e3),
            format!("{:.2}", p95.as_secs_f64() * 1e3),
            format!("{:.1}%", m.kv_savings() * 100.0),
        ]);
        emit_bench_json(
            "scheduler",
            &format!("prefill-heavy-b{budget}"),
            &kv.name(),
            &kv.name(),
            &[
                ("tok_s", m.tokens_per_sec()),
                ("p50_ms", p50.as_secs_f64() * 1e3),
                ("p95_ms", p95.as_secs_f64() * 1e3),
                ("ttft_p50_steps", ttft.quantile(0.5) as f64),
                ("ttft_mean_steps", ttft.mean()),
                ("engine_steps", steps as f64),
                ("effective_bits", kv_bits),
            ],
        );
        sweep.push((budget, m.tokens_per_sec(), ttft.quantile(0.5), ttft.mean(), steps));
    }
    t.print();

    let (b1, b16) = (&sweep[0], &sweep[1]);
    println!(
        "\nbudget 16 vs 1: {:.2}x tok/s, ttft p50 {} -> {} steps, mean {:.1} -> {:.1}, \
         engine steps {} -> {} (acceptance: lower p50 TTFT at equal-or-better tok/s; \
         tok/s is reported, not asserted — wall-clock noise belongs to the JSON trajectory)",
        b16.1 / b1.1,
        b1.2,
        b16.2,
        b1.3,
        b16.3,
        b1.4,
        b16.4
    );
    // only the machine-independent halves gate: TTFT-in-steps and engine
    // steps are deterministic on SynthBackend, wall-clock tok/s is not
    assert!(
        b16.3 < b1.3 && b16.4 <= b1.4,
        "chunked prefill must cut deterministic TTFT without extra steps \
         (ttft mean {:.1} vs {:.1}, steps {} vs {})",
        b16.3,
        b1.3,
        b16.4,
        b1.4
    );

    // ---- prefix sharing on shared-system-prompt traffic -----------------
    banner("HotpathScheduler", "paged-KV prefix cache, shared system prompt");
    let sys_len = seq / 2;
    let n_reqs = bursts * per_burst;
    let budget = 16usize;
    let mut rng = Rng::seeded(44);
    let shared = shared_prefix_traffic(n_reqs, sys_len, &mut rng);
    println!(
        "traffic: {n_reqs} requests sharing a {sys_len}-token system prompt \
         + 4-token user suffixes, prefill budget {budget}, KV {}\n",
        kv.name()
    );
    let mut t = Table::new(&[
        "prefix cache", "steps", "ttft mean steps", "hit rate", "dedup", "kv unique KiB",
    ]);
    let mut runs = Vec::new();
    for cache in [false, true] {
        let label = if cache { "on" } else { "off" };
        let mut eng = engine(seq, &kv);
        let (resps, ttft, steps) = run_prefix(&mut eng, &shared, budget, cache);
        assert_eq!(resps.len(), shared.len(), "prefix cache {label}: lost responses");
        let mut toks: Vec<(u64, Vec<i32>)> =
            resps.into_iter().map(|r| (r.id, r.tokens)).collect();
        toks.sort();
        let m = eng.metrics;
        let hit_rate = eng.serving.prefix_hit_rate();
        t.row(&[
            label.to_string(),
            format!("{steps}"),
            format!("{:.1}", ttft.mean()),
            format!("{:.0}%", hit_rate * 100.0),
            format!("{:.2}x", m.dedup_factor()),
            format!("{}", m.kv_bits_packed_dedup() / 8 / 1024),
        ]);
        emit_bench_json(
            "scheduler",
            &format!("prefix-cache-{label}"),
            &kv.name(),
            &kv.name(),
            &[
                ("tok_s", m.tokens_per_sec()),
                ("engine_steps", steps as f64),
                ("ttft_mean_steps", ttft.mean()),
                ("prefix_hit_rate", hit_rate),
                ("dedup_factor", m.dedup_factor()),
                ("kv_unique_kib", (m.kv_bits_packed_dedup() / 8 / 1024) as f64),
            ],
        );
        runs.push((toks, ttft.mean(), steps, hit_rate, m.dedup_factor()));
    }
    t.print();
    let (off_run, on_run) = (&runs[0], &runs[1]);
    assert_eq!(off_run.0, on_run.0, "prefix cache changed a generation");
    println!(
        "\nprefix cache on vs off: identical generations, hit rate {:.0}%, \
         dedup {:.2}x, ttft mean {:.1} -> {:.1} steps, engine steps {} -> {} \
         (acceptance: dedup > 1x and strictly fewer steps at bit-identical output)",
        on_run.3 * 100.0,
        on_run.4,
        off_run.1,
        on_run.1,
        off_run.2,
        on_run.2
    );
    assert!(
        on_run.4 > 1.0 && on_run.1 < off_run.1 && on_run.2 < off_run.2,
        "prefix cache must dedup (got {:.2}x) and cut deterministic TTFT \
         ({:.1} vs {:.1}) and engine steps ({} vs {})",
        on_run.4,
        on_run.1,
        off_run.1,
        on_run.2,
        off_run.2
    );

    // ---- mixed-precision KV policy on the same bursty traffic ----------
    banner("HotpathScheduler", "mixed-precision KV policy (kv.k=nxfp5, kv.v=mxfp4)");
    let mixed = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").expect("mixed policy spec");
    let (cfg_k, cfg_v) = (NxConfig::nxfp(5), NxConfig::mxfp(4));
    let mut rng = Rng::seeded(43);
    let reqs = traffic(bursts, per_burst, seq, &mut rng);
    let mut eng = engine(seq, &mixed);
    let lats = run_continuous(&mut eng, &reqs);
    assert_eq!(lats.len(), reqs.len(), "mixed policy: lost responses");
    let m = eng.metrics;
    // both streams store the same row count, so the per-class split must
    // follow the two configs' per-row footprints exactly
    let d = spec(seq).d_model;
    assert_eq!(
        m.kv_bits_packed_k * cfg_v.footprint_bits(d),
        m.kv_bits_packed_v * cfg_k.footprint_bits(d),
        "per-stream footprint split off the configs' accounting"
    );
    let (p50, p95) = (quantile_duration(&lats, 0.5), quantile_duration(&lats, 0.95));
    println!(
        "mixed KV: {:.0} tok/s, kv savings {:.1}% (K {} KiB / V {} KiB packed)",
        m.tokens_per_sec(),
        m.kv_savings() * 100.0,
        m.kv_bits_packed_k / 8 / 1024,
        m.kv_bits_packed_v / 8 / 1024
    );
    emit_bench_json(
        "scheduler",
        "mixed-kv",
        // config = the resolved formats, policy = the spec that chose them
        &format!("K={} V={}", cfg_k.name(), cfg_v.name()),
        &mixed.name(),
        &[
            ("tok_s", m.tokens_per_sec()),
            ("p50_ms", p50.as_secs_f64() * 1e3),
            ("p95_ms", p95.as_secs_f64() * 1e3),
            ("decode_steps", m.decode_steps as f64),
            (
                "effective_bits",
                (cfg_k.effective_bits() + cfg_v.effective_bits()) / 2.0,
            ),
        ],
    );

    // ---- fault sweep: transient step errors at 0% / 1% / 5% -------------
    banner("HotpathScheduler", "fault sweep: seeded transient step errors");
    let mut rng = Rng::seeded(45);
    let reqs = traffic(bursts, per_burst, seq, &mut rng);
    println!(
        "traffic: {} requests, continuous mode, retries absorb every transient \
         fault in place (acceptance: zero lost requests, fault counters match \
         the injected schedule, bit-identical generations at every rate)\n",
        reqs.len()
    );
    let mut t = Table::new(&[
        "fault rate", "tok/s", "injected", "retries", "backoff p95 ms", "lost", "completed",
    ]);
    let mut baseline: Option<Vec<(u64, Vec<i32>)>> = None;
    for rate in [0.0f64, 0.01, 0.05] {
        // every seed must satisfy the invariants; the reported run is the
        // first whose schedule actually fired (rate 0 fires vacuously), so
        // a low rate on a short smoke run can't report a no-op sweep
        let mut reported = false;
        for seed in 7u64..23 {
            let mut eng = engine(seq, &kv);
            eng.set_retry_policy(8, Duration::from_micros(50));
            let stats = eng.inject_faults(&FaultPlan::transient_steps(seed, rate));
            let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
            for r in &reqs {
                sched.enqueue(r.clone());
            }
            let resps = eng.serve_continuous(&mut sched).expect("fault sweep run failed");
            let injected = stats.borrow().step_errors;
            let completed =
                resps.iter().filter(|r| r.reason == FinishReason::Completed).count();
            let lost = reqs.len() - resps.len();
            // hard gates: nothing lost, nothing failed, counters exact
            assert_eq!(lost, 0, "rate {rate}: lost requests");
            assert_eq!(completed, reqs.len(), "rate {rate}: non-Completed responses");
            assert_eq!(eng.serving.step_faults, injected, "rate {rate}: counter drift");
            assert_eq!(eng.serving.retries, injected, "rate {rate}: one retry per fault");
            assert_eq!(eng.serving.backend_failed + eng.serving.requeued, 0);
            let mut toks: Vec<(u64, Vec<i32>)> =
                resps.into_iter().map(|r| (r.id, r.tokens)).collect();
            toks.sort();
            match &baseline {
                None => baseline = Some(toks),
                Some(b) => assert_eq!(b, &toks, "rate {rate}: generations diverged"),
            }
            if rate > 0.0 && injected == 0 {
                continue; // schedule never fired on this seed: try the next
            }
            let m = eng.metrics;
            let backoff_p95_ms = eng.serving.retry_backoff.p95() * 1e3;
            t.row(&[
                format!("{:.0}%", rate * 100.0),
                format!("{:.0}", m.tokens_per_sec()),
                format!("{injected}"),
                format!("{}", eng.serving.retries),
                format!("{backoff_p95_ms:.2}"),
                format!("{lost}"),
                format!("{completed}/{}", reqs.len()),
            ]);
            emit_bench_json(
                "scheduler",
                "fault-sweep",
                // config keys the rate so bench_compare tracks each fault
                // mode as its own trajectory instead of mixing rates
                &format!("step={rate}"),
                &kv.name(),
                &[
                    ("tok_s", m.tokens_per_sec()),
                    ("fault_rate", rate),
                    ("lost_requests", lost as f64),
                    ("step_faults", injected as f64),
                    ("retries", eng.serving.retries as f64),
                    ("requeued", eng.serving.requeued as f64),
                    ("backoff_p95_ms", backoff_p95_ms),
                ],
            );
            reported = true;
            break;
        }
        assert!(reported, "rate {rate}: no scanned seed fired");
    }
    t.print();
    println!(
        "\nfault sweep: every rate completed {}/{} requests bit-identically; \
         tok/s degrades with injected retries, never with lost work",
        reqs.len(),
        reqs.len()
    );

    // ---- observability: tracing overhead + code-occupancy probes --------
    banner("HotpathScheduler", "observability: tracing overhead, occupancy probes");
    let mut rng = Rng::seeded(46);
    let reqs = traffic(bursts, per_burst, seq, &mut rng);
    println!(
        "traffic: {} requests, continuous mode (acceptance: tracing on is \
         bit-identical to tracing off, the in-memory trace passes the \
         lifecycle checker, occupancy probes report nonzero coverage)\n",
        reqs.len()
    );
    let mut obs_runs = Vec::new();
    for traced in [false, true] {
        let label = if traced { "on" } else { "off" };
        let mut eng = engine(seq, &kv);
        if traced {
            eng.set_trace_sink(TraceSink::enabled(DEFAULT_TRACE_CAP));
            eng.enable_occupancy();
        }
        let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
        sched.set_trace_sink(eng.trace_sink());
        for r in &reqs {
            sched.enqueue(r.clone());
        }
        let resps = eng.serve_continuous(&mut sched).expect("obs run failed");
        assert_eq!(resps.len(), reqs.len(), "tracing {label}: lost responses");
        let mut toks: Vec<(u64, Vec<i32>)> =
            resps.into_iter().map(|r| (r.id, r.tokens)).collect();
        toks.sort();
        let m = &eng.metrics;
        let mut fields = vec![("tok_s", m.tokens_per_sec())];
        if traced {
            // the live ring must already satisfy the lifecycle checker
            let trace = Trace {
                entries: eng.trace_sink().entries(),
                summary: Some(TraceSummary::from_serving(&eng.serving)),
            };
            let viol = check_trace(&trace);
            assert!(viol.is_empty(), "live trace failed the checker: {viol:?}");
            let occ = eng.occupancy_report();
            assert!(!occ.is_empty(), "occupancy probes reported no tables");
            for o in &occ {
                println!("{}", o.summary());
                assert!(o.total > 0, "occupancy probe saw no codes");
            }
            fields.push(("occ_clip_rate", occ[0].clip_rate()));
            fields.push(("occ_vacant_fraction", occ[0].vacant_fraction()));
            fields.push(("occ_recycle_rate", occ[0].recycle_rate()));
            println!(
                "tracing on: {} trace entries, {:.0} tok/s",
                trace.entries.len(),
                m.tokens_per_sec()
            );
        } else {
            println!("tracing off: {:.0} tok/s", m.tokens_per_sec());
        }
        emit_bench_json(
            "scheduler",
            &format!("obs-tracing-{label}"),
            &kv.name(),
            &kv.name(),
            &fields,
        );
        obs_runs.push(toks);
    }
    assert_eq!(obs_runs[0], obs_runs[1], "tracing changed a generation");
    println!("tracing on vs off: bit-identical generations");

    // ---- fleet: multi-replica serving through the prefix-affinity router
    banner("HotpathScheduler", "fleet: replicas 1/2/4, affinity routing, mid-run drain");
    let sys_len = (seq / 3).max(8);
    let n_reqs = bursts * per_burst;
    let fleet_reqs = fleet_shared_traffic(n_reqs, sys_len, &mut Rng::seeded(47));
    println!(
        "traffic: {n_reqs} requests over 4 distinct {sys_len}-token system prompts, \
         submitted as one burst (acceptance: zero lost requests through a mid-run \
         drain, bit-identical to the single-replica run, exact rollup sums, \
         prefix hits on every loaded replica)\n"
    );
    let fleet_opts = ServeOpts {
        max_batch: MAX_BATCH,
        prefill_budget: 16,
        // full pages under the shared prefix even at the smoke spec
        kv_page_rows: 8,
        ..Default::default()
    };
    let mut t = Table::new(&[
        "replicas", "tok/s", "lost", "redispatched", "hit rate", "p50 lat ms", "p95 lat ms",
    ]);
    let mut fleet_runs: Vec<(Vec<(u64, Vec<i32>)>, f64)> = Vec::new();
    for n in [1usize, 2, 4] {
        let t0 = Instant::now();
        let mut fleet = FleetHandle::spawn(n, spec(seq), kv.clone(), fleet_opts.clone());
        for r in &fleet_reqs {
            assert!(fleet.submit(r.clone()), "fleet {n}: submit {} refused", r.id);
        }
        let mut resps = Vec::with_capacity(n_reqs);
        for _ in 0..n_reqs / 4 {
            resps.push(fleet.recv().expect("fleet response"));
        }
        if n > 1 {
            // graceful mid-run drain: replica 0 finishes its backlog, the
            // router stops routing there, racing dispatches replay elsewhere
            fleet.drain_replica(0);
        }
        while resps.len() < n_reqs {
            resps.push(fleet.recv().expect("fleet response after drain"));
        }
        let wall = t0.elapsed();
        let report = fleet.shutdown().expect("fleet shutdown");
        // hard gates: nothing lost, nothing non-Completed, rollup exact
        assert_eq!(resps.len(), n_reqs, "fleet {n}: lost responses");
        let completed =
            resps.iter().filter(|r| r.reason == FinishReason::Completed).count();
        assert_eq!(completed, n_reqs, "fleet {n}: non-Completed responses");
        assert!(report.merge_errors.is_empty(), "fleet {n}: {:?}", report.merge_errors);
        assert_eq!(
            report.metrics.tokens_generated,
            report.replicas.iter().map(|r| r.metrics.tokens_generated).sum::<u64>(),
            "fleet {n}: rollup drift"
        );
        assert_eq!(
            report.serving.prefix_hits,
            report.replicas.iter().map(|r| r.serving.prefix_hits).sum::<u64>(),
            "fleet {n}: prefix-hit rollup drift"
        );
        // affinity keeps each prefix family on one replica, so every
        // replica that saw real load reuses its family's pages
        for (i, rep) in report.replicas.iter().enumerate() {
            if rep.serving.admitted >= (2 * MAX_BATCH) as u64 {
                assert!(
                    rep.serving.prefix_hits > 0,
                    "fleet {n}: replica {i} admitted {} with zero prefix hits",
                    rep.serving.admitted
                );
            }
        }
        let tps = report.metrics.tokens_generated as f64 / wall.as_secs_f64();
        let lats: Vec<Duration> = resps.iter().map(|r| r.latency).collect();
        let (p50, p95) = (quantile_duration(&lats, 0.5), quantile_duration(&lats, 0.95));
        let hit_rate = report.serving.prefix_hit_rate();
        t.row(&[
            format!("{n}"),
            format!("{tps:.0}"),
            "0".to_string(),
            format!("{}", report.redispatched),
            format!("{:.0}%", hit_rate * 100.0),
            format!("{:.2}", p50.as_secs_f64() * 1e3),
            format!("{:.2}", p95.as_secs_f64() * 1e3),
        ]);
        emit_bench_json(
            "fleet",
            "shared-prefix-drain",
            // config keys the replica count so bench_compare tracks each
            // fleet size as its own trajectory
            &format!("replicas={n}"),
            &kv.name(),
            &[
                ("tok_s", tps),
                ("lost_requests", 0.0),
                ("redispatched", report.redispatched as f64),
                ("prefix_hit_rate", hit_rate),
                ("p50_ms", p50.as_secs_f64() * 1e3),
                ("p95_ms", p95.as_secs_f64() * 1e3),
                ("effective_bits", kv_bits),
            ],
        );
        let mut toks: Vec<(u64, Vec<i32>)> =
            resps.into_iter().map(|r| (r.id, r.tokens)).collect();
        toks.sort();
        fleet_runs.push((toks, tps));
    }
    t.print();
    // placement, drain redistribution, and replay are invisible in tokens
    assert_eq!(fleet_runs[0].0, fleet_runs[1].0, "fleet of 2 diverged from solo");
    assert_eq!(fleet_runs[0].0, fleet_runs[2].0, "fleet of 4 diverged from solo");
    let (solo_tps, best_tps) = (
        fleet_runs[0].1,
        fleet_runs.iter().map(|r| r.1).fold(f64::MIN, f64::max),
    );
    println!(
        "\nfleet vs solo: bit-identical generations, best fleet {:.2}x solo tok/s \
         (acceptance: >= 1x with replicas stepping on their own threads; only a \
         degenerate-serialization floor is asserted — wall-clock noise belongs \
         to the JSON trajectory)",
        best_tps / solo_tps
    );
    assert!(
        best_tps >= solo_tps * 0.5,
        "fleet serialized: best {best_tps:.0} tok/s vs solo {solo_tps:.0}"
    );

    // ---- speculative decoding: the quantized engine drafts for itself ---
    banner("HotpathScheduler", "speculative decoding: nxfp4 drafts, fp16 verifies");
    let verify = "fp16";
    let spec_reqs = spec_traffic(2 * MAX_BATCH, seq, &mut Rng::seeded(48));
    println!(
        "traffic: {} decode-heavy requests (max_new {}), draft {} -> verify {verify}, \
         lane pairing halves concurrency to {} requests in flight (acceptance: \
         bit-identical to the verifier-alone run at every k, nonzero acceptance \
         rate, strictly fewer macro steps per token at every k > 1 than k = 1)\n",
        spec_reqs.len(),
        seq / 2,
        kv.name(),
        MAX_BATCH / 2
    );
    // the bit-identity target: the same checkpoint decoded by the verifier
    // policy alone — speculation must never change what gets generated
    let vkv = QuantPolicy::parse(verify).expect("verify policy spec");
    let mut ref_eng = engine(seq, &vkv);
    let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
    for r in &spec_reqs {
        sched.enqueue(r.clone());
    }
    let mut want: Vec<(u64, Vec<i32>)> = ref_eng
        .serve_continuous(&mut sched)
        .expect("spec reference run failed")
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect();
    want.sort();
    let mut t = Table::new(&[
        "k", "macro steps", "tokens", "steps/token", "accept rate", "rolled rows", "tok/s",
    ]);
    let mut spt_by_k = Vec::new();
    for k in [1usize, 2, 4, 8] {
        let policy = SpecPolicy::parse(k, verify).expect("spec policy");
        let mut se = SpecEngine::new(engine(seq, &kv), policy).expect("spec engine");
        let mut sched = se.scheduler(Scheduler::DEFAULT_PROMOTE_AFTER);
        for r in &spec_reqs {
            sched.enqueue(r.clone());
        }
        let mut resps = Vec::new();
        // one scheduler tick per step_continuous call: steps counts macro
        // rounds (draft k + verify + commit each), not backend calls —
        // per-call accounting would hand k=1 the bonus-token win for free
        let mut steps = 0u64;
        while sched.has_work() {
            let done = se.step_continuous(&mut sched).expect("spec step failed");
            steps += 1;
            resps.extend(done);
        }
        assert_eq!(resps.len(), spec_reqs.len(), "spec k={k}: lost responses");
        let mut toks: Vec<(u64, Vec<i32>)> =
            resps.into_iter().map(|r| (r.id, r.tokens)).collect();
        toks.sort();
        assert_eq!(toks, want, "spec k={k}: diverged from the {verify} verifier-alone run");
        let eng = se.into_engine();
        let s = &eng.serving;
        let tokens = eng.metrics.tokens_generated;
        let spt = steps as f64 / tokens as f64;
        let accept = s.spec_accept_rate();
        assert!(s.spec_rounds > 0, "spec k={k}: no verify rounds ran");
        assert!(accept > 0.0, "spec k={k}: the draft never landed a token");
        assert_eq!(
            s.spec_accepted + s.spec_rejected + s.spec_forced,
            tokens,
            "spec k={k}: accept/reject/bonus counters drifted off tokens_generated"
        );
        t.row(&[
            format!("{k}"),
            format!("{steps}"),
            format!("{tokens}"),
            format!("{spt:.3}"),
            format!("{:.0}%", accept * 100.0),
            format!("{}", s.spec_rollback_rows),
            format!("{:.0}", eng.metrics.tokens_per_sec()),
        ]);
        emit_bench_json(
            "scheduler",
            "spec-decode",
            // config keys the draft depth so bench_compare tracks each k
            // as its own trajectory
            &format!("k={k} {}->{verify}", kv.name()),
            &kv.name(),
            &[
                ("accept_rate", accept),
                ("steps_per_token", spt),
                ("macro_steps", steps as f64),
                ("tokens", tokens as f64),
                ("rollback_rows", s.spec_rollback_rows as f64),
                ("tok_s", eng.metrics.tokens_per_sec()),
                ("effective_bits", kv_bits),
            ],
        );
        spt_by_k.push((k, spt, accept));
    }
    t.print();
    let base = spt_by_k[0].1;
    println!(
        "\nspeculation at k=8 vs k=1: {:.3} -> {:.3} macro steps per token at \
         {:.0}% acceptance, bit-identical to the {verify} verifier-alone run \
         (acceptance: strictly fewer steps per token for every k > 1)",
        base,
        spt_by_k[3].1,
        spt_by_k[3].2 * 100.0
    );
    for (k, spt, _) in &spt_by_k[1..] {
        assert!(
            *spt < base,
            "speculation must pay for itself: k={k} took {spt:.3} macro steps \
             per token vs {base:.3} at k=1"
        );
    }

    // with NXFP_OBS_OUT=<dir>, write the CI observability artifacts from a
    // traced fault run (so Retry events appear) and re-validate the JSONL
    // round trip through the same checker `nxfp trace check` uses
    if let Ok(dir) = std::env::var("NXFP_OBS_OUT") {
        if !dir.is_empty() {
            let dir = std::path::PathBuf::from(dir);
            let mut eng = engine(seq, &kv);
            eng.set_retry_policy(8, Duration::from_micros(50));
            // scan seeds like the fault sweep so the artifact trace
            // actually contains Retry events
            let mut fired = 0u64;
            for seed in 7u64..23 {
                let mut e = engine(seq, &kv);
                e.set_retry_policy(8, Duration::from_micros(50));
                let stats = e.inject_faults(&FaultPlan::transient_steps(seed, 0.05));
                e.set_trace_sink(TraceSink::enabled(DEFAULT_TRACE_CAP));
                e.enable_occupancy();
                let mut sched = Scheduler::new(MAX_BATCH, Scheduler::DEFAULT_PROMOTE_AFTER);
                sched.set_trace_sink(e.trace_sink());
                for r in &reqs {
                    sched.enqueue(r.clone());
                }
                let resps = e.serve_continuous(&mut sched).expect("obs fault run failed");
                assert_eq!(resps.len(), reqs.len(), "obs fault run: lost responses");
                fired = stats.borrow().step_errors;
                eng = e;
                if fired > 0 {
                    break;
                }
            }
            let occ = eng.occupancy_report();
            let summary = TraceSummary::from_serving(&eng.serving);
            let trace_path = dir.join("trace.jsonl");
            eng.trace_sink().write_jsonl(&trace_path, &summary).expect("trace write failed");
            write_metrics(&dir.join("metrics.prom"), &eng.metrics, &eng.serving, &occ)
                .expect("prometheus write failed");
            write_metrics(&dir.join("metrics.json"), &eng.metrics, &eng.serving, &occ)
                .expect("metrics json write failed");
            let trace = read_jsonl(&trace_path).expect("trace reread failed");
            let viol = check_trace(&trace);
            assert!(viol.is_empty(), "obs artifact trace failed the checker: {viol:?}");
            println!(
                "obs artifacts written to {} ({} trace entries, {} injected faults, \
                 {} retries)",
                dir.display(),
                trace.entries.len(),
                fired,
                eng.serving.retries
            );
        }
    }
}
