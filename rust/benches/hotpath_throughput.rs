//! Hot-path throughput (paper §6: on-the-fly dequantization on
//! off-the-shelf hardware; Qualcomm reports ~2× decode speedup from MxFP6
//! because DRAM traffic shrinks).
//!
//! Measures, on a checkpoint-sized weight matrix:
//! * quantization throughput (offline direct-cast, all formats),
//! * LUT dequantization throughput from packed form (GiB/s of produced f32),
//! * fused dequant+GEMV vs f32 GEMV — the traffic-bound decode proxy:
//!   effective bytes *read* per output are 4.25/16 of FP16's, so a
//!   traffic-bound core sees up to ~3.7× (W4); CPU here is compute-bound
//!   but must stay within ~2× of the f32 GEMV to prove decode is cheap.

use nxfp::bench_util::{banner, bench_quick, Table};
use nxfp::dequant::{dequantize_packed, gemm_packed, gemv_packed, DequantLut};
use nxfp::formats::packed::PackedMatrix;
use nxfp::formats::{BaseFormat, NxConfig};
use nxfp::quant::quantize_matrix;
use nxfp::tensor::Tensor2;
use nxfp::util::rng::Rng;
use std::hint::black_box;

fn main() {
    banner("Hotpath", "quantize / dequantize / fused-GEMV throughput");
    let mut rng = Rng::seeded(9);
    let rows = 1024usize;
    let cols = 4096usize;
    let w = Tensor2::random_normal(rows, cols, 0.02, &mut rng);
    let bytes_f32 = rows * cols * 4;
    println!("matrix: {rows}x{cols} f32 ({} MiB)\n", bytes_f32 >> 20);

    let n_rhs = 8usize;
    let mut t = Table::new(&[
        "format",
        "quantize GiB/s",
        "dequant GiB/s",
        "gemv ms",
        "gemm8/rhs ms",
        "vs f32 gemv",
    ]);

    // f32 GEMV baseline
    let x: Vec<f32> = (0..cols).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut y = vec![0.0f32; rows];
    let base = bench_quick(|| {
        for r in 0..rows {
            let mut acc = 0.0f32;
            for (a, b) in w.row(r).iter().zip(&x) {
                acc += a * b;
            }
            y[r] = acc;
        }
        black_box(&y);
    });
    println!(
        "f32 GEMV baseline: {:.3} ms ({:.2} GiB/s weight traffic)\n",
        base.mean.as_secs_f64() * 1e3,
        base.gib_per_sec(bytes_f32)
    );

    for cfg in [
        NxConfig::bfp(4),
        NxConfig::mxfp(4),
        NxConfig::nxfp(4),
        NxConfig::nxfp(5),
        NxConfig::mxfp(6),
        NxConfig::nxfp(6),
    ] {
        let tq = bench_quick(|| {
            black_box(quantize_matrix(&w, &cfg));
        });
        let q = quantize_matrix(&w, &cfg);
        let packed = PackedMatrix::from_store(rows, cols, &cfg, &q.store);
        let lut = DequantLut::new(&cfg);
        let base_mx = cfg.base == BaseFormat::Mx;
        let td = bench_quick(|| {
            black_box(dequantize_packed(&packed, &lut, base_mx));
        });
        let mut yq = vec![0.0f32; rows];
        let tg = bench_quick(|| {
            gemv_packed(&packed, &lut, base_mx, &x, &mut yq);
            black_box(&yq);
        });
        // batched RHS: the threaded gemm unpacks each block once for all
        // columns, so per-RHS cost should undercut the single gemv
        let xm: Vec<f32> = (0..cols * n_rhs).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut ym = vec![0.0f32; rows * n_rhs];
        let tm = bench_quick(|| {
            gemm_packed(&packed, &lut, base_mx, &xm, n_rhs, &mut ym);
            black_box(&ym);
        });
        t.row(&[
            cfg.name(),
            format!("{:.2}", tq.gib_per_sec(bytes_f32)),
            format!("{:.2}", td.gib_per_sec(bytes_f32)),
            format!("{:.3}", tg.mean.as_secs_f64() * 1e3),
            format!("{:.3}", tm.mean.as_secs_f64() * 1e3 / n_rhs as f64),
            format!("{:.2}x", tg.mean.as_secs_f64() / base.mean.as_secs_f64()),
        ]);
    }
    t.print();
    println!("\ntraffic model: W4 packed reads {:.2}x less DRAM than FP16 \
              (the source of the paper's deploy speedup)",
             16.0 / NxConfig::nxfp(4).effective_bits());
}
