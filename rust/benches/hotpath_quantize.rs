//! Quantize-side hot path: reference `quantize_block` (per-element level
//! search + per-candidate `Vec` allocations + per-block `BlockCode` heap
//! objects) vs the table-driven allocation-free engine
//! (`EncodePlan` + flat `BlockStore`) — paper §5 Algorithm 1 at direct-cast
//! checkpoint scale, plus a prefill-shaped KV-append scenario driving the
//! exact `KvCache::append` path `serve_wave` uses.
//!
//! Both matrix paths run single-threaded so the table isolates the
//! per-block engine win (the threaded `quantize_matrix` stripes scale both
//! the same way). The KV scenario uses `bench_series` (the
//! `hotpath_serving` idiom) so per-step drift would be visible: append cost
//! must stay flat as the cache fills.
//!
//! Set `NXFP_BENCH_SMOKE=1` for a seconds-scale CI smoke run (tiny sizes,
//! short budgets) that still exercises every path; set
//! `NXFP_BENCH_JSON=<dir>` to append records to `BENCH_quantize.json`.

use nxfp::bench_util::{
    banner, bench, bench_series, emit_bench_json, mean_duration, quartile_growth, smoke_env,
    Table,
};
use nxfp::formats::{quantize_block, BlockCode, BlockStore, EncodePlan, EncodeScratch, NxConfig};
use nxfp::quant::kv_cache::KvCache;
use nxfp::tensor::Tensor2;
use nxfp::util::rng::Rng;
use std::hint::black_box;
use std::time::Duration;

fn budgets() -> (Duration, Duration) {
    if smoke_env() {
        (Duration::from_millis(5), Duration::from_millis(30))
    } else {
        (Duration::from_millis(200), Duration::from_millis(800))
    }
}

fn main() {
    banner("HotpathQuantize", "reference vs engine encode throughput");
    let (rows, cols) = if smoke_env() { (16, 128) } else { (256, 2048) };
    let (warm, meas) = budgets();
    let mut rng = Rng::seeded(23);
    let w = Tensor2::random_normal(rows, cols, 0.02, &mut rng);
    println!("matrix: {rows}x{cols} f32, single-threaded encode\n");

    let mut t = Table::new(&["format", "ref Mblk/s", "engine Mblk/s", "speedup"]);
    for bits in 4u8..=6 {
        for cfg in [NxConfig::bfp(bits), NxConfig::mxfp(bits), NxConfig::nxfp(bits)] {
            let k = cfg.block_size;
            let n_blocks = rows * cols.div_ceil(k);
            // reference: the pre-engine path — one BlockCode (owned Vec)
            // per block, binary-search encode, decode-per-element SSE
            let tabs = cfg.tables();
            let mut blocks: Vec<BlockCode> = Vec::with_capacity(n_blocks);
            let tr = bench(warm, meas, || {
                blocks.clear();
                for r in 0..rows {
                    for chunk in w.row_blocks(r, k) {
                        blocks.push(quantize_block(chunk, &cfg, &tabs));
                    }
                }
                black_box(&blocks);
            });
            // engine: reusable plan/scratch writing into a flat BlockStore
            let plan = EncodePlan::new(&cfg);
            let mut scratch = EncodeScratch::new();
            let mut store = BlockStore::with_rows(rows, cols, k);
            let te = bench(warm, meas, || {
                for r in 0..rows {
                    let (codes, e, nano, fmt) = store.row_slices_mut(r);
                    plan.quantize_row_into(w.row(r), &mut scratch, codes, e, nano, fmt);
                }
                black_box(&store);
            });
            let ref_bps = n_blocks as f64 * tr.per_sec() / 1e6;
            let eng_bps = n_blocks as f64 * te.per_sec() / 1e6;
            t.row(&[
                cfg.name(),
                format!("{ref_bps:.2}"),
                format!("{eng_bps:.2}"),
                format!("{:.2}x", eng_bps / ref_bps),
            ]);
            emit_bench_json(
                "quantize",
                "matrix_encode",
                &cfg.name(),
                &cfg.name(),
                &[
                    ("ref_mblk_s", ref_bps),
                    ("engine_mblk_s", eng_bps),
                    ("speedup", eng_bps / ref_bps),
                    ("effective_bits", cfg.effective_bits()),
                ],
            );
        }
    }
    t.print();

    // Prefill-shaped KV append: one row per step through the real
    // KvCache::append (engine) vs the legacy per-block Vec emulation.
    let (dim, steps) = if smoke_env() { (64, 32) } else { (1024, 512) };
    let cfg = NxConfig::nxfp(4);
    let tabs = cfg.tables();
    let row: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    println!("\nKV prefill: dim={dim}, {steps} appended rows, {}", cfg.name());

    let mut k_blocks: Vec<BlockCode> = Vec::new();
    let mut v_blocks: Vec<BlockCode> = Vec::new();
    let ref_series = bench_series(steps, |_| {
        for chunk in row.chunks(cfg.block_size) {
            k_blocks.push(quantize_block(chunk, &cfg, &tabs));
        }
        for chunk in row.chunks(cfg.block_size) {
            v_blocks.push(quantize_block(chunk, &cfg, &tabs));
        }
        black_box((&k_blocks, &v_blocks));
    });
    let mut cache = KvCache::with_capacity(dim, cfg.clone(), steps);
    let eng_series = bench_series(steps, |_| {
        cache.append(&row, &row);
        black_box(&cache);
    });

    let mut kt = Table::new(&["kv append path", "rows/s", "step mean us", "growth"]);
    let paths = [
        ("reference (Vec<BlockCode>)", &ref_series),
        ("engine (BlockStore)", &eng_series),
    ];
    for (label, series) in paths {
        let (_, _, growth) = quartile_growth(series);
        let total: Duration = series.iter().sum();
        let rows_s = series.len() as f64 / total.as_secs_f64();
        kt.row(&[
            label.to_string(),
            format!("{:.0}", rows_s),
            format!("{:.2}", mean_duration(series).as_secs_f64() * 1e6),
            format!("{growth:.2}x"),
        ]);
        emit_bench_json(
            "quantize",
            label,
            &cfg.name(),
            &cfg.name(),
            &[
                ("kv_rows_s", rows_s),
                ("growth", growth),
                ("effective_bits", cfg.effective_bits()),
            ],
        );
    }
    kt.print();
    let rt: Duration = ref_series.iter().sum();
    let et: Duration = eng_series.iter().sum();
    println!(
        "\nengine append is {:.2}x the reference path (flat growth expected on both)",
        rt.as_secs_f64() / et.as_secs_f64().max(1e-12)
    );
}
