//! Fig. 10 — reasoning-accuracy degradation (MMLU stand-in) under weight
//! quantization at 6/5/4/3 bits for BFP, MxFP and NxFP.
//!
//! Paper expectation: all formats hold accuracy at ≥6 bits; at 4 and
//! especially 3 bits BFP/MxFP collapse toward chance (25%) while NxFP
//! retains significantly more accuracy (paper: up to +30.2%).

use nxfp::bench_util::scenario::{default_corpus, load_or_train};
use nxfp::bench_util::{banner, Table};
use nxfp::eval::{quantize_checkpoint, reasoning_accuracy};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::corpus::Probe;
use nxfp::models::LmSpec;
use nxfp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    banner("Fig.10", "reasoning accuracy degradation (4-way multiple choice)");
    let spec = LmSpec::small();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu("artifacts")?;
    let ck = load_or_train(&mut rt, &corpus, 42)?;
    let score = rt.load("score_step")?;
    let n_probes: usize = std::env::var("NXFP_PROBES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let probes = Probe::generate(&corpus.spec, n_probes, 77);
    let quantizable = spec.quantizable();

    let acc_of = |ck: &nxfp::models::Checkpoint| -> anyhow::Result<f64> {
        reasoning_accuracy(&score, ck, &probes, spec.seq_len, 8)
    };
    let fp16 = acc_of(&ck)?;
    println!("FP16 accuracy: {:.1}% ({} probes, chance 25%)\n", fp16 * 100.0, probes.len());

    let mut t = Table::new(&["bits", "BFP", "MxFP", "NxFP", "NxFP-MxFP"]);
    for bits in [6u8, 5, 4, 3] {
        let mut row = vec![bits.to_string()];
        let mut accs = Vec::new();
        for cfg in [NxConfig::bfp(bits), NxConfig::mxfp(bits), NxConfig::nxfp(bits)] {
            let q = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
            let a = acc_of(&q)?;
            accs.push(a);
            row.push(format!("{:.1}%", a * 100.0));
        }
        row.push(format!("{:+.1}%", (accs[2] - accs[1]) * 100.0));
        t.row(&row);
    }
    t.print();
    println!("\npaper shape: NxFP mitigates the 3–4 bit collapse (gains up to +30%)");
    Ok(())
}
