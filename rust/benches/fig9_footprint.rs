//! Fig. 9 — perplexity-to-footprint trade-offs.
//!
//! Two coupled outputs, as in the paper:
//! (1) measured perplexity of the trained LM per format (weight-only and
//!     weight+KV via the Pallas kvq artifacts), and
//! (2) bit-true footprint in GB of the *named* published models
//!     (Llama3-8B / Llama2-7B at 2K sequence) under the same formats —
//!     the paper's x-axis, where absolute GB numbers are meaningful.
//!
//! Paper expectation: NxFP sits on the Pareto frontier; NxFP5 ≈ MxFP6
//! perplexity at 13–16% less footprint.

use nxfp::bench_util::scenario::{default_corpus, load_or_train};
use nxfp::bench_util::{banner, Table};
use nxfp::eval::{perplexity, quantize_checkpoint};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::{LmSpec, NamedModel};
use nxfp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    banner("Fig.9", "perplexity-to-footprint Pareto (weights, weights+KV)");
    let spec = LmSpec::small();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu("artifacts")?;
    let ck = load_or_train(&mut rt, &corpus, 42)?;
    let quantizable = spec.quantizable();
    let eval_step = rt.load("eval_step")?;
    let fp16 = perplexity(&eval_step, &ck, &corpus, spec.seq_len, 8)?.ppl();

    let named: Vec<NamedModel> = ["Llama3-8B", "Llama2-7B"]
        .iter()
        .map(|n| NamedModel::by_name(n).unwrap())
        .collect();

    // ---- (a)(c) weight-only ------------------------------------------
    println!("\n(a)(c) weight-only: measured ppl + named-model footprints (seq 2K, KV FP16)");
    let mut t = Table::new(&["format", "ppl", "Δppl", "Llama3-8B GB", "Llama2-7B GB"]);
    t.row(&[
        "FP16".into(),
        format!("{fp16:.4}"),
        "—".into(),
        format!("{:.2}", named[0].footprint_gb(None, None, 2048)),
        format!("{:.2}", named[1].footprint_gb(None, None, 2048)),
    ]);
    let formats: Vec<NxConfig> = vec![
        NxConfig::bfp(4),
        NxConfig::bfp(5),
        NxConfig::bfp(6),
        NxConfig::mxfp(4),
        NxConfig::mxfp(5),
        NxConfig::mxfp(6),
        NxConfig::nxfp(4),
        NxConfig::nxfp(5),
        NxConfig::nxfp(6),
    ];
    for cfg in &formats {
        let q = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
        let p = perplexity(&eval_step, &q, &corpus, spec.seq_len, 8)?.ppl();
        t.row(&[
            cfg.name(),
            format!("{p:.4}"),
            format!("{:+.4}", p - fp16),
            format!("{:.2}", named[0].footprint_gb(Some(cfg), None, 2048)),
            format!("{:.2}", named[1].footprint_gb(Some(cfg), None, 2048)),
        ]);
    }
    t.print();

    // ---- (b)(d) weights + KV cache -----------------------------------
    println!("\n(b)(d) weights + KV cache (kvq artifacts; KV quantized in-graph)");
    let mut t2 = Table::new(&["format", "ppl (W+KV)", "Δppl", "Llama3-8B GB", "Llama2-7B GB"]);
    t2.row(&[
        "FP16".into(),
        format!("{fp16:.4}"),
        "—".into(),
        format!("{:.2}", named[0].footprint_gb(None, None, 2048)),
        format!("{:.2}", named[1].footprint_gb(None, None, 2048)),
    ]);
    for bits in [4u8, 5, 6] {
        for (fam, cfg) in [
            ("bfp", NxConfig::bfp(bits)),
            ("mxfp", NxConfig::mxfp(bits)),
            ("nxfp", NxConfig::nxfp(bits)),
        ] {
            let step = rt.load(&format!("eval_step_kvq_{fam}{bits}"))?;
            let q = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
            let p = perplexity(&step, &q, &corpus, spec.seq_len, 8)?.ppl();
            t2.row(&[
                cfg.name(),
                format!("{p:.4}"),
                format!("{:+.4}", p - fp16),
                format!("{:.2}", named[0].footprint_gb(Some(&cfg), Some(&cfg), 2048)),
                format!("{:.2}", named[1].footprint_gb(Some(&cfg), Some(&cfg), 2048)),
            ]);
        }
    }
    t2.print();

    // headline comparison
    let nx5 = NxConfig::nxfp(5);
    let mx6 = NxConfig::mxfp(6);
    let a = named[0].footprint_gb(Some(&nx5), Some(&nx5), 2048);
    let b = named[0].footprint_gb(Some(&mx6), Some(&mx6), 2048);
    println!(
        "\nheadline: NxFP5 vs MxFP6 on Llama3-8B (W+KV, 2K): {a:.2} GB vs {b:.2} GB \
         ({:.1}% smaller; paper: ~16%)",
        (1.0 - a / b) * 100.0
    );
    Ok(())
}
