//! Fig. 12 — perplexity-to-footprint trade-offs across block sizes
//! (8..128) at 4 bits for BFP4 / MxFP4 / NxFP4.
//!
//! Paper expectation: NxFP4 dominates at every block size; MxFP4 overtakes
//! BFP4 as the block grows (microexponents recover element-wise dynamic
//! range when blocks are long and scattered).

use nxfp::bench_util::scenario::{default_corpus, load_or_train};
use nxfp::bench_util::{banner, Table};
use nxfp::eval::{perplexity, quantize_checkpoint};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::{LmSpec, NamedModel};
use nxfp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    banner("Fig.12", "block-size sweep at 4 bits (ppl + effective bits)");
    let spec = LmSpec::small();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu("artifacts")?;
    let ck = load_or_train(&mut rt, &corpus, 42)?;
    let eval_step = rt.load("eval_step")?;
    let quantizable = spec.quantizable();
    let llama3 = NamedModel::by_name("Llama3-8B").unwrap();

    let mut t = Table::new(&[
        "block", "format", "ppl", "eff.bits", "Llama3-8B W GB",
    ]);
    for k in [8usize, 16, 32, 64, 128] {
        for cfg in [
            NxConfig::bfp(4).with_block_size(k),
            NxConfig::mxfp(4).with_block_size(k),
            NxConfig::nxfp(4).with_block_size(k),
        ] {
            let q = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
            let p = perplexity(&eval_step, &q, &corpus, spec.seq_len, 8)?.ppl();
            let gb = cfg.footprint_bits(llama3.weight_elements() as usize) as f64 / 8e9;
            t.row(&[
                k.to_string(),
                cfg.name(),
                format!("{p:.4}"),
                format!("{:.2}", cfg.effective_bits()),
                format!("{gb:.2}"),
            ]);
        }
    }
    t.print();
    println!("\npaper shape: NxFP4 best at all block sizes; MxFP4 > BFP4 at large blocks");
    Ok(())
}
