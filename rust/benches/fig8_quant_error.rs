//! Fig. 8 — quantization-error (MSE) reduction of NxFP4 over MxFP4 on the
//! synthetic model profiles, with the cumulative technique ablation the
//! paper reports: NM, NM+AM, NM+AM+CR.
//!
//! Paper expectation: NxFP4 reduces MSE by 10–45% vs MxFP4; NM contributes
//! up to ~26%, AM ~14%, CR ~4.7%.

use nxfp::bench_util::{banner, Table};
use nxfp::formats::NxConfig;
use nxfp::models::{synth_weights, ModelProfile};
use nxfp::quant::fake_quant_matrix;
use nxfp::tensor::stats::mse;
use nxfp::tensor::Tensor2;

fn tensor_mse(w: &Tensor2, cfg: &NxConfig) -> f64 {
    mse(&w.data, &fake_quant_matrix(w, cfg).data)
}

fn main() {
    banner("Fig.8", "MSE of NxFP4 vs MxFP4, cumulative NM / +AM / +CR");
    let mut t = Table::new(&[
        "model", "MxFP4 MSE", "NM", "NM+AM", "NM+AM+CR", "total reduction",
    ]);
    let mut worst: f64 = 1.0;
    for p in ModelProfile::all() {
        let w = synth_weights(&p, 256, 2048);
        let base = tensor_mse(&w, &NxConfig::mxfp(4));
        let nm = tensor_mse(&w, &NxConfig::nxfp_nm(4));
        let nm_am = tensor_mse(&w, &NxConfig::nxfp_nm_am(4));
        let full = tensor_mse(&w, &NxConfig::nxfp(4));
        let red = 1.0 - full / base;
        worst = worst.min(red);
        t.row(&[
            p.name.to_string(),
            format!("{base:.3e}"),
            format!("-{:.1}%", (1.0 - nm / base) * 100.0),
            format!("-{:.1}%", (1.0 - nm_am / base) * 100.0),
            format!("-{:.1}%", red * 100.0),
            format!("{:.1}%", red * 100.0),
        ]);
    }
    t.print();
    println!("\npaper: 10–45% total MSE reduction (NM≤26%, AM≤14%, CR≤4.7%)");
    println!("measured minimum total reduction across models: {:.1}%", worst * 100.0);
}
