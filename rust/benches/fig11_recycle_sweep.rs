//! Fig. 11 — sweeping the remapped value of Code Recycling on (a) MxFP4 and
//! (b) BFP4: the recycled −0 code is remapped to each midpoint between
//! adjacent quantization levels (plus half-of-smallest) and the resulting
//! held-out perplexity is measured.
//!
//! Paper expectation: half-of-smallest is (one of) the best choices on both
//! element formats; midpoints near the top also help MxFP4 (vacant level).

use nxfp::bench_util::scenario::{default_corpus, load_or_train};
use nxfp::bench_util::{banner, Table};
use nxfp::eval::{perplexity, quantize_checkpoint};
use nxfp::formats::{ElementFormat, NxConfig, QuantPolicy, RecycleTarget};
use nxfp::models::LmSpec;
use nxfp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    banner("Fig.11", "perplexity vs recycled value (MxFP4 / BFP4 + CR)");
    let spec = LmSpec::small();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu("artifacts")?;
    let ck = load_or_train(&mut rt, &corpus, 42)?;
    let eval_step = rt.load("eval_step")?;
    let quantizable = spec.quantizable();

    let ppl_of = |cfg: &NxConfig| -> anyhow::Result<f64> {
        let q = quantize_checkpoint(&ck, &quantizable, &QuantPolicy::uniform(cfg.clone()));
        Ok(perplexity(&eval_step, &q, &corpus, spec.seq_len, 8)?.ppl())
    };

    for (panel, base, elem) in [
        ("(a) MxFP4 + CR", NxConfig::mxfp(4), ElementFormat::mx_default(4)),
        ("(b) BFP4 + CR", NxConfig::bfp(4), ElementFormat::bfp(4)),
    ] {
        println!("\n{panel}");
        let baseline = ppl_of(&base)?;
        println!("  baseline (no CR): ppl {baseline:.4}  <- dotted line");
        let mut t = Table::new(&["remap target", "ppl", "Δ vs baseline"]);
        let mut best = (String::new(), f64::INFINITY);
        for (label, target) in RecycleTarget::sweep_targets(&elem) {
            let cfg = base.clone().with_recycle(target);
            let p = ppl_of(&cfg)?;
            if p < best.1 {
                best = (label.clone(), p);
            }
            t.row(&[label, format!("{p:.4}"), format!("{:+.4}", p - baseline)]);
        }
        t.print();
        println!("  best remap: {} (ppl {:.4}); paper: ½·V_smallest", best.0, best.1);
    }
    Ok(())
}
