//! Table 1 — weight-only quantization perplexity at W6/W5/W4 for
//! BFP (MSFP), MxFP and NxFP (NM / NM+AM / NM+AM+CR) on the trained in-repo
//! LMs (three seeds stand in for the paper's model zoo; see DESIGN.md §3).
//!
//! Paper expectation (shape): NxFP ≤ MxFP ≤ BFP degradation at every
//! bitwidth, with the gap widening as bits shrink; NxFP4 recovers ~half of
//! MxFP4's degradation.

use nxfp::bench_util::scenario::{default_corpus, load_or_train};
use nxfp::bench_util::{banner, Table};
use nxfp::eval::{perplexity, quantize_checkpoint};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;
use nxfp::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    banner("Table 1", "weight-only perplexity (W4/W5/W6) across formats");
    let spec = LmSpec::small();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu("artifacts")?;
    let eval_step = rt.load("eval_step")?;
    let quantizable = spec.quantizable();

    // training seeds = "models" (paper columns); 2 by default on this
    // single-core testbed, NXFP_TABLE1_SEEDS=42,43,44 for more
    let seeds: Vec<u64> = std::env::var("NXFP_TABLE1_SEEDS")
        .unwrap_or_else(|_| "42,43".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let mut cols = Vec::new();
    for &s in &seeds {
        cols.push((format!("lm-s{s}"), load_or_train(&mut rt, &corpus, s)?));
    }

    let headers: Vec<&str> = ["W", "format"]
        .into_iter()
        .chain(cols.iter().map(|(n, _)| n.as_str()))
        .collect();
    let mut t = Table::new(&headers);
    let ppl = |ck: &nxfp::models::Checkpoint| -> anyhow::Result<f64> {
        Ok(perplexity(&eval_step, ck, &corpus, spec.seq_len, 8)?.ppl())
    };
    let mut fp16_row = vec!["16".to_string(), "FP16".to_string()];
    for (_, ck) in &cols {
        fp16_row.push(format!("{:.4}", ppl(ck)?));
    }
    t.row(&fp16_row);
    // 3-bit rows go beyond the paper's table: at this testbed's tiny model
    // scale the W4 deltas sit inside loss-landscape noise, so the extra
    // quantization pressure is where the format ordering becomes visible
    for bits in [6u8, 5, 4, 3] {
        for cfg in [
            NxConfig::bfp(bits),
            NxConfig::mxfp(bits),
            NxConfig::nxfp_nm(bits),
            NxConfig::nxfp_nm_am(bits),
            NxConfig::nxfp(bits),
        ] {
            let mut cells = vec![format!("{bits}"), cfg.name()];
            let policy = QuantPolicy::uniform(cfg.clone());
            for (_, ck) in &cols {
                let q = quantize_checkpoint(ck, &quantizable, &policy);
                cells.push(format!("{:.4}", ppl(&q)?));
            }
            t.row(&cells);
        }
    }
    t.print();
    println!("\npaper shape: NxFP < MxFP < BFP perplexity at 4–6 bits, gap grows at 4 bits");
    Ok(())
}
