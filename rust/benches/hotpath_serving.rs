//! Serving-decode hot path: per-step KV work as the cache fills (paper §6
//! deployment; the coordinator's wave loop). Runs without the PJRT runtime
//! or artifacts: it drives the exact KV machinery `serve_wave` uses — per
//! step and slot, quantize-append one row per layer, incrementally sync
//! new rows into the batched step slab, then pay the slab→literal
//! materialization copy the decode step performs regardless.
//!
//! Three variants over a full wave:
//! * `fp32 baseline`   — rows written straight into the slab (no quantizer),
//! * `quantized incr`  — packed caches + dirty-row watermark (the new path),
//! * `quantized full`  — packed caches fully re-decoded every step (the old
//!   `serve_wave` behavior this bench exists to keep dead).
//!
//! Flatness is reported as last-quarter / first-quarter mean per-step time:
//! ≈1 means decode work no longer grows with total cache fill; the old
//! full-redecode path grows without bound.
//!
//! Set `NXFP_BENCH_SMOKE=1` for a seconds-scale CI smoke run; set
//! `NXFP_BENCH_JSON=<dir>` to append records to `BENCH_serving.json`.

use nxfp::bench_util::{
    banner, bench_series, emit_bench_json, quantile_duration, quartile_growth, smoke_env, Table,
};
use nxfp::coordinator::SlotKv;
use nxfp::formats::NxConfig;
use nxfp::quant::kv_cache::KvCache;
use nxfp::util::rng::Rng;
use std::hint::black_box;
use std::time::Duration;

const BSZ: usize = 4;
const LAYERS: usize = 4;
const DIM: usize = 64;

struct Slab {
    k: Vec<f32>,
    v: Vec<f32>,
    scratch: Vec<f32>,
}

impl Slab {
    fn new(seq: usize) -> Self {
        let n = BSZ * LAYERS * seq * DIM;
        Slab { k: vec![0.0; n], v: vec![0.0; n], scratch: vec![0.0; 2 * n] }
    }

    /// Emulate `lit::from_f32` building the step literals: the full padded
    /// slab is copied every step regardless of KV format.
    fn materialize(&mut self) {
        let n = self.k.len();
        self.scratch[..n].copy_from_slice(&self.k);
        self.scratch[n..].copy_from_slice(&self.v);
        black_box(&self.scratch);
    }
}

fn report(label: &str, cfg_name: &str, eff_bits: f64, t: &mut Table, series: &[Duration]) -> f64 {
    let (first, last, growth) = quartile_growth(series);
    let total: Duration = series.iter().sum();
    let toks = (BSZ * series.len()) as f64 / total.as_secs_f64();
    t.row(&[
        label.to_string(),
        format!("{:.1}", toks),
        format!("{:.1}", first.as_secs_f64() * 1e6),
        format!("{:.1}", last.as_secs_f64() * 1e6),
        format!("{:.2}x", growth),
    ]);
    emit_bench_json(
        "serving",
        label,
        cfg_name,
        cfg_name,
        &[
            ("tok_s", toks),
            ("p95_step_ms", quantile_duration(series, 0.95).as_secs_f64() * 1e3),
            ("growth", growth),
            ("effective_bits", eff_bits),
        ],
    );
    toks
}

fn main() {
    banner("HotpathServing", "per-step KV decode work vs cache fill");
    let seq: usize = if smoke_env() { 32 } else { 512 };
    let steps = seq - 1;
    let cfg = NxConfig::nxfp(4);
    println!(
        "wave: B={BSZ} L={LAYERS} S={seq} D={DIM}, {steps} decode steps, KV {}\n",
        cfg.name()
    );
    let mut rng = Rng::seeded(17);
    let row: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let lane = LAYERS * seq * DIM;

    let mut t = Table::new(&["kv path", "tok/s", "step[0..25%] us", "step[75%..] us", "growth"]);

    // FP32 baseline: write the new row straight into the slab.
    let mut slab = Slab::new(seq);
    let fp32 = bench_series(steps, |step| {
        for b in 0..BSZ {
            for li in 0..LAYERS {
                let base = b * lane + (li * seq + step) * DIM;
                slab.k[base..base + DIM].copy_from_slice(&row);
                slab.v[base..base + DIM].copy_from_slice(&row);
            }
        }
        slab.materialize();
    });
    let fp32_toks = report("fp32 baseline", "fp32", 32.0, &mut t, &fp32);

    // Quantized, incremental (the new serve_wave path): append + watermark
    // sync decodes only this step's rows.
    let mut slab = Slab::new(seq);
    let mut slots: Vec<SlotKv> = (0..BSZ).map(|_| SlotKv::new(LAYERS, DIM, seq, &cfg)).collect();
    let inc = bench_series(steps, |_| {
        for (b, kv) in slots.iter_mut().enumerate() {
            for li in 0..LAYERS {
                kv.append(li, &row, &row);
            }
            kv.sync_into(
                &mut slab.k[b * lane..(b + 1) * lane],
                &mut slab.v[b * lane..(b + 1) * lane],
            );
        }
        slab.materialize();
    });
    let inc_toks = report("quantized incr", &cfg.name(), cfg.effective_bits(), &mut t, &inc);

    // Quantized, full re-decode every step (the old behavior).
    let mut slab = Slab::new(seq);
    let mut caches: Vec<Vec<KvCache>> = (0..BSZ)
        .map(|_| (0..LAYERS).map(|_| KvCache::new(DIM, cfg.clone())).collect())
        .collect();
    let full = bench_series(steps, |_| {
        for (b, layer_caches) in caches.iter_mut().enumerate() {
            for (li, cache) in layer_caches.iter_mut().enumerate() {
                cache.append(&row, &row);
                let (kd, vd) = cache.dequantize(seq);
                let base = b * lane + li * seq * DIM;
                slab.k[base..base + seq * DIM].copy_from_slice(&kd.data);
                slab.v[base..base + seq * DIM].copy_from_slice(&vd.data);
            }
        }
        slab.materialize();
    });
    report("quantized full (old)", &cfg.name(), cfg.effective_bits(), &mut t, &full);

    t.print();
    println!(
        "\nquantized-incremental runs at {:.2}x the fp32-KV step cost \
         (acceptance: within 2x) and per-step work stays flat as fill grows",
        fp32_toks / inc_toks
    );
}
