//! §7.2 ablation — per-technique MSE contributions plus the design-choice
//! ablations DESIGN.md calls out:
//!
//! * Algorithm-1's two-candidate NanoMantissa vs exhaustive 2-bit search
//!   (how much does the paper's cheap heuristic leave on the table?);
//! * recycled-code target (½·min vs top-gap midpoint) interaction with AM;
//! * MxFP6 element-format choice E2M3 vs E3M2 (the paper "reports the best").

use nxfp::bench_util::{banner, Table};
use nxfp::formats::{ElementFormat, NanoMode, NxConfig, RecycleTarget};
use nxfp::models::{synth_weights, ModelProfile};
use nxfp::quant::fake_quant_matrix;
use nxfp::tensor::stats::mse;

fn main() {
    banner("Ablation", "NanoMantissa search, CR target, FP6 element format");
    let p = ModelProfile::by_name("Llama3-8B").unwrap();
    let w = synth_weights(&p, 256, 2048);
    let m = |cfg: &NxConfig| mse(&w.data, &fake_quant_matrix(&w, cfg).data);

    println!("\n(1) NanoMantissa candidate policy (NxFP4, NM only):");
    let two = m(&NxConfig::nxfp_nm(4));
    let exh = m(&NxConfig::nxfp_nm(4).with_nano_mode(NanoMode::Exhaustive));
    let mut t = Table::new(&["policy", "MSE", "vs two-candidate"]);
    t.row(&["two-candidate (Algorithm 1)".into(), format!("{two:.3e}"), "—".into()]);
    t.row(&["exhaustive {0,1,2,3}".into(), format!("{exh:.3e}"),
            format!("{:+.2}%", (exh / two - 1.0) * 100.0)]);
    t.print();

    println!("\n(2) Code-recycling target under full NxFP4:");
    let mut t = Table::new(&["target", "MSE"]);
    for (label, target) in [
        ("½·V_smallest (paper)", RecycleTarget::HalfMin),
        ("mid(top, 2nd)", RecycleTarget::MidTopPair),
    ] {
        let cfg = NxConfig::nxfp(4).with_recycle(target);
        t.row(&[label.into(), format!("{:.3e}", m(&cfg))]);
    }
    t.print();

    println!("\n(3) MxFP6 element format (the paper reports the better of the two):");
    let mut t = Table::new(&["element", "MSE"]);
    for elem in [ElementFormat::new(2, 3), ElementFormat::new(3, 2)] {
        let cfg = NxConfig::mxfp_elem(6, elem);
        t.row(&[elem.name(), format!("{:.3e}", m(&cfg))]);
    }
    t.print();

    println!("\n(4) cumulative techniques at 4/5/6 bits (MSE, Llama3 profile):");
    let mut t = Table::new(&["bits", "BFP", "MxFP", "NM", "NM+AM", "NM+AM+CR"]);
    for bits in [4u8, 5, 6] {
        t.row(&[
            bits.to_string(),
            format!("{:.3e}", m(&NxConfig::bfp(bits))),
            format!("{:.3e}", m(&NxConfig::mxfp(bits))),
            format!("{:.3e}", m(&NxConfig::nxfp_nm(bits))),
            format!("{:.3e}", m(&NxConfig::nxfp_nm_am(bits))),
            format!("{:.3e}", m(&NxConfig::nxfp(bits))),
        ]);
    }
    t.print();
}
