//! Integration test for the threaded serving coordinator: multiple clients
//! submit concurrently, waves batch up, every request completes, and the
//! quantized-KV metrics are sane. Requires `make artifacts`.

use std::path::PathBuf;
use std::time::Duration;

use nxfp::coordinator::scheduler::SchedMode;
use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::GenRequest;
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::{Checkpoint, LmSpec};

#[test]
fn server_completes_all_requests_and_batches() {
    if !std::path::Path::new("artifacts/decode_step.hlo.txt").exists() {
        eprintln!(
            "skipping server_completes_all_requests_and_batches: artifacts \
             missing (run `make artifacts` to enable)"
        );
        return;
    }
    let spec = LmSpec::small();
    // an untrained checkpoint is fine: the server's correctness is about
    // scheduling, not text quality
    let ck = Checkpoint::init(&spec, 11);
    let mut server = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        spec,
        ck,
        QuantPolicy::uniform(NxConfig::nxfp(4)),
        ServeOpts {
            max_batch: 4,
            batch_window: Duration::from_millis(20),
            mode: SchedMode::Continuous,
            prefill_budget: 16,
            ..Default::default()
        },
    );
    let n_req = 10usize; // more requests than lanes: admission must churn
    for i in 0..n_req {
        assert!(server.submit(GenRequest {
            id: i as u64,
            prompt: vec![0, (5 + i) as i32, 70],
            max_new: 3 + (i % 3),
        }));
    }
    let mut seen = std::collections::BTreeSet::new();
    for _ in 0..n_req {
        let resp = server.recv_timeout(Duration::from_secs(300)).expect("timed out");
        assert!(resp.generated >= 3 && resp.generated <= 5);
        assert!(resp.tokens.len() == 3 + resp.generated);
        assert!(seen.insert(resp.id), "duplicate response {}", resp.id);
    }
    assert_eq!(seen.len(), n_req);
    let report = server.shutdown().unwrap();
    let m = report.metrics;
    assert_eq!(m.requests as usize, n_req);
    assert!(m.tokens_generated >= (3 * n_req) as u64);
    // batching actually happened: fewer decode steps than tokens+prompts
    // would need unbatched (each step serves up to 4 slots)
    assert!(m.decode_steps < (m.tokens_generated + 3 * n_req as u64));
    assert!(m.kv_savings() > 0.5, "kv savings {}", m.kv_savings());
    assert!(m.tokens_per_sec() > 0.0);
    // serving histograms saw every admitted request
    assert_eq!(report.serving.admitted as usize, n_req);
    assert_eq!(report.serving.latency.count() as usize, n_req);
    assert_eq!(report.serving.rejected, 0);
}

#[test]
fn server_shutdown_without_requests_is_clean() {
    if !std::path::Path::new("artifacts/decode_step.hlo.txt").exists() {
        eprintln!(
            "skipping server_shutdown_without_requests_is_clean: artifacts \
             missing (run `make artifacts` to enable)"
        );
        return;
    }
    let spec = LmSpec::small();
    let ck = Checkpoint::init(&spec, 12);
    let mut server = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        spec,
        ck,
        QuantPolicy::fp16(),
        ServeOpts {
            max_batch: 2,
            batch_window: Duration::from_millis(1),
            mode: SchedMode::Wave,
            prefill_budget: 1,
            ..Default::default()
        },
    );
    let report = server.shutdown().unwrap();
    assert_eq!(report.metrics.requests, 0);
    // a second shutdown is a well-defined error, not a panic
    assert!(server.shutdown().is_err());
    // the worker is gone: submits are refused rather than silently dropped
    assert!(!server.submit(GenRequest { id: 99, prompt: vec![0, 1], max_new: 1 }));
}
