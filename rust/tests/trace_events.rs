//! Observability integration tests: the trace sink's overhead contract
//! (disabled = no entries, enabled = bit-identical generations), event-
//! order legality on fault-sweep traces, exact agreement between event
//! counts and `ServingMetrics` counters, and the JSONL round trip the
//! `nxfp trace` subcommand reads. Everything runs on the deterministic
//! [`SynthBackend`]; no artifacts needed.

use std::time::Duration;

use nxfp::coordinator::fault::FaultPlan;
use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::{DecodeEngine, FinishReason, GenRequest, GenResponse, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;
use nxfp::obs::{
    check_trace, read_jsonl, timelines, Trace, TraceEntry, TraceEvent, TraceSink, TraceSummary,
    DEFAULT_TRACE_CAP,
};

fn requests() -> Vec<GenRequest> {
    (0..6u64)
        .map(|i| GenRequest {
            id: i,
            prompt: if i % 2 == 0 {
                vec![1, 2, 3, 4, 5 + i as i32]
            } else {
                vec![7 + i as i32, 9]
            },
            max_new: 3 + (i as usize % 3),
        })
        .collect()
}

/// Serve [`requests`] through a 2-lane continuous engine with the trace
/// sink enabled (or disabled), returning the sorted responses, the
/// engine, and the live trace (entries + counter summary).
fn serve_traced(
    traced: bool,
    plan: Option<FaultPlan>,
    cfg_engine: impl FnOnce(&mut DecodeEngine),
    cfg_sched: impl FnOnce(&mut Scheduler),
) -> (Vec<GenResponse>, DecodeEngine, Trace) {
    let spec = LmSpec::tiny();
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let mut eng =
        DecodeEngine::with_backend(spec.clone(), Box::new(SynthBackend::new(&spec)), &policy, 2);
    eng.set_prefill_budget(4);
    if traced {
        eng.set_trace_sink(TraceSink::enabled(DEFAULT_TRACE_CAP));
    }
    cfg_engine(&mut eng);
    if let Some(p) = plan {
        eng.inject_faults(&p);
    }
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_prefill_budget(eng.prefill_budget());
    sched.set_trace_sink(eng.trace_sink());
    cfg_sched(&mut sched);
    for r in requests() {
        assert!(sched.enqueue(r).is_none(), "queue under its cap must accept");
    }
    let mut out = eng.serve_continuous(&mut sched).expect("serve failed");
    out.sort_by_key(|r| r.id);
    let trace = Trace {
        entries: eng.trace_sink().entries(),
        summary: Some(TraceSummary::from_serving(&eng.serving)),
    };
    (out, eng, trace)
}

fn count_events(trace: &Trace, name: &str) -> u64 {
    trace
        .entries
        .iter()
        .filter(|e| matches!(e, TraceEntry::Event(r) if r.event.name() == name))
        .count() as u64
}

/// Every `Finished` event's reason must match the `GenResponse` shipped
/// for the same request id.
fn assert_finished_match_responses(trace: &Trace, resps: &[GenResponse]) {
    for e in &trace.entries {
        let TraceEntry::Event(r) = e else { continue };
        let TraceEvent::Finished { reason } = &r.event else { continue };
        let id = r.req.expect("Finished must carry a request id");
        let resp = resps.iter().find(|x| x.id == id).expect("Finished without a response");
        assert_eq!(*reason, resp.reason, "req {id}: trace reason drifted from response");
    }
}

#[test]
fn disabled_sink_records_nothing_and_generations_are_bit_identical() {
    let (clean, eng, empty) = serve_traced(false, None, |_| {}, |_| {});
    assert!(!eng.trace_sink().is_enabled());
    assert!(empty.entries.is_empty(), "disabled sink must record nothing");
    let (traced, _, trace) = serve_traced(true, None, |_| {}, |_| {});
    assert!(!trace.entries.is_empty());
    // the tracing overhead contract: identical tokens, ids, and reasons
    assert_eq!(clean.len(), traced.len());
    for (c, t) in clean.iter().zip(&traced) {
        assert_eq!(c.id, t.id);
        assert_eq!(c.tokens, t.tokens, "req {}: tracing changed a generation", c.id);
        assert_eq!(c.reason, t.reason);
    }
    let viol = check_trace(&trace);
    assert!(viol.is_empty(), "clean-run trace violations: {viol:?}");
    // a clean run's lifecycle: one Enqueued, Admitted, and Finished per
    // request, every Finished Completed
    let n = requests().len() as u64;
    assert_eq!(count_events(&trace, "enqueued"), n);
    assert_eq!(count_events(&trace, "admitted"), n);
    assert_eq!(count_events(&trace, "finished"), n);
}

#[test]
fn spans_account_for_every_prefill_token() {
    let (resps, _, trace) = serve_traced(true, None, |_| {}, |_| {});
    let total_prompt: usize = requests().iter().map(|r| r.prompt.len()).sum();
    let (mut span_prefill, mut span_decode, mut chunk_tokens, mut spans) = (0usize, 0, 0, 0);
    for e in &trace.entries {
        match e {
            TraceEntry::Span(s) => {
                span_prefill += s.prefill_tokens;
                span_decode += s.decode_tokens;
                assert!(s.occupancy <= 2, "span occupancy exceeds the lane count");
                spans += 1;
            }
            TraceEntry::Event(r) => {
                if let TraceEvent::PrefillChunk { tokens } = r.event {
                    chunk_tokens += tokens;
                }
            }
        }
    }
    assert!(spans > 0, "continuous steps must emit spans");
    // the per-step split and the per-request chunk events count the same
    // prompt tokens, and every prompt token is fed exactly once
    assert_eq!(span_prefill, chunk_tokens);
    assert_eq!(span_prefill, total_prompt);
    let generated: usize = resps.iter().map(|r| r.generated).sum();
    // each prompt's final token samples during prefill accounting, so
    // decode-step tokens are the remainder
    assert_eq!(span_decode, generated - resps.len());
}

#[test]
fn fault_sweep_traces_stay_lifecycle_legal_with_exact_counters() {
    // in-place retry scenario: Retry events (batch-scoped, no req id)
    let mut fired = false;
    for seed in 0..8 {
        let plan = FaultPlan::transient_steps(seed, 0.25);
        let (resps, eng, trace) = serve_traced(
            true,
            Some(plan),
            |e| e.set_retry_policy(6, Duration::ZERO),
            |_| {},
        );
        let viol = check_trace(&trace);
        assert!(viol.is_empty(), "seed {seed}: {viol:?}");
        assert_eq!(count_events(&trace, "retry"), eng.serving.retries);
        assert_finished_match_responses(&trace, &resps);
        if eng.serving.retries > 0 {
            fired = true;
            break;
        }
    }
    assert!(fired, "no scanned seed fired a retry");

    // requeue scenario: retry budget 0 routes every fault through
    // Requeued; the re-admitted request gets a second Admitted, which the
    // checker only accepts from the Queued state the Requeued set
    fired = false;
    for seed in 0..8 {
        let plan = FaultPlan::transient_steps(seed, 0.15);
        let (resps, eng, trace) = serve_traced(
            true,
            Some(plan),
            |e| {
                e.set_retry_policy(0, Duration::ZERO);
                e.set_requeue_max(10_000);
            },
            |_| {},
        );
        let viol = check_trace(&trace);
        assert!(viol.is_empty(), "seed {seed}: {viol:?}");
        assert_eq!(count_events(&trace, "requeued"), eng.serving.requeued);
        assert_finished_match_responses(&trace, &resps);
        if eng.serving.requeued > 0 {
            fired = true;
            break;
        }
    }
    assert!(fired, "no scanned seed fired a requeue");
}

#[test]
fn shed_deadline_and_reject_lifecycles_are_legal() {
    // deadline: a zero wall deadline expires every request at admission
    let (resps, eng, trace) =
        serve_traced(true, None, |e| e.set_deadline(Some(Duration::ZERO)), |_| {});
    let viol = check_trace(&trace);
    assert!(viol.is_empty(), "deadline trace violations: {viol:?}");
    assert_eq!(count_events(&trace, "deadline_expired"), eng.serving.deadline_expired);
    assert_eq!(eng.serving.deadline_expired, requests().len() as u64);
    assert_finished_match_responses(&trace, &resps);

    // reject + shed on one engine: an invalid prompt finishes Rejected at
    // admission; overflow past the queue cap is shed by the server policy
    let spec = LmSpec::tiny();
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let mut eng =
        DecodeEngine::with_backend(spec.clone(), Box::new(SynthBackend::new(&spec)), &policy, 2);
    eng.set_trace_sink(TraceSink::enabled(DEFAULT_TRACE_CAP));
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_trace_sink(eng.trace_sink());
    sched.set_queue_cap(2);
    let mut resps = Vec::new();
    let mut shed = 0u64;
    let mut reqs = requests();
    reqs[1].prompt.clear(); // invalid: rejected at admission, not shed
    for r in reqs {
        if let Some(back) = sched.enqueue(r) {
            resps.push(eng.shed_response(back));
            shed += 1;
        }
    }
    assert!(shed > 0, "cap 2 must shed part of the burst");
    resps.extend(eng.serve_continuous(&mut sched).expect("serve failed"));
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), requests().len(), "every request must be answered");
    let trace = Trace {
        entries: eng.trace_sink().entries(),
        summary: Some(TraceSummary::from_serving(&eng.serving)),
    };
    let viol = check_trace(&trace);
    assert!(viol.is_empty(), "shed/reject trace violations: {viol:?}");
    assert_eq!(count_events(&trace, "shed"), shed);
    assert_eq!(eng.serving.shed, shed);
    assert_eq!(eng.serving.rejected, 1);
    assert_finished_match_responses(&trace, &resps);
}

#[test]
fn jsonl_round_trip_preserves_entries_and_passes_the_cli_checker() {
    let mut fired = false;
    for seed in 0..8 {
        let plan = FaultPlan::transient_steps(seed, 0.25);
        let (_, eng, live) = serve_traced(
            true,
            Some(plan),
            |e| e.set_retry_policy(6, Duration::ZERO),
            |_| {},
        );
        if eng.serving.retries == 0 {
            continue;
        }
        fired = true;
        let dir = std::env::temp_dir()
            .join(format!("nxfp_trace_test_{seed}_{}", std::process::id()));
        let path = dir.join("trace.jsonl");
        let summary = TraceSummary::from_serving(&eng.serving);
        eng.trace_sink().write_jsonl(&path, &summary).expect("trace write failed");
        let reread = read_jsonl(&path).expect("trace reread failed");
        // lossless round trip: entries, order, payloads, and the summary
        assert_eq!(reread.entries, live.entries);
        assert_eq!(reread.summary.as_ref(), Some(&summary));
        let viol = check_trace(&reread);
        assert!(viol.is_empty(), "reread trace violations: {viol:?}");
        // the timelines `nxfp trace show` renders cover every request
        let tl = timelines(&reread);
        assert_eq!(tl.len(), requests().len());
        for t in &tl {
            assert_eq!(t.reason, Some(FinishReason::Completed));
            assert!(t.prefill_tokens > 0);
        }
        std::fs::remove_dir_all(&dir).ok();
        break;
    }
    assert!(fired, "no scanned seed fired a retry");
}

#[test]
fn a_tampered_trace_is_caught_by_the_checker() {
    let (_, eng, _) = serve_traced(true, None, |_| {}, |_| {});
    let dir = std::env::temp_dir().join(format!("nxfp_trace_tamper_{}", std::process::id()));
    let path = dir.join("trace.jsonl");
    // lie about the counters: claim one more admission than traced
    let mut summary = TraceSummary::from_serving(&eng.serving);
    summary.admitted += 1;
    eng.trace_sink().write_jsonl(&path, &summary).expect("trace write failed");
    let reread = read_jsonl(&path).expect("trace reread failed");
    let viol = check_trace(&reread);
    assert!(
        viol.iter().any(|v| v.contains("admitted")),
        "counter drift must be reported, got {viol:?}"
    );
    std::fs::remove_dir_all(&dir).ok();
}
