//! Bit-identity contract between the table-driven quantizer engine
//! (`formats::encode`) / flat `BlockStore` storage and the normative
//! reference path (`formats::quantize_block` + legacy `Vec<BlockCode>`):
//! randomized sweeps over bits 4..=6, every NM/AM/CR toggle combination,
//! both nano modes, partial tail blocks, and blocks containing
//! ±0/NaN/±Inf. The reference path is itself pinned to the Python oracle by
//! `golden_cross_check.rs`, so these properties transitively pin the engine
//! to the oracle.

use nxfp::dequant::{dequantize_packed, DequantLut};
use nxfp::formats::packed::PackedMatrix;
use nxfp::formats::{
    quantize_block, BaseFormat, BlockStore, EncodePlan, EncodeScratch, NanoMode, NxConfig,
};
use nxfp::quant::quantize_matrix;
use nxfp::tensor::Tensor2;
use nxfp::util::proptest;
use nxfp::util::rng::Rng;

/// Draw a random config covering the full toggle space.
fn random_cfg(rng: &mut Rng) -> NxConfig {
    let bits = 4 + rng.below(3) as u8;
    let base = if rng.below(2) == 0 {
        NxConfig::bfp(bits)
    } else {
        NxConfig::mxfp(bits)
    };
    let mut cfg = NxConfig {
        enable_nm: rng.below(2) == 1,
        enable_am: rng.below(2) == 1,
        enable_cr: rng.below(2) == 1,
        ..base
    };
    if rng.below(2) == 1 {
        cfg = cfg.with_nano_mode(NanoMode::Exhaustive);
    }
    let ks = [4usize, 8, 16, 32];
    cfg.with_block_size(ks[rng.below(4)])
}

/// Random values at a random magnitude, with occasional specials injected.
fn random_values(rng: &mut Rng, len: usize) -> Vec<f32> {
    let scale = nxfp::util::exp2i(rng.range(-24, 24) as i32);
    (0..len)
        .map(|_| {
            if rng.below(16) == 0 {
                match rng.below(6) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => f32::NAN,
                    3 => f32::INFINITY,
                    4 => f32::NEG_INFINITY,
                    _ => 1.0e-44, // subnormal
                }
            } else {
                rng.normal_f32(0.0, 1.0) * scale
            }
        })
        .collect()
}

#[test]
fn prop_engine_bit_identical_to_reference() {
    proptest::check_default("engine-vs-reference", |rng| {
        let cfg = random_cfg(rng);
        let k = cfg.block_size;
        // 1..=3 full blocks plus a possibly-partial tail
        let len = 1 + rng.below(3 * k + 3);
        let v = random_values(rng, len);
        let tabs = cfg.tables();
        let plan = EncodePlan::new(&cfg);
        let mut scratch = EncodeScratch::new();
        let mut codes = vec![0u8; k];
        for (bi, chunk) in v.chunks(k).enumerate() {
            let want = quantize_block(chunk, &cfg, &tabs);
            let out = &mut codes[..chunk.len()];
            let (e, nano, fmt) = plan.quantize_block_into(chunk, &mut scratch, out);
            if (e, nano, fmt) != (want.e_shared, want.nano, want.fmt_mx) {
                return Err(format!(
                    "{} block {bi}: meta ({e},{nano},{fmt}) != ({},{},{}) on {chunk:?}",
                    cfg.name(),
                    want.e_shared,
                    want.nano,
                    want.fmt_mx
                ));
            }
            if out != &want.codes[..] {
                return Err(format!(
                    "{} block {bi}: codes {out:?} != {:?} on {chunk:?}",
                    cfg.name(),
                    want.codes
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_store_legacy_round_trip_and_pack_equivalence() {
    proptest::check_default("store-vs-legacy", |rng| {
        let cfg = random_cfg(rng);
        let rows = 1 + rng.below(4);
        let cols = 1 + rng.below(70);
        let mut t = Tensor2::zeros(rows, cols);
        let vals = random_values(rng, rows * cols);
        t.data.copy_from_slice(&vals);
        let q = quantize_matrix(&t, &cfg);
        // SoA store <-> legacy Vec<BlockCode> is lossless
        let legacy = q.store.to_block_codes();
        let back = BlockStore::from_block_codes(rows, cols, cfg.block_size, &legacy);
        if back != q.store {
            return Err(format!("{}: store round-trip diverged", cfg.name()));
        }
        // legacy per-block pack and the flat-store pack emit identical
        // byte streams
        let p_legacy = PackedMatrix::pack(rows, cols, &cfg, &legacy);
        let p_store = PackedMatrix::from_store(rows, cols, &cfg, &q.store);
        if p_legacy.scales != p_store.scales
            || p_legacy.meta != p_store.meta
            || p_legacy.payload != p_store.payload
        {
            return Err(format!("{}: packed streams diverged", cfg.name()));
        }
        // and the LUT decode of the packed form matches the store decode
        let lut = DequantLut::new(&cfg);
        let fast = dequantize_packed(&p_store, &lut, cfg.base == BaseFormat::Mx);
        let reference = q.dequantize(&cfg);
        for (i, (a, b)) in reference.data.iter().zip(&fast.data).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("{}: dequant elem {i}: {a} vs {b}", cfg.name()));
            }
        }
        Ok(())
    });
}
