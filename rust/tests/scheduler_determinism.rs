//! Scheduler invariants that make continuous batching safe to ship:
//!
//! 1. **Bit-identity** — a request admitted mid-stream into a freed lane
//!    (continuous batching) generates exactly the tokens it generates
//!    running alone, under quantized and baseline KV alike. Greedy decode
//!    is deterministic and per-slot independent, so any divergence means
//!    lane hygiene is broken (stale rows, missed syncs, cross-lane leaks).
//! 2. **No starvation** — the max-waiting-steps promotion rule bounds how
//!    long the shortest-prompt-first admission policy can bypass a long
//!    request.
//! 3. **Lane mobility** — moving a live slot to another lane
//!    (`DecodeEngine::move_lane` slab copy) preserves KV contents: the
//!    generation continues bit-identically.
//! 4. **Chunked prefill stays safe** — the anti-starvation bound still
//!    holds when prefill is chunked under a token budget, and a budget of
//!    16 strictly beats budget 1 on deterministic TTFT-in-steps for the
//!    bursty prefill-heavy workload (the reason the knob exists). The
//!    bit-identity side of chunking is pinned in `prefill_chunking.rs`.
//!
//! All tests run on the deterministic `SynthBackend` — no PJRT runtime or
//! `make artifacts` needed (unlike `server_integration.rs`).

use nxfp::bench_util::StepTtft;
use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::{DecodeEngine, GenRequest, GenResponse, SlotState, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;

fn spec() -> LmSpec {
    LmSpec { vocab: 48, d_model: 24, n_layers: 2, n_heads: 2, d_ff: 64, seq_len: 24 }
}

fn engine(kv: Option<NxConfig>, max_batch: usize) -> DecodeEngine {
    let sp = spec();
    // Option<NxConfig> lowers to the legacy-shaped policies
    // (QuantPolicy::uniform / QuantPolicy::fp16) via From
    let policy: QuantPolicy = kv.into();
    DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &policy, max_batch)
}

/// Tokens a request generates running completely alone (batch of 1).
fn solo_tokens(kv: Option<NxConfig>, req: &GenRequest) -> Vec<i32> {
    let mut eng = engine(kv, 1);
    let resps = eng.serve_wave(vec![req.clone()]).unwrap();
    assert_eq!(resps.len(), 1);
    resps.into_iter().next().unwrap().tokens
}

fn by_id(resps: &[GenResponse], id: u64) -> &GenResponse {
    resps.iter().find(|r| r.id == id).unwrap()
}

#[test]
fn mid_stream_admission_is_bit_identical_to_solo() {
    for kv in [Some(NxConfig::nxfp(4)), Some(NxConfig::mxfp(5)), None] {
        // lanes: A (long) and B (short) admitted first; T waits in the
        // queue and is admitted into B's freed lane while A still decodes
        let a = GenRequest { id: 0, prompt: vec![7, 3], max_new: 12 };
        let b = GenRequest { id: 1, prompt: vec![9, 2], max_new: 3 };
        let t = GenRequest { id: 2, prompt: vec![4, 11, 5], max_new: 6 };
        let mut eng = engine(kv.clone(), 2);
        let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
        for r in [&a, &b, &t] {
            sched.enqueue(r.clone());
        }
        let resps = eng.serve_continuous(&mut sched).unwrap();
        assert_eq!(resps.len(), 3);
        // T really waited in the queue and joined mid-stream: B finished
        // before T, and A (admitted at step 0) finished after T started
        assert_eq!(eng.serving.admitted, 3);
        assert!(eng.serving.queue_depth.max() >= 1.0, "T never queued");
        let order: Vec<u64> = resps.iter().map(|r| r.id).collect();
        assert!(
            order.iter().position(|&x| x == 1) < order.iter().position(|&x| x == 0),
            "short B should finish before long A: {order:?}"
        );
        for r in [&a, &b, &t] {
            assert_eq!(
                by_id(&resps, r.id).tokens,
                solo_tokens(kv.clone(), r),
                "request {} diverged from its solo run (kv {:?})",
                r.id,
                kv.as_ref().map(|c| c.name())
            );
        }
    }
}

#[test]
fn continuous_matches_wave_for_identical_admission() {
    // with exactly max_batch requests there is no mid-stream admission:
    // both schedulers must produce identical generations
    let kv = Some(NxConfig::nxfp(4));
    let reqs = vec![
        GenRequest { id: 0, prompt: vec![1, 2, 3], max_new: 5 },
        GenRequest { id: 1, prompt: vec![8], max_new: 7 },
    ];
    let mut wave_eng = engine(kv.clone(), 2);
    let wave = wave_eng.serve_wave(reqs.clone()).unwrap();
    let mut cont_eng = engine(kv, 2);
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    for r in &reqs {
        sched.enqueue(r.clone());
    }
    let cont = cont_eng.serve_continuous(&mut sched).unwrap();
    for r in &reqs {
        assert_eq!(by_id(&wave, r.id).tokens, by_id(&cont, r.id).tokens);
    }
    // continuous never takes more steps than the wave barrier forces
    assert!(cont_eng.metrics.decode_steps <= wave_eng.metrics.decode_steps);
}

#[test]
fn promotion_bounds_queue_wait_for_long_prompts() {
    let promote_after = 6u64;
    let long = GenRequest { id: 99, prompt: vec![3; 12], max_new: 4 };
    let shorts: Vec<GenRequest> =
        (0..24).map(|i| GenRequest { id: i, prompt: vec![2, 5], max_new: 3 }).collect();
    let run = |promote_after: u64| -> (Vec<u64>, u64) {
        let mut eng = engine(Some(NxConfig::nxfp(4)), 2);
        let mut sched = Scheduler::new(2, promote_after);
        sched.enqueue(shorts[0].clone());
        sched.enqueue(long.clone()); // second in FIFO, longest prompt
        for s in &shorts[1..] {
            sched.enqueue(s.clone());
        }
        let resps = eng.serve_continuous(&mut sched).unwrap();
        assert_eq!(resps.len(), 25);
        (resps.iter().map(|r| r.id).collect(), eng.serving.promoted)
    };
    // greedy-only control: the long prompt is bypassed by every short and
    // finishes dead last
    let (order, promoted) = run(100_000);
    assert_eq!(*order.last().unwrap(), 99, "control: greedy starves the long request");
    assert_eq!(promoted, 0);
    // with the promotion rule it overtakes the shorts once its wait
    // crosses the bound: it must finish well before the queue drains
    let (order, promoted) = run(promote_after);
    let pos = order.iter().position(|&x| x == 99).unwrap();
    assert!(promoted >= 1, "promotion rule never fired");
    assert!(pos < 12, "long request finished at position {pos} of 25: {order:?}");
}

#[test]
fn promotion_bounds_queue_wait_with_chunked_prefill() {
    // the anti-starvation bound must survive chunking: at budget 4 the
    // 12-token prompt still costs 3x the estimated prefill steps of a
    // 2-token short, so the budget-aware greedy keeps bypassing it until
    // the promotion rule fires
    let budget = 4usize;
    let promote_after = 6u64;
    let long = GenRequest { id: 99, prompt: vec![3; 12], max_new: 4 };
    let shorts: Vec<GenRequest> =
        (0..24).map(|i| GenRequest { id: i, prompt: vec![2, 5], max_new: 3 }).collect();
    let run = |promote_after: u64| -> (Vec<u64>, u64) {
        let mut eng = engine(Some(NxConfig::nxfp(4)), 2);
        eng.set_prefill_budget(budget);
        let mut sched = Scheduler::new(2, promote_after);
        sched.set_prefill_budget(budget);
        sched.enqueue(shorts[0].clone());
        sched.enqueue(long.clone());
        for s in &shorts[1..] {
            sched.enqueue(s.clone());
        }
        let resps = eng.serve_continuous(&mut sched).unwrap();
        assert_eq!(resps.len(), 25);
        (resps.iter().map(|r| r.id).collect(), eng.serving.promoted)
    };
    // greedy-only control: still starved under chunking
    let (order, promoted) = run(100_000);
    assert_eq!(*order.last().unwrap(), 99, "control: greedy starves the long request");
    assert_eq!(promoted, 0);
    // with the bound the long request overtakes once it becomes urgent
    let (order, promoted) = run(promote_after);
    let pos = order.iter().position(|&x| x == 99).unwrap();
    assert!(promoted >= 1, "promotion rule never fired under chunking");
    assert!(pos < 12, "long request finished at position {pos} of 25: {order:?}");
}

/// Drive a continuous run step by step, tracking deterministic
/// TTFT-in-steps per request; returns the tracker and total engine steps.
fn run_with_ttft(budget: usize, reqs: &[GenRequest], lanes: usize) -> (StepTtft, u64) {
    let mut eng = engine(Some(NxConfig::nxfp(4)), lanes);
    eng.set_prefill_budget(budget);
    let mut sched = Scheduler::new(lanes, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_prefill_budget(budget);
    for r in reqs {
        sched.enqueue(r.clone());
    }
    let mut ttft = StepTtft::new();
    let mut step = 0u64;
    let mut done = 0usize;
    while sched.has_work() {
        let finished = eng.step_continuous(&mut sched).unwrap();
        step += 1;
        ttft.observe(step, sched.slots());
        ttft.observe_done(step, &finished);
        done += finished.len();
    }
    assert_eq!(done, reqs.len());
    assert_eq!(ttft.count(), reqs.len());
    (ttft, step)
}

#[test]
fn chunked_prefill_strictly_beats_unchunked_ttft() {
    // bursty prefill-heavy synth workload: long prompts, short answers —
    // the regime where feeding one prompt token per step inflates TTFT.
    // budget 16 must strictly beat budget 1 on first-token steps without
    // spending more engine steps overall.
    let reqs: Vec<GenRequest> = (0..8u64)
        .map(|i| {
            let plen = 14 + (i as usize % 3);
            let prompt = (0..plen).map(|t| ((i as usize + t * 5) % 40) as i32 + 1).collect();
            GenRequest { id: i, prompt, max_new: 3 }
        })
        .collect();
    let (ttft1, steps1) = run_with_ttft(1, &reqs, 2);
    let (ttft16, steps16) = run_with_ttft(16, &reqs, 2);
    assert!(
        ttft16.mean() < ttft1.mean(),
        "budget 16 mean TTFT {} steps must strictly beat budget 1's {}",
        ttft16.mean(),
        ttft1.mean()
    );
    assert!(ttft16.quantile(0.5) < ttft1.quantile(0.5), "p50 TTFT did not improve");
    assert!(steps16 <= steps1, "chunking spent more steps ({steps16} vs {steps1})");
    // and per-request first tokens never arrive later under chunking
    for r in &reqs {
        assert!(ttft16.get(r.id).unwrap() <= ttft1.get(r.id).unwrap(), "req {} regressed", r.id);
    }
}

#[test]
fn move_lane_preserves_generation() {
    let kv = Some(NxConfig::nxfp(4));
    let req = GenRequest { id: 5, prompt: vec![6, 1, 9, 2, 8, 4], max_new: 8 };
    let want = solo_tokens(kv.clone(), &req);

    let mut eng = engine(kv, 3);
    let mut sched = Scheduler::new(3, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.enqueue(req.clone());
    // run a few steps (still prefilling: prompt is 6 tokens)
    let mut resps = Vec::new();
    for _ in 0..4 {
        resps.extend(eng.step_continuous(&mut sched).unwrap());
    }
    {
        let slot = sched.slots()[0].as_ref().expect("slot admitted into lane 0");
        assert_eq!(slot.request_id(), 5);
        assert_eq!(slot.state(), SlotState::Prefilling);
    }
    // reassign to lane 2 mid-prefill: slab copy, no re-decode
    eng.move_lane(sched.slots_mut(), 0, 2).unwrap();
    assert!(sched.slots()[0].is_none());
    // vacated lane is zeroed for the next occupant
    let (k0, v0) = eng.lane(0);
    assert!(k0.iter().chain(v0).all(|&x| x == 0.0));
    resps.extend(eng.serve_continuous(&mut sched).unwrap());
    assert_eq!(resps.len(), 1);
    assert_eq!(resps[0].tokens, want, "generation diverged after the lane move");
}

#[test]
fn failed_lane_move_requeues_instead_of_panicking() {
    // two live slots: moving one onto the other is the occupied-target
    // fault that used to assert-kill the thread. The contained path must
    // requeue the source slot, keep the target slot untouched, and the
    // replayed request must still generate its solo tokens bit-exactly.
    let kv = Some(NxConfig::nxfp(4));
    let a = GenRequest { id: 0, prompt: vec![6, 1, 9, 2, 8, 4], max_new: 8 };
    let b = GenRequest { id: 1, prompt: vec![3, 7, 5, 2], max_new: 6 };
    let want_a = solo_tokens(kv.clone(), &a);
    let want_b = solo_tokens(kv.clone(), &b);

    let mut eng = engine(kv, 2);
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.enqueue(a.clone());
    sched.enqueue(b.clone());
    let mut resps = Vec::new();
    for _ in 0..3 {
        resps.extend(eng.step_continuous(&mut sched).unwrap());
    }
    assert!(sched.slots()[0].is_some() && sched.slots()[1].is_some());
    // occupied target: Err from the raw call, lanes untouched
    assert!(eng.move_lane(sched.slots_mut(), 0, 1).is_err());
    assert!(sched.slots()[0].is_some() && sched.slots()[1].is_some());
    // contained path: the source slot requeues, the engine keeps serving
    let mut done = Vec::new();
    assert!(!eng.move_lane_contained(&mut sched, 0, 1, &mut done));
    assert!(done.is_empty(), "requeue-eligible slot must not fail outright");
    assert_eq!(eng.serving.requeued, 1);
    assert!(sched.slots()[0].is_none(), "faulted source lane must be freed");
    assert_eq!(sched.queue_depth(), 1, "source slot's request must be requeued");
    resps.extend(eng.serve_continuous(&mut sched).unwrap());
    assert_eq!(resps.len(), 2);
    assert_eq!(by_id(&resps, 0).tokens, want_a, "requeued request diverged from solo");
    assert_eq!(by_id(&resps, 1).tokens, want_b, "untouched slot diverged from solo");
    // empty-source fault with no slot to requeue: error contained, no-op
    let mut done = Vec::new();
    assert!(!eng.move_lane_contained(&mut sched, 0, 1, &mut done));
    assert!(done.is_empty());
    assert_eq!(eng.serving.requeued, 1);
}

#[test]
fn invalid_requests_reject_without_consuming_lanes() {
    let mut eng = engine(None, 2);
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.enqueue(GenRequest { id: 0, prompt: vec![], max_new: 4 });
    sched.enqueue(GenRequest { id: 1, prompt: vec![1; 64], max_new: 4 }); // > seq_len
    sched.enqueue(GenRequest { id: 2, prompt: vec![1, 2], max_new: 2 });
    let resps = eng.serve_continuous(&mut sched).unwrap();
    assert_eq!(resps.len(), 3);
    assert_eq!(by_id(&resps, 0).generated, 0);
    assert_eq!(by_id(&resps, 1).generated, 0);
    assert_eq!(by_id(&resps, 2).generated, 2);
    assert_eq!(eng.serving.rejected, 2);
    assert_eq!(eng.serving.admitted, 1);
    assert_eq!(eng.metrics.requests, 1);
}
