//! Paged-KV prefix sharing invariants (see `coordinator/ARCHITECTURE.md`):
//!
//! 1. **Cache off is a no-op** — with `--prefix-cache off` the paged
//!    storage is pure plumbing: generations AND packed KV bytes are
//!    bit-identical across page geometries and to solo runs.
//! 2. **Cache on is invisible to outputs** — shared-prefix traffic adopts
//!    packed pages (prefix hits observed) yet every generation stays
//!    bit-identical to the request's solo run; only steps and the
//!    dedup-aware footprint improve.
//! 3. **COW is exact at every split point** — divergence at any offset
//!    within a page (including a page boundary) reproduces the solo
//!    generation, on a block geometry that leaves a ragged block per row.
//! 4. **No leaks** — after churn, retiring every slot and clearing the
//!    prefix cache drains the page pool to zero.
//! 5. **Dedup math is pinned** — on a symmetric shared-prefix workload
//!    the dedup factor is exactly 2.0, not merely "> 1".
//!
//! All tests run on the deterministic `SynthBackend` — no PJRT runtime or
//! `make artifacts` needed.

use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::{DecodeEngine, GenRequest, GenResponse, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;

fn spec() -> LmSpec {
    LmSpec { vocab: 48, d_model: 24, n_layers: 2, n_heads: 2, d_ff: 64, seq_len: 24 }
}

/// Run a continuous-batching serve over `reqs` and return the responses
/// plus the engine and scheduler for metric/pool inspection.
fn serve(
    kv: Option<NxConfig>,
    page_rows: usize,
    prefix_cache: bool,
    reqs: &[GenRequest],
    lanes: usize,
) -> (Vec<GenResponse>, DecodeEngine, Scheduler) {
    let sp = spec();
    let policy: QuantPolicy = kv.into();
    let mut eng =
        DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &policy, lanes);
    eng.set_kv_page_rows(page_rows);
    let mut sched = Scheduler::new(lanes, Scheduler::DEFAULT_PROMOTE_AFTER);
    if prefix_cache {
        sched.enable_prefix_cache(eng.page_pool(), 64);
    }
    for r in reqs {
        sched.enqueue(r.clone());
    }
    let resps = eng.serve_continuous(&mut sched).unwrap();
    assert_eq!(resps.len(), reqs.len());
    (resps, eng, sched)
}

/// Tokens a request generates running completely alone (batch of 1).
fn solo_tokens(kv: Option<NxConfig>, req: &GenRequest) -> Vec<i32> {
    let sp = spec();
    let policy: QuantPolicy = kv.into();
    let mut eng = DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &policy, 1);
    let resps = eng.serve_wave(vec![req.clone()]).unwrap();
    resps.into_iter().next().unwrap().tokens
}

fn by_id(resps: &[GenResponse], id: u64) -> &GenResponse {
    resps.iter().find(|r| r.id == id).unwrap()
}

/// A shared 12-token system prompt plus a distinct 3-token suffix each.
fn shared_prefix_reqs(n: u64, max_new: usize) -> Vec<GenRequest> {
    let sys: Vec<i32> = (0..12).map(|t| (t % 40) as i32 + 1).collect();
    (0..n)
        .map(|i| {
            let mut p = sys.clone();
            p.extend([40 + i as i32, 44, (41 + i) as i32 % 47]);
            GenRequest { id: i, prompt: p, max_new }
        })
        .collect()
}

#[test]
fn cache_off_is_bit_identical_across_page_geometries() {
    let kv = Some(NxConfig::nxfp(4));
    let reqs = shared_prefix_reqs(4, 4);
    let (r16, e16, _) = serve(kv.clone(), 16, false, &reqs, 2);
    let (r3, e3, _) = serve(kv.clone(), 3, false, &reqs, 2);
    let (r1, e1, _) = serve(kv.clone(), 1, false, &reqs, 2);
    for r in &reqs {
        let want = solo_tokens(kv.clone(), r);
        for (resps, label) in [(&r16, "16"), (&r3, "3"), (&r1, "1")] {
            assert_eq!(by_id(resps, r.id).tokens, want, "req {} page_rows {label}", r.id);
        }
    }
    // packed bytes are a function of rows and format, never of paging
    assert_eq!(e16.metrics.kv_bits_packed, e3.metrics.kv_bits_packed);
    assert_eq!(e16.metrics.kv_bits_packed, e1.metrics.kv_bits_packed);
    // cache off: every page is charged by its own request, factor exactly 1
    for e in [&e16, &e3, &e1] {
        assert_eq!(e.metrics.kv_bits_packed_dedup(), e.metrics.kv_bits_packed);
        assert_eq!(e.metrics.dedup_factor(), 1.0);
        assert_eq!(e.serving.prefix_hits + e.serving.prefix_misses, 0);
        assert_eq!(e.page_pool().borrow().cow_copies(), 0);
        assert_eq!(e.page_pool().borrow().live_pages(), 0);
    }
}

#[test]
fn shared_prefix_traffic_hits_and_stays_bit_identical() {
    let kv = Some(NxConfig::nxfp(4));
    let reqs = shared_prefix_reqs(4, 4);
    // one lane so each later request admits after the first registered
    let (off, eoff, _) = serve(kv.clone(), 4, false, &reqs, 1);
    let (on, eon, _) = serve(kv.clone(), 4, true, &reqs, 1);
    for r in &reqs {
        assert_eq!(by_id(&on, r.id).tokens, by_id(&off, r.id).tokens, "req {}", r.id);
        assert_eq!(by_id(&on, r.id).tokens, solo_tokens(kv.clone(), r), "req {}", r.id);
    }
    // requests 1..3 each adopt the 12 shared rows
    assert_eq!(eon.serving.prefix_hits, 3);
    assert_eq!(eon.serving.prefix_misses, 1);
    assert_eq!(eon.serving.prefix_hit_rate(), 0.75);
    assert_eq!(eon.serving.prefix_rows.min(), 12.0);
    assert_eq!(eon.serving.prefix_rows.max(), 12.0);
    // at budget 1 each adopted row skips one prefill step: strictly fewer
    // engine steps end to end on the same traffic
    assert!(
        eon.metrics.decode_steps + 3 * 12 <= eoff.metrics.decode_steps,
        "expected 36 skipped steps: {} vs {}",
        eon.metrics.decode_steps,
        eoff.metrics.decode_steps
    );
    // the raw packed charge is unchanged; the dedup-aware charge counts
    // each shared page once
    assert_eq!(eon.metrics.kv_bits_packed, eoff.metrics.kv_bits_packed);
    assert!(eon.metrics.kv_bits_packed_dedup() < eon.metrics.kv_bits_packed);
    assert!(eon.metrics.dedup_factor() > 1.0);
    assert!(eon.serving.shared_pages.max() > 0.0);
}

#[test]
fn dedup_footprint_math_is_pinned_exactly() {
    // geometry chosen so the numbers close in whole pages: prompt 15
    // (12 shared + 3 distinct), max_new 4 -> 18 KV rows per request.
    // Donor charges all 18; each adopter shares pages for rows 0..12 and
    // charges only its 6 distinct rows. 4 requests:
    //   packed = 4 * 18 = 72 row-units, dedup = 18 + 3 * 6 = 36
    // -> factor exactly 2.0. (The cache's retained partial-tail pages are
    // never charged: no completed request owns them.)
    let kv = Some(NxConfig::nxfp(4));
    let reqs = shared_prefix_reqs(4, 4);
    let (_, eon, _) = serve(kv, 4, true, &reqs, 1);
    assert_eq!(eon.metrics.kv_bits_packed_dedup() * 2, eon.metrics.kv_bits_packed);
    assert_eq!(eon.metrics.dedup_factor(), 2.0);
    // K and V charge identically under a uniform format
    assert_eq!(eon.metrics.kv_bits_packed_dedup_k, eon.metrics.kv_bits_packed_dedup_v);
}

#[test]
fn cow_divergence_is_bit_identical_at_every_split_point() {
    // block_size 16 against d_model 24 leaves a ragged 8-element block in
    // every row; page_rows 4 with split points 5..=12 covers every local
    // offset within a page, including an exact page boundary (8 and 12)
    let kv = Some(NxConfig::nxfp(4).with_block_size(16));
    let base: Vec<i32> = (0..13).map(|t| 3 + (t * 7 % 37) as i32).collect();
    for l in 5..=12usize {
        let mut pa = base[..l].to_vec();
        pa.push(45);
        let mut pb = base[..l].to_vec();
        pb.extend([46, 44]);
        let ra = GenRequest { id: 0, prompt: pa, max_new: 5 };
        let rb = GenRequest { id: 1, prompt: pb, max_new: 5 };
        let (resps, eng, _) = serve(kv.clone(), 4, true, &[ra.clone(), rb.clone()], 1);
        assert_eq!(eng.serving.prefix_hits, 1, "split {l}");
        assert_eq!(eng.serving.prefix_rows.max(), l as f64, "split {l}");
        assert_eq!(by_id(&resps, 0).tokens, solo_tokens(kv.clone(), &ra), "donor, split {l}");
        assert_eq!(by_id(&resps, 1).tokens, solo_tokens(kv.clone(), &rb), "adopter, split {l}");
    }
}

#[test]
fn fp16_kv_with_cache_on_is_a_noop() {
    let reqs = shared_prefix_reqs(3, 3);
    let (on, eon, _) = serve(None, 4, true, &reqs, 1);
    let (off, eoff, _) = serve(None, 4, false, &reqs, 1);
    for r in &reqs {
        assert_eq!(by_id(&on, r.id).tokens, by_id(&off, r.id).tokens, "req {}", r.id);
    }
    assert_eq!(eon.metrics.decode_steps, eoff.metrics.decode_steps);
    // fp16 lanes have no packed pages: nothing to look up or register
    assert_eq!(eon.serving.prefix_hits + eon.serving.prefix_misses, 0);
    assert_eq!(eon.page_pool().borrow().live_pages(), 0);
    assert_eq!(eon.metrics.kv_bits_packed, 0);
}

#[test]
fn page_pool_drains_after_churn() {
    let kv = Some(NxConfig::nxfp(4));
    // two lanes, six requests with a shared 12-token prefix: concurrent
    // prefills, adoptions, COW splits, epoch-free registrations
    let reqs = shared_prefix_reqs(6, 3);
    let (resps, eng, mut sched) = serve(kv.clone(), 4, true, &reqs, 2);
    for r in &reqs {
        assert_eq!(by_id(&resps, r.id).tokens, solo_tokens(kv.clone(), r), "req {}", r.id);
    }
    let pool = eng.page_pool();
    // slots are all retired; only prefix-cache registrations hold pages
    assert!(pool.borrow().live_pages() > 0);
    assert!(pool.borrow().cow_copies() > 0, "COW was never exercised");
    sched.clear_prefix_cache();
    assert_eq!(pool.borrow().live_pages(), 0, "page leak after churn");
    assert_eq!(pool.borrow().shared_pages(), 0);
}
