//! QuantPolicy equivalence suite — the contract the API redesign ships
//! under:
//!
//! 1. **Uniform-policy bit-identity** — `QuantPolicy::uniform(cfg)` routed
//!    through every policy-driven entry point (`quantize_checkpoint`,
//!    `direct_cast_packed`, `KvPlans`-built caches, the serving engine)
//!    produces the exact bytes and tokens of the pre-redesign
//!    single-config path (per-tensor `quantize_matrix` + `pack`,
//!    `KvCache::new`, uniform `SlotKv::new`), across bfp/mxfp/nxfp at
//!    4..=6 bits.
//! 2. **Mixed policies serve end-to-end** — `kv.k=nxfp5,kv.v=mxfp4` (and a
//!    per-layer mix) runs on `SynthBackend` through the continuous
//!    scheduler, with the per-class packed footprint reported and each
//!    stream bit-identical to a uniform cache of its config.
//!
//! Parser property tests (precedence, spec-string round-trip, rejection
//! with the class vocabulary) live in `formats::policy`; this file covers
//! the cross-layer behavior.

use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::{DecodeEngine, GenRequest, SlotKv, SynthBackend};
use nxfp::eval::quantize_checkpoint;
use nxfp::formats::{KvStream, NxConfig, QuantPolicy, TensorClass};
use nxfp::models::{Checkpoint, LmSpec};
use nxfp::quant::kv_cache::{KvCache, KvPlans};
use nxfp::quant::quantize_matrix;
use nxfp::util::rng::Rng;

fn spec() -> LmSpec {
    LmSpec { vocab: 48, d_model: 24, n_layers: 2, n_heads: 2, d_ff: 64, seq_len: 24 }
}

fn all_formats() -> Vec<NxConfig> {
    let mut out = Vec::new();
    for bits in 4u8..=6 {
        out.push(NxConfig::bfp(bits));
        out.push(NxConfig::mxfp(bits));
        out.push(NxConfig::nxfp(bits));
    }
    out
}

/// Uniform policy through `direct_cast_packed` == the legacy per-tensor
/// single-config path (`quantize_matrix(t, cfg).pack(cfg)`), byte for
/// byte, across every format family and bit width.
#[test]
fn uniform_packed_checkpoint_bit_identical_to_single_config_path() {
    let spec = LmSpec::tiny();
    let ck = Checkpoint::init(&spec, 21);
    let names = spec.quantizable();
    for cfg in all_formats() {
        let policy = QuantPolicy::uniform(cfg.clone());
        let via_policy = ck.direct_cast_packed(&names, &policy);
        assert_eq!(via_policy.len(), names.len(), "{}", cfg.name());
        for (name, pcfg, packed) in &via_policy {
            assert_eq!(pcfg, &cfg);
            let t = ck.get(name).unwrap();
            let legacy = quantize_matrix(t, &cfg).pack(&cfg);
            assert_eq!(packed, &legacy, "{} {name}: packed bytes diverged", cfg.name());
        }
    }
}

/// Uniform policy through `quantize_checkpoint` == a hand-rolled
/// per-tensor fake-quant under the same config.
#[test]
fn uniform_quantize_checkpoint_matches_single_config_path() {
    let spec = LmSpec::tiny();
    let ck = Checkpoint::init(&spec, 22);
    let names = spec.quantizable();
    for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(6)] {
        let via_policy = quantize_checkpoint(&ck, &names, &QuantPolicy::uniform(cfg.clone()));
        for name in &names {
            let want = quantize_matrix(ck.get(name).unwrap(), &cfg).dequantize(&cfg);
            assert_eq!(via_policy.get(name).unwrap(), &want, "{} {name}", cfg.name());
        }
        // non-quantizable tensors untouched
        assert_eq!(via_policy.get("embed").unwrap(), ck.get("embed").unwrap());
    }
}

/// `KvPlans`-built caches (the policy path) store and decode the exact
/// bits of `KvCache::new` (the legacy single-config constructor) for
/// every format, including the packed streams.
#[test]
fn uniform_kv_plans_bit_identical_to_legacy_cache() {
    let dim = 45; // partial tail block
    let mut rng = Rng::seeded(31);
    for cfg in all_formats() {
        let plans = KvPlans::from_policy(&QuantPolicy::uniform(cfg.clone()), 1).unwrap().unwrap();
        let (kp, vp) = plans.layers[0].clone();
        let mut via_policy = KvCache::with_plans(dim, kp, vp, 8);
        let mut legacy = KvCache::new(dim, cfg.clone());
        for _ in 0..6 {
            let k: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.2)).collect();
            let v: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.0, 1.2)).collect();
            via_policy.append(&k, &v);
            legacy.append(&k, &v);
        }
        assert_eq!(via_policy.stores(), legacy.stores(), "{}", cfg.name());
        let (pk, pv) = via_policy.dequantize(8);
        let (lk, lv) = legacy.dequantize(8);
        assert_eq!(pk.data, lk.data);
        assert_eq!(pv.data, lv.data);
        assert_eq!(via_policy.footprint_bits(), legacy.footprint_bits());
    }
}

/// Tokens a request generates on an engine with the given KV policy.
fn generate(policy: &QuantPolicy, reqs: &[GenRequest]) -> Vec<Vec<i32>> {
    let sp = spec();
    let mut eng = DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), policy, 2);
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    for r in reqs {
        sched.enqueue(r.clone());
    }
    let mut resps = eng.serve_continuous(&mut sched).unwrap();
    resps.sort_by_key(|r| r.id);
    resps.into_iter().map(|r| r.tokens).collect()
}

fn reqs() -> Vec<GenRequest> {
    vec![
        GenRequest { id: 0, prompt: vec![7, 3, 11, 2], max_new: 6 },
        GenRequest { id: 1, prompt: vec![9, 2], max_new: 4 },
        GenRequest { id: 2, prompt: vec![4, 11, 5, 1, 8], max_new: 5 },
    ]
}

/// Serving under `QuantPolicy::uniform(cfg)` generates exactly the tokens
/// the legacy `Option<NxConfig>` engine shapes generate (the From
/// conversions are those shapes verbatim), across formats.
#[test]
fn uniform_policy_generations_match_legacy_shapes() {
    let rs = reqs();
    for cfg in [NxConfig::bfp(4), NxConfig::mxfp(5), NxConfig::nxfp(4), NxConfig::nxfp(6)] {
        let uniform = generate(&QuantPolicy::uniform(cfg.clone()), &rs);
        let via_from: QuantPolicy = Some(cfg.clone()).into();
        assert_eq!(uniform, generate(&via_from, &rs), "{}", cfg.name());
        // and a policy that spells the same uniform config rule-by-rule
        let spelled = QuantPolicy::parse(&format!("kv={}", cfg.spec_name().unwrap())).unwrap();
        assert_eq!(uniform, generate(&spelled, &rs), "{} spelled", cfg.name());
    }
    // fp16 policy == legacy None
    let none: QuantPolicy = None::<NxConfig>.into();
    assert_eq!(generate(&QuantPolicy::fp16(), &rs), generate(&none, &rs));
}

/// The acceptance-criteria scenario: a mixed policy
/// (`weights=nxfp4,kv.k=nxfp5,kv.v=mxfp4`) serves end-to-end on
/// `SynthBackend` with the per-class footprint reported, and each KV
/// stream's packed bits follow that stream's config exactly.
#[test]
fn mixed_policy_serves_end_to_end_with_per_class_footprint() {
    let sp = spec();
    let policy = QuantPolicy::parse("weights=nxfp4,kv.k=nxfp5,kv.v=mxfp4").unwrap();
    // weight classes resolve independently of the KV side
    assert_eq!(policy.resolve(TensorClass::weight("l0.wq")).unwrap().bits, 4);
    let mut eng = DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &policy, 2);
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    let rs = reqs();
    for r in &rs {
        sched.enqueue(r.clone());
    }
    let resps = eng.serve_continuous(&mut sched).unwrap();
    assert_eq!(resps.len(), rs.len());
    for (r, resp) in rs.iter().zip({
        let mut v = resps.clone();
        v.sort_by_key(|x| x.id);
        v
    }) {
        assert_eq!(resp.generated, r.max_new, "request {} did not complete", r.id);
    }
    let m = eng.metrics;
    // per-class footprint is reported and split by stream config: both
    // streams hold the same rows, so the split follows the two configs'
    // per-row footprints exactly
    assert!(m.kv_bits_packed_k > 0 && m.kv_bits_packed_v > 0);
    assert_eq!(m.kv_bits_packed, m.kv_bits_packed_k + m.kv_bits_packed_v);
    let (ck, cv) = (NxConfig::nxfp(5), NxConfig::mxfp(4));
    let d = spec().d_model;
    assert_eq!(
        m.kv_bits_packed_k * cv.footprint_bits(d),
        m.kv_bits_packed_v * ck.footprint_bits(d),
        "per-stream split does not follow the configs' accounting"
    );
    assert!(m.kv_savings() > 0.5, "kv savings {}", m.kv_savings());
}

/// Mixed-stream and per-layer KV policies store, per stream and layer,
/// exactly what a uniform cache of that config stores — and the engine's
/// generations change when precision changes (the policy is live, not
/// cosmetic).
#[test]
fn mixed_kv_streams_are_bit_identical_per_class() {
    let (l, s, d) = (2usize, 12usize, 24usize);
    let policy = QuantPolicy::parse("layers.0.kv=mxfp6,kv.k=nxfp5,kv.v=mxfp4").unwrap();
    let plans = KvPlans::from_policy(&policy, l).unwrap().unwrap();
    // layer 0 both streams mxfp6; layer 1 split nxfp5/mxfp4
    assert_eq!(plans.layers[0].0.cfg.name(), "MxFP6-E2M3");
    assert_eq!(plans.layers[0].1.cfg.name(), "MxFP6-E2M3");
    assert_eq!(plans.layers[1].0.cfg.name(), "NxFP5 (NM+AM+CR)");
    assert_eq!(plans.layers[1].1.cfg.name(), "MxFP4-E2M1");
    let mut kv = SlotKv::from_plans(&plans, d, s);
    let mut uni6 = KvCache::new(d, NxConfig::mxfp(6));
    let mut uni5 = KvCache::new(d, NxConfig::nxfp(5));
    let mut uni4 = KvCache::new(d, NxConfig::mxfp(4));
    let mut rng = Rng::seeded(33);
    for _ in 0..5 {
        let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        kv.append(0, &k, &v);
        kv.append(1, &k, &v);
        uni6.append(&k, &v);
        uni5.append(&k, &k); // K stream comparison uses the K rows
        uni4.append(&v, &v); // V stream comparison uses the V rows
    }
    let caches = kv.caches();
    assert_eq!(caches[0].stores().0, uni6.stores().0, "layer 0 K");
    assert_eq!(caches[0].stores().1, uni6.stores().1, "layer 0 V");
    assert_eq!(caches[1].stores().0, uni5.stores().0, "layer 1 K");
    assert_eq!(caches[1].stores().1, uni4.stores().1, "layer 1 V");

    // precision changes propagate to generations: a 4-bit-value policy
    // and a 6-bit-value policy disagree on this workload (long decodes
    // accumulate enough value-stream error to flip greedy argmaxes;
    // divergence verified against the Python oracle simulation)
    let rs = vec![
        GenRequest { id: 0, prompt: vec![7, 3, 11, 2], max_new: 16 },
        GenRequest { id: 1, prompt: vec![4, 11, 5, 1, 8], max_new: 14 },
    ];
    let coarse = generate(&QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap(), &rs);
    let fine = generate(&QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp6").unwrap(), &rs);
    assert_ne!(coarse, fine, "value-stream precision had no observable effect");
    // determinism: the same mixed policy twice is bit-identical
    assert_eq!(coarse, generate(&QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap(), &rs));
}

/// Mixed KV policies survive the full slot lifecycle — chunked prefill
/// (bulk appends) and continuous admission churn — bit-identically to
/// solo runs, the same invariant the scheduler pins for uniform configs.
#[test]
fn mixed_policy_invariant_under_chunked_prefill() {
    let policy = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap();
    let rs = reqs();
    let sp = spec();
    let run = |budget: usize| -> Vec<Vec<i32>> {
        let mut eng = DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &policy, 2);
        eng.set_prefill_budget(budget);
        let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
        sched.set_prefill_budget(budget);
        for r in &rs {
            sched.enqueue(r.clone());
        }
        let mut resps = eng.serve_continuous(&mut sched).unwrap();
        resps.sort_by_key(|r| r.id);
        resps.into_iter().map(|r| r.tokens).collect()
    };
    let unchunked = run(1);
    for budget in [3usize, 16, usize::MAX] {
        assert_eq!(run(budget), unchunked, "budget {budget} diverged under mixed KV");
    }
}

/// Engine construction rejects policies that mix FP16 and quantized KV
/// streams (the one unsupported corner) with a useful error.
#[test]
fn partially_quantized_kv_policy_is_rejected() {
    let policy = QuantPolicy::parse("kv.k=nxfp4").unwrap(); // kv.v stays fp16
    let err = KvPlans::from_policy(&policy, 2).unwrap_err().to_string();
    assert!(err.contains("FP16"), "unhelpful error: {err}");
    // kv_uniform flags it for the eval-artifact path too
    assert!(policy.kv_uniform(2).is_err());
    // and a weights-only policy is fine: engine runs baseline KV
    let weights_only = QuantPolicy::parse("weights=nxfp4").unwrap();
    let sp = spec();
    let eng = DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &weights_only, 1);
    assert!(eng.kv_plans().is_none());
}

/// `KvStream`/`TensorClass` resolution drives SlotKv construction: the
/// interned plans are shared (pointer-equal) across layers and slots.
#[test]
fn slot_admission_shares_interned_plans() {
    use std::sync::Arc;
    let policy = QuantPolicy::parse("kv=nxfp4").unwrap();
    let plans = KvPlans::from_policy(&policy, 3).unwrap().unwrap();
    let a = SlotKv::from_plans(&plans, 24, 8);
    let b = SlotKv::from_plans(&plans, 24, 8);
    // both slots' caches point at the one interned plan
    let plan0 = &plans.layers[0].0;
    for slot in [&a, &b] {
        for cache in slot.caches() {
            assert_eq!(cache.cfg_k().name(), "NxFP4 (NM+AM+CR)");
        }
    }
    assert!(Arc::ptr_eq(&plans.layers[1].0.plan, &plan0.plan));
    assert!(Arc::ptr_eq(&plans.layers[2].1.lut, &plan0.lut));
    // resolution vocabulary sanity: kv.k/kv.v are distinct classes
    assert_eq!(
        policy.resolve(TensorClass::kv(0, KvStream::Key)).unwrap(),
        policy.resolve(TensorClass::kv(2, KvStream::Value)).unwrap()
    );
}
