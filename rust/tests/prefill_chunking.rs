//! Chunk-invariance property tests: chunked prefill is a **scheduling**
//! optimization and must be unobservable in outputs. For any per-step
//! prefill budget, every request's generated tokens and the packed bits
//! of its quantized KV streams are identical to the unchunked (budget 1)
//! schedule — including chunk boundaries that land mid-row on a split
//! 16-element quant block (d_model 24 = one full block + an 8-element
//! tail per row), and regardless of admission order.
//!
//! All tests run on the deterministic `SynthBackend` (native multi-token
//! chunk path); the artifact-loop fallback is pinned separately in
//! `coordinator::tests::chunked_prefill_via_artifact_loop_is_bit_identical`.

use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::{DecodeEngine, GenRequest, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;
use nxfp::quant::kv_cache::KvCache;
use nxfp::util::proptest::check;
use nxfp::util::rng::Rng;

/// Budgets the invariance contract is pinned over (1 = unchunked,
/// `usize::MAX` = whole prompt in one step).
const BUDGETS: [usize; 4] = [1, 3, 16, usize::MAX];

fn spec() -> LmSpec {
    LmSpec { vocab: 48, d_model: 24, n_layers: 2, n_heads: 2, d_ff: 64, seq_len: 24 }
}

/// KV format whose 16-element blocks split every 24-element row mid-row.
fn kv_cfg() -> NxConfig {
    NxConfig::nxfp(4).with_block_size(16)
}

fn engine(budget: usize, max_batch: usize) -> DecodeEngine {
    let sp = spec();
    let policy = QuantPolicy::uniform(kv_cfg());
    let mut eng =
        DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &policy, max_batch);
    eng.set_prefill_budget(budget);
    eng
}

/// Tokens a request generates running completely alone, unchunked.
fn solo_tokens(req: &GenRequest) -> Vec<i32> {
    let mut eng = engine(1, 1);
    eng.serve_wave(vec![req.clone()]).unwrap().remove(0).tokens
}

#[test]
fn generation_invariant_across_budgets_and_admission_orders() {
    // prompt lengths straddle every budget: shorter than the chunk, one
    // token short of it, exactly on it, and far past it
    let shapes: [(u64, usize, usize); 5] =
        [(0, 2, 6), (1, 4, 5), (2, 15, 4), (3, 16, 3), (4, 9, 4)];
    let reqs: Vec<GenRequest> = shapes
        .iter()
        .map(|&(id, plen, max_new)| GenRequest {
            id,
            prompt: (0..plen).map(|i| ((id as usize * 7 + i * 3) % 47) as i32 + 1).collect(),
            max_new,
        })
        .collect();
    let want: Vec<Vec<i32>> = reqs.iter().map(solo_tokens).collect();
    // two admission orders: arrival order and reversed (the scheduler
    // re-ranks internally; the contract is per-request bit-identity)
    let orders: [Vec<usize>; 2] = [vec![0, 1, 2, 3, 4], vec![4, 3, 2, 1, 0]];
    for budget in BUDGETS {
        for order in &orders {
            let mut eng = engine(budget, 2);
            let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
            sched.set_prefill_budget(budget);
            for &i in order {
                sched.enqueue(reqs[i].clone());
            }
            let resps = eng.serve_continuous(&mut sched).unwrap();
            assert_eq!(resps.len(), reqs.len());
            for (req, want) in reqs.iter().zip(&want) {
                let got = &resps.iter().find(|r| r.id == req.id).unwrap().tokens;
                assert_eq!(
                    got, want,
                    "request {} diverged (budget {budget}, order {order:?})",
                    req.id
                );
            }
        }
    }
}

#[test]
fn packed_kv_bits_invariant_across_budgets() {
    // run the same request under every budget and freeze each run at the
    // same cache fill (prompt fully prefilled + 2 generated rows): the
    // packed K and V streams of every layer must be byte-identical —
    // chunked bulk appends may not change a single stored bit
    let prompt: Vec<i32> = (0..17).map(|i| (i * 5 % 43) as i32 + 1).collect();
    let fill_at = prompt.len() + 2;
    let req = GenRequest { id: 7, prompt, max_new: 8 };
    let snapshot = |budget: usize| {
        let mut eng = engine(budget, 1);
        let mut sched = Scheduler::new(1, Scheduler::DEFAULT_PROMOTE_AFTER);
        sched.set_prefill_budget(budget);
        sched.enqueue(req.clone());
        loop {
            let done = eng.step_continuous(&mut sched).unwrap();
            assert!(done.is_empty(), "request finished before the snapshot fill");
            let slot = sched.slots()[0].as_ref().expect("slot admitted");
            let kv = slot.kv().expect("quantized mode");
            assert!(kv.fill() <= fill_at, "stepped past the snapshot fill");
            if kv.fill() == fill_at {
                // clone the packed streams of every layer (K then V)
                return kv
                    .caches()
                    .iter()
                    .flat_map(|c| {
                        let (k, v) = c.stores();
                        [k.clone(), v.clone()]
                    })
                    .collect::<Vec<_>>();
            }
        }
    };
    let want = snapshot(1);
    for budget in &BUDGETS[1..] {
        assert_eq!(snapshot(*budget), want, "packed KV bits diverged at budget {budget}");
    }
}

#[test]
fn bulk_append_rows_property_random_splits() {
    // KvCache::append_rows over arbitrary chunk partitions must store the
    // exact bytes of the per-row path, for dims that split blocks mid-row
    // and across format families
    check("append_rows random splits", 64, |rng: &mut Rng| {
        let dim = 1 + rng.below(70); // covers < block, == block, tails
        let cfg = match rng.below(3) {
            0 => NxConfig::bfp(4),
            1 => NxConfig::mxfp(5),
            _ => NxConfig::nxfp(4),
        }
        .with_block_size(16);
        let n = 1 + rng.below(10);
        let rows: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
        let vows: Vec<f32> = (0..n * dim).map(|_| rng.normal_f32(0.0, 1.5)).collect();
        let mut single = KvCache::new(dim, cfg.clone());
        for r in 0..n {
            single.append(&rows[r * dim..(r + 1) * dim], &vows[r * dim..(r + 1) * dim]);
        }
        let mut bulk = KvCache::new(dim, cfg);
        let mut at = 0usize;
        while at < n {
            let take = 1 + rng.below(n - at);
            bulk.append_rows(
                &rows[at * dim..(at + take) * dim],
                &vows[at * dim..(at + take) * dim],
                take,
            );
            at += take;
        }
        if bulk.len != n {
            return Err(format!("bulk len {} != {n}", bulk.len));
        }
        if bulk.stores() != single.stores() {
            return Err(format!("stores diverged (dim {dim}, {n} rows)"));
        }
        // decoded lanes bit-identical too
        let (kb, vb) = bulk.dequantize(n);
        let (ks, vs) = single.dequantize(n);
        if kb.data != ks.data || vb.data != vs.data {
            return Err("dequantized rows diverged".into());
        }
        Ok(())
    });
}

#[test]
fn wave_mode_honors_the_same_invariance() {
    // the budget knob exists in both sched modes; wave mode must be just
    // as unobservable
    let reqs = vec![
        GenRequest { id: 0, prompt: vec![9, 3, 17, 5, 21, 2, 8, 11, 4, 6], max_new: 5 },
        GenRequest { id: 1, prompt: vec![30, 1], max_new: 7 },
    ];
    let want: Vec<Vec<i32>> = reqs.iter().map(solo_tokens).collect();
    for budget in BUDGETS {
        let mut eng = engine(budget, 2);
        let resps = eng.serve_wave(reqs.clone()).unwrap();
        for (req, want) in reqs.iter().zip(&want) {
            let got = &resps.iter().find(|r| r.id == req.id).unwrap().tokens;
            assert_eq!(got, want, "wave request {} diverged at budget {budget}", req.id);
        }
    }
}
