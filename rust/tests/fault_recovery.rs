//! Fault-domain integration tests: seeded faults injected under the
//! continuous scheduler must be *contained* — retried in place, or the
//! affected slots retired and requeued — and the surviving generations
//! must be bit-identical to a fault-free run. Everything here runs on the
//! deterministic [`SynthBackend`]; only the threaded-server tests at the
//! bottom need `make artifacts`.

use std::time::Duration;

use nxfp::coordinator::fault::{FaultPlan, FaultStats};
use nxfp::coordinator::scheduler::Scheduler;
use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::{DecodeEngine, FinishReason, GenRequest, GenResponse, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::{Checkpoint, LmSpec};

/// Deterministic request mix: half share a 4-token prefix (so the prefix
/// cache has something to adopt when it's on), lengths vary per lane.
fn requests() -> Vec<GenRequest> {
    (0..6u64)
        .map(|i| GenRequest {
            id: i,
            prompt: if i % 2 == 0 {
                vec![1, 2, 3, 4, 5 + i as i32]
            } else {
                vec![7 + i as i32, 9]
            },
            max_new: 3 + (i as usize % 3),
        })
        .collect()
}

/// Serve [`requests`] through a 2-lane continuous engine, returning the
/// responses sorted by id plus the engine (for its metrics), the
/// scheduler (for its pool-retaining prefix cache), and the injector's
/// ground-truth counters when a plan was given.
fn serve(
    policy: &QuantPolicy,
    prefix_cache: bool,
    plan: Option<FaultPlan>,
    cfg_engine: impl FnOnce(&mut DecodeEngine),
    cfg_sched: impl FnOnce(&mut Scheduler),
) -> (Vec<GenResponse>, DecodeEngine, Scheduler, Option<FaultStats>) {
    let spec = LmSpec::tiny();
    let mut eng =
        DecodeEngine::with_backend(spec.clone(), Box::new(SynthBackend::new(&spec)), policy, 2);
    eng.set_prefill_budget(4);
    cfg_engine(&mut eng);
    let stats = plan.map(|p| eng.inject_faults(&p));
    let mut sched = Scheduler::new(2, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_prefill_budget(eng.prefill_budget());
    if prefix_cache {
        sched.enable_prefix_cache(eng.page_pool(), Scheduler::DEFAULT_PREFIX_ENTRIES);
    }
    cfg_sched(&mut sched);
    for r in requests() {
        assert!(sched.enqueue(r).is_none(), "queue under its cap must accept");
    }
    let mut out = eng.serve_continuous(&mut sched).expect("faults must be contained, not Err");
    out.sort_by_key(|r| r.id);
    (out, eng, sched, stats.map(|s| *s.borrow()))
}

fn assert_bit_identical(clean: &[GenResponse], faulted: &[GenResponse]) {
    assert_eq!(clean.len(), faulted.len());
    for (c, f) in clean.iter().zip(faulted) {
        assert_eq!(c.id, f.id);
        assert_eq!(f.reason, FinishReason::Completed, "request {} did not complete", f.id);
        assert_eq!(c.tokens, f.tokens, "request {} diverged under faults", c.id);
        assert_eq!(c.generated, f.generated);
    }
}

#[test]
fn transient_step_faults_retry_to_bit_identical_generations() {
    // in-place retry: a failed call mutates nothing, so the re-issued
    // step sees identical slabs and the generations cannot drift. Every
    // seed must be bit-identical; at least one of the scanned seeds must
    // actually fire (the fault schedule is deterministic per seed, so
    // scanning keeps the test robust without weakening any assertion).
    let q = QuantPolicy::uniform(NxConfig::nxfp(4));
    for (policy, prefix) in [(&q, false), (&q, true), (&QuantPolicy::fp16(), false)] {
        let (clean, ..) = serve(policy, prefix, None, |_| {}, |_| {});
        let mut fired = false;
        for seed in 0..8 {
            let plan = FaultPlan::transient_steps(seed, 0.25);
            let (faulted, eng, _, stats) = serve(
                policy,
                prefix,
                Some(plan),
                |e| e.set_retry_policy(6, Duration::ZERO),
                |_| {},
            );
            let stats = stats.unwrap();
            // engine counters exactly match the injector's ground truth
            assert_eq!(eng.serving.step_faults, stats.step_errors);
            assert_eq!(eng.serving.retries, stats.step_errors);
            assert_eq!(eng.serving.backend_failed, 0, "rate 0.25 cannot beat 6 retries");
            assert_eq!(eng.serving.requeued, 0);
            assert_bit_identical(&clean, &faulted);
            if stats.step_errors > 0 {
                fired = true;
                break;
            }
        }
        assert!(fired, "no scanned seed fired (prefix={prefix})");
    }
}

#[test]
fn requeue_replays_prefill_bit_identically() {
    // retry budget 0: every transient fault kills the occupied slots and
    // requeues them at the queue front; re-admission replays prefill
    // (prefix-adopted or not) and the tokens still match the clean run
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    for prefix in [false, true] {
        let (clean, ..) = serve(&policy, prefix, None, |_| {}, |_| {});
        let mut fired = false;
        for seed in 0..8 {
            let plan = FaultPlan::transient_steps(seed, 0.15);
            let (faulted, eng, _, stats) = serve(
                &policy,
                prefix,
                Some(plan),
                |e| {
                    e.set_retry_policy(0, Duration::ZERO);
                    e.set_requeue_max(10_000);
                },
                |_| {},
            );
            let stats = stats.unwrap();
            assert_eq!(eng.serving.step_faults, stats.step_errors);
            assert_eq!(eng.serving.backend_failed, 0);
            assert_bit_identical(&clean, &faulted);
            if stats.step_errors > 0 {
                assert!(eng.serving.requeued > 0, "retry budget 0 must route through requeue");
                fired = true;
                break;
            }
        }
        assert!(fired, "no scanned seed fired (prefix={prefix})");
    }
}

#[test]
fn chunk_faults_recover_on_both_paths() {
    // budget 4 uses the native prefill_chunk path, which has its own
    // fault gate; exercise in-place retry and the requeue fallback
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let (clean, ..) = serve(&policy, false, None, |_| {}, |_| {});
    let mut fired = false;
    for seed in 0..12 {
        let plan = FaultPlan { seed, chunk_error_rate: 0.4, ..FaultPlan::default() };
        let (retried, eng, _, stats) = serve(
            &policy,
            false,
            Some(plan),
            |e| e.set_retry_policy(8, Duration::ZERO),
            |_| {},
        );
        let stats = stats.unwrap();
        assert_eq!(eng.serving.chunk_faults, stats.chunk_errors);
        assert_eq!(eng.serving.backend_failed, 0);
        assert_bit_identical(&clean, &retried);
        if stats.chunk_errors > 0 {
            fired = true;
            break;
        }
    }
    assert!(fired, "no scanned seed fired a chunk error (retry path)");
    fired = false;
    for seed in 0..12 {
        let plan = FaultPlan { seed, chunk_error_rate: 0.4, ..FaultPlan::default() };
        let (requeued, eng, _, stats) = serve(
            &policy,
            false,
            Some(plan),
            |e| {
                e.set_retry_policy(0, Duration::ZERO);
                e.set_requeue_max(10_000);
            },
            |_| {},
        );
        assert_eq!(eng.serving.backend_failed, 0);
        assert_bit_identical(&clean, &requeued);
        if stats.unwrap().chunk_errors > 0 {
            assert!(eng.serving.requeued > 0);
            fired = true;
            break;
        }
    }
    assert!(fired, "no scanned seed fired a chunk error (requeue path)");
}

#[test]
fn nan_logits_never_reach_sampling() {
    // poisoned logits are caught before greedy argmax (whose partial_cmp
    // would panic on NaN); the re-run recomputes clean lanes identically
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let (clean, ..) = serve(&policy, false, None, |_| {}, |_| {});
    let mut fired = false;
    for seed in 0..8 {
        let plan = FaultPlan { seed, nan_rate: 0.2, ..FaultPlan::default() };
        let (faulted, eng, _, stats) = serve(
            &policy,
            false,
            Some(plan),
            |e| e.set_retry_policy(6, Duration::ZERO),
            |_| {},
        );
        let stats = stats.unwrap();
        assert_eq!(eng.serving.nan_faults, stats.nan_steps);
        assert_eq!(eng.serving.backend_failed, 0);
        assert_bit_identical(&clean, &faulted);
        if stats.nan_steps > 0 {
            fired = true;
            break;
        }
    }
    assert!(fired, "no scanned seed poisoned a step");
}

#[test]
fn fatal_fault_fails_only_the_affected_slots() {
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let plan = FaultPlan { seed: 1, fatal_at_step: Some(4), ..FaultPlan::default() };
    let (resps, eng, _, stats) = serve(&policy, false, Some(plan), |_| {}, |_| {});
    assert_eq!(stats.unwrap().fatal_errors, 1);
    // every request is answered: the slots live at the fatal call fail,
    // the rest of the queue keeps serving on the same engine
    assert_eq!(resps.len(), requests().len());
    let failed = resps.iter().filter(|r| r.reason == FinishReason::BackendError).count();
    let completed = resps.iter().filter(|r| r.reason == FinishReason::Completed).count();
    assert!(failed >= 1, "the fatal step must fail someone");
    assert!(completed >= 1, "the engine must keep serving after a fatal fault");
    assert_eq!(failed + completed, resps.len());
    assert_eq!(eng.serving.backend_failed, failed as u64);
}

#[test]
fn page_pool_drains_to_zero_after_fault_churn() {
    // every request dies: first decode step always faults, one requeue
    // allowed, so each request holds pages mid-flight twice and then
    // fails — nothing may leak into the pool
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let plan = FaultPlan::transient_steps(2, 1.0);
    let (resps, eng, mut sched, _) = serve(
        &policy,
        true,
        Some(plan),
        |e| {
            e.set_retry_policy(0, Duration::ZERO);
            e.set_requeue_max(1);
        },
        |_| {},
    );
    assert_eq!(resps.len(), requests().len());
    assert!(resps.iter().all(|r| r.reason == FinishReason::BackendError));
    assert_eq!(eng.serving.backend_failed, requests().len() as u64);
    // prefix registrations are the only legitimate page retainers left
    sched.clear_prefix_cache();
    assert_eq!(eng.page_pool().borrow().live_pages(), 0, "fault churn leaked pages");
}

#[test]
fn wall_deadline_expires_requests_instead_of_losing_them() {
    // a zero deadline is already past at admission: every request is
    // answered Deadline with its prompt echoed and nothing generated
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let (resps, eng, _, _) =
        serve(&policy, false, None, |e| e.set_deadline(Some(Duration::ZERO)), |_| {});
    assert_eq!(resps.len(), requests().len());
    for r in &resps {
        assert_eq!(r.reason, FinishReason::Deadline);
        assert_eq!(r.generated, 0);
    }
    assert_eq!(eng.serving.deadline_expired, requests().len() as u64);
}

#[test]
fn queue_steps_deadline_expires_only_the_stale_tail() {
    // two lanes, six requests, zero tolerated queue steps: the head of
    // the queue is admitted fresh, the tail expires while waiting
    let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
    let (resps, eng, _, _) =
        serve(&policy, false, None, |_| {}, |s| s.set_max_queue_steps(Some(0)));
    assert_eq!(resps.len(), requests().len());
    let expired = resps.iter().filter(|r| r.reason == FinishReason::Deadline).count();
    let completed = resps.iter().filter(|r| r.reason == FinishReason::Completed).count();
    assert_eq!(expired + completed, resps.len());
    assert!(completed >= 2, "lane-count head of the queue admits fresh");
    assert!(expired >= 1, "the waiting tail must expire");
    assert_eq!(eng.serving.deadline_expired, expired as u64);
}

// ---- threaded-server tests (need `make artifacts`) ----------------------

fn artifacts_present() -> bool {
    std::path::Path::new("artifacts/decode_step.hlo.txt").exists()
}

#[test]
fn drain_completes_in_flight_then_reports() {
    if !artifacts_present() {
        eprintln!("skipping drain_completes_in_flight_then_reports: artifacts missing");
        return;
    }
    let spec = LmSpec::small();
    let ck = Checkpoint::init(&spec, 13);
    let mut server = ServerHandle::spawn(
        std::path::PathBuf::from("artifacts"),
        spec,
        ck,
        QuantPolicy::uniform(NxConfig::nxfp(4)),
        ServeOpts { max_batch: 4, prefill_budget: 16, ..Default::default() },
    );
    let n = 6u64;
    for i in 0..n {
        assert!(server.submit(GenRequest { id: i, prompt: vec![0, 3 + i as i32], max_new: 4 }));
    }
    // drain: everything submitted before the drain message (same sender,
    // FIFO) still completes; the handle then refuses new work
    let report = server.drain().unwrap();
    assert_eq!(report.metrics.requests, n);
    assert_eq!(report.serving.shed, 0);
    let mut done = 0;
    while let Some(resp) = server.recv_timeout(Duration::from_secs(5)) {
        assert_eq!(resp.reason, FinishReason::Completed);
        done += 1;
    }
    assert_eq!(done, n);
    assert!(!server.submit(GenRequest { id: 99, prompt: vec![0, 1], max_new: 1 }));
    assert!(server.shutdown().is_err(), "drain already joined the worker");
}

#[test]
fn dead_worker_is_an_error_not_a_panic() {
    // bogus artifacts dir: the worker dies during engine construction.
    // The handle must degrade to refused submits and an Err report —
    // never a panic (the old expect("already joined")).
    let spec = LmSpec::small();
    let ck = Checkpoint::init(&spec, 14);
    let mut server = ServerHandle::spawn(
        std::path::PathBuf::from("definitely/not/artifacts"),
        spec,
        ck,
        QuantPolicy::fp16(),
        ServeOpts::default(),
    );
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        if !server.submit(GenRequest { id: 0, prompt: vec![0, 1], max_new: 1 }) {
            break; // worker gone: sends are refused, not silently dropped
        }
        assert!(std::time::Instant::now() < deadline, "worker never died");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(server.shutdown().is_err(), "dead worker must surface its error");
    assert!(server.drain().is_err(), "second join is a well-defined error");
}
