//! Bit-exact cross-check between the Rust quantizer and the Python NumPy
//! oracle: `make artifacts` dumps `artifacts/golden_fakequant.txt` from
//! `python/compile/kernels/ref.py`; this test replays every vector through
//! `nxfp::quant::fake_quant` and requires identical f32 bit patterns.
//!
//! This is the contract that lets the Rust-side weight quantization and the
//! in-graph (Pallas) KV quantization be treated as the same number system.

use nxfp::formats::NxConfig;
use nxfp::quant::fake_quant;
use std::path::PathBuf;

fn cfg_by_id(id: &str) -> Option<NxConfig> {
    Some(match id {
        "bfp4" => NxConfig::bfp(4),
        "bfp5" => NxConfig::bfp(5),
        "bfp6" => NxConfig::bfp(6),
        "mxfp4" => NxConfig::mxfp(4),
        "mxfp5" => NxConfig::mxfp(5),
        "mxfp6" => NxConfig::mxfp(6),
        "mxfp8" => NxConfig::mxfp(8),
        "nxfp4" => NxConfig::nxfp(4),
        "nxfp5" => NxConfig::nxfp(5),
        "nxfp6" => NxConfig::nxfp(6),
        "nxfp4_nm" => NxConfig::nxfp_nm(4),
        "nxfp4_nm_am" => NxConfig::nxfp_nm_am(4),
        _ => return None,
    })
}

fn parse_hex_f32(s: &str) -> Vec<f32> {
    assert!(s.len() % 8 == 0, "hex length {} not a multiple of 8", s.len());
    (0..s.len() / 8)
        .map(|i| {
            let word = u32::from_str_radix(&s[i * 8..(i + 1) * 8], 16).unwrap();
            // numpy little-endian u32 view prints the native u32 value
            f32::from_bits(word)
        })
        .collect()
}

fn golden_path() -> PathBuf {
    let base = std::env::var("NXFP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    PathBuf::from(base).join("golden_fakequant.txt")
}

#[test]
fn rust_matches_python_oracle_bit_for_bit() {
    let path = golden_path();
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!(
            "skipping rust_matches_python_oracle_bit_for_bit: golden vectors \
             missing at {path:?} (run `make artifacts` or set NXFP_ARTIFACTS)"
        );
        return;
    };
    let mut n_vec = 0usize;
    let mut per_cfg: std::collections::BTreeMap<String, usize> = Default::default();
    for (lineno, line) in text.lines().enumerate() {
        let mut parts = line.split_whitespace();
        let (Some(id), Some(k), Some(ih), Some(oh)) =
            (parts.next(), parts.next(), parts.next(), parts.next())
        else {
            continue;
        };
        let Some(cfg) = cfg_by_id(id) else {
            panic!("line {lineno}: unknown config id {id}");
        };
        let k: usize = k.parse().unwrap();
        let cfg = cfg.with_block_size(k);
        let input = parse_hex_f32(ih);
        let want = parse_hex_f32(oh);
        assert_eq!(input.len(), k, "line {lineno}");
        let got = fake_quant(&input, &cfg);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "line {lineno} ({id}, k={k}) elem {i}: rust {g} vs oracle {w} \
                 (input {})",
                input[i]
            );
        }
        n_vec += 1;
        *per_cfg.entry(id.to_string()).or_default() += 1;
    }
    assert!(n_vec >= 500, "only {n_vec} golden vectors checked");
    // every config family must be represented
    for fam in ["bfp4", "mxfp4", "nxfp4", "nxfp5", "nxfp6", "mxfp8"] {
        assert!(per_cfg.contains_key(fam), "no vectors for {fam}");
    }
}
