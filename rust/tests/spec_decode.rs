//! Precision-speculative decoding integration tests: the nxfp draft lane
//! proposes, the high-precision verifier lane judges, and the served
//! output must be **bit-identical** to the verifier serving alone — for
//! every draft depth, under rejection-heavy drafts, under injected
//! faults, and composed with prefix sharing. Everything runs on the
//! deterministic [`SynthBackend`]; no artifacts needed.

use std::time::Duration;

use nxfp::coordinator::fault::{FaultPlan, FaultStats};
use nxfp::coordinator::scheduler::{SchedMode, Scheduler};
use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::{DecodeEngine, FinishReason, GenRequest, GenResponse, SynthBackend};
use nxfp::formats::QuantPolicy;
use nxfp::models::LmSpec;
use nxfp::obs::{check_trace, read_jsonl, TraceSink, TraceSummary, DEFAULT_TRACE_CAP};
use nxfp::spec::{SpecEngine, SpecPolicy};

/// Deterministic request mix on the tiny spec (seq_len 16): varied prompt
/// lengths, varied budgets, and one context-capped request (`max_new` far
/// past the window) so the bonus-token clamp at the budget edge fires.
fn requests() -> Vec<GenRequest> {
    (0..6u64)
        .map(|i| GenRequest {
            id: i,
            prompt: if i % 2 == 0 {
                vec![1, 2, 3, 4, 5 + i as i32]
            } else {
                vec![7 + i as i32, 9]
            },
            max_new: if i == 5 { 64 } else { 3 + (i as usize % 3) },
        })
        .collect()
}

/// Verifier-alone reference: a plain engine serving `reqs` at `policy`.
fn plain_serve(policy: &str, lanes: usize, reqs: &[GenRequest]) -> Vec<GenResponse> {
    let spec = LmSpec::tiny();
    let mut eng = DecodeEngine::with_backend(
        spec,
        Box::new(SynthBackend::new(&spec)),
        &QuantPolicy::parse(policy).unwrap(),
        lanes,
    );
    eng.set_prefill_budget(4);
    let mut sched = Scheduler::new(lanes, Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_prefill_budget(eng.prefill_budget());
    for r in reqs {
        assert!(sched.enqueue(r.clone()).is_none());
    }
    let mut out = eng.serve_continuous(&mut sched).unwrap();
    out.sort_by_key(|r| r.id);
    out
}

/// Speculative run: `max_batch / 2` draft/verifier pairs serving `reqs`,
/// returning the sorted responses, the unwrapped engine (counters), and
/// the fault injector's ground truth when a plan was given.
#[allow(clippy::too_many_arguments)]
fn spec_serve(
    draft: &str,
    verify: &str,
    k: usize,
    max_batch: usize,
    reqs: &[GenRequest],
    plan: Option<FaultPlan>,
    prefix_cache: bool,
    cfg_engine: impl FnOnce(&mut DecodeEngine),
) -> (Vec<GenResponse>, DecodeEngine, Option<FaultStats>) {
    let spec = LmSpec::tiny();
    let mut eng = DecodeEngine::with_backend(
        spec,
        Box::new(SynthBackend::new(&spec)),
        &QuantPolicy::parse(draft).unwrap(),
        max_batch,
    );
    eng.set_prefill_budget(4);
    let stats = plan.map(|p| eng.inject_faults(&p));
    cfg_engine(&mut eng);
    let mut se = SpecEngine::new(eng, SpecPolicy::parse(k, verify).unwrap()).unwrap();
    let mut sched = se.scheduler(Scheduler::DEFAULT_PROMOTE_AFTER);
    sched.set_trace_sink(se.engine().trace_sink());
    sched.set_prefill_budget(se.engine().prefill_budget());
    if prefix_cache {
        sched.enable_prefix_cache(se.engine().page_pool(), Scheduler::DEFAULT_PREFIX_ENTRIES);
    }
    for r in reqs {
        assert!(sched.enqueue(r.clone()).is_none());
    }
    let mut out = se.serve_continuous(&mut sched).unwrap();
    out.sort_by_key(|r| r.id);
    (out, se.into_engine(), stats.map(|s| *s.borrow()))
}

fn assert_same_tokens(want: &[GenResponse], got: &[GenResponse]) {
    assert_eq!(want.len(), got.len());
    for (w, g) in want.iter().zip(got) {
        assert_eq!(w.id, g.id);
        assert_eq!(g.reason, FinishReason::Completed, "req {} did not complete", g.id);
        assert_eq!(w.tokens, g.tokens, "req {} diverged from verifier-alone decode", w.id);
        assert_eq!(w.generated, g.generated, "req {} token count diverged", w.id);
    }
}

/// accept + reject + bonus counters must telescope to every token the
/// engine reported generated, and every round records one histogram
/// sample — for any k and any draft/verifier pairing.
fn assert_counters_coherent(eng: &DecodeEngine) {
    let s = &eng.serving;
    assert!(s.spec_rounds > 0, "speculative serving must run verify rounds");
    assert_eq!(
        s.spec_accepted + s.spec_rejected + s.spec_forced,
        eng.metrics.tokens_generated,
        "accept/reject/bonus counters must telescope to tokens generated"
    );
    assert_eq!(s.spec_accept.count(), s.spec_rounds);
    let rate = s.spec_accept_rate();
    assert!((0.0..=1.0).contains(&rate), "accept rate {rate} out of range");
}

#[test]
fn speculative_output_is_bit_identical_for_every_k() {
    let want = plain_serve("fp16", 2, &requests());
    for k in [1usize, 2, 4, 8] {
        let (got, eng, _) =
            spec_serve("nxfp4", "fp16", k, 4, &requests(), None, false, |_| {});
        assert_same_tokens(&want, &got);
        assert_counters_coherent(&eng);
    }
}

#[test]
fn quantized_verifier_matches_nxfp6_alone_for_every_k() {
    // the verifier lane re-quantizes between tokens, so speculative
    // output must equal a *plain nxfp6* engine, not fp16
    let want = plain_serve("nxfp6", 2, &requests());
    for k in [1usize, 2, 4, 8] {
        let (got, eng, _) =
            spec_serve("nxfp4", "nxfp6", k, 4, &requests(), None, false, |_| {});
        assert_same_tokens(&want, &got);
        assert_counters_coherent(&eng);
    }
}

#[test]
fn lossy_drafts_roll_back_and_never_corrupt_output() {
    // coarser draft formats disagree with fp16 more often; whatever the
    // rejection rate, the committed output may never drift. At least one
    // scanned format must actually reject (a draft that never diverges
    // would leave the rollback path untested).
    let want = plain_serve("fp16", 2, &requests());
    let mut fired = false;
    for draft in ["bfp4", "mxfp4", "nxfp4"] {
        let (got, eng, _) = spec_serve(draft, "fp16", 4, 4, &requests(), None, false, |_| {});
        assert_same_tokens(&want, &got);
        assert_counters_coherent(&eng);
        let s = &eng.serving;
        // each reject rolls at most k - 1 provisional rows off the draft
        assert!(s.spec_rollback_rows <= s.spec_rejected * 3, "rollback rows out of bound");
        if s.spec_rejected > 0 {
            fired = true;
        }
    }
    assert!(fired, "no scanned draft format ever rejected");
}

#[test]
fn transient_faults_retry_to_bit_identical_output() {
    // step faults hit the draft micro-steps; chunk faults share a gate
    // with verify_chunk, so they hit the verifier too. In-place retry
    // mutates nothing — every seed must stay bit-identical, and at least
    // one scanned seed must fire.
    let want = plain_serve("fp16", 2, &requests());
    for (name, mk) in [
        ("step", (|seed| FaultPlan::transient_steps(seed, 0.2)) as fn(u64) -> FaultPlan),
        ("chunk", |seed| FaultPlan { seed, chunk_error_rate: 0.3, ..FaultPlan::default() }),
    ] {
        let mut fired = false;
        for seed in 0..8 {
            let (got, eng, stats) =
                spec_serve("nxfp4", "fp16", 3, 4, &requests(), Some(mk(seed)), false, |e| {
                    e.set_retry_policy(8, Duration::ZERO);
                });
            assert_same_tokens(&want, &got);
            assert_counters_coherent(&eng);
            assert_eq!(eng.serving.backend_failed, 0, "rate cannot beat 8 retries");
            let st = stats.unwrap();
            if st.step_errors + st.chunk_errors > 0 {
                fired = true;
                break;
            }
        }
        assert!(fired, "no scanned seed fired a {name} fault");
    }
}

#[test]
fn verify_faults_requeue_and_replay_bit_identically() {
    // retry budget 0: a verify fault retires the whole pair and requeues
    // the request at the queue front; replay re-drafts and re-verifies
    // from the prompt and must land on the same tokens
    let want = plain_serve("fp16", 2, &requests());
    let mut fired = false;
    for seed in 0..12 {
        let plan = FaultPlan { seed, chunk_error_rate: 0.25, ..FaultPlan::default() };
        let (got, eng, stats) =
            spec_serve("nxfp4", "fp16", 3, 4, &requests(), Some(plan), false, |e| {
                e.set_retry_policy(0, Duration::ZERO);
                e.set_requeue_max(10_000);
            });
        assert_same_tokens(&want, &got);
        assert_eq!(eng.serving.backend_failed, 0);
        if stats.unwrap().chunk_errors > 0 {
            assert!(eng.serving.requeued > 0, "retry budget 0 must route through requeue");
            fired = true;
            break;
        }
    }
    assert!(fired, "no scanned seed fired a verify fault");
}

#[test]
fn prefix_adoption_composes_with_speculation() {
    // one pair (serial admission): the donor registers its prompt pages,
    // the adopter picks up the 12 shared rows, and both still match the
    // verifier-alone reference exactly
    let shared: Vec<i32> = (1..=12).collect();
    let mut pa = shared.clone();
    pa.extend([45, 3]);
    let mut pb = shared;
    pb.extend([46, 44]);
    let reqs = vec![
        GenRequest { id: 0, prompt: pa, max_new: 2 },
        GenRequest { id: 1, prompt: pb, max_new: 2 },
    ];
    let want = plain_serve("fp16", 1, &reqs);
    let (got, eng, _) = spec_serve("nxfp4", "fp16", 3, 2, &reqs, None, true, |e| {
        e.set_kv_page_rows(4);
    });
    assert_same_tokens(&want, &got);
    assert_eq!(eng.serving.prefix_hits, 1, "the adopter must reuse the donor's pages");
    assert_eq!(eng.serving.prefix_rows.max(), 12.0);
}

#[test]
fn trace_checker_accepts_a_speculative_trace() {
    // draft/verify/rollback events must satisfy the trace state machine
    // and reconcile with the counter summary under `nxfp trace check`
    let (_, eng, _) = spec_serve("bfp4", "fp16", 4, 4, &requests(), None, false, |e| {
        e.set_trace_sink(TraceSink::enabled(DEFAULT_TRACE_CAP));
    });
    let path = std::env::temp_dir().join(format!("nxfp_spec_trace_{}.jsonl", std::process::id()));
    let summary = TraceSummary::from_serving(&eng.serving);
    eng.trace_sink().write_jsonl(&path, &summary).unwrap();
    let trace = read_jsonl(&path).unwrap();
    let violations = check_trace(&trace);
    assert!(violations.is_empty(), "trace violations: {violations:?}");
    assert!(eng.serving.spec_rounds > 0);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn server_handle_serves_speculatively_end_to_end() {
    // the threaded front-end: --spec-k through ServeOpts, synth backend
    let want = plain_serve("fp16", 2, &requests());
    let opts = ServeOpts {
        max_batch: 4,
        prefill_budget: 4,
        prefix_cache: false,
        spec_k: 3,
        spec_verify: "fp16".to_string(),
        ..Default::default()
    };
    let mut server = ServerHandle::spawn_synth(
        LmSpec::tiny(),
        QuantPolicy::parse("nxfp4").unwrap(),
        opts,
    );
    for r in requests() {
        assert!(server.submit(r));
    }
    let mut got: Vec<GenResponse> = (0..requests().len())
        .map(|_| server.recv().expect("worker died mid-serve"))
        .collect();
    got.sort_by_key(|r| r.id);
    let report = server.shutdown().unwrap();
    assert_same_tokens(&want, &got);
    assert!(report.serving.spec_rounds > 0);
    assert!(report.serving.spec_accept_rate() > 0.0, "accept rate must surface in the report");
}

#[test]
fn wave_mode_refuses_speculation() {
    // wave scheduling has no between-step seam to verify in: the worker
    // must fail loudly at startup, never silently serve unverified
    let opts = ServeOpts {
        max_batch: 4,
        mode: SchedMode::Wave,
        spec_k: 2,
        ..Default::default()
    };
    let mut server =
        ServerHandle::spawn_synth(LmSpec::tiny(), QuantPolicy::parse("nxfp4").unwrap(), opts);
    assert!(server.shutdown().is_err(), "wave + spec must be a startup error");
}
