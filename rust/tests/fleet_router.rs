//! Fleet-tier invariants on the deterministic `SynthBackend` (no PJRT
//! runtime or `make artifacts` needed):
//!
//! 1. **Zero loss through lifecycle events** — a 4-replica fleet serving
//!    bursty shared-prefix traffic answers every accepted request exactly
//!    once through one abrupt `kill_replica` (unserved work replayed
//!    from the prompt onto survivors) and one graceful `drain_replica`.
//! 2. **Bit-identity** — every fleet response equals the request's
//!    single-engine solo run: replicas share nothing, replay is
//!    from-prompt, and per-slot purity makes placement invisible.
//! 3. **Exact rollup** — `FleetReport` counters equal the sum of the
//!    per-replica counters, and histogram rollups merge without
//!    geometry errors on a homogeneous fleet.
//! 4. **Snapshot cadence** — `metrics_snapshot_steps` produces periodic
//!    `--metrics-out` rewrites *before* shutdown in both scheduling
//!    modes, and suppresses them when the interval is never reached.

use std::time::Duration;

use nxfp::coordinator::router::FleetHandle;
use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::{DecodeEngine, FinishReason, GenRequest, GenResponse, SynthBackend};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::LmSpec;

fn spec() -> LmSpec {
    LmSpec { vocab: 48, d_model: 24, n_layers: 2, n_heads: 2, d_ff: 64, seq_len: 24 }
}

fn kv() -> QuantPolicy {
    QuantPolicy::uniform(NxConfig::nxfp(4))
}

fn opts() -> ServeOpts {
    // 4-row pages: the 10-token shared system prompts span full pages, so
    // prefix reuse actually fires at this tiny spec (page geometry never
    // changes generations, only dedup granularity)
    ServeOpts { max_batch: 2, prefill_budget: 4, kv_page_rows: 4, ..Default::default() }
}

/// Tokens a request generates running completely alone (batch of 1).
fn solo_tokens(req: &GenRequest) -> Vec<i32> {
    let sp = spec();
    let mut eng =
        DecodeEngine::with_backend(sp, Box::new(SynthBackend::new(&sp)), &kv(), 1);
    let resps = eng.serve_wave(vec![req.clone()]).unwrap();
    resps.into_iter().next().unwrap().tokens
}

/// Bursty shared-prefix traffic: `n` requests cycling over four distinct
/// 10-token system prompts with short per-request suffixes.
fn shared_prefix_requests(n: usize) -> Vec<GenRequest> {
    let sys: Vec<Vec<i32>> = (0..4)
        .map(|s| (0..10).map(|t| ((s * 11 + t * 3) % 47) as i32).collect())
        .collect();
    (0..n)
        .map(|i| {
            let mut prompt = sys[i % 4].clone();
            prompt.push(((i * 7) % 43) as i32);
            prompt.push(((i * 13) % 41) as i32);
            GenRequest { id: i as u64, prompt, max_new: 3 + (i % 4) }
        })
        .collect()
}

fn recv_all(fleet: &mut FleetHandle, n: usize) -> Vec<GenResponse> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(
            fleet
                .recv_timeout(Duration::from_secs(300))
                .expect("fleet dropped a response"),
        );
    }
    out
}

#[test]
fn four_replica_fleet_survives_kill_and_drain_bit_identically() {
    let reqs = shared_prefix_requests(32);
    let mut fleet = FleetHandle::spawn(4, spec(), kv(), opts());
    // burst the whole workload in before receiving anything: routing
    // decisions depend only on submit order, so placement is
    // deterministic and the lifecycle events below race real work
    for r in &reqs {
        assert!(fleet.submit(r.clone()), "submit {} refused", r.id);
    }
    // abrupt kill mid-traffic: whatever replica 1 had accepted and not
    // answered is replayed from the prompt onto survivors
    let moved = fleet.kill_replica(1).unwrap();
    // graceful drain of another replica while traffic is still in flight
    fleet.drain_replica(2);
    let resps = recv_all(&mut fleet, reqs.len());
    let report = fleet.shutdown().unwrap();
    // lost_requests == 0: every id answered exactly once, and completed
    let mut seen = std::collections::BTreeSet::new();
    for r in &resps {
        assert!(seen.insert(r.id), "request {} answered twice", r.id);
        assert_eq!(r.reason, FinishReason::Completed, "request {} not completed", r.id);
    }
    assert_eq!(seen.len(), reqs.len(), "lost requests: {:?}", {
        let mut missing: Vec<u64> =
            reqs.iter().map(|r| r.id).filter(|id| !seen.contains(id)).collect();
        missing.sort_unstable();
        missing
    });
    // bit-identity: placement, kill replay, and drain redistribution are
    // all invisible in the tokens
    for req in &reqs {
        let got = &resps.iter().find(|r| r.id == req.id).unwrap().tokens;
        assert_eq!(got, &solo_tokens(req), "request {} diverged from solo", req.id);
    }
    // the kill'd replica reported; redispatch bookkeeping is consistent
    assert_eq!(report.replicas.len(), 4);
    assert!(report.redispatched >= moved as u64);
    // shared-prefix traffic actually exercised affinity + prefix reuse
    assert!(report.serving.prefix_hits > 0, "no prefix hits across the fleet");
    // exact rollup: counters equal the per-replica sums
    let sum = |f: fn(&nxfp::coordinator::metrics::ServingMetrics) -> u64| -> u64 {
        report.replicas.iter().map(|r| f(&r.serving)).sum()
    };
    assert_eq!(report.serving.admitted, sum(|s| s.admitted));
    assert_eq!(report.serving.promoted, sum(|s| s.promoted));
    assert_eq!(report.serving.rejected, sum(|s| s.rejected));
    assert_eq!(report.serving.prefix_hits, sum(|s| s.prefix_hits));
    assert_eq!(report.serving.prefix_misses, sum(|s| s.prefix_misses));
    assert_eq!(report.serving.requeued, sum(|s| s.requeued));
    assert_eq!(report.serving.backend_failed, sum(|s| s.backend_failed));
    assert_eq!(report.serving.shed, sum(|s| s.shed));
    assert_eq!(report.serving.deadline_expired, sum(|s| s.deadline_expired));
    assert_eq!(
        report.metrics.requests,
        report.replicas.iter().map(|r| r.metrics.requests).sum::<u64>()
    );
    assert_eq!(
        report.metrics.tokens_generated,
        report.replicas.iter().map(|r| r.metrics.tokens_generated).sum::<u64>()
    );
    assert_eq!(
        report.serving.latency.count(),
        report.replicas.iter().map(|r| r.serving.latency.count()).sum::<u64>()
    );
    // homogeneous fleet: the histogram rollup merged cleanly
    assert!(report.merge_errors.is_empty(), "{:?}", report.merge_errors);
}

#[test]
fn fleet_responses_are_reproducible_across_runs() {
    // same arrival order twice: the sorted (id, tokens) sets must match
    // exactly — dispatch determinism end to end, not just in the router
    let reqs = shared_prefix_requests(24);
    let run = || {
        let mut fleet = FleetHandle::spawn(3, spec(), kv(), opts());
        for r in &reqs {
            assert!(fleet.submit(r.clone()));
        }
        let mut got: Vec<(u64, Vec<i32>)> =
            recv_all(&mut fleet, reqs.len()).into_iter().map(|r| (r.id, r.tokens)).collect();
        fleet.shutdown().unwrap();
        got.sort();
        got
    };
    assert_eq!(run(), run());
}

#[test]
fn drain_replica_mid_traffic_redistributes_without_loss() {
    let reqs = shared_prefix_requests(16);
    let mut fleet = FleetHandle::spawn(2, spec(), kv(), opts());
    for r in &reqs[..8] {
        assert!(fleet.submit(r.clone()));
    }
    // drain replica 0 immediately: its backlog completes, racing
    // dispatches shed back and are replayed on replica 1
    fleet.drain_replica(0);
    for r in &reqs[8..] {
        assert!(fleet.submit(r.clone()), "submit {} refused during drain", r.id);
    }
    let resps = recv_all(&mut fleet, reqs.len());
    let report = fleet.shutdown().unwrap();
    let mut ids: Vec<u64> = resps.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..reqs.len() as u64).collect::<Vec<_>>());
    for r in &resps {
        assert_eq!(r.reason, FinishReason::Completed);
        assert_eq!(r.tokens, solo_tokens(&reqs[r.id as usize]));
    }
    // everything submitted after the drain landed on the survivor
    assert!(report.replicas[1].metrics.requests >= 8);
    assert_eq!(
        report.metrics.requests,
        report.replicas.iter().map(|r| r.metrics.requests).sum::<u64>()
    );
}

#[test]
fn kill_with_no_survivors_is_an_error_not_a_loss() {
    let mut fleet = FleetHandle::spawn(1, spec(), kv(), opts());
    let reqs = shared_prefix_requests(4);
    for r in &reqs {
        assert!(fleet.submit(r.clone()));
    }
    // killing the only replica: if it still held unserved work there is
    // no survivor to replay on, and that surfaces as an error — never as
    // silently missing responses
    match fleet.kill_replica(0) {
        Ok(_) => {
            // replica finished everything before the kill landed: all
            // responses are still deliverable
            let resps = recv_all(&mut fleet, reqs.len());
            assert_eq!(resps.len(), reqs.len());
        }
        Err(e) => assert!(
            e.to_string().contains("no surviving replica"),
            "unexpected error: {e:#}"
        ),
    }
}

/// Poll until `path` exists (bounded): periodic snapshots are written by
/// the worker thread, so the test only controls "eventually".
fn wait_for(path: &std::path::Path) -> bool {
    for _ in 0..2000 {
        if path.exists() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    false
}

#[test]
fn metrics_snapshots_fire_periodically_in_both_modes() {
    use nxfp::coordinator::scheduler::SchedMode;
    let dir = std::env::temp_dir().join(format!("nxfp-fleet-snap-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for (mode, name) in [(SchedMode::Continuous, "cont"), (SchedMode::Wave, "wave")] {
        let path = dir.join(format!("snap-{name}.json"));
        let mut o = opts();
        o.mode = mode;
        o.metrics_out = Some(path.clone());
        o.metrics_snapshot_steps = 2; // tiny interval: first wave/steps cross it
        let server = ServerHandle::spawn_synth(spec(), kv(), o);
        for r in shared_prefix_requests(12) {
            assert!(server.submit(r));
        }
        // the snapshot appears while the worker is still serving (no
        // drain/shutdown message has been sent yet) — that is the whole
        // point of the periodic cadence
        assert!(wait_for(&path), "{name}: no periodic snapshot before shutdown");
        let early = std::fs::read_to_string(&path).unwrap();
        assert!(early.starts_with('{'), "{name}: snapshot should be JSON");
        let mut server = server;
        for _ in 0..12 {
            server.recv_timeout(Duration::from_secs(300)).expect("response");
        }
        let report = server.shutdown().unwrap();
        assert_eq!(report.metrics.requests, 12);
        // shutdown rewrote the export with the final counters
        let final_text = std::fs::read_to_string(&path).unwrap();
        assert!(final_text.contains("\"requests\":12"), "{name}: {final_text}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn huge_snapshot_interval_suppresses_periodic_writes() {
    let dir = std::env::temp_dir().join(format!("nxfp-snap-off-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("only-at-shutdown.json");
    let mut o = opts();
    o.metrics_out = Some(path.clone());
    o.metrics_snapshot_steps = u64::MAX;
    let mut server = ServerHandle::spawn_synth(spec(), kv(), o);
    let reqs = shared_prefix_requests(6);
    for r in &reqs {
        assert!(server.submit(r.clone()));
    }
    for _ in 0..reqs.len() {
        server.recv_timeout(Duration::from_secs(300)).expect("response");
    }
    // all work answered, worker idle, nothing written yet
    assert!(!path.exists(), "snapshot written despite unreachable interval");
    server.shutdown().unwrap();
    assert!(path.exists(), "shutdown export missing");
    std::fs::remove_dir_all(&dir).ok();
}
