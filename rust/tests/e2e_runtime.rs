//! End-to-end integration over the PJRT runtime and the real (small-spec)
//! artifacts: load + compile every artifact, run a few train steps (loss
//! must drop), evaluate perplexity, score reasoning probes, and run the
//! decode engine with a quantized KV cache. Requires `make artifacts`.

use nxfp::coordinator::{DecodeEngine, GenRequest};
use nxfp::eval::{perplexity, quantize_checkpoint, reasoning_accuracy};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::corpus::Probe;
use nxfp::models::{Checkpoint, Corpus, GrammarSpec, LmSpec};
use nxfp::runtime::Runtime;
use nxfp::train::{TrainConfig, Trainer};

fn artifacts() -> String {
    std::env::var("NXFP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts()).join("train_step.hlo.txt").exists()
}

#[test]
fn train_eval_score_decode_compose() {
    if !have_artifacts() {
        eprintln!(
            "skipping train_eval_score_decode_compose: artifacts missing \
             (run `make artifacts` or set NXFP_ARTIFACTS to enable)"
        );
        return;
    }
    let spec = LmSpec::small();
    let corpus = Corpus::generate(GrammarSpec::default_for_vocab(spec.vocab), 60_000, 12_000, 7);
    let mut rt = Runtime::cpu(artifacts()).unwrap();

    // --- train a handful of steps: loss must be finite and decreasing-ish
    let cfg = TrainConfig { batch: 16, steps: 8, log_every: 1, seed: 5 };
    let init = Checkpoint::init(&spec, 5);
    let mut tr = Trainer::new(&mut rt, spec, &init, &cfg).unwrap();
    let mut losses = Vec::new();
    tr.train(&corpus, &cfg, |_, l| losses.push(l)).unwrap();
    assert_eq!(losses.len(), 8);
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(first.is_finite() && last.is_finite());
    assert!(
        last < first,
        "loss did not drop over 8 steps: {first} -> {last}"
    );
    // fresh init on a 512-vocab ~ uniform: loss near ln(512) = 6.24
    assert!((first - 6.24).abs() < 1.0, "initial loss {first} implausible");

    let ck = tr.checkpoint().unwrap();

    // --- eval: fp16 vs quantized weights (W4 must not beat FP16)
    let eval_step = rt.load("eval_step").unwrap();
    let p16 = perplexity(&eval_step, &ck, &corpus, spec.seq_len, 8).unwrap();
    assert!(p16.ppl() > 1.0 && p16.ppl() < 600.0, "ppl {}", p16.ppl());
    let q4 =
        quantize_checkpoint(&ck, &spec.quantizable(), &QuantPolicy::uniform(NxConfig::nxfp(4)));
    let p4 = perplexity(&eval_step, &q4, &corpus, spec.seq_len, 8).unwrap();
    assert!(p4.ppl() >= p16.ppl() * 0.99, "W4 ppl {} < FP16 {}", p4.ppl(), p16.ppl());

    // --- kv-quantized eval artifact composes
    let kvq = rt.load("eval_step_kvq_nxfp4").unwrap();
    let pkv = perplexity(&kvq, &ck, &corpus, spec.seq_len, 8).unwrap();
    assert!(pkv.ppl().is_finite());
    assert!(pkv.ppl() >= p16.ppl() * 0.98);

    // --- reasoning scorer runs and returns a probability
    let score_step = rt.load("score_step").unwrap();
    let probes = Probe::generate(&corpus.spec, 16, 3);
    let acc = reasoning_accuracy(&score_step, &ck, &probes, spec.seq_len, 8).unwrap();
    assert!((0.0..=1.0).contains(&acc));

    // --- decode engine with quantized KV serves requests
    let mut engine =
        DecodeEngine::new(&mut rt, spec, &ck, &QuantPolicy::uniform(NxConfig::nxfp(4)), 4)
            .unwrap();
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| GenRequest { id: i, prompt: vec![0, 5, 70], max_new: 6 })
        .collect();
    let resps = engine.serve_wave(reqs).unwrap();
    assert_eq!(resps.len(), 4);
    for r in &resps {
        assert_eq!(r.generated, 6);
        assert_eq!(r.tokens.len(), 3 + 6);
        assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < spec.vocab));
    }
    assert!(engine.metrics.kv_savings() > 0.5, "kv savings {}", engine.metrics.kv_savings());
}
