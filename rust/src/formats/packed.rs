//! Bit-true packed storage for quantized tensors (the paper's §6
//! "structural memory layout"). Three tightly packed streams per tensor:
//!
//! * `scales`  — one E8M0 byte per block (biased shared exponent),
//! * `meta`    — 3 bits per block (2-bit NanoMantissa + 1-bit format index),
//!   present only for NxFP configs,
//! * `payload` — `bits` per element, row-major.
//!
//! `footprint_bytes()` is exactly what a deployment would ship to DRAM, and
//! is what the Fig. 9 / Fig. 12 footprint axes report.

use super::{BlockCode, NxConfig};

/// Append-only bit writer (LSB-first within each byte).
pub struct BitWriter {
    buf: Vec<u8>,
    bitpos: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        BitWriter { buf: Vec::new(), bitpos: 0 }
    }

    #[inline]
    pub fn push(&mut self, value: u32, nbits: u32) {
        debug_assert!(nbits <= 32);
        debug_assert!(nbits == 32 || value < (1u32 << nbits));
        let mut v = value as u64;
        let mut n = nbits as usize;
        while n > 0 {
            let byte = self.bitpos / 8;
            let off = self.bitpos % 8;
            if byte == self.buf.len() {
                self.buf.push(0);
            }
            let take = (8 - off).min(n);
            self.buf[byte] |= ((v & ((1u64 << take) - 1)) as u8) << off;
            v >>= take;
            n -= take;
            self.bitpos += take;
        }
    }

    /// Append a run of equal-width codes. Semantically identical to
    /// pushing each code with [`BitWriter::push`], but byte-aligned 8-bit
    /// runs become a memcpy and byte-aligned 4-bit runs a nibble-pack walk
    /// — the [`super::BlockStore`] payload path.
    pub fn push_codes(&mut self, codes: &[u8], nbits: u32) {
        debug_assert!((1..=8).contains(&nbits));
        if nbits == 8 && self.bitpos & 7 == 0 {
            self.buf.extend_from_slice(codes);
            self.bitpos += codes.len() * 8;
            return;
        }
        if nbits == 4 && self.bitpos & 7 == 0 {
            for pair in codes.chunks(2) {
                debug_assert!(pair.iter().all(|&c| c < 16));
                // LSB-first: first code of the pair is the low nibble; an
                // odd tail leaves the high nibble zero with bitpos mid-byte,
                // exactly like push()
                self.buf.push(pair[0] | (pair.get(1).copied().unwrap_or(0) << 4));
            }
            self.bitpos += codes.len() * 4;
            return;
        }
        for &c in codes {
            self.push(c as u32, nbits);
        }
    }

    pub fn bits(&self) -> usize {
        self.bitpos
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for BitWriter {
    fn default() -> Self {
        Self::new()
    }
}

/// Sequential bit reader matching [`BitWriter`].
pub struct BitReader<'a> {
    buf: &'a [u8],
    bitpos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, bitpos: 0 }
    }

    #[inline]
    pub fn read(&mut self, nbits: u32) -> u32 {
        let mut out = 0u64;
        let mut got = 0usize;
        let mut n = nbits as usize;
        while n > 0 {
            let byte = self.bitpos / 8;
            let off = self.bitpos % 8;
            let take = (8 - off).min(n);
            let chunk = (self.buf[byte] >> off) as u64 & ((1u64 << take) - 1);
            out |= chunk << got;
            got += take;
            n -= take;
            self.bitpos += take;
        }
        out as u32
    }

    /// Position a reader at an absolute bit offset.
    pub fn seek(&mut self, bit: usize) {
        self.bitpos = bit;
    }
}

/// A quantized 2-D tensor in packed deployable form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub block_size: usize,
    pub bits: u8,
    pub has_meta: bool,
    /// E8M0 biased shared exponents, one per block.
    pub scales: Vec<u8>,
    /// 3-bit (nano, fmt) records, bit-packed; empty when `!has_meta`.
    pub meta: Vec<u8>,
    /// Element codes, `bits` each, bit-packed row-major.
    pub payload: Vec<u8>,
    /// blocks per row
    pub blocks_per_row: usize,
}

pub const E8M0_BIAS: i32 = 127;

impl PackedMatrix {
    /// Pack per-row block codes (as produced by `quant::quantize_matrix`).
    pub fn pack(rows: usize, cols: usize, cfg: &NxConfig, blocks: &[BlockCode]) -> Self {
        let k = cfg.block_size;
        let bpr = cols.div_ceil(k);
        assert_eq!(blocks.len(), rows * bpr, "block count mismatch");
        let has_meta = cfg.enable_nm || cfg.enable_am;
        let mut scales = Vec::with_capacity(blocks.len());
        let mut metaw = BitWriter::new();
        let mut payload = BitWriter::new();
        for b in blocks {
            scales.push((b.e_shared as i32 + E8M0_BIAS) as u8);
            if has_meta {
                metaw.push(b.nano as u32 | ((b.fmt_mx as u32) << 2), 3);
            }
            for &c in &b.codes {
                payload.push(c as u32, cfg.bits as u32);
            }
        }
        PackedMatrix {
            rows,
            cols,
            block_size: k,
            bits: cfg.bits,
            has_meta,
            scales,
            meta: metaw.into_bytes(),
            payload: payload.into_bytes(),
            blocks_per_row: bpr,
        }
    }

    /// Pack a flat [`super::BlockStore`] (the storage-native path): the
    /// payload is one straight walk of the store's contiguous codes buffer
    /// and the metadata streams are linear scans of the SoA arrays — no
    /// per-block `Vec` chasing. Produces byte-identical streams to
    /// [`PackedMatrix::pack`] on the equivalent legacy blocks.
    pub fn from_store(
        rows: usize,
        cols: usize,
        cfg: &NxConfig,
        store: &super::BlockStore,
    ) -> Self {
        assert_eq!(store.rows, rows, "store geometry mismatch");
        assert_eq!(store.row_len, cols, "store geometry mismatch");
        assert_eq!(store.block_size, cfg.block_size, "store geometry mismatch");
        let n_blocks = store.n_blocks();
        let has_meta = cfg.enable_nm || cfg.enable_am;
        let mut scales = Vec::with_capacity(n_blocks);
        let mut metaw = BitWriter::new();
        for flat in 0..n_blocks {
            scales.push((store.e_shared[flat] as i32 + E8M0_BIAS) as u8);
            if has_meta {
                metaw.push(store.nano[flat] as u32 | ((store.fmt_mx[flat] as u32) << 2), 3);
            }
        }
        // flat codes are already in payload element order (row-major,
        // blocks never straddle rows)
        let mut payload = BitWriter::new();
        payload.push_codes(&store.codes, cfg.bits as u32);
        PackedMatrix {
            rows,
            cols,
            block_size: cfg.block_size,
            bits: cfg.bits,
            has_meta,
            scales,
            meta: metaw.into_bytes(),
            payload: payload.into_bytes(),
            blocks_per_row: store.blocks_per_row(),
        }
    }

    /// Unpack back to per-block codes (inverse of [`PackedMatrix::pack`]).
    pub fn unpack(&self) -> Vec<BlockCode> {
        let mut out = Vec::with_capacity(self.rows * self.blocks_per_row);
        let mut metar = BitReader::new(&self.meta);
        let mut payr = BitReader::new(&self.payload);
        for r in 0..self.rows {
            for bi in 0..self.blocks_per_row {
                let flat = r * self.blocks_per_row + bi;
                let e = self.scales[flat] as i32 - E8M0_BIAS;
                let (nano, fmt_mx) = if self.has_meta {
                    let m = metar.read(3);
                    ((m & 0b11) as u8, m & 0b100 != 0)
                } else {
                    (0, true) // caller's config decides the base format
                };
                let start = bi * self.block_size;
                let len = self.block_size.min(self.cols - start);
                let mut codes = Vec::with_capacity(len);
                for _ in 0..len {
                    codes.push(payr.read(self.bits as u32) as u8);
                }
                out.push(BlockCode { e_shared: e as i16, nano, fmt_mx, codes });
            }
        }
        out
    }

    /// Exact stored size (what DRAM traffic/capacity accounting uses).
    pub fn footprint_bytes(&self) -> usize {
        self.scales.len() + self.meta.len() + self.payload.len()
    }

    /// Format-true footprint in bits (no byte rounding), matching
    /// `NxConfig::footprint_bits`.
    pub fn footprint_bits(&self) -> u64 {
        let n_blocks = (self.rows * self.blocks_per_row) as u64;
        let meta_bits = if self.has_meta { 3 } else { 0 };
        n_blocks * (8 + meta_bits)
            + (self.rows * self.cols) as u64 * self.bits as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;
    use crate::quant::quantize_matrix;
    use crate::tensor::Tensor2;
    use crate::util::rng::Rng;

    #[test]
    fn bitwriter_reader_round_trip() {
        let mut w = BitWriter::new();
        let vals = [(5u32, 4u32), (0, 1), (1, 1), (255, 8), (6, 3), (1023, 10)];
        for &(v, n) in &vals {
            w.push(v, n);
        }
        let total: u32 = vals.iter().map(|&(_, n)| n).sum();
        assert_eq!(w.bits(), total as usize);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &vals {
            assert_eq!(r.read(n), v);
        }
    }

    #[test]
    fn bitwriter_random_round_trip() {
        let mut rng = Rng::seeded(21);
        for _ in 0..50 {
            let items: Vec<(u32, u32)> = (0..200)
                .map(|_| {
                    let n = 1 + rng.below(16) as u32;
                    (rng.u32() & ((1u32 << n) - 1), n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &items {
                w.push(v, n);
            }
            let bytes = w.into_bytes();
            let mut r = BitReader::new(&bytes);
            for &(v, n) in &items {
                assert_eq!(r.read(n), v);
            }
        }
    }

    #[test]
    fn pack_unpack_round_trip_all_formats() {
        let mut rng = Rng::seeded(22);
        let t = Tensor2::random_normal(8, 70, 1.0, &mut rng); // partial tail block
        for cfg in [
            NxConfig::bfp(4),
            NxConfig::mxfp(4),
            NxConfig::mxfp(6),
            NxConfig::nxfp(4),
            NxConfig::nxfp(5),
        ] {
            let q = quantize_matrix(&t, &cfg);
            let blocks = q.store.to_block_codes();
            let packed = PackedMatrix::pack(t.rows, t.cols, &cfg, &blocks);
            let blocks2 = packed.unpack();
            if cfg.enable_nm || cfg.enable_am {
                assert_eq!(blocks, blocks2, "{}", cfg.name());
            } else {
                // base formats don't store meta; compare codes + exponents
                for (a, b) in blocks.iter().zip(&blocks2) {
                    assert_eq!(a.e_shared, b.e_shared);
                    assert_eq!(a.codes, b.codes);
                }
            }
        }
    }

    #[test]
    fn from_store_streams_identical_to_legacy_pack() {
        // the SoA fast path must emit byte-identical scales/meta/payload
        // to the legacy per-block pack, incl. partial tails and 5/6-bit
        // payloads that end mid-byte
        let mut rng = Rng::seeded(25);
        for (rows, cols) in [(8usize, 70usize), (3, 33), (1, 5)] {
            let t = Tensor2::random_normal(rows, cols, 1.0, &mut rng);
            for cfg in [
                NxConfig::bfp(4),
                NxConfig::mxfp(5),
                NxConfig::mxfp(8),
                NxConfig::nxfp(4),
                NxConfig::nxfp(5),
                NxConfig::nxfp(6),
            ] {
                let q = quantize_matrix(&t, &cfg);
                let legacy = PackedMatrix::pack(rows, cols, &cfg, &q.store.to_block_codes());
                let fast = PackedMatrix::from_store(rows, cols, &cfg, &q.store);
                assert_eq!(legacy.scales, fast.scales, "{}", cfg.name());
                assert_eq!(legacy.meta, fast.meta, "{}", cfg.name());
                assert_eq!(legacy.payload, fast.payload, "{}", cfg.name());
                assert_eq!(legacy.blocks_per_row, fast.blocks_per_row);
            }
        }
    }

    #[test]
    fn push_codes_matches_per_code_push() {
        let mut rng = Rng::seeded(26);
        for bits in [3u32, 4, 5, 6, 8] {
            for len in [1usize, 2, 5, 31, 64] {
                let codes: Vec<u8> =
                    (0..len).map(|_| (rng.u32() & ((1u32 << bits) - 1)) as u8).collect();
                let mut a = BitWriter::new();
                for &c in &codes {
                    a.push(c as u32, bits);
                }
                let mut b = BitWriter::new();
                b.push_codes(&codes, bits);
                assert_eq!(a.bits(), b.bits(), "bits={bits} len={len}");
                assert_eq!(a.into_bytes(), b.into_bytes(), "bits={bits} len={len}");
            }
        }
    }

    #[test]
    fn footprint_bits_match_config_accounting() {
        let mut rng = Rng::seeded(23);
        let t = Tensor2::random_normal(4, 64, 1.0, &mut rng);
        for cfg in [NxConfig::mxfp(4), NxConfig::nxfp(5)] {
            let q = quantize_matrix(&t, &cfg);
            let packed = q.pack(&cfg);
            // per-row accounting: each row quantizes independently
            let per_row = cfg.footprint_bits(t.cols);
            assert_eq!(packed.footprint_bits(), per_row * t.rows as u64);
        }
    }

    #[test]
    fn footprint_bytes_close_to_bits() {
        let mut rng = Rng::seeded(24);
        let t = Tensor2::random_normal(16, 256, 1.0, &mut rng);
        let cfg = NxConfig::nxfp(4);
        let q = quantize_matrix(&t, &cfg);
        let packed = q.pack(&cfg);
        let bytes = packed.footprint_bytes() as u64;
        let bits = packed.footprint_bits();
        assert!(bytes * 8 >= bits);
        assert!(bytes * 8 <= bits + 16); // only stream-tail rounding slack
    }
}
