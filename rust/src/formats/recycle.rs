//! Code Recycling (paper §4.3): the sign-magnitude code `10…0` (−0) is
//! wasted; NxFP remaps it to a useful quantization level. The paper sweeps
//! candidate remap targets (Fig. 11) and settles on half of the smallest
//! positive level (a 1-bit right shift of the smallest level in hardware).

use super::element::ElementFormat;

/// Where the recycled code lands, expressed in the *scaled element domain*
/// (the same domain as the level table).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RecycleTarget {
    /// ½ · smallest positive level, decoded with the code's sign bit (=1):
    /// the paper's default (`½·V_smallest`, right-shift decode).
    HalfMin,
    /// Midpoint between the largest and second-largest level (the other
    /// strong candidate in Fig. 11a) — fills the "vacant level" gap.
    MidTopPair,
    /// Midpoint between levels `i` and `i+1` (Fig. 11 sweep points).
    MidPair(usize),
    /// Arbitrary signed value in the scaled domain.
    Custom(f32),
}

impl RecycleTarget {
    /// Resolve to the signed scaled-domain value assigned to code `10…0`.
    /// The sign bit of the recycled code is 1, so hardware decode naturally
    /// yields a negative value; sweep targets follow the same convention.
    pub fn resolve(&self, levels: &[f32]) -> f32 {
        match *self {
            RecycleTarget::HalfMin => {
                // smallest positive level is levels[1] (levels[0] == 0)
                -(levels[1] / 2.0)
            }
            RecycleTarget::MidTopPair => {
                let n = levels.len();
                -((levels[n - 1] + levels[n - 2]) / 2.0)
            }
            RecycleTarget::MidPair(i) => {
                assert!(i + 1 < levels.len(), "MidPair index out of range");
                -((levels[i] + levels[i + 1]) / 2.0)
            }
            RecycleTarget::Custom(v) => v,
        }
    }

    /// All midpoint sweep targets for a format (the Fig. 11 x-axis):
    /// midpoints between every adjacent positive-level pair, plus HalfMin.
    pub fn sweep_targets(elem: &ElementFormat) -> Vec<(String, RecycleTarget)> {
        let levels = elem.levels();
        let mut out = vec![("min/2".to_string(), RecycleTarget::HalfMin)];
        for i in 1..levels.len() - 1 {
            out.push((
                format!("mid({},{})", levels[i], levels[i + 1]),
                RecycleTarget::MidPair(i),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn half_min_on_fp4_is_quarter() {
        let lv = ElementFormat::new(2, 1).levels();
        assert_eq!(RecycleTarget::HalfMin.resolve(&lv), -0.25);
    }

    #[test]
    fn mid_top_pair_on_fp4_is_five() {
        let lv = ElementFormat::new(2, 1).levels();
        assert_eq!(RecycleTarget::MidTopPair.resolve(&lv), -5.0);
    }

    #[test]
    fn mid_pair_indices() {
        let lv = ElementFormat::new(2, 1).levels();
        assert_eq!(RecycleTarget::MidPair(1).resolve(&lv), -0.75);
        assert_eq!(RecycleTarget::MidPair(6).resolve(&lv), -5.0);
    }

    #[test]
    fn sweep_covers_all_adjacent_pairs() {
        let elem = ElementFormat::new(2, 1);
        let sweep = RecycleTarget::sweep_targets(&elem);
        // 8 levels -> 6 midpoints between positive pairs + half-min
        assert_eq!(sweep.len(), 7);
        assert_eq!(sweep[0].1, RecycleTarget::HalfMin);
    }

    #[test]
    fn custom_passthrough() {
        let lv = ElementFormat::new(2, 1).levels();
        assert_eq!(RecycleTarget::Custom(1.23).resolve(&lv), 1.23);
    }
}
