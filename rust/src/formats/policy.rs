//! `QuantPolicy` — first-class per-tensor / per-layer format resolution.
//!
//! The paper's Pareto argument (NxFP5 ≈ MxFP6 perplexity at ~16% less
//! footprint) is a *per-tensor* trade: outlier-heavy projections want
//! NanoMantissa bits that embeddings don't need, and KV keys tolerate
//! different precision than KV values. A policy maps a [`TensorClass`]
//! (weight name/layer, KV key vs value per layer) to an **interned**
//! [`NxConfig`] through an ordered rule list with **first-match
//! precedence**; anything no rule matches stays FP16.
//!
//! Policies come from three places:
//!
//! * [`QuantPolicy::uniform`] / [`QuantPolicy::fp16`] — the two legacy
//!   single-config shapes (`--format nxfp4` lowers to these);
//! * [`QuantPolicy::parse`] — the CLI/config spec string, e.g.
//!   `weights=nxfp4,kv.k=nxfp5,kv.v=mxfp4,layers.0-1.*=mxfp6`
//!   (a bare format name is shorthand for the uniform policy);
//! * [`QuantPolicy::builder`] — typed rule construction for library users.
//!
//! Distinct resolved configs are interned ([`QuantPolicy::configs`] holds
//! one entry per distinct config; rules reference indices), so runtime
//! consumers build exactly one `EncodePlan`/`DequantLut` per distinct
//! config — see `quant::kv_cache::KvPlans` and `eval::quantize_checkpoint`
//! — instead of one per tensor or per serving slot.

use super::{BaseFormat, EncodePlan, NxConfig};
use anyhow::{anyhow, bail, Result};

/// Which KV-cache stream a row belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvStream {
    Key,
    Value,
}

/// The class of one logical tensor, as seen by policy resolution.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorClass<'a> {
    /// A named weight tensor; `layer` is parsed from the repo's `l<N>.`
    /// name prefix when present (`embed`/`unembed`/`lnf` have none).
    Weight { name: &'a str, layer: Option<usize> },
    /// One KV-cache stream of one layer.
    Kv { layer: usize, stream: KvStream },
}

impl<'a> TensorClass<'a> {
    /// Classify a weight by checkpoint name (layer index derived from the
    /// `l<N>.` prefix convention of `LmSpec::param_specs`).
    pub fn weight(name: &'a str) -> Self {
        TensorClass::Weight { name, layer: weight_layer(name) }
    }

    pub fn kv(layer: usize, stream: KvStream) -> Self {
        TensorClass::Kv { layer, stream }
    }

    fn layer(&self) -> Option<usize> {
        match self {
            TensorClass::Weight { layer, .. } => *layer,
            TensorClass::Kv { layer, .. } => Some(*layer),
        }
    }
}

/// Layer index from a `l<N>.`-prefixed weight name (`l3.wq` → 3).
fn weight_layer(name: &str) -> Option<usize> {
    let rest = name.strip_prefix('l')?;
    let dot = rest.find('.')?;
    rest[..dot].parse().ok()
}

/// Weight-name pattern: exact, or `prefix*` matching any name that starts
/// with the prefix.
#[derive(Clone, Debug, PartialEq)]
enum NamePat {
    Exact(String),
    Prefix(String),
}

impl NamePat {
    fn parse(s: &str) -> NamePat {
        match s.strip_suffix('*') {
            Some(p) => NamePat::Prefix(p.to_string()),
            None => NamePat::Exact(s.to_string()),
        }
    }

    fn matches(&self, name: &str) -> bool {
        match self {
            NamePat::Exact(n) => n == name,
            NamePat::Prefix(p) => name.starts_with(p.as_str()),
        }
    }

    fn render(&self) -> String {
        match self {
            NamePat::Exact(n) => n.clone(),
            NamePat::Prefix(p) => format!("{p}*"),
        }
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Scope {
    /// `*` — every weight and KV stream.
    Any,
    /// `weights` / `weights.<name>` / `weights.<prefix>*`.
    Weights(Option<NamePat>),
    /// `kv` / `kv.k` / `kv.v`.
    Kv(Option<KvStream>),
}

/// One rule's match condition: a scope plus an optional inclusive layer
/// range (`layers.<a>-<b>.<scope>` in spec syntax). A layer-filtered
/// selector never matches tensors without a layer index (`embed`,
/// `unembed`, `lnf`).
#[derive(Clone, Debug, PartialEq)]
pub struct Selector {
    scope: Scope,
    layers: Option<(usize, usize)>,
}

impl Selector {
    /// `*` — matches everything.
    pub fn any() -> Self {
        Selector { scope: Scope::Any, layers: None }
    }

    /// `weights` — every weight tensor.
    pub fn weights() -> Self {
        Selector { scope: Scope::Weights(None), layers: None }
    }

    /// `weights.<name>` — one weight by exact name, or a `prefix*` glob.
    pub fn weight_named(pat: &str) -> Self {
        Selector { scope: Scope::Weights(Some(NamePat::parse(pat))), layers: None }
    }

    /// `kv` — both KV streams of every layer.
    pub fn kv() -> Self {
        Selector { scope: Scope::Kv(None), layers: None }
    }

    /// `kv.k` / `kv.v` — one KV stream of every layer.
    pub fn kv_stream(s: KvStream) -> Self {
        Selector { scope: Scope::Kv(Some(s)), layers: None }
    }

    /// Restrict to layers `lo..=hi` (`layers.<lo>-<hi>.…`).
    pub fn in_layers(mut self, lo: usize, hi: usize) -> Self {
        assert!(lo <= hi, "bad layer range {lo}-{hi}");
        self.layers = Some((lo, hi));
        self
    }

    fn matches(&self, class: &TensorClass) -> bool {
        if let Some((lo, hi)) = self.layers {
            match class.layer() {
                Some(l) if lo <= l && l <= hi => {}
                _ => return false,
            }
        }
        match (&self.scope, class) {
            (Scope::Any, _) => true,
            (Scope::Weights(pat), TensorClass::Weight { name, .. }) => {
                pat.as_ref().map_or(true, |p| p.matches(name))
            }
            (Scope::Kv(want), TensorClass::Kv { stream, .. }) => {
                want.map_or(true, |w| w == *stream)
            }
            _ => false,
        }
    }

    fn render(&self) -> String {
        let scope = match &self.scope {
            Scope::Any => "*".to_string(),
            Scope::Weights(None) => "weights".to_string(),
            Scope::Weights(Some(p)) => format!("weights.{}", p.render()),
            Scope::Kv(None) => "kv".to_string(),
            Scope::Kv(Some(KvStream::Key)) => "kv.k".to_string(),
            Scope::Kv(Some(KvStream::Value)) => "kv.v".to_string(),
        };
        match self.layers {
            None => scope,
            Some((lo, hi)) if lo == hi => format!("layers.{lo}.{scope}"),
            Some((lo, hi)) => format!("layers.{lo}-{hi}.{scope}"),
        }
    }
}

/// The class vocabulary, quoted verbatim by every parse error so a typo'd
/// spec string tells the operator what *would* have worked.
const VALID_CLASSES: &str =
    "*, weights, weights.<name|prefix*>, kv, kv.k, kv.v, layers.<a>[-<b>].<class>";

#[derive(Clone, Debug, PartialEq)]
struct Rule {
    sel: Selector,
    /// Index into the interned config table; `None` = FP16 (unquantized).
    cfg: Option<usize>,
}

/// Ordered format-resolution rules over interned configs. See the module
/// docs for semantics; construction via [`QuantPolicy::uniform`],
/// [`QuantPolicy::parse`], or [`QuantPolicy::builder`].
#[derive(Clone, Debug, PartialEq)]
pub struct QuantPolicy {
    rules: Vec<Rule>,
    configs: Vec<NxConfig>,
}

impl QuantPolicy {
    /// No quantization anywhere (the legacy `--format fp16` shape).
    pub fn fp16() -> Self {
        QuantPolicy { rules: Vec::new(), configs: Vec::new() }
    }

    /// One config for every class (the legacy single-`NxConfig` shape).
    pub fn uniform(cfg: NxConfig) -> Self {
        QuantPolicy {
            rules: vec![Rule { sel: Selector::any(), cfg: Some(0) }],
            configs: vec![cfg],
        }
    }

    pub fn builder() -> PolicyBuilder {
        PolicyBuilder { rules: Vec::new() }
    }

    /// Parse a spec string: comma-separated `selector=format` rules
    /// (first match wins), or a bare format name as shorthand for the
    /// uniform policy (`nxfp4` ≡ `*=nxfp4`, `fp16` ≡ no quantization).
    pub fn parse(spec: &str) -> Result<Self> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(QuantPolicy::fp16());
        }
        if !spec.contains('=') {
            return Ok(match parse_format(spec)? {
                Some(cfg) => QuantPolicy::uniform(cfg),
                None => QuantPolicy::fp16(),
            });
        }
        let mut b = QuantPolicy::builder();
        for rule in spec.split(',') {
            let rule = rule.trim();
            if rule.is_empty() {
                continue;
            }
            let (sel, fmt) = rule
                .split_once('=')
                .ok_or_else(|| anyhow!("bad policy rule `{rule}` (want selector=format)"))?;
            b = b.rule(parse_selector(sel.trim())?, parse_format(fmt.trim())?);
        }
        Ok(b.build())
    }

    /// First-match resolution to an interned config index (`None` = FP16).
    pub fn resolve_id(&self, class: TensorClass) -> Option<usize> {
        self.rules.iter().find(|r| r.sel.matches(&class)).and_then(|r| r.cfg)
    }

    /// First-match resolution to the config itself (`None` = FP16).
    pub fn resolve(&self, class: TensorClass) -> Option<&NxConfig> {
        self.resolve_id(class).map(|i| &self.configs[i])
    }

    /// The interned config table (one entry per distinct resolved config;
    /// [`QuantPolicy::resolve_id`] indexes into it). Runtime consumers
    /// build one `EncodePlan`/`DequantLut` per entry, never per tensor.
    pub fn configs(&self) -> &[NxConfig] {
        &self.configs
    }

    pub fn config(&self, id: usize) -> &NxConfig {
        &self.configs[id]
    }

    /// True when no class can resolve to a quantized config.
    pub fn is_fp16(&self) -> bool {
        self.rules.iter().all(|r| r.cfg.is_none())
    }

    /// The single config the KV classes resolve to, if they all agree
    /// across every layer and both streams (`Ok(None)` = uniformly FP16).
    /// The per-format eval artifacts (`eval_step_kvq_*`) bake one format
    /// into the graph, so mixed-KV policies cannot drive them.
    pub fn kv_uniform(&self, n_layers: usize) -> Result<Option<NxConfig>> {
        let mut agreed: Option<Option<usize>> = None;
        for l in 0..n_layers.max(1) {
            for s in [KvStream::Key, KvStream::Value] {
                let id = self.resolve_id(TensorClass::kv(l, s));
                match agreed {
                    None => agreed = Some(id),
                    Some(a) if a == id => {}
                    Some(_) => bail!(
                        "policy `{}` resolves KV streams to more than one format; \
                         this consumer needs a uniform KV format",
                        self.render()
                    ),
                }
            }
        }
        Ok(agreed.flatten().map(|id| self.configs[id].clone()))
    }

    /// Per-layer `(K, V)` KV resolution for consumers that can bake one
    /// format per stream per layer (the layered kvq eval artifacts —
    /// see `kvq_layered_artifact_name` in the CLI and `--kvq-layers` in
    /// aot.py). Unlike [`QuantPolicy::kv_uniform`] this never fails on a
    /// mixed policy: streams resolving to FP16 come back as `None`
    /// entries (no fake-quant applied to them). Returns `None` when every
    /// stream of every layer stays FP16.
    pub fn kv_layers(&self, n_layers: usize) -> Option<Vec<(Option<NxConfig>, Option<NxConfig>)>> {
        let layers: Vec<_> = (0..n_layers)
            .map(|l| {
                (
                    self.resolve(TensorClass::kv(l, KvStream::Key)).cloned(),
                    self.resolve(TensorClass::kv(l, KvStream::Value)).cloned(),
                )
            })
            .collect();
        if layers.iter().all(|(k, v)| k.is_none() && v.is_none()) {
            return None;
        }
        Some(layers)
    }

    /// Canonical spec-string form. Policies whose configs all have
    /// parseable spec names round-trip: `parse(p.render()) == p`.
    /// Non-canonical configs (custom block size, swept recycle targets…)
    /// render as their display name, which does not re-parse.
    pub fn render(&self) -> String {
        if self.rules.is_empty() {
            return "fp16".to_string();
        }
        self.rules
            .iter()
            .map(|r| {
                let fmt = match r.cfg {
                    None => "fp16".to_string(),
                    Some(id) => {
                        let c = &self.configs[id];
                        c.spec_name().unwrap_or_else(|| c.name())
                    }
                };
                format!("{}={fmt}", r.sel.render())
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Human-facing display name: the config name for uniform policies
    /// (`NxFP4 (NM+AM+CR)`, `FP16`), the rendered spec otherwise.
    pub fn name(&self) -> String {
        if self.is_fp16() {
            return "FP16".to_string();
        }
        if let [rule] = &self.rules[..] {
            if rule.sel == Selector::any() {
                if let Some(id) = rule.cfg {
                    return self.configs[id].name();
                }
            }
        }
        self.render()
    }
}

impl From<NxConfig> for QuantPolicy {
    fn from(cfg: NxConfig) -> Self {
        QuantPolicy::uniform(cfg)
    }
}

impl From<Option<NxConfig>> for QuantPolicy {
    fn from(cfg: Option<NxConfig>) -> Self {
        match cfg {
            Some(c) => QuantPolicy::uniform(c),
            None => QuantPolicy::fp16(),
        }
    }
}

/// Lazy one-[`EncodePlan`]-per-distinct-config table over a policy's
/// interned configs — the checkpoint-side counterpart of the serving
/// side's `KvPlans` interning. `eval::quantize_checkpoint` and
/// `Checkpoint::direct_cast_packed` both resolve tensors through one of
/// these, so the one-plan-per-config invariant lives in a single place.
/// Plans are built on first use and live as long as the table.
pub struct PlanTable<'p> {
    policy: &'p QuantPolicy,
    plans: Vec<Option<EncodePlan>>,
}

impl<'p> PlanTable<'p> {
    pub fn new(policy: &'p QuantPolicy) -> Self {
        PlanTable { policy, plans: (0..policy.configs().len()).map(|_| None).collect() }
    }

    /// Resolve a class to its config and (lazily built) encode plan;
    /// `None` when the class stays FP16.
    pub fn resolve(&mut self, class: TensorClass) -> Option<(&NxConfig, &EncodePlan)> {
        let id = self.policy.resolve_id(class)?;
        let cfg = self.policy.config(id);
        let plan = self.plans[id].get_or_insert_with(|| EncodePlan::new(cfg));
        Some((cfg, plan))
    }
}

/// Typed rule construction; rules are matched in insertion order (first
/// match wins) and configs are interned at [`PolicyBuilder::build`].
pub struct PolicyBuilder {
    rules: Vec<(Selector, Option<NxConfig>)>,
}

impl PolicyBuilder {
    /// Append one rule (`None` config = FP16 for the matched classes).
    pub fn rule(mut self, sel: Selector, cfg: Option<NxConfig>) -> Self {
        self.rules.push((sel, cfg));
        self
    }

    pub fn build(self) -> QuantPolicy {
        let mut configs: Vec<NxConfig> = Vec::new();
        let rules = self
            .rules
            .into_iter()
            .map(|(sel, cfg)| Rule {
                sel,
                cfg: cfg.map(|c| match configs.iter().position(|x| *x == c) {
                    Some(i) => i,
                    None => {
                        configs.push(c);
                        configs.len() - 1
                    }
                }),
            })
            .collect();
        QuantPolicy { rules, configs }
    }
}

/// Parse one selector. Grammar (see [`VALID_CLASSES`]):
///
/// ```text
/// selector := "*" | class | "layers." range [ "." class ]
/// class    := "weights" [ "." namepat ] | "kv" [ ".k" | ".v" ]
/// range    := <a> [ "-" <b> ]            (inclusive)
/// ```
fn parse_selector(s: &str) -> Result<Selector> {
    if let Some(rest) = s.strip_prefix("layers.") {
        let (range, sub) = match rest.split_once('.') {
            Some((r, sub)) => (r, sub),
            None => (rest, "*"),
        };
        let (lo, hi) = match range.split_once('-') {
            Some((a, b)) => (parse_layer(a, s)?, parse_layer(b, s)?),
            None => {
                let l = parse_layer(range, s)?;
                (l, l)
            }
        };
        if lo > hi {
            bail!("empty layer range `{s}` ({lo} > {hi})");
        }
        return Ok(parse_scope(sub, s)?.in_layers(lo, hi));
    }
    parse_scope(s, s)
}

fn parse_layer(s: &str, whole: &str) -> Result<usize> {
    s.parse().map_err(|_| {
        anyhow!("bad layer index `{s}` in selector `{whole}` (valid: {VALID_CLASSES})")
    })
}

fn parse_scope(s: &str, whole: &str) -> Result<Selector> {
    match s {
        "*" => Ok(Selector::any()),
        "weights" => Ok(Selector::weights()),
        "kv" => Ok(Selector::kv()),
        "kv.k" => Ok(Selector::kv_stream(KvStream::Key)),
        "kv.v" => Ok(Selector::kv_stream(KvStream::Value)),
        _ => match s.strip_prefix("weights.") {
            Some(pat) if !pat.is_empty() => Ok(Selector::weight_named(pat)),
            _ => bail!("unknown class `{whole}` (valid: {VALID_CLASSES})"),
        },
    }
}

/// Parse a format name: `fp16`/`none` (no quantization), `bfp<B>`,
/// `mxfp<B>`, `nxfp<B>[-nm|-nm+am|-nm+am+cr]`. Moved here from the CLI so
/// the policy spec parser and the `--format`/`--kv-format` sugar share one
/// grammar.
pub fn parse_format(s: &str) -> Result<Option<NxConfig>> {
    let s = s.to_lowercase();
    if s == "fp16" || s == "none" || s.is_empty() {
        return Ok(None);
    }
    let (base, suffix) = match s.split_once('-') {
        Some((b, s)) => (b.to_string(), Some(s.to_string())),
        None => (s.clone(), None),
    };
    let bits: u8 = base
        .trim_start_matches(|c: char| c.is_alphabetic())
        .parse()
        .map_err(|_| anyhow!("bad format {s}"))?;
    let cfg = if base.starts_with("bfp") {
        NxConfig::bfp(bits)
    } else if base.starts_with("mxfp") {
        NxConfig::mxfp(bits)
    } else if base.starts_with("nxfp") {
        match suffix.as_deref() {
            None | Some("nm+am+cr") => NxConfig::nxfp(bits),
            Some("nm") => NxConfig::nxfp_nm(bits),
            Some("nm+am") => NxConfig::nxfp_nm_am(bits),
            Some(other) => bail!("unknown NxFP variant {other}"),
        }
    } else {
        bail!("unknown format {s}");
    };
    if !base.starts_with("nxfp") && suffix.is_some() {
        bail!("format {s} takes no -suffix");
    }
    Ok(Some(cfg))
}

impl NxConfig {
    /// The parseable CLI/spec name of this config, when it is exactly one
    /// of the canonical constructor outputs ([`parse_format`] inverts it);
    /// `None` for customized configs (block size, recycle target, …).
    pub fn spec_name(&self) -> Option<String> {
        let b = self.bits;
        if !(2..=8).contains(&b) {
            return None;
        }
        // BFP is defined down to 2 bits; the Mx/Nx constructors need a
        // default minifloat element, which only exists for 3..=8.
        let mut candidates = vec![(format!("bfp{b}"), NxConfig::bfp(b))];
        if b >= 3 {
            candidates.push((format!("mxfp{b}"), NxConfig::mxfp(b)));
            candidates.push((format!("nxfp{b}"), NxConfig::nxfp(b)));
            candidates.push((format!("nxfp{b}-nm"), NxConfig::nxfp_nm(b)));
            candidates.push((format!("nxfp{b}-nm+am"), NxConfig::nxfp_nm_am(b)));
        }
        candidates.into_iter().find(|(_, c)| self == c).map(|(n, _)| n)
    }

    /// Short stable digest over every field that changes the emitted bits
    /// (element format, base, block size, NM/AM/CR toggles, nano mode,
    /// recycle target). Two configs that quantize identically share a
    /// digest; artifact names use it to keep distinct configs from
    /// colliding on one cache entry.
    pub fn digest(&self) -> String {
        // FNV-1a over a canonical field encoding; Debug is stable for
        // these plain enums/fields within the crate.
        let enc = format!(
            "{}|{:?}|{:?}|{}|{}{}{}|{:?}|{:?}",
            self.bits,
            self.elem_mx,
            self.base,
            self.block_size,
            self.enable_nm as u8,
            self.enable_am as u8,
            self.enable_cr as u8,
            self.nano_mode,
            self.recycle,
        );
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for byte in enc.as_bytes() {
            h ^= *byte as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        format!("{:06x}", h & 0xff_ffff)
    }

    /// The artifact-name family of this config (`bfp`/`mxfp`/`nxfp`): any
    /// NxFP technique makes it `nxfp`, else the base format.
    pub fn family(&self) -> &'static str {
        if self.enable_nm || self.enable_am || self.enable_cr {
            "nxfp"
        } else {
            match self.base {
                BaseFormat::Mx => "mxfp",
                BaseFormat::Bfp => "bfp",
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::RecycleTarget;

    fn w(name: &str) -> TensorClass<'_> {
        TensorClass::weight(name)
    }

    #[test]
    fn weight_layer_parsing() {
        assert_eq!(weight_layer("l0.wq"), Some(0));
        assert_eq!(weight_layer("l12.w2"), Some(12));
        assert_eq!(weight_layer("lnf"), None);
        assert_eq!(weight_layer("embed"), None);
        assert_eq!(weight_layer("unembed"), None);
        assert_eq!(weight_layer("l0.ln1"), Some(0));
    }

    #[test]
    fn uniform_policy_resolves_everything() {
        let p = QuantPolicy::uniform(NxConfig::nxfp(4));
        assert_eq!(p.configs().len(), 1);
        for class in [w("l0.wq"), w("embed"), TensorClass::kv(3, KvStream::Value)] {
            assert_eq!(p.resolve(class).unwrap().name(), "NxFP4 (NM+AM+CR)");
        }
        assert!(!p.is_fp16());
        assert_eq!(p.name(), "NxFP4 (NM+AM+CR)");
    }

    #[test]
    fn fp16_policy_resolves_nothing() {
        let p = QuantPolicy::fp16();
        assert!(p.is_fp16());
        assert!(p.resolve(w("l0.wq")).is_none());
        assert!(p.resolve(TensorClass::kv(0, KvStream::Key)).is_none());
        assert_eq!(p.name(), "FP16");
        assert_eq!(p.render(), "fp16");
    }

    #[test]
    fn parse_shorthand_is_uniform() {
        assert_eq!(QuantPolicy::parse("nxfp4").unwrap(), QuantPolicy::uniform(NxConfig::nxfp(4)));
        assert_eq!(QuantPolicy::parse("fp16").unwrap(), QuantPolicy::fp16());
        assert_eq!(QuantPolicy::parse("").unwrap(), QuantPolicy::fp16());
        assert_eq!(QuantPolicy::parse("none").unwrap(), QuantPolicy::fp16());
    }

    #[test]
    fn parse_issue_example_resolves_per_class() {
        let p =
            QuantPolicy::parse("weights=nxfp4,kv.k=nxfp5,kv.v=mxfp4,layers.0-1.*=mxfp6").unwrap();
        // first match wins: the layers rule is shadowed for weights/kv by
        // the earlier class rules
        assert_eq!(p.resolve(w("l0.wq")).unwrap().name(), "NxFP4 (NM+AM+CR)");
        let k = p.resolve(TensorClass::kv(0, KvStream::Key)).unwrap();
        assert_eq!(k.name(), "NxFP5 (NM+AM+CR)");
        assert_eq!(p.resolve(TensorClass::kv(7, KvStream::Value)).unwrap().name(), "MxFP4-E2M1");
        // unembed has no layer and is a weight -> weights rule
        assert_eq!(p.resolve(w("unembed")).unwrap().bits, 4);
        assert_eq!(p.configs().len(), 4);
    }

    #[test]
    fn first_match_precedence_layer_override() {
        // layer rules listed first override the class-wide fallback
        let p = QuantPolicy::parse("layers.0-1.weights=mxfp6,weights=nxfp4").unwrap();
        assert_eq!(p.resolve(w("l0.wq")).unwrap().name(), "MxFP6-E2M3");
        assert_eq!(p.resolve(w("l1.w2")).unwrap().name(), "MxFP6-E2M3");
        assert_eq!(p.resolve(w("l2.wq")).unwrap().name(), "NxFP4 (NM+AM+CR)");
        // no layer index -> the layer rule can't match
        assert_eq!(p.resolve(w("unembed")).unwrap().name(), "NxFP4 (NM+AM+CR)");
        // reversed order: the class-wide rule shadows the layer rule
        let q = QuantPolicy::parse("weights=nxfp4,layers.0-1.weights=mxfp6").unwrap();
        assert_eq!(q.resolve(w("l0.wq")).unwrap().name(), "NxFP4 (NM+AM+CR)");
    }

    #[test]
    fn named_and_prefix_weight_selectors() {
        let p = QuantPolicy::parse("weights.l0.wq=nxfp6,weights.l1.*=mxfp6,weights=nxfp4")
            .unwrap();
        assert_eq!(p.resolve(w("l0.wq")).unwrap().bits, 6);
        assert_eq!(p.resolve(w("l0.wk")).unwrap().bits, 4);
        assert_eq!(p.resolve(w("l1.wk")).unwrap().name(), "MxFP6-E2M3");
        assert_eq!(p.resolve(w("l2.w1")).unwrap().bits, 4);
        // KV never matches weight selectors: default fp16
        assert!(p.resolve(TensorClass::kv(0, KvStream::Key)).is_none());
    }

    #[test]
    fn single_layer_and_bare_range_selectors() {
        let p = QuantPolicy::parse("layers.2.kv.v=mxfp4,layers.0-1=nxfp5,kv=nxfp4").unwrap();
        assert_eq!(p.resolve(TensorClass::kv(2, KvStream::Value)).unwrap().name(), "MxFP4-E2M1");
        assert_eq!(p.resolve(TensorClass::kv(2, KvStream::Key)).unwrap().bits, 4);
        // `layers.0-1` with no subclass means `layers.0-1.*`
        assert_eq!(p.resolve(TensorClass::kv(0, KvStream::Key)).unwrap().bits, 5);
        assert_eq!(p.resolve(w("l1.wq")).unwrap().bits, 5);
        assert!(p.resolve(w("l2.wq")).is_none());
    }

    #[test]
    fn explicit_fp16_rule_wins_first_match() {
        let p = QuantPolicy::parse("kv.v=fp16,kv=nxfp4").unwrap();
        assert!(p.resolve(TensorClass::kv(0, KvStream::Value)).is_none());
        assert_eq!(p.resolve(TensorClass::kv(0, KvStream::Key)).unwrap().bits, 4);
        assert!(!p.is_fp16());
    }

    #[test]
    fn unknown_class_error_lists_valid_classes() {
        for bad in ["weightz=nxfp4", "kv.q=nxfp4", "layers.x.kv=nxfp4", "embeddings=nxfp4"] {
            let err = QuantPolicy::parse(bad).unwrap_err().to_string();
            assert!(err.contains("kv.k"), "error for `{bad}` should list classes: {err}");
            assert!(err.contains("weights"), "error for `{bad}` should list classes: {err}");
        }
        assert!(QuantPolicy::parse("kv=zfp4").is_err());
        assert!(QuantPolicy::parse("kv").is_err()); // bare selector is not a format name
        assert!(QuantPolicy::parse("layers.3-1.kv=nxfp4").is_err()); // empty range
    }

    #[test]
    fn interning_dedups_configs() {
        let p = QuantPolicy::parse("kv.k=nxfp4,kv.v=nxfp4,weights=nxfp4").unwrap();
        assert_eq!(p.configs().len(), 1);
        let kid = p.resolve_id(TensorClass::kv(0, KvStream::Key)).unwrap();
        let vid = p.resolve_id(TensorClass::kv(0, KvStream::Value)).unwrap();
        assert_eq!(kid, vid);
    }

    #[test]
    fn render_round_trips() {
        for spec in [
            "nxfp4",
            "weights=nxfp4,kv.k=nxfp5,kv.v=mxfp4,layers.0-1.*=mxfp6",
            "layers.2.kv.v=mxfp4,kv=nxfp4",
            "weights.l0.wq=nxfp6,weights.l1.*=mxfp6,weights=bfp5",
            "kv.v=fp16,kv=nxfp5-nm+am",
            "fp16",
        ] {
            let p = QuantPolicy::parse(spec).unwrap();
            let rendered = p.render();
            let q = QuantPolicy::parse(&rendered).unwrap();
            assert_eq!(p, q, "spec `{spec}` -> `{rendered}` did not round-trip");
        }
    }

    #[test]
    fn kv_uniform_detection() {
        let u = QuantPolicy::uniform(NxConfig::nxfp(4));
        assert_eq!(u.kv_uniform(4).unwrap().unwrap().name(), "NxFP4 (NM+AM+CR)");
        assert!(QuantPolicy::fp16().kv_uniform(4).unwrap().is_none());
        // weights-only policy: KV uniformly fp16
        let wo = QuantPolicy::parse("weights=nxfp4").unwrap();
        assert!(wo.kv_uniform(4).unwrap().is_none());
        // mixed streams: not uniform
        let m = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap();
        assert!(m.kv_uniform(4).is_err());
        // per-layer mix: not uniform
        let l = QuantPolicy::parse("layers.0.kv=mxfp6,kv=nxfp4").unwrap();
        assert!(l.kv_uniform(2).is_err());
        assert!(l.kv_uniform(1).unwrap().is_some()); // only layer 0 exists
    }

    #[test]
    fn kv_layers_resolution() {
        // uniformly fp16 (weights-only): nothing to bake
        assert!(QuantPolicy::fp16().kv_layers(3).is_none());
        assert!(QuantPolicy::parse("weights=nxfp4").unwrap().kv_layers(3).is_none());
        // mixed streams resolve per layer where kv_uniform errors out
        let m = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap();
        assert!(m.kv_uniform(2).is_err());
        let layers = m.kv_layers(2).unwrap();
        assert_eq!(layers.len(), 2);
        for (k, v) in &layers {
            assert_eq!(k.as_ref().unwrap().bits, 5);
            assert_eq!(v.as_ref().unwrap().name(), "MxFP4-E2M1");
        }
        // per-layer override with an fp16 stream: None entry for it
        let l = QuantPolicy::parse("layers.0.kv.k=mxfp6,kv.v=fp16,kv=nxfp4").unwrap();
        let layers = l.kv_layers(2).unwrap();
        assert_eq!(layers[0].0.as_ref().unwrap().name(), "MxFP6-E2M3");
        assert!(layers[0].1.is_none());
        assert_eq!(layers[1].0.as_ref().unwrap().bits, 4);
        assert!(layers[1].1.is_none());
        // uniform policies agree with kv_uniform on every entry
        let u = QuantPolicy::uniform(NxConfig::nxfp(4));
        let cfg = u.kv_uniform(2).unwrap().unwrap();
        for (k, v) in u.kv_layers(2).unwrap() {
            assert_eq!(k.as_ref(), Some(&cfg));
            assert_eq!(v.as_ref(), Some(&cfg));
        }
    }

    #[test]
    fn from_conversions_preserve_legacy_shapes() {
        let some: QuantPolicy = Some(NxConfig::mxfp(5)).into();
        assert_eq!(some, QuantPolicy::uniform(NxConfig::mxfp(5)));
        let none: QuantPolicy = None::<NxConfig>.into();
        assert_eq!(none, QuantPolicy::fp16());
        let direct: QuantPolicy = NxConfig::bfp(4).into();
        assert_eq!(direct.name(), "BFP4");
    }

    #[test]
    fn builder_matches_parser() {
        let built = QuantPolicy::builder()
            .rule(Selector::kv_stream(KvStream::Key), Some(NxConfig::nxfp(5)))
            .rule(Selector::kv_stream(KvStream::Value), Some(NxConfig::mxfp(4)))
            .rule(Selector::weights(), Some(NxConfig::nxfp(4)))
            .build();
        let parsed = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4,weights=nxfp4").unwrap();
        assert_eq!(built, parsed);
        let ranged = QuantPolicy::builder()
            .rule(Selector::kv().in_layers(0, 1), Some(NxConfig::mxfp(6)))
            .rule(Selector::any(), Some(NxConfig::nxfp(4)))
            .build();
        assert_eq!(ranged, QuantPolicy::parse("layers.0-1.kv=mxfp6,*=nxfp4").unwrap());
    }

    #[test]
    fn plan_table_builds_one_plan_per_config() {
        let p = QuantPolicy::parse("weights.l0.*=mxfp6,weights=nxfp4,kv=nxfp4").unwrap();
        let mut table = PlanTable::new(&p);
        // fp16-resolved classes yield no plan
        assert!(table.resolve(TensorClass::kv(0, KvStream::Key)).is_some());
        let unmatched = QuantPolicy::parse("weights=nxfp4").unwrap();
        assert!(PlanTable::new(&unmatched).resolve(TensorClass::kv(0, KvStream::Key)).is_none());
        // the same interned config returns the same cached plan (pointer
        // equality across resolves, incl. across distinct classes)
        let p1 = table.resolve(TensorClass::weight("l1.wq")).unwrap().1 as *const EncodePlan;
        let p2 = table.resolve(TensorClass::weight("l2.w2")).unwrap().1 as *const EncodePlan;
        let p3 = table.resolve(TensorClass::kv(1, KvStream::Value)).unwrap().1 as *const _;
        assert_eq!(p1, p2);
        assert_eq!(p1, p3); // kv=nxfp4 interns to the same config as weights
        // a different config gets a different plan, built for it
        let (cfg6, plan6) = table.resolve(TensorClass::weight("l0.wq")).unwrap();
        assert_eq!(cfg6.name(), "MxFP6-E2M3");
        assert_eq!(plan6.cfg.name(), "MxFP6-E2M3");
    }

    #[test]
    fn spec_names_cover_canonical_configs() {
        assert_eq!(NxConfig::nxfp(4).spec_name().as_deref(), Some("nxfp4"));
        assert_eq!(NxConfig::mxfp(6).spec_name().as_deref(), Some("mxfp6"));
        assert_eq!(NxConfig::bfp(5).spec_name().as_deref(), Some("bfp5"));
        assert_eq!(NxConfig::nxfp_nm(5).spec_name().as_deref(), Some("nxfp5-nm"));
        assert_eq!(NxConfig::nxfp_nm_am(4).spec_name().as_deref(), Some("nxfp4-nm+am"));
        // customized configs have no parseable name
        assert!(NxConfig::nxfp(4).with_block_size(16).spec_name().is_none());
        assert!(NxConfig::mxfp(4).with_recycle(RecycleTarget::MidTopPair).spec_name().is_none());
        // 2-bit BFP exists (no minifloat counterpart), out-of-range bits don't
        assert_eq!(NxConfig::bfp(2).spec_name().as_deref(), Some("bfp2"));
        // and every spec name parses back to the same config
        for cfg in [NxConfig::nxfp(4), NxConfig::mxfp(6), NxConfig::nxfp_nm(5)] {
            let name = cfg.spec_name().unwrap();
            assert_eq!(parse_format(&name).unwrap().unwrap(), cfg);
        }
    }

    #[test]
    fn digests_distinguish_configs() {
        let a = NxConfig::nxfp(4);
        assert_eq!(a.digest(), NxConfig::nxfp(4).digest());
        let distinct = [
            NxConfig::nxfp(4).digest(),
            NxConfig::nxfp_nm(4).digest(),
            NxConfig::nxfp(4).with_block_size(16).digest(),
            NxConfig::nxfp(4).with_recycle(RecycleTarget::MidTopPair).digest(),
            NxConfig::mxfp(4).digest(),
            NxConfig::bfp(4).digest(),
            NxConfig::nxfp(5).digest(),
        ];
        let uniq: std::collections::BTreeSet<&String> = distinct.iter().collect();
        assert_eq!(uniq.len(), distinct.len(), "digest collision: {distinct:?}");
        assert_eq!(a.digest().len(), 6);
    }

    #[test]
    fn families() {
        assert_eq!(NxConfig::nxfp(4).family(), "nxfp");
        assert_eq!(NxConfig::nxfp_nm(5).family(), "nxfp");
        assert_eq!(NxConfig::mxfp(5).family(), "mxfp");
        assert_eq!(NxConfig::bfp(6).family(), "bfp");
    }

    #[test]
    fn parse_format_families() {
        assert!(parse_format("fp16").unwrap().is_none());
        assert!(parse_format("none").unwrap().is_none());
        assert_eq!(parse_format("bfp4").unwrap().unwrap().name(), "BFP4");
        assert_eq!(parse_format("mxfp6").unwrap().unwrap().name(), "MxFP6-E2M3");
        assert_eq!(parse_format("nxfp4").unwrap().unwrap().name(), "NxFP4 (NM+AM+CR)");
        assert_eq!(parse_format("nxfp5-nm").unwrap().unwrap().name(), "NxFP5 (NM)");
        assert_eq!(parse_format("NXFP4-NM+AM").unwrap().unwrap().name(), "NxFP4 (NM+AM)");
        assert!(parse_format("zfp4").is_err());
        assert!(parse_format("nxfp4-zzz").is_err());
        assert!(parse_format("mxfpx").is_err());
        assert!(parse_format("mxfp4-nm").is_err());
    }
}
