//! Element-level number formats: minifloats (ExMy, OCP Microscaling
//! semantics) and all-mantissa fixed-point elements (BFP). An element format
//! defines the per-element **level table** — the sorted positive magnitudes
//! representable by its magnitude code — plus the scale convention that ties
//! the table to a block's shared exponent.

use crate::util::floor_log2;

/// An element format: 1 sign bit + `ebits` exponent bits + `mbits` mantissa
/// bits. `ebits == 0` denotes the BFP (all-mantissa, fixed-point) element.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ElementFormat {
    pub ebits: u8,
    pub mbits: u8,
}

impl ElementFormat {
    pub const fn new(ebits: u8, mbits: u8) -> Self {
        ElementFormat { ebits, mbits }
    }

    /// OCP MxFP element defaults per total bitwidth (the configuration the
    /// paper reports): FP4 = E2M1, FP5 = E2M2, FP6 = E2M3, FP8 = E4M3.
    pub fn mx_default(bits: u8) -> Self {
        match bits {
            3 => ElementFormat::new(2, 0), // FP3 (Fig. 10 3-bit points)
            4 => ElementFormat::new(2, 1),
            5 => ElementFormat::new(2, 2),
            6 => ElementFormat::new(2, 3),
            7 => ElementFormat::new(3, 3),
            8 => ElementFormat::new(4, 3),
            _ => panic!("unsupported MxFP bitwidth {bits}"),
        }
    }

    /// The BFP element with the same total bitwidth.
    pub fn bfp(bits: u8) -> Self {
        assert!(bits >= 2, "BFP needs at least sign + 1 mantissa bit");
        ElementFormat::new(0, bits - 1)
    }

    /// Total storage bits per element (sign + exponent + mantissa).
    pub const fn bits(&self) -> u8 {
        1 + self.ebits + self.mbits
    }

    /// IEEE-style exponent bias.
    pub fn bias(&self) -> i32 {
        if self.ebits == 0 {
            0
        } else {
            (1i32 << (self.ebits - 1)) - 1
        }
    }

    /// Sorted positive magnitudes for magnitude codes `0..len`. Monotone in
    /// the code, so "ties to even table index" == "ties to even mantissa
    /// code" (RTNE). Non-finite codes (E4M3 NaN, E5M2 inf/NaN) are excluded;
    /// encoding saturates at the largest finite level.
    pub fn levels(&self) -> Vec<f32> {
        let e = self.ebits as u32;
        let m = self.mbits as u32;
        if e == 0 {
            // fixed-point magnitudes 0, 1, .., 2^m - 1 (step applied by the
            // block scale)
            return (0..(1u32 << m)).map(|c| c as f32).collect();
        }
        let bias = self.bias();
        let mut out = Vec::with_capacity(1 << (e + m));
        for code in 0..(1u32 << (e + m)) {
            let exp_field = (code >> m) as i32;
            let m_field = (code & ((1 << m) - 1)) as f32;
            let frac = m_field / (1u32 << m) as f32;
            // OCP FP8 specials: E4M3 has NaN at the all-ones code; E5M2 has
            // IEEE inf/NaN at exp field all-ones. Exclude from finite levels.
            if self.ebits == 4 && self.mbits == 3 && code == (1 << (e + m)) - 1 {
                break;
            }
            if self.ebits == 5 && exp_field == (1 << e) - 1 {
                break;
            }
            let v = if exp_field == 0 {
                // subnormal
                frac * crate::util::exp2i(1 - bias)
            } else {
                (1.0 + frac) * crate::util::exp2i(exp_field - bias)
            };
            out.push(v);
        }
        out
    }

    /// Largest finite magnitude.
    pub fn max_finite(&self) -> f32 {
        *self.levels().last().unwrap()
    }

    /// Exponent of the largest finite magnitude (`emax` in the OCP spec).
    pub fn emax(&self) -> i32 {
        floor_log2(self.max_finite()).unwrap()
    }

    /// Exponent offset of the block scale: the shared scale is
    /// `X = 2^(E_shared + offset)` so that a block max `|v| in [2^E, 2^(E+1))`
    /// lands near the top of the level table.
    ///
    /// * minifloat: `offset = -emax` (OCP Microscaling rule);
    /// * fixed-point: `offset = 1 - mbits` (top magnitude `2^m - 1` covers
    ///   `~2^(E+1)`), i.e. the MSFP/BFP alignment.
    pub fn scale_exp_offset(&self) -> i32 {
        if self.ebits == 0 {
            1 - self.mbits as i32
        } else {
            -self.emax()
        }
    }

    /// Human-readable name, e.g. `E2M1` or `M3` (fixed-point).
    pub fn name(&self) -> String {
        if self.ebits == 0 {
            format!("M{}", self.mbits)
        } else {
            format!("E{}M{}", self.ebits, self.mbits)
        }
    }
}

/// Project `a >= 0` onto the sorted level table: nearest, ties to the even
/// index (== round-to-nearest-even on the magnitude code), saturating at the
/// top. Returns the table index.
#[inline]
pub fn project_magnitude(levels: &[f32], a: f32) -> usize {
    debug_assert!(a >= 0.0 || a.is_nan());
    if a.is_nan() {
        return levels.len() - 1; // direct-cast of NaN saturates (documented)
    }
    // partition point: first index with level >= a
    let i = levels.partition_point(|&l| l < a);
    if i == 0 {
        return 0;
    }
    if i == levels.len() {
        return levels.len() - 1;
    }
    let lo = levels[i - 1];
    let hi = levels[i];
    let dl = a - lo;
    let dh = hi - a;
    if dl < dh {
        i - 1
    } else if dh < dl {
        i
    } else {
        // exact tie: even index wins
        if (i - 1) % 2 == 0 {
            i - 1
        } else {
            i
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2m1_levels_match_ocp_fp4() {
        let f = ElementFormat::new(2, 1);
        assert_eq!(f.levels(), vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
        assert_eq!(f.max_finite(), 6.0);
        assert_eq!(f.emax(), 2);
        assert_eq!(f.scale_exp_offset(), -2);
        assert_eq!(f.bits(), 4);
        assert_eq!(f.name(), "E2M1");
    }

    #[test]
    fn e2m3_levels_match_ocp_fp6() {
        let f = ElementFormat::new(2, 3);
        let lv = f.levels();
        assert_eq!(lv.len(), 32);
        assert_eq!(f.max_finite(), 7.5);
        assert_eq!(lv[0], 0.0);
        assert_eq!(lv[1], 0.125); // subnormal step 2^-3 * 2^0
        assert_eq!(f.emax(), 2);
    }

    #[test]
    fn e3m2_levels_match_ocp_fp6_alt() {
        let f = ElementFormat::new(3, 2);
        assert_eq!(f.max_finite(), 28.0);
        assert_eq!(f.emax(), 4);
        assert_eq!(f.levels().len(), 32);
    }

    #[test]
    fn e4m3_excludes_nan_max_448() {
        let f = ElementFormat::new(4, 3);
        assert_eq!(f.max_finite(), 448.0);
        assert_eq!(f.levels().len(), 127); // 128 codes minus the NaN code
    }

    #[test]
    fn e5m2_excludes_inf_nan_max_57344() {
        let f = ElementFormat::new(5, 2);
        assert_eq!(f.max_finite(), 57344.0);
        assert_eq!(f.levels().len(), 124); // 4 non-finite codes dropped
    }

    #[test]
    fn bfp4_element_is_integer_grid() {
        let f = ElementFormat::bfp(4);
        assert_eq!(f.levels(), vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(f.scale_exp_offset(), -2);
        assert_eq!(f.name(), "M3");
    }

    #[test]
    fn levels_strictly_monotone_for_all_supported_formats() {
        for f in [
            ElementFormat::new(2, 1),
            ElementFormat::new(2, 2),
            ElementFormat::new(2, 3),
            ElementFormat::new(3, 2),
            ElementFormat::new(3, 3),
            ElementFormat::new(4, 3),
            ElementFormat::new(5, 2),
            ElementFormat::bfp(4),
            ElementFormat::bfp(5),
            ElementFormat::bfp(6),
            ElementFormat::bfp(8),
        ] {
            let lv = f.levels();
            for w in lv.windows(2) {
                assert!(w[0] < w[1], "{:?} not monotone: {:?}", f, w);
            }
        }
    }

    #[test]
    fn project_nearest_and_saturates() {
        let lv = ElementFormat::new(2, 1).levels();
        assert_eq!(project_magnitude(&lv, 0.0), 0);
        assert_eq!(project_magnitude(&lv, 0.2), 0);
        assert_eq!(project_magnitude(&lv, 0.3), 1);
        assert_eq!(project_magnitude(&lv, 5.1), 7); // nearer 6 than 4
        assert_eq!(project_magnitude(&lv, 4.9), 6);
        assert_eq!(project_magnitude(&lv, 100.0), 7); // saturate
    }

    #[test]
    fn project_ties_to_even_index() {
        let lv = ElementFormat::new(2, 1).levels();
        // 0.25 is exactly between levels 0 (0.0, even) and 1 (0.5) -> 0
        assert_eq!(project_magnitude(&lv, 0.25), 0);
        // 1.25 between idx 2 (1.0, even) and 3 (1.5) -> 2
        assert_eq!(project_magnitude(&lv, 1.25), 2);
        // 2.5 between idx 4 (2.0, even) and 5 (3.0) -> 4
        assert_eq!(project_magnitude(&lv, 2.5), 4);
        // 5.0 between idx 6 (4.0, even) and 7 (6.0) -> 6
        assert_eq!(project_magnitude(&lv, 5.0), 6);
    }

    #[test]
    fn project_exact_levels_idempotent() {
        for f in [ElementFormat::new(2, 3), ElementFormat::bfp(6)] {
            let lv = f.levels();
            for (i, &l) in lv.iter().enumerate() {
                assert_eq!(project_magnitude(&lv, l), i);
            }
        }
    }

    #[test]
    fn project_nan_saturates() {
        let lv = ElementFormat::new(2, 1).levels();
        assert_eq!(project_magnitude(&lv, f32::NAN), 7);
    }
}
