//! Block number formats: BFP (MSFP/MxINT-style), Microscaling MxFP, and the
//! paper's Nanoscaling NxFP (NanoMantissa + Adaptive Microexponent + Code
//! Recycling), all over shared-exponent blocks of `k` elements.
//!
//! The semantics here are **normative** for the whole repo: the Python
//! oracle (`python/compile/kernels/ref.py`) and the Pallas kernel implement
//! the same rules and are cross-checked bit-for-bit through golden vectors
//! (`rust/tests/golden_cross_check.rs`).

pub mod element;
pub mod encode;
pub mod packed;
pub mod policy;
pub mod recycle;
pub mod store;

pub use element::{project_magnitude, ElementFormat};
pub use encode::{EncodePlan, EncodeScratch};
pub use policy::{parse_format, KvStream, PlanTable, QuantPolicy, TensorClass};
pub use recycle::RecycleTarget;
pub use store::BlockStore;

use crate::util::{exp2i, floor_log2};

/// Shared-exponent storage range (OCP E8M0 without the NaN code).
pub const E_SHARED_MIN: i32 = -127;
pub const E_SHARED_MAX: i32 = 127;

/// A fully-resolved per-block element format: level table + scale convention
/// + optional recycled code value. Build once per config, reuse per block.
#[derive(Clone, Debug)]
pub struct BlockFormat {
    pub elem: ElementFormat,
    /// Sorted positive magnitudes for magnitude codes `0..levels.len()`.
    pub levels: Vec<f32>,
    /// Shared scale is `2^(E_shared + offset)` (NanoMantissa multiplies it).
    pub offset: i32,
    /// Scaled-domain value decoded for code `10…0` when Code Recycling is on.
    pub recycle: Option<f32>,
}

impl BlockFormat {
    pub fn new(elem: ElementFormat, recycle: Option<RecycleTarget>) -> Self {
        let levels = elem.levels();
        let recycle = recycle.map(|t| t.resolve(&levels));
        BlockFormat { elem, offset: elem.scale_exp_offset(), levels, recycle }
    }

    /// Total element bits (incl. sign).
    #[inline]
    pub fn bits(&self) -> u8 {
        self.elem.bits()
    }

    /// Encode one scaled-domain value to a sign-magnitude code.
    /// Nearest level, ties-to-even mantissa code, saturating; the recycled
    /// code participates in nearest-neighbour search when enabled (grid
    /// levels win exact ties against the recycled level).
    #[inline]
    pub fn encode(&self, a: f32) -> u8 {
        let sign = a < 0.0;
        let idx = project_magnitude(&self.levels, a.abs());
        let grid = if sign { -self.levels[idx] } else { self.levels[idx] };
        if let Some(r) = self.recycle {
            if (a - r).abs() < (a - grid).abs() {
                return 1u8 << (self.bits() - 1); // sign=1, magnitude=0
            }
        }
        if idx == 0 {
            return 0; // canonical +0 (code -0 is reserved / recycled)
        }
        ((sign as u8) << (self.bits() - 1)) | idx as u8
    }

    /// Decode a code back to the scaled domain.
    #[inline]
    pub fn decode(&self, code: u8) -> f32 {
        let sign_bit = 1u8 << (self.bits() - 1);
        let idx = (code & (sign_bit - 1)) as usize;
        let neg = code & sign_bit != 0;
        if neg && idx == 0 {
            return self.recycle.unwrap_or(0.0);
        }
        let idx = idx.min(self.levels.len() - 1);
        let v = self.levels[idx];
        if neg {
            -v
        } else {
            v
        }
    }

    /// Largest representable magnitude (scaled domain).
    #[inline]
    pub fn top(&self) -> f32 {
        *self.levels.last().unwrap()
    }
}

/// Which base block format a non-adaptive config uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaseFormat {
    /// Microscaling: minifloat elements (microexponents present).
    Mx,
    /// Block floating point: all-mantissa elements.
    Bfp,
}

/// NanoMantissa candidate policy (paper Algorithm 1 tries the rounded
/// candidate and zero; the exhaustive mode is our ablation upper bound).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NanoMode {
    /// `{m_candidate, 0}` — the paper's Algorithm 1.
    TwoCandidate,
    /// `{0, 1, 2, 3}` — exhaustive search over the 2-bit field.
    Exhaustive,
}

/// Complete quantizer configuration for one tensor. Equality compares
/// every field that changes the emitted bits (the same contract as
/// [`NxConfig::digest`]) — the policy layer interns configs by it.
#[derive(Clone, Debug, PartialEq)]
pub struct NxConfig {
    /// Element bits (4, 5, 6, … incl. sign).
    pub bits: u8,
    /// Minifloat element used on the Mx path.
    pub elem_mx: ElementFormat,
    /// Base format when Adaptive Microexponent is disabled.
    pub base: BaseFormat,
    /// Block size `k` (paper default 32).
    pub block_size: usize,
    pub enable_nm: bool,
    pub enable_am: bool,
    pub enable_cr: bool,
    pub nano_mode: NanoMode,
    pub recycle: RecycleTarget,
}

impl NxConfig {
    /// Plain block floating point (MSFP / MxINT baseline).
    pub fn bfp(bits: u8) -> Self {
        NxConfig {
            bits,
            elem_mx: ElementFormat::mx_default(bits.max(3)),
            base: BaseFormat::Bfp,
            block_size: 32,
            enable_nm: false,
            enable_am: false,
            enable_cr: false,
            nano_mode: NanoMode::TwoCandidate,
            recycle: RecycleTarget::HalfMin,
        }
    }

    /// OCP Microscaling with the default element format for `bits`.
    pub fn mxfp(bits: u8) -> Self {
        NxConfig { base: BaseFormat::Mx, ..NxConfig::bfp(bits) }
    }

    /// Microscaling with an explicit element format (e.g. E3M2 for FP6).
    pub fn mxfp_elem(bits: u8, elem: ElementFormat) -> Self {
        assert_eq!(elem.bits(), bits);
        NxConfig { elem_mx: elem, ..NxConfig::mxfp(bits) }
    }

    /// Full Nanoscaling: NM + AM + CR (the paper's headline format).
    pub fn nxfp(bits: u8) -> Self {
        NxConfig {
            enable_nm: true,
            enable_am: true,
            enable_cr: true,
            ..NxConfig::mxfp(bits)
        }
    }

    /// Ablation: NanoMantissa only.
    pub fn nxfp_nm(bits: u8) -> Self {
        NxConfig { enable_nm: true, ..NxConfig::mxfp(bits) }
    }

    /// Ablation: NanoMantissa + Adaptive Microexponent.
    pub fn nxfp_nm_am(bits: u8) -> Self {
        NxConfig { enable_nm: true, enable_am: true, ..NxConfig::mxfp(bits) }
    }

    pub fn with_block_size(mut self, k: usize) -> Self {
        assert!(k > 0);
        self.block_size = k;
        self
    }

    pub fn with_recycle(mut self, t: RecycleTarget) -> Self {
        self.recycle = t;
        self.enable_cr = true;
        self
    }

    pub fn with_nano_mode(mut self, m: NanoMode) -> Self {
        self.nano_mode = m;
        self
    }

    /// Display name mirroring the paper's tables, e.g. `NxFP4 (NM+AM+CR)`.
    pub fn name(&self) -> String {
        let any_nx = self.enable_nm || self.enable_am || self.enable_cr;
        if !any_nx {
            return match self.base {
                BaseFormat::Bfp => format!("BFP{}", self.bits),
                BaseFormat::Mx => format!("MxFP{}-{}", self.bits, self.elem_mx.name()),
            };
        }
        let mut techs = Vec::new();
        if self.enable_nm {
            techs.push("NM");
        }
        if self.enable_am {
            techs.push("AM");
        }
        if self.enable_cr {
            techs.push("CR");
        }
        format!("NxFP{} ({})", self.bits, techs.join("+"))
    }

    /// Per-block metadata bits: shared exponent (E8M0) + NanoMantissa (2) +
    /// format index (1). Code Recycling is free.
    pub fn overhead_bits_per_block(&self) -> u32 {
        8 + if self.enable_nm { 2 } else { 0 } + if self.enable_am { 1 } else { 0 }
    }

    /// Bit-true storage cost of `n` elements (paper footprint accounting).
    pub fn footprint_bits(&self, n: usize) -> u64 {
        let k = self.block_size;
        let blocks = n.div_ceil(k) as u64;
        blocks * self.overhead_bits_per_block() as u64 + (n as u64) * self.bits as u64
    }

    /// Effective bits per element including metadata.
    pub fn effective_bits(&self) -> f64 {
        self.bits as f64 + self.overhead_bits_per_block() as f64 / self.block_size as f64
    }

    /// Resolve the (Mx, Bfp) block formats with recycling applied as
    /// configured. Cache this per tensor — level tables allocate.
    pub fn tables(&self) -> FormatTables {
        let rec = if self.enable_cr { Some(self.recycle) } else { None };
        FormatTables {
            mx: BlockFormat::new(self.elem_mx, rec),
            bfp: BlockFormat::new(ElementFormat::bfp(self.bits), rec),
        }
    }
}

/// Pre-built level tables for both adaptive paths.
#[derive(Clone, Debug)]
pub struct FormatTables {
    pub mx: BlockFormat,
    pub bfp: BlockFormat,
}

impl FormatTables {
    #[inline]
    pub fn get(&self, fmt_mx: bool) -> &BlockFormat {
        if fmt_mx {
            &self.mx
        } else {
            &self.bfp
        }
    }
}

/// One quantized block: shared exponent, 2-bit NanoMantissa, format index
/// bit, and per-element sign-magnitude codes.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockCode {
    pub e_shared: i16,
    pub nano: u8,
    pub fmt_mx: bool,
    pub codes: Vec<u8>,
}

impl BlockCode {
    /// NanoMantissa multiplier `(1.mm)₂`.
    #[inline]
    pub fn nano_scale(&self) -> f32 {
        1.0 + self.nano as f32 / 4.0
    }

    /// Full dequantization scale for this block under `tabs`.
    #[inline]
    pub fn scale(&self, tabs: &FormatTables) -> f32 {
        self.nano_scale() * exp2i(self.e_shared as i32 + tabs.get(self.fmt_mx).offset)
    }
}

/// Shared exponent of a block: `⌊log2 max|v|⌋`, clamped to E8M0 range.
/// `None` for an all-zero (or all-nonfinite) block.
pub fn shared_exponent(v: &[f32]) -> Option<i32> {
    let mut maxabs = 0.0f32;
    for &x in v {
        let a = x.abs();
        if a.is_finite() && a > maxabs {
            maxabs = a;
        }
    }
    floor_log2(maxabs).map(|e| e.clamp(E_SHARED_MIN, E_SHARED_MAX))
}

/// Largest **finite** `|v|` in a block (0 when there is none) — the block
/// max fed to [`nano_candidate`]. Filters non-finite values exactly like
/// [`shared_exponent`] (and the Python oracle's
/// `np.abs(v[np.isfinite(v)])`): a stray Inf must not saturate the
/// NanoMantissa candidate. Shared by the reference path and the engine so
/// the rule cannot drift between them.
pub fn finite_max_abs(v: &[f32]) -> f32 {
    v.iter().fold(0.0f32, |m, &x| {
        let a = x.abs();
        if a.is_finite() && a > m { a } else { m }
    })
}

/// NanoMantissa candidate: round the block max against the top level of the
/// target format (the paper's Fig. 4 rule; see DESIGN.md §4 for why the
/// worked example, not Algorithm 1's pseudocode formula, is normative).
pub fn nano_candidate(vmax: f32, bf: &BlockFormat, e_shared: i32) -> u8 {
    let cap = bf.top() * exp2i(e_shared + bf.offset);
    if cap <= 0.0 || !cap.is_finite() {
        return 0;
    }
    let ratio = vmax / cap;
    if ratio <= 1.0 {
        return 0;
    }
    (((ratio - 1.0) * 4.0).round() as i32).clamp(0, 3) as u8
}

/// Quantize one block with a fixed (format, nano) choice. Returns the codes
/// and the sum of squared errors in the **original** domain.
pub fn quantize_block_fixed(
    v: &[f32],
    bf: &BlockFormat,
    e_shared: i32,
    nano: u8,
) -> (Vec<u8>, f64) {
    let scale = (1.0 + nano as f32 / 4.0) * exp2i(e_shared + bf.offset);
    let inv = 1.0 / scale;
    let mut codes = Vec::with_capacity(v.len());
    let mut sse = 0.0f64;
    for &x in v {
        let code = bf.encode(x * inv);
        let back = bf.decode(code) * scale;
        let d = (x - back) as f64;
        sse += d * d;
        codes.push(code);
    }
    (codes, sse)
}

/// Quantize one block under a full config (paper Algorithm 1 generalized to
/// the ablation toggles). Deterministic candidate order: for each format
/// (Mx first), the rounded NanoMantissa candidate then 0; strictly smaller
/// SSE wins.
///
/// This is the **reference path** (also mirrored by the Python oracle); the
/// production encode path is the table-driven engine in [`encode`], which
/// must stay bit-identical to this function (`tests/engine_equivalence.rs`).
pub fn quantize_block(v: &[f32], cfg: &NxConfig, tabs: &FormatTables) -> BlockCode {
    let Some(e_shared) = shared_exponent(v) else {
        // all-zero block: canonical zero encoding
        return BlockCode {
            e_shared: E_SHARED_MIN as i16,
            nano: 0,
            fmt_mx: cfg.base == BaseFormat::Mx || cfg.enable_am,
            codes: vec![0; v.len()],
        };
    };
    let vmax = finite_max_abs(v);

    let formats: &[bool] = if cfg.enable_am {
        &[true, false]
    } else {
        match cfg.base {
            BaseFormat::Mx => &[true],
            BaseFormat::Bfp => &[false],
        }
    };

    let mut best: Option<(f64, BlockCode)> = None;
    for &fmt_mx in formats {
        let bf = tabs.get(fmt_mx);
        let nanos: Vec<u8> = if cfg.enable_nm {
            match cfg.nano_mode {
                NanoMode::TwoCandidate => {
                    let m = nano_candidate(vmax, bf, e_shared);
                    if m == 0 {
                        vec![0]
                    } else {
                        vec![m, 0]
                    }
                }
                NanoMode::Exhaustive => vec![0, 1, 2, 3],
            }
        } else {
            vec![0]
        };
        for nano in nanos {
            let (codes, sse) = quantize_block_fixed(v, bf, e_shared, nano);
            let better = match &best {
                None => true,
                Some((b, _)) => sse < *b,
            };
            if better {
                best = Some((
                    sse,
                    BlockCode { e_shared: e_shared as i16, nano, fmt_mx, codes },
                ));
            }
        }
    }
    best.unwrap().1
}

/// Dequantize one block (reference path; the LUT fast path lives in
/// [`crate::dequant`]).
pub fn dequantize_block(block: &BlockCode, tabs: &FormatTables, out: &mut [f32]) {
    let bf = tabs.get(block.fmt_mx);
    let scale = block.scale(tabs);
    for (o, &c) in out.iter_mut().zip(&block.codes) {
        *o = bf.decode(c) * scale;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fakequant(v: &[f32], cfg: &NxConfig) -> Vec<f32> {
        let tabs = cfg.tables();
        let b = quantize_block(v, cfg, &tabs);
        let mut out = vec![0.0; v.len()];
        dequantize_block(&b, &tabs, &mut out);
        out
    }

    fn sse(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum()
    }

    #[test]
    fn mxfp4_quantizes_fig2_style_vector() {
        // values already near the element domain: E=2, X=1
        let v = [6.0, -3.0, 0.5, 1.5, 2.2, -0.1, 0.0, 4.9];
        let cfg = NxConfig::mxfp(4);
        let out = fakequant(&v, &cfg);
        assert_eq!(out[0], 6.0);
        assert_eq!(out[1], -3.0);
        assert_eq!(out[2], 0.5);
        assert_eq!(out[3], 1.5);
        assert_eq!(out[4], 2.0);
        assert_eq!(out[5], 0.0);
        assert_eq!(out[6], 0.0);
        assert_eq!(out[7], 4.0); // 4.9 -> nearer 4 than 6
    }

    #[test]
    fn bfp4_integer_grid() {
        let v = [7.0, -3.2, 1.4, 0.2];
        let cfg = NxConfig::bfp(4);
        let out = fakequant(&v, &cfg);
        // E = 2, step = 1
        assert_eq!(out, vec![7.0, -3.0, 1.0, 0.0]);
    }

    #[test]
    fn nanomantissa_reproduces_fig4_example() {
        // Paper Fig. 4: block max -7.4 (scaled domain), MxFP4 alone gives -6
        // (error 1.4); with NanoMantissa 1.25 it gives -7.5 (error 0.1).
        let v = [-7.4, 2.0, 1.0, 0.5, -1.5, 3.0, 0.0, 1.0];
        let plain = fakequant(&v, &NxConfig::mxfp(4));
        assert_eq!(plain[0], -6.0);
        let nm = fakequant(&v, &NxConfig::nxfp_nm(4));
        assert!((nm[0] - -7.5).abs() < 1e-6, "got {}", nm[0]);
    }

    #[test]
    fn nm_never_hurts_mse() {
        let mut rng = crate::util::rng::Rng::seeded(11);
        for _ in 0..200 {
            let v: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let base = sse(&v, &fakequant(&v, &NxConfig::mxfp(4)));
            let nm = sse(&v, &fakequant(&v, &NxConfig::nxfp_nm(4)));
            assert!(nm <= base + 1e-9, "NM raised SSE: {nm} > {base}");
        }
    }

    #[test]
    fn am_never_hurts_mse() {
        let mut rng = crate::util::rng::Rng::seeded(12);
        for _ in 0..200 {
            let v: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let nm = sse(&v, &fakequant(&v, &NxConfig::nxfp_nm(4)));
            let nm_am = sse(&v, &fakequant(&v, &NxConfig::nxfp_nm_am(4)));
            assert!(nm_am <= nm + 1e-9);
        }
    }

    #[test]
    fn cr_never_hurts_mse() {
        let mut rng = crate::util::rng::Rng::seeded(13);
        for _ in 0..200 {
            let v: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 1.5)).collect();
            let nm_am = sse(&v, &fakequant(&v, &NxConfig::nxfp_nm_am(4)));
            let full = sse(&v, &fakequant(&v, &NxConfig::nxfp(4)));
            assert!(full <= nm_am + 1e-9);
        }
    }

    #[test]
    fn exhaustive_nano_at_least_as_good_as_two_candidate() {
        let mut rng = crate::util::rng::Rng::seeded(14);
        for _ in 0..100 {
            let v: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let two = sse(&v, &fakequant(&v, &NxConfig::nxfp(4)));
            let exh = sse(
                &v,
                &fakequant(&v, &NxConfig::nxfp(4).with_nano_mode(NanoMode::Exhaustive)),
            );
            assert!(exh <= two + 1e-9);
        }
    }

    #[test]
    fn recycled_code_decodes_to_half_min() {
        let cfg = NxConfig::nxfp(4);
        let tabs = cfg.tables();
        // FP4 min positive level 0.5 -> recycled value -0.25
        assert_eq!(tabs.mx.decode(0b1000), -0.25);
        // BFP4 min positive level 1 -> -0.5
        assert_eq!(tabs.bfp.decode(0b1000), -0.5);
    }

    #[test]
    fn minus_zero_is_canonicalized_without_cr() {
        let cfg = NxConfig::mxfp(4);
        let tabs = cfg.tables();
        // a tiny negative value rounds to zero -> must emit +0, not -0 code
        assert_eq!(tabs.mx.encode(-0.01), 0);
        assert_eq!(tabs.mx.decode(0b1000), 0.0);
    }

    #[test]
    fn all_zero_block() {
        let v = [0.0f32; 32];
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(4), NxConfig::nxfp(4)] {
            let out = fakequant(&v, &cfg);
            assert!(out.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn single_element_and_partial_blocks() {
        let v = [3.7f32];
        let out = fakequant(&v, &NxConfig::nxfp(4));
        assert!((out[0] - 3.7).abs() < 0.5);
    }

    #[test]
    fn huge_and_tiny_magnitudes_clamp_to_e8m0() {
        let v = [3.0e38f32, 1.0];
        let out = fakequant(&v, &NxConfig::mxfp(4));
        assert!(out[0].is_finite());
        let tiny = [1.0e-44f32, -1.0e-45];
        let out = fakequant(&tiny, &NxConfig::mxfp(4));
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn footprint_accounting_matches_paper() {
        // NxFP5 (11 + 32*5 = 171 bits/block) vs MxFP6 (8 + 32*6 = 200):
        // 14.5% smaller — the paper's footprint win.
        let nx5 = NxConfig::nxfp(5).footprint_bits(32);
        let mx6 = NxConfig::mxfp(6).footprint_bits(32);
        assert_eq!(nx5, 171);
        assert_eq!(mx6, 200);
        assert!((1.0 - nx5 as f64 / mx6 as f64 - 0.145).abs() < 0.01);
    }

    #[test]
    fn effective_bits() {
        assert!((NxConfig::mxfp(4).effective_bits() - 4.25).abs() < 1e-12);
        assert!((NxConfig::nxfp(4).effective_bits() - (4.0 + 11.0 / 32.0)).abs() < 1e-12);
    }

    #[test]
    fn names() {
        assert_eq!(NxConfig::bfp(4).name(), "BFP4");
        assert_eq!(NxConfig::mxfp(4).name(), "MxFP4-E2M1");
        assert_eq!(NxConfig::nxfp(4).name(), "NxFP4 (NM+AM+CR)");
        assert_eq!(NxConfig::nxfp_nm(5).name(), "NxFP5 (NM)");
    }

    #[test]
    fn code_round_trip_all_formats() {
        // decode . encode is identity on every representable value
        for cfg in [
            NxConfig::bfp(4),
            NxConfig::bfp(6),
            NxConfig::mxfp(4),
            NxConfig::mxfp(5),
            NxConfig::mxfp(6),
            NxConfig::mxfp(8), // E4M3 incl. saturation below the NaN code
            NxConfig::nxfp(4),
        ] {
            let tabs = cfg.tables();
            for bf in [&tabs.mx, &tabs.bfp] {
                for idx in 0..bf.levels.len() {
                    for sign in [1.0f32, -1.0] {
                        let v = sign * bf.levels[idx];
                        let c = bf.encode(v);
                        assert_eq!(bf.decode(c), v + 0.0, "{} idx={idx}", cfg.name());
                    }
                }
                if let Some(r) = bf.recycle {
                    let c = bf.encode(r);
                    assert_eq!(bf.decode(c), r, "recycled value not a fixed point");
                }
            }
        }
    }

    #[test]
    fn mxfp8_e4m3_block() {
        // 8-bit path: levels up to 448, idx field 7 bits
        let cfg = NxConfig::mxfp(8);
        let v = [400.0f32, -0.4, 3.1, 250.0];
        let out = fakequant(&v, &cfg);
        // E=8, X=2^0... relative error should be tiny at 8 bits
        for (a, b) in v.iter().zip(&out) {
            assert!((a - b).abs() <= 0.07 * a.abs().max(1.0), "{a} -> {b}");
        }
    }

    #[test]
    fn nano_candidate_range() {
        let bf = BlockFormat::new(ElementFormat::mx_default(4), None);
        // vmax exactly at the cap -> 0; slightly above -> 1; way above -> 3
        let e = 2; // cap = 6 * 2^0 = 6
        assert_eq!(nano_candidate(6.0, &bf, e), 0);
        assert_eq!(nano_candidate(7.4, &bf, e), 1); // the Fig. 4 example
        assert_eq!(nano_candidate(7.9, &bf, e), 1);
        // ratio can't reach 1.33+ for E2M1 (maxabs < 2^(E+1) = 8/6 = 1.33)
        assert!(nano_candidate(100.0, &bf, e) == 3); // clamped anyway
    }

    #[test]
    fn finite_max_abs_filters_nonfinite() {
        assert_eq!(finite_max_abs(&[1.0, -3.0, f32::INFINITY, f32::NAN]), 3.0);
        assert_eq!(finite_max_abs(&[f32::INFINITY, f32::NAN]), 0.0);
        assert_eq!(finite_max_abs(&[]), 0.0);
        assert_eq!(finite_max_abs(&[-0.0, 0.5]), 0.5);
    }

    #[test]
    fn nonfinite_elements_do_not_hijack_nano_candidate() {
        // Regression: the vmax fold used to include Inf, so one Inf element
        // saturated `nano_candidate` at 3 — and because the block SSE is
        // NaN-poisoned (first candidate always wins), nano=3 shipped. The
        // oracle filters non-finite from vmax; so must we.
        let cfg = NxConfig::nxfp(4);
        let tabs = cfg.tables();
        let v = [f32::INFINITY, 1.0, -0.5, 0.25];
        let b = quantize_block(&v, &cfg, &tabs);
        // finite max 1.0 at e=0 sits below the Mx cap -> nano must be 0
        assert_eq!(b.nano, 0, "Inf hijacked the NanoMantissa candidate");
        // the Inf element itself still saturates to the top magnitude code
        let top = (tabs.get(b.fmt_mx).levels.len() - 1) as u8;
        assert_eq!(b.codes[0], top);
        // finite elements must match a block without the Inf
        let fin = quantize_block(&[0.0, 1.0, -0.5, 0.25], &cfg, &tabs);
        assert_eq!(&b.codes[1..], &fin.codes[1..]);
        // NaN variant: candidate order likewise driven by finite values only
        let n = quantize_block(&[f32::NAN, 1.0, -0.5, 0.25], &cfg, &tabs);
        assert_eq!(n.nano, 0);
        assert_eq!(n.codes[0], top);
    }

    #[test]
    fn shared_exponent_cases() {
        assert_eq!(shared_exponent(&[0.0, 0.0]), None);
        assert_eq!(shared_exponent(&[0.5, -0.25]), Some(-1));
        assert_eq!(shared_exponent(&[6.0]), Some(2));
        assert_eq!(shared_exponent(&[f32::NAN, 2.0]), Some(1));
        assert_eq!(shared_exponent(&[f32::INFINITY]), None);
    }

    #[test]
    fn idempotent_fakequant() {
        // quantizing an already-quantized vector is exact (non-NM formats;
        // see quant::tests::prop_dequant_values_on_grid for why NM is
        // excluded)
        let mut rng = crate::util::rng::Rng::seeded(15);
        let am_cr = NxConfig { enable_nm: false, ..NxConfig::nxfp(4) };
        for cfg in [NxConfig::bfp(4), NxConfig::mxfp(4), am_cr] {
            let v: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let q1 = fakequant(&v, &cfg);
            let q2 = fakequant(&q1, &cfg);
            assert_eq!(q1, q2, "{} not idempotent", cfg.name());
        }
    }
}
