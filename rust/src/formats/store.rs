//! Flat structure-of-arrays storage for quantized blocks — the crate's
//! storage-native layout.
//!
//! The legacy `Vec<BlockCode>` layout paid one heap allocation per 16–32
//! element block (every `BlockCode` owns a `Vec<u8>`), which dominated
//! `quantize_matrix` / `KvCache::append` at checkpoint and prefill scale.
//! [`BlockStore`] keeps **one contiguous codes buffer** (one byte per
//! element, row-major) plus flat per-block metadata arrays (`e_shared`,
//! `nano`, `fmt_mx`), so:
//!
//! * quantizing appends/writes into plain slices — zero per-block allocs,
//! * `PackedMatrix::from_store` walks the codes buffer linearly,
//! * thread stripes of `quantize_matrix` write disjoint sub-slices with no
//!   post-hoc collection.
//!
//! Geometry: `rows` logical rows of `row_len` values each, blocked
//! independently per row in `block_size` chunks (blocks never straddle
//! rows — a vector is simply `rows == 1`). Block `(r, bi)` covers codes
//! `[r*row_len + bi*k, ..)` and has flat metadata index
//! `r * blocks_per_row() + bi`.

use super::{BlockCode, FormatTables};
use crate::util::exp2i;

/// Flat SoA storage for the quantized blocks of one tensor (or one growing
/// KV stream). See the module docs for the layout contract.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockStore {
    /// Block size `k` (elements per full block).
    pub block_size: usize,
    /// Values per logical row (a vector is one row of `len` values).
    pub row_len: usize,
    /// Logical rows stored.
    pub rows: usize,
    /// Element codes, one byte each, row-major: `rows * row_len` entries.
    pub codes: Vec<u8>,
    /// Per-block shared exponents, flat-indexed: `rows * blocks_per_row()`.
    pub e_shared: Vec<i16>,
    /// Per-block 2-bit NanoMantissa fields.
    pub nano: Vec<u8>,
    /// Per-block format index (0 = BFP, 1 = Mx), stored as a byte.
    pub fmt_mx: Vec<u8>,
}

impl BlockStore {
    /// Empty store (no rows yet) — the KV-cache starting state.
    pub fn new(row_len: usize, block_size: usize) -> Self {
        assert!(block_size > 0);
        BlockStore {
            block_size,
            row_len,
            rows: 0,
            codes: Vec::new(),
            e_shared: Vec::new(),
            nano: Vec::new(),
            fmt_mx: Vec::new(),
        }
    }

    /// Pre-sized zeroed store for `rows` rows — the `quantize_matrix`
    /// destination (thread stripes fill disjoint ranges in place).
    pub fn with_rows(rows: usize, row_len: usize, block_size: usize) -> Self {
        let mut s = BlockStore::new(row_len, block_size);
        s.rows = rows;
        s.codes = vec![0; rows * row_len];
        let nb = rows * s.blocks_per_row();
        s.e_shared = vec![0; nb];
        s.nano = vec![0; nb];
        s.fmt_mx = vec![0; nb];
        s
    }

    #[inline]
    pub fn blocks_per_row(&self) -> usize {
        self.row_len.div_ceil(self.block_size)
    }

    #[inline]
    pub fn n_blocks(&self) -> usize {
        self.rows * self.blocks_per_row()
    }

    /// Reserve space for `additional` more rows (amortization control for
    /// append-heavy users like the KV cache).
    pub fn reserve_rows(&mut self, additional: usize) {
        self.codes.reserve(additional * self.row_len);
        let nb = additional * self.blocks_per_row();
        self.e_shared.reserve(nb);
        self.nano.reserve(nb);
        self.fmt_mx.reserve(nb);
    }

    /// Append one zeroed row and return its index; fill it in place via
    /// [`BlockStore::row_slices_mut`].
    pub fn push_row(&mut self) -> usize {
        self.push_rows(1)
    }

    /// Append `n` zeroed rows in **one** grow per stream (the bulk variant
    /// of [`BlockStore::push_row`] behind `KvCache::append_rows` — a
    /// chunked prefill appends a whole chunk with one resize instead of
    /// one per token). Returns the index of the first new row.
    pub fn push_rows(&mut self, n: usize) -> usize {
        let r = self.rows;
        self.rows += n;
        self.codes.resize(self.rows * self.row_len, 0);
        let nb = self.rows * self.blocks_per_row();
        self.e_shared.resize(nb, 0);
        self.nano.resize(nb, 0);
        self.fmt_mx.resize(nb, 0);
        r
    }

    /// Mutable views of row `r`: `(codes, e_shared, nano, fmt_mx)` — the
    /// destination slices a quantizer engine writes into.
    pub fn row_slices_mut(&mut self, r: usize) -> (&mut [u8], &mut [i16], &mut [u8], &mut [u8]) {
        let bpr = self.blocks_per_row();
        let codes = &mut self.codes[r * self.row_len..(r + 1) * self.row_len];
        let e = &mut self.e_shared[r * bpr..(r + 1) * bpr];
        let nano = &mut self.nano[r * bpr..(r + 1) * bpr];
        let fmt = &mut self.fmt_mx[r * bpr..(r + 1) * bpr];
        (codes, e, nano, fmt)
    }

    /// Codes-buffer range of flat block `flat`: `(start, len)`.
    #[inline]
    pub fn block_range(&self, flat: usize) -> (usize, usize) {
        let bpr = self.blocks_per_row();
        let (r, bi) = (flat / bpr, flat % bpr);
        let off = bi * self.block_size;
        (r * self.row_len + off, self.block_size.min(self.row_len - off))
    }

    /// Codes of flat block `flat` (tail blocks are short).
    #[inline]
    pub fn block_codes(&self, flat: usize) -> &[u8] {
        let (start, len) = self.block_range(flat);
        &self.codes[start..start + len]
    }

    /// Full dequantization scale of flat block `flat` under `tabs`
    /// (mirror of [`BlockCode::scale`]).
    #[inline]
    pub fn scale(&self, flat: usize, tabs: &FormatTables) -> f32 {
        let offset = tabs.get(self.fmt_mx[flat] != 0).offset;
        (1.0 + self.nano[flat] as f32 / 4.0) * exp2i(self.e_shared[flat] as i32 + offset)
    }

    /// Materialize one block in the legacy owned form (test/interop path —
    /// allocates; the hot paths read the flat buffers directly).
    pub fn block(&self, flat: usize) -> BlockCode {
        BlockCode {
            e_shared: self.e_shared[flat],
            nano: self.nano[flat],
            fmt_mx: self.fmt_mx[flat] != 0,
            codes: self.block_codes(flat).to_vec(),
        }
    }

    /// Materialize every block in the legacy layout (test/interop path).
    pub fn to_block_codes(&self) -> Vec<BlockCode> {
        (0..self.n_blocks()).map(|f| self.block(f)).collect()
    }

    /// Build a store from legacy per-block codes (inverse of
    /// [`BlockStore::to_block_codes`]).
    pub fn from_block_codes(
        rows: usize,
        row_len: usize,
        block_size: usize,
        blocks: &[BlockCode],
    ) -> Self {
        let mut s = BlockStore::with_rows(rows, row_len, block_size);
        assert_eq!(blocks.len(), s.n_blocks(), "block count mismatch");
        for (flat, b) in blocks.iter().enumerate() {
            let (start, len) = s.block_range(flat);
            assert_eq!(b.codes.len(), len, "block {flat} length mismatch");
            s.codes[start..start + len].copy_from_slice(&b.codes);
            s.e_shared[flat] = b.e_shared;
            s.nano[flat] = b.nano;
            s.fmt_mx[flat] = b.fmt_mx as u8;
        }
        s
    }

    /// Dequantize flat block `flat` into `out` (reference semantics, same
    /// as [`super::dequantize_block`] on the materialized block).
    pub fn dequantize_block_into(&self, flat: usize, tabs: &FormatTables, out: &mut [f32]) {
        let bf = tabs.get(self.fmt_mx[flat] != 0);
        let scale = self.scale(flat, tabs);
        for (o, &c) in out.iter_mut().zip(self.block_codes(flat)) {
            *o = bf.decode(c) * scale;
        }
    }

    /// Drop every row past `rows`, keeping the first `rows` bit-identical.
    /// The copy-on-write primitive of the paged KV cache: a truncated
    /// clone of a shared page keeps exactly the adopted prefix. A no-op
    /// when `rows >= self.rows`.
    pub fn truncate_rows(&mut self, rows: usize) {
        if rows >= self.rows {
            return;
        }
        let bpr = self.blocks_per_row();
        self.rows = rows;
        self.codes.truncate(rows * self.row_len);
        self.e_shared.truncate(rows * bpr);
        self.nano.truncate(rows * bpr);
        self.fmt_mx.truncate(rows * bpr);
    }

    /// Append the first `rows` rows of `other` (same geometry) as new rows
    /// of `self`, bit-identically. Because blocks never straddle rows, a
    /// row's codes and per-block metadata are self-contained slices that
    /// concatenate freely — this is how a paged cache materializes its
    /// logical flat stream ([`BlockStore`] page concatenation) and how a
    /// COW clone copies a prefix.
    pub fn append_rows_from(&mut self, other: &BlockStore, rows: usize) {
        assert_eq!(self.row_len, other.row_len, "row_len mismatch");
        assert_eq!(self.block_size, other.block_size, "block_size mismatch");
        assert!(rows <= other.rows, "append_rows_from: {} > {} rows", rows, other.rows);
        let bpr = self.blocks_per_row();
        self.rows += rows;
        self.codes.extend_from_slice(&other.codes[..rows * self.row_len]);
        self.e_shared.extend_from_slice(&other.e_shared[..rows * bpr]);
        self.nano.extend_from_slice(&other.nano[..rows * bpr]);
        self.fmt_mx.extend_from_slice(&other.fmt_mx[..rows * bpr]);
    }

    /// Owned copy of the first `rows` rows (COW page-split helper).
    pub fn clone_prefix(&self, rows: usize) -> BlockStore {
        let mut s = BlockStore::new(self.row_len, self.block_size);
        s.append_rows_from(self, rows);
        s
    }

    pub fn clear(&mut self) {
        self.rows = 0;
        self.codes.clear();
        self.e_shared.clear();
        self.nano.clear();
        self.fmt_mx.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NxConfig;

    #[test]
    fn geometry_partial_tail() {
        // 2 rows of 5 values, k=2 -> 3 blocks/row, tail block of 1
        let s = BlockStore::with_rows(2, 5, 2);
        assert_eq!(s.blocks_per_row(), 3);
        assert_eq!(s.n_blocks(), 6);
        assert_eq!(s.block_range(0), (0, 2));
        assert_eq!(s.block_range(2), (4, 1));
        assert_eq!(s.block_range(3), (5, 2)); // row 1 starts at codes[5]
        assert_eq!(s.block_range(5), (9, 1));
    }

    #[test]
    fn push_rows_bulk_matches_repeated_push_row() {
        // 5-value rows, k=2 -> partial tail block per row
        let mut bulk = BlockStore::new(5, 2);
        let mut single = BlockStore::new(5, 2);
        let r0 = bulk.push_rows(3);
        assert_eq!(r0, 0);
        for _ in 0..3 {
            single.push_row();
        }
        assert_eq!(bulk, single);
        assert_eq!(bulk.push_rows(2), 3);
        assert_eq!(bulk.rows, 5);
        assert_eq!(bulk.codes.len(), 25);
        assert_eq!(bulk.e_shared.len(), 5 * 3);
        // zero-row bulk append is a no-op
        assert_eq!(bulk.push_rows(0), 5);
        assert_eq!(bulk.rows, 5);
    }

    #[test]
    fn push_row_grows_all_streams() {
        let mut s = BlockStore::new(6, 4);
        assert_eq!(s.n_blocks(), 0);
        let r = s.push_row();
        assert_eq!(r, 0);
        assert_eq!(s.codes.len(), 6);
        assert_eq!(s.e_shared.len(), 2);
        let (codes, e, nano, fmt) = s.row_slices_mut(0);
        assert_eq!(codes.len(), 6);
        assert_eq!((e.len(), nano.len(), fmt.len()), (2, 2, 2));
        s.clear();
        assert_eq!(s.rows, 0);
        assert_eq!(s.n_blocks(), 0);
    }

    #[test]
    fn legacy_round_trip() {
        let mut s = BlockStore::with_rows(2, 5, 4);
        for (i, c) in s.codes.iter_mut().enumerate() {
            *c = i as u8;
        }
        for flat in 0..s.n_blocks() {
            s.e_shared[flat] = flat as i16 - 2;
            s.nano[flat] = (flat % 4) as u8;
            s.fmt_mx[flat] = (flat % 2) as u8;
        }
        let legacy = s.to_block_codes();
        assert_eq!(legacy.len(), 4);
        assert_eq!(legacy[1].codes, vec![4]); // row-0 tail block
        let back = BlockStore::from_block_codes(2, 5, 4, &legacy);
        assert_eq!(back, s);
    }

    /// Filled store with distinct per-cell values (5-value rows, k=2 →
    /// partial tail block per row) so prefix copies are distinguishable.
    fn filled(rows: usize) -> BlockStore {
        let mut s = BlockStore::with_rows(rows, 5, 2);
        for (i, c) in s.codes.iter_mut().enumerate() {
            *c = i as u8;
        }
        for flat in 0..s.n_blocks() {
            s.e_shared[flat] = flat as i16 - 7;
            s.nano[flat] = (flat % 4) as u8;
            s.fmt_mx[flat] = (flat % 2) as u8;
        }
        s
    }

    #[test]
    fn truncate_rows_keeps_prefix_bit_identical() {
        let full = filled(4);
        for keep in 0..=4 {
            let mut t = full.clone();
            t.truncate_rows(keep);
            assert_eq!(t, full.clone_prefix(keep), "keep={keep}");
            assert_eq!(t.rows, keep);
            assert_eq!(t.codes.len(), keep * 5);
            assert_eq!(t.e_shared.len(), keep * 3);
        }
        // truncating past the end is a no-op
        let mut t = full.clone();
        t.truncate_rows(9);
        assert_eq!(t, full);
    }

    #[test]
    fn append_rows_from_concatenates_bit_identically() {
        let full = filled(4);
        // rebuild row-by-row from single-row prefixal pieces
        let mut rebuilt = BlockStore::new(5, 2);
        for r in 0..4 {
            let mut piece = filled(4);
            // drop rows before r by shifting: emulate a page holding row r
            piece.codes.drain(..r * 5);
            piece.e_shared.drain(..r * 3);
            piece.nano.drain(..r * 3);
            piece.fmt_mx.drain(..r * 3);
            piece.rows -= r;
            rebuilt.append_rows_from(&piece, 1);
        }
        assert_eq!(rebuilt, full);
        // split/concat round trip at every cut point
        for cut in 0..=4 {
            let head = full.clone_prefix(cut);
            let mut glued = head.clone();
            let mut tail = full.clone();
            tail.codes.drain(..cut * 5);
            tail.e_shared.drain(..cut * 3);
            tail.nano.drain(..cut * 3);
            tail.fmt_mx.drain(..cut * 3);
            tail.rows -= cut;
            glued.append_rows_from(&tail, tail.rows);
            assert_eq!(glued, full, "cut={cut}");
        }
    }

    #[test]
    #[should_panic(expected = "append_rows_from")]
    fn append_rows_from_rejects_overrun() {
        let mut s = BlockStore::new(5, 2);
        let other = filled(2);
        s.append_rows_from(&other, 3);
    }

    #[test]
    fn scale_matches_legacy_block_scale() {
        let cfg = NxConfig::nxfp(4);
        let tabs = cfg.tables();
        let mut s = BlockStore::with_rows(1, 8, 8);
        s.e_shared[0] = 3;
        s.nano[0] = 2;
        s.fmt_mx[0] = 1;
        assert_eq!(s.scale(0, &tabs), s.block(0).scale(&tabs));
    }
}
