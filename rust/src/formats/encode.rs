//! Table-driven, allocation-free quantizer engine (the encode-side sibling
//! of [`crate::dequant`]'s LUT decode path).
//!
//! The reference path ([`super::quantize_block`]) is normative but slow: per
//! element it runs a nearest-level search with two subtractions and a tie
//! branch, then calls `decode` again just to accumulate the SSE, and every
//! candidate of the NanoMantissa/Adaptive-Microexponent search allocates a
//! fresh `Vec<u8>`. [`EncodePlan`] precomputes, once per `NxConfig`:
//!
//! * **decision thresholds** per format — the exact f32 values where the
//!   reference projection switches to the next code, found by bisecting the
//!   f32 bit space with [`project_magnitude`] as the oracle, so the
//!   per-element search collapses to a branchless threshold count that is
//!   bit-identical to the reference **by construction** (nearest, ties to
//!   even mantissa code, saturation — all baked into the thresholds);
//! * a **signed decode LUT** per format (`dec[code]`, recycled code
//!   included), so SSE accumulation is a table lookup instead of a `decode`
//!   call — the same `fl(dec * scale)` product the reference computes;
//! * the per-format level tables and recycle values the candidate loop
//!   needs.
//!
//! All candidate scratch lives in a caller-owned reusable
//! [`EncodeScratch`]; codes are written straight into caller slices
//! (normally a [`super::BlockStore`]), so the steady state performs **zero
//! heap allocations per block**. The contract, enforced by
//! `tests/engine_equivalence.rs`, is bit-identity with the reference path
//! for every config/toggle/special-value combination.

use super::element::project_magnitude;
use super::{
    finite_max_abs, nano_candidate, shared_exponent, BaseFormat, BlockFormat, FormatTables,
    NanoMode, NxConfig, E_SHARED_MIN,
};
use crate::util::exp2i;

/// Per-format precomputed tables (scale-free; the block scale is applied
/// per candidate at block time, exactly like the reference).
#[derive(Clone, Debug)]
struct FormatPlan {
    /// Sorted code-decision thresholds: the projected index of magnitude
    /// `m` is `#{t in thresholds : t <= m}` (see [`build_thresholds`]).
    thresholds: Vec<f32>,
    /// Signed decode LUT over all `2^bits` codes (recycle remap included):
    /// `dec[code] == BlockFormat::decode(code)`.
    dec: Vec<f32>,
    /// Sorted positive magnitudes (the reference level table).
    levels: Vec<f32>,
    /// Scaled-domain recycled value for code `10…0`, when CR is on.
    recycle: Option<f32>,
    /// Block-scale exponent offset of this format.
    offset: i32,
    /// `1 << (bits - 1)`.
    sign_bit: u8,
    /// `levels.len() - 1` (the NaN/saturation index).
    top_idx: usize,
}

/// For each adjacent level pair, bisect the positive-f32 bit space for the
/// smallest magnitude the reference projection sends to the upper index.
/// `project_magnitude` is monotone in the magnitude (nearest with ties to
/// even over a sorted table), so these thresholds reproduce it exactly:
/// `project_magnitude(levels, m) == #{t : t <= m}` for every finite `m`.
fn build_thresholds(levels: &[f32]) -> Vec<f32> {
    let mut out = Vec::with_capacity(levels.len() - 1);
    for i in 0..levels.len() - 1 {
        // invariant: project(lo) <= i < project(hi); positive f32 bit
        // patterns are order-isomorphic to their values
        let mut lo = levels[i].to_bits();
        let mut hi = levels[i + 1].to_bits();
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if project_magnitude(levels, f32::from_bits(mid)) > i {
                hi = mid;
            } else {
                lo = mid;
            }
        }
        out.push(f32::from_bits(hi));
    }
    out
}

impl FormatPlan {
    fn build(bf: &BlockFormat) -> Self {
        let n = 1usize << bf.bits();
        FormatPlan {
            thresholds: build_thresholds(&bf.levels),
            dec: (0..n).map(|c| bf.decode(c as u8)).collect(),
            levels: bf.levels.clone(),
            recycle: bf.recycle,
            offset: bf.offset,
            sign_bit: 1u8 << (bf.bits() - 1),
            top_idx: bf.levels.len() - 1,
        }
    }

    /// Bit-identical replacement for `project_magnitude(levels, m)`.
    #[inline]
    fn project(&self, m: f32) -> usize {
        if m.is_nan() {
            return self.top_idx; // direct-cast NaN saturates (reference rule)
        }
        let th = &self.thresholds;
        if th.len() <= 32 {
            // branchless count — autovectorizes for the 4/5/6-bit tables
            let mut n = 0usize;
            for &t in th {
                n += (t <= m) as usize;
            }
            n
        } else {
            th.partition_point(|&t| t <= m)
        }
    }
}

/// Reusable candidate scratch: holds the codes of the candidate being
/// evaluated so the search never allocates in steady state.
#[derive(Clone, Debug, Default)]
pub struct EncodeScratch {
    cand: Vec<u8>,
}

impl EncodeScratch {
    pub fn new() -> Self {
        EncodeScratch { cand: Vec::new() }
    }
}

/// Precomputed quantizer engine for one `NxConfig`. Build once per tensor
/// (or hold alongside a KV cache) and reuse across every block.
#[derive(Clone, Debug)]
pub struct EncodePlan {
    pub cfg: NxConfig,
    /// The reference-format tables (kept for `nano_candidate` and interop).
    pub tabs: FormatTables,
    mx: FormatPlan,
    bfp: FormatPlan,
    /// Candidate format order (Mx first under AM, else the base format).
    formats: [bool; 2],
    n_formats: usize,
    /// Format recorded for all-zero blocks (reference rule).
    zero_fmt_mx: bool,
    /// True when exactly one (format, nano) candidate exists — the SSE
    /// search (and its scratch pass) can be skipped entirely.
    single_candidate: bool,
}

impl EncodePlan {
    pub fn new(cfg: &NxConfig) -> Self {
        let tabs = cfg.tables();
        let (formats, n_formats) = if cfg.enable_am {
            ([true, false], 2)
        } else {
            ([cfg.base == BaseFormat::Mx, false], 1)
        };
        EncodePlan {
            mx: FormatPlan::build(&tabs.mx),
            bfp: FormatPlan::build(&tabs.bfp),
            formats,
            n_formats,
            zero_fmt_mx: cfg.base == BaseFormat::Mx || cfg.enable_am,
            single_candidate: n_formats == 1 && !cfg.enable_nm,
            tabs,
            cfg: cfg.clone(),
        }
    }

    #[inline]
    fn format(&self, fmt_mx: bool) -> &FormatPlan {
        if fmt_mx {
            &self.mx
        } else {
            &self.bfp
        }
    }

    /// Quantize one block, writing the element codes into `out`
    /// (`out.len() == v.len()`), and return `(e_shared, nano, fmt_mx)`.
    /// Bit-identical to [`super::quantize_block`] on the same input.
    pub fn quantize_block_into(
        &self,
        v: &[f32],
        scratch: &mut EncodeScratch,
        out: &mut [u8],
    ) -> (i16, u8, bool) {
        debug_assert_eq!(v.len(), out.len());
        let Some(e_shared) = shared_exponent(v) else {
            out.fill(0);
            return (E_SHARED_MIN as i16, 0, self.zero_fmt_mx);
        };
        if self.single_candidate {
            // one candidate: no SSE needed, encode straight into `out`
            let fmt_mx = self.formats[0];
            encode_candidate::<false>(self.format(fmt_mx), e_shared, 0, v, out);
            return (e_shared as i16, 0, fmt_mx);
        }
        let vmax = finite_max_abs(v);
        if scratch.cand.len() < v.len() {
            scratch.cand.resize(v.len(), 0);
        }
        let mut first = true;
        let mut best_sse = 0.0f64;
        let (mut best_nano, mut best_fmt) = (0u8, false);
        for &fmt_mx in &self.formats[..self.n_formats] {
            let fp = self.format(fmt_mx);
            let mut nanos = [0u8; 4];
            let n_nanos = if self.cfg.enable_nm {
                match self.cfg.nano_mode {
                    NanoMode::TwoCandidate => {
                        let m = nano_candidate(vmax, self.tabs.get(fmt_mx), e_shared);
                        if m == 0 {
                            1
                        } else {
                            nanos[0] = m;
                            2
                        }
                    }
                    NanoMode::Exhaustive => {
                        nanos = [0, 1, 2, 3];
                        4
                    }
                }
            } else {
                1
            };
            for &nano in &nanos[..n_nanos] {
                let cand = &mut scratch.cand[..v.len()];
                let sse = encode_candidate::<true>(fp, e_shared, nano, v, cand);
                // strictly-smaller-SSE wins in candidate order; the first
                // candidate always lands (even when SSE is NaN — blocks
                // with non-finite elements), exactly like the reference
                if first || sse < best_sse {
                    out.copy_from_slice(cand);
                    best_sse = sse;
                    best_nano = nano;
                    best_fmt = fmt_mx;
                    first = false;
                }
            }
        }
        (e_shared as i16, best_nano, best_fmt)
    }

    /// Quantize one logical row (blocked in `cfg.block_size` chunks) into
    /// flat destination slices — the [`super::BlockStore`] row layout.
    /// `codes.len() == v.len()`; the metadata slices hold one entry per
    /// block of the row.
    pub fn quantize_row_into(
        &self,
        v: &[f32],
        scratch: &mut EncodeScratch,
        codes: &mut [u8],
        e_shared: &mut [i16],
        nano: &mut [u8],
        fmt_mx: &mut [u8],
    ) {
        debug_assert_eq!(v.len(), codes.len());
        let k = self.cfg.block_size;
        for (bi, chunk) in v.chunks(k).enumerate() {
            let dst = &mut codes[bi * k..bi * k + chunk.len()];
            let (e, n, f) = self.quantize_block_into(chunk, scratch, dst);
            e_shared[bi] = e;
            nano[bi] = n;
            fmt_mx[bi] = f as u8;
        }
    }
}

/// One branchless encode pass for a fixed `(format, nano)` candidate:
/// threshold-count projection, LUT reconstruction, and (when `SSE`)
/// sequential f64 SSE accumulation — operation-for-operation the same f32
/// arithmetic as the reference `quantize_block_fixed`.
#[inline]
fn encode_candidate<const SSE: bool>(
    fp: &FormatPlan,
    e_shared: i32,
    nano: u8,
    v: &[f32],
    out: &mut [u8],
) -> f64 {
    let scale = (1.0 + nano as f32 / 4.0) * exp2i(e_shared + fp.offset);
    let inv = 1.0 / scale;
    let sign_bit = fp.sign_bit;
    let mut sse = 0.0f64;
    match fp.recycle {
        Some(r) => {
            for (o, &x) in out.iter_mut().zip(v) {
                let a = x * inv;
                let idx = fp.project(a.abs());
                let sign = a < 0.0;
                let grid = if sign { -fp.levels[idx] } else { fp.levels[idx] };
                let mut code = if idx == 0 {
                    0
                } else {
                    (sign as u8) * sign_bit | idx as u8
                };
                // recycled level competes in the nearest search; grid wins
                // exact ties (strict `<`), mirroring `BlockFormat::encode`
                if (a - r).abs() < (a - grid).abs() {
                    code = sign_bit;
                }
                *o = code;
                if SSE {
                    let back = fp.dec[code as usize] * scale;
                    let d = (x - back) as f64;
                    sse += d * d;
                }
            }
        }
        None => {
            for (o, &x) in out.iter_mut().zip(v) {
                let a = x * inv;
                let idx = fp.project(a.abs());
                let sign = a < 0.0;
                let code = if idx == 0 {
                    0
                } else {
                    (sign as u8) * sign_bit | idx as u8
                };
                *o = code;
                if SSE {
                    let back = fp.dec[code as usize] * scale;
                    let d = (x - back) as f64;
                    sse += d * d;
                }
            }
        }
    }
    sse
}

#[cfg(test)]
mod tests {
    use super::super::quantize_block;
    use super::*;
    use crate::util::rng::Rng;

    fn assert_engine_matches_reference(v: &[f32], cfg: &NxConfig) {
        let tabs = cfg.tables();
        let want = quantize_block(v, cfg, &tabs);
        let plan = EncodePlan::new(cfg);
        let mut scratch = EncodeScratch::new();
        let mut codes = vec![0u8; v.len()];
        let (e, nano, fmt) = plan.quantize_block_into(v, &mut scratch, &mut codes);
        assert_eq!(
            (e, nano, fmt, &codes),
            (want.e_shared, want.nano, want.fmt_mx, &want.codes),
            "{} diverged on {v:?}",
            cfg.name()
        );
    }

    #[test]
    fn thresholds_reproduce_projection_exactly() {
        // sweep magnitudes incl. exact levels, exact ties, and the bit
        // neighbours of every threshold
        for bf in [
            BlockFormat::new(crate::formats::ElementFormat::mx_default(4), None),
            BlockFormat::new(crate::formats::ElementFormat::mx_default(5), None),
            BlockFormat::new(crate::formats::ElementFormat::mx_default(6), None),
            BlockFormat::new(crate::formats::ElementFormat::bfp(6), None),
            BlockFormat::new(crate::formats::ElementFormat::mx_default(8), None),
        ] {
            let fp = FormatPlan::build(&bf);
            let mut probes: Vec<f32> = bf.levels.clone();
            for &t in &fp.thresholds {
                probes.push(t);
                probes.push(f32::from_bits(t.to_bits() - 1));
                probes.push(f32::from_bits(t.to_bits() + 1));
            }
            for w in bf.levels.windows(2) {
                probes.push((w[0] + w[1]) / 2.0); // exact midpoints (ties)
            }
            probes.push(0.0);
            probes.push(f32::INFINITY);
            probes.push(bf.top() * 4.0);
            for m in probes {
                assert_eq!(
                    fp.project(m),
                    project_magnitude(&bf.levels, m),
                    "m={m} ({:?})",
                    bf.elem
                );
            }
            assert_eq!(fp.project(f32::NAN), bf.levels.len() - 1);
        }
    }

    #[test]
    fn engine_matches_reference_randomized() {
        let mut rng = Rng::seeded(91);
        let cfgs = [
            NxConfig::bfp(4),
            NxConfig::mxfp(5),
            NxConfig::nxfp(4),
            NxConfig::nxfp(6),
            NxConfig::nxfp(5).with_nano_mode(NanoMode::Exhaustive),
        ];
        for cfg in &cfgs {
            for _ in 0..200 {
                let len = 1 + rng.below(33);
                let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                assert_engine_matches_reference(&v, cfg);
            }
        }
    }

    #[test]
    fn engine_matches_reference_specials() {
        for cfg in [NxConfig::nxfp(4), NxConfig::mxfp(5), NxConfig::bfp(6)] {
            assert_engine_matches_reference(&[0.0; 8], &cfg);
            assert_engine_matches_reference(&[-0.0, 0.0, 1.0, -1.0], &cfg);
            assert_engine_matches_reference(&[f32::NAN, 1.5, -0.25, 0.0], &cfg);
            assert_engine_matches_reference(&[f32::INFINITY, 1.0, -0.5], &cfg);
            assert_engine_matches_reference(&[f32::NEG_INFINITY, 0.125], &cfg);
            assert_engine_matches_reference(&[f32::INFINITY; 4], &cfg);
            assert_engine_matches_reference(&[3.0e38, 1.0e-44, -1.0], &cfg);
        }
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        // the same scratch across different blocks/configs must not leak
        let mut rng = Rng::seeded(92);
        let mut scratch = EncodeScratch::new();
        for cfg in [NxConfig::nxfp(6), NxConfig::nxfp(4)] {
            let plan = EncodePlan::new(&cfg);
            let tabs = cfg.tables();
            for _ in 0..50 {
                let len = 1 + rng.below(40);
                let v: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let want = quantize_block(&v, &cfg, &tabs);
                let mut codes = vec![0u8; v.len()];
                let got = plan.quantize_block_into(&v, &mut scratch, &mut codes);
                assert_eq!((got.0, got.1, got.2), (want.e_shared, want.nano, want.fmt_mx));
                assert_eq!(codes, want.codes);
            }
        }
    }
}
