//! Minimal 2-D tensor plus the statistics the evaluation needs
//! (MSE/SNR, histograms for the Fig. 3 profile).

pub mod stats;

use crate::util::rng::Rng;

/// Row-major 2-D f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Tensor2 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Tensor2 { rows, cols, data }
    }

    /// i.i.d. N(0, std) entries.
    pub fn random_normal(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor2::zeros(rows, cols);
        rng.fill_normal(&mut t.data, std);
        t
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Iterate `block_size`-wide chunks of one row (tail block may be short).
    pub fn row_blocks(&self, r: usize, block_size: usize) -> impl Iterator<Item = &[f32]> {
        self.row(r).chunks(block_size)
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_rows() {
        let t = Tensor2::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    fn row_blocks_partial_tail() {
        let t = Tensor2::from_vec(1, 5, vec![1., 2., 3., 4., 5.]);
        let blocks: Vec<&[f32]> = t.row_blocks(0, 2).collect();
        assert_eq!(blocks.len(), 3);
        assert_eq!(blocks[2], &[5.]);
    }

    #[test]
    fn random_normal_stats() {
        let mut rng = Rng::seeded(1);
        let t = Tensor2::random_normal(100, 100, 2.0, &mut rng);
        let mean: f32 = t.data.iter().sum::<f32>() / t.len() as f32;
        let var: f32 =
            t.data.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / t.len() as f32;
        assert!(mean.abs() < 0.05);
        assert!((var - 4.0).abs() < 0.2);
    }
}
