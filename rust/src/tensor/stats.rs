//! Error metrics and histograms used by the evaluation section.

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Mean absolute (L1) error.
pub fn l1(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).abs()).sum::<f64>() / a.len() as f64
}

/// Signal-to-quantization-noise ratio in dB.
pub fn sqnr_db(signal: &[f32], quantized: &[f32]) -> f64 {
    let sig_pow: f64 = signal.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let noise: f64 = signal
        .iter()
        .zip(quantized)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum();
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig_pow / noise).log10()
}

/// Fixed-range histogram (used by the Fig. 3 weight profile).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f32,
    pub hi: f32,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f32, hi: f32, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], underflow: 0, overflow: 0, total: 0 }
    }

    #[inline]
    pub fn add(&mut self, x: f32) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
            return;
        }
        if x >= self.hi {
            self.overflow += 1;
            return;
        }
        let f = (x - self.lo) / (self.hi - self.lo);
        let i = ((f * self.counts.len() as f32) as usize).min(self.counts.len() - 1);
        self.counts[i] += 1;
    }

    pub fn add_all(&mut self, xs: &[f32]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Bin centers (for plotting/printing).
    pub fn centers(&self) -> Vec<f32> {
        let w = (self.hi - self.lo) / self.counts.len() as f32;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f32 + 0.5))
            .collect()
    }

    /// Fraction of samples with |x| above `thresh` (outlier mass).
    pub fn fraction_outside(&self, thresh: f32) -> f64 {
        let mut out = self.underflow + self.overflow;
        for (c, &n) in self.centers().iter().zip(&self.counts) {
            if c.abs() > thresh {
                out += n;
            }
        }
        out as f64 / self.total.max(1) as f64
    }

    /// Render a terminal bar chart (one row per bin), used by the profile
    /// bench to reproduce Fig. 3 visually.
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mut s = String::new();
        for (c, &n) in self.centers().iter().zip(&self.counts) {
            let bar = "#".repeat((n as usize * width / max as usize).max(usize::from(n > 0)));
            s.push_str(&format!("{c:>7.2} | {bar} {n}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mse_and_l1_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert_eq!(mse(&[0.0, 0.0], &[1.0, -1.0]), 1.0);
        assert_eq!(l1(&[0.0, 0.0], &[1.0, -3.0]), 2.0);
    }

    #[test]
    fn sqnr_infinite_when_exact() {
        assert!(sqnr_db(&[1.0, 2.0], &[1.0, 2.0]).is_infinite());
    }

    #[test]
    fn sqnr_reasonable_value() {
        // noise power 1% of signal power -> 20 dB
        let s = [10.0f32, 10.0];
        let q = [11.0f32, 9.0];
        assert!((sqnr_db(&s, &q) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.add_all(&[-2.0, -0.9, -0.4, 0.1, 0.6, 3.0]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts, vec![1, 1, 1, 1]);
        assert_eq!(h.total, 6);
    }

    #[test]
    fn histogram_outlier_fraction() {
        let mut h = Histogram::new(-8.0, 8.0, 64);
        for _ in 0..99 {
            h.add(0.0);
        }
        h.add(7.9);
        let f = h.fraction_outside(6.0);
        assert!((f - 0.01).abs() < 1e-9);
    }
}
