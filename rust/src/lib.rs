//! # nxfp — Nanoscaling Floating-Point for direct-cast LLM compression
//!
//! Reproduction of *"Nanoscaling Floating-Point (NxFP): NanoMantissa,
//! Adaptive Microexponents, and Code Recycling for Direct-Cast Compression
//! of Large Language Models"* (Lo, Wei, Brooks — Harvard, 2024).
//!
//! The crate is the Layer-3 (deployment) half of a three-layer stack:
//!
//! * **L1** — a Pallas fake-quantization kernel (`python/compile/kernels/`)
//!   that implements the same block-format semantics on the accelerator side.
//! * **L2** — a JAX transformer LM (`python/compile/model.py`) whose
//!   train/eval/score/decode steps are AOT-lowered to HLO text at build time.
//! * **L3** — this crate: bit-exact format codecs, the direct-cast
//!   quantization pipeline (Algorithm 1), the on-the-fly dequantization hot
//!   path (paper Fig. 7), a PJRT runtime that executes the AOT artifacts, a
//!   training/eval driver, and a serving coordinator with a quantized
//!   KV-cache manager.
//!
//! See `examples/quickstart.rs` for a guided tour and `DESIGN.md` for the
//! normative format semantics shared with the Python oracle.

pub mod bench_util;
pub mod coordinator;
pub mod dequant;
pub mod eval;
pub mod formats;
pub mod models;
pub mod obs;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod spec;
pub mod tensor;
pub mod train;
pub mod util;

pub use formats::{
    BlockFormat, BlockStore, ElementFormat, EncodePlan, EncodeScratch, KvStream, NxConfig,
    QuantPolicy, TensorClass,
};
pub use quant::{quantize_matrix, quantize_matrix_with, quantize_vector, QuantizedMatrix};
pub use tensor::Tensor2;
