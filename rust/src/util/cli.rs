//! Minimal declarative CLI argument parser (clap and thiserror are
//! unavailable offline, so errors are hand-implemented).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments and
//! subcommands, with typed getters and auto-generated `--help`.

use std::collections::BTreeMap;

/// Declared option (for help text + validation).
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Parsed argument bag for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    specs: Vec<OptSpec>,
    prog: String,
    about: &'static str,
}

#[derive(Debug)]
pub enum CliError {
    Unknown(String, String),
    MissingValue(String),
    BadValue(String, String),
    Help(String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name, usage) => write!(f, "unknown option --{name}\n{usage}"),
            CliError::MissingValue(name) => write!(f, "option --{name} expects a value"),
            CliError::BadValue(name, val) => write!(f, "invalid value for --{name}: {val}"),
            CliError::Help(usage) => f.write_str(usage),
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    pub fn new(prog: &str, about: &'static str) -> Self {
        Args { prog: prog.to_string(), about, ..Default::default() }
    }

    /// Declare a value-taking option with an optional default.
    pub fn opt(mut self, name: &'static str, default: Option<&str>, help: &'static str) -> Self {
        self.specs.push(OptSpec {
            name,
            help,
            default: default.map(|s| s.to_string()),
            is_flag: false,
        });
        if let Some(d) = default {
            self.opts.insert(name.to_string(), d.to_string());
        }
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.prog, self.about);
        for spec in &self.specs {
            let head = if spec.is_flag {
                format!("  --{}", spec.name)
            } else {
                format!("  --{} <value>", spec.name)
            };
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<28} {}{def}\n", spec.help));
        }
        s
    }

    /// Parse a raw argument list (without the program name).
    pub fn parse(mut self, raw: &[String]) -> Result<Self, CliError> {
        let known = |name: &str, specs: &[OptSpec]| specs.iter().find(|s| s.name == name).cloned();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(CliError::Help(self.usage()));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = known(&name, &self.specs)
                    .ok_or_else(|| CliError::Unknown(name.clone(), self.usage()))?;
                if spec.is_flag {
                    self.flags.push(name);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    self.opts.insert(name, val);
                }
            } else {
                self.positional.push(tok.clone());
            }
        }
        Ok(self)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str) -> String {
        self.opts.get(name).cloned().unwrap_or_default()
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        let raw = self
            .opts
            .get(name)
            .ok_or_else(|| CliError::MissingValue(name.to_string()))?;
        raw.parse()
            .map_err(|_| CliError::BadValue(name.to_string(), raw.clone()))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, CliError> {
        self.get_parsed(name)
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, CliError> {
        self.get_parsed(name)
    }

    pub fn get_f32(&self, name: &str) -> Result<f32, CliError> {
        self.get_parsed(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn strs(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn base() -> Args {
        Args::new("prog", "test")
            .opt("steps", Some("100"), "number of steps")
            .opt("out", None, "output path")
            .flag("verbose", "chatty")
    }

    #[test]
    fn defaults_apply() {
        let a = base().parse(&strs(&[])).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 100);
        assert!(a.get("out").is_none());
        assert!(!a.has("verbose"));
    }

    #[test]
    fn parses_separated_and_inline_values() {
        let a = base()
            .parse(&strs(&["--steps", "7", "--out=x.bin", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 7);
        assert_eq!(a.get("out"), Some("x.bin"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(matches!(
            base().parse(&strs(&["--bogus"])),
            Err(CliError::Unknown(..))
        ));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            base().parse(&strs(&["--out"])),
            Err(CliError::MissingValue(..))
        ));
    }

    #[test]
    fn bad_typed_value_errors() {
        let a = base().parse(&strs(&["--steps", "zebra"])).unwrap();
        assert!(matches!(a.get_usize("steps"), Err(CliError::BadValue(..))));
    }

    #[test]
    fn help_requested() {
        assert!(matches!(base().parse(&strs(&["-h"])), Err(CliError::Help(_))));
    }
}
