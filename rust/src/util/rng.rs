//! Deterministic PRNG (xoshiro256** seeded via SplitMix64) plus the samplers
//! the synthetic-model and corpus generators need. Implemented in-tree
//! because `rand` is not available in the offline registry cache.

/// xoshiro256** generator. Fast, high-quality, reproducible across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller variate
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded generator; any u64 seed (including 0) is fine.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-thread / per-layer use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seeded(self.u64() ^ stream.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    #[inline]
    pub fn u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn u32(&mut self) -> u32 {
        (self.u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`. Lemire-style rejection-free for our purposes
    /// (modulo bias is negligible at u64 width for n << 2^64).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.u64() % n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (integer).
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.u64() % (hi - lo) as u64) as i64
    }

    /// Uniform f64 in `[0, 1)` with 53 random bits.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fill a slice with i.i.d. N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_f32(0.0, std);
        }
    }

    /// Pick one element of a slice uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Shuffle in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf-distributed sampler over `{0, .., n-1}` with exponent `s`, used by
/// the synthetic corpus to get natural-language-like token frequencies.
/// Uses an inverse-CDF table (n is small: vocab-sized).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        let same = (0..64).filter(|_| a.u64() == b.u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(4);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::seeded(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_is_monotone_decreasing_in_rank() {
        let mut r = Rng::seeded(6);
        let z = Zipf::new(50, 1.1);
        let mut counts = [0usize; 50];
        for _ in 0..100_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seeded(7);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
