//! Offline-friendly utilities.
//!
//! The build sandbox has no network access and only the `xla` dependency tree
//! in its cargo cache, so the usual ecosystem crates (clap, rand, proptest,
//! serde, criterion) are unavailable. This module provides the small, tested
//! replacements the rest of the crate uses:
//!
//! * [`rng`] — SplitMix64 / xoshiro256** PRNG with normal + Zipf samplers.
//! * [`cli`] — a tiny declarative command-line parser.
//! * [`proptest`] — randomized property-test driver with failing-seed replay.
//! * [`ser`] — a minimal length-prefixed binary serializer for checkpoints.

pub mod cli;
pub mod proptest;
pub mod rng;
pub mod ser;

/// `ldexp`-style scale: `2^e` as an `f32`, exact for the full normal range
/// and graceful (gradual underflow / saturate to inf) outside it.
#[inline]
pub fn exp2i(e: i32) -> f32 {
    if e >= -126 && e <= 127 {
        f32::from_bits(((e + 127) as u32) << 23)
    } else if e < -126 {
        // subnormal or zero
        if e < -149 {
            0.0
        } else {
            f32::from_bits(1u32 << (e + 149) as u32)
        }
    } else {
        f32::INFINITY
    }
}

/// `floor(log2(|x|))` for finite nonzero `x`, via bit inspection (handles
/// subnormals). Returns `None` for zero / NaN / inf.
#[inline]
pub fn floor_log2(x: f32) -> Option<i32> {
    let bits = x.to_bits() & 0x7fff_ffff;
    if bits == 0 || bits >= 0x7f80_0000 {
        return None;
    }
    let exp = (bits >> 23) as i32;
    if exp != 0 {
        Some(exp - 127)
    } else {
        // subnormal: exponent of the leading fraction bit
        let lead = 31 - (bits.leading_zeros() as i32); // position of MSB set
        Some(lead - 149)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp2i_matches_powi() {
        for e in -140..=127 {
            // f64 exp2 is exact over this range (f32::powi is not, for
            // subnormal results)
            assert_eq!(exp2i(e), (e as f64).exp2() as f32, "e={e}");
        }
        assert_eq!(exp2i(-150), 0.0);
        assert!(exp2i(128).is_infinite());
    }

    #[test]
    fn floor_log2_basics() {
        assert_eq!(floor_log2(1.0), Some(0));
        assert_eq!(floor_log2(1.5), Some(0));
        assert_eq!(floor_log2(2.0), Some(1));
        assert_eq!(floor_log2(0.75), Some(-1));
        assert_eq!(floor_log2(-6.0), Some(2));
        assert_eq!(floor_log2(0.0), None);
        assert_eq!(floor_log2(f32::NAN), None);
        assert_eq!(floor_log2(f32::INFINITY), None);
    }

    #[test]
    fn floor_log2_subnormals() {
        let tiny = f32::from_bits(1); // 2^-149
        assert_eq!(floor_log2(tiny), Some(-149));
        let sub = f32::from_bits(1 << 22); // 2^-127
        assert_eq!(floor_log2(sub), Some(-127));
    }

    #[test]
    fn floor_log2_random_agree_with_float_log2() {
        let mut r = rng::Rng::seeded(7);
        for _ in 0..10_000 {
            let x = (r.f32() - 0.5) * r.f32() * 1e6;
            if x == 0.0 {
                continue;
            }
            let want = x.abs().log2().floor() as i32;
            assert_eq!(floor_log2(x), Some(want), "x={x}");
        }
    }
}
