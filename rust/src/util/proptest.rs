//! Tiny property-testing driver (proptest/quickcheck are unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`]; the driver runs it for N
//! cases with derived seeds and, on failure, reports the exact seed so the
//! case can be replayed with `NXFP_PROP_SEED=<seed> cargo test <name>`.

use super::rng::Rng;

/// Number of cases per property (override with `NXFP_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("NXFP_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256)
}

/// Run `prop` for `cases` random cases. The closure returns `Err(msg)` to
/// fail. Panics with the failing seed on the first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Replay mode: run exactly one pinned seed.
    if let Ok(seed) = std::env::var("NXFP_PROP_SEED") {
        let seed: u64 = seed.parse().expect("NXFP_PROP_SEED must be a u64");
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at replayed seed {seed}: {msg}");
        }
        return;
    }
    let base = 0x5eed_0000_0000_0000u64 ^ fxhash(name);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        let mut rng = Rng::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (replay with \
                 NXFP_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Run with the default case count.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, default_cases(), prop);
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Assert two f32 slices are elementwise close.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let tol = atol + rtol * y.abs();
        if (x - y).abs() > tol && !(x.is_nan() && y.is_nan()) {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always-ok", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "NXFP_PROP_SEED")]
    fn failing_property_reports_seed() {
        check("always-bad", 5, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.5], 0.1, 0.0).is_err());
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-6], 1e-5, 0.0).is_ok());
    }
}
