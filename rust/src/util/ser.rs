//! Minimal binary serializer for checkpoints and quantized tensors
//! (serde is unavailable offline). Little-endian, length-prefixed, with a
//! magic/version header per file.

use std::io::{self, Read, Write};

pub const MAGIC: &[u8; 4] = b"NXFP";
pub const VERSION: u32 = 1;

pub struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    pub fn new(mut w: W) -> io::Result<Self> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        Ok(Writer { w })
    }

    pub fn u8(&mut self, v: u8) -> io::Result<()> {
        self.w.write_all(&[v])
    }

    pub fn u32(&mut self, v: u32) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }

    pub fn u64(&mut self, v: u64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }

    pub fn i32(&mut self, v: i32) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }

    pub fn f32(&mut self, v: f32) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }

    pub fn str(&mut self, s: &str) -> io::Result<()> {
        self.bytes(s.as_bytes())
    }

    pub fn bytes(&mut self, b: &[u8]) -> io::Result<()> {
        self.u64(b.len() as u64)?;
        self.w.write_all(b)
    }

    pub fn f32_slice(&mut self, xs: &[f32]) -> io::Result<()> {
        self.u64(xs.len() as u64)?;
        // bulk write via byte view
        let mut buf = Vec::with_capacity(xs.len() * 4);
        for x in xs {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        self.w.write_all(&buf)
    }

    pub fn u8_slice(&mut self, xs: &[u8]) -> io::Result<()> {
        self.bytes(xs)
    }

    pub fn finish(mut self) -> io::Result<W> {
        self.w.flush()?;
        Ok(self.w)
    }
}

pub struct Reader<R: Read> {
    r: R,
}

impl<R: Read> Reader<R> {
    pub fn new(mut r: R) -> io::Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "bad magic"));
        }
        let mut ver = [0u8; 4];
        r.read_exact(&mut ver)?;
        let v = u32::from_le_bytes(ver);
        if v != VERSION {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("unsupported version {v}"),
            ));
        }
        Ok(Reader { r })
    }

    pub fn u8(&mut self) -> io::Result<u8> {
        let mut b = [0u8; 1];
        self.r.read_exact(&mut b)?;
        Ok(b[0])
    }

    pub fn u32(&mut self) -> io::Result<u32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.r.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    pub fn i32(&mut self) -> io::Result<i32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(i32::from_le_bytes(b))
    }

    pub fn f32(&mut self) -> io::Result<f32> {
        let mut b = [0u8; 4];
        self.r.read_exact(&mut b)?;
        Ok(f32::from_le_bytes(b))
    }

    pub fn str(&mut self) -> io::Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b).map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }

    pub fn bytes(&mut self) -> io::Result<Vec<u8>> {
        let n = self.u64()? as usize;
        let mut b = vec![0u8; n];
        self.r.read_exact(&mut b)?;
        Ok(b)
    }

    pub fn f32_slice(&mut self) -> io::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let mut buf = vec![0u8; n * 4];
        self.r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn u8_slice(&mut self) -> io::Result<Vec<u8>> {
        self.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_types() {
        let mut buf = Vec::new();
        {
            let mut w = Writer::new(&mut buf).unwrap();
            w.u8(7).unwrap();
            w.u32(0xdead_beef).unwrap();
            w.u64(u64::MAX).unwrap();
            w.i32(-42).unwrap();
            w.f32(3.5).unwrap();
            w.str("héllo").unwrap();
            w.f32_slice(&[1.0, -2.0, f32::MIN_POSITIVE]).unwrap();
            w.u8_slice(&[1, 2, 3]).unwrap();
            w.finish().unwrap();
        }
        let mut r = Reader::new(&buf[..]).unwrap();
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xdead_beef);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.i32().unwrap(), -42);
        assert_eq!(r.f32().unwrap(), 3.5);
        assert_eq!(r.str().unwrap(), "héllo");
        assert_eq!(r.f32_slice().unwrap(), vec![1.0, -2.0, f32::MIN_POSITIVE]);
        assert_eq!(r.u8_slice().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"XXXX\x01\x00\x00\x00".to_vec();
        assert!(Reader::new(&buf[..]).is_err());
    }

    #[test]
    fn rejects_bad_version() {
        let mut buf = MAGIC.to_vec();
        buf.extend_from_slice(&99u32.to_le_bytes());
        assert!(Reader::new(&buf[..]).is_err());
    }

    #[test]
    fn truncated_input_errors() {
        let mut buf = Vec::new();
        {
            let mut w = Writer::new(&mut buf).unwrap();
            w.f32_slice(&[1.0; 16]).unwrap();
            w.finish().unwrap();
        }
        buf.truncate(buf.len() - 3);
        let mut r = Reader::new(&buf[..]).unwrap();
        assert!(r.f32_slice().is_err());
    }
}
