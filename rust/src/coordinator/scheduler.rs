//! Continuous-batching scheduler: slot-level admission over a fixed pool
//! of batch lanes.
//!
//! The decode artifact has a fixed batch shape `[B, L, S, D]`, so serving
//! owns exactly `B` **lanes**. Wave scheduling (`DecodeEngine::serve_wave`)
//! fills all lanes at once and holds every lane until the whole wave
//! drains: a short request parks an idle lane for as long as the longest
//! request in its wave keeps decoding. This module replaces the wave
//! barrier with per-slot lifecycle:
//!
//! ```text
//! Queued ── admit (free lane) ──► Prefilling ──► Decoding ──► Finished
//!    ▲                                                           │
//!    └────────────── lane freed, next request admitted ◄─────────┘
//! ```
//!
//! The moment a slot finishes mid-step, its lane is zeroed and the next
//! queued request is admitted into it on the following step — prefill of
//! the newcomer proceeds *in the same batched steps* that keep decoding
//! the other lanes, so no lane ever waits for a wave boundary.
//!
//! # Admission policy
//!
//! `pop_next` is throughput-greedy: it picks the **shortest-prompt**
//! queued request (cheapest prefill, frees the lane for decode soonest;
//! FIFO among equals). Greedy ordering alone starves long prompts under a
//! stream of short ones, so every request carries its enqueue step and any
//! request that has waited more than `promote_after` engine steps becomes
//! **urgent**: urgent requests are admitted in strict FIFO order before
//! any non-urgent one. The wait of a request enqueued behind `n` earlier
//! arrivals is therefore bounded by `promote_after` plus the time for `n`
//! earlier urgents and one lane to drain — no unbounded starvation.
//!
//! The scheduler owns queue and lanes but never touches tensors; the
//! engine (`DecodeEngine::step_continuous`) drives admission, stepping,
//! and metrics. Lane *contents* live in the engine's step slabs; moving a
//! slot between lanes is `DecodeEngine::move_lane` (slab copy) with a
//! per-cache watermark reset (packed re-decode) as the fallback.
//!
//! # Prefix cache
//!
//! Under quantized KV the scheduler can also keep a **radix tree over
//! completed prompt prefills** ([`Scheduler::enable_prefix_cache`]): when
//! a slot finishes its prompt, its per-layer packed page tables are
//! registered under the prompt's token sequence
//! ([`Scheduler::register_prefixes`], retaining the pages); at admission
//! the engine asks for the longest registered prefix of the new prompt
//! ([`Scheduler::prefix_lookup`]) and maps those pages into the fresh slot
//! read-only. Deterministic quantization makes this sound: KV row `i`
//! depends only on tokens `0..=i`, so prompts sharing a token prefix
//! store bit-identical packed rows for it. A lookup never covers the
//! *whole* prompt — at least the final prompt token always goes through
//! the batched step (its logits are sampled), which also guarantees an
//! adopted slot still prefills at least one token.
//!
//! Admission cost becomes **suffix-aware**: the greedy key charges
//! `ceil(suffix_len / budget)` steps, where `suffix_len` is the prompt
//! minus its best cached prefix — a long prompt behind a hot system
//! prompt is as cheap to admit as a short one. Capacity is bounded by
//! `max_entries` with per-entry **LRU eviction**: every lookup or
//! registration stamps the touched entry with a logical clock, and a
//! registration at capacity evicts only the stalest entry (releasing its
//! page refs and repairing the radix path its prompt created), so a hot
//! system prompt survives arbitrary churn of cold one-off prompts instead
//! of being dropped by a wholesale epoch reset.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::rc::Rc;
use std::time::Instant;

use crate::obs::{TraceEvent, TraceSink};
use crate::quant::page::{PageId, PagePool};

use super::{GenRequest, Requeue, Slot, SlotState};

/// Which serving loop the front-end drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Legacy wave-at-a-time: batch up to `B` requests, run to completion.
    Wave,
    /// Slot-level continuous batching through [`Scheduler`].
    Continuous,
}

impl std::str::FromStr for SchedMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_lowercase().as_str() {
            "wave" => Ok(SchedMode::Wave),
            "continuous" | "cont" => Ok(SchedMode::Continuous),
            other => Err(format!("unknown scheduler mode {other} (wave|continuous)")),
        }
    }
}

/// A request waiting for a lane.
struct Queued {
    req: GenRequest,
    arrival: Instant,
    enq_step: u64,
    /// Times this request has already been requeued by slot-killing
    /// faults (0 for fresh arrivals).
    requeues: u32,
}

/// What `pop_next` decided, so the engine can account promotions.
pub struct Admission {
    pub req: GenRequest,
    pub arrival: Instant,
    /// Engine steps spent in the queue.
    pub waited_steps: u64,
    /// True when the anti-starvation rule overrode the greedy pick.
    pub promoted: bool,
    /// Requeue count carried through from a faulted slot.
    pub requeues: u32,
    /// True when the request waited past the max-queue-steps deadline
    /// ([`Scheduler::set_max_queue_steps`]) — the engine answers it with
    /// `FinishReason::Deadline` instead of admitting it.
    pub expired: bool,
}

/// One registered prefill: the prompt's per-layer page tables, with one
/// pool ref held per page for as long as the entry lives.
struct PrefixEntry {
    /// Prompt rows the page tables cover (== registered prompt length).
    rows: usize,
    /// Per-layer `(k_pages, v_pages)`, each `ceil(rows / page_rows)` long.
    pages: Vec<(Vec<PageId>, Vec<PageId>)>,
    /// The registered prompt itself, kept so LRU eviction can walk and
    /// repair exactly the radix path this entry created or inherited.
    prompt: Vec<i32>,
    /// Logical-clock stamp of the last lookup/registration touch (`Cell`
    /// because lookups run through `&self`).
    last_used: Cell<u64>,
}

/// Node in the radix tree over registered prompts. Every node is created
/// by some registration whose prompt runs through it, so `entry` is
/// always a valid index — a lookup that dies partway down an edge can
/// still adopt from that node's entry (it shares every matched token).
struct PrefixNode {
    /// Token labels on the edge from the parent (root edge is empty).
    edge: Vec<i32>,
    entry: usize,
    children: Vec<usize>,
}

/// Radix tree over completed prompt prefills; see the module docs.
struct PrefixCache {
    pool: Rc<RefCell<PagePool>>,
    nodes: Vec<PrefixNode>,
    /// Entry slab: `None` marks an evicted slot awaiting reuse, so the
    /// entry indices stored in nodes stay stable across evictions.
    entries: Vec<Option<PrefixEntry>>,
    free_entries: Vec<usize>,
    /// Node slots unlinked by eviction, reused by later inserts.
    free_nodes: Vec<usize>,
    /// Logical LRU clock, bumped on every touch.
    clock: Cell<u64>,
    max_entries: usize,
}

impl PrefixCache {
    fn new(pool: Rc<RefCell<PagePool>>, max_entries: usize) -> Self {
        let max_entries = max_entries.max(1);
        PrefixCache {
            pool,
            nodes: Vec::new(),
            entries: Vec::new(),
            free_entries: Vec::new(),
            free_nodes: Vec::new(),
            clock: Cell::new(0),
            max_entries,
        }
    }

    /// Entries currently resident (slab slots minus the free list).
    fn live_entries(&self) -> usize {
        self.entries.len() - self.free_entries.len()
    }

    /// Stamp `entry` with a fresh logical-clock tick.
    fn touch(&self, entry: usize) {
        let t = self.clock.get() + 1;
        self.clock.set(t);
        if let Some(e) = self.entries[entry].as_ref() {
            e.last_used.set(t);
        }
    }

    /// Longest registered prefix of `prompt`: `(matched_rows, entry)`.
    /// The entry's prompt shares at least `matched_rows` leading tokens,
    /// so the first `ceil(matched_rows / page_rows)` pages of its tables
    /// are bit-identical to what `prompt`'s own prefill would produce.
    fn lookup(&self, prompt: &[i32]) -> Option<(usize, usize)> {
        if self.nodes.is_empty() {
            return None;
        }
        let mut node = 0;
        let mut depth = 0;
        let mut best = None;
        loop {
            let Some(&next) = self.nodes[node]
                .children
                .iter()
                .find(|&&c| self.nodes[c].edge.first() == prompt.get(depth))
            else {
                break;
            };
            let edge = &self.nodes[next].edge;
            let mut m = 0;
            while m < edge.len() && depth + m < prompt.len() && edge[m] == prompt[depth + m] {
                m += 1;
            }
            depth += m;
            best = Some((depth, self.nodes[next].entry));
            if m < edge.len() || depth == prompt.len() {
                break;
            }
            node = next;
        }
        if let Some((_, e)) = best {
            self.touch(e);
        }
        best
    }

    /// Register a finished prefill. Retains every page in `pages`; skips
    /// prompts already fully covered by an existing entry. At capacity the
    /// least-recently-touched entry is evicted first (releasing its page
    /// refs), so a hot prefix survives churn of cold ones.
    fn register(&mut self, prompt: &[i32], pages: Vec<(Vec<PageId>, Vec<PageId>)>) {
        if prompt.is_empty() {
            return;
        }
        if let Some((rows, _)) = self.lookup(prompt) {
            if rows == prompt.len() {
                return;
            }
        }
        if self.live_entries() >= self.max_entries {
            self.evict_lru();
        }
        let mut pool = self.pool.borrow_mut();
        for (k, v) in &pages {
            for &id in k.iter().chain(v.iter()) {
                pool.retain(id);
            }
        }
        drop(pool);
        let t = self.clock.get() + 1;
        self.clock.set(t);
        let e = PrefixEntry {
            rows: prompt.len(),
            pages,
            prompt: prompt.to_vec(),
            last_used: Cell::new(t),
        };
        let entry = match self.free_entries.pop() {
            Some(i) => {
                self.entries[i] = Some(e);
                i
            }
            None => {
                self.entries.push(Some(e));
                self.entries.len() - 1
            }
        };
        self.insert(prompt, entry);
    }

    /// Evict the least-recently-touched entry: release its page refs,
    /// then repair the radix tree along the entry's own prompt path.
    fn evict_lru(&mut self) {
        let victim = self
            .entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| e.as_ref().map(|e| (e.last_used.get(), i)))
            .min()
            .map(|(_, i)| i);
        let Some(victim) = victim else { return };
        let e = self.entries[victim].take().unwrap();
        self.free_entries.push(victim);
        let mut pool = self.pool.borrow_mut();
        for (k, v) in &e.pages {
            for &id in k.iter().chain(v.iter()) {
                pool.release(id);
            }
        }
        drop(pool);
        self.repair_path(&e.prompt, victim);
    }

    /// Remove every reference to `victim` from the nodes on `prompt`'s
    /// path, deepest-first. Every node referencing an entry lies on that
    /// entry's prompt path (created by its registration, or inherited at
    /// an edge split the prompt runs through), so walking the stored
    /// prompt visits every node to fix: a childless node unlinks (its
    /// subtree spelled only the victim's prompt), one with children
    /// re-points at its first child's entry — live by then, because
    /// deeper path nodes were repaired first and off-path children never
    /// reference the victim. Sibling edges are not re-merged after an
    /// unlink; lookups stay correct either way.
    fn repair_path(&mut self, prompt: &[i32], victim: usize) {
        if self.nodes.is_empty() {
            return;
        }
        let mut path = vec![0usize];
        let mut node = 0;
        let mut depth = 0;
        while depth < prompt.len() {
            let Some(&next) = self.nodes[node]
                .children
                .iter()
                .find(|&&c| self.nodes[c].edge.first() == prompt.get(depth))
            else {
                break;
            };
            // the victim's own path always matches whole edges
            let edge_len = self.nodes[next].edge.len();
            if prompt.len() - depth < edge_len {
                break;
            }
            path.push(next);
            depth += edge_len;
            node = next;
        }
        for i in (0..path.len()).rev() {
            let n = path[i];
            if self.nodes[n].entry != victim {
                continue;
            }
            match self.nodes[n].children.first().copied() {
                Some(c) => self.nodes[n].entry = self.nodes[c].entry,
                None if i == 0 => {
                    // childless root: the whole tree spelled the victim
                    self.nodes.clear();
                    self.free_nodes.clear();
                }
                None => {
                    let parent = path[i - 1];
                    self.nodes[parent].children.retain(|&c| c != n);
                    self.nodes[n].edge = Vec::new();
                    self.free_nodes.push(n);
                }
            }
        }
    }

    /// Allocate a node, reusing a slot unlinked by eviction if any.
    fn new_node(&mut self, edge: Vec<i32>, entry: usize, children: Vec<usize>) -> usize {
        let n = PrefixNode { edge, entry, children };
        match self.free_nodes.pop() {
            Some(i) => {
                self.nodes[i] = n;
                i
            }
            None => {
                self.nodes.push(n);
                self.nodes.len() - 1
            }
        }
    }

    fn insert(&mut self, prompt: &[i32], entry: usize) {
        if self.nodes.is_empty() {
            self.nodes.push(PrefixNode { edge: Vec::new(), entry, children: Vec::new() });
        }
        let mut node = 0;
        let mut depth = 0;
        loop {
            let child = self.nodes[node]
                .children
                .iter()
                .copied()
                .find(|&c| self.nodes[c].edge.first() == prompt.get(depth));
            let Some(next) = child else {
                // no edge starts with our next token: hang the remainder
                // off `node` as a fresh leaf
                if depth < prompt.len() {
                    let leaf = self.new_node(prompt[depth..].to_vec(), entry, Vec::new());
                    self.nodes[node].children.push(leaf);
                }
                return;
            };
            let mut m = 0;
            while m < self.nodes[next].edge.len()
                && depth + m < prompt.len()
                && self.nodes[next].edge[m] == prompt[depth + m]
            {
                m += 1;
            }
            if m == self.nodes[next].edge.len() {
                depth += m;
                if depth == prompt.len() {
                    return; // existing path already spells the prompt
                }
                node = next;
                continue;
            }
            // edge diverges at m: split it with an intermediate node that
            // inherits `next`'s entry (that entry's prompt runs through it)
            let tail = self.nodes[next].edge.split_off(m);
            let head = std::mem::replace(&mut self.nodes[next].edge, tail);
            let inherited = self.nodes[next].entry;
            let mid = self.new_node(head, inherited, vec![next]);
            let pos = self.nodes[node].children.iter().position(|&c| c == next).unwrap();
            self.nodes[node].children[pos] = mid;
            if depth + m < prompt.len() {
                let leaf = self.new_node(prompt[depth + m..].to_vec(), entry, Vec::new());
                self.nodes[mid].children.push(leaf);
            }
            return;
        }
    }

    /// Drop every entry's page refs and clear the tree.
    fn release_all(&mut self) {
        let mut pool = self.pool.borrow_mut();
        for e in self.entries.drain(..).flatten() {
            for (k, v) in &e.pages {
                for &id in k.iter().chain(v.iter()) {
                    pool.release(id);
                }
            }
        }
        drop(pool);
        self.free_entries.clear();
        self.nodes.clear();
        self.free_nodes.clear();
    }
}

impl Drop for PrefixCache {
    fn drop(&mut self) {
        self.release_all();
    }
}

/// Admission queue + fixed lane pool. See the module docs for the policy.
pub struct Scheduler {
    queue: VecDeque<Queued>,
    slots: Vec<Option<Slot>>,
    promote_after: u64,
    /// Engine steps ticked so far (the clock the promotion rule runs on).
    step: u64,
    /// Per-step prefill token budget the engine runs under (see
    /// [`Scheduler::set_prefill_budget`]); the greedy admission key ranks
    /// by estimated prefill *steps* under this budget, not raw prompt
    /// length.
    prefill_budget: usize,
    /// Radix prefix cache over completed prefills; `None` until the
    /// front-end opts in via [`Scheduler::enable_prefix_cache`].
    prefix: Option<PrefixCache>,
    /// Bounded-admission cap: [`Scheduler::enqueue`] sheds arrivals once
    /// the queue holds this many (`usize::MAX` = unbounded). Requeues of
    /// already-admitted work are exempt — a faulted slot's request never
    /// turns into a shed.
    queue_cap: usize,
    /// Queue-steps deadline: a request that waits more than this many
    /// engine steps pops as [`Admission::expired`] (`None` = no bound).
    max_queue_steps: Option<u64>,
    /// Requests enqueued over the scheduler's lifetime.
    pub enqueued: u64,
    /// Batch lanes each admitted request occupies (1 = plain decode,
    /// 2 = speculative draft+verifier pairing). The slot pool is sized in
    /// whole lane *groups* — see [`Scheduler::with_lanes_per_request`].
    lanes_per_request: usize,
    /// Trace sink for `Enqueued`/`Requeued` lifecycle events; the no-op
    /// sink (the default) costs one null check per emission site.
    trace: TraceSink,
}

impl Scheduler {
    /// Default anti-starvation bound: a queued request overtakes shorter
    /// newcomers after this many engine steps.
    pub const DEFAULT_PROMOTE_AFTER: u64 = 64;

    /// Default prefix-cache capacity before LRU eviction begins.
    pub const DEFAULT_PREFIX_ENTRIES: usize = 512;

    pub fn new(max_batch: usize, promote_after: u64) -> Self {
        Self::with_lanes_per_request(max_batch, promote_after, 1)
    }

    /// Scheduler over a `max_batch`-lane pool where every admitted
    /// request occupies `lanes` lanes (speculative decoding pairs a draft
    /// lane with a verifier lane: `lanes == 2`). Admission capacity is
    /// counted in whole **groups** — the slot pool holds
    /// `max_batch / lanes` entries, so [`Scheduler::free_lane`],
    /// [`Scheduler::active`], queue-cap shed, promotion, and drain all
    /// operate on complete groups and a draft lane can never be admitted
    /// without its verifier lane. With an odd pool under pairing the
    /// unpairable remainder lane is simply never scheduled (a half-pair
    /// admission would be a correctness bug, not extra capacity).
    pub fn with_lanes_per_request(max_batch: usize, promote_after: u64, lanes: usize) -> Self {
        assert!(lanes >= 1, "lanes_per_request must be at least 1");
        assert!(
            max_batch >= lanes,
            "lane pool of {max_batch} cannot hold one {lanes}-lane request"
        );
        Scheduler {
            queue: VecDeque::new(),
            slots: (0..max_batch / lanes).map(|_| None).collect(),
            promote_after: promote_after.max(1),
            step: 0,
            prefill_budget: 1,
            prefix: None,
            queue_cap: usize::MAX,
            max_queue_steps: None,
            enqueued: 0,
            lanes_per_request: lanes,
            trace: TraceSink::disabled(),
        }
    }

    /// Lanes each admitted request occupies (see
    /// [`Scheduler::with_lanes_per_request`]).
    pub fn lanes_per_request(&self) -> usize {
        self.lanes_per_request
    }

    /// Attach a trace sink (a clone of the engine's, so queue-side and
    /// slot-side events land in one ring in emission order).
    pub fn set_trace_sink(&mut self, sink: TraceSink) {
        sink.set_step(self.step);
        self.trace = sink;
    }

    /// Bound the admission queue (`--queue-cap`); `usize::MAX` (the
    /// default) never sheds. Clamped to at least 1.
    pub fn set_queue_cap(&mut self, cap: usize) {
        self.queue_cap = cap.max(1);
    }

    /// Expire requests that wait more than `steps` engine steps in the
    /// queue (`None` = no bound). Enforced at pop time: an expired
    /// request still pops — flagged — so the engine can answer it with
    /// `FinishReason::Deadline` in arrival-ordered turn.
    pub fn set_max_queue_steps(&mut self, steps: Option<u64>) {
        self.max_queue_steps = steps;
    }

    /// Turn on prefix sharing over `pool` (the engine's page pool — see
    /// [`super::DecodeEngine::page_pool`]). Only meaningful with quantized
    /// KV: fp16-lane-only slots have no page tables to register, so the
    /// cache simply stays empty.
    pub fn enable_prefix_cache(&mut self, pool: Rc<RefCell<PagePool>>, max_entries: usize) {
        self.prefix = Some(PrefixCache::new(pool, max_entries));
    }

    pub fn prefix_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Rows of `prompt` a registered prefill already covers, capped at
    /// `prompt.len() - 1` — the final prompt token always runs through the
    /// batched step so its logits get sampled.
    fn prefix_rows(&self, prompt: &[i32]) -> usize {
        let cap = prompt.len().saturating_sub(1);
        self.prefix
            .as_ref()
            .and_then(|pc| pc.lookup(prompt))
            .map(|(rows, _)| rows.min(cap))
            .unwrap_or(0)
    }

    /// Longest shared prefix for a prompt being admitted: the row count
    /// plus the per-layer page tables covering exactly those rows (the
    /// entry's tables truncated to `ceil(rows / page_rows)` pages). Pages
    /// are **not** retained here — adoption
    /// ([`super::SlotKv::adopt_prefix`]) takes the refs, and nothing can
    /// release in between on this single-threaded path. Returns `None` on
    /// a miss or when the match rounds down to zero rows.
    pub fn prefix_lookup(
        &self,
        prompt: &[i32],
    ) -> Option<(usize, Vec<(Vec<PageId>, Vec<PageId>)>)> {
        let pc = self.prefix.as_ref()?;
        let (rows, entry) = pc.lookup(prompt)?;
        let rows = rows.min(prompt.len().saturating_sub(1));
        if rows == 0 {
            return None;
        }
        let n_pages = rows.div_ceil(pc.pool.borrow().page_rows());
        // entries reachable from the tree are live by the repair invariant
        let e = pc.entries[entry].as_ref()?;
        debug_assert!(e.rows >= rows);
        let pages = e
            .pages
            .iter()
            .map(|(k, v)| (k[..n_pages].to_vec(), v[..n_pages].to_vec()))
            .collect();
        Some((rows, pages))
    }

    /// Register every slot that just finished its prompt (first step in
    /// `Decoding`) into the prefix cache. Runs once per slot, at the one
    /// moment its page tables cover exactly the prompt rows; the engine
    /// calls this each step after `step_slots`. Registration retains the
    /// slot's pages — including its current tail, which the slot will
    /// copy-on-write at its next append.
    pub fn register_prefixes(&mut self) {
        let Some(pc) = self.prefix.as_mut() else { return };
        for slot in self.slots.iter_mut().flatten() {
            if slot.prefix_registered || slot.state != SlotState::Decoding {
                continue;
            }
            slot.prefix_registered = true;
            if let Some(kv) = slot.kv.as_ref() {
                if kv.fill() == slot.req.prompt.len() {
                    pc.register(&slot.req.prompt, kv.page_table());
                }
            }
        }
    }

    /// Drop every cached prefix (and the page refs it holds). Leak tests
    /// use this to prove the pool drains once slots are gone too.
    pub fn clear_prefix_cache(&mut self) {
        if let Some(pc) = self.prefix.as_mut() {
            pc.release_all();
        }
    }

    /// Make admission budget-aware: with chunked prefill a long prompt no
    /// longer costs `prompt_len` engine steps, so the greedy pick ranks
    /// queued requests by **estimated prefill steps under the budget**
    /// (`ceil(prompt_len / budget)`, the cost when the whole per-step
    /// budget lands on one slot) instead of raw prompt length. At budget
    /// 1 this degenerates to shortest-prompt-first (today's order); at a
    /// large budget most requests tie at one step and admission becomes
    /// plain FIFO — fairer, with nothing left to gain from reordering.
    /// Keep this in sync with the engine's budget
    /// ([`super::DecodeEngine::set_prefill_budget`]); the server
    /// front-end sets both from one knob.
    pub fn set_prefill_budget(&mut self, budget: usize) {
        self.prefill_budget = budget.max(1);
    }

    /// Estimated engine steps to prefill a prompt under the configured
    /// budget — the greedy admission key.
    fn prefill_steps(&self, prompt_len: usize) -> usize {
        prompt_len.div_ceil(self.prefill_budget)
    }

    /// Add a request to the admission queue (stamps arrival time and the
    /// current engine step for the promotion clock). With the queue at
    /// its cap the request is **shed**: handed back as `Some(req)` for
    /// the front-end to answer with `FinishReason::Shed` — never silently
    /// dropped. `None` means accepted.
    pub fn enqueue(&mut self, req: GenRequest) -> Option<GenRequest> {
        if self.queue.len() >= self.queue_cap {
            return Some(req);
        }
        self.enqueued += 1;
        self.trace.event(Some(req.id), TraceEvent::Enqueued);
        self.queue.push_back(Queued {
            req,
            arrival: Instant::now(),
            enq_step: self.step,
            requeues: 0,
        });
        None
    }

    /// Put a faulted slot's request back at the **front** of the queue
    /// (it already waited its turn once; its original arrival survives so
    /// latency spans the whole ordeal). Exempt from the queue cap, not
    /// double-counted in `enqueued`, and re-stamps the promotion clock.
    pub fn requeue(&mut self, r: Requeue) {
        self.trace.event(Some(r.req.id), TraceEvent::Requeued);
        self.queue.push_front(Queued {
            req: r.req,
            arrival: r.arrival,
            enq_step: self.step,
            requeues: r.requeues,
        });
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Lanes currently running a slot.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Anything left to do (queued or in-flight)?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(Option::is_some)
    }

    /// Index of a free lane, if any.
    pub fn free_lane(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    /// Place an admitted slot into lane `b` (must be free).
    pub fn place(&mut self, b: usize, slot: Slot) {
        debug_assert!(self.slots[b].is_none(), "lane {b} already occupied");
        self.slots[b] = Some(slot);
    }

    /// The lane pool, for the engine's batched step.
    pub fn slots_mut(&mut self) -> &mut [Option<Slot>] {
        &mut self.slots
    }

    pub fn slots(&self) -> &[Option<Slot>] {
        &self.slots
    }

    /// Pick the next request to admit: oldest urgent request if any has
    /// waited past `promote_after`, else the cheapest prefill under the
    /// configured budget — fewest estimated prefill steps **for the
    /// uncached suffix** (a prefix-cache hit makes a long prompt cheap),
    /// which is shortest-prompt-first at budget 1 with the cache off
    /// (FIFO among equals — stable because the scan keeps
    /// strictly-earlier entries on ties).
    pub fn pop_next(&mut self) -> Option<Admission> {
        if self.queue.is_empty() {
            return None;
        }
        let urgent = self
            .queue
            .iter()
            .position(|q| self.step.saturating_sub(q.enq_step) >= self.promote_after);
        let greedy = self
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(i, q)| {
                let suffix = q.req.prompt.len() - self.prefix_rows(&q.req.prompt);
                (self.prefill_steps(suffix), *i)
            })
            .map(|(i, _)| i)
            .unwrap();
        let (idx, promoted) = match urgent {
            Some(u) => (u, u != greedy),
            None => (greedy, false),
        };
        let q = self.queue.remove(idx).unwrap();
        let waited_steps = self.step.saturating_sub(q.enq_step);
        Some(Admission {
            waited_steps,
            expired: self.max_queue_steps.map_or(false, |max| waited_steps > max),
            req: q.req,
            arrival: q.arrival,
            promoted,
            requeues: q.requeues,
        })
    }

    /// Tear down all pending work: every queued request plus every
    /// in-flight slot's request (queue front first, then lanes in index
    /// order). Slots are dropped, releasing their pages; the fleet router
    /// uses this on an abrupt replica kill to requeue the replica's work
    /// from the prompt onto survivors — deterministic quantization plus
    /// the per-slot-pure backend make the replay bit-identical (the same
    /// argument as the single-replica requeue ladder).
    pub fn take_unserved(&mut self) -> Vec<GenRequest> {
        let mut out: Vec<GenRequest> = self.queue.drain(..).map(|q| q.req).collect();
        for slot in self.slots.iter_mut() {
            if let Some(s) = slot.take() {
                out.push(s.req);
            }
        }
        out
    }

    /// Advance the promotion clock one engine step and report the sampled
    /// queue depth (the engine records it).
    pub fn tick(&mut self) -> usize {
        self.step += 1;
        // keep the shared step clock coherent for events emitted between
        // engine steps (enqueues, drain sheds)
        self.trace.set_step(self.step);
        self.queue.len()
    }

    /// Current engine-step clock.
    pub fn step_count(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize) -> GenRequest {
        GenRequest { id, prompt: vec![1; prompt_len], max_new: 4 }
    }

    #[test]
    fn shortest_prompt_first_fifo_on_ties() {
        let mut s = Scheduler::new(2, 100);
        s.enqueue(req(0, 8));
        s.enqueue(req(1, 3));
        s.enqueue(req(2, 3));
        s.enqueue(req(3, 1));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop_next().map(|a| a.req.id)).collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
        assert!(!s.has_work());
    }

    #[test]
    fn promotion_overrides_greedy_after_bound() {
        let mut s = Scheduler::new(1, 5);
        s.enqueue(req(0, 50)); // long prompt: greedy would never pick it
        for t in 0..6 {
            s.enqueue(req(10 + t, 1));
            s.tick();
        }
        // 6 steps elapsed >= promote_after 5: the long request is urgent
        let a = s.pop_next().unwrap();
        assert_eq!(a.req.id, 0);
        assert!(a.promoted);
        assert!(a.waited_steps >= 5);
        // remaining shorts drain greedily (FIFO among equals), unpromoted
        // until they cross the bound themselves
        let b = s.pop_next().unwrap();
        assert_eq!(b.req.id, 10);
    }

    #[test]
    fn budget_aware_greedy_ranks_by_prefill_steps() {
        // prompts 40 / 9 / 33, budget 16 -> 3 / 1 / 3 estimated steps:
        // the 9-token prompt still wins, but 40 vs 33 tie at 3 steps and
        // drain FIFO instead of shortest-first
        let mut s = Scheduler::new(2, 100);
        s.set_prefill_budget(16);
        s.enqueue(req(0, 40));
        s.enqueue(req(1, 9));
        s.enqueue(req(2, 33));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop_next().map(|a| a.req.id)).collect();
        assert_eq!(order, vec![1, 0, 2]);
        // unbounded budget: everything ties at one step -> plain FIFO
        let mut s = Scheduler::new(2, 100);
        s.set_prefill_budget(usize::MAX);
        s.enqueue(req(0, 40));
        s.enqueue(req(1, 9));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop_next().map(|a| a.req.id)).collect();
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn urgent_requests_drain_fifo() {
        let mut s = Scheduler::new(1, 2);
        s.enqueue(req(0, 9));
        s.enqueue(req(1, 5));
        for _ in 0..3 {
            s.tick();
        }
        // both urgent: strict FIFO, not shortest-first
        assert_eq!(s.pop_next().unwrap().req.id, 0);
        assert_eq!(s.pop_next().unwrap().req.id, 1);
    }

    #[test]
    fn lane_pool_accounting() {
        let mut s = Scheduler::new(3, 10);
        assert_eq!(s.free_lane(), Some(0));
        assert_eq!(s.active(), 0);
        assert!(!s.has_work());
        s.enqueue(req(0, 1));
        assert!(s.has_work());
        assert_eq!(s.queue_depth(), 1);
        assert_eq!(s.tick(), 1);
        assert_eq!(s.step_count(), 1);
    }

    #[test]
    fn radix_lookup_finds_longest_registered_prefix() {
        let pool = Rc::new(RefCell::new(PagePool::new(2)));
        let mut pc = PrefixCache::new(pool, 8);
        assert_eq!(pc.lookup(&[1, 2, 3]), None);
        pc.register(&[1, 2, 3, 4], Vec::new());
        pc.register(&[1, 2, 9], Vec::new()); // splits the [1,2,3,4] edge
        assert_eq!(pc.lookup(&[1, 2, 3, 4, 7]), Some((4, 0)));
        assert_eq!(pc.lookup(&[1, 2, 3, 8]), Some((3, 0))); // partial edge
        assert_eq!(pc.lookup(&[1, 2, 9, 9]), Some((3, 1)));
        assert_eq!(pc.lookup(&[1, 2, 8]), Some((2, 0))); // dies at the split
        assert_eq!(pc.lookup(&[5, 1]), None);
        // prompts the tree already spells register as no-ops
        pc.register(&[1, 2], Vec::new());
        pc.register(&[1, 2, 3, 4], Vec::new());
        assert_eq!(pc.live_entries(), 2);
    }

    #[test]
    fn prefix_lookup_truncates_tables_and_never_covers_whole_prompt() {
        let pool = Rc::new(RefCell::new(PagePool::new(2)));
        let mut ids = Vec::new();
        for _ in 0..4 {
            ids.push(pool.borrow_mut().alloc(4, 4));
        }
        let (ka, kb, va, vb) = (ids[0], ids[1], ids[2], ids[3]);
        let mut s = Scheduler::new(1, 10);
        assert!(!s.prefix_enabled());
        assert!(s.prefix_lookup(&[5, 6]).is_none());
        s.enable_prefix_cache(pool.clone(), 8);
        assert!(s.prefix_enabled());
        let prompt = vec![5, 6, 7, 8];
        s.prefix.as_mut().unwrap().register(&prompt, vec![(vec![ka, kb], vec![va, vb])]);
        assert_eq!(pool.borrow().refs(ka), 2); // registration holds a ref
        // exact re-ask: capped at len-1 = 3 rows, still spanning both pages
        let (rows, pages) = s.prefix_lookup(&prompt).unwrap();
        assert_eq!(rows, 3);
        assert_eq!(pages, vec![(vec![ka, kb], vec![va, vb])]);
        // 2-row overlap: tables truncated to one page per stream
        let (rows, pages) = s.prefix_lookup(&[5, 6, 0, 0]).unwrap();
        assert_eq!(rows, 2);
        assert_eq!(pages, vec![(vec![ka], vec![va])]);
        // longer prompt sharing all 4 registered rows: no cap applies
        assert_eq!(s.prefix_lookup(&[5, 6, 7, 8, 9]).unwrap().0, 4);
        assert_eq!(s.prefix_lookup(&[5, 0]).unwrap().0, 1);
        assert!(s.prefix_lookup(&[9, 9]).is_none());
        // cap rounding to zero rows reports a miss, not a 0-row hit
        assert!(s.prefix_lookup(&[5]).is_none());
        // scheduler teardown releases the registration refs
        drop(s);
        assert_eq!(pool.borrow().refs(ka), 1);
        assert_eq!(pool.borrow().shared_pages(), 0);
    }

    #[test]
    fn greedy_admission_charges_only_the_uncached_suffix() {
        let pool = Rc::new(RefCell::new(PagePool::new(4)));
        let mut s = Scheduler::new(2, 100);
        s.set_prefill_budget(4);
        s.enable_prefix_cache(pool, 8);
        let shared: Vec<i32> = (0..32).collect();
        s.prefix.as_mut().unwrap().register(&shared, Vec::new());
        let mut long = shared.clone();
        long.push(99); // 33 tokens but a 1-token suffix after the hit
        s.enqueue(GenRequest { id: 0, prompt: long, max_new: 4 });
        s.enqueue(GenRequest { id: 1, prompt: vec![1; 8], max_new: 4 });
        // ceil(1/4) = 1 step beats ceil(8/4) = 2: the long prompt wins the
        // greedy pick it would lose without the cache
        assert_eq!(s.pop_next().unwrap().req.id, 0);
        assert_eq!(s.pop_next().unwrap().req.id, 1);
    }

    #[test]
    fn covered_prompt_registers_once() {
        let pool = Rc::new(RefCell::new(PagePool::new(2)));
        let mut pc = PrefixCache::new(pool.clone(), 8);
        let a = pool.borrow_mut().alloc(4, 4);
        pc.register(&[1, 2], vec![(vec![a], vec![a])]);
        assert_eq!(pool.borrow().refs(a), 3);
        pc.register(&[1, 2], vec![(vec![a], vec![a])]);
        assert_eq!(pool.borrow().refs(a), 3); // covered: no second retain
        assert_eq!(pc.live_entries(), 1);
    }

    #[test]
    fn lru_eviction_keeps_hot_prefix_and_releases_cold_refs() {
        let pool = Rc::new(RefCell::new(PagePool::new(2)));
        let mut pc = PrefixCache::new(pool.clone(), 2);
        let a = pool.borrow_mut().alloc(4, 4);
        let b = pool.borrow_mut().alloc(4, 4);
        let c = pool.borrow_mut().alloc(4, 4);
        pc.register(&[1, 2], vec![(vec![a], vec![])]);
        pc.register(&[3, 4], vec![(vec![b], vec![])]);
        assert_eq!((pool.borrow().refs(a), pool.borrow().refs(b)), (2, 2));
        // keep [1,2] hot: the lookup stamps its recency past [3,4]'s
        assert_eq!(pc.lookup(&[1, 2, 9]).unwrap().0, 2);
        // the registration at capacity evicts exactly the cold [3,4] —
        // releasing its ref — while the hot entry survives
        pc.register(&[5, 6], vec![(vec![c], vec![])]);
        assert_eq!(pool.borrow().refs(b), 1, "cold entry must release its ref");
        assert_eq!(pool.borrow().refs(a), 2, "hot entry must survive capacity pressure");
        assert_eq!(pool.borrow().refs(c), 2);
        assert_eq!(pc.live_entries(), 2);
        assert!(pc.lookup(&[3, 4]).is_none(), "evicted prefix still resolves");
        assert_eq!(pc.lookup(&[1, 2, 3]).unwrap().0, 2);
        assert_eq!(pc.lookup(&[5, 6, 7]).unwrap().0, 2);
        // churn of cold one-offs never touches the repeatedly-hit prefix
        for t in 0..8 {
            assert_eq!(pc.lookup(&[1, 2, t]).unwrap().0, 2);
            pc.register(&[20 + t, 30], Vec::new());
        }
        assert_eq!(pool.borrow().refs(a), 2, "hot entry evicted under churn");
        assert_eq!(pc.lookup(&[1, 2]).unwrap().0, 2);
        pc.release_all();
        assert_eq!((pool.borrow().refs(a), pool.borrow().refs(c)), (1, 1));
        assert_eq!(pool.borrow().shared_pages(), 0);
    }

    #[test]
    fn lru_eviction_repairs_shared_radix_paths() {
        let pool = Rc::new(RefCell::new(PagePool::new(2)));
        let mut pc = PrefixCache::new(pool.clone(), 2);
        pc.register(&[1, 2, 3, 4], Vec::new()); // entry 0
        pc.register(&[1, 2, 9], Vec::new()); // splits the edge; mid inherits entry 0
        // touch entry 1 so entry 0 is the LRU victim
        assert_eq!(pc.lookup(&[1, 2, 9, 9]).unwrap().0, 3);
        // evicting [1,2,3,4] must repair the split node that inherited its
        // entry: the shared [1,2] prefix re-points at the survivor and the
        // [3,4] tail unlinks, so no node references a freed slab slot
        pc.register(&[7, 7], Vec::new());
        assert_eq!(pc.live_entries(), 2);
        let (rows, e) = pc.lookup(&[1, 2, 0]).unwrap();
        assert_eq!(rows, 2);
        assert!(pc.entries[e].is_some(), "repair left a dangling entry index");
        assert_eq!(pc.lookup(&[1, 2, 3, 4]).unwrap().0, 2, "evicted tail must not match");
        assert_eq!(pc.lookup(&[1, 2, 9]).unwrap().0, 3);
        assert_eq!(pc.lookup(&[7, 7, 1]).unwrap().0, 2);
        // evicted slab and node slots are reused, not leaked
        pc.register(&[8, 8], Vec::new()); // evicts another entry into the free lists
        assert_eq!(pc.live_entries(), 2);
        assert!(pc.entries.len() <= 3, "slab must reuse freed entry slots");
    }

    #[test]
    fn paired_lanes_admit_in_whole_groups() {
        // 5 lanes under draft+verifier pairing -> 2 schedulable pairs;
        // the unpairable 5th lane must never admit a draft without a
        // verifier (capacity rounds down, it never half-admits)
        let s = Scheduler::with_lanes_per_request(5, 10, 2);
        assert_eq!(s.lanes_per_request(), 2);
        assert_eq!(s.slots().len(), 2);
        assert_eq!(s.free_lane(), Some(0));
        // plain construction is the 1-lane special case
        let s = Scheduler::new(5, 10);
        assert_eq!(s.lanes_per_request(), 1);
        assert_eq!(s.slots().len(), 5);
        // queue-cap shed and drain count requests, not lanes: the cap
        // applies to queued work identically under pairing
        let mut s = Scheduler::with_lanes_per_request(4, 10, 2);
        s.set_queue_cap(1);
        assert!(s.enqueue(req(0, 1)).is_none());
        assert!(s.enqueue(req(1, 1)).is_some(), "cap 1 must shed the second arrival");
        assert_eq!(s.take_unserved().len(), 1);
        assert!(!s.has_work());
    }

    #[test]
    #[should_panic(expected = "cannot hold")]
    fn paired_lanes_reject_an_unpairable_pool() {
        let _ = Scheduler::with_lanes_per_request(1, 10, 2);
    }

    #[test]
    fn queue_cap_sheds_instead_of_growing() {
        let mut s = Scheduler::new(1, 10);
        s.set_queue_cap(2);
        assert!(s.enqueue(req(0, 1)).is_none());
        assert!(s.enqueue(req(1, 1)).is_none());
        // cap hit: the request comes straight back, never silently dropped
        let shed = s.enqueue(req(2, 1)).unwrap();
        assert_eq!(shed.id, 2);
        assert_eq!(s.queue_depth(), 2);
        assert_eq!(s.enqueued, 2);
        // requeues are exempt: faulted in-flight work re-enters even at cap
        s.requeue(Requeue { req: req(0, 1), arrival: Instant::now(), requeues: 1 });
        assert_eq!(s.queue_depth(), 3);
        assert_eq!(s.enqueued, 2, "requeue must not double-count");
    }

    #[test]
    fn requeue_goes_to_front_and_carries_its_count() {
        let mut s = Scheduler::new(1, 100);
        s.enqueue(req(1, 1));
        s.requeue(Requeue { req: req(0, 1), arrival: Instant::now(), requeues: 3 });
        let a = s.pop_next().unwrap();
        assert_eq!(a.req.id, 0, "requeued request is at the queue front");
        assert_eq!(a.requeues, 3);
        assert!(!a.expired);
        assert_eq!(s.pop_next().unwrap().requeues, 0);
    }

    #[test]
    fn max_queue_steps_flags_expired_admissions() {
        let mut s = Scheduler::new(1, 100);
        s.set_max_queue_steps(Some(2));
        s.enqueue(req(0, 1));
        s.tick();
        s.enqueue(req(1, 1));
        s.tick();
        s.tick();
        // id 0 waited 3 > 2 steps; id 1 waited 2 <= 2
        let popped: Vec<(u64, bool)> =
            std::iter::from_fn(|| s.pop_next().map(|a| (a.req.id, a.expired))).collect();
        assert!(popped.contains(&(0, true)));
        assert!(popped.contains(&(1, false)));
    }

    #[test]
    fn mode_parses() {
        assert_eq!("wave".parse::<SchedMode>().unwrap(), SchedMode::Wave);
        assert_eq!("Continuous".parse::<SchedMode>().unwrap(), SchedMode::Continuous);
        assert!("waves".parse::<SchedMode>().is_err());
    }
}
