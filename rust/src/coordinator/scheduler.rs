//! Continuous-batching scheduler: slot-level admission over a fixed pool
//! of batch lanes.
//!
//! The decode artifact has a fixed batch shape `[B, L, S, D]`, so serving
//! owns exactly `B` **lanes**. Wave scheduling (`DecodeEngine::serve_wave`)
//! fills all lanes at once and holds every lane until the whole wave
//! drains: a short request parks an idle lane for as long as the longest
//! request in its wave keeps decoding. This module replaces the wave
//! barrier with per-slot lifecycle:
//!
//! ```text
//! Queued ── admit (free lane) ──► Prefilling ──► Decoding ──► Finished
//!    ▲                                                           │
//!    └────────────── lane freed, next request admitted ◄─────────┘
//! ```
//!
//! The moment a slot finishes mid-step, its lane is zeroed and the next
//! queued request is admitted into it on the following step — prefill of
//! the newcomer proceeds *in the same batched steps* that keep decoding
//! the other lanes, so no lane ever waits for a wave boundary.
//!
//! # Admission policy
//!
//! `pop_next` is throughput-greedy: it picks the **shortest-prompt**
//! queued request (cheapest prefill, frees the lane for decode soonest;
//! FIFO among equals). Greedy ordering alone starves long prompts under a
//! stream of short ones, so every request carries its enqueue step and any
//! request that has waited more than `promote_after` engine steps becomes
//! **urgent**: urgent requests are admitted in strict FIFO order before
//! any non-urgent one. The wait of a request enqueued behind `n` earlier
//! arrivals is therefore bounded by `promote_after` plus the time for `n`
//! earlier urgents and one lane to drain — no unbounded starvation.
//!
//! The scheduler owns queue and lanes but never touches tensors; the
//! engine (`DecodeEngine::step_continuous`) drives admission, stepping,
//! and metrics. Lane *contents* live in the engine's step slabs; moving a
//! slot between lanes is `DecodeEngine::move_lane` (slab copy) with
//! `SlotKv::resync_full_into` (packed re-decode) as the fallback.

use std::collections::VecDeque;
use std::time::Instant;

use super::{GenRequest, Slot};

/// Which serving loop the front-end drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Legacy wave-at-a-time: batch up to `B` requests, run to completion.
    Wave,
    /// Slot-level continuous batching through [`Scheduler`].
    Continuous,
}

impl std::str::FromStr for SchedMode {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.to_lowercase().as_str() {
            "wave" => Ok(SchedMode::Wave),
            "continuous" | "cont" => Ok(SchedMode::Continuous),
            other => Err(format!("unknown scheduler mode {other} (wave|continuous)")),
        }
    }
}

/// A request waiting for a lane.
struct Queued {
    req: GenRequest,
    arrival: Instant,
    enq_step: u64,
}

/// What `pop_next` decided, so the engine can account promotions.
pub struct Admission {
    pub req: GenRequest,
    pub arrival: Instant,
    /// Engine steps spent in the queue.
    pub waited_steps: u64,
    /// True when the anti-starvation rule overrode the greedy pick.
    pub promoted: bool,
}

/// Admission queue + fixed lane pool. See the module docs for the policy.
pub struct Scheduler {
    queue: VecDeque<Queued>,
    slots: Vec<Option<Slot>>,
    promote_after: u64,
    /// Engine steps ticked so far (the clock the promotion rule runs on).
    step: u64,
    /// Per-step prefill token budget the engine runs under (see
    /// [`Scheduler::set_prefill_budget`]); the greedy admission key ranks
    /// by estimated prefill *steps* under this budget, not raw prompt
    /// length.
    prefill_budget: usize,
    /// Requests enqueued over the scheduler's lifetime.
    pub enqueued: u64,
}

impl Scheduler {
    /// Default anti-starvation bound: a queued request overtakes shorter
    /// newcomers after this many engine steps.
    pub const DEFAULT_PROMOTE_AFTER: u64 = 64;

    pub fn new(max_batch: usize, promote_after: u64) -> Self {
        assert!(max_batch > 0);
        Scheduler {
            queue: VecDeque::new(),
            slots: (0..max_batch).map(|_| None).collect(),
            promote_after: promote_after.max(1),
            step: 0,
            prefill_budget: 1,
            enqueued: 0,
        }
    }

    /// Make admission budget-aware: with chunked prefill a long prompt no
    /// longer costs `prompt_len` engine steps, so the greedy pick ranks
    /// queued requests by **estimated prefill steps under the budget**
    /// (`ceil(prompt_len / budget)`, the cost when the whole per-step
    /// budget lands on one slot) instead of raw prompt length. At budget
    /// 1 this degenerates to shortest-prompt-first (today's order); at a
    /// large budget most requests tie at one step and admission becomes
    /// plain FIFO — fairer, with nothing left to gain from reordering.
    /// Keep this in sync with the engine's budget
    /// ([`super::DecodeEngine::set_prefill_budget`]); the server
    /// front-end sets both from one knob.
    pub fn set_prefill_budget(&mut self, budget: usize) {
        self.prefill_budget = budget.max(1);
    }

    /// Estimated engine steps to prefill a prompt under the configured
    /// budget — the greedy admission key.
    fn prefill_steps(&self, prompt_len: usize) -> usize {
        prompt_len.div_ceil(self.prefill_budget)
    }

    /// Add a request to the admission queue (stamps arrival time and the
    /// current engine step for the promotion clock).
    pub fn enqueue(&mut self, req: GenRequest) {
        self.enqueued += 1;
        self.queue.push_back(Queued { req, arrival: Instant::now(), enq_step: self.step });
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Lanes currently running a slot.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Anything left to do (queued or in-flight)?
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.slots.iter().any(Option::is_some)
    }

    /// Index of a free lane, if any.
    pub fn free_lane(&self) -> Option<usize> {
        self.slots.iter().position(Option::is_none)
    }

    /// Place an admitted slot into lane `b` (must be free).
    pub fn place(&mut self, b: usize, slot: Slot) {
        debug_assert!(self.slots[b].is_none(), "lane {b} already occupied");
        self.slots[b] = Some(slot);
    }

    /// The lane pool, for the engine's batched step.
    pub fn slots_mut(&mut self) -> &mut [Option<Slot>] {
        &mut self.slots
    }

    pub fn slots(&self) -> &[Option<Slot>] {
        &self.slots
    }

    /// Pick the next request to admit: oldest urgent request if any has
    /// waited past `promote_after`, else the cheapest prefill under the
    /// configured budget — fewest estimated prefill steps, which is
    /// shortest-prompt-first at budget 1 (FIFO among equals — stable
    /// because the scan keeps strictly-earlier entries on ties).
    pub fn pop_next(&mut self) -> Option<Admission> {
        if self.queue.is_empty() {
            return None;
        }
        let urgent = self
            .queue
            .iter()
            .position(|q| self.step.saturating_sub(q.enq_step) >= self.promote_after);
        let greedy = self
            .queue
            .iter()
            .enumerate()
            .min_by_key(|(i, q)| (self.prefill_steps(q.req.prompt.len()), *i))
            .map(|(i, _)| i)
            .unwrap();
        let (idx, promoted) = match urgent {
            Some(u) => (u, u != greedy),
            None => (greedy, false),
        };
        let q = self.queue.remove(idx).unwrap();
        Some(Admission {
            waited_steps: self.step.saturating_sub(q.enq_step),
            req: q.req,
            arrival: q.arrival,
            promoted,
        })
    }

    /// Advance the promotion clock one engine step and report the sampled
    /// queue depth (the engine records it).
    pub fn tick(&mut self) -> usize {
        self.step += 1;
        self.queue.len()
    }

    /// Current engine-step clock.
    pub fn step_count(&self) -> u64 {
        self.step
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize) -> GenRequest {
        GenRequest { id, prompt: vec![1; prompt_len], max_new: 4 }
    }

    #[test]
    fn shortest_prompt_first_fifo_on_ties() {
        let mut s = Scheduler::new(2, 100);
        s.enqueue(req(0, 8));
        s.enqueue(req(1, 3));
        s.enqueue(req(2, 3));
        s.enqueue(req(3, 1));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop_next().map(|a| a.req.id)).collect();
        assert_eq!(order, vec![3, 1, 2, 0]);
        assert!(!s.has_work());
    }

    #[test]
    fn promotion_overrides_greedy_after_bound() {
        let mut s = Scheduler::new(1, 5);
        s.enqueue(req(0, 50)); // long prompt: greedy would never pick it
        for t in 0..6 {
            s.enqueue(req(10 + t, 1));
            s.tick();
        }
        // 6 steps elapsed >= promote_after 5: the long request is urgent
        let a = s.pop_next().unwrap();
        assert_eq!(a.req.id, 0);
        assert!(a.promoted);
        assert!(a.waited_steps >= 5);
        // remaining shorts drain greedily (FIFO among equals), unpromoted
        // until they cross the bound themselves
        let b = s.pop_next().unwrap();
        assert_eq!(b.req.id, 10);
    }

    #[test]
    fn budget_aware_greedy_ranks_by_prefill_steps() {
        // prompts 40 / 9 / 33, budget 16 -> 3 / 1 / 3 estimated steps:
        // the 9-token prompt still wins, but 40 vs 33 tie at 3 steps and
        // drain FIFO instead of shortest-first
        let mut s = Scheduler::new(2, 100);
        s.set_prefill_budget(16);
        s.enqueue(req(0, 40));
        s.enqueue(req(1, 9));
        s.enqueue(req(2, 33));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop_next().map(|a| a.req.id)).collect();
        assert_eq!(order, vec![1, 0, 2]);
        // unbounded budget: everything ties at one step -> plain FIFO
        let mut s = Scheduler::new(2, 100);
        s.set_prefill_budget(usize::MAX);
        s.enqueue(req(0, 40));
        s.enqueue(req(1, 9));
        let order: Vec<u64> = std::iter::from_fn(|| s.pop_next().map(|a| a.req.id)).collect();
        assert_eq!(order, vec![0, 1]);
    }

    #[test]
    fn urgent_requests_drain_fifo() {
        let mut s = Scheduler::new(1, 2);
        s.enqueue(req(0, 9));
        s.enqueue(req(1, 5));
        for _ in 0..3 {
            s.tick();
        }
        // both urgent: strict FIFO, not shortest-first
        assert_eq!(s.pop_next().unwrap().req.id, 0);
        assert_eq!(s.pop_next().unwrap().req.id, 1);
    }

    #[test]
    fn lane_pool_accounting() {
        let mut s = Scheduler::new(3, 10);
        assert_eq!(s.free_lane(), Some(0));
        assert_eq!(s.active(), 0);
        assert!(!s.has_work());
        s.enqueue(req(0, 1));
        assert!(s.has_work());
        assert_eq!(s.queue_depth(), 1);
        assert_eq!(s.tick(), 1);
        assert_eq!(s.step_count(), 1);
    }

    #[test]
    fn mode_parses() {
        assert_eq!("wave".parse::<SchedMode>().unwrap(), SchedMode::Wave);
        assert_eq!("Continuous".parse::<SchedMode>().unwrap(), SchedMode::Continuous);
        assert!("waves".parse::<SchedMode>().is_err());
    }
}
