//! Serving metrics: log-bucketed histograms for per-request latency, TTFT
//! (time to first token), admission wait, and queue depth — the numbers
//! that distinguish a scheduler that keeps lanes busy from one that
//! merely completes requests.
//!
//! Histograms are fixed-size (no per-sample storage) so a server can run
//! for millions of requests without growing: `record` is O(1), quantiles
//! are read by walking the bucket counts. Bucket boundaries are
//! geometric, so relative error is bounded by the per-decade resolution
//! (~13% at the default 18 buckets/decade); exact `min`/`max`/`mean` are
//! tracked alongside and quantile estimates are clamped into `[min, max]`.

/// Fixed-size log-bucketed histogram for non-negative samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Lower bound of bucket 0; samples below it land in bucket 0.
    lo: f64,
    /// Geometric growth factor between bucket boundaries.
    growth: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Buckets span `[lo, hi]` geometrically; samples outside are clamped
    /// into the first/last bucket (and still tracked exactly by min/max).
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(lo > 0.0 && hi > lo && buckets >= 2);
        Histogram {
            lo,
            growth: (hi / lo).powf(1.0 / buckets as f64),
            counts: vec![0; buckets],
            total: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Latency-shaped default: 1 µs .. 1000 s in seconds.
    pub fn for_seconds() -> Self {
        Histogram::new(1e-6, 1e3, 162)
    }

    /// Count-shaped default (queue depths, wait steps): 1 .. 1e6.
    pub fn for_counts() -> Self {
        Histogram::new(1.0, 1e6, 108)
    }

    /// Rate-shaped default (per-round speculative acceptance): 0.01 .. 1,
    /// 36 buckets (~13% relative resolution). Constructed identically
    /// everywhere so fleet rollups merge without geometry mismatches.
    pub fn for_rate() -> Self {
        Histogram::new(0.01, 1.0, 36)
    }

    fn bucket(&self, v: f64) -> usize {
        if v <= self.lo {
            return 0;
        }
        let i = (v / self.lo).ln() / self.growth.ln();
        (i as usize).min(self.counts.len() - 1)
    }

    /// Record one sample. Negative/NaN samples are clamped to zero.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        let b = self.bucket(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.sum / self.total as f64
    }

    pub fn max(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.max
    }

    pub fn min(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.min
    }

    /// Approximate `q`-quantile (`0.0..=1.0`): geometric midpoint of the
    /// bucket holding the q-th sample, clamped into `[min, max]`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.total as f64).ceil() as u64).max(1);
        // the top rank is the maximum by definition — return it exactly
        // rather than a bucket-midpoint estimate (matters when samples
        // saturate past `hi` into the last bucket, where the midpoint
        // would clamp all the way down to `min`)
        if rank >= self.total {
            return self.max;
        }
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let lo = self.lo * self.growth.powi(i as i32);
                let est = lo * self.growth.sqrt();
                return est.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    /// Sum of all recorded samples (0.0 when empty).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Raw per-bucket counts, for exporters that need the full shape.
    pub fn bucket_counts(&self) -> &[u64] {
        &self.counts
    }

    /// Upper bound of bucket `i` (`lo · growth^(i+1)`): the Prometheus
    /// `le` value for that bucket.
    pub fn bucket_bound(&self, i: usize) -> f64 {
        self.lo * self.growth.powi(i as i32 + 1)
    }

    /// Bucket geometry `(lo, growth, buckets)` — two histograms merge iff
    /// these match.
    pub fn geometry(&self) -> (f64, f64, usize) {
        (self.lo, self.growth, self.counts.len())
    }

    /// Fold another histogram with identical geometry into this one:
    /// bucket counts, totals, and sums add; min/max fold (an empty side
    /// contributes nothing since its min/max are ±infinity sentinels).
    /// The rollup primitive for multi-replica aggregation and exporters.
    pub fn merge(&mut self, other: &Histogram) -> anyhow::Result<()> {
        if self.geometry() != other.geometry() {
            anyhow::bail!(
                "histogram geometry mismatch: {:?} vs {:?}",
                self.geometry(),
                other.geometry()
            );
        }
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        Ok(())
    }
}

/// Per-request serving statistics, recorded by the engine/scheduler as
/// slots move through their lifecycle. All durations in seconds.
#[derive(Clone, Debug)]
pub struct ServingMetrics {
    /// Arrival (enqueue) → request completion.
    pub latency: Histogram,
    /// Arrival (enqueue) → first *generated* token sampled.
    pub ttft: Histogram,
    /// Engine steps a request spent queued before lane admission.
    pub wait_steps: Histogram,
    /// Queue depth sampled once per scheduler tick.
    pub queue_depth: Histogram,
    /// Prompt tokens a prefilling slot consumed in one engine step (its
    /// phase-A chunk plus the batched-step token) — one sample per
    /// prefilling slot per step, so every prompt token is counted exactly
    /// once and the histogram sum telescopes to total prompt tokens. All
    /// samples are 1 when the prefill budget is 1 (unchunked).
    pub prefill_chunk: Histogram,
    /// Prompt tokens fed across all slots in one engine step (sampled
    /// once per step with at least one occupied lane).
    pub step_prefill_tokens: Histogram,
    /// Decode (generation) tokens sampled across all slots in one engine
    /// step — together with `step_prefill_tokens` this is the
    /// prefill-vs-decode token split of every step.
    pub step_decode_tokens: Histogram,
    /// Requests admitted into a lane.
    pub admitted: u64,
    /// Admissions that used the anti-starvation promotion rule (an urgent
    /// request overtook the throughput-greedy pick).
    pub promoted: u64,
    /// Requests rejected at validation (empty/over-long prompt).
    pub rejected: u64,
    /// Admissions that adopted a cached prompt prefix (≥ 1 shared row).
    pub prefix_hits: u64,
    /// Admissions that asked the prefix cache and found nothing (only
    /// counted while the cache is enabled, so hits + misses == lookups).
    pub prefix_misses: u64,
    /// Prompt rows adopted per prefix-cache hit — the prefill steps each
    /// hit skipped are `ceil(rows / budget)` fewer than a cold admission.
    pub prefix_rows: Histogram,
    /// Pool pages referenced by ≥ 2 holders, sampled once per engine step
    /// while the prefix cache is enabled (the dedup gauge over time).
    pub shared_pages: Histogram,
    /// Transient `step` faults absorbed (one per failed backend attempt,
    /// retried or not). Under injection this equals the fault plan's
    /// `step_errors` exactly.
    pub step_faults: u64,
    /// Transient `prefill_chunk` faults absorbed.
    pub chunk_faults: u64,
    /// Step outputs rejected for non-finite logits before sampling (one
    /// per poisoned step attempt).
    pub nan_faults: u64,
    /// In-place retries performed (backoff sleeps taken) across step,
    /// chunk, and NaN recovery.
    pub retries: u64,
    /// Slots retired by faults and requeued for bit-exact replay.
    pub requeued: u64,
    /// Requests failed with `FinishReason::BackendError` (fatal fault, or
    /// transient churn past the retry/requeue budgets).
    pub backend_failed: u64,
    /// Requests shed by overload policy (queue at cap, or submitted while
    /// draining) with `FinishReason::Shed`.
    pub shed: u64,
    /// Requests dropped at their deadline (wall clock or max queue steps)
    /// with `FinishReason::Deadline`.
    pub deadline_expired: u64,
    /// Backoff slept per retry, in seconds (records zero-length backoffs
    /// too, so `count == retries`).
    pub retry_backoff: Histogram,
    /// Draft tokens accepted by the speculative verifier (surfaced
    /// verbatim). Telescoping invariant:
    /// `spec_accepted + spec_rejected + spec_forced == tokens_generated`
    /// under speculative serving — every generated token is exactly one
    /// of accepted draft / verifier correction / verifier bonus.
    pub spec_accepted: u64,
    /// Verify rounds that rejected a draft position (each such round
    /// surfaces the verifier's correction token in its place).
    pub spec_rejected: u64,
    /// Verifier bonus tokens surfaced by all-accepted rounds (the free
    /// token the verifier's last logits buy when every proposal stands).
    pub spec_forced: u64,
    /// Draft KV rows rolled back by rejections (proposals past the first
    /// rejected position: `m - a - 1` per rejecting round).
    pub spec_rollback_rows: u64,
    /// Speculative verify rounds run (the engine-call denominator behind
    /// steps-per-token: one batched verify per round).
    pub spec_rounds: u64,
    /// Per-round acceptance rate `a / m` (accepted prefix over proposals
    /// judged) — the live nxfp-draft-vs-verifier fidelity probe.
    pub spec_accept: Histogram,
    /// Fleet routing: dispatches steered to this replica by prefix
    /// affinity when least-loaded would have picked another replica.
    /// Populated by the fleet rollup; zero in single-engine serving.
    /// Read next to `prefix_hit_rate()` — it says what the stickiness
    /// bought.
    pub affinity_overrides: u64,
    /// Fleet routing: dispatches whose affinity owner was this replica
    /// but fell through to least-loaded (drain/death or slack exceeded).
    pub affinity_spills: u64,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        ServingMetrics {
            latency: Histogram::for_seconds(),
            ttft: Histogram::for_seconds(),
            wait_steps: Histogram::for_counts(),
            queue_depth: Histogram::for_counts(),
            prefill_chunk: Histogram::for_counts(),
            step_prefill_tokens: Histogram::for_counts(),
            step_decode_tokens: Histogram::for_counts(),
            admitted: 0,
            promoted: 0,
            rejected: 0,
            prefix_hits: 0,
            prefix_misses: 0,
            prefix_rows: Histogram::for_counts(),
            shared_pages: Histogram::for_counts(),
            step_faults: 0,
            chunk_faults: 0,
            nan_faults: 0,
            retries: 0,
            requeued: 0,
            backend_failed: 0,
            shed: 0,
            deadline_expired: 0,
            retry_backoff: Histogram::for_seconds(),
            spec_accepted: 0,
            spec_rejected: 0,
            spec_forced: 0,
            spec_rollback_rows: 0,
            spec_rounds: 0,
            spec_accept: Histogram::for_rate(),
            affinity_overrides: 0,
            affinity_spills: 0,
        }
    }
}

impl ServingMetrics {
    /// Total faults absorbed across all injection/detection sites.
    pub fn total_faults(&self) -> u64 {
        self.step_faults + self.chunk_faults + self.nan_faults
    }

    /// Fraction of prefix-cache lookups that adopted at least one row
    /// (0.0 when the cache is disabled or nothing was admitted).
    pub fn prefix_hit_rate(&self) -> f64 {
        let lookups = self.prefix_hits + self.prefix_misses;
        if lookups == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / lookups as f64
    }

    /// Aggregate speculative acceptance rate: accepted draft tokens over
    /// all draft tokens judged (`accepted + rejected` — a rejecting round
    /// judges exactly one losing position; bonus tokens are the
    /// verifier's own and don't enter the ratio). This is the paper's
    /// offline nxfp-vs-fp16 fidelity plot measured on served traffic
    /// (0.0 when nothing speculative ran).
    pub fn spec_accept_rate(&self) -> f64 {
        let judged = self.spec_accepted + self.spec_rejected;
        if judged == 0 {
            return 0.0;
        }
        self.spec_accepted as f64 / judged as f64
    }

    /// Fold another replica's serving metrics into this rollup. Counters
    /// are summed **unconditionally and exactly**; histograms merge
    /// bucket-wise via [`Histogram::merge`]. A geometry mismatch degrades
    /// only the mismatched histogram (this side's data is kept untouched)
    /// and is surfaced in the returned error — so a fleet rollup across
    /// heterogeneous builds still reports exact counters, with the
    /// histogram gaps named instead of panicking mid-report.
    pub fn merge(&mut self, other: &ServingMetrics) -> anyhow::Result<()> {
        self.admitted += other.admitted;
        self.promoted += other.promoted;
        self.rejected += other.rejected;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.step_faults += other.step_faults;
        self.chunk_faults += other.chunk_faults;
        self.nan_faults += other.nan_faults;
        self.retries += other.retries;
        self.requeued += other.requeued;
        self.backend_failed += other.backend_failed;
        self.shed += other.shed;
        self.deadline_expired += other.deadline_expired;
        self.spec_accepted += other.spec_accepted;
        self.spec_rejected += other.spec_rejected;
        self.spec_forced += other.spec_forced;
        self.spec_rollback_rows += other.spec_rollback_rows;
        self.spec_rounds += other.spec_rounds;
        self.affinity_overrides += other.affinity_overrides;
        self.affinity_spills += other.affinity_spills;
        let pairs: [(&str, &mut Histogram, &Histogram); 11] = [
            ("latency", &mut self.latency, &other.latency),
            ("ttft", &mut self.ttft, &other.ttft),
            ("wait_steps", &mut self.wait_steps, &other.wait_steps),
            ("queue_depth", &mut self.queue_depth, &other.queue_depth),
            ("prefill_chunk", &mut self.prefill_chunk, &other.prefill_chunk),
            ("step_prefill_tokens", &mut self.step_prefill_tokens, &other.step_prefill_tokens),
            ("step_decode_tokens", &mut self.step_decode_tokens, &other.step_decode_tokens),
            ("prefix_rows", &mut self.prefix_rows, &other.prefix_rows),
            ("shared_pages", &mut self.shared_pages, &other.shared_pages),
            ("retry_backoff", &mut self.retry_backoff, &other.retry_backoff),
            ("spec_accept", &mut self.spec_accept, &other.spec_accept),
        ];
        let mut errs = Vec::new();
        for (name, mine, theirs) in pairs {
            if let Err(e) = mine.merge(theirs) {
                errs.push(format!("{name}: {e:#}"));
            }
        }
        if errs.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("{}", errs.join("; "))
        }
    }

    /// Human-readable one-block summary for logs and the CLI.
    pub fn summary(&self) -> String {
        let ms = |s: f64| s * 1e3;
        let mut out = format!(
            "latency p50/p95 {:.1}/{:.1} ms  ttft p50/p95 {:.1}/{:.1} ms  \
             queue depth mean/max {:.1}/{:.0}  admitted {} (promoted {}, rejected {})",
            ms(self.latency.p50()),
            ms(self.latency.p95()),
            ms(self.ttft.p50()),
            ms(self.ttft.p95()),
            self.queue_depth.mean(),
            self.queue_depth.max(),
            self.admitted,
            self.promoted,
            self.rejected
        );
        if self.prefill_chunk.count() > 0 {
            out.push_str(&format!(
                "\nprefill chunk mean/max {:.1}/{:.0} tok  \
                 per-step prefill/decode tokens mean {:.1}/{:.1}",
                self.prefill_chunk.mean(),
                self.prefill_chunk.max(),
                self.step_prefill_tokens.mean(),
                self.step_decode_tokens.mean()
            ));
        }
        if self.prefix_hits + self.prefix_misses > 0 {
            out.push_str(&format!(
                "\nprefix cache hit rate {:.0}% ({} of {} lookups)  \
                 adopted rows mean/max {:.1}/{:.0}  shared pages mean/max {:.1}/{:.0}",
                self.prefix_hit_rate() * 100.0,
                self.prefix_hits,
                self.prefix_hits + self.prefix_misses,
                self.prefix_rows.mean(),
                self.prefix_rows.max(),
                self.shared_pages.mean(),
                self.shared_pages.max()
            ));
        }
        if self.spec_rounds > 0 {
            out.push_str(&format!(
                "\nspec accept rate {:.0}% ({} accepted, {} rejected, {} bonus)  \
                 rounds {}  rolled-back rows {}  per-round accept p50 {:.2}",
                self.spec_accept_rate() * 100.0,
                self.spec_accepted,
                self.spec_rejected,
                self.spec_forced,
                self.spec_rounds,
                self.spec_rollback_rows,
                self.spec_accept.p50()
            ));
        }
        if self.total_faults() + self.shed + self.deadline_expired > 0 {
            out.push_str(&format!(
                "\nfaults step/chunk/nan {}/{}/{}  retries {} (backoff p95 {:.2} ms)  \
                 requeued {}  failed {}  shed {}  deadline {}",
                self.step_faults,
                self.chunk_faults,
                self.nan_faults,
                self.retries,
                ms(self.retry_backoff.p95()),
                self.requeued,
                self.backend_failed,
                self.shed,
                self.deadline_expired
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_basic_stats() {
        let mut h = Histogram::for_seconds();
        for v in [0.001, 0.002, 0.004, 0.008, 0.016] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean() - 0.0062).abs() < 1e-9);
        assert_eq!(h.min(), 0.001);
        assert_eq!(h.max(), 0.016);
        // empty histogram degrades to zeros
        let e = Histogram::for_counts();
        assert_eq!(e.count(), 0);
        assert_eq!(e.quantile(0.5), 0.0);
        assert_eq!(e.mean(), 0.0);
    }

    #[test]
    fn quantiles_are_monotonic_and_bounded() {
        let mut h = Histogram::for_seconds();
        for i in 1..=1000 {
            h.record(i as f64 * 1e-4); // 0.1ms .. 100ms uniform
        }
        let (p10, p50, p95, p99) = (h.quantile(0.10), h.p50(), h.p95(), h.quantile(0.99));
        assert!(p10 <= p50 && p50 <= p95 && p95 <= p99);
        assert!(p50 >= h.min() && p99 <= h.max());
        // log-bucket resolution: within ~15% of the true quantile
        assert!((p50 - 0.05).abs() / 0.05 < 0.15, "p50 {p50}");
        assert!((p95 - 0.095).abs() / 0.095 < 0.15, "p95 {p95}");
    }

    #[test]
    fn record_clamps_junk() {
        let mut h = Histogram::for_counts();
        h.record(-4.0);
        h.record(f64::NAN);
        h.record(1e12); // above hi -> last bucket, max tracked exactly
        assert_eq!(h.count(), 3);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 1e12);
        assert!(h.quantile(1.0) <= 1e12);
    }

    #[test]
    fn quantile_bounds_at_bucket_edges() {
        // zero sample: clamps into bucket 0, min pins every quantile to 0
        let mut h = Histogram::for_counts();
        h.record(0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        // one sample: min == max == v, so every quantile is exactly v
        // (the geometric-midpoint estimate is clamped to the exact value)
        let mut h = Histogram::for_seconds();
        h.record(0.0137);
        for q in [0.0, 0.25, 0.5, 0.95, 1.0] {
            assert_eq!(h.quantile(q), 0.0137, "q={q}");
        }
        // saturating max: above-range samples land in the last bucket but
        // quantiles stay exact through the max clamp
        let mut h = Histogram::for_counts();
        h.record(3e7); // hi is 1e6
        h.record(4e7);
        assert_eq!(h.quantile(1.0), 4e7);
        assert!(h.quantile(0.25) >= 3e7);
        // exactly at the lower bound lo: bucket 0, exact via min clamp
        let mut h = Histogram::new(1.0, 100.0, 4);
        h.record(1.0);
        assert_eq!(h.quantile(0.5), 1.0);
    }

    #[test]
    fn merge_sums_same_geometry_histograms() {
        let mut a = Histogram::for_seconds();
        let mut b = Histogram::for_seconds();
        for v in [0.001, 0.004, 0.020] {
            a.record(v);
        }
        for v in [0.002, 0.100] {
            b.record(v);
        }
        // reference: everything recorded into one histogram
        let mut all = Histogram::for_seconds();
        for v in [0.001, 0.004, 0.020, 0.002, 0.100] {
            all.record(v);
        }
        a.merge(&b).unwrap();
        assert_eq!(a.count(), 5);
        assert!((a.sum() - all.sum()).abs() < 1e-12);
        assert_eq!(a.min(), 0.001);
        assert_eq!(a.max(), 0.100);
        assert_eq!(a.bucket_counts(), all.bucket_counts());
        assert_eq!(a.p95(), all.p95());
    }

    #[test]
    fn merge_handles_empty_sides() {
        let mut a = Histogram::for_counts();
        let mut b = Histogram::for_counts();
        b.record(7.0);
        a.merge(&b).unwrap();
        assert_eq!(a.count(), 1);
        assert_eq!(a.min(), 7.0);
        assert_eq!(a.max(), 7.0);
        // merging an empty histogram changes nothing
        let empty = Histogram::for_counts();
        a.merge(&empty).unwrap();
        assert_eq!(a.count(), 1);
        assert_eq!(a.min(), 7.0);
    }

    #[test]
    fn merge_rejects_geometry_mismatch() {
        let mut a = Histogram::for_seconds();
        let b = Histogram::for_counts();
        assert!(a.merge(&b).is_err());
        let c = Histogram::new(1e-6, 1e3, 161); // same span, different buckets
        assert!(a.merge(&c).is_err());
    }

    #[test]
    fn serving_merge_sums_counters_and_histograms() {
        let mut a = ServingMetrics::default();
        let mut b = ServingMetrics::default();
        a.admitted = 7;
        a.prefix_hits = 3;
        a.shed = 1;
        a.latency.record(0.010);
        b.admitted = 5;
        b.prefix_hits = 2;
        b.requeued = 4;
        b.latency.record(0.030);
        b.ttft.record(0.002);
        a.merge(&b).unwrap();
        assert_eq!(a.admitted, 12);
        assert_eq!(a.prefix_hits, 5);
        assert_eq!(a.shed, 1);
        assert_eq!(a.requeued, 4);
        assert_eq!(a.latency.count(), 2);
        assert_eq!(a.latency.max(), 0.030);
        assert_eq!(a.ttft.count(), 1);
    }

    #[test]
    fn spec_counters_merge_and_rate() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.spec_accept_rate(), 0.0);
        m.spec_accepted = 6;
        m.spec_rejected = 2;
        m.spec_forced = 1;
        m.spec_rollback_rows = 3;
        m.spec_rounds = 3;
        m.spec_accept.record(0.75);
        assert_eq!(m.spec_accept_rate(), 0.75);
        // summary gains a spec line only once a verify round ran
        assert!(ServingMetrics::default().summary().find("spec accept").is_none());
        let s = m.summary();
        assert!(s.contains("spec accept rate 75% (6 accepted, 2 rejected, 1 bonus)"));
        assert!(s.contains("rolled-back rows 3"));
        let mut rollup = ServingMetrics::default();
        rollup.spec_accepted = 4;
        rollup.spec_rounds = 2;
        rollup.spec_accept.record(1.0);
        rollup.merge(&m).unwrap();
        assert_eq!(rollup.spec_accepted, 10);
        assert_eq!(rollup.spec_rejected, 2);
        assert_eq!(rollup.spec_forced, 1);
        assert_eq!(rollup.spec_rollback_rows, 3);
        assert_eq!(rollup.spec_rounds, 5);
        assert_eq!(rollup.spec_accept.count(), 2);
        assert_eq!(rollup.spec_accept_rate(), 10.0 / 12.0);
    }

    #[test]
    fn serving_merge_mismatch_is_error_with_exact_counters() {
        let mut a = ServingMetrics::default();
        let mut b = ServingMetrics::default();
        // one replica built with a different latency geometry
        b.latency = Histogram::new(1e-3, 1e2, 50);
        b.latency.record(0.5);
        b.admitted = 9;
        b.ttft.record(0.004);
        let err = a.merge(&b).unwrap_err().to_string();
        assert!(err.contains("latency"), "err: {err}");
        assert!(!err.contains("ttft"), "only the mismatched histogram is named: {err}");
        // counters summed exactly despite the error; the mismatched
        // histogram kept this side's (empty) data, the rest merged
        assert_eq!(a.admitted, 9);
        assert_eq!(a.latency.count(), 0);
        assert_eq!(a.ttft.count(), 1);
    }

    #[test]
    fn bucket_bounds_bracket_recorded_values() {
        let mut h = Histogram::for_seconds();
        h.record(0.0123);
        let (i, _) = h
            .bucket_counts()
            .iter()
            .enumerate()
            .find(|(_, &c)| c > 0)
            .expect("one bucket populated");
        assert!(h.bucket_bound(i) >= 0.0123, "upper bound contains the sample");
        if i > 0 {
            assert!(h.bucket_bound(i - 1) <= 0.0123 * 1.0001);
        }
    }

    #[test]
    fn prefill_chunk_histogram_records_fed_chunk_sizes() {
        use crate::coordinator::{DecodeEngine, GenRequest, SynthBackend};
        use crate::formats::{NxConfig, QuantPolicy};
        use crate::models::LmSpec;
        let spec = LmSpec::tiny();
        let run = |budget: usize| {
            let mut eng = DecodeEngine::with_backend(
                spec.clone(),
                Box::new(SynthBackend::new(&spec)),
                &QuantPolicy::uniform(NxConfig::nxfp(4)),
                1,
            );
            eng.set_prefill_budget(budget);
            let req = GenRequest { id: 0, prompt: vec![3; 10], max_new: 1 };
            eng.serve_wave(vec![req]).unwrap();
            eng.serving
        };
        // budget 4, one lane: 3 extra tokens per step -> the 10-token
        // prompt is fed as per-step totals [4, 4, 2], exactly
        let m = run(4);
        assert_eq!(m.prefill_chunk.count(), 3);
        assert_eq!(m.prefill_chunk.max(), 4.0);
        assert_eq!(m.prefill_chunk.min(), 2.0);
        assert!((m.prefill_chunk.mean() - 10.0 / 3.0).abs() < 1e-9);
        // the per-step split histograms saw the same prefill totals
        assert_eq!(m.step_prefill_tokens.count(), 3);
        assert_eq!(m.step_prefill_tokens.max(), 4.0);
        // unbounded budget: the whole prompt is one fed chunk of 10
        let m = run(usize::MAX);
        assert_eq!(m.prefill_chunk.count(), 1);
        assert_eq!(m.prefill_chunk.min(), 10.0);
        assert_eq!(m.prefill_chunk.max(), 10.0);
        // unchunked: ten feeds of exactly one token
        let m = run(1);
        assert_eq!(m.prefill_chunk.count(), 10);
        assert_eq!(m.prefill_chunk.max(), 1.0);
        assert_eq!(m.prefill_chunk.mean(), 1.0);
    }

    #[test]
    fn serving_metrics_summary_renders() {
        let mut m = ServingMetrics::default();
        m.latency.record(0.010);
        m.ttft.record(0.004);
        m.queue_depth.record(3.0);
        m.admitted = 1;
        let s = m.summary();
        assert!(s.contains("latency"));
        assert!(s.contains("admitted 1"));
        // prefix-cache line only renders once a lookup happened
        assert!(!s.contains("prefix cache"));
        m.prefix_hits = 3;
        m.prefix_misses = 1;
        m.prefix_rows.record(48.0);
        assert!(m.summary().contains("prefix cache hit rate 75% (3 of 4 lookups)"));
        // fault line only renders once something went wrong
        assert!(!m.summary().contains("faults"));
        m.step_faults = 2;
        m.nan_faults = 1;
        m.retries = 3;
        m.requeued = 1;
        assert_eq!(m.total_faults(), 3);
        let s = m.summary();
        assert!(s.contains("faults step/chunk/nan 2/0/1"));
        assert!(s.contains("requeued 1"));
    }

    #[test]
    fn prefix_hit_rate_handles_empty_and_mixed() {
        let mut m = ServingMetrics::default();
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.prefix_misses = 2;
        assert_eq!(m.prefix_hit_rate(), 0.0);
        m.prefix_hits = 6;
        assert_eq!(m.prefix_hit_rate(), 0.75);
    }
}
