//! Threaded serving front-end: a request queue feeding the batched decode
//! engine on a dedicated worker thread (std::thread + mpsc; tokio is
//! unavailable offline). Requests accumulate into waves of up to
//! `max_batch`; the worker drains the queue between waves so bursty clients
//! batch naturally. Within a wave the engine keeps per-slot staging
//! buffers and dequantizes the packed KV caches incrementally (see
//! [`super::SlotKv`]), so per-step decode work does not grow with cache
//! fill. Set `NXFP_SERVE_LOG=1` to log per-wave throughput.

use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::{DecodeEngine, GenRequest, GenResponse, Metrics};
use crate::formats::NxConfig;
use crate::models::{Checkpoint, LmSpec};
use crate::runtime::Runtime;

enum Msg {
    Req(GenRequest),
    Shutdown,
}

/// Handle to a running server worker.
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    rx: mpsc::Receiver<GenResponse>,
    join: Option<JoinHandle<Result<Metrics>>>,
}

impl ServerHandle {
    /// Spawn the worker (builds the PJRT runtime on its own thread: the
    /// client is not Send).
    pub fn spawn(
        artifacts_dir: PathBuf,
        spec: LmSpec,
        ck: Checkpoint,
        kv_cfg: Option<NxConfig>,
        max_batch: usize,
        batch_window: Duration,
    ) -> ServerHandle {
        let (tx, worker_rx) = mpsc::channel::<Msg>();
        let (resp_tx, rx) = mpsc::channel::<GenResponse>();
        let join = std::thread::spawn(move || -> Result<Metrics> {
            let mut rt = Runtime::cpu(artifacts_dir)?;
            let mut engine = DecodeEngine::new(&mut rt, spec, &ck, kv_cfg, max_batch)?;
            let mut pending: Vec<GenRequest> = Vec::new();
            let mut shutting_down = false;
            let log_waves = std::env::var("NXFP_SERVE_LOG").is_ok_and(|v| v != "0");
            loop {
                // block for the first request, then drain within the window
                if pending.is_empty() && !shutting_down {
                    match worker_rx.recv() {
                        Ok(Msg::Req(r)) => pending.push(r),
                        Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
                    }
                }
                if !shutting_down {
                    let deadline = std::time::Instant::now() + batch_window;
                    while pending.len() < max_batch {
                        let left = deadline.saturating_duration_since(std::time::Instant::now());
                        match worker_rx.recv_timeout(left) {
                            Ok(Msg::Req(r)) => pending.push(r),
                            Ok(Msg::Shutdown) => {
                                shutting_down = true;
                                break;
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                shutting_down = true;
                                break;
                            }
                        }
                    }
                }
                if pending.is_empty() && shutting_down {
                    return Ok(engine.metrics);
                }
                let wave: Vec<GenRequest> =
                    pending.drain(..pending.len().min(max_batch)).collect();
                if wave.is_empty() {
                    continue;
                }
                let wave_size = wave.len();
                let before = engine.metrics;
                for resp in engine.serve_wave(wave)? {
                    let _ = resp_tx.send(resp);
                }
                if log_waves {
                    let m = engine.metrics;
                    let tokens = m.tokens_generated - before.tokens_generated;
                    let wall = m.wall.saturating_sub(before.wall);
                    let savings = if m.kv_bits_fp16 > 0 {
                        format!(", kv savings {:.1}% (cumulative)", m.kv_savings() * 100.0)
                    } else {
                        String::new()
                    };
                    eprintln!(
                        "[serve] wave of {wave_size}: {} steps, {tokens} tokens, \
                         {:.1} tok/s{savings}",
                        m.decode_steps - before.decode_steps,
                        tokens as f64 / wall.as_secs_f64().max(1e-9)
                    );
                }
            }
        });
        ServerHandle { tx, rx, join: Some(join) }
    }

    pub fn submit(&self, req: GenRequest) {
        let _ = self.tx.send(Msg::Req(req));
    }

    /// Blocking receive of the next completed response.
    pub fn recv(&self) -> Option<GenResponse> {
        self.rx.recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<GenResponse> {
        self.rx.recv_timeout(d).ok()
    }

    /// Finish outstanding work and return aggregate metrics.
    pub fn shutdown(mut self) -> Result<Metrics> {
        let _ = self.tx.send(Msg::Shutdown);
        self.join
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("server worker panicked"))?
    }
}
