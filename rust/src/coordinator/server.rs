//! Threaded serving front-end: a request queue feeding the batched decode
//! engine on a dedicated worker thread (std::thread + mpsc; tokio is
//! unavailable offline).
//!
//! Two scheduling modes (see [`SchedMode`] and `ARCHITECTURE.md`):
//!
//! * **Continuous** (default): requests stream into a
//!   [`Scheduler`] admission queue; the worker drains arrivals between
//!   engine steps and the scheduler admits into any lane the moment it
//!   frees — no wave barrier, so a short request never parks a lane while
//!   a long neighbour keeps decoding.
//! * **Wave** (legacy): requests accumulate into waves of up to
//!   `max_batch` within `batch_window`, and each wave runs to completion
//!   before the next starts.
//!
//! Within a step the engine dequantizes the packed KV caches incrementally
//! straight into each slot's lane (see [`super::SlotKv`]), so per-step
//! decode work does not grow with cache fill. Both modes run chunked
//! prefill under [`ServeOpts::prefill_budget`] (continuous mode also
//! feeds the budget into the admission ranking). Set `NXFP_SERVE_LOG=1`
//! to log per-wave (wave mode) or periodic (continuous mode) throughput.

use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::fault::FaultPlan;
use super::metrics::ServingMetrics;
use super::scheduler::{SchedMode, Scheduler};
use super::{
    DecodeEngine, GenRequest, GenResponse, Metrics, SynthBackend, DEFAULT_PREFILL_BUDGET,
    DEFAULT_RETRY_BACKOFF, DEFAULT_RETRY_MAX,
};
use crate::formats::QuantPolicy;
use crate::models::{Checkpoint, LmSpec};
use crate::obs::{write_metrics, CodeOccupancy, TraceSink, TraceSummary, DEFAULT_TRACE_CAP};
use crate::runtime::Runtime;
use crate::spec::{SpecEngine, SpecPolicy};

/// Default snapshot cadence ([`ServeOpts::metrics_snapshot_steps`]): the
/// worker rewrites `--metrics-out` every this many engine steps (cheap: a
/// few KB of text), so a live server's metrics file is never more than a
/// snapshot interval stale.
pub const METRICS_SNAPSHOT_STEPS: u64 = 256;

enum Msg {
    Req(GenRequest),
    /// Stop admitting (new submits are answered `FinishReason::Shed`),
    /// finish in-flight work, then report.
    Drain,
    Shutdown,
    /// Abrupt stop: abandon queued and in-flight work immediately and
    /// report it back through [`ServeReport::unserved`] — the fleet
    /// router replays it on surviving replicas.
    Kill,
}

/// Front-end configuration for [`ServerHandle::spawn`] — everything about
/// *scheduling*, as opposed to the model/format arguments.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Batch lanes (must match the artifact's baked `B`).
    pub max_batch: usize,
    /// Wave-mode accumulation window; continuous admission happens
    /// between engine steps and ignores this.
    pub batch_window: Duration,
    pub mode: SchedMode,
    /// Per-step token budget for chunked prefill, applied in **both**
    /// modes (engine and admission policy); 1 = unchunked per-token
    /// prefill, `usize::MAX` = whole prompts in one step.
    pub prefill_budget: usize,
    /// Rows per quantized-KV page (`--kv-page-rows`). Page geometry never
    /// changes packed bytes or generations — it only sets the granularity
    /// prefix sharing dedups at.
    pub kv_page_rows: usize,
    /// Share packed KV pages across prompts with a common token prefix
    /// (`--prefix-cache`, continuous mode + quantized KV only). Off:
    /// admission, generations, and packed bytes are bit-identical to a
    /// build without the cache.
    pub prefix_cache: bool,
    /// Bounded admission queue (`--queue-cap`): arrivals past this depth
    /// are answered `FinishReason::Shed` instead of queueing without
    /// bound. `usize::MAX` = unbounded (the default).
    pub queue_cap: usize,
    /// Per-request wall-clock deadline (`--deadline-ms`), enforced at
    /// admission and per step; `None` = no deadline.
    pub deadline: Option<Duration>,
    /// Per-request queue-steps deadline: a request that waits more than
    /// this many engine steps is answered `FinishReason::Deadline` at
    /// admission; `None` = no bound.
    pub max_queue_steps: Option<u64>,
    /// Transient-fault retries per backend call (`--retry-max`) before
    /// the affected slots retire into the requeue path.
    pub retry_max: u32,
    /// Seeded fault injection (`--fault-plan`; bench/test only): wraps
    /// the backend in a `FaultBackend` before serving.
    pub fault: Option<FaultPlan>,
    /// Write the structured JSONL event trace here at drain/shutdown
    /// (`--trace-out`). `None` leaves the no-op sink installed: the
    /// traced lifecycle costs one null check per would-be event.
    pub trace_out: Option<PathBuf>,
    /// Write a metrics export here (`--metrics-out`): Prometheus text,
    /// or JSON when the extension is `.json`. Written at drain/shutdown
    /// and refreshed every [`METRICS_SNAPSHOT_STEPS`] continuous steps.
    pub metrics_out: Option<PathBuf>,
    /// Attach live code-occupancy probes to every slot's KV caches
    /// (`--occupancy`): per-config clip/vacant/recycle rates in the
    /// metrics export and [`ServeReport::occupancy`].
    pub occupancy: bool,
    /// Snapshot cadence for `metrics_out`: continuous mode rewrites the
    /// export every this many engine steps; wave mode rewrites it after
    /// any wave that crosses a multiple of it (per-wave granularity —
    /// a wave never pauses mid-flight to write text). Defaults to
    /// [`METRICS_SNAPSHOT_STEPS`]; tests shrink it.
    pub metrics_snapshot_steps: u64,
    /// Draft depth for precision-speculative decoding (`--spec-k`): the
    /// serving-precision lanes propose up to this many tokens per round
    /// and a paired higher-precision lane verifies them in one chunked
    /// call. 0 (the default) serves plain per-token decode. Continuous
    /// mode only — lane pairing halves concurrent requests per step.
    pub spec_k: usize,
    /// Verifier-lane KV policy for speculative decoding (`--spec-verify`):
    /// `fp16` (the default reference) or a higher-precision quantized
    /// policy such as `nxfp6`. Ignored while `spec_k` is 0.
    pub spec_verify: String,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            max_batch: 4,
            batch_window: Duration::from_millis(5),
            mode: SchedMode::Continuous,
            prefill_budget: DEFAULT_PREFILL_BUDGET,
            kv_page_rows: crate::quant::page::DEFAULT_KV_PAGE_ROWS,
            prefix_cache: true,
            queue_cap: usize::MAX,
            deadline: None,
            max_queue_steps: None,
            retry_max: DEFAULT_RETRY_MAX,
            fault: None,
            trace_out: None,
            metrics_out: None,
            occupancy: false,
            metrics_snapshot_steps: METRICS_SNAPSHOT_STEPS,
            spec_k: 0,
            spec_verify: "fp16".to_string(),
        }
    }
}

/// Final accounting a worker returns at shutdown.
pub struct ServeReport {
    pub metrics: Metrics,
    pub serving: ServingMetrics,
    /// Per-config occupancy probe tables (empty unless
    /// [`ServeOpts::occupancy`] was set).
    pub occupancy: Vec<CodeOccupancy>,
    /// Requests accepted but never answered, handed back by
    /// [`ServerHandle::kill`] for replay elsewhere (queue order first,
    /// then in-flight slots by lane). Always empty on a graceful
    /// shutdown or drain — those paths answer everything.
    pub unserved: Vec<GenRequest>,
}

/// Handle to a running server worker.
pub struct ServerHandle {
    tx: mpsc::Sender<Msg>,
    // Option so a fleet router can detach the stream (`take_rx`) and pump
    // it from a forwarder thread instead of polling N handles.
    rx: Option<mpsc::Receiver<GenResponse>>,
    join: Option<JoinHandle<Result<ServeReport>>>,
}

impl ServerHandle {
    /// Spawn the worker (builds the PJRT runtime on its own thread: the
    /// client is not Send). `kv` is the quantization policy's KV side:
    /// per-layer, per-stream formats (`QuantPolicy::uniform(cfg)` and
    /// `QuantPolicy::fp16()` reproduce the legacy single-config shapes).
    pub fn spawn(
        artifacts_dir: PathBuf,
        spec: LmSpec,
        ck: Checkpoint,
        kv: QuantPolicy,
        opts: ServeOpts,
    ) -> ServerHandle {
        let (tx, worker_rx) = mpsc::channel::<Msg>();
        let (resp_tx, rx) = mpsc::channel::<GenResponse>();
        let join = std::thread::spawn(move || -> Result<ServeReport> {
            // the runtime outlives the engine on this thread; it cannot
            // move through the generic `spawn_with` seam (not Send)
            let mut rt = Runtime::cpu(artifacts_dir)?;
            let engine = DecodeEngine::new(&mut rt, spec, &ck, &kv, opts.max_batch)?;
            serve_thread(engine, &worker_rx, &resp_tx, &opts)
        });
        ServerHandle { tx, rx: Some(rx), join: Some(join) }
    }

    /// Spawn a worker around an engine built by `make_engine` on the
    /// worker thread itself (engines are not Send: they hold
    /// `Rc<RefCell<PagePool>>`). All scheduling opts — budget, retry
    /// policy, deadline, faults, trace, occupancy — are applied here, so
    /// every spawn flavor serves identically.
    pub fn spawn_with<F>(make_engine: F, opts: ServeOpts) -> ServerHandle
    where
        F: FnOnce(&ServeOpts) -> Result<DecodeEngine> + Send + 'static,
    {
        let (tx, worker_rx) = mpsc::channel::<Msg>();
        let (resp_tx, rx) = mpsc::channel::<GenResponse>();
        let join = std::thread::spawn(move || -> Result<ServeReport> {
            let engine = make_engine(&opts)?;
            serve_thread(engine, &worker_rx, &resp_tx, &opts)
        });
        ServerHandle { tx, rx: Some(rx), join: Some(join) }
    }

    /// Artifact-free worker over the deterministic [`SynthBackend`] —
    /// the fleet router's per-replica engine (and the bench/test seam).
    pub fn spawn_synth(spec: LmSpec, kv: QuantPolicy, opts: ServeOpts) -> ServerHandle {
        Self::spawn_with(
            move |opts| {
                Ok(DecodeEngine::with_backend(
                    spec.clone(),
                    Box::new(SynthBackend::new(&spec)),
                    &kv,
                    opts.max_batch,
                ))
            },
            opts,
        )
    }

    /// Submit a request. Returns whether the worker will see it: `false`
    /// means the worker is gone (shut down, drained, or dead) and the
    /// request was **not** accepted — never a silent drop. `true` from a
    /// draining worker still yields a response: `FinishReason::Shed`.
    pub fn submit(&self, req: GenRequest) -> bool {
        self.tx.send(Msg::Req(req)).is_ok()
    }

    /// Blocking receive of the next completed response. `None` once the
    /// worker is gone — or always, after [`Self::take_rx`] detached the
    /// stream.
    pub fn recv(&self) -> Option<GenResponse> {
        self.rx.as_ref()?.recv().ok()
    }

    pub fn recv_timeout(&self, d: Duration) -> Option<GenResponse> {
        self.rx.as_ref()?.recv_timeout(d).ok()
    }

    /// Detach the response stream so a fleet forwarder thread can own it;
    /// `recv`/`recv_timeout` on the handle return `None` afterwards.
    pub fn take_rx(&mut self) -> Option<mpsc::Receiver<GenResponse>> {
        self.rx.take()
    }

    /// Finish outstanding work and return the final accounting. A second
    /// call (or a call after [`Self::drain`]) returns an error instead of
    /// panicking.
    pub fn shutdown(&mut self) -> Result<ServeReport> {
        let _ = self.tx.send(Msg::Shutdown);
        self.join_inner()
    }

    /// Graceful drain: stop admitting (submits already in flight are
    /// answered `FinishReason::Shed`), finish every active request, then
    /// return the final accounting. Subsequent `submit` returns `false`.
    pub fn drain(&mut self) -> Result<ServeReport> {
        let _ = self.tx.send(Msg::Drain);
        self.join_inner()
    }

    /// Start a graceful drain without joining: the worker stops
    /// admitting (racing submits are answered `FinishReason::Shed`),
    /// finishes its backlog, and exits. Collect the report later with
    /// [`Self::drain`]/[`Self::shutdown`] — the router uses this to
    /// drain one replica while traffic keeps flowing elsewhere.
    pub fn begin_drain(&self) {
        let _ = self.tx.send(Msg::Drain);
    }

    /// Abrupt kill: abandon queued and in-flight work immediately and
    /// return the report with [`ServeReport::unserved`] — every accepted
    /// request that never produced a response, in deterministic order,
    /// for the caller to replay from the prompt elsewhere (bit-identical:
    /// same determinism argument as requeue-from-prompt replay).
    pub fn kill(&mut self) -> Result<ServeReport> {
        let _ = self.tx.send(Msg::Kill);
        self.join_inner()
    }

    fn join_inner(&mut self) -> Result<ServeReport> {
        match self.join.take() {
            Some(j) => j.join().map_err(|_| anyhow::anyhow!("server worker panicked"))?,
            None => Err(anyhow::anyhow!("server already shut down")),
        }
    }
}

/// Shared worker body: apply every scheduling opt to the freshly built
/// engine, then run the mode's serve loop. Both spawn flavors (PJRT
/// artifacts and synthetic backends) funnel through here so they serve
/// identically. With `spec_k > 0` the engine is wrapped in a
/// [`SpecEngine`] and the continuous loop drives draft/verify rounds
/// instead of per-token steps — same admission, drain, and kill paths.
fn serve_thread(
    mut engine: DecodeEngine,
    worker_rx: &mpsc::Receiver<Msg>,
    resp_tx: &mpsc::Sender<GenResponse>,
    opts: &ServeOpts,
) -> Result<ServeReport> {
    engine.set_prefill_budget(opts.prefill_budget);
    engine.set_kv_page_rows(opts.kv_page_rows);
    engine.set_retry_policy(opts.retry_max, DEFAULT_RETRY_BACKOFF);
    engine.set_deadline(opts.deadline);
    if let Some(plan) = &opts.fault {
        engine.inject_faults(plan);
    }
    if opts.trace_out.is_some() {
        engine.set_trace_sink(TraceSink::enabled(DEFAULT_TRACE_CAP));
    }
    if opts.occupancy {
        engine.enable_occupancy();
    }
    let log = std::env::var("NXFP_SERVE_LOG").is_ok_and(|v| v != "0");
    match opts.mode {
        SchedMode::Continuous if opts.spec_k > 0 => {
            let policy = SpecPolicy::parse(opts.spec_k, &opts.spec_verify)?;
            let mut se = SpecEngine::new(engine, policy)?;
            let sched = se.scheduler(Scheduler::DEFAULT_PROMOTE_AFTER);
            run_continuous(&mut se, sched, worker_rx, resp_tx, opts, log)
        }
        SchedMode::Continuous => {
            let sched = Scheduler::new(engine.max_batch, Scheduler::DEFAULT_PROMOTE_AFTER);
            run_continuous(&mut engine, sched, worker_rx, resp_tx, opts, log)
        }
        SchedMode::Wave => {
            anyhow::ensure!(
                opts.spec_k == 0,
                "--spec-k requires continuous scheduling (wave mode runs to completion \
                 per batch; there is no between-step seam to verify in)"
            );
            run_waves(&mut engine, worker_rx, resp_tx, opts, log)
        }
    }
}

/// Seam between the plain engine and the speculative wrapper: the
/// continuous loop needs the underlying [`DecodeEngine`] for admission,
/// validation, and observability, plus one macro-step entry point — and
/// nothing else differs between the two drivers.
trait ContinuousStepper {
    fn inner(&self) -> &DecodeEngine;
    fn inner_mut(&mut self) -> &mut DecodeEngine;
    fn step(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>>;
}

impl ContinuousStepper for DecodeEngine {
    fn inner(&self) -> &DecodeEngine {
        self
    }
    fn inner_mut(&mut self) -> &mut DecodeEngine {
        self
    }
    fn step(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        self.step_continuous(sched)
    }
}

impl ContinuousStepper for SpecEngine {
    fn inner(&self) -> &DecodeEngine {
        self.engine()
    }
    fn inner_mut(&mut self) -> &mut DecodeEngine {
        self.engine_mut()
    }
    fn step(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        self.step_continuous(sched)
    }
}

/// Kill-path epilogue: sweep requests still sitting in the channel into
/// `unserved` (they were accepted — `submit` returned `true`), write the
/// observability artifacts, and report. Nothing is answered: the caller
/// owns replaying `unserved`.
fn finish_kill(
    engine: &mut DecodeEngine,
    mut unserved: Vec<GenRequest>,
    worker_rx: &mpsc::Receiver<Msg>,
    opts: &ServeOpts,
    log: bool,
) -> Result<ServeReport> {
    while let Ok(msg) = worker_rx.try_recv() {
        if let Msg::Req(r) = msg {
            unserved.push(r);
        }
    }
    if log {
        eprintln!("[serve] killed with {} unserved request(s)", unserved.len());
    }
    let occ = engine.occupancy_report();
    write_obs_outputs(engine, opts, &occ);
    Ok(ServeReport {
        metrics: engine.metrics,
        serving: engine.serving.clone(),
        occupancy: occ,
        unserved,
    })
}

/// Continuous worker loop: drain arrivals into the scheduler between
/// engine steps; block only when fully idle. The caller builds the bare
/// scheduler (the speculative driver pairs lanes, so its slot count
/// differs); every policy knob is applied here so both drivers admit
/// identically.
fn run_continuous<S: ContinuousStepper>(
    stepper: &mut S,
    mut sched: Scheduler,
    worker_rx: &mpsc::Receiver<Msg>,
    resp_tx: &mpsc::Sender<GenResponse>,
    opts: &ServeOpts,
    log: bool,
) -> Result<ServeReport> {
    // the scheduler shares the engine's trace ring and step clock
    sched.set_trace_sink(stepper.inner().trace_sink());
    // admission ranks by prefill steps under the same budget the engine
    // chunks with (one knob: ServeOpts::prefill_budget)
    sched.set_prefill_budget(stepper.inner().prefill_budget());
    sched.set_queue_cap(opts.queue_cap);
    sched.set_max_queue_steps(opts.max_queue_steps);
    // prefix sharing needs packed pages to share: fp16 lanes have none
    if opts.prefix_cache && stepper.inner().kv_plans().is_some() {
        let pool = stepper.inner().page_pool();
        sched.enable_prefix_cache(pool, Scheduler::DEFAULT_PREFIX_ENTRIES);
    }
    let mut shutting_down = false;
    let mut draining = false;
    let mut steps = 0u64;
    // overload/drain rejections answer immediately: the request never
    // queues, and the caller learns why via FinishReason::Shed
    let shed = |engine: &mut DecodeEngine, r: GenRequest| {
        let _ = resp_tx.send(engine.shed_response(r));
    };
    // deterministic rejections answer at enqueue time instead of queuing
    // behind real work (admit() re-validates for direct Scheduler users)
    let accept = |engine: &mut DecodeEngine, r: GenRequest, sched: &mut Scheduler, drn: bool| {
        if drn {
            shed(engine, r);
            return;
        }
        match engine.validate(&r) {
            Some(resp) => {
                let _ = resp_tx.send(resp);
            }
            None => {
                if let Some(back) = sched.enqueue(r) {
                    shed(engine, back);
                }
            }
        }
    };
    loop {
        // fully idle and not shutting down: block for the next message
        if !sched.has_work() {
            if shutting_down {
                // requests racing the drain/shutdown message are answered
                // (shed), not silently dropped: submit() returned `true`
                while let Ok(msg) = worker_rx.try_recv() {
                    if let Msg::Req(r) = msg {
                        shed(stepper.inner_mut(), r);
                    }
                }
                if log {
                    eprintln!("[serve] continuous summary: {}", stepper.inner().serving.summary());
                }
                let occ = stepper.inner().occupancy_report();
                write_obs_outputs(stepper.inner(), opts, &occ);
                let report = ServeReport {
                    metrics: stepper.inner().metrics,
                    serving: stepper.inner().serving.clone(),
                    occupancy: occ,
                    unserved: Vec::new(),
                };
                return Ok(report);
            }
            match worker_rx.recv() {
                Ok(Msg::Req(r)) => accept(stepper.inner_mut(), r, &mut sched, draining),
                Ok(Msg::Drain) => {
                    shutting_down = true;
                    draining = true;
                    continue;
                }
                Ok(Msg::Kill) => {
                    let unserved = sched.take_unserved();
                    return finish_kill(stepper.inner_mut(), unserved, worker_rx, opts, log);
                }
                Ok(Msg::Shutdown) | Err(_) => {
                    shutting_down = true;
                    continue;
                }
            }
        }
        // non-blocking drain: arrivals join the queue between steps
        let mut killed = false;
        loop {
            match worker_rx.try_recv() {
                Ok(Msg::Req(r)) => accept(stepper.inner_mut(), r, &mut sched, draining),
                Ok(Msg::Drain) => {
                    shutting_down = true;
                    draining = true;
                }
                Ok(Msg::Kill) => {
                    killed = true;
                    break;
                }
                Ok(Msg::Shutdown) => {
                    shutting_down = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    shutting_down = true;
                    break;
                }
            }
        }
        if killed {
            let unserved = sched.take_unserved();
            return finish_kill(stepper.inner_mut(), unserved, worker_rx, opts, log);
        }
        for resp in stepper.step(&mut sched)? {
            if log {
                eprintln!(
                    "[serve] req {} done: {} tokens in {:?} (queue {}, active {})",
                    resp.id,
                    resp.generated,
                    resp.latency,
                    sched.queue_depth(),
                    sched.active()
                );
            }
            let _ = resp_tx.send(resp);
        }
        steps += 1;
        if opts.metrics_out.is_some() && steps % opts.metrics_snapshot_steps.max(1) == 0 {
            let occ = stepper.inner().occupancy_report();
            if let Some(path) = &opts.metrics_out {
                let eng = stepper.inner();
                if let Err(e) = write_metrics(path, &eng.metrics, &eng.serving, &occ) {
                    eprintln!("[serve] metrics snapshot failed ({}): {e:#}", path.display());
                }
            }
        }
    }
}

/// Write the `--metrics-out` / `--trace-out` artifacts (best-effort: a
/// failed write is logged, never fatal — the in-memory report survives).
fn write_obs_outputs(engine: &DecodeEngine, opts: &ServeOpts, occ: &[CodeOccupancy]) {
    if let Some(path) = &opts.metrics_out {
        if let Err(e) = write_metrics(path, &engine.metrics, &engine.serving, occ) {
            eprintln!("[serve] metrics write failed ({}): {e:#}", path.display());
        }
    }
    if let Some(path) = &opts.trace_out {
        let summary = TraceSummary::from_serving(&engine.serving);
        if let Err(e) = engine.trace_sink().write_jsonl(path, &summary) {
            eprintln!("[serve] trace write failed ({}): {e:#}", path.display());
        }
    }
}

/// Legacy wave worker loop: accumulate up to `max_batch` requests within
/// `batch_window`, then run the wave to completion.
fn run_waves(
    engine: &mut DecodeEngine,
    worker_rx: &mpsc::Receiver<Msg>,
    resp_tx: &mpsc::Sender<GenResponse>,
    opts: &ServeOpts,
    log: bool,
) -> Result<ServeReport> {
    let (max_batch, batch_window) = (opts.max_batch, opts.batch_window);
    let mut pending: Vec<GenRequest> = Vec::new();
    let mut shutting_down = false;
    // wave-mode snapshots fire between waves: a wave that crosses a
    // multiple of the snapshot interval rewrites the export afterwards
    let mut last_snapshot_steps = 0u64;
    loop {
        // block for the first request, then drain within the window
        if pending.is_empty() && !shutting_down {
            match worker_rx.recv() {
                Ok(Msg::Req(r)) => pending.push(r),
                Ok(Msg::Kill) => return finish_kill(engine, pending, worker_rx, opts, log),
                Ok(Msg::Drain) | Ok(Msg::Shutdown) | Err(_) => shutting_down = true,
            }
        }
        if !shutting_down {
            let deadline = std::time::Instant::now() + batch_window;
            while pending.len() < max_batch {
                let left = deadline.saturating_duration_since(std::time::Instant::now());
                match worker_rx.recv_timeout(left) {
                    Ok(Msg::Req(r)) => pending.push(r),
                    Ok(Msg::Kill) => return finish_kill(engine, pending, worker_rx, opts, log),
                    Ok(Msg::Drain) | Ok(Msg::Shutdown) => {
                        shutting_down = true;
                        break;
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        shutting_down = true;
                        break;
                    }
                }
            }
        }
        if pending.is_empty() && shutting_down {
            // answer any stragglers still in the channel (requests racing
            // a drain/shutdown) before the final report, so no submit that
            // returned `true` goes unanswered
            while let Ok(msg) = worker_rx.try_recv() {
                if let Msg::Req(r) = msg {
                    let _ = resp_tx.send(engine.shed_response(r));
                }
            }
            let occ = engine.occupancy_report();
            write_obs_outputs(engine, opts, &occ);
            return Ok(ServeReport {
                metrics: engine.metrics,
                serving: engine.serving.clone(),
                occupancy: occ,
                unserved: Vec::new(),
            });
        }
        let wave: Vec<GenRequest> = pending.drain(..pending.len().min(max_batch)).collect();
        if wave.is_empty() {
            continue;
        }
        let wave_size = wave.len();
        let before = engine.metrics;
        for resp in engine.serve_wave(wave)? {
            let _ = resp_tx.send(resp);
        }
        // periodic snapshot at per-wave granularity: same cadence knob as
        // the continuous loop, so a long wave-mode run stays scrapeable
        if let Some(path) = &opts.metrics_out {
            let snap = opts.metrics_snapshot_steps.max(1);
            if engine.metrics.decode_steps / snap != last_snapshot_steps / snap {
                last_snapshot_steps = engine.metrics.decode_steps;
                let occ = engine.occupancy_report();
                if let Err(e) = write_metrics(path, &engine.metrics, &engine.serving, &occ) {
                    eprintln!("[serve] metrics snapshot failed ({}): {e:#}", path.display());
                }
            }
        }
        if log {
            let m = engine.metrics;
            let tokens = m.tokens_generated - before.tokens_generated;
            let wall = m.wall.saturating_sub(before.wall);
            let savings = if m.kv_bits_fp16 > 0 {
                format!(", kv savings {:.1}% (cumulative)", m.kv_savings() * 100.0)
            } else {
                String::new()
            };
            eprintln!(
                "[serve] wave of {wave_size}: {} steps, {tokens} tokens, \
                 {:.1} tok/s{savings}",
                m.decode_steps - before.decode_steps,
                tokens as f64 / wall.as_secs_f64().max(1e-9)
            );
        }
    }
}
