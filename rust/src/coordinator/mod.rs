//! Serving coordinator: batched greedy decoding through the `decode_step`
//! artifact with the KV cache held in **quantized packed form** between
//! steps (paper §6 on-the-fly dequantization deployment).
//!
//! `decode_step` contract (pinned against `python/compile/aot.py`):
//! inputs `P` params, `tokens [B]` (i32, current token per slot),
//! `pos [B]` (i32, cache fill per slot), `k_cache [B, L, S, D]`,
//! `v_cache [B, L, S, D]` (f32); outputs `logits [B, V]`,
//! `k_new [B, L, D]`, `v_new [B, L, D]`.
//!
//! See `ARCHITECTURE.md` in this directory for the full lane/slot/queue
//! vocabulary and the wave-vs-continuous design discussion.
//!
//! # Structure
//!
//! * [`StepBackend`] — the batched step kernel behind the engine: the PJRT
//!   artifact in production, the deterministic [`SynthBackend`] in tests
//!   and benches (no artifacts needed).
//! * [`DecodeEngine`] — owns the persistent `[B, L, S, D]` step slabs and
//!   the step primitives: admit-one-slot, one batched decode step,
//!   lane-to-lane slot moves.
//! * [`scheduler::Scheduler`] — slot-level admission queue + lane pool
//!   (continuous batching); [`DecodeEngine::serve_wave`] remains as the
//!   legacy wave-at-a-time loop.
//! * [`metrics::ServingMetrics`] — per-request latency/TTFT/queue-depth
//!   histograms next to the aggregate [`Metrics`] counters.
//!
//! # Decode hot path
//!
//! The batched step tensors (`k_f32`/`v_f32` slabs) persist inside the
//! engine, and each slot's packed caches carry a dirty-row watermark (see
//! [`crate::quant::kv_cache`]), so a decode step dequantizes only the rows
//! appended since the previous step — O(new rows), not O(total fill) —
//! **straight into the slot's lane** (no f32 staging mirror; PR 3 halved
//! resident f32 KV per slot by deleting it). Finished slots release their
//! packed buffers immediately, free their lane for the next queued
//! request, and have their slab lanes zeroed exactly once.

pub mod metrics;
pub mod scheduler;
pub mod server;

use anyhow::Result;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::formats::NxConfig;
use crate::models::{Checkpoint, LmSpec};
use crate::quant::kv_cache::KvCache;
use crate::runtime::{lit, Runtime, Step};
use crate::train::params_to_literals;

use self::metrics::ServingMetrics;
use self::scheduler::Scheduler;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// prompt + generated tokens
    pub tokens: Vec<i32>,
    pub generated: usize,
    /// Arrival → completion (queue wait included under the continuous
    /// scheduler; wave mode stamps arrival at wave start).
    pub latency: Duration,
}

/// Aggregate serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub wall: Duration,
    /// Packed KV bits summed at request **completion**: each finished
    /// request contributes its final cache footprint once. A completion-
    /// time total, not a live peak (formerly misnamed `kv_bits_peak`).
    pub kv_bits_packed: u64,
    /// FP16 bits the same completed caches would have occupied.
    pub kv_bits_fp16: u64,
}

impl Metrics {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn kv_savings(&self) -> f64 {
        1.0 - self.kv_bits_packed as f64 / self.kv_bits_fp16.max(1) as f64
    }
}

/// Output of one batched decode step.
pub struct StepOut {
    /// `[B, V]` next-token logits.
    pub logits: Vec<f32>,
    /// `[B, L, D]` freshly produced K rows (one per layer per slot).
    pub k_new: Vec<f32>,
    /// `[B, L, D]` freshly produced V rows.
    pub v_new: Vec<f32>,
}

/// The batched decode-step kernel the engine drives. `tokens`/`pos` are
/// `[B]`, `k`/`v` are the persistent `[B, L, S, D]` slabs. Implementations
/// must be **per-slot pure**: slot `b`'s outputs may depend only on
/// `tokens[b]`, `pos[b]`, and lane `b` of the slabs — that independence is
/// what makes continuous batching bit-identical to solo decoding (and is
/// what the real artifact guarantees, since attention never crosses batch
/// lanes).
pub trait StepBackend {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut>;
}

/// Production backend: the AOT `decode_step` artifact through PJRT.
struct PjrtBackend {
    step_fn: Rc<Step>,
    params: Vec<xla::Literal>,
    /// `(B, L, S, D)` as baked into the artifact.
    dims: (usize, usize, usize, usize),
}

impl StepBackend for PjrtBackend {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
        let (b, l, s, d) = self.dims;
        let tok_lit = lit::from_i32(tokens, &[b as i64])?;
        let pos_lit = lit::from_i32(pos, &[b as i64])?;
        let k_lit = lit::from_f32(k, &[b as i64, l as i64, s as i64, d as i64])?;
        let v_lit = lit::from_f32(v, &[b as i64, l as i64, s as i64, d as i64])?;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend([&tok_lit, &pos_lit, &k_lit, &v_lit]);
        let out = self.step_fn.run(&args)?;
        anyhow::ensure!(out.len() == 3, "decode_step returned {} outputs", out.len());
        Ok(StepOut {
            logits: lit::to_f32(&out[0])?,
            k_new: lit::to_f32(&out[1])?,
            v_new: lit::to_f32(&out[2])?,
        })
    }
}

/// Deterministic synthetic decode step for scheduler tests and benches —
/// no PJRT runtime or artifacts required.
///
/// Shaped like the real artifact (fixed `[B, L, S, D]` cost per step, all
/// lanes processed every step) and deliberately **KV-sensitive**: slot
/// `b`'s logits are an attention-like reduction over *every* row of lane
/// `b`, so stale rows from a previous occupant, missed incremental syncs,
/// or cross-lane mix-ups change the generated tokens. Padding rows are
/// zero and contribute nothing, which keeps a slot's generation
/// bit-identical whether it runs alone or packed into a busy batch — the
/// property the scheduler determinism tests pin.
pub struct SynthBackend {
    l: usize,
    s: usize,
    d: usize,
    vocab: usize,
}

impl SynthBackend {
    pub fn new(spec: &LmSpec) -> Self {
        SynthBackend { l: spec.n_layers, s: spec.seq_len, d: spec.d_model, vocab: spec.vocab }
    }
}

/// Integer hash → f32 in `[-1, 1)`, exactly representable (24-bit
/// mantissa path) so every platform produces the same bits.
fn hash01(x: u32) -> f32 {
    let mut h = x.wrapping_mul(0x9E37_79B9);
    h ^= h >> 16;
    h = h.wrapping_mul(0x21F0_AAAD);
    h ^= h >> 15;
    (h >> 8) as f32 * (2.0 / (1 << 24) as f32) - 1.0
}

impl StepBackend for SynthBackend {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
        let (l, s, d, vb) = (self.l, self.s, self.d, self.vocab);
        let bsz = tokens.len();
        let lane = l * s * d;
        let mut logits = vec![0.0f32; bsz * vb];
        let mut k_new = vec![0.0f32; bsz * l * d];
        let mut v_new = vec![0.0f32; bsz * l * d];
        for b in 0..bsz {
            let tok = tokens[b] as u32;
            let p = pos[b] as u32;
            let k_lane = &k[b * lane..(b + 1) * lane];
            let v_lane = &v[b * lane..(b + 1) * lane];
            let lg = &mut logits[b * vb..(b + 1) * vb];
            for li in 0..l {
                // fresh KV row: a pure function of (token, pos, layer, dim)
                for j in 0..d {
                    let key = tok.wrapping_mul(31) ^ p.rotate_left(9) ^ ((li as u32) << 20);
                    k_new[(b * l + li) * d + j] = hash01(key ^ j as u32);
                    v_new[(b * l + li) * d + j] = hash01(key ^ j as u32 ^ 0xA5A5_5A5A);
                }
                // attention-like read of the whole lane: every stored row
                // contributes, zero padding rows vanish
                let base = li * s * d;
                for r in 0..s {
                    let mut score = 0.0f32;
                    let mut val = 0.0f32;
                    for j in 0..d {
                        let row = base + r * d + j;
                        score += k_lane[row] * hash01(j as u32 ^ tok.wrapping_mul(0x9E37_79B1));
                        val += v_lane[row] * hash01(j as u32 ^ 0x5851_F42D);
                    }
                    lg[(r * 31 + li * 7 + 3) % vb] += score * val;
                }
            }
            // token/pos spike keeps greedy decoding non-degenerate
            let spike = (tok as usize).wrapping_mul(7).wrapping_add(p as usize) % vb;
            lg[spike] += 2.0 * hash01(tok ^ p.wrapping_mul(97));
        }
        Ok(StepOut { logits, k_new, v_new })
    }
}

/// Per-slot quantized KV state: one packed [`KvCache`] per layer that
/// decodes **straight into the slot's assigned batch lane**.
///
/// [`SlotKv::sync_into`] decodes only the rows appended since the previous
/// call (the caches' dirty-row watermark) directly into the slot's
/// `[L, S, D]` lane of the batched step tensors, so per-step decode work
/// is O(new rows) instead of O(total fill) and there is **no intermediate
/// f32 staging mirror** (PR 1 kept one for lane mobility, doubling
/// resident f32 KV per slot; PR 3 deleted it). A slot moves to a different
/// lane either by a lane-to-lane slab copy (`DecodeEngine::move_lane` —
/// watermarks stay valid, nothing is re-decoded) or, when the old lane is
/// gone, by [`SlotKv::resync_full_into`], which re-decodes the whole
/// prefix from the packed streams. Dropping a `SlotKv` releases the packed
/// blocks (finished slots free immediately).
pub struct SlotKv {
    caches: Vec<KvCache>,
    /// Lane rows (the artifact's fixed context length `S`).
    pad_len: usize,
    dim: usize,
}

impl SlotKv {
    /// `n_layers` caches of feature dim `dim` for a lane padded to
    /// `pad_len` rows. Each cache pre-reserves the full window so
    /// decode-step appends never reallocate.
    pub fn new(n_layers: usize, dim: usize, pad_len: usize, cfg: &NxConfig) -> Self {
        SlotKv {
            caches: (0..n_layers)
                .map(|_| KvCache::with_capacity(dim, cfg.clone(), pad_len))
                .collect(),
            pad_len,
            dim,
        }
    }

    /// Rows appended so far (cache fill; identical across layers).
    pub fn fill(&self) -> usize {
        self.caches[0].len
    }

    /// Quantize and append one generated (k, v) row for `layer`.
    pub fn append(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        self.caches[layer].append(k_row, v_row);
    }

    /// Incrementally decode rows appended since the previous call straight
    /// into this slot's `[L, S, D]` lanes of the batched step tensors. The
    /// lane must persist across steps (the engine keeps the slab alive and
    /// zeroes a lane only when its slot finishes) or be a bit-identical
    /// copy (after [`DecodeEngine::move_lane`]).
    pub fn sync_into(&mut self, k_lane: &mut [f32], v_lane: &mut [f32]) {
        let (s, d) = (self.pad_len, self.dim);
        debug_assert_eq!(k_lane.len(), self.caches.len() * s * d);
        debug_assert_eq!(v_lane.len(), k_lane.len());
        for (li, cache) in self.caches.iter_mut().enumerate() {
            let base = li * s * d;
            cache.dequantize_into_slab(
                &mut k_lane[base..base + s * d],
                &mut v_lane[base..base + s * d],
            );
        }
    }

    /// Rebuild the **entire** decoded prefix (rows `0..fill`) in a lane by
    /// re-decoding the packed streams — the lane-reassignment fallback for
    /// when the previous lane's contents cannot be slab-copied. Resets the
    /// dirty-row watermarks first, so the shared decode routine replays
    /// every row; the result is bit-identical to what incremental syncs
    /// had produced. Prefer `DecodeEngine::move_lane` (slab copy, no
    /// decode) when both lanes are reachable.
    pub fn resync_full_into(&mut self, k_lane: &mut [f32], v_lane: &mut [f32]) {
        for cache in &mut self.caches {
            cache.reset_watermark();
        }
        self.sync_into(k_lane, v_lane);
    }

    /// Bit-true packed footprint across layers (K and V).
    pub fn footprint_bits(&self) -> u64 {
        self.caches.iter().map(|c| c.footprint_bits()).sum()
    }

    /// FP16 footprint of the same caches.
    pub fn fp16_footprint_bits(&self) -> u64 {
        self.caches.iter().map(|c| c.fp16_footprint_bits()).sum()
    }
}

/// Lifecycle state of an admitted slot. Queued and Finished are implicit:
/// waiting requests live in the [`Scheduler`] queue, and a finished slot
/// is dropped from its lane the step it completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    /// Consuming prompt tokens (one per step) into the lane's KV.
    Prefilling,
    /// Prompt consumed; sampling one new token per step.
    Decoding,
}

/// An admitted request occupying one batch lane.
pub struct Slot {
    req: GenRequest,
    /// When the request entered the system (enqueue time under the
    /// continuous scheduler; wave start under `serve_wave`).
    arrival: Instant,
    state: SlotState,
    /// next prompt token to feed (while < prompt.len() we are prefilling)
    cursor: usize,
    output: Vec<i32>,
    /// quantized KV state; `None` = baseline mode (FP32 rows written
    /// straight into the slab, no quantizer setup at all)
    kv: Option<SlotKv>,
    /// cache fill (rows appended); tracked directly so baselines don't
    /// need a `KvCache` just for its length counter
    fill: usize,
}

impl Slot {
    pub fn state(&self) -> SlotState {
        self.state
    }

    pub fn request_id(&self) -> u64 {
        self.req.id
    }
}

/// Batched decode engine. `B` (max batch) and `S` (max context) are baked
/// into the artifact; the engine pads unused lanes and owns the persistent
/// `[B, L, S, D]` step slabs (free lanes are always zero).
pub struct DecodeEngine {
    pub spec: LmSpec,
    backend: Box<dyn StepBackend>,
    pub kv_cfg: Option<NxConfig>,
    pub max_batch: usize,
    pub metrics: Metrics,
    /// Per-request latency/TTFT/queue-depth histograms.
    pub serving: ServingMetrics,
    k_f32: Vec<f32>,
    v_f32: Vec<f32>,
}

impl DecodeEngine {
    pub fn new(
        rt: &mut Runtime,
        spec: LmSpec,
        ck: &Checkpoint,
        kv_cfg: Option<NxConfig>,
        max_batch: usize,
    ) -> Result<Self> {
        ck.check_spec(&spec)?;
        let backend = PjrtBackend {
            step_fn: rt.load("decode_step")?,
            params: params_to_literals(ck)?,
            dims: (max_batch, spec.n_layers, spec.seq_len, spec.d_model),
        };
        Ok(Self::with_backend(spec, Box::new(backend), kv_cfg, max_batch))
    }

    /// Engine over an arbitrary step kernel (tests and benches use
    /// [`SynthBackend`]; no PJRT runtime or artifacts needed).
    pub fn with_backend(
        spec: LmSpec,
        backend: Box<dyn StepBackend>,
        kv_cfg: Option<NxConfig>,
        max_batch: usize,
    ) -> Self {
        let n = max_batch * spec.n_layers * spec.seq_len * spec.d_model;
        DecodeEngine {
            spec,
            backend,
            kv_cfg,
            max_batch,
            metrics: Metrics::default(),
            serving: ServingMetrics::default(),
            k_f32: vec![0.0; n],
            v_f32: vec![0.0; n],
        }
    }

    /// Elements in one `[L, S, D]` lane.
    fn lane_len(&self) -> usize {
        self.spec.n_layers * self.spec.seq_len * self.spec.d_model
    }

    /// Shared admission validity check: a prompt must be non-empty and
    /// shorter than the artifact's context length `S` (prefill appends one
    /// KV row per prompt token before the first sample, so a longer prompt
    /// would overrun the cache). Invalid requests complete immediately
    /// with `generated == 0` and never consume a lane. The server front-end
    /// also calls this at enqueue time so a deterministic rejection never
    /// waits in the queue behind real work.
    pub(crate) fn validate(&mut self, req: &GenRequest) -> Option<GenResponse> {
        let s = self.spec.seq_len;
        if !req.prompt.is_empty() && req.prompt.len() < s {
            return None;
        }
        eprintln!(
            "[serve] rejecting request {}: prompt length {} (must be 1..{s})",
            req.id,
            req.prompt.len()
        );
        self.serving.rejected += 1;
        Some(GenResponse {
            id: req.id,
            tokens: req.prompt.clone(),
            generated: 0,
            latency: Duration::ZERO,
        })
    }

    fn make_slot(&self, req: GenRequest, arrival: Instant) -> Slot {
        let (l, s, d) = (self.spec.n_layers, self.spec.seq_len, self.spec.d_model);
        Slot {
            arrival,
            state: SlotState::Prefilling,
            cursor: 0,
            output: req.prompt.clone(),
            kv: self.kv_cfg.as_ref().map(|cfg| SlotKv::new(l, d, s, cfg)),
            fill: 0,
            req,
        }
    }

    /// One batched decode step over every occupied lane: sync quantized KV
    /// incrementally into the slabs, run the backend, append the fresh KV
    /// rows, advance prefill cursors, sample greedily, and retire finished
    /// slots (their lanes are zeroed and freed for the next admission).
    fn step_slots(
        &mut self,
        slots: &mut [Option<Slot>],
        done: &mut Vec<GenResponse>,
    ) -> Result<()> {
        let (l, s, d, vb) =
            (self.spec.n_layers, self.spec.seq_len, self.spec.d_model, self.spec.vocab);
        let bsz = self.max_batch;
        debug_assert_eq!(slots.len(), bsz);
        let lane = self.lane_len();
        let mut tokens = vec![0i32; bsz];
        let mut pos = vec![0i32; bsz];
        for (b, sl) in slots.iter_mut().enumerate() {
            let Some(sl) = sl else { continue };
            tokens[b] = if sl.cursor < sl.req.prompt.len() {
                sl.req.prompt[sl.cursor]
            } else {
                *sl.output.last().unwrap()
            };
            pos[b] = sl.fill as i32;
            if let Some(kv) = &mut sl.kv {
                // incremental on-the-fly dequantize: only rows appended
                // since the previous step decode here, straight into the
                // slot's lane
                kv.sync_into(
                    &mut self.k_f32[b * lane..(b + 1) * lane],
                    &mut self.v_f32[b * lane..(b + 1) * lane],
                );
            }
        }
        let out = self.backend.step(&tokens, &pos, &self.k_f32, &self.v_f32)?;
        self.metrics.decode_steps += 1;

        for (b, slot) in slots.iter_mut().enumerate() {
            let Some(sl) = slot.as_mut() else { continue };
            // append the new KV row (quantized or raw)
            for li in 0..l {
                let row = &out.k_new[(b * l + li) * d..(b * l + li + 1) * d];
                let vow = &out.v_new[(b * l + li) * d..(b * l + li + 1) * d];
                if let Some(kv) = &mut sl.kv {
                    kv.append(li, row, vow);
                } else {
                    let base = ((b * l + li) * s + sl.fill) * d;
                    self.k_f32[base..base + d].copy_from_slice(row);
                    self.v_f32[base..base + d].copy_from_slice(vow);
                }
            }
            sl.fill += 1;
            if sl.cursor < sl.req.prompt.len() {
                sl.cursor += 1; // still consuming the prompt
                if sl.cursor < sl.req.prompt.len() {
                    continue;
                }
                sl.state = SlotState::Decoding; // last prompt token: sample
            }
            // sample greedily from this slot's logits
            let row = &out.logits[b * vb..(b + 1) * vb];
            let next = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0 as i32;
            sl.output.push(next);
            self.metrics.tokens_generated += 1;
            if sl.output.len() == sl.req.prompt.len() + 1 {
                self.serving.ttft.record(sl.arrival.elapsed().as_secs_f64());
            }
            let generated = sl.output.len() - sl.req.prompt.len();
            let finished = generated >= sl.req.max_new || sl.fill + 1 >= s;
            if finished {
                // slot lifecycle: account the final footprint, release the
                // packed buffers, zero the lane exactly once, free the lane
                let sl = slot.take().unwrap();
                if let Some(kv) = sl.kv {
                    self.metrics.kv_bits_packed += kv.footprint_bits();
                    self.metrics.kv_bits_fp16 += kv.fp16_footprint_bits();
                }
                self.k_f32[b * lane..(b + 1) * lane].fill(0.0);
                self.v_f32[b * lane..(b + 1) * lane].fill(0.0);
                let latency = sl.arrival.elapsed();
                self.serving.latency.record(latency.as_secs_f64());
                done.push(GenResponse { id: sl.req.id, generated, tokens: sl.output, latency });
                self.metrics.requests += 1;
            }
        }
        Ok(())
    }

    /// Serve a wave of up to `max_batch` requests to completion (the
    /// legacy scheduling mode: every lane is held until the whole wave
    /// drains). Invalid requests are rejected individually — they complete
    /// immediately with `generated == 0` and do not abort the wave.
    pub fn serve_wave(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        assert!(reqs.len() <= self.max_batch);
        let wave_start = Instant::now();
        let mut responses = Vec::new();
        let mut slots: Vec<Option<Slot>> = Vec::with_capacity(self.max_batch);
        for req in reqs {
            match self.validate(&req) {
                Some(resp) => responses.push(resp),
                None => {
                    self.serving.admitted += 1;
                    slots.push(Some(self.make_slot(req, Instant::now())));
                }
            }
        }
        slots.resize_with(self.max_batch, || None);
        while slots.iter().any(Option::is_some) {
            self.step_slots(&mut slots, &mut responses)?;
        }
        self.metrics.wall += wave_start.elapsed();
        Ok(responses)
    }

    /// Fill free lanes from the scheduler queue. Validation rejections
    /// complete immediately into `done` without consuming a lane.
    fn admit(&mut self, sched: &mut Scheduler, done: &mut Vec<GenResponse>) {
        while let Some(b) = sched.free_lane() {
            let Some(adm) = sched.pop_next() else { break };
            if let Some(resp) = self.validate(&adm.req) {
                done.push(resp);
                continue;
            }
            self.serving.admitted += 1;
            if adm.promoted {
                self.serving.promoted += 1;
            }
            self.serving.wait_steps.record(adm.waited_steps as f64);
            let slot = self.make_slot(adm.req, adm.arrival);
            sched.place(b, slot);
        }
    }

    /// One continuous-batching iteration: admit queued requests into free
    /// lanes, run one batched decode step across all occupied lanes, and
    /// advance the scheduler's promotion clock. Returns the requests that
    /// completed this step. The server worker calls this in its loop, so
    /// newly arrived requests join between steps — no wave barrier.
    pub fn step_continuous(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        let t0 = Instant::now();
        let mut done = Vec::new();
        self.admit(sched, &mut done);
        if sched.active() > 0 {
            self.step_slots(sched.slots_mut(), &mut done)?;
        }
        let depth = sched.tick();
        self.serving.queue_depth.record(depth as f64);
        self.metrics.wall += t0.elapsed();
        Ok(done)
    }

    /// Drive the continuous scheduler until the queue and all lanes drain.
    pub fn serve_continuous(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        while sched.has_work() {
            out.extend(self.step_continuous(sched)?);
        }
        Ok(out)
    }

    /// Move the slot in lane `from` to the free lane `to` with a
    /// lane-to-lane slab copy: O(L·S·D) `memcpy`, **no packed re-decode**
    /// — the `SlotKv` watermarks stay valid because the new lane is
    /// bit-identical to the old. (The fallback when the source lane is
    /// unavailable is [`SlotKv::resync_full_into`].) The vacated lane is
    /// zeroed, preserving the free-lanes-are-zero invariant.
    pub fn move_lane(&mut self, slots: &mut [Option<Slot>], from: usize, to: usize) {
        assert!(from != to, "move_lane: from == to");
        assert!(slots[to].is_none(), "move_lane: target lane {to} occupied");
        let slot = slots[from].take().expect("move_lane: source lane empty");
        let lane = self.lane_len();
        self.k_f32.copy_within(from * lane..(from + 1) * lane, to * lane);
        self.v_f32.copy_within(from * lane..(from + 1) * lane, to * lane);
        self.k_f32[from * lane..(from + 1) * lane].fill(0.0);
        self.v_f32[from * lane..(from + 1) * lane].fill(0.0);
        slots[to] = Some(slot);
    }

    /// Read-only view of one lane of the step slabs (tests).
    pub fn lane(&self, b: usize) -> (&[f32], &[f32]) {
        let lane = self.lane_len();
        (&self.k_f32[b * lane..(b + 1) * lane], &self.v_f32[b * lane..(b + 1) * lane])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The incremental sync must leave the lane bit-identical to a full
    /// re-decode of every layer at every step — the exact invariant the
    /// old `serve_wave` paid O(fill) per step to maintain.
    #[test]
    fn slot_kv_sync_matches_full_redecode() {
        let (l, s, d) = (3usize, 16usize, 40usize);
        let mut rng = Rng::seeded(81);
        let cfg = NxConfig::nxfp(4);
        let mut kv = SlotKv::new(l, d, s, &cfg);
        let mut k_lane = vec![0.0f32; l * s * d];
        let mut v_lane = vec![0.0f32; l * s * d];
        for step in 0..10 {
            for li in 0..l {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(li, &k, &v);
            }
            kv.sync_into(&mut k_lane, &mut v_lane);
            assert_eq!(kv.fill(), step + 1);
            for (li, cache) in kv.caches.iter().enumerate() {
                let (k_full, v_full) = cache.dequantize(s);
                assert_eq!(&k_lane[li * s * d..(li + 1) * s * d], &k_full.data[..]);
                assert_eq!(&v_lane[li * s * d..(li + 1) * s * d], &v_full.data[..]);
            }
        }
    }

    #[test]
    fn resync_full_reproduces_lane_after_move() {
        // lane-reassignment fallback: the packed streams alone must
        // rebuild the decoded prefix bit-identically in a fresh lane
        let (l, s, d) = (2usize, 8usize, 32usize);
        let mut rng = Rng::seeded(82);
        let mut kv = SlotKv::new(l, d, s, &NxConfig::nxfp(5));
        let mut lane_k = vec![0.0f32; l * s * d];
        let mut lane_v = vec![0.0f32; l * s * d];
        for _ in 0..5 {
            for li in 0..l {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(li, &k, &k);
            }
            kv.sync_into(&mut lane_k, &mut lane_v);
        }
        let mut moved_k = vec![0.0f32; l * s * d];
        let mut moved_v = vec![0.0f32; l * s * d];
        kv.resync_full_into(&mut moved_k, &mut moved_v);
        assert_eq!(moved_k, lane_k);
        assert_eq!(moved_v, lane_v);
    }

    #[test]
    fn lane_copy_then_incremental_sync_stays_bit_identical() {
        // slot churn: move a live slot to another lane via slab copy, keep
        // appending, and compare against a never-moved control slot
        let (l, s, d) = (2usize, 12usize, 24usize);
        let mut rng = Rng::seeded(83);
        let cfg = NxConfig::nxfp(4);
        let mut kv = SlotKv::new(l, d, s, &cfg);
        let mut ctl = SlotKv::new(l, d, s, &cfg);
        let lane = l * s * d;
        // two-lane slab: slot starts in lane 0
        let mut k_slab = vec![0.0f32; 2 * lane];
        let mut v_slab = vec![0.0f32; 2 * lane];
        let mut k_ctl = vec![0.0f32; lane];
        let mut v_ctl = vec![0.0f32; lane];
        let mut rows = Vec::new();
        for _ in 0..4 {
            let r: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            rows.push(r);
        }
        for step in 0..8 {
            let r = &rows[step % rows.len()];
            for li in 0..l {
                kv.append(li, r, r);
                ctl.append(li, r, r);
            }
            let lo = if step < 4 { 0 } else { lane };
            kv.sync_into(&mut k_slab[lo..lo + lane], &mut v_slab[lo..lo + lane]);
            ctl.sync_into(&mut k_ctl, &mut v_ctl);
            if step == 3 {
                // reassign lane 0 -> lane 1 with a slab copy (watermark
                // untouched: the new lane is bit-identical)
                k_slab.copy_within(0..lane, lane);
                v_slab.copy_within(0..lane, lane);
                k_slab[..lane].fill(0.0);
                v_slab[..lane].fill(0.0);
            }
        }
        assert_eq!(&k_slab[lane..], &k_ctl[..]);
        assert_eq!(&v_slab[lane..], &v_ctl[..]);
        // the vacated lane stayed zero for the next occupant
        assert!(k_slab[..lane].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slot_kv_footprints_sum_layers() {
        let (l, s, d) = (2usize, 8usize, 64usize);
        let mut kv = SlotKv::new(l, d, s, &NxConfig::nxfp(4));
        let row = vec![0.25f32; d];
        for li in 0..l {
            kv.append(li, &row, &row);
        }
        assert_eq!(kv.fill(), 1);
        let one_layer = kv.caches[0].footprint_bits();
        assert_eq!(kv.footprint_bits(), l as u64 * one_layer);
        assert!(kv.fp16_footprint_bits() > kv.footprint_bits());
    }

    #[test]
    fn metrics_savings_uses_completion_totals() {
        let m = Metrics { kv_bits_packed: 25, kv_bits_fp16: 100, ..Metrics::default() };
        assert!((m.kv_savings() - 0.75).abs() < 1e-12);
        // empty metrics: no division by zero
        assert!(Metrics::default().kv_savings() <= 1.0);
    }

    #[test]
    fn synth_backend_is_deterministic_and_per_slot_pure() {
        let spec = LmSpec::tiny();
        let mut be = SynthBackend::new(&spec);
        let lane = spec.n_layers * spec.seq_len * spec.d_model;
        let mut rng = Rng::seeded(84);
        let mut k = vec![0.0f32; 2 * lane];
        let mut v = vec![0.0f32; 2 * lane];
        for x in k.iter_mut().chain(v.iter_mut()) {
            *x = rng.normal_f32(0.0, 1.0);
        }
        let a = be.step(&[3, 9], &[2, 5], &k, &v).unwrap();
        let b = be.step(&[3, 9], &[2, 5], &k, &v).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.k_new, b.k_new);
        // swap the lanes (and the token/pos pairing): per-slot outputs
        // must swap with them — nothing crosses lanes
        let mut ks = v.clone();
        let mut vs = k.clone();
        ks[..lane].copy_from_slice(&k[lane..]);
        ks[lane..].copy_from_slice(&k[..lane]);
        vs[..lane].copy_from_slice(&v[lane..]);
        vs[lane..].copy_from_slice(&v[..lane]);
        let c = be.step(&[9, 3], &[5, 2], &ks, &vs).unwrap();
        let vb = spec.vocab;
        assert_eq!(&c.logits[..vb], &a.logits[vb..]);
        assert_eq!(&c.logits[vb..], &a.logits[..vb]);
    }

    #[test]
    fn wave_engine_runs_on_synth_backend() {
        let spec = LmSpec::tiny();
        let backend = Box::new(SynthBackend::new(&spec));
        let mut engine =
            DecodeEngine::with_backend(spec.clone(), backend, Some(NxConfig::nxfp(4)), 2);
        let reqs = vec![
            GenRequest { id: 0, prompt: vec![1, 2, 3], max_new: 4 },
            GenRequest { id: 1, prompt: vec![5], max_new: 2 },
            GenRequest { id: 2, prompt: vec![], max_new: 2 }, // rejected
        ];
        // 3 reqs > max_batch 2 would assert; split waves
        let mut resps = engine.serve_wave(reqs[..2].to_vec()).unwrap();
        resps.extend(engine.serve_wave(reqs[2..].to_vec()).unwrap());
        assert_eq!(resps.len(), 3);
        let by_id = |id: u64| resps.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).generated, 4);
        assert_eq!(by_id(1).generated, 2);
        assert_eq!(by_id(2).generated, 0);
        assert_eq!(engine.metrics.requests, 2);
        assert_eq!(engine.serving.rejected, 1);
        assert!(engine.metrics.kv_savings() > 0.5);
        // free lanes are zero after the waves drained
        let (k0, v0) = engine.lane(0);
        assert!(k0.iter().chain(v0).all(|&x| x == 0.0));
    }
}
