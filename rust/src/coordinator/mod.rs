//! Serving coordinator: batched greedy decoding through the `decode_step`
//! artifact with the KV cache held in **quantized packed form** between
//! steps (paper §6 on-the-fly dequantization deployment).
//!
//! `decode_step` contract (pinned against `python/compile/aot.py`):
//! inputs `P` params, `tokens [B]` (i32, current token per slot),
//! `pos [B]` (i32, cache fill per slot), `k_cache [B, L, S, D]`,
//! `v_cache [B, L, S, D]` (f32); outputs `logits [B, V]`,
//! `k_new [B, L, D]`, `v_new [B, L, D]`.

pub mod server;

use anyhow::Result;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::formats::NxConfig;
use crate::models::{Checkpoint, LmSpec};
use crate::quant::kv_cache::KvCache;
use crate::runtime::{lit, Runtime, Step};
use crate::train::params_to_literals;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// prompt + generated tokens
    pub tokens: Vec<i32>,
    pub generated: usize,
    pub latency: Duration,
}

/// Aggregate serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub wall: Duration,
    /// packed KV bits at peak vs what FP16 would have used
    pub kv_bits_peak: u64,
    pub kv_bits_fp16: u64,
}

impl Metrics {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn kv_savings(&self) -> f64 {
        1.0 - self.kv_bits_peak as f64 / self.kv_bits_fp16.max(1) as f64
    }
}

struct Slot {
    req: GenRequest,
    started: Instant,
    /// next prompt token to feed (while < prompt.len() we are prefilling)
    cursor: usize,
    output: Vec<i32>,
    /// per-layer quantized KV (None = slot holds FP32 cache for baselines)
    caches: Vec<KvCache>,
    done: bool,
}

/// Batched decode engine. `B` (max batch) and `S` (max context) are baked
/// into the artifact; the engine pads unused slots.
pub struct DecodeEngine {
    pub spec: LmSpec,
    step_fn: Rc<Step>,
    params: Vec<xla::Literal>,
    pub kv_cfg: Option<NxConfig>,
    pub max_batch: usize,
    pub metrics: Metrics,
}

impl DecodeEngine {
    pub fn new(
        rt: &mut Runtime,
        spec: LmSpec,
        ck: &Checkpoint,
        kv_cfg: Option<NxConfig>,
        max_batch: usize,
    ) -> Result<Self> {
        ck.check_spec(&spec)?;
        let step_fn = rt.load("decode_step")?;
        Ok(DecodeEngine {
            spec,
            step_fn,
            params: params_to_literals(ck)?,
            kv_cfg,
            max_batch,
            metrics: Metrics::default(),
        })
    }

    /// Serve a wave of up to `max_batch` requests to completion.
    pub fn serve_wave(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        assert!(reqs.len() <= self.max_batch);
        let (bsz, l, s, d, v) = (
            self.max_batch,
            self.spec.n_layers,
            self.spec.seq_len,
            self.spec.d_model,
            self.spec.vocab,
        );
        let wave_start = Instant::now();
        let kv_cfg = self.kv_cfg.clone().unwrap_or_else(|| NxConfig::mxfp(8));
        let quantize_kv = self.kv_cfg.is_some();
        let mut slots: Vec<Option<Slot>> = reqs
            .into_iter()
            .map(|req| {
                Some(Slot {
                    started: Instant::now(),
                    cursor: 0,
                    output: req.prompt.clone(),
                    caches: (0..l).map(|_| KvCache::new(d, kv_cfg.clone())).collect(),
                    req,
                    done: false,
                })
            })
            .collect();
        slots.resize_with(bsz, || None);
        // FP32 fallback caches (baseline mode, no quantization)
        let mut k_f32 = vec![0.0f32; bsz * l * s * d];
        let mut v_f32 = vec![0.0f32; bsz * l * s * d];
        let mut responses = Vec::new();

        while slots.iter().flatten().any(|sl| !sl.done) {
            // assemble step inputs
            let mut tokens = vec![0i32; bsz];
            let mut pos = vec![0i32; bsz];
            for (b, sl) in slots.iter().enumerate() {
                if let Some(sl) = sl {
                    if sl.done {
                        continue;
                    }
                    tokens[b] = if sl.cursor < sl.req.prompt.len() {
                        sl.req.prompt[sl.cursor]
                    } else {
                        *sl.output.last().unwrap()
                    };
                    pos[b] = sl.caches[0].len as i32;
                }
            }
            if quantize_kv {
                // on-the-fly dequantize packed caches into the step tensors
                for (b, sl) in slots.iter().enumerate() {
                    let Some(sl) = sl else { continue };
                    for (li, cache) in sl.caches.iter().enumerate() {
                        let (kd, vd) = cache.dequantize(s);
                        let base = (b * l + li) * s * d;
                        k_f32[base..base + s * d].copy_from_slice(&kd.data);
                        v_f32[base..base + s * d].copy_from_slice(&vd.data);
                    }
                }
            }
            let tok_lit = lit::from_i32(&tokens, &[bsz as i64])?;
            let pos_lit = lit::from_i32(&pos, &[bsz as i64])?;
            let k_lit = lit::from_f32(&k_f32, &[bsz as i64, l as i64, s as i64, d as i64])?;
            let v_lit = lit::from_f32(&v_f32, &[bsz as i64, l as i64, s as i64, d as i64])?;
            let mut args: Vec<&xla::Literal> = self.params.iter().collect();
            args.extend([&tok_lit, &pos_lit, &k_lit, &v_lit]);
            let out = self.step_fn.run(&args)?;
            anyhow::ensure!(out.len() == 3, "decode_step returned {} outputs", out.len());
            let logits = lit::to_f32(&out[0])?;
            let k_new = lit::to_f32(&out[1])?;
            let v_new = lit::to_f32(&out[2])?;
            self.metrics.decode_steps += 1;

            for (b, sl) in slots.iter_mut().enumerate() {
                let Some(sl) = sl else { continue };
                if sl.done {
                    continue;
                }
                // append the new KV row (quantized or raw)
                for li in 0..l {
                    let row = &k_new[(b * l + li) * d..(b * l + li + 1) * d];
                    let vow = &v_new[(b * l + li) * d..(b * l + li + 1) * d];
                    if quantize_kv {
                        sl.caches[li].append(row, vow);
                    } else {
                        let p = pos[b] as usize;
                        let base = ((b * l + li) * s + p) * d;
                        k_f32[base..base + d].copy_from_slice(row);
                        v_f32[base..base + d].copy_from_slice(vow);
                        sl.caches[li].len += 1; // track fill without storing
                    }
                }
                if sl.cursor < sl.req.prompt.len() {
                    sl.cursor += 1; // still consuming the prompt
                    if sl.cursor < sl.req.prompt.len() {
                        continue;
                    }
                }
                // sample greedily from this slot's logits
                let row = &logits[b * v..(b + 1) * v];
                let next = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                sl.output.push(next);
                self.metrics.tokens_generated += 1;
                let generated = sl.output.len() - sl.req.prompt.len();
                let ctx_full = sl.caches[0].len + 1 >= s;
                if generated >= sl.req.max_new || ctx_full {
                    sl.done = true;
                    if quantize_kv {
                        let bits: u64 = sl.caches.iter().map(|c| c.footprint_bits()).sum();
                        let fp16: u64 =
                            sl.caches.iter().map(|c| c.fp16_footprint_bits()).sum();
                        self.metrics.kv_bits_peak += bits;
                        self.metrics.kv_bits_fp16 += fp16;
                    }
                    responses.push(GenResponse {
                        id: sl.req.id,
                        tokens: sl.output.clone(),
                        generated,
                        latency: sl.started.elapsed(),
                    });
                    self.metrics.requests += 1;
                }
            }
        }
        self.metrics.wall += wave_start.elapsed();
        Ok(responses)
    }
}
