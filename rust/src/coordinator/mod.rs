//! Serving coordinator: batched greedy decoding through the `decode_step`
//! artifact with the KV cache held in **quantized packed form** between
//! steps (paper §6 on-the-fly dequantization deployment).
//!
//! `decode_step` contract (pinned against `python/compile/aot.py`):
//! inputs `P` params, `tokens [B]` (i32, current token per slot),
//! `pos [B]` (i32, cache fill per slot), `k_cache [B, L, S, D]`,
//! `v_cache [B, L, S, D]` (f32); outputs `logits [B, V]`,
//! `k_new [B, L, D]`, `v_new [B, L, D]`.
//!
//! # Decode hot path
//!
//! The batched step tensors (`k_f32`/`v_f32` slabs) persist across the
//! steps of a wave, and each slot's packed caches carry a dirty-row
//! watermark (see [`crate::quant::kv_cache`]), so a decode step dequantizes
//! only the rows appended since the previous step — O(new rows), not
//! O(total fill). Finished slots release their packed and staging buffers
//! immediately, are skipped by the assembly loop, and have their slab lanes
//! zeroed exactly once.

pub mod server;

use anyhow::Result;
use std::rc::Rc;
use std::time::{Duration, Instant};

use crate::formats::NxConfig;
use crate::models::{Checkpoint, LmSpec};
use crate::quant::kv_cache::KvCache;
use crate::runtime::{lit, Runtime, Step};
use crate::tensor::Tensor2;
use crate::train::params_to_literals;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// prompt + generated tokens
    pub tokens: Vec<i32>,
    pub generated: usize,
    pub latency: Duration,
}

/// Aggregate serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub wall: Duration,
    /// Packed KV bits summed at request **completion**: each finished
    /// request contributes its final cache footprint once. A completion-
    /// time total, not a live peak (formerly misnamed `kv_bits_peak`).
    pub kv_bits_packed: u64,
    /// FP16 bits the same completed caches would have occupied.
    pub kv_bits_fp16: u64,
}

impl Metrics {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn kv_savings(&self) -> f64 {
        1.0 - self.kv_bits_packed as f64 / self.kv_bits_fp16.max(1) as f64
    }
}

/// Per-slot quantized KV state: one packed [`KvCache`] per layer plus a
/// persistent f32 staging mirror of the decoded prefix.
///
/// [`SlotKv::sync_into`] decodes only the rows appended since the previous
/// call (the caches' dirty-row watermark) and copies exactly those rows
/// into the slot's lane of the batched step tensors, so per-step decode
/// work is O(new rows) instead of O(total fill). The staging mirror holds
/// the full decoded prefix, so [`SlotKv::resync_full_into`] can move a
/// slot to a *different* lane without re-decoding — the enabler for
/// continuous batching. Dropping a `SlotKv` releases both the packed
/// blocks and the staging buffers (finished slots free immediately).
///
/// Trade-off: the mirror is a second f32 copy of the decoded prefix on
/// top of the slot's slab lane, bought for lane mobility. If that memory
/// ever dominates (big `L·S·D`), the alternative is decoding straight
/// into the lane and moving slots lane-to-lane with a slab copy — see
/// ROADMAP "Open items".
pub struct SlotKv {
    caches: Vec<KvCache>,
    stage_k: Vec<Tensor2>,
    stage_v: Vec<Tensor2>,
}

impl SlotKv {
    /// `n_layers` caches of feature dim `dim`, staged to `pad_len` rows
    /// (the artifact's fixed context length `S`). Each cache pre-reserves
    /// the full window so decode-step appends never reallocate.
    pub fn new(n_layers: usize, dim: usize, pad_len: usize, cfg: &NxConfig) -> Self {
        SlotKv {
            caches: (0..n_layers)
                .map(|_| KvCache::with_capacity(dim, cfg.clone(), pad_len))
                .collect(),
            stage_k: (0..n_layers).map(|_| Tensor2::zeros(pad_len, dim)).collect(),
            stage_v: (0..n_layers).map(|_| Tensor2::zeros(pad_len, dim)).collect(),
        }
    }

    /// Rows appended so far (cache fill; identical across layers).
    pub fn fill(&self) -> usize {
        self.caches[0].len
    }

    /// Quantize and append one generated (k, v) row for `layer`.
    pub fn append(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        self.caches[layer].append(k_row, v_row);
    }

    /// Incrementally decode rows appended since the previous call and copy
    /// them into this slot's `[L, S, D]` lanes of the batched step
    /// tensors. The lanes must persist across steps (the coordinator
    /// reuses the same slab for a whole wave).
    pub fn sync_into(&mut self, k_lane: &mut [f32], v_lane: &mut [f32]) {
        let (s, d) = (self.stage_k[0].rows, self.stage_k[0].cols);
        debug_assert_eq!(k_lane.len(), self.caches.len() * s * d);
        debug_assert_eq!(v_lane.len(), k_lane.len());
        for (li, cache) in self.caches.iter_mut().enumerate() {
            let new = cache.dequantize_into(&mut self.stage_k[li], &mut self.stage_v[li]);
            let base = li * s * d;
            for r in new {
                let dst = base + r * d;
                k_lane[dst..dst + d].copy_from_slice(self.stage_k[li].row(r));
                v_lane[dst..dst + d].copy_from_slice(self.stage_v[li].row(r));
            }
        }
    }

    /// Re-sync the **entire** decoded prefix (rows `0..fill`) into a lane
    /// from the staging mirror, without touching the packed streams — the
    /// continuous-batching entry point for moving a slot to a different
    /// batch lane. Rows past the watermark must be pulled separately with
    /// [`SlotKv::sync_into`].
    pub fn resync_full_into(&self, k_lane: &mut [f32], v_lane: &mut [f32]) {
        let (s, d) = (self.stage_k[0].rows, self.stage_k[0].cols);
        debug_assert_eq!(k_lane.len(), self.caches.len() * s * d);
        for (li, cache) in self.caches.iter().enumerate() {
            let base = li * s * d;
            for r in 0..cache.watermark() {
                let dst = base + r * d;
                k_lane[dst..dst + d].copy_from_slice(self.stage_k[li].row(r));
                v_lane[dst..dst + d].copy_from_slice(self.stage_v[li].row(r));
            }
        }
    }

    /// Bit-true packed footprint across layers (K and V).
    pub fn footprint_bits(&self) -> u64 {
        self.caches.iter().map(|c| c.footprint_bits()).sum()
    }

    /// FP16 footprint of the same caches.
    pub fn fp16_footprint_bits(&self) -> u64 {
        self.caches.iter().map(|c| c.fp16_footprint_bits()).sum()
    }
}

struct Slot {
    req: GenRequest,
    started: Instant,
    /// next prompt token to feed (while < prompt.len() we are prefilling)
    cursor: usize,
    output: Vec<i32>,
    /// quantized KV state; `None` = baseline mode (FP32 rows written
    /// straight into the slab, no quantizer setup at all)
    kv: Option<SlotKv>,
    /// cache fill (rows appended); tracked directly so baselines don't
    /// need a `KvCache` just for its length counter
    fill: usize,
    done: bool,
}

/// Batched decode engine. `B` (max batch) and `S` (max context) are baked
/// into the artifact; the engine pads unused slots.
pub struct DecodeEngine {
    pub spec: LmSpec,
    step_fn: Rc<Step>,
    params: Vec<xla::Literal>,
    pub kv_cfg: Option<NxConfig>,
    pub max_batch: usize,
    pub metrics: Metrics,
}

impl DecodeEngine {
    pub fn new(
        rt: &mut Runtime,
        spec: LmSpec,
        ck: &Checkpoint,
        kv_cfg: Option<NxConfig>,
        max_batch: usize,
    ) -> Result<Self> {
        ck.check_spec(&spec)?;
        let step_fn = rt.load("decode_step")?;
        Ok(DecodeEngine {
            spec,
            step_fn,
            params: params_to_literals(ck)?,
            kv_cfg,
            max_batch,
            metrics: Metrics::default(),
        })
    }

    /// Serve a wave of up to `max_batch` requests to completion. A prompt
    /// must be non-empty and shorter than the artifact's context length
    /// `S` (prefill appends one KV row per prompt token before the first
    /// sample, so a longer prompt would overrun the cache); invalid
    /// requests are rejected individually — they complete immediately with
    /// `generated == 0` and do not abort the rest of the wave.
    pub fn serve_wave(&mut self, reqs: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        assert!(reqs.len() <= self.max_batch);
        let (bsz, l, s, d, v) = (
            self.max_batch,
            self.spec.n_layers,
            self.spec.seq_len,
            self.spec.d_model,
            self.spec.vocab,
        );
        let wave_start = Instant::now();
        let mut responses = Vec::new();
        let reqs: Vec<GenRequest> = reqs
            .into_iter()
            .filter(|req| {
                let ok = !req.prompt.is_empty() && req.prompt.len() < s;
                if !ok {
                    eprintln!(
                        "[serve] rejecting request {}: prompt length {} \
                         (must be 1..{s})",
                        req.id,
                        req.prompt.len()
                    );
                    responses.push(GenResponse {
                        id: req.id,
                        tokens: req.prompt.clone(),
                        generated: 0,
                        latency: Duration::ZERO,
                    });
                }
                ok
            })
            .collect();
        let kv_cfg = self.kv_cfg.clone();
        let lane = l * s * d;
        let mut slots: Vec<Option<Slot>> = reqs
            .into_iter()
            .map(|req| {
                Some(Slot {
                    started: Instant::now(),
                    cursor: 0,
                    output: req.prompt.clone(),
                    kv: kv_cfg.as_ref().map(|cfg| SlotKv::new(l, d, s, cfg)),
                    fill: 0,
                    req,
                    done: false,
                })
            })
            .collect();
        slots.resize_with(bsz, || None);
        // Batched step tensors; persist across the wave's steps so active
        // slots only ever write new rows into them.
        let mut k_f32 = vec![0.0f32; bsz * lane];
        let mut v_f32 = vec![0.0f32; bsz * lane];

        while slots.iter().flatten().any(|sl| !sl.done) {
            // assemble step inputs: finished slots are skipped entirely
            // (their lanes were zeroed once at completion)
            let mut tokens = vec![0i32; bsz];
            let mut pos = vec![0i32; bsz];
            for (b, sl) in slots.iter_mut().enumerate() {
                let Some(sl) = sl else { continue };
                if sl.done {
                    continue;
                }
                tokens[b] = if sl.cursor < sl.req.prompt.len() {
                    sl.req.prompt[sl.cursor]
                } else {
                    *sl.output.last().unwrap()
                };
                pos[b] = sl.fill as i32;
                if let Some(kv) = &mut sl.kv {
                    // incremental on-the-fly dequantize: only rows appended
                    // since the previous step decode here
                    kv.sync_into(
                        &mut k_f32[b * lane..(b + 1) * lane],
                        &mut v_f32[b * lane..(b + 1) * lane],
                    );
                }
            }
            let tok_lit = lit::from_i32(&tokens, &[bsz as i64])?;
            let pos_lit = lit::from_i32(&pos, &[bsz as i64])?;
            let k_lit = lit::from_f32(&k_f32, &[bsz as i64, l as i64, s as i64, d as i64])?;
            let v_lit = lit::from_f32(&v_f32, &[bsz as i64, l as i64, s as i64, d as i64])?;
            let mut args: Vec<&xla::Literal> = self.params.iter().collect();
            args.extend([&tok_lit, &pos_lit, &k_lit, &v_lit]);
            let out = self.step_fn.run(&args)?;
            anyhow::ensure!(out.len() == 3, "decode_step returned {} outputs", out.len());
            let logits = lit::to_f32(&out[0])?;
            let k_new = lit::to_f32(&out[1])?;
            let v_new = lit::to_f32(&out[2])?;
            self.metrics.decode_steps += 1;

            for (b, sl) in slots.iter_mut().enumerate() {
                let Some(sl) = sl else { continue };
                if sl.done {
                    continue;
                }
                // append the new KV row (quantized or raw)
                for li in 0..l {
                    let row = &k_new[(b * l + li) * d..(b * l + li + 1) * d];
                    let vow = &v_new[(b * l + li) * d..(b * l + li + 1) * d];
                    if let Some(kv) = &mut sl.kv {
                        kv.append(li, row, vow);
                    } else {
                        let base = ((b * l + li) * s + sl.fill) * d;
                        k_f32[base..base + d].copy_from_slice(row);
                        v_f32[base..base + d].copy_from_slice(vow);
                    }
                }
                sl.fill += 1;
                if sl.cursor < sl.req.prompt.len() {
                    sl.cursor += 1; // still consuming the prompt
                    if sl.cursor < sl.req.prompt.len() {
                        continue;
                    }
                }
                // sample greedily from this slot's logits
                let row = &logits[b * v..(b + 1) * v];
                let next = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                sl.output.push(next);
                self.metrics.tokens_generated += 1;
                let generated = sl.output.len() - sl.req.prompt.len();
                let ctx_full = sl.fill + 1 >= s;
                if generated >= sl.req.max_new || ctx_full {
                    sl.done = true;
                    // slot lifecycle: account the final footprint, release
                    // packed + staging buffers, zero the lanes exactly once
                    if let Some(kv) = sl.kv.take() {
                        self.metrics.kv_bits_packed += kv.footprint_bits();
                        self.metrics.kv_bits_fp16 += kv.fp16_footprint_bits();
                    }
                    k_f32[b * lane..(b + 1) * lane].fill(0.0);
                    v_f32[b * lane..(b + 1) * lane].fill(0.0);
                    responses.push(GenResponse {
                        id: sl.req.id,
                        tokens: sl.output.clone(),
                        generated,
                        latency: sl.started.elapsed(),
                    });
                    self.metrics.requests += 1;
                }
            }
        }
        self.metrics.wall += wave_start.elapsed();
        Ok(responses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The incremental sync must leave the lane bit-identical to a full
    /// re-decode of every layer at every step — the exact invariant the
    /// old `serve_wave` paid O(fill) per step to maintain.
    #[test]
    fn slot_kv_sync_matches_full_redecode() {
        let (l, s, d) = (3usize, 16usize, 40usize);
        let mut rng = Rng::seeded(81);
        let cfg = NxConfig::nxfp(4);
        let mut kv = SlotKv::new(l, d, s, &cfg);
        let mut k_lane = vec![0.0f32; l * s * d];
        let mut v_lane = vec![0.0f32; l * s * d];
        for step in 0..10 {
            for li in 0..l {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(li, &k, &v);
            }
            kv.sync_into(&mut k_lane, &mut v_lane);
            assert_eq!(kv.fill(), step + 1);
            for (li, cache) in kv.caches.iter().enumerate() {
                let (k_full, v_full) = cache.dequantize(s);
                assert_eq!(&k_lane[li * s * d..(li + 1) * s * d], &k_full.data[..]);
                assert_eq!(&v_lane[li * s * d..(li + 1) * s * d], &v_full.data[..]);
            }
        }
    }

    #[test]
    fn resync_full_reproduces_lane_after_move() {
        // simulate a continuous-batching lane move: decoded prefix must
        // land in the new lane without touching the packed streams
        let (l, s, d) = (2usize, 8usize, 32usize);
        let mut rng = Rng::seeded(82);
        let mut kv = SlotKv::new(l, d, s, &NxConfig::nxfp(5));
        let mut lane_k = vec![0.0f32; l * s * d];
        let mut lane_v = vec![0.0f32; l * s * d];
        for _ in 0..5 {
            for li in 0..l {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(li, &k, &k);
            }
            kv.sync_into(&mut lane_k, &mut lane_v);
        }
        let mut moved_k = vec![0.0f32; l * s * d];
        let mut moved_v = vec![0.0f32; l * s * d];
        kv.resync_full_into(&mut moved_k, &mut moved_v);
        assert_eq!(moved_k, lane_k);
        assert_eq!(moved_v, lane_v);
    }

    #[test]
    fn slot_kv_footprints_sum_layers() {
        let (l, s, d) = (2usize, 8usize, 64usize);
        let mut kv = SlotKv::new(l, d, s, &NxConfig::nxfp(4));
        let row = vec![0.25f32; d];
        for li in 0..l {
            kv.append(li, &row, &row);
        }
        assert_eq!(kv.fill(), 1);
        let one_layer = kv.caches[0].footprint_bits();
        assert_eq!(kv.footprint_bits(), l as u64 * one_layer);
        assert!(kv.fp16_footprint_bits() > kv.footprint_bits());
    }

    #[test]
    fn metrics_savings_uses_completion_totals() {
        let m = Metrics { kv_bits_packed: 25, kv_bits_fp16: 100, ..Metrics::default() };
        assert!((m.kv_savings() - 0.75).abs() < 1e-12);
        // empty metrics: no division by zero
        assert!(Metrics::default().kv_savings() <= 1.0);
    }
}
