//! Serving coordinator: batched greedy decoding through the `decode_step`
//! artifact with the KV cache held in **quantized packed form** between
//! steps (paper §6 on-the-fly dequantization deployment).
//!
//! `decode_step` contract (pinned against `python/compile/aot.py`):
//! inputs `P` params, `tokens [B]` (i32, current token per slot),
//! `pos [B]` (i32, cache fill per slot), `k_cache [B, L, S, D]`,
//! `v_cache [B, L, S, D]` (f32); outputs `logits [B, V]`,
//! `k_new [B, L, D]`, `v_new [B, L, D]`.
//!
//! See `ARCHITECTURE.md` in this directory for the full lane/slot/queue
//! vocabulary and the wave-vs-continuous design discussion.
//!
//! # Structure
//!
//! * [`StepBackend`] — the batched step kernel behind the engine: the PJRT
//!   artifact in production, the deterministic [`SynthBackend`] in tests
//!   and benches (no artifacts needed). Backends may provide a native
//!   multi-token [`StepBackend::prefill_chunk`] path; the engine loops
//!   the single-token step for those that don't.
//! * [`DecodeEngine`] — owns the persistent `[B, L, S, D]` step slabs and
//!   the step primitives: admit-one-slot, one batched decode step,
//!   lane-to-lane slot moves.
//! * [`scheduler::Scheduler`] — slot-level admission queue + lane pool
//!   (continuous batching); [`DecodeEngine::serve_wave`] remains as the
//!   legacy wave-at-a-time loop.
//! * [`metrics::ServingMetrics`] — per-request latency/TTFT/queue-depth
//!   histograms next to the aggregate [`Metrics`] counters.
//!
//! # Decode hot path
//!
//! The batched step tensors (`k_f32`/`v_f32` slabs) persist inside the
//! engine, and each slot's packed caches carry a dirty-row watermark (see
//! [`crate::quant::kv_cache`]), so a decode step dequantizes only the rows
//! appended since the previous step — O(new rows), not O(total fill) —
//! **straight into the slot's lane** (no f32 staging mirror; PR 3 halved
//! resident f32 KV per slot by deleting it). Finished slots release their
//! packed buffers immediately, free their lane for the next queued
//! request, and have their slab lanes zeroed exactly once.
//!
//! # Chunked prefill
//!
//! A budgeted step runs in two phases: phase A
//! (`DecodeEngine::chunk_prefill`) distributes the per-step prefill token
//! budget across prefilling slots as multi-token chunks (bulk quantized
//! appends, no sampling); phase B is the ordinary batched step, which
//! always feeds a slot's *final* prompt token so the sampled logits see
//! exactly the lane state the unchunked schedule builds. Budget 1 makes
//! phase A a no-op — bit-for-bit the legacy schedule. See
//! `ARCHITECTURE.md` for the policy and the invariance contract.
//!
//! # Paged KV and prefix sharing
//!
//! Quantized slots no longer own their packed rows: every [`KvCache`]
//! borrows fixed-size pages from the engine's shared
//! [`PagePool`] (see `quant/page.rs`), and the continuous scheduler keeps
//! a radix-tree **prefix cache** over completed prompt prefills. At
//! admission the longest shared prompt prefix's pages are mapped into the
//! new slot read-only (refcount bumps, zero re-quantization); chunked
//! prefill then only pays for the *suffix*, and the first divergent
//! append copy-on-writes the partially covered tail page. With the
//! prefix cache off, every page has exactly one owner and scheduling is
//! bit-identical to the pre-paging engine; with it on, generations stay
//! bit-identical (per-slot purity + deterministic quantization: the same
//! prompt prefix produces the same packed rows) while TTFT-in-steps and
//! the dedup-aware footprint ([`Metrics::dedup_factor`]) improve on
//! shared-prefix traffic.

pub mod fault;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;

use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::formats::{EncodePlan, NxConfig, QuantPolicy};
use crate::models::{Checkpoint, LmSpec};
use crate::obs::{CodeOccupancy, TraceEvent, TraceSink};
use crate::quant::kv_cache::{KvCache, KvPlans, KvStreamPlan};
use crate::quant::page::{PageId, PagePool, DEFAULT_KV_PAGE_ROWS};
use crate::runtime::{lit, Runtime, Step};
use crate::train::params_to_literals;

use self::metrics::ServingMetrics;
use self::scheduler::Scheduler;

/// One generation request.
#[derive(Clone, Debug)]
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new: usize,
}

/// Why a request's response was produced. Anything other than
/// `Completed` is a policy or fault outcome; the response still carries
/// whatever tokens were generated before the request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Generated `max_new` tokens (or filled the context window).
    Completed,
    /// Failed admission validation (empty or over-length prompt).
    Rejected,
    /// Dropped by overload policy: admission queue at `--queue-cap`, or
    /// submitted while the server was draining.
    Shed,
    /// Missed its deadline (wall clock or max queue steps) — enforced at
    /// admission and per step.
    Deadline,
    /// A backend fault the retry/requeue policy could not absorb killed
    /// this slot (the engine itself keeps serving).
    BackendError,
}

/// Completed generation.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// prompt + generated tokens
    pub tokens: Vec<i32>,
    pub generated: usize,
    /// Arrival → completion (queue wait included under the continuous
    /// scheduler; wave mode stamps arrival at wave start).
    pub latency: Duration,
    /// How the request left the engine (`Completed` is the happy path).
    pub reason: FinishReason,
}

/// Aggregate serving metrics.
#[derive(Clone, Copy, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub tokens_generated: u64,
    pub decode_steps: u64,
    pub wall: Duration,
    /// Packed KV bits summed at request **completion**: each finished
    /// request contributes its final cache footprint once. A completion-
    /// time total, not a live peak (formerly misnamed `kv_bits_peak`).
    pub kv_bits_packed: u64,
    /// Key-stream share of `kv_bits_packed` — with a mixed policy
    /// (`kv.k=nxfp5,kv.v=mxfp4`) the per-class split is the footprint
    /// story, so it is accounted per stream.
    pub kv_bits_packed_k: u64,
    /// Value-stream share of `kv_bits_packed`.
    pub kv_bits_packed_v: u64,
    /// FP16 bits the same completed caches would have occupied.
    pub kv_bits_fp16: u64,
    /// Dedup-aware key-stream footprint: like `kv_bits_packed_k`, but
    /// every **page** is charged the first time a completed request
    /// references it and never again — pages shared across slots by the
    /// prefix cache count once pool-wide. With prefix sharing off this
    /// equals `kv_bits_packed_k` exactly (every page has one owner).
    pub kv_bits_packed_dedup_k: u64,
    /// Dedup-aware value-stream footprint (see `kv_bits_packed_dedup_k`).
    pub kv_bits_packed_dedup_v: u64,
}

impl Metrics {
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens_generated as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn kv_savings(&self) -> f64 {
        1.0 - self.kv_bits_packed as f64 / self.kv_bits_fp16.max(1) as f64
    }

    /// Dedup-aware packed footprint (both streams, shared pages once).
    pub fn kv_bits_packed_dedup(&self) -> u64 {
        self.kv_bits_packed_dedup_k + self.kv_bits_packed_dedup_v
    }

    /// How much the per-slot packed totals overcount actual pool bytes:
    /// `kv_bits_packed / kv_bits_packed_dedup`. Exactly 1.0 with prefix
    /// sharing off; > 1.0 when slots shared prefix pages.
    pub fn dedup_factor(&self) -> f64 {
        self.kv_bits_packed as f64 / self.kv_bits_packed_dedup().max(1) as f64
    }

    /// Fold another engine's counters into this rollup (fleet totals are
    /// exact sums). `wall` sums each replica's *stepping* time — replicas
    /// step concurrently, so it is aggregate compute, not fleet
    /// wall-clock; rate helpers like [`Self::tokens_per_sec`] read as
    /// per-replica averages on a rollup.
    pub fn merge(&mut self, other: &Metrics) {
        self.requests += other.requests;
        self.tokens_generated += other.tokens_generated;
        self.decode_steps += other.decode_steps;
        self.wall += other.wall;
        self.kv_bits_packed += other.kv_bits_packed;
        self.kv_bits_packed_k += other.kv_bits_packed_k;
        self.kv_bits_packed_v += other.kv_bits_packed_v;
        self.kv_bits_fp16 += other.kv_bits_fp16;
        self.kv_bits_packed_dedup_k += other.kv_bits_packed_dedup_k;
        self.kv_bits_packed_dedup_v += other.kv_bits_packed_dedup_v;
    }
}

/// Default per-step prefill token budget for the serving front-end and
/// CLI (`--prefill-budget`). 1 reproduces the unchunked per-token
/// schedule; engines constructed directly default to 1 so chunking is
/// always an explicit opt-in ([`DecodeEngine::set_prefill_budget`]).
pub const DEFAULT_PREFILL_BUDGET: usize = 64;

/// Default bound on transient-fault retries per backend call
/// (`--retry-max`). Attempt `n` backs off `base * 2^(n-1)`, capped at
/// [`MAX_RETRY_BACKOFF`]; exhaustion retires the affected slots.
pub const DEFAULT_RETRY_MAX: u32 = 3;

/// Ceiling on one exponential-backoff sleep between retries.
pub const MAX_RETRY_BACKOFF: Duration = Duration::from_millis(50);

/// Default first-retry backoff (attempt `n` waits `2^(n-1)` times this).
pub const DEFAULT_RETRY_BACKOFF: Duration = Duration::from_micros(100);

/// How many times one request may be requeued after slot-killing faults
/// before the engine gives up and fails it with
/// [`FinishReason::BackendError`] — bounds churn under a persistently
/// faulting backend.
pub const DEFAULT_REQUEUE_MAX: u32 = 8;

/// Output of one batched decode step.
pub struct StepOut {
    /// `[B, V]` next-token logits.
    pub logits: Vec<f32>,
    /// `[B, L, D]` freshly produced K rows (one per layer per slot).
    pub k_new: Vec<f32>,
    /// `[B, L, D]` freshly produced V rows.
    pub v_new: Vec<f32>,
}

/// KV rows produced by a multi-token prefill chunk for **one** slot.
/// Layer-major `[L, n, D]` (each layer's rows contiguous), so the rows
/// feed `KvCache::append_rows` per layer without a gather. Chunks carry
/// no logits: chunked tokens are never sampled — the final prompt token
/// always goes through the batched [`StepBackend::step`], which is what
/// makes chunking bit-invariant (the sampling step sees exactly the lane
/// state the unchunked schedule would have built).
pub struct ChunkKv {
    /// `[L, n, D]` K rows.
    pub k_rows: Vec<f32>,
    /// `[L, n, D]` V rows.
    pub v_rows: Vec<f32>,
}

/// Output of a speculative multi-token verify for **one** slot: the
/// next-token logits at every fed position plus the KV rows those tokens
/// append — what [`crate::spec::SpecEngine`] scores a draft's proposals
/// with in one batched call instead of one step per token.
pub struct VerifyOut {
    /// `[n, V]` logits: row `i` is the next-token distribution after
    /// feeding `tokens[i]` at position `pos0 + i` (each row sees the lane
    /// plus the rows of the earlier chunk tokens, exactly like `n`
    /// successive single-token steps over raw rows).
    pub logits: Vec<f32>,
    /// KV rows for the fed tokens (layer-major `[L, n, D]`, the
    /// [`ChunkKv`] layout — accepted prefixes bulk-append per layer).
    pub kv: ChunkKv,
}

/// The batched decode-step kernel the engine drives. `tokens`/`pos` are
/// `[B]`, `k`/`v` are the persistent `[B, L, S, D]` slabs. Implementations
/// must be **per-slot pure**: slot `b`'s outputs may depend only on
/// `tokens[b]`, `pos[b]`, and lane `b` of the slabs — that independence is
/// what makes continuous batching bit-identical to solo decoding (and is
/// what the real artifact guarantees, since attention never crosses batch
/// lanes).
pub trait StepBackend {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut>;

    /// Multi-token prefill fast path: produce the KV rows for `tokens`
    /// fed at positions `pos0..pos0 + tokens.len()` of one slot, given
    /// that slot's current `[L, S, D]` lane (rows `0..pos0` already
    /// decoded). Backends whose KV projections need the cache updated
    /// *between* chunk tokens — the single-token PJRT artifact — return
    /// `Ok(None)` (the default) and the engine falls back to a batched
    /// artifact loop: every chunking lane advances one token per inner
    /// `step` invocation (decode lanes masked), interleaving quantized
    /// appends exactly like the per-token schedule — same bits, fewer
    /// scheduler steps, though on a single-token artifact the loop
    /// redistributes invocations toward prefill rather than saving them.
    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<ChunkKv>> {
        let _ = (tokens, pos0, k_lane, v_lane);
        Ok(None)
    }

    /// Speculative verify: like [`StepBackend::prefill_chunk`] but with
    /// logits at **every** fed position — one batched call scores all `k`
    /// draft proposals at once. Token `i`'s logits must equal what a
    /// plain [`StepBackend::step`] would produce given the lane state
    /// after the earlier chunk tokens' raw rows landed (the speculative
    /// bit-identity guarantee builds on that equivalence). Backends that
    /// cannot produce intermediate logits in one call return `Ok(None)`
    /// (the default) and the spec engine refuses to serve speculatively
    /// rather than silently degrading.
    fn verify_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<VerifyOut>> {
        let _ = (tokens, pos0, k_lane, v_lane);
        Ok(None)
    }
}

/// Delegation so wrappers generic over `B: StepBackend` — notably
/// [`fault::FaultBackend`] — can wrap an engine's boxed backend without
/// knowing its concrete type.
impl StepBackend for Box<dyn StepBackend> {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
        (**self).step(tokens, pos, k, v)
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<ChunkKv>> {
        (**self).prefill_chunk(tokens, pos0, k_lane, v_lane)
    }

    fn verify_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<VerifyOut>> {
        (**self).verify_chunk(tokens, pos0, k_lane, v_lane)
    }
}

/// Production backend: the AOT `decode_step` artifact through PJRT.
struct PjrtBackend {
    step_fn: Rc<Step>,
    params: Vec<xla::Literal>,
    /// `(B, L, S, D)` as baked into the artifact.
    dims: (usize, usize, usize, usize),
}

impl StepBackend for PjrtBackend {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
        let (b, l, s, d) = self.dims;
        let tok_lit = lit::from_i32(tokens, &[b as i64])?;
        let pos_lit = lit::from_i32(pos, &[b as i64])?;
        let k_lit = lit::from_f32(k, &[b as i64, l as i64, s as i64, d as i64])?;
        let v_lit = lit::from_f32(v, &[b as i64, l as i64, s as i64, d as i64])?;
        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        args.extend([&tok_lit, &pos_lit, &k_lit, &v_lit]);
        let out = self.step_fn.run(&args)?;
        anyhow::ensure!(out.len() == 3, "decode_step returned {} outputs", out.len());
        Ok(StepOut {
            logits: lit::to_f32(&out[0])?,
            k_new: lit::to_f32(&out[1])?,
            v_new: lit::to_f32(&out[2])?,
        })
    }
}

/// Deterministic synthetic decode step for scheduler tests and benches —
/// no PJRT runtime or artifacts required.
///
/// Shaped like the real artifact (fixed `[B, L, S, D]` cost per step, all
/// lanes processed every step) and deliberately **KV-sensitive**: slot
/// `b`'s logits are an attention-like reduction over *every* row of lane
/// `b`, so stale rows from a previous occupant, missed incremental syncs,
/// or cross-lane mix-ups change the generated tokens. Padding rows are
/// zero and contribute nothing, which keeps a slot's generation
/// bit-identical whether it runs alone or packed into a busy batch — the
/// property the scheduler determinism tests pin.
pub struct SynthBackend {
    l: usize,
    s: usize,
    d: usize,
    vocab: usize,
}

impl SynthBackend {
    pub fn new(spec: &LmSpec) -> Self {
        SynthBackend { l: spec.n_layers, s: spec.seq_len, d: spec.d_model, vocab: spec.vocab }
    }
}

/// Integer hash → f32 in `[-1, 1)`, exactly representable (24-bit
/// mantissa path) so every platform produces the same bits.
fn hash01(x: u32) -> f32 {
    let mut h = x.wrapping_mul(0x9E37_79B9);
    h ^= h >> 16;
    h = h.wrapping_mul(0x21F0_AAAD);
    h ^= h >> 15;
    (h >> 8) as f32 * (2.0 / (1 << 24) as f32) - 1.0
}

/// Greedy sampling reduction shared by the batched step and the
/// speculative verifier: `max_by` keeps the **last** of equal maxima, and
/// speculative bit-identity depends on the draft and verify paths using
/// exactly this reduction (a first-max-wins verifier would disagree with
/// the step path on ties and break the invariant silently).
pub fn greedy_argmax(row: &[f32]) -> i32 {
    row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32
}

/// One lane's worth of the synthetic step — the fresh per-layer KV row
/// plus the attention-like logit reduction — factored out so
/// `SynthBackend::verify_chunk` scores chunk tokens through the
/// **identical float-operation order** as `step`. Bit-identity between
/// speculative and plain decode rests on this sharing: a re-derived
/// reduction with a different accumulation order would produce different
/// low bits and be rejected as a draft divergence.
fn synth_lane_step(
    (l, s, d, vb): (usize, usize, usize, usize),
    tok: u32,
    p: u32,
    k_lane: &[f32],
    v_lane: &[f32],
    lg: &mut [f32],
    k_new: &mut [f32],
    v_new: &mut [f32],
) {
    for li in 0..l {
        // fresh KV row: a pure function of (token, pos, layer, dim)
        for j in 0..d {
            let key = tok.wrapping_mul(31) ^ p.rotate_left(9) ^ ((li as u32) << 20);
            k_new[li * d + j] = hash01(key ^ j as u32);
            v_new[li * d + j] = hash01(key ^ j as u32 ^ 0xA5A5_5A5A);
        }
        // attention-like read of the whole lane: every stored row
        // contributes, zero padding rows vanish
        let base = li * s * d;
        for r in 0..s {
            let mut score = 0.0f32;
            let mut val = 0.0f32;
            for j in 0..d {
                let row = base + r * d + j;
                score += k_lane[row] * hash01(j as u32 ^ tok.wrapping_mul(0x9E37_79B1));
                val += v_lane[row] * hash01(j as u32 ^ 0x5851_F42D);
            }
            lg[(r * 31 + li * 7 + 3) % vb] += score * val;
        }
    }
    // token/pos spike keeps greedy decoding non-degenerate
    let spike = (tok as usize).wrapping_mul(7).wrapping_add(p as usize) % vb;
    lg[spike] += 2.0 * hash01(tok ^ p.wrapping_mul(97));
}

impl StepBackend for SynthBackend {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
        let (l, s, d, vb) = (self.l, self.s, self.d, self.vocab);
        let bsz = tokens.len();
        let lane = l * s * d;
        let mut logits = vec![0.0f32; bsz * vb];
        let mut k_new = vec![0.0f32; bsz * l * d];
        let mut v_new = vec![0.0f32; bsz * l * d];
        for b in 0..bsz {
            synth_lane_step(
                (l, s, d, vb),
                tokens[b] as u32,
                pos[b] as u32,
                &k[b * lane..(b + 1) * lane],
                &v[b * lane..(b + 1) * lane],
                &mut logits[b * vb..(b + 1) * vb],
                &mut k_new[b * l * d..(b + 1) * l * d],
                &mut v_new[b * l * d..(b + 1) * l * d],
            );
        }
        Ok(StepOut { logits, k_new, v_new })
    }

    /// Native multi-token prefill: the synth's KV rows are pure functions
    /// of `(token, pos, layer, dim)` — the exact expressions `step` uses —
    /// so a whole chunk is produced in one call with no attention pass
    /// (rows carry no logits) and no intermediate cache round-trips. This
    /// is the cost model of a real prefill kernel: chunk work scales with
    /// the token count, not with `chunk × full-step` invocations.
    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        _k_lane: &[f32],
        _v_lane: &[f32],
    ) -> Result<Option<ChunkKv>> {
        let (l, d, n) = (self.l, self.d, tokens.len());
        let mut k_rows = vec![0.0f32; l * n * d];
        let mut v_rows = vec![0.0f32; l * n * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as u32;
            let p = (pos0 + t) as u32;
            for li in 0..l {
                let key = tok.wrapping_mul(31) ^ p.rotate_left(9) ^ ((li as u32) << 20);
                let base = (li * n + t) * d;
                for j in 0..d {
                    k_rows[base + j] = hash01(key ^ j as u32);
                    v_rows[base + j] = hash01(key ^ j as u32 ^ 0xA5A5_5A5A);
                }
            }
        }
        Ok(Some(ChunkKv { k_rows, v_rows }))
    }

    /// Native speculative verify: score each chunk token through the
    /// exact `step` reduction (`synth_lane_step` — shared code, shared
    /// float order) against a scratch copy of the lane that accumulates
    /// the earlier chunk tokens' raw rows, so row `i`'s logits are
    /// bit-identical to what `i` successive single-token steps would
    /// have produced over raw (unquantized) lane rows. Callers whose
    /// verifier re-quantizes KV between steps must feed one token per
    /// call and route the rows through their packed cache instead (see
    /// `spec::SpecEngine`).
    fn verify_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<VerifyOut>> {
        let (l, s, d, vb) = (self.l, self.s, self.d, self.vocab);
        let n = tokens.len();
        anyhow::ensure!(pos0 + n <= s, "verify_chunk overruns the context window");
        let mut k_scratch = k_lane.to_vec();
        let mut v_scratch = v_lane.to_vec();
        let mut logits = vec![0.0f32; n * vb];
        let mut k_rows = vec![0.0f32; l * n * d];
        let mut v_rows = vec![0.0f32; l * n * d];
        let mut k_new = vec![0.0f32; l * d];
        let mut v_new = vec![0.0f32; l * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let p = (pos0 + t) as u32;
            synth_lane_step(
                (l, s, d, vb),
                tok as u32,
                p,
                &k_scratch,
                &v_scratch,
                &mut logits[t * vb..(t + 1) * vb],
                &mut k_new,
                &mut v_new,
            );
            // commit this token's rows: into the scratch lane (the next
            // chunk token's logits must see them, mirroring the engine's
            // step→append interleave) and into the [L, n, D] output
            for li in 0..l {
                let row = &k_new[li * d..(li + 1) * d];
                let vow = &v_new[li * d..(li + 1) * d];
                let dst = (li * s + pos0 + t) * d;
                k_scratch[dst..dst + d].copy_from_slice(row);
                v_scratch[dst..dst + d].copy_from_slice(vow);
                let out = (li * n + t) * d;
                k_rows[out..out + d].copy_from_slice(row);
                v_rows[out..out + d].copy_from_slice(vow);
            }
        }
        Ok(Some(VerifyOut { logits, kv: ChunkKv { k_rows, v_rows } }))
    }
}

/// Per-slot quantized KV state: one packed [`KvCache`] per layer that
/// decodes **straight into the slot's assigned batch lane**.
///
/// [`SlotKv::sync_into`] decodes only the rows appended since the previous
/// call (the caches' dirty-row watermark) directly into the slot's
/// `[L, S, D]` lane of the batched step tensors, so per-step decode work
/// is O(new rows) instead of O(total fill) and there is **no intermediate
/// f32 staging mirror** (PR 1 kept one for lane mobility, doubling
/// resident f32 KV per slot; PR 3 deleted it). A slot moves to a different
/// lane by a lane-to-lane slab copy (`DecodeEngine::move_lane` —
/// watermarks stay valid, nothing is re-decoded); if the old lane is gone,
/// resetting each cache's watermark (`KvCache::reset_watermark`) makes the
/// next sync replay the whole prefix from the packed pages — the same
/// mechanism a prefix-adopted slot uses for its very first sync. Dropping
/// a `SlotKv` releases its page references (finished slots free
/// immediately; pages shared with other slots or the prefix cache live on).
pub struct SlotKv {
    caches: Vec<KvCache>,
    /// Lane rows (the artifact's fixed context length `S`).
    pad_len: usize,
    dim: usize,
}

impl SlotKv {
    /// Uniform convenience: `n_layers` caches of feature dim `dim` under
    /// one config (equivalent to [`SlotKv::from_plans`] over
    /// [`KvPlans::uniform`]). Each cache pre-reserves the full window so
    /// decode-step appends never reallocate.
    pub fn new(n_layers: usize, dim: usize, pad_len: usize, cfg: &NxConfig) -> Self {
        Self::from_plans(&KvPlans::uniform(cfg, n_layers), dim, pad_len)
    }

    /// One cache per layer from a policy-resolved [`KvPlans`] table:
    /// per-layer, per-stream configs, with encode plans and decode LUTs
    /// shared by `Arc` — admitting a slot builds no plans at all (the
    /// engine interned them once). Pages come from a **private** pool;
    /// serving slots use [`SlotKv::from_plans_in`] with the engine's
    /// shared pool so prefixes can be shared across slots.
    pub fn from_plans(plans: &KvPlans, dim: usize, pad_len: usize) -> Self {
        let pool = Rc::new(RefCell::new(PagePool::new(DEFAULT_KV_PAGE_ROWS)));
        Self::from_plans_in(plans, dim, pad_len, pool)
    }

    /// [`SlotKv::from_plans`] over a caller-provided shared [`PagePool`]
    /// (every layer of every slot borrows pages from the engine's pool).
    pub fn from_plans_in(
        plans: &KvPlans,
        dim: usize,
        pad_len: usize,
        pool: Rc<RefCell<PagePool>>,
    ) -> Self {
        SlotKv {
            caches: plans
                .layers
                .iter()
                .map(|(k, v)| {
                    KvCache::with_plans_in(dim, k.clone(), v.clone(), pad_len, pool.clone())
                })
                .collect(),
            pad_len,
            dim,
        }
    }

    /// Rows appended so far (cache fill; identical across layers).
    pub fn fill(&self) -> usize {
        self.caches[0].len
    }

    /// Quantize and append one generated (k, v) row for `layer`.
    pub fn append(&mut self, layer: usize, k_row: &[f32], v_row: &[f32]) {
        self.caches[layer].append(k_row, v_row);
    }

    /// Bulk-append `n` rows per layer from layer-major `[L, n, D]` chunk
    /// tensors (the [`StepBackend::prefill_chunk`] output layout — each
    /// layer's rows are contiguous, so they feed
    /// [`KvCache::append_rows`]'s one-grow-per-chunk path directly).
    pub fn append_chunk(&mut self, n: usize, k_rows: &[f32], v_rows: &[f32]) {
        let d = self.dim;
        debug_assert_eq!(k_rows.len(), self.caches.len() * n * d);
        debug_assert_eq!(v_rows.len(), k_rows.len());
        for (li, cache) in self.caches.iter_mut().enumerate() {
            let at = li * n * d;
            cache.append_rows(&k_rows[at..at + n * d], &v_rows[at..at + n * d], n);
        }
    }

    /// Roll every layer's packed cache back to its first `rows` rows —
    /// the speculative-decode rejection path ([`KvCache::truncate_rows`]
    /// per layer: trailing pages release, watermarks clamp, nothing is
    /// re-decoded). The caller owns zeroing the stale lane rows past the
    /// cut (`DecodeEngine::zero_lane_rows`), the same division of labor
    /// `move_lane` has with its vacated lane.
    pub fn truncate(&mut self, rows: usize) {
        for cache in &mut self.caches {
            cache.truncate_rows(rows);
        }
    }

    /// Per-layer packed caches (chunk-invariance tests compare the stored
    /// bits across prefill budgets; hot paths never need this).
    pub fn caches(&self) -> &[KvCache] {
        &self.caches
    }

    /// Attach the engine's per-layer `(K, V)` occupancy probe tables to
    /// this slot's caches (shared `Rc`s — every slot feeds the same
    /// per-config aggregates; see `DecodeEngine::enable_occupancy`).
    pub fn set_probes(
        &mut self,
        probes: &[(Rc<RefCell<CodeOccupancy>>, Rc<RefCell<CodeOccupancy>>)],
    ) {
        debug_assert_eq!(probes.len(), self.caches.len());
        for (cache, (k, v)) in self.caches.iter_mut().zip(probes) {
            cache.set_probes(Some(k.clone()), Some(v.clone()));
        }
    }

    /// Incrementally decode rows appended since the previous call straight
    /// into this slot's `[L, S, D]` lanes of the batched step tensors. The
    /// lane must persist across steps (the engine keeps the slab alive and
    /// zeroes a lane only when its slot finishes) or be a bit-identical
    /// copy (after [`DecodeEngine::move_lane`]).
    pub fn sync_into(&mut self, k_lane: &mut [f32], v_lane: &mut [f32]) {
        let (s, d) = (self.pad_len, self.dim);
        debug_assert_eq!(k_lane.len(), self.caches.len() * s * d);
        debug_assert_eq!(v_lane.len(), k_lane.len());
        for (li, cache) in self.caches.iter_mut().enumerate() {
            let base = li * s * d;
            cache.dequantize_into_slab(
                &mut k_lane[base..base + s * d],
                &mut v_lane[base..base + s * d],
            );
        }
    }

    /// Adopt a shared prompt prefix of `rows` tokens: map each layer's
    /// (K, V) page tables into layer `l`'s **empty** cache, refcount-only.
    /// The watermarks stay 0, so the next [`SlotKv::sync_into`] decodes
    /// the whole adopted prefix into the slot's lane in one pass — that
    /// single decode replaces the per-token prefill of `rows` tokens.
    pub fn adopt_prefix(&mut self, rows: usize, pages: &[(Vec<PageId>, Vec<PageId>)]) {
        assert_eq!(pages.len(), self.caches.len(), "layer count mismatch");
        for (cache, (k_ids, v_ids)) in self.caches.iter_mut().zip(pages) {
            cache.adopt_pages(rows, k_ids, v_ids);
        }
    }

    /// Per-layer (K, V) page tables — what a prefix-cache registration
    /// records at the prompt→decode transition.
    pub fn page_table(&self) -> Vec<(Vec<PageId>, Vec<PageId>)> {
        self.caches
            .iter()
            .map(|c| {
                let (k, v) = c.page_ids();
                (k.to_vec(), v.to_vec())
            })
            .collect()
    }

    /// Dedup-aware footprint charge `(K bits, V bits)` across layers:
    /// every referenced page not yet charged pool-wide, marked charged
    /// (see `KvCache::take_dedup_bits`).
    pub fn take_dedup_bits(&self) -> (u64, u64) {
        self.caches.iter().map(|c| c.take_dedup_bits()).fold((0, 0), |(ak, av), (k, v)| {
            (ak + k, av + v)
        })
    }

    /// Bit-true packed footprint across layers (K and V).
    pub fn footprint_bits(&self) -> u64 {
        self.caches.iter().map(|c| c.footprint_bits()).sum()
    }

    /// Per-stream packed footprint `(K bits, V bits)` across layers — the
    /// per-class breakdown a mixed policy reports.
    pub fn footprint_bits_split(&self) -> (u64, u64) {
        self.caches.iter().map(|c| c.footprint_bits_split()).fold((0, 0), |(ak, av), (k, v)| {
            (ak + k, av + v)
        })
    }

    /// FP16 footprint of the same caches.
    pub fn fp16_footprint_bits(&self) -> u64 {
        self.caches.iter().map(|c| c.fp16_footprint_bits()).sum()
    }
}

/// Lifecycle state of an admitted slot. Queued and Finished are implicit:
/// waiting requests live in the [`Scheduler`] queue, and a finished slot
/// is dropped from its lane the step it completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SlotState {
    /// Consuming prompt tokens into the lane's KV — one per step through
    /// the batched step, plus any multi-token chunk the per-step prefill
    /// budget grants (see [`DecodeEngine::set_prefill_budget`]).
    Prefilling,
    /// Prompt consumed; sampling one new token per step.
    Decoding,
}

/// An admitted request occupying one batch lane.
pub struct Slot {
    req: GenRequest,
    /// When the request entered the system (enqueue time under the
    /// continuous scheduler; wave start under `serve_wave`).
    arrival: Instant,
    state: SlotState,
    /// next prompt token to feed (while < prompt.len() we are prefilling)
    cursor: usize,
    output: Vec<i32>,
    /// quantized KV state; `None` = baseline mode (FP32 rows written
    /// straight into the slab, no quantizer setup at all)
    kv: Option<SlotKv>,
    /// cache fill (rows appended); tracked directly so baselines don't
    /// need a `KvCache` just for its length counter
    fill: usize,
    /// Prompt tokens fed by `chunk_prefill` in the current step (phase A);
    /// consumed into the prefill-chunk histogram when the slot feeds its
    /// batched-step token (phase B).
    chunk_fed: usize,
    /// Whether this slot's finished prompt prefill has been offered to the
    /// scheduler's prefix cache (`Scheduler::register_prefixes` runs once,
    /// at the prompt→decode transition, when the packed pages cover
    /// exactly the prompt rows).
    prefix_registered: bool,
    /// How many times this request has already been requeued by
    /// slot-killing faults (bounds fault churn; see
    /// [`DecodeEngine::set_requeue_max`]).
    requeues: u32,
}

/// A faulted slot's request on its way back to the scheduler queue. The
/// original arrival survives — latency spans the whole ordeal — and the
/// requeue count bounds how often one request may churn. Re-admission
/// replays the prompt prefill from scratch (or from the prefix cache's
/// packed pages); deterministic encoding plus greedy sampling make the
/// replayed generation bit-identical to an undisturbed run.
pub struct Requeue {
    pub req: GenRequest,
    pub arrival: Instant,
    pub requeues: u32,
}

impl Slot {
    pub fn state(&self) -> SlotState {
        self.state
    }

    pub fn request_id(&self) -> u64 {
        self.req.id
    }

    /// Tokens generated so far (0 while still prefilling). Deterministic
    /// TTFT-in-steps trackers poll this between engine steps.
    pub fn generated(&self) -> usize {
        self.output.len() - self.req.prompt.len()
    }

    /// Prompt tokens not yet fed.
    pub fn remaining_prompt(&self) -> usize {
        self.req.prompt.len() - self.cursor
    }

    /// The slot's packed KV state (`None` in baseline mode). Exposed for
    /// the chunk-invariance tests.
    pub fn kv(&self) -> Option<&SlotKv> {
        self.kv.as_ref()
    }

    // --- speculative-decode surface (crate-internal): `spec::SpecEngine`
    // edits a slot's provisional tail in place — truncating rejected
    // proposals, pushing the verifier's correction, rolling the draft KV
    // back — while everything else about the slot lifecycle stays owned
    // by the engine.

    pub(crate) fn request(&self) -> &GenRequest {
        &self.req
    }

    pub(crate) fn arrival(&self) -> Instant {
        self.arrival
    }

    pub(crate) fn output(&self) -> &[i32] {
        &self.output
    }

    pub(crate) fn output_mut(&mut self) -> &mut Vec<i32> {
        &mut self.output
    }

    /// Cache fill in rows (the draft lane's, in spec mode).
    pub(crate) fn fill_rows(&self) -> usize {
        self.fill
    }

    /// Reset the fill counter after a speculative rollback (the packed
    /// caches were truncated to match via [`SlotKv::truncate`]).
    pub(crate) fn set_fill(&mut self, rows: usize) {
        self.fill = rows;
    }

    pub(crate) fn kv_mut(&mut self) -> Option<&mut SlotKv> {
        self.kv.as_mut()
    }
}

/// Occupancy-table interning: streams whose `EncodePlan` is the same
/// `Arc` (the `KvPlans` interning guarantee) share one table, so the
/// report has exactly one entry per distinct config.
fn intern_occ(
    sp: &KvStreamPlan,
    uniq: &mut Vec<(Arc<EncodePlan>, Rc<RefCell<CodeOccupancy>>)>,
) -> Rc<RefCell<CodeOccupancy>> {
    for (p, t) in uniq.iter() {
        if Arc::ptr_eq(p, &sp.plan) {
            return t.clone();
        }
    }
    let t = Rc::new(RefCell::new(CodeOccupancy::new(&sp.cfg)));
    uniq.push((sp.plan.clone(), t.clone()));
    t
}

/// Batched decode engine. `B` (max batch) and `S` (max context) are baked
/// into the artifact; the engine pads unused lanes and owns the persistent
/// `[B, L, S, D]` step slabs (free lanes are always zero).
pub struct DecodeEngine {
    pub spec: LmSpec,
    backend: Box<dyn StepBackend>,
    /// Policy-resolved per-layer, per-stream KV plans (`None` = FP32
    /// baseline: raw rows in the slabs, no quantizer at all).
    kv: Option<KvPlans>,
    pub max_batch: usize,
    pub metrics: Metrics,
    /// Per-request latency/TTFT/queue-depth histograms.
    pub serving: ServingMetrics,
    /// Per-step token budget for chunked prefill (see
    /// [`DecodeEngine::set_prefill_budget`]); 1 = unchunked.
    prefill_budget: usize,
    /// Transient-fault retries per backend call before the affected slots
    /// are retired (see [`DecodeEngine::set_retry_policy`]).
    retry_max: u32,
    /// First retry's backoff; attempt `n` waits `base * 2^(n-1)` capped
    /// at [`MAX_RETRY_BACKOFF`].
    retry_backoff_base: Duration,
    /// Requeues one request may survive before a slot-killing fault fails
    /// it with [`FinishReason::BackendError`].
    requeue_max: u32,
    /// Per-request wall-clock deadline, enforced at admission and per
    /// step (`None` = no deadline).
    deadline: Option<Duration>,
    /// Structured trace sink (disabled by default: every emission is one
    /// null check; see `obs::TraceSink`).
    trace: TraceSink,
    /// Per-layer `(K, V)` occupancy probe tables handed to every admitted
    /// slot's caches; empty until [`DecodeEngine::enable_occupancy`].
    probes: Vec<(Rc<RefCell<CodeOccupancy>>, Rc<RefCell<CodeOccupancy>>)>,
    /// The distinct tables behind `probes` (one per interned config).
    occ_tables: Vec<Rc<RefCell<CodeOccupancy>>>,
    /// `(prefill tokens, decode tokens)` fed by the most recent
    /// [`DecodeEngine::step_slots`] — the step-span token split.
    last_step_split: (u64, u64),
    /// Speculative hold: when set (only by `spec::SpecEngine`), sampled
    /// tokens are **provisional draft proposals** — `step_slots` still
    /// pushes them onto the slot output, but defers `tokens_generated`,
    /// TTFT, and the whole finish path to the spec round that verifies
    /// them (an unverified token must never be surfaced or counted).
    pub(crate) spec_hold: bool,
    /// Shared page pool every quantized slot's caches borrow from — the
    /// substrate of cross-slot prefix sharing (unused in FP32 baseline
    /// mode, where slots carry no packed caches at all).
    pool: Rc<RefCell<PagePool>>,
    k_f32: Vec<f32>,
    v_f32: Vec<f32>,
}

impl DecodeEngine {
    /// Engine over the production PJRT artifact. `kv` is the quantization
    /// policy's KV side: per-layer, per-stream formats are resolved once
    /// here ([`KvPlans::from_policy`]) with one `EncodePlan`/`DequantLut`
    /// per distinct config; slot admission only clones `Arc`s.
    pub fn new(
        rt: &mut Runtime,
        spec: LmSpec,
        ck: &Checkpoint,
        kv: &QuantPolicy,
        max_batch: usize,
    ) -> Result<Self> {
        ck.check_spec(&spec)?;
        let plans = KvPlans::from_policy(kv, spec.n_layers)?;
        let backend = PjrtBackend {
            step_fn: rt.load("decode_step")?,
            params: params_to_literals(ck)?,
            dims: (max_batch, spec.n_layers, spec.seq_len, spec.d_model),
        };
        Ok(Self::with_plans(spec, Box::new(backend), plans, max_batch))
    }

    /// Engine over an arbitrary step kernel (tests and benches use
    /// [`SynthBackend`]; no PJRT runtime or artifacts needed). Panics on a
    /// policy the engine cannot serve (KV streams mixing FP16 with
    /// quantized formats) — use [`DecodeEngine::new`] or
    /// [`KvPlans::from_policy`] + [`DecodeEngine::with_plans`] to handle
    /// that as an error.
    pub fn with_backend(
        spec: LmSpec,
        backend: Box<dyn StepBackend>,
        kv: &QuantPolicy,
        max_batch: usize,
    ) -> Self {
        let plans = KvPlans::from_policy(kv, spec.n_layers).expect("unsupported KV policy");
        Self::with_plans(spec, backend, plans, max_batch)
    }

    /// Engine over pre-resolved KV plans (`None` = FP32 baseline).
    pub fn with_plans(
        spec: LmSpec,
        backend: Box<dyn StepBackend>,
        kv: Option<KvPlans>,
        max_batch: usize,
    ) -> Self {
        let n = max_batch * spec.n_layers * spec.seq_len * spec.d_model;
        DecodeEngine {
            spec,
            backend,
            kv,
            max_batch,
            metrics: Metrics::default(),
            serving: ServingMetrics::default(),
            prefill_budget: 1,
            retry_max: DEFAULT_RETRY_MAX,
            retry_backoff_base: DEFAULT_RETRY_BACKOFF,
            requeue_max: DEFAULT_REQUEUE_MAX,
            deadline: None,
            trace: TraceSink::disabled(),
            probes: Vec::new(),
            occ_tables: Vec::new(),
            last_step_split: (0, 0),
            spec_hold: false,
            pool: Rc::new(RefCell::new(PagePool::new(DEFAULT_KV_PAGE_ROWS))),
            k_f32: vec![0.0; n],
            v_f32: vec![0.0; n],
        }
    }

    /// The engine's shared KV page pool (what a scheduler's prefix cache
    /// retains entry pages in; see `Scheduler::enable_prefix_cache`).
    pub fn page_pool(&self) -> Rc<RefCell<PagePool>> {
        self.pool.clone()
    }

    /// Replace the page geometry (`--kv-page-rows`). Only valid before
    /// any slot has allocated pages — page ids don't survive a pool swap.
    pub fn set_kv_page_rows(&mut self, rows: usize) {
        assert_eq!(
            self.pool.borrow().live_pages(),
            0,
            "set_kv_page_rows after pages were allocated"
        );
        self.pool = Rc::new(RefCell::new(PagePool::new(rows)));
    }

    /// Set the per-step token budget for chunked prefill (both scheduling
    /// modes). Every occupied lane feeds one token through the batched
    /// step each engine step (decode lanes are reserved first and a
    /// prefilling slot never stalls); any budget beyond that is handed to
    /// prefilling slots as extra multi-token chunks, so a budget of 1 —
    /// the constructor default — reproduces the unchunked per-token
    /// schedule bit for bit, and `usize::MAX` prefills a whole prompt in
    /// one step. Values are clamped to at least 1.
    pub fn set_prefill_budget(&mut self, budget: usize) {
        self.prefill_budget = budget.max(1);
    }

    pub fn prefill_budget(&self) -> usize {
        self.prefill_budget
    }

    /// Set the transient-fault retry policy: up to `max` retries per
    /// backend call, attempt `n` backing off `base * 2^(n-1)` (capped at
    /// [`MAX_RETRY_BACKOFF`]). `max` 0 disables in-place retry — every
    /// transient fault immediately retires the affected slots (they still
    /// requeue under the continuous scheduler). Tests pass
    /// `Duration::ZERO` as `base` to retry without sleeping.
    pub fn set_retry_policy(&mut self, max: u32, base: Duration) {
        self.retry_max = max;
        self.retry_backoff_base = base;
    }

    /// Bound how many times one request may be requeued by slot-killing
    /// faults before it fails with [`FinishReason::BackendError`].
    pub fn set_requeue_max(&mut self, max: u32) {
        self.requeue_max = max;
    }

    /// Per-request wall-clock deadline (`None` = none). Enforced at
    /// admission (a request that expired in the queue never takes a
    /// lane) and per continuous step (an in-flight request past its
    /// deadline is dropped with [`FinishReason::Deadline`] and its
    /// partial output shipped).
    pub fn set_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline;
    }

    /// Install a structured trace sink (see `obs::TraceSink`). The server
    /// front-end clones the same sink into the [`Scheduler`] so engine
    /// and scheduler emissions share one ring and one step clock.
    pub fn set_trace_sink(&mut self, sink: TraceSink) {
        self.trace = sink;
    }

    /// A clone of the engine's trace sink (shared ring).
    pub fn trace_sink(&self) -> TraceSink {
        self.trace.clone()
    }

    /// Turn on live code-occupancy probes: one [`CodeOccupancy`] table
    /// per **interned** config (plans shared across layers/streams feed
    /// one aggregate), attached to every subsequently admitted slot's
    /// caches. No-op in FP32 baseline mode or when already enabled.
    /// Probe overhead is a few mul/cmp per encoded element, and only on
    /// slots admitted after this call.
    pub fn enable_occupancy(&mut self) {
        if !self.probes.is_empty() {
            return;
        }
        let Some(plans) = self.kv.as_ref() else { return };
        let mut uniq: Vec<(Arc<EncodePlan>, Rc<RefCell<CodeOccupancy>>)> = Vec::new();
        let probes: Vec<_> = plans
            .layers
            .iter()
            .map(|(k, v)| (intern_occ(k, &mut uniq), intern_occ(v, &mut uniq)))
            .collect();
        self.probes = probes;
        self.occ_tables = uniq.into_iter().map(|(_, t)| t).collect();
    }

    /// Snapshot of every occupancy probe table (one per interned config;
    /// empty when probes are off).
    pub fn occupancy_report(&self) -> Vec<CodeOccupancy> {
        self.occ_tables.iter().map(|t| t.borrow().clone()).collect()
    }

    /// Complete `req` as shed by overload policy (queue cap or drain),
    /// counting it and emitting its trace lifecycle. The server
    /// front-end routes shed requests through here so metrics and traces
    /// stay in exact agreement.
    pub fn shed_response(&mut self, req: GenRequest) -> GenResponse {
        self.serving.shed += 1;
        self.trace.event(Some(req.id), TraceEvent::Shed);
        self.trace.event(Some(req.id), TraceEvent::Finished { reason: FinishReason::Shed });
        GenResponse {
            id: req.id,
            tokens: req.prompt,
            generated: 0,
            latency: Duration::ZERO,
            reason: FinishReason::Shed,
        }
    }

    /// Wrap the current backend in a [`fault::FaultBackend`] injecting
    /// `plan` (bench/test only — this is how `--fault-plan` and the fault
    /// sweep exercise the recovery paths on any backend). Returns the
    /// injection counters; the engine's own `ServingMetrics` fault
    /// counters are asserted against them in the fault-recovery tests.
    pub fn inject_faults(&mut self, plan: &fault::FaultPlan) -> Rc<RefCell<fault::FaultStats>> {
        struct Placeholder;
        impl StepBackend for Placeholder {
            fn step(&mut self, _: &[i32], _: &[i32], _: &[f32], _: &[f32]) -> Result<StepOut> {
                anyhow::bail!("placeholder backend stepped")
            }
        }
        let inner = std::mem::replace(&mut self.backend, Box::new(Placeholder));
        let wrapped = fault::FaultBackend::new(inner, plan.clone());
        let stats = wrapped.stats();
        self.backend = Box::new(wrapped);
        stats
    }

    /// Elements in one `[L, S, D]` lane.
    pub(crate) fn lane_len(&self) -> usize {
        self.spec.n_layers * self.spec.seq_len * self.spec.d_model
    }

    /// Shared admission validity check: a prompt must be non-empty and
    /// shorter than the artifact's context length `S` (prefill appends one
    /// KV row per prompt token before the first sample, so a longer prompt
    /// would overrun the cache). Invalid requests complete immediately
    /// with `generated == 0` and never consume a lane. The server front-end
    /// also calls this at enqueue time so a deterministic rejection never
    /// waits in the queue behind real work.
    pub(crate) fn validate(&mut self, req: &GenRequest) -> Option<GenResponse> {
        let s = self.spec.seq_len;
        if !req.prompt.is_empty() && req.prompt.len() < s {
            return None;
        }
        eprintln!(
            "[serve] rejecting request {}: prompt length {} (must be 1..{s})",
            req.id,
            req.prompt.len()
        );
        self.serving.rejected += 1;
        self.trace.event(Some(req.id), TraceEvent::Finished { reason: FinishReason::Rejected });
        Some(GenResponse {
            id: req.id,
            tokens: req.prompt.clone(),
            generated: 0,
            latency: Duration::ZERO,
            reason: FinishReason::Rejected,
        })
    }

    /// The engine's resolved KV plans (`None` = FP32 baseline).
    pub fn kv_plans(&self) -> Option<&KvPlans> {
        self.kv.as_ref()
    }

    /// Record one retry and sleep attempt `n`'s capped exponential
    /// backoff (`base * 2^(n-1)`, at most [`MAX_RETRY_BACKOFF`]).
    fn backoff(&mut self, attempt: u32) {
        self.serving.retries += 1;
        self.trace.event(None, TraceEvent::Retry { attempt });
        let exp = self.retry_backoff_base.saturating_mul(1u32 << (attempt - 1).min(20));
        let wait = exp.min(MAX_RETRY_BACKOFF);
        self.serving.retry_backoff.record(wait.as_secs_f64());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
    }

    /// Run the batched step, retrying transient faults in place with
    /// bounded backoff. In-place retry is bit-exact: a failed call
    /// mutates no engine state, the inputs are unchanged, and re-running
    /// the watermark sync would be a no-op — so the retried call is the
    /// same call. Returns `Err` only on a fatal error or after
    /// `retry_max` transient failures (every failed attempt counts into
    /// `serving.step_faults`).
    fn step_with_retry(&mut self, tokens: &[i32], pos: &[i32]) -> Result<StepOut> {
        let mut attempt = 0u32;
        loop {
            match self.backend.step(tokens, pos, &self.k_f32, &self.v_f32) {
                Ok(out) => return Ok(out),
                Err(e) if fault::is_transient(&e) => {
                    self.serving.step_faults += 1;
                    attempt += 1;
                    if attempt > self.retry_max {
                        return Err(e);
                    }
                    self.backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// [`DecodeEngine::step_with_retry`]'s twin for the native
    /// multi-token prefill path (failed attempts count into
    /// `serving.chunk_faults`).
    pub(crate) fn chunk_with_retry(
        &mut self,
        toks: &[i32],
        pos0: usize,
        b: usize,
    ) -> Result<Option<ChunkKv>> {
        let lane = self.lane_len();
        let mut attempt = 0u32;
        loop {
            let r = self.backend.prefill_chunk(
                toks,
                pos0,
                &self.k_f32[b * lane..(b + 1) * lane],
                &self.v_f32[b * lane..(b + 1) * lane],
            );
            match r {
                Ok(out) => return Ok(out),
                Err(e) if fault::is_transient(&e) => {
                    self.serving.chunk_faults += 1;
                    attempt += 1;
                    if attempt > self.retry_max {
                        return Err(e);
                    }
                    self.backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// [`DecodeEngine::chunk_with_retry`]'s twin for the speculative
    /// verify path: one batched multi-token call over lane `b`, transient
    /// faults retried in place (counted into `serving.chunk_faults` —
    /// verifies are chunk-class calls, see `fault::FaultBackend`), and
    /// non-finite logits caught **before any proposal is judged** exactly
    /// like `step_slots` catches them before sampling — retried as a
    /// transient fault, surfaced as one on exhaustion so the affected
    /// pair retires down the requeue-and-replay ladder.
    pub(crate) fn verify_with_retry(
        &mut self,
        toks: &[i32],
        pos0: usize,
        b: usize,
    ) -> Result<Option<VerifyOut>> {
        let lane = self.lane_len();
        let mut attempt = 0u32;
        let mut nan_attempts = 0u32;
        loop {
            let r = self.backend.verify_chunk(
                toks,
                pos0,
                &self.k_f32[b * lane..(b + 1) * lane],
                &self.v_f32[b * lane..(b + 1) * lane],
            );
            match r {
                Ok(Some(out)) => {
                    if out.logits.iter().all(|x| x.is_finite()) {
                        return Ok(Some(out));
                    }
                    self.serving.nan_faults += 1;
                    nan_attempts += 1;
                    if nan_attempts > self.retry_max {
                        return Err(fault::transient("non-finite verify logits"));
                    }
                    self.backoff(nan_attempts);
                }
                Ok(None) => return Ok(None),
                Err(e) if fault::is_transient(&e) => {
                    self.serving.chunk_faults += 1;
                    attempt += 1;
                    if attempt > self.retry_max {
                        return Err(e);
                    }
                    self.backoff(attempt);
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Zero lane `b`'s rows `from..` in every layer (the stale tail a
    /// speculative rollback leaves behind: packed caches truncate via
    /// [`SlotKv::truncate`], the decoded lane copy is the caller's to
    /// scrub — same division of labor as `move_lane`'s vacated lane).
    pub(crate) fn zero_lane_rows(&mut self, b: usize, from: usize) {
        let (l, s, d) = (self.spec.n_layers, self.spec.seq_len, self.spec.d_model);
        let lane = self.lane_len();
        for li in 0..l {
            let at = b * lane + (li * s + from) * d;
            let end = b * lane + (li + 1) * s * d;
            self.k_f32[at..end].fill(0.0);
            self.v_f32[at..end].fill(0.0);
        }
    }

    /// Write a layer-major `[L, n, D]` row block straight into lane `b`
    /// at row `pos0` — the baseline-mode (no packed KV) twin of
    /// [`SlotKv::append_chunk`], shared by the speculative accept path.
    pub(crate) fn write_lane_rows(
        &mut self,
        b: usize,
        pos0: usize,
        n: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) {
        let (l, s, d) = (self.spec.n_layers, self.spec.seq_len, self.spec.d_model);
        let lane = self.lane_len();
        debug_assert_eq!(k_rows.len(), l * n * d);
        for li in 0..l {
            let src = li * n * d;
            let dst = b * lane + (li * s + pos0) * d;
            self.k_f32[dst..dst + n * d].copy_from_slice(&k_rows[src..src + n * d]);
            self.v_f32[dst..dst + n * d].copy_from_slice(&v_rows[src..src + n * d]);
        }
    }

    /// Mutable view of one lane of the step slabs (the spec engine syncs
    /// a verifier slot's packed KV into its lane between verify calls).
    pub(crate) fn lane_mut(&mut self, b: usize) -> (&mut [f32], &mut [f32]) {
        let lane = self.lane_len();
        (
            &mut self.k_f32[b * lane..(b + 1) * lane],
            &mut self.v_f32[b * lane..(b + 1) * lane],
        )
    }

    /// Emit a trace event through the engine's sink (the spec engine's
    /// Draft/Verify/Rollback lifecycle shares the engine's ring and step
    /// clock).
    pub(crate) fn trace_event(&mut self, id: Option<u64>, ev: TraceEvent) {
        self.trace.event(id, ev);
    }

    /// Retire lane `b`'s slot after a fault the retry policy could not
    /// absorb: drop its packed KV (page references release immediately —
    /// adopted prefix pages included), zero the lane, and either push a
    /// [`Requeue`] for bit-exact replay or fail the request with
    /// [`FinishReason::BackendError`] (requeue disallowed, fatal error,
    /// or the request's requeue budget spent).
    pub(crate) fn retire_faulted(
        &mut self,
        slots: &mut [Option<Slot>],
        b: usize,
        done: &mut Vec<GenResponse>,
        requeue: &mut Vec<Requeue>,
        allow_requeue: bool,
        why: &str,
    ) {
        let lane = self.lane_len();
        let sl = slots[b].take().expect("retire_faulted: empty lane");
        self.k_f32[b * lane..(b + 1) * lane].fill(0.0);
        self.v_f32[b * lane..(b + 1) * lane].fill(0.0);
        if allow_requeue && sl.requeues < self.requeue_max {
            self.serving.requeued += 1;
            requeue.push(Requeue {
                req: sl.req,
                arrival: sl.arrival,
                requeues: sl.requeues + 1,
            });
            return;
        }
        eprintln!("[serve] request {} failed ({why}), requeues {}", sl.req.id, sl.requeues);
        self.serving.backend_failed += 1;
        self.trace
            .event(Some(sl.req.id), TraceEvent::Finished { reason: FinishReason::BackendError });
        let generated = sl.output.len() - sl.req.prompt.len();
        let latency = sl.arrival.elapsed();
        self.serving.latency.record(latency.as_secs_f64());
        done.push(GenResponse {
            id: sl.req.id,
            tokens: sl.output,
            generated,
            latency,
            reason: FinishReason::BackendError,
        });
        self.metrics.requests += 1;
    }

    /// Complete a slot that generated its full output: account the final
    /// KV footprint, release the packed buffers, zero the lane exactly
    /// once, record latency, emit the `Finished` trace, and push the
    /// response. Extracted from [`DecodeEngine::step_slots`] so the
    /// speculative engine — which owns the finish decision in spec mode
    /// (`spec_hold`) — retires slots through the identical lifecycle.
    pub(crate) fn finish_slot(&mut self, sl: Slot, b: usize, done: &mut Vec<GenResponse>) {
        let lane = self.lane_len();
        let generated = sl.output.len() - sl.req.prompt.len();
        if let Some(kv) = sl.kv {
            let (kb, vb) = kv.footprint_bits_split();
            self.metrics.kv_bits_packed += kb + vb;
            self.metrics.kv_bits_packed_k += kb;
            self.metrics.kv_bits_packed_v += vb;
            self.metrics.kv_bits_fp16 += kv.fp16_footprint_bits();
            // dedup-aware charge: pages shared with earlier completions
            // were already accounted and add zero here
            let (dk, dv) = kv.take_dedup_bits();
            self.metrics.kv_bits_packed_dedup_k += dk;
            self.metrics.kv_bits_packed_dedup_v += dv;
        }
        self.k_f32[b * lane..(b + 1) * lane].fill(0.0);
        self.v_f32[b * lane..(b + 1) * lane].fill(0.0);
        let latency = sl.arrival.elapsed();
        self.serving.latency.record(latency.as_secs_f64());
        self.trace
            .event(Some(sl.req.id), TraceEvent::Finished { reason: FinishReason::Completed });
        done.push(GenResponse {
            id: sl.req.id,
            generated,
            tokens: sl.output,
            latency,
            reason: FinishReason::Completed,
        });
        self.metrics.requests += 1;
    }

    /// Enforce the wall-clock deadline on occupied lanes: an expired slot
    /// is dropped mid-flight with [`FinishReason::Deadline`] (partial
    /// output shipped, packed pages released, lane zeroed and freed).
    pub(crate) fn expire_slots(&mut self, slots: &mut [Option<Slot>], done: &mut Vec<GenResponse>) {
        let Some(deadline) = self.deadline else { return };
        let lane = self.lane_len();
        for b in 0..slots.len() {
            let expired =
                slots[b].as_ref().map_or(false, |sl| sl.arrival.elapsed() > deadline);
            if !expired {
                continue;
            }
            let sl = slots[b].take().unwrap();
            self.k_f32[b * lane..(b + 1) * lane].fill(0.0);
            self.v_f32[b * lane..(b + 1) * lane].fill(0.0);
            self.serving.deadline_expired += 1;
            self.trace.event(Some(sl.req.id), TraceEvent::DeadlineExpired);
            self.trace
                .event(Some(sl.req.id), TraceEvent::Finished { reason: FinishReason::Deadline });
            let generated = sl.output.len() - sl.req.prompt.len();
            let latency = sl.arrival.elapsed();
            self.serving.latency.record(latency.as_secs_f64());
            done.push(GenResponse {
                id: sl.req.id,
                tokens: sl.output,
                generated,
                latency,
                reason: FinishReason::Deadline,
            });
            self.metrics.requests += 1;
        }
    }

    fn make_slot(&self, req: GenRequest, arrival: Instant) -> Slot {
        let (s, d) = (self.spec.seq_len, self.spec.d_model);
        Slot {
            arrival,
            state: SlotState::Prefilling,
            cursor: 0,
            output: req.prompt.clone(),
            kv: self.kv.as_ref().map(|plans| {
                let mut kv = SlotKv::from_plans_in(plans, d, s, self.pool.clone());
                if !self.probes.is_empty() {
                    kv.set_probes(&self.probes);
                }
                kv
            }),
            fill: 0,
            chunk_fed: 0,
            prefix_registered: false,
            requeues: 0,
            req,
        }
    }

    /// Phase A of a budgeted step: distribute the per-step prefill token
    /// budget across prefilling slots as multi-token chunks.
    ///
    /// Every occupied lane — decoding *or* prefilling — feeds one token
    /// through the batched step in phase B, so decode lanes are reserved
    /// first by construction and only `budget - occupied` tokens remain
    /// for chunk work; with budget 1 this is a no-op and the schedule is
    /// exactly the legacy per-token one. The remainder goes
    /// **shortest-remaining-prefill-first** (ties broken by lane index):
    /// finishing one prefill outright starts that request decoding — and
    /// counting toward TTFT — a whole step sooner than spreading the same
    /// tokens evenly. A chunk never includes a slot's *final* prompt
    /// token: that one is fed by phase B, whose logits are sampled, so
    /// the sampling step sees the identical lane state the unchunked
    /// schedule builds (the chunk-invariance contract).
    /// Chunk failures are contained per lane: a transient
    /// `prefill_chunk` fault that outlives the retry budget (or a fatal
    /// one) retires only the slot it was feeding — the other lanes'
    /// chunks and the batched step proceed untouched.
    pub(crate) fn chunk_prefill(
        &mut self,
        slots: &mut [Option<Slot>],
        done: &mut Vec<GenResponse>,
        requeue: &mut Vec<Requeue>,
        allow_requeue: bool,
    ) {
        let occupied = slots.iter().filter(|s| s.is_some()).count();
        let mut extra = self.prefill_budget.saturating_sub(occupied);
        if extra == 0 {
            return;
        }
        let mut order: Vec<(usize, usize)> = slots
            .iter()
            .enumerate()
            .filter_map(|(b, s)| {
                let rem = s.as_ref()?.remaining_prompt();
                (rem > 1).then_some((rem, b))
            })
            .collect();
        order.sort_unstable();
        // lanes whose backend had no native multi-token path take the
        // batched artifact loop together
        let mut looped: Vec<(usize, usize)> = Vec::new();
        for (rem, b) in order {
            if extra == 0 {
                break;
            }
            let n = extra.min(rem - 1);
            match self.feed_chunk_native(slots, b, n) {
                Ok(true) => {}
                Ok(false) => looped.push((b, n)),
                Err(e) => {
                    // the failed call mutated nothing: retire just this
                    // lane (requeue replays its prefill bit-exactly)
                    let transient = fault::is_transient(&e);
                    self.retire_faulted(
                        slots,
                        b,
                        done,
                        requeue,
                        allow_requeue && transient,
                        &format!("prefill chunk: {e:#}"),
                    );
                }
            }
            extra -= n;
        }
        if !looped.is_empty() {
            self.feed_chunk_looped(slots, &looped, done, requeue, allow_requeue);
        }
    }

    /// Feed `n` prompt tokens of the slot in lane `b` through the
    /// backend's native multi-token path: one `prefill_chunk` call → bulk
    /// quantized append (or raw lane write in baseline mode); quantized
    /// rows reach the lane through the regular watermark sync at the top
    /// of the next batched step. Returns `false` — with the slot
    /// untouched — when the backend has no native path (the caller then
    /// folds the lane into the batched artifact loop).
    fn feed_chunk_native(
        &mut self,
        slots: &mut [Option<Slot>],
        b: usize,
        n: usize,
    ) -> Result<bool> {
        let (l, s, d) = (self.spec.n_layers, self.spec.seq_len, self.spec.d_model);
        let lane = self.lane_len();
        let sl = slots[b].as_mut().expect("feed_chunk: empty lane");
        debug_assert!(n >= 1 && n < sl.remaining_prompt());
        if let Some(kv) = &mut sl.kv {
            // honor the prefill_chunk precondition (rows 0..pos0 decoded
            // in-lane): the row appended by the previous batched step is
            // still pending its watermark sync at this point
            kv.sync_into(
                &mut self.k_f32[b * lane..(b + 1) * lane],
                &mut self.v_f32[b * lane..(b + 1) * lane],
            );
        }
        let toks: Vec<i32> = sl.req.prompt[sl.cursor..sl.cursor + n].to_vec();
        let pos0 = sl.fill;
        let chunk = self.chunk_with_retry(&toks, pos0, b)?;
        let Some(ck) = chunk else {
            return Ok(false);
        };
        let sl = slots[b].as_mut().expect("feed_chunk: empty lane");
        debug_assert_eq!(ck.k_rows.len(), l * n * d);
        debug_assert_eq!(ck.v_rows.len(), l * n * d);
        if let Some(kv) = &mut sl.kv {
            kv.append_chunk(n, &ck.k_rows, &ck.v_rows);
        } else {
            for li in 0..l {
                let src = li * n * d;
                let dst = b * lane + (li * s + pos0) * d;
                self.k_f32[dst..dst + n * d].copy_from_slice(&ck.k_rows[src..src + n * d]);
                self.v_f32[dst..dst + n * d].copy_from_slice(&ck.v_rows[src..src + n * d]);
            }
        }
        sl.cursor += n;
        sl.fill += n;
        sl.chunk_fed += n;
        Ok(true)
    }

    /// Batched artifact-loop fallback for backends with no native
    /// multi-token path (the single-token PJRT artifact): **all** the
    /// assigned lanes advance one prompt token per inner batched step
    /// (decode lanes masked, outputs of unassigned lanes ignored), so
    /// concurrent prefills cost `max(chunk)` backend invocations — not
    /// `sum(chunk)` — and each slot sees exactly the per-token schedule's
    /// sync→step→append interleave (bit-identity by per-slot purity).
    /// Inner invocations still count as `decode_steps`: on a single-token
    /// artifact, chunking redistributes invocations toward prefill (TTFT)
    /// rather than eliminating them; see ARCHITECTURE.md.
    fn feed_chunk_looped(
        &mut self,
        slots: &mut [Option<Slot>],
        chunks: &[(usize, usize)],
        done: &mut Vec<GenResponse>,
        requeue: &mut Vec<Requeue>,
        allow_requeue: bool,
    ) {
        let (l, s, d) = (self.spec.n_layers, self.spec.seq_len, self.spec.d_model);
        let lane = self.lane_len();
        let rounds = chunks.iter().map(|&(_, n)| n).max().unwrap_or(0);
        let mut tokens = vec![0i32; self.max_batch];
        let mut pos = vec![0i32; self.max_batch];
        for i in 0..rounds {
            for &(b, n) in chunks {
                if i >= n {
                    continue;
                }
                let sl = slots[b].as_mut().expect("feed_chunk: empty lane");
                tokens[b] = sl.req.prompt[sl.cursor];
                pos[b] = sl.fill as i32;
                if let Some(kv) = &mut sl.kv {
                    kv.sync_into(
                        &mut self.k_f32[b * lane..(b + 1) * lane],
                        &mut self.v_f32[b * lane..(b + 1) * lane],
                    );
                }
            }
            let out = match self.step_with_retry(&tokens, &pos) {
                Ok(out) => out,
                Err(e) => {
                    // rounds 0..i committed cleanly (per-slot purity):
                    // lanes whose chunk already finished keep their
                    // state; only the still-chunking lanes retire
                    let transient = fault::is_transient(&e);
                    for &(b, n) in chunks {
                        if i < n && slots[b].is_some() {
                            self.retire_faulted(
                                slots,
                                b,
                                done,
                                requeue,
                                allow_requeue && transient,
                                &format!("prefill loop: {e:#}"),
                            );
                        }
                    }
                    return;
                }
            };
            self.metrics.decode_steps += 1;
            for &(b, n) in chunks {
                if i >= n {
                    continue;
                }
                let sl = slots[b].as_mut().expect("feed_chunk: empty lane");
                for li in 0..l {
                    let row = &out.k_new[(b * l + li) * d..(b * l + li + 1) * d];
                    let vow = &out.v_new[(b * l + li) * d..(b * l + li + 1) * d];
                    if let Some(kv) = &mut sl.kv {
                        kv.append(li, row, vow);
                    } else {
                        let base = ((b * l + li) * s + sl.fill) * d;
                        self.k_f32[base..base + d].copy_from_slice(row);
                        self.v_f32[base..base + d].copy_from_slice(vow);
                    }
                }
                sl.cursor += 1;
                sl.fill += 1;
                sl.chunk_fed += 1;
            }
        }
    }

    /// One batched decode step over every occupied lane: sync quantized KV
    /// incrementally into the slabs, run the backend, append the fresh KV
    /// rows, advance prefill cursors, sample greedily, and retire finished
    /// slots (their lanes are zeroed and freed for the next admission).
    ///
    /// Backend faults never escape: transient step errors retry in place
    /// with bounded backoff (bit-exact — see
    /// [`DecodeEngine::step_with_retry`]); exhaustion retires every
    /// occupied slot into `requeue` for replay; non-finite logits are
    /// detected **before sampling**, retried like a transient fault, and
    /// on exhaustion retire only the poisoned lanes (per-slot purity lets
    /// the clean lanes commit the same output); a fatal error fails every
    /// occupied slot with [`FinishReason::BackendError`] while the engine
    /// itself keeps serving.
    pub(crate) fn step_slots(
        &mut self,
        slots: &mut [Option<Slot>],
        done: &mut Vec<GenResponse>,
        requeue: &mut Vec<Requeue>,
        allow_requeue: bool,
    ) {
        let (l, s, d, vb) =
            (self.spec.n_layers, self.spec.seq_len, self.spec.d_model, self.spec.vocab);
        let bsz = self.max_batch;
        // spec mode schedules over the draft half of the lane pool: the
        // slots vec covers lanes 0..B/2 while the step still runs the
        // full B-lane slab (verifier lanes carry KV but never sample)
        debug_assert!(slots.len() == bsz || (self.spec_hold && slots.len() <= bsz));
        let lane = self.lane_len();
        let mut tokens = vec![0i32; bsz];
        let mut pos = vec![0i32; bsz];
        for (b, sl) in slots.iter_mut().enumerate() {
            let Some(sl) = sl else { continue };
            tokens[b] = if sl.cursor < sl.req.prompt.len() {
                sl.req.prompt[sl.cursor]
            } else {
                *sl.output.last().unwrap()
            };
            pos[b] = sl.fill as i32;
            if let Some(kv) = &mut sl.kv {
                // incremental on-the-fly dequantize: only rows appended
                // since the previous step decode here, straight into the
                // slot's lane
                kv.sync_into(
                    &mut self.k_f32[b * lane..(b + 1) * lane],
                    &mut self.v_f32[b * lane..(b + 1) * lane],
                );
            }
        }
        let mut nan_attempts = 0u32;
        let out = loop {
            match self.step_with_retry(&tokens, &pos) {
                Ok(out) => {
                    // poisoned logits are a backend fault caught before
                    // sampling, never shipped as garbage tokens (greedy
                    // argmax would also panic on NaN); every lane is
                    // scanned — non-finite output anywhere means the
                    // backend misbehaved, occupied or not
                    let poisoned: Vec<usize> = (0..bsz)
                        .filter(|&b| {
                            out.logits[b * vb..(b + 1) * vb].iter().any(|x| !x.is_finite())
                        })
                        .collect();
                    if poisoned.is_empty() {
                        break out;
                    }
                    self.serving.nan_faults += 1;
                    nan_attempts += 1;
                    if nan_attempts <= self.retry_max {
                        // inputs unchanged: the re-run recomputes clean
                        // lanes bit-identically
                        self.backoff(nan_attempts);
                        continue;
                    }
                    // exhausted: only the poisoned occupied lanes retire;
                    // per-slot purity lets the clean lanes commit this
                    // output (an empty poisoned lane is never sampled;
                    // a poisoned verifier lane surfaces at verify time)
                    for b in poisoned {
                        if b < slots.len() && slots[b].is_some() {
                            self.retire_faulted(
                                slots,
                                b,
                                done,
                                requeue,
                                allow_requeue,
                                "non-finite logits",
                            );
                        }
                    }
                    break out;
                }
                Err(e) if fault::is_transient(&e) => {
                    // retry budget spent: requeue every occupied slot for
                    // bit-exact replay and abandon this step — the engine
                    // keeps serving
                    for b in 0..slots.len() {
                        if slots[b].is_some() {
                            self.retire_faulted(
                                slots,
                                b,
                                done,
                                requeue,
                                allow_requeue,
                                &format!("step retries exhausted: {e:#}"),
                            );
                        }
                    }
                    return;
                }
                Err(e) => {
                    // fatal: fail every occupied slot, keep the engine up
                    for b in 0..slots.len() {
                        if slots[b].is_some() {
                            self.retire_faulted(
                                slots,
                                b,
                                done,
                                requeue,
                                false,
                                &format!("fatal backend error: {e:#}"),
                            );
                        }
                    }
                    return;
                }
            }
        };
        self.metrics.decode_steps += 1;

        // per-step prefill-vs-decode token split (phase-A chunks count
        // toward the step that fed them)
        let mut prefill_toks = 0u64;
        let mut decode_toks = 0u64;
        for (b, slot) in slots.iter_mut().enumerate() {
            let Some(sl) = slot.as_mut() else { continue };
            // append the new KV row (quantized or raw)
            for li in 0..l {
                let row = &out.k_new[(b * l + li) * d..(b * l + li + 1) * d];
                let vow = &out.v_new[(b * l + li) * d..(b * l + li + 1) * d];
                if let Some(kv) = &mut sl.kv {
                    kv.append(li, row, vow);
                } else {
                    let base = ((b * l + li) * s + sl.fill) * d;
                    self.k_f32[base..base + d].copy_from_slice(row);
                    self.v_f32[base..base + d].copy_from_slice(vow);
                }
            }
            sl.fill += 1;
            if sl.cursor < sl.req.prompt.len() {
                // this step consumed chunk_fed phase-A tokens plus this
                // batched-step token of the prompt
                let fed = sl.chunk_fed as u64 + 1;
                self.serving.prefill_chunk.record(fed as f64);
                self.trace
                    .event(Some(sl.req.id), TraceEvent::PrefillChunk { tokens: fed as usize });
                prefill_toks += fed;
                sl.chunk_fed = 0;
                sl.cursor += 1; // still consuming the prompt
                if sl.cursor < sl.req.prompt.len() {
                    continue;
                }
                sl.state = SlotState::Decoding; // last prompt token: sample
            } else {
                decode_toks += 1;
            }
            // sample greedily from this slot's logits
            let next = greedy_argmax(&out.logits[b * vb..(b + 1) * vb]);
            sl.output.push(next);
            if self.spec_hold {
                // provisional draft proposal: the spec round verifies it
                // before anything is counted, surfaced, or finished
                continue;
            }
            self.metrics.tokens_generated += 1;
            if sl.output.len() == sl.req.prompt.len() + 1 {
                self.serving.ttft.record(sl.arrival.elapsed().as_secs_f64());
            }
            let generated = sl.output.len() - sl.req.prompt.len();
            let finished = generated >= sl.req.max_new || sl.fill + 1 >= s;
            if finished {
                let sl = slot.take().unwrap();
                self.finish_slot(sl, b, done);
            }
        }
        if prefill_toks + decode_toks > 0 {
            self.serving.step_prefill_tokens.record(prefill_toks as f64);
            self.serving.step_decode_tokens.record(decode_toks as f64);
        }
        self.last_step_split = (prefill_toks, decode_toks);
    }

    /// Serve requests wave-at-a-time (the legacy scheduling mode: every
    /// lane is held until the whole wave drains). More than `max_batch`
    /// requests run as sequential sub-waves — the historical
    /// oversized-input panic is gone. Invalid requests are rejected
    /// individually — they complete immediately with `generated == 0` and
    /// do not abort the wave. Wave mode has no queue to requeue into, so
    /// faults that outlive the retry budget fail their slots with
    /// [`FinishReason::BackendError`].
    pub fn serve_wave(&mut self, mut reqs: Vec<GenRequest>) -> Result<Vec<GenResponse>> {
        let mut responses = Vec::new();
        while reqs.len() > self.max_batch {
            let rest = reqs.split_off(self.max_batch);
            responses.extend(self.serve_one_wave(std::mem::replace(&mut reqs, rest)));
        }
        responses.extend(self.serve_one_wave(reqs));
        Ok(responses)
    }

    fn serve_one_wave(&mut self, reqs: Vec<GenRequest>) -> Vec<GenResponse> {
        debug_assert!(reqs.len() <= self.max_batch);
        let wave_start = Instant::now();
        let mut responses = Vec::new();
        let mut slots: Vec<Option<Slot>> = Vec::with_capacity(self.max_batch);
        for req in reqs {
            match self.validate(&req) {
                Some(resp) => responses.push(resp),
                None => {
                    self.serving.admitted += 1;
                    self.trace.event(Some(req.id), TraceEvent::Admitted { lane: slots.len() });
                    slots.push(Some(self.make_slot(req, Instant::now())));
                }
            }
        }
        slots.resize_with(self.max_batch, || None);
        let mut no_requeue = Vec::new();
        while slots.iter().any(Option::is_some) {
            self.expire_slots(&mut slots, &mut responses);
            self.chunk_prefill(&mut slots, &mut responses, &mut no_requeue, false);
            if slots.iter().any(Option::is_some) {
                self.step_slots(&mut slots, &mut responses, &mut no_requeue, false);
            }
        }
        debug_assert!(no_requeue.is_empty());
        self.metrics.wall += wave_start.elapsed();
        responses
    }

    /// Fill free lanes from the scheduler queue. Validation rejections
    /// and queue-expired deadlines complete immediately into `done`
    /// without consuming a lane.
    pub(crate) fn admit(&mut self, sched: &mut Scheduler, done: &mut Vec<GenResponse>) {
        while let Some(b) = sched.free_lane() {
            let Some(adm) = sched.pop_next() else { break };
            if let Some(resp) = self.validate(&adm.req) {
                done.push(resp);
                continue;
            }
            // deadline enforcement at admission: the scheduler tracked
            // the queue-steps bound; the wall clock is checked here
            let wall_expired = self.deadline.map_or(false, |d| adm.arrival.elapsed() > d);
            if adm.expired || wall_expired {
                self.serving.deadline_expired += 1;
                self.trace.event(Some(adm.req.id), TraceEvent::DeadlineExpired);
                self.trace.event(
                    Some(adm.req.id),
                    TraceEvent::Finished { reason: FinishReason::Deadline },
                );
                let latency = adm.arrival.elapsed();
                self.serving.latency.record(latency.as_secs_f64());
                done.push(GenResponse {
                    id: adm.req.id,
                    tokens: adm.req.prompt,
                    generated: 0,
                    latency,
                    reason: FinishReason::Deadline,
                });
                self.metrics.requests += 1;
                continue;
            }
            let rid = adm.req.id;
            self.serving.admitted += 1;
            self.trace.event(Some(rid), TraceEvent::Admitted { lane: b });
            if adm.promoted {
                self.serving.promoted += 1;
                self.trace.event(Some(rid), TraceEvent::Promoted);
            }
            self.serving.wait_steps.record(adm.waited_steps as f64);
            let mut slot = self.make_slot(adm.req, adm.arrival);
            slot.requeues = adm.requeues;
            // prefix-cache hit: map the shared prefix's packed pages into
            // the fresh slot (refcount-only) and skip its prefill — the
            // remaining suffix goes through the ordinary budgeted path
            if let Some(kv) = slot.kv.as_mut() {
                match sched.prefix_lookup(&slot.req.prompt) {
                    Some((rows, pages)) => {
                        kv.adopt_prefix(rows, &pages);
                        slot.cursor = rows;
                        slot.fill = rows;
                        self.serving.prefix_hits += 1;
                        self.trace.event(Some(rid), TraceEvent::PrefixAdopted { rows });
                        self.serving.prefix_rows.record(rows as f64);
                    }
                    None if sched.prefix_enabled() => self.serving.prefix_misses += 1,
                    None => {}
                }
            }
            sched.place(b, slot);
        }
    }

    /// One continuous-batching iteration: admit queued requests into free
    /// lanes, run one batched decode step across all occupied lanes, and
    /// advance the scheduler's promotion clock. Returns the requests that
    /// completed this step. The server worker calls this in its loop, so
    /// newly arrived requests join between steps — no wave barrier.
    pub fn step_continuous(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        let t0 = Instant::now();
        let tracing = self.trace.is_enabled();
        let mut done = Vec::new();
        let mut requeue = Vec::new();
        self.expire_slots(sched.slots_mut(), &mut done);
        self.admit(sched, &mut done);
        let (mut phase_a_us, mut phase_b_us) = (0u64, 0u64);
        self.last_step_split = (0, 0);
        if sched.active() > 0 {
            let ta = tracing.then(Instant::now);
            self.chunk_prefill(sched.slots_mut(), &mut done, &mut requeue, true);
            if let Some(t) = ta {
                phase_a_us = t.elapsed().as_micros() as u64;
            }
            if sched.active() > 0 {
                let tb = tracing.then(Instant::now);
                self.step_slots(sched.slots_mut(), &mut done, &mut requeue, true);
                if let Some(t) = tb {
                    phase_b_us = t.elapsed().as_micros() as u64;
                }
            }
        }
        // faulted slots' requests go back to the *front* of the queue:
        // re-admission replays their prefill from packed KV bit-exactly
        for r in requeue {
            sched.requeue(r);
        }
        // offer freshly finished prefills to the prefix cache (no-op when
        // the cache is disabled) and sample the shared-page gauge
        sched.register_prefixes();
        if sched.prefix_enabled() {
            self.serving.shared_pages.record(self.pool.borrow().shared_pages() as f64);
        }
        // span is stamped with the *current* step (tick advances after)
        if tracing {
            self.trace.span(
                phase_a_us,
                phase_b_us,
                sched.active(),
                self.last_step_split.0 as usize,
                self.last_step_split.1 as usize,
            );
        }
        let depth = sched.tick();
        self.serving.queue_depth.record(depth as f64);
        self.metrics.wall += t0.elapsed();
        Ok(done)
    }

    /// Drive the continuous scheduler until the queue and all lanes drain.
    pub fn serve_continuous(&mut self, sched: &mut Scheduler) -> Result<Vec<GenResponse>> {
        let mut out = Vec::new();
        while sched.has_work() {
            out.extend(self.step_continuous(sched)?);
        }
        Ok(out)
    }

    /// Move the slot in lane `from` to the free lane `to` with a
    /// lane-to-lane slab copy: O(L·S·D) `memcpy`, **no packed re-decode**
    /// — the `SlotKv` watermarks stay valid because the new lane is
    /// bit-identical to the old. (When the source lane is unavailable,
    /// reset each cache's watermark — `KvCache::reset_watermark` — and the
    /// next sync replays the prefix from the packed pages.) The vacated
    /// lane is zeroed, preserving the free-lanes-are-zero invariant.
    ///
    /// An occupied target or empty source is an `Err`, not a panic: a
    /// replica thread must survive a bad move (route the error through
    /// [`DecodeEngine::move_lane_contained`] so the affected slot requeues
    /// and the lanes stay untouched). The invariants remain
    /// `debug_assert!`s so debug builds still catch the caller bug at the
    /// call site.
    pub fn move_lane(&mut self, slots: &mut [Option<Slot>], from: usize, to: usize) -> Result<()> {
        debug_assert!(from != to, "move_lane: from == to");
        debug_assert!(from < slots.len() && to < slots.len(), "move_lane: lane out of range");
        if from == to || from >= slots.len() || to >= slots.len() {
            anyhow::bail!("move_lane: bad lanes {from} -> {to} (pool of {})", slots.len());
        }
        if slots[to].is_some() {
            anyhow::bail!("move_lane: target lane {to} occupied");
        }
        let Some(slot) = slots[from].take() else {
            anyhow::bail!("move_lane: source lane {from} empty");
        };
        let lane = self.lane_len();
        self.k_f32.copy_within(from * lane..(from + 1) * lane, to * lane);
        self.v_f32.copy_within(from * lane..(from + 1) * lane, to * lane);
        self.k_f32[from * lane..(from + 1) * lane].fill(0.0);
        self.v_f32[from * lane..(from + 1) * lane].fill(0.0);
        slots[to] = Some(slot);
        Ok(())
    }

    /// [`DecodeEngine::move_lane`] routed through the fault-containment
    /// ladder: a failed move no longer kills the serving thread — the
    /// affected source slot retires through the requeue path (replayed
    /// from the prompt bit-exactly at its next admission, or failed with
    /// [`FinishReason::BackendError`] into `done` once past the requeue
    /// budget) and the replica keeps serving. Returns whether the move
    /// actually happened.
    pub fn move_lane_contained(
        &mut self,
        sched: &mut Scheduler,
        from: usize,
        to: usize,
        done: &mut Vec<GenResponse>,
    ) -> bool {
        let err = match self.move_lane(sched.slots_mut(), from, to) {
            Ok(()) => return true,
            Err(e) => e,
        };
        let mut requeue = Vec::new();
        if sched.slots().get(from).map_or(false, Option::is_some) {
            self.retire_faulted(
                sched.slots_mut(),
                from,
                done,
                &mut requeue,
                true,
                &format!("lane move {from} -> {to} failed: {err:#}"),
            );
        } else {
            eprintln!("[serve] lane move {from} -> {to} failed with no source slot: {err:#}");
        }
        for r in requeue {
            sched.requeue(r);
        }
        false
    }

    /// Read-only view of one lane of the step slabs (tests).
    pub fn lane(&self, b: usize) -> (&[f32], &[f32]) {
        let lane = self.lane_len();
        (&self.k_f32[b * lane..(b + 1) * lane], &self.v_f32[b * lane..(b + 1) * lane])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// `hash01` is the whole of the synthetic backend's "weights": pin
    /// its 24-bit hashes against the constants replicated in
    /// `python/tests/test_spec_decode.py`, so both languages derive the
    /// same deterministic model. Every arithmetic step here is exact in
    /// f32 (the mantissa never exceeds 24 bits), which is what makes the
    /// integer round-trip — and the cross-language pin — well-defined.
    #[test]
    fn hash01_pins_cross_language_constants() {
        let h24 = |x: u32| ((hash01(x) + 1.0) * (1u32 << 23) as f32) as u32;
        for (x, want) in [
            (0u32, 0u32),
            (1, 7_252_763),
            (42, 5_672_153),
            (97, 2_100_070),
            (0xDEAD_BEEF, 4_914_951),
        ] {
            assert_eq!(h24(x), want, "hash01({x:#x}) drifted from the cross-language pin");
        }
        assert_eq!(hash01(0), -1.0);
    }

    /// Last-max-wins tie-breaking is load-bearing for speculative
    /// bit-identity (draft and verifier must reduce ties identically);
    /// the python mirror pins the same cases.
    #[test]
    fn greedy_argmax_keeps_the_last_of_equal_maxima() {
        assert_eq!(greedy_argmax(&[1.0, 3.0, 2.0, 3.0]), 3);
        assert_eq!(greedy_argmax(&[5.0]), 0);
        assert_eq!(greedy_argmax(&[2.0, 2.0, 2.0]), 2);
    }

    /// The incremental sync must leave the lane bit-identical to a full
    /// re-decode of every layer at every step — the exact invariant the
    /// old `serve_wave` paid O(fill) per step to maintain.
    #[test]
    fn slot_kv_sync_matches_full_redecode() {
        let (l, s, d) = (3usize, 16usize, 40usize);
        let mut rng = Rng::seeded(81);
        let cfg = NxConfig::nxfp(4);
        let mut kv = SlotKv::new(l, d, s, &cfg);
        let mut k_lane = vec![0.0f32; l * s * d];
        let mut v_lane = vec![0.0f32; l * s * d];
        for step in 0..10 {
            for li in 0..l {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(li, &k, &v);
            }
            kv.sync_into(&mut k_lane, &mut v_lane);
            assert_eq!(kv.fill(), step + 1);
            for (li, cache) in kv.caches.iter().enumerate() {
                let (k_full, v_full) = cache.dequantize(s);
                assert_eq!(&k_lane[li * s * d..(li + 1) * s * d], &k_full.data[..]);
                assert_eq!(&v_lane[li * s * d..(li + 1) * s * d], &v_full.data[..]);
            }
        }
    }

    #[test]
    fn watermark_reset_reproduces_lane_after_move() {
        // lane-reassignment fallback: the packed pages alone must rebuild
        // the decoded prefix bit-identically in a fresh lane after a
        // per-cache watermark reset (the stale `resync_full_into` wrapper
        // was deleted with the paged refactor; this is its contract)
        let (l, s, d) = (2usize, 8usize, 32usize);
        let mut rng = Rng::seeded(82);
        let mut kv = SlotKv::new(l, d, s, &NxConfig::nxfp(5));
        let mut lane_k = vec![0.0f32; l * s * d];
        let mut lane_v = vec![0.0f32; l * s * d];
        for _ in 0..5 {
            for li in 0..l {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                kv.append(li, &k, &k);
            }
            kv.sync_into(&mut lane_k, &mut lane_v);
        }
        let mut moved_k = vec![0.0f32; l * s * d];
        let mut moved_v = vec![0.0f32; l * s * d];
        for cache in &mut kv.caches {
            cache.reset_watermark();
        }
        kv.sync_into(&mut moved_k, &mut moved_v);
        assert_eq!(moved_k, lane_k);
        assert_eq!(moved_v, lane_v);
    }

    #[test]
    fn lane_copy_then_incremental_sync_stays_bit_identical() {
        // slot churn: move a live slot to another lane via slab copy, keep
        // appending, and compare against a never-moved control slot
        let (l, s, d) = (2usize, 12usize, 24usize);
        let mut rng = Rng::seeded(83);
        let cfg = NxConfig::nxfp(4);
        let mut kv = SlotKv::new(l, d, s, &cfg);
        let mut ctl = SlotKv::new(l, d, s, &cfg);
        let lane = l * s * d;
        // two-lane slab: slot starts in lane 0
        let mut k_slab = vec![0.0f32; 2 * lane];
        let mut v_slab = vec![0.0f32; 2 * lane];
        let mut k_ctl = vec![0.0f32; lane];
        let mut v_ctl = vec![0.0f32; lane];
        let mut rows = Vec::new();
        for _ in 0..4 {
            let r: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            rows.push(r);
        }
        for step in 0..8 {
            let r = &rows[step % rows.len()];
            for li in 0..l {
                kv.append(li, r, r);
                ctl.append(li, r, r);
            }
            let lo = if step < 4 { 0 } else { lane };
            kv.sync_into(&mut k_slab[lo..lo + lane], &mut v_slab[lo..lo + lane]);
            ctl.sync_into(&mut k_ctl, &mut v_ctl);
            if step == 3 {
                // reassign lane 0 -> lane 1 with a slab copy (watermark
                // untouched: the new lane is bit-identical)
                k_slab.copy_within(0..lane, lane);
                v_slab.copy_within(0..lane, lane);
                k_slab[..lane].fill(0.0);
                v_slab[..lane].fill(0.0);
            }
        }
        assert_eq!(&k_slab[lane..], &k_ctl[..]);
        assert_eq!(&v_slab[lane..], &v_ctl[..]);
        // the vacated lane stayed zero for the next occupant
        assert!(k_slab[..lane].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn slot_kv_footprints_sum_layers() {
        let (l, s, d) = (2usize, 8usize, 64usize);
        let mut kv = SlotKv::new(l, d, s, &NxConfig::nxfp(4));
        let row = vec![0.25f32; d];
        for li in 0..l {
            kv.append(li, &row, &row);
        }
        assert_eq!(kv.fill(), 1);
        let one_layer = kv.caches[0].footprint_bits();
        assert_eq!(kv.footprint_bits(), l as u64 * one_layer);
        assert!(kv.fp16_footprint_bits() > kv.footprint_bits());
    }

    #[test]
    fn metrics_savings_uses_completion_totals() {
        let m = Metrics { kv_bits_packed: 25, kv_bits_fp16: 100, ..Metrics::default() };
        assert!((m.kv_savings() - 0.75).abs() < 1e-12);
        // empty metrics: no division by zero
        assert!(Metrics::default().kv_savings() <= 1.0);
    }

    #[test]
    fn synth_backend_is_deterministic_and_per_slot_pure() {
        let spec = LmSpec::tiny();
        let mut be = SynthBackend::new(&spec);
        let lane = spec.n_layers * spec.seq_len * spec.d_model;
        let mut rng = Rng::seeded(84);
        let mut k = vec![0.0f32; 2 * lane];
        let mut v = vec![0.0f32; 2 * lane];
        for x in k.iter_mut().chain(v.iter_mut()) {
            *x = rng.normal_f32(0.0, 1.0);
        }
        let a = be.step(&[3, 9], &[2, 5], &k, &v).unwrap();
        let b = be.step(&[3, 9], &[2, 5], &k, &v).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.k_new, b.k_new);
        // swap the lanes (and the token/pos pairing): per-slot outputs
        // must swap with them — nothing crosses lanes
        let mut ks = v.clone();
        let mut vs = k.clone();
        ks[..lane].copy_from_slice(&k[lane..]);
        ks[lane..].copy_from_slice(&k[..lane]);
        vs[..lane].copy_from_slice(&v[lane..]);
        vs[lane..].copy_from_slice(&v[..lane]);
        let c = be.step(&[9, 3], &[5, 2], &ks, &vs).unwrap();
        let vb = spec.vocab;
        assert_eq!(&c.logits[..vb], &a.logits[vb..]);
        assert_eq!(&c.logits[vb..], &a.logits[..vb]);
    }

    #[test]
    fn synth_prefill_chunk_matches_stepped_rows() {
        // the native chunk path must produce the exact KV rows the
        // batched step produces token by token (same hash expressions)
        let spec = LmSpec::tiny();
        let (l, s, d) = (spec.n_layers, spec.seq_len, spec.d_model);
        let mut be = SynthBackend::new(&spec);
        let lane = vec![0.0f32; l * s * d];
        let toks = [5i32, 9, 2, 41];
        let pos0 = 3usize;
        let ck = be.prefill_chunk(&toks, pos0, &lane, &lane).unwrap().unwrap();
        assert_eq!(ck.k_rows.len(), l * toks.len() * d);
        for (t, &tok) in toks.iter().enumerate() {
            let p = (pos0 + t) as i32;
            let out = be.step(&[tok], &[p], &lane, &lane).unwrap();
            for li in 0..l {
                let want_k = &out.k_new[li * d..(li + 1) * d];
                let want_v = &out.v_new[li * d..(li + 1) * d];
                let base = (li * toks.len() + t) * d;
                assert_eq!(&ck.k_rows[base..base + d], want_k, "tok {t} layer {li}");
                assert_eq!(&ck.v_rows[base..base + d], want_v);
            }
        }
    }

    #[test]
    fn slot_kv_append_chunk_matches_per_token_appends() {
        let (l, s, d) = (3usize, 16usize, 40usize);
        let mut rng = Rng::seeded(85);
        let cfg = NxConfig::nxfp(4);
        let mut bulk = SlotKv::new(l, d, s, &cfg);
        let mut single = SlotKv::new(l, d, s, &cfg);
        let n = 5;
        // layer-major [L, n, D] chunk
        let k_rows: Vec<f32> = (0..l * n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v_rows: Vec<f32> = (0..l * n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        bulk.append_chunk(n, &k_rows, &v_rows);
        for t in 0..n {
            for li in 0..l {
                let at = (li * n + t) * d;
                single.append(li, &k_rows[at..at + d], &v_rows[at..at + d]);
            }
        }
        assert_eq!(bulk.fill(), n);
        for (bc, sc) in bulk.caches.iter().zip(&single.caches) {
            assert_eq!(bc.stores(), sc.stores());
        }
        // and the decoded lane is bit-identical too
        let mut lk = vec![0.0f32; l * s * d];
        let mut lv = vec![0.0f32; l * s * d];
        let mut sk = vec![0.0f32; l * s * d];
        let mut sv = vec![0.0f32; l * s * d];
        bulk.sync_into(&mut lk, &mut lv);
        single.sync_into(&mut sk, &mut sv);
        assert_eq!(lk, sk);
        assert_eq!(lv, sv);
    }

    /// Backend with no native multi-token path: the engine must fall back
    /// to looping the batched step (the PJRT shape) and stay bit-identical
    /// to the unchunked schedule.
    struct LoopedSynth(SynthBackend);

    impl StepBackend for LoopedSynth {
        fn step(&mut self, t: &[i32], p: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
            self.0.step(t, p, k, v)
        }
        // default prefill_chunk -> Ok(None)
    }

    #[test]
    fn chunked_prefill_via_artifact_loop_is_bit_identical() {
        let spec = LmSpec::tiny();
        let kv = QuantPolicy::uniform(NxConfig::nxfp(4));
        let req = GenRequest { id: 0, prompt: vec![3, 7, 1, 9, 4, 2, 8], max_new: 5 };
        let run = |budget: usize, looped: bool| -> Vec<i32> {
            let backend: Box<dyn StepBackend> = if looped {
                Box::new(LoopedSynth(SynthBackend::new(&spec)))
            } else {
                Box::new(SynthBackend::new(&spec))
            };
            let mut eng = DecodeEngine::with_backend(spec.clone(), backend, &kv, 2);
            eng.set_prefill_budget(budget);
            let resps = eng.serve_wave(vec![req.clone()]).unwrap();
            resps.into_iter().next().unwrap().tokens
        };
        let want = run(1, false);
        for budget in [4usize, usize::MAX] {
            assert_eq!(run(budget, false), want, "native chunk, budget {budget}");
            assert_eq!(run(budget, true), want, "artifact loop, budget {budget}");
        }
        // two slots prefilling concurrently through the *batched* loop:
        // both lanes advance in the same inner invocations and both must
        // match their solo runs
        let req2 = GenRequest { id: 1, prompt: vec![2, 6, 1, 7, 3], max_new: 4 };
        let solo: Vec<Vec<i32>> = [&req, &req2]
            .iter()
            .map(|r| {
                let mut eng = DecodeEngine::with_backend(
                    spec.clone(),
                    Box::new(SynthBackend::new(&spec)),
                    &kv,
                    1,
                );
                eng.serve_wave(vec![(*r).clone()]).unwrap().remove(0).tokens
            })
            .collect();
        let mut eng = DecodeEngine::with_backend(
            spec.clone(),
            Box::new(LoopedSynth(SynthBackend::new(&spec))),
            &kv,
            2,
        );
        eng.set_prefill_budget(6);
        let resps = eng.serve_wave(vec![req.clone(), req2.clone()]).unwrap();
        for (r, want) in [&req, &req2].iter().zip(&solo) {
            let got = &resps.iter().find(|x| x.id == r.id).unwrap().tokens;
            assert_eq!(got, want, "batched loop diverged for request {}", r.id);
        }
    }

    #[test]
    fn budgeted_wave_takes_fewer_steps_and_same_tokens() {
        let spec = LmSpec::tiny();
        // prompt fills most of the window; decode a few tokens
        let req = GenRequest { id: 0, prompt: vec![2; 10], max_new: 4 };
        let run = |budget: usize| {
            let mut eng = DecodeEngine::with_backend(
                spec.clone(),
                Box::new(SynthBackend::new(&spec)),
                &QuantPolicy::uniform(NxConfig::nxfp(4)),
                1,
            );
            eng.set_prefill_budget(budget);
            let resps = eng.serve_wave(vec![req.clone()]).unwrap();
            (resps.into_iter().next().unwrap().tokens, eng.metrics.decode_steps)
        };
        let (tok1, steps1) = run(1);
        let (tok_inf, steps_inf) = run(usize::MAX);
        assert_eq!(tok1, tok_inf);
        // 10 prompt feeds (the 10th samples the first token) + 3 decode
        assert_eq!(steps1, 13);
        // a 9-token chunk folds the prompt into step 1's batched feed
        assert_eq!(steps_inf, 4);
    }

    #[test]
    fn wave_engine_runs_on_synth_backend() {
        let spec = LmSpec::tiny();
        let backend = Box::new(SynthBackend::new(&spec));
        let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
        let mut engine = DecodeEngine::with_backend(spec.clone(), backend, &policy, 2);
        let reqs = vec![
            GenRequest { id: 0, prompt: vec![1, 2, 3], max_new: 4 },
            GenRequest { id: 1, prompt: vec![5], max_new: 2 },
            GenRequest { id: 2, prompt: vec![], max_new: 2 }, // rejected
        ];
        // 3 reqs > max_batch 2: serve_wave splits into sequential
        // sub-waves instead of asserting (the historical panic)
        let resps = engine.serve_wave(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        let by_id = |id: u64| resps.iter().find(|r| r.id == id).unwrap();
        assert_eq!(by_id(0).generated, 4);
        assert_eq!(by_id(0).reason, FinishReason::Completed);
        assert_eq!(by_id(1).generated, 2);
        assert_eq!(by_id(2).generated, 0);
        assert_eq!(by_id(2).reason, FinishReason::Rejected);
        assert_eq!(engine.metrics.requests, 2);
        assert_eq!(engine.serving.rejected, 1);
        assert!(engine.metrics.kv_savings() > 0.5);
        // free lanes are zero after the waves drained
        let (k0, v0) = engine.lane(0);
        assert!(k0.iter().chain(v0).all(|&x| x == 0.0));
    }

    #[test]
    fn wave_transient_faults_retry_to_bit_identical_tokens() {
        let spec = LmSpec::tiny();
        let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
        let reqs = vec![
            GenRequest { id: 0, prompt: vec![1, 2, 3, 4], max_new: 5 },
            GenRequest { id: 1, prompt: vec![9, 8], max_new: 3 },
        ];
        let clean = {
            let mut eng = DecodeEngine::with_backend(
                spec.clone(),
                Box::new(SynthBackend::new(&spec)),
                &policy,
                2,
            );
            eng.serve_wave(reqs.clone()).unwrap()
        };
        let mut eng = DecodeEngine::with_backend(
            spec.clone(),
            Box::new(SynthBackend::new(&spec)),
            &policy,
            2,
        );
        eng.set_retry_policy(6, Duration::ZERO);
        let stats = eng.inject_faults(&fault::FaultPlan {
            seed: 21,
            step_error_rate: 0.3,
            nan_rate: 0.1,
            ..fault::FaultPlan::default()
        });
        let faulted = eng.serve_wave(reqs).unwrap();
        assert!(stats.borrow().step_errors > 0, "plan must actually fire");
        assert_eq!(eng.serving.step_faults, stats.borrow().step_errors);
        assert_eq!(eng.serving.nan_faults, stats.borrow().nan_steps);
        assert_eq!(eng.serving.backend_failed, 0, "rate 0.3 cannot beat 6 retries here");
        for (c, f) in clean.iter().zip(&faulted) {
            assert_eq!(c.id, f.id);
            assert_eq!(c.tokens, f.tokens, "request {} diverged under faults", c.id);
            assert_eq!(f.reason, FinishReason::Completed);
        }
    }

    #[test]
    fn wave_fault_without_retry_budget_fails_slots_not_engine() {
        // wave mode has no queue: retry budget 0 means the first
        // transient fault downgrades every occupied slot to BackendError
        // — but the engine survives and serves the next wave cleanly
        let spec = LmSpec::tiny();
        let policy = QuantPolicy::uniform(NxConfig::nxfp(4));
        let mut eng = DecodeEngine::with_backend(
            spec.clone(),
            Box::new(SynthBackend::new(&spec)),
            &policy,
            2,
        );
        eng.set_retry_policy(0, Duration::ZERO);
        eng.inject_faults(&fault::FaultPlan {
            seed: 2,
            step_error_rate: 1.0,
            ..fault::FaultPlan::default()
        });
        let req = GenRequest { id: 7, prompt: vec![1, 2], max_new: 3 };
        let resps = eng.serve_wave(vec![req.clone()]).unwrap();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].reason, FinishReason::BackendError);
        assert_eq!(eng.serving.backend_failed, 1);
        // pages released, lane zeroed
        assert_eq!(eng.page_pool().borrow().live_pages(), 0);
        let (k0, v0) = eng.lane(0);
        assert!(k0.iter().chain(v0).all(|&x| x == 0.0));
        // a fault-free engine after the storm: swap in a clean backend
        let mut clean = DecodeEngine::with_backend(
            spec.clone(),
            Box::new(SynthBackend::new(&spec)),
            &policy,
            2,
        );
        assert_eq!(clean.serve_wave(vec![req]).unwrap()[0].reason, FinishReason::Completed);
    }
}
