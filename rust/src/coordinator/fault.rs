//! Seeded fault injection for the serving tier.
//!
//! [`FaultBackend`] wraps any [`StepBackend`] and injects deterministic,
//! seeded faults from a [`FaultPlan`]: transient step errors, transient
//! `prefill_chunk` failures, non-finite logits, per-step stalls, and one
//! optional fatal error at a chosen step call. Every recovery path in the
//! engine — bounded-backoff retry, retire-and-requeue from packed KV,
//! NaN containment, fatal-fault slot failure — is testable on
//! [`super::SynthBackend`] with no artifacts, and reproducible: the same
//! plan against the same traffic injects the same faults at the same
//! call sites on every run.
//!
//! # Transient vs fatal
//!
//! Injected transient faults carry a typed [`TransientFault`] root error;
//! the engine classifies with [`is_transient`] (a `downcast_ref`, not
//! string matching). Anything else — including the plan's `fatal_at_step`
//! injection and every real backend error — is fatal: the engine does not
//! retry it, and fails the affected slots with
//! `FinishReason::BackendError` instead of killing the serve loop.
//!
//! # Determinism
//!
//! One RNG draw per fault gate per call, in a fixed order, whether or not
//! the gate fires — so the fault schedule depends only on `(seed, call
//! sequence)`. A retried call is a *new* call and draws fresh gates,
//! which is what lets a transient fault clear on retry. [`FaultStats`]
//! counts every injection; the fault-recovery tests assert the engine's
//! `ServingMetrics` fault counters equal these exactly.

use anyhow::Result;
use std::cell::RefCell;
use std::fmt;
use std::rc::Rc;
use std::time::Duration;

use crate::util::rng::Rng;

use super::{ChunkKv, StepBackend, StepOut};

/// Typed root error for injected (and, in principle, real) transient
/// backend failures — the marker [`is_transient`] classifies on.
#[derive(Debug)]
pub struct TransientFault(pub String);

impl fmt::Display for TransientFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "transient backend fault: {}", self.0)
    }
}

impl std::error::Error for TransientFault {}

/// Build a transient error (retryable by the engine).
pub fn transient(msg: impl fmt::Display) -> anyhow::Error {
    anyhow::Error::from(TransientFault(msg.to_string()))
}

/// True when the engine may retry the failed call: the error's root is a
/// [`TransientFault`]. Everything else is fatal.
pub fn is_transient(e: &anyhow::Error) -> bool {
    e.downcast_ref::<TransientFault>().is_some()
}

/// Deterministic, seeded fault schedule. All rates are probabilities per
/// backend call, drawn from one seeded stream in a fixed gate order (see
/// the module docs); `Default` injects nothing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability a `step` call fails with a [`TransientFault`] before
    /// reaching the inner backend.
    pub step_error_rate: f64,
    /// Probability a `prefill_chunk` call fails with a [`TransientFault`].
    pub chunk_error_rate: f64,
    /// Probability a successful `step` gets one lane's logits poisoned
    /// with `NaN` (the lane is drawn from the same stream).
    pub nan_rate: f64,
    /// Probability a `step` call stalls for [`FaultPlan::stall`] first.
    pub stall_rate: f64,
    /// Injected stall duration (only with `stall_rate > 0`).
    pub stall: Duration,
    /// Inject one **fatal** (non-retryable) error at exactly this `step`
    /// call (1-based count across the backend's lifetime).
    pub fatal_at_step: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            step_error_rate: 0.0,
            chunk_error_rate: 0.0,
            nan_rate: 0.0,
            stall_rate: 0.0,
            stall: Duration::ZERO,
            fatal_at_step: None,
        }
    }
}

impl FaultPlan {
    /// Transient-step-errors-only plan (the bench fault sweep's shape).
    pub fn transient_steps(seed: u64, rate: f64) -> Self {
        FaultPlan { seed, step_error_rate: rate, ..FaultPlan::default() }
    }

    /// Parse a CLI/bench spec: comma-separated `key=value` with keys
    /// `seed`, `step`, `chunk`, `nan`, `stall-rate` (probabilities in
    /// `0..=1`), `stall-us`/`stall-ms` (duration), and `fatal-at` (step
    /// call index). Example: `seed=7,step=0.05,nan=0.01,stall-ms=1`.
    pub fn parse(spec: &str) -> Result<Self> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("bad fault-plan entry {part} (want key=value)"))?;
            let rate = |v: &str| -> Result<f64> {
                v.parse::<f64>()
                    .ok()
                    .filter(|r| (0.0..=1.0).contains(r))
                    .ok_or_else(|| anyhow::anyhow!("bad fault rate {v} (want 0..=1)"))
            };
            match key.trim() {
                "seed" => plan.seed = val.parse()?,
                "step" => plan.step_error_rate = rate(val)?,
                "chunk" => plan.chunk_error_rate = rate(val)?,
                "nan" => plan.nan_rate = rate(val)?,
                "stall-rate" => plan.stall_rate = rate(val)?,
                "stall-us" => plan.stall = Duration::from_micros(val.parse()?),
                "stall-ms" => plan.stall = Duration::from_millis(val.parse()?),
                "fatal-at" => plan.fatal_at_step = Some(val.parse()?),
                other => anyhow::bail!("unknown fault-plan key {other}"),
            }
        }
        Ok(plan)
    }

    /// Does this plan inject anything at all?
    pub fn is_noop(&self) -> bool {
        self.step_error_rate == 0.0
            && self.chunk_error_rate == 0.0
            && self.nan_rate == 0.0
            && self.stall_rate == 0.0
            && self.fatal_at_step.is_none()
    }
}

/// Counts of every fault actually injected — what the engine's
/// `ServingMetrics` counters are asserted against.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Transient `step` errors injected.
    pub step_errors: u64,
    /// Transient `prefill_chunk` errors injected.
    pub chunk_errors: u64,
    /// Steps whose logits got a poisoned lane.
    pub nan_steps: u64,
    /// Steps stalled before running.
    pub stalls: u64,
    /// Fatal errors injected (0 or 1).
    pub fatal_errors: u64,
}

/// [`StepBackend`] wrapper injecting the plan's faults ahead of (or onto
/// the output of) an inner backend. Obtain a [`FaultBackend::stats`]
/// handle **before** boxing the wrapper into an engine — the handle stays
/// live and counts every injection.
pub struct FaultBackend<B> {
    inner: B,
    plan: FaultPlan,
    rng: Rng,
    stats: Rc<RefCell<FaultStats>>,
    step_calls: u64,
}

impl<B: StepBackend> FaultBackend<B> {
    pub fn new(inner: B, plan: FaultPlan) -> Self {
        let rng = Rng::seeded(plan.seed);
        FaultBackend { inner, plan, rng, stats: Rc::default(), step_calls: 0 }
    }

    /// Shared view of the injection counters (single-threaded, like the
    /// engine itself).
    pub fn stats(&self) -> Rc<RefCell<FaultStats>> {
        self.stats.clone()
    }

    /// Draw one fault gate (always consumes a draw, even at rate 0, so
    /// the schedule is a pure function of the seed and call sequence).
    fn gate(&mut self, rate: f64) -> bool {
        self.rng.f64() < rate
    }
}

impl<B: StepBackend> StepBackend for FaultBackend<B> {
    fn step(&mut self, tokens: &[i32], pos: &[i32], k: &[f32], v: &[f32]) -> Result<StepOut> {
        self.step_calls += 1;
        if self.plan.fatal_at_step == Some(self.step_calls) {
            self.stats.borrow_mut().fatal_errors += 1;
            anyhow::bail!("injected fatal backend failure (step call {})", self.step_calls);
        }
        // fixed gate order: stall, step error, nan lane (see module docs)
        let stall = self.gate(self.plan.stall_rate);
        let step_err = self.gate(self.plan.step_error_rate);
        let nan = self.gate(self.plan.nan_rate);
        let nan_lane = self.rng.below(tokens.len().max(1));
        if stall {
            self.stats.borrow_mut().stalls += 1;
            if !self.plan.stall.is_zero() {
                std::thread::sleep(self.plan.stall);
            }
        }
        if step_err {
            self.stats.borrow_mut().step_errors += 1;
            return Err(transient(format!("injected step error (call {})", self.step_calls)));
        }
        let mut out = self.inner.step(tokens, pos, k, v)?;
        if nan {
            let vb = out.logits.len() / tokens.len().max(1);
            for x in &mut out.logits[nan_lane * vb..(nan_lane + 1) * vb] {
                *x = f32::NAN;
            }
            self.stats.borrow_mut().nan_steps += 1;
        }
        Ok(out)
    }

    fn prefill_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<ChunkKv>> {
        if self.gate(self.plan.chunk_error_rate) {
            self.stats.borrow_mut().chunk_errors += 1;
            return Err(transient(format!("injected prefill_chunk error (pos0 {pos0})")));
        }
        self.inner.prefill_chunk(tokens, pos0, k_lane, v_lane)
    }

    /// Speculative verifies share the chunk fault gate (and counter):
    /// both are multi-token calls on one lane, recovered by the same
    /// retry-then-retire ladder, so the fault-recovery tests keep one
    /// `chunk_errors == serving.chunk_faults` equality across plain and
    /// speculative serving.
    fn verify_chunk(
        &mut self,
        tokens: &[i32],
        pos0: usize,
        k_lane: &[f32],
        v_lane: &[f32],
    ) -> Result<Option<super::VerifyOut>> {
        if self.gate(self.plan.chunk_error_rate) {
            self.stats.borrow_mut().chunk_errors += 1;
            return Err(transient(format!("injected verify_chunk error (pos0 {pos0})")));
        }
        self.inner.verify_chunk(tokens, pos0, k_lane, v_lane)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::LmSpec;

    #[test]
    fn transient_classification_by_type_not_message() {
        let t = transient("flaky link");
        assert!(is_transient(&t));
        assert!(format!("{t:#}").contains("flaky link"));
        let fatal = anyhow::anyhow!("transient-sounding but untyped");
        assert!(!is_transient(&fatal));
    }

    #[test]
    fn plan_parses_and_rejects_junk() {
        let p = FaultPlan::parse("seed=7,step=0.05,chunk=0.5,nan=0.01,stall-ms=2,stall-rate=1")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.step_error_rate, 0.05);
        assert_eq!(p.chunk_error_rate, 0.5);
        assert_eq!(p.nan_rate, 0.01);
        assert_eq!(p.stall, Duration::from_millis(2));
        assert_eq!(p.stall_rate, 1.0);
        assert!(!p.is_noop());
        assert_eq!(FaultPlan::parse("fatal-at=9").unwrap().fatal_at_step, Some(9));
        assert!(FaultPlan::parse("").unwrap().is_noop());
        assert!(FaultPlan::parse("step=1.5").is_err()); // rate out of range
        assert!(FaultPlan::parse("bogus=1").is_err());
        assert!(FaultPlan::parse("step").is_err());
    }

    #[test]
    fn injection_is_deterministic_and_counted() {
        let spec = LmSpec::tiny();
        let run = || {
            let mut be = FaultBackend::new(
                super::super::SynthBackend::new(&spec),
                FaultPlan { seed: 11, step_error_rate: 0.5, ..FaultPlan::default() },
            );
            let stats = be.stats();
            let lane = spec.n_layers * spec.seq_len * spec.d_model;
            let (k, v) = (vec![0.0f32; lane], vec![0.0f32; lane]);
            let outcomes: Vec<bool> =
                (0..32).map(|i| be.step(&[i], &[0], &k, &v).is_ok()).collect();
            (outcomes, *stats.borrow())
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b, "same seed must fault the same calls");
        assert_eq!(sa, sb);
        assert_eq!(sa.step_errors, a.iter().filter(|ok| !**ok).count() as u64);
        assert!(sa.step_errors > 0 && sa.step_errors < 32, "rate 0.5 over 32 calls");
    }

    #[test]
    fn nan_injection_poisons_exactly_one_lane() {
        let spec = LmSpec::tiny();
        let mut be = FaultBackend::new(
            super::super::SynthBackend::new(&spec),
            FaultPlan { seed: 3, nan_rate: 1.0, ..FaultPlan::default() },
        );
        let stats = be.stats();
        let lane = spec.n_layers * spec.seq_len * spec.d_model;
        let (k, v) = (vec![0.0f32; 2 * lane], vec![0.0f32; 2 * lane]);
        let out = be.step(&[3, 5], &[0, 0], &k, &v).unwrap();
        let vb = spec.vocab;
        let poisoned = (0..2)
            .filter(|b| out.logits[b * vb..(b + 1) * vb].iter().any(|x| !x.is_finite()))
            .count();
        assert_eq!(poisoned, 1);
        assert_eq!(stats.borrow().nan_steps, 1);
        // KV rows stay clean: only logits are poisoned
        assert!(out.k_new.iter().chain(&out.v_new).all(|x| x.is_finite()));
    }

    #[test]
    fn fatal_at_step_fires_once_and_is_not_transient() {
        let spec = LmSpec::tiny();
        let mut be = FaultBackend::new(
            super::super::SynthBackend::new(&spec),
            FaultPlan { seed: 1, fatal_at_step: Some(2), ..FaultPlan::default() },
        );
        let stats = be.stats();
        let lane = spec.n_layers * spec.seq_len * spec.d_model;
        let (k, v) = (vec![0.0f32; lane], vec![0.0f32; lane]);
        assert!(be.step(&[1], &[0], &k, &v).is_ok());
        let err = be.step(&[1], &[1], &k, &v).unwrap_err();
        assert!(!is_transient(&err));
        assert!(be.step(&[1], &[2], &k, &v).is_ok(), "fatal injection fires exactly once");
        assert_eq!(stats.borrow().fatal_errors, 1);
    }

    #[test]
    fn chunk_errors_gate_independently() {
        let spec = LmSpec::tiny();
        let mut be = FaultBackend::new(
            super::super::SynthBackend::new(&spec),
            FaultPlan { seed: 5, chunk_error_rate: 1.0, ..FaultPlan::default() },
        );
        let stats = be.stats();
        let lane = spec.n_layers * spec.seq_len * spec.d_model;
        let (k, v) = (vec![0.0f32; lane], vec![0.0f32; lane]);
        let err = be.prefill_chunk(&[1, 2], 0, &k, &v).unwrap_err();
        assert!(is_transient(&err));
        assert_eq!(stats.borrow().chunk_errors, 1);
        // step path unaffected by the chunk gate
        assert!(be.step(&[1], &[0], &k, &v).is_ok());
    }
}
