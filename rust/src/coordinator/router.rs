//! Multi-replica serving tier: a [`Router`] fronting N decode-engine
//! replicas, each a [`ServerHandle`] worker thread over its own engine
//! (engines hold `Rc<RefCell<PagePool>>` and are not Send, so nothing is
//! shared — every replica owns its pool, scheduler, and prefix cache).
//!
//! Dispatch is queue-depth/TTFT-aware with **prefix-affinity routing**:
//! the router keeps its own radix tree over previously dispatched
//! prompts (the same longest-registered-prefix lookup the per-replica
//! `PrefixCache` uses, but entries are replica indices, not page refs),
//! so requests sharing a system prompt land on the replica that already
//! holds those pages and adopt them via its prefix cache. Affinity
//! yields to least-loaded when the affine replica's outstanding depth
//! runs `slack` past the least-loaded one — a queue-depth bound on the
//! TTFT a sticky route can cost — or when the replica is draining/dead.
//!
//! Replica lifecycle is first-class:
//!
//! * [`FleetHandle::drain_replica`] gracefully drains one replica
//!   mid-traffic: the router stops routing there immediately, the
//!   replica finishes its backlog, and dispatches racing the drain come
//!   back `Shed` and are transparently re-dispatched to a survivor.
//! * [`FleetHandle::kill_replica`] abruptly stops one: every accepted
//!   request it never answered comes back through
//!   [`ServeReport::unserved`] and is replayed **from the prompt** on
//!   survivors — bit-identical to a clean run by the same argument as
//!   single-engine requeue-replay (per-slot purity + deterministic
//!   quantization), so `lost_requests == 0` holds through a kill.
//!
//! At shutdown per-replica [`ServeReport`]s roll up into a
//! [`FleetReport`]: counter sums are exact, histograms merge via
//! `Histogram::merge`, and geometry mismatches surface as strings in
//! [`FleetReport::merge_errors`] rather than a panic mid-report.

use anyhow::Result;
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::metrics::ServingMetrics;
use super::server::{ServeOpts, ServeReport, ServerHandle};
use super::{FinishReason, GenRequest, GenResponse, Metrics};
use crate::formats::QuantPolicy;
use crate::models::LmSpec;

/// Cap on affinity-tree nodes: registration stops at the cap (lookups
/// keep working), so a pathological prompt stream degrades affinity to
/// least-loaded routing instead of growing the tree without bound.
const MAX_AFF_NODES: usize = 4096;

/// One radix node of the affinity tree. First tokens of sibling edges
/// are distinct, so a lookup never backtracks.
struct AffNode {
    edge: Vec<i32>,
    /// Replica that first dispatched a prompt through this node — the
    /// "owner" of the prefix (its prefix cache holds the pages).
    replica: usize,
    children: Vec<usize>,
}

/// Deterministic dispatch policy over N replicas. Pure bookkeeping — no
/// threads, no channels — so routing decisions are unit-testable and
/// replayable: the same submit/complete sequence always produces the
/// same routes.
pub struct Router {
    /// `nodes[0]` is a sentinel root with an empty edge.
    nodes: Vec<AffNode>,
    /// Requests dispatched to each replica and not yet completed.
    outstanding: Vec<usize>,
    /// Routable = accepting new work (not draining, not dead).
    routable: Vec<bool>,
    min_affinity: usize,
    slack: usize,
    /// Per-replica steering tallies (see [`SteeringStats`]).
    steering: Vec<SteeringStats>,
}

/// How often prefix affinity actually changed a routing decision for one
/// replica — the counters that tell whether stickiness is earning its
/// keep (read them next to that replica's prefix hit rate).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SteeringStats {
    /// Dispatches steered to this replica by affinity when least-loaded
    /// would have picked a different one. Affine routes that agree with
    /// least-loaded are not overrides — stickiness changed nothing.
    pub overrides: u64,
    /// Dispatches whose affinity owner was this replica but fell through
    /// to least-loaded (owner draining/dead or `slack` exceeded).
    pub spills: u64,
}

impl Router {
    /// Shortest shared prefix (tokens) that makes affinity worth a
    /// sticky route; shorter matches fall through to least-loaded.
    pub const DEFAULT_MIN_AFFINITY: usize = 8;

    /// `slack` bounds how far past the least-loaded replica an affine
    /// route may stack work (one batch of lanes is the natural unit:
    /// the affine replica can be a full batch deeper before stickiness
    /// starts costing admission latency).
    pub fn new(n_replicas: usize, slack: usize) -> Router {
        assert!(n_replicas > 0, "router needs at least one replica");
        Router {
            nodes: vec![AffNode { edge: Vec::new(), replica: usize::MAX, children: Vec::new() }],
            outstanding: vec![0; n_replicas],
            routable: vec![true; n_replicas],
            min_affinity: Self::DEFAULT_MIN_AFFINITY,
            slack: slack.max(1),
            steering: vec![SteeringStats::default(); n_replicas],
        }
    }

    pub fn set_min_affinity(&mut self, tokens: usize) {
        self.min_affinity = tokens.max(1);
    }

    pub fn n_replicas(&self) -> usize {
        self.outstanding.len()
    }

    pub fn is_routable(&self, replica: usize) -> bool {
        self.routable[replica]
    }

    /// Mark a replica draining/dead (`false`): the router stops routing
    /// new work there, existing affinity entries fall through.
    pub fn set_routable(&mut self, replica: usize, on: bool) {
        self.routable[replica] = on;
    }

    /// Pick a replica for `prompt` and charge it one outstanding
    /// request: the affinity owner of the longest registered prefix
    /// (when routable and within `slack` of least-loaded), else the
    /// least-loaded routable replica (ties break to the lowest index).
    pub fn route(&mut self, prompt: &[i32]) -> usize {
        let least = self.least_loaded();
        let choice = match self.affinity(prompt) {
            Some(r)
                if self.routable[r]
                    && self.outstanding[r] < self.outstanding[least] + self.slack =>
            {
                if r != least {
                    self.steering[r].overrides += 1;
                }
                r
            }
            Some(r) => {
                // affine owner exists but lost: charge the spill to the
                // owner so drains show up on the replica they cost
                self.steering[r].spills += 1;
                least
            }
            None => least,
        };
        self.outstanding[choice] += 1;
        self.register(prompt, choice);
        choice
    }

    /// Per-replica steering tallies, index-aligned with replicas.
    pub fn steering(&self) -> &[SteeringStats] {
        &self.steering
    }

    /// A request previously charged to `replica` finished (or was taken
    /// back for re-dispatch).
    pub fn complete(&mut self, replica: usize) {
        self.outstanding[replica] = self.outstanding[replica].saturating_sub(1);
    }

    pub fn outstanding(&self, replica: usize) -> usize {
        self.outstanding[replica]
    }

    /// Least-loaded routable replica; if none is routable (the whole
    /// fleet is draining), fall back to the global minimum so `route`
    /// still returns an index — the submit path surfaces the failure.
    fn least_loaded(&self) -> usize {
        let pick = |routable_only: bool| {
            self.outstanding
                .iter()
                .enumerate()
                .filter(|(i, _)| !routable_only || self.routable[*i])
                .min_by_key(|(i, &o)| (o, *i))
                .map(|(i, _)| i)
        };
        pick(true).or_else(|| pick(false)).expect("n_replicas > 0")
    }

    /// Longest-registered-prefix owner, if the match is at least
    /// `min_affinity` tokens.
    fn affinity(&self, prompt: &[i32]) -> Option<usize> {
        let mut cur = 0usize;
        let mut depth = 0usize;
        let mut best: Option<(usize, usize)> = None;
        loop {
            let rem = &prompt[depth..];
            let mut advanced = false;
            for &c in &self.nodes[cur].children {
                let edge = &self.nodes[c].edge;
                let m = edge.iter().zip(rem.iter()).take_while(|(a, b)| a == b).count();
                if m == 0 {
                    continue;
                }
                best = Some((depth + m, self.nodes[c].replica));
                if m == edge.len() && m < rem.len() {
                    cur = c;
                    depth += m;
                    advanced = true;
                }
                break; // sibling edges have distinct first tokens
            }
            if !advanced {
                break;
            }
        }
        best.filter(|(matched, _)| *matched >= self.min_affinity).map(|(_, r)| r)
    }

    /// Record that `replica` now holds `prompt`'s pages. Nodes created
    /// by a split inherit the deeper node's replica, so the **first**
    /// dispatcher of a prefix stays its affinity owner even when a
    /// later overflow route sends a sibling suffix elsewhere.
    fn register(&mut self, prompt: &[i32], replica: usize) {
        if self.nodes.len() >= MAX_AFF_NODES {
            return;
        }
        let mut cur = 0usize;
        let mut depth = 0usize;
        loop {
            if depth == prompt.len() {
                return; // fully covered by existing nodes
            }
            let rem = &prompt[depth..];
            let mut hit: Option<(usize, usize)> = None;
            for &c in &self.nodes[cur].children {
                let edge = &self.nodes[c].edge;
                let m = edge.iter().zip(rem.iter()).take_while(|(a, b)| a == b).count();
                if m > 0 {
                    hit = Some((c, m));
                    break;
                }
            }
            match hit {
                None => {
                    let leaf =
                        AffNode { edge: rem.to_vec(), replica, children: Vec::new() };
                    self.nodes.push(leaf);
                    let id = self.nodes.len() - 1;
                    self.nodes[cur].children.push(id);
                    return;
                }
                Some((c, m)) if m == self.nodes[c].edge.len() => {
                    cur = c;
                    depth += m;
                }
                Some((c, m)) => {
                    // split c's edge at m: mid keeps the shared head and
                    // c's owner, c keeps the tail
                    let tail = self.nodes[c].edge.split_off(m);
                    let head = std::mem::replace(&mut self.nodes[c].edge, tail);
                    let owner = self.nodes[c].replica;
                    self.nodes.push(AffNode { edge: head, replica: owner, children: vec![c] });
                    let mid = self.nodes.len() - 1;
                    let pos = self.nodes[cur]
                        .children
                        .iter()
                        .position(|&x| x == c)
                        .expect("child listed under its parent");
                    self.nodes[cur].children[pos] = mid;
                    cur = mid;
                    depth += m;
                }
            }
        }
    }
}

/// Fleet-level final accounting: per-replica reports plus the rollup.
pub struct FleetReport {
    /// Per-replica accounting, index-aligned with spawn order.
    pub replicas: Vec<ServeReport>,
    /// Exact sums of every replica's engine counters (`wall` sums
    /// per-replica stepping time, not fleet wall-clock — replicas step
    /// concurrently).
    pub metrics: Metrics,
    /// Serving rollup: counters summed exactly, histograms merged
    /// bucket-wise via `Histogram::merge`.
    pub serving: ServingMetrics,
    /// Histogram geometry mismatches hit during the rollup, one string
    /// per affected replica — surfaced here instead of panicking;
    /// counter sums above are exact regardless.
    pub merge_errors: Vec<String>,
    /// Requests replayed onto a survivor after a drain or kill.
    pub redispatched: u64,
    /// Per-replica prefix-steering tallies from the router, index-aligned
    /// with `replicas`. Also folded into each replica's serving counters
    /// (`affinity_overrides` / `affinity_spills`) before the rollup, so
    /// the metrics exporters carry them under the existing `replica`
    /// labels.
    pub steering: Vec<SteeringStats>,
}

/// Handle to a running fleet: N replica workers, one forwarder thread
/// per replica funneling responses into a single stream, and the
/// [`Router`] deciding placement.
pub struct FleetHandle {
    replicas: Vec<Option<ServerHandle>>,
    router: Router,
    rx: mpsc::Receiver<(usize, GenResponse)>,
    forwarders: Vec<JoinHandle<()>>,
    /// Accepted requests not yet answered: id → (request, owner). The
    /// request copy is what a kill/drain replays on a survivor.
    inflight: HashMap<u64, (GenRequest, usize)>,
    reports: Vec<Option<ServeReport>>,
    redispatched: u64,
}

impl FleetHandle {
    /// Spawn `n_replicas` artifact-free workers over the deterministic
    /// `SynthBackend` (one engine per thread; nothing shared). Per-file
    /// observability paths in `opts` are suffixed `.rN` per replica so
    /// the exports don't clobber each other.
    pub fn spawn(n_replicas: usize, spec: LmSpec, kv: QuantPolicy, opts: ServeOpts) -> FleetHandle {
        assert!(n_replicas > 0, "fleet needs at least one replica");
        let handles = (0..n_replicas)
            .map(|i| {
                let mut o = opts.clone();
                o.trace_out = o.trace_out.map(|p| replica_path(&p, i));
                o.metrics_out = o.metrics_out.map(|p| replica_path(&p, i));
                ServerHandle::spawn_synth(spec, kv.clone(), o)
            })
            .collect();
        Self::from_handles(handles, opts.max_batch)
    }

    /// Assemble a fleet from already-spawned workers (the PJRT path
    /// builds each replica's runtime itself). Handles must still own
    /// their response streams (`take_rx` not called).
    pub fn from_handles(mut handles: Vec<ServerHandle>, max_batch: usize) -> FleetHandle {
        assert!(!handles.is_empty(), "fleet needs at least one replica");
        let n = handles.len();
        let (agg_tx, rx) = mpsc::channel::<(usize, GenResponse)>();
        let mut forwarders = Vec::with_capacity(n);
        for (i, h) in handles.iter_mut().enumerate() {
            let hrx = h.take_rx().expect("fleet replica handle already had its rx taken");
            let tx = agg_tx.clone();
            forwarders.push(std::thread::spawn(move || {
                // exits when the worker drops its sender (drain/kill/
                // shutdown) or the fleet drops the aggregate receiver
                while let Ok(resp) = hrx.recv() {
                    if tx.send((i, resp)).is_err() {
                        break;
                    }
                }
            }));
        }
        FleetHandle {
            replicas: handles.into_iter().map(Some).collect(),
            router: Router::new(n, max_batch),
            rx,
            forwarders,
            inflight: HashMap::new(),
            reports: (0..n).map(|_| None).collect(),
            redispatched: 0,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Requests replayed onto survivors so far.
    pub fn redispatched(&self) -> u64 {
        self.redispatched
    }

    /// Route and submit one request (ids must be unique fleet-wide).
    /// Returns `false` only when no live replica accepted it.
    pub fn submit(&mut self, req: GenRequest) -> bool {
        for _ in 0..self.replicas.len() {
            let r = self.router.route(&req.prompt);
            match self.replicas[r].as_ref() {
                Some(h) if h.submit(req.clone()) => {
                    self.inflight.insert(req.id, (req, r));
                    return true;
                }
                _ => {
                    // worker gone underneath us: uncharge the route,
                    // stop routing there, try the next-best replica
                    self.router.complete(r);
                    self.router.set_routable(r, false);
                }
            }
        }
        false
    }

    /// Next completed response from any replica (blocking). A `Shed`
    /// from a replica the router already stopped routing to (draining
    /// or killed) means the dispatch raced the lifecycle event — the
    /// fleet still owns the request, so it is replayed on a survivor
    /// instead of surfacing. Capacity sheds from healthy replicas pass
    /// through: that is client-visible backpressure.
    pub fn recv(&mut self) -> Option<GenResponse> {
        loop {
            let (i, resp) = self.rx.recv().ok()?;
            match self.inflight.get(&resp.id) {
                Some((req, owner))
                    if *owner == i
                        && resp.reason == FinishReason::Shed
                        && !self.router.is_routable(i) =>
                {
                    let req = req.clone();
                    self.router.complete(i);
                    self.inflight.remove(&resp.id);
                    self.redispatched += 1;
                    if self.submit(req) {
                        continue;
                    }
                    // no survivor left: surface the shed rather than drop
                    return Some(resp);
                }
                Some((_, owner)) if *owner == i => {
                    self.router.complete(i);
                    self.inflight.remove(&resp.id);
                    return Some(resp);
                }
                // stale or unknown: the request was already re-homed
                // (response no longer owed by this replica) — skip
                _ => continue,
            }
        }
    }

    pub fn recv_timeout(&mut self, d: Duration) -> Option<GenResponse> {
        // one bounded wait, then drain through the same ownership logic
        let deadline = std::time::Instant::now() + d;
        loop {
            let left = deadline.saturating_duration_since(std::time::Instant::now());
            let (i, resp) = self.rx.recv_timeout(left).ok()?;
            match self.inflight.get(&resp.id) {
                Some((req, owner))
                    if *owner == i
                        && resp.reason == FinishReason::Shed
                        && !self.router.is_routable(i) =>
                {
                    let req = req.clone();
                    self.router.complete(i);
                    self.inflight.remove(&resp.id);
                    self.redispatched += 1;
                    if self.submit(req) {
                        continue;
                    }
                    return Some(resp);
                }
                Some((_, owner)) if *owner == i => {
                    self.router.complete(i);
                    self.inflight.remove(&resp.id);
                    return Some(resp);
                }
                _ => continue,
            }
        }
    }

    /// Gracefully drain replica `i` mid-traffic: the router stops
    /// routing there immediately and the replica finishes its backlog.
    /// Dispatches racing the drain come back `Shed` and are replayed on
    /// survivors by `recv`. The replica's report is collected at
    /// [`Self::shutdown`].
    pub fn drain_replica(&mut self, i: usize) {
        self.router.set_routable(i, false);
        if let Some(h) = &self.replicas[i] {
            h.begin_drain();
        }
    }

    /// Abruptly kill replica `i` and replay every request it accepted
    /// but never answered onto survivors, from the prompt (bit-identical
    /// replay). Returns how many requests were re-dispatched. Responses
    /// the replica already produced are still delivered by `recv`.
    pub fn kill_replica(&mut self, i: usize) -> Result<usize> {
        self.router.set_routable(i, false);
        let Some(mut h) = self.replicas[i].take() else {
            anyhow::bail!("replica {i} already stopped");
        };
        let report = h.kill()?;
        let unserved = report.unserved.clone();
        self.reports[i] = Some(report);
        let mut moved = 0usize;
        for req in unserved {
            // the dead replica's outstanding charge goes with it
            self.router.complete(i);
            self.inflight.remove(&req.id);
            self.redispatched += 1;
            moved += 1;
            if !self.submit(req) {
                anyhow::bail!("no surviving replica accepted a re-dispatched request");
            }
        }
        Ok(moved)
    }

    /// Finish outstanding work on every remaining replica and build the
    /// fleet rollup. Buffered responses stay receivable afterwards
    /// (callers normally `recv` everything first). A second call errors.
    pub fn shutdown(&mut self) -> Result<FleetReport> {
        if self.replicas.iter().all(Option::is_none) && self.reports.iter().all(Option::is_none) {
            anyhow::bail!("fleet already shut down");
        }
        for (i, slot) in self.replicas.iter_mut().enumerate() {
            if let Some(mut h) = slot.take() {
                self.reports[i] = Some(h.shutdown()?);
            }
        }
        // every worker sender is dropped now, so forwarders drain and exit
        for f in self.forwarders.drain(..) {
            let _ = f.join();
        }
        let mut replicas: Vec<ServeReport> = self
            .reports
            .iter_mut()
            .map(|r| r.take().expect("every replica produced a report"))
            .collect();
        // fold the router's steering tallies into each replica's serving
        // counters so the rollup and the replica-labeled exports see them
        for (rep, st) in replicas.iter_mut().zip(self.router.steering()) {
            rep.serving.affinity_overrides = st.overrides;
            rep.serving.affinity_spills = st.spills;
        }
        let mut metrics = Metrics::default();
        let mut serving = ServingMetrics::default();
        let mut merge_errors = Vec::new();
        for (i, rep) in replicas.iter().enumerate() {
            metrics.merge(&rep.metrics);
            if let Err(e) = serving.merge(&rep.serving) {
                merge_errors.push(format!("replica {i}: {e:#}"));
            }
        }
        let steering = self.router.steering().to_vec();
        Ok(FleetReport {
            replicas,
            metrics,
            serving,
            merge_errors,
            redispatched: self.redispatched,
            steering,
        })
    }
}

/// `metrics.json` → `metrics.r3.json`; extensionless paths get `.r3`
/// appended. Keeps per-replica observability exports from clobbering
/// each other when one `ServeOpts` fans out to N workers (the CLI uses
/// it for the PJRT fleet path too).
pub fn replica_path(path: &std::path::Path, i: usize) -> std::path::PathBuf {
    match path.extension().and_then(|e| e.to_str()) {
        Some(ext) => path.with_extension(format!("r{i}.{ext}")),
        None => path.with_extension(format!("r{i}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn least_loaded_breaks_ties_low_and_skips_unroutable() {
        let mut r = Router::new(3, 4);
        // empty tree: everything falls through to least-loaded
        assert_eq!(r.route(&[1, 2, 3]), 0);
        assert_eq!(r.route(&[4, 5, 6]), 1);
        assert_eq!(r.route(&[7, 8, 9]), 2);
        r.complete(1);
        assert_eq!(r.route(&[10, 11]), 1);
        r.set_routable(1, false);
        r.complete(0);
        r.complete(2);
        // 1 is now least-loaded but unroutable
        assert_eq!(r.route(&[12, 13]), 0);
    }

    #[test]
    fn affinity_sticks_within_slack_then_spills() {
        let mut r = Router::new(2, 2);
        r.set_min_affinity(4);
        let sys: Vec<i32> = (100..112).collect();
        let with_suffix = |s: i32| {
            let mut p = sys.clone();
            p.push(s);
            p
        };
        assert_eq!(r.route(&with_suffix(1)), 0);
        // shared 12-token prefix ≥ min_affinity: sticks to 0 while
        // outstanding(0) < outstanding(least) + slack (1 < 0 + 2)
        assert_eq!(r.route(&with_suffix(2)), 0);
        // now 0 is a full slack (2) ahead of empty replica 1: spill
        assert_eq!(r.route(&with_suffix(3)), 1);
        assert_eq!(r.outstanding(0), 2);
        assert_eq!(r.outstanding(1), 1);
        // drain replica 0's backlog: affinity resumes (owner stayed 0)
        r.complete(0);
        r.complete(0);
        assert_eq!(r.route(&with_suffix(4)), 0);
        // short shared prefix stays least-loaded (below min_affinity)
        let mut s = Router::new(2, 2);
        s.set_min_affinity(4);
        assert_eq!(s.route(&[5, 6]), 0);
        assert_eq!(s.route(&[5, 7]), 1, "2-token match is below min_affinity");
    }

    #[test]
    fn steering_counters_split_overrides_from_spills() {
        let mut r = Router::new(2, 2);
        r.set_min_affinity(4);
        let sys: Vec<i32> = (100..112).collect();
        let with_suffix = |s: i32| {
            let mut p = sys.clone();
            p.push(s);
            p
        };
        // first dispatch: no affinity yet, nothing steered
        assert_eq!(r.route(&with_suffix(1)), 0);
        assert_eq!(r.steering()[0], SteeringStats::default());
        // affine route that disagrees with least-loaded (1 is emptier)
        assert_eq!(r.route(&with_suffix(2)), 0);
        assert_eq!(r.steering()[0].overrides, 1);
        // slack exceeded: the owner is charged a spill, 1 takes the work
        assert_eq!(r.route(&with_suffix(3)), 1);
        assert_eq!(r.steering()[0], SteeringStats { overrides: 1, spills: 1 });
        assert_eq!(r.steering()[1], SteeringStats::default());
        // draining the owner also counts as a spill on the owner
        r.complete(0);
        r.complete(0);
        r.set_routable(0, false);
        assert_eq!(r.route(&with_suffix(4)), 1);
        assert_eq!(r.steering()[0].spills, 2);
        // an affine route that matches least-loaded is not an override
        let mut q = Router::new(2, 4);
        q.set_min_affinity(4);
        assert_eq!(q.route(&sys), 0);
        q.complete(0);
        assert_eq!(q.route(&sys), 0, "affine and least-loaded agree");
        assert_eq!(q.steering()[0], SteeringStats::default());
    }

    #[test]
    fn affinity_owner_survives_edge_splits() {
        let mut r = Router::new(3, 8);
        r.set_min_affinity(4);
        let a: Vec<i32> = vec![1, 2, 3, 4, 5, 6];
        assert_eq!(r.route(&a), 0);
        // same 4-token head, diverging tail: split keeps owner 0
        let b: Vec<i32> = vec![1, 2, 3, 4, 9, 9];
        assert_eq!(r.route(&b), 0);
        // force a spill by loading 0 past slack... instead just verify
        // the mid node's owner directly via another lookup after the
        // split: a third suffix still routes to 0
        let c: Vec<i32> = vec![1, 2, 3, 4, 7];
        assert_eq!(r.route(&c), 0);
    }

    #[test]
    fn unroutable_affinity_falls_through_to_least_loaded() {
        let mut r = Router::new(2, 4);
        r.set_min_affinity(4);
        let p: Vec<i32> = vec![3, 1, 4, 1, 5, 9];
        assert_eq!(r.route(&p), 0);
        r.set_routable(0, false);
        assert_eq!(r.route(&p), 1, "affinity owner is draining: reroute");
    }

    #[test]
    fn dispatch_is_deterministic_for_a_seeded_arrival_order() {
        // same arrival sequence → identical route decisions, twice over
        let mk = || {
            let mut r = Router::new(4, 4);
            r.set_min_affinity(6);
            r
        };
        // seeded xorshift keeps the sequence reproducible without rand
        let mut x = 0x9e3779b9u32;
        let mut step = move || {
            x ^= x << 13;
            x ^= x >> 17;
            x ^= x << 5;
            x
        };
        let sys: [Vec<i32>; 3] = [
            (10..22).collect(),
            (30..44).collect(),
            (50..58).collect(),
        ];
        let mut prompts = Vec::new();
        for _ in 0..64 {
            let s = &sys[(step() % 3) as usize];
            let mut p = s.clone();
            p.push((step() % 97) as i32);
            prompts.push(p);
        }
        let mut r1 = mk();
        let mut r2 = mk();
        let routes1: Vec<usize> = prompts.iter().map(|p| r1.route(p)).collect();
        let routes2: Vec<usize> = prompts.iter().map(|p| r2.route(p)).collect();
        assert_eq!(routes1, routes2);
        // and the policy did something: affinity grouped each system
        // prompt onto few replicas rather than spraying uniformly
        assert!(routes1.iter().any(|&r| r != routes1[0]) || prompts.len() < 2);
    }

    #[test]
    fn replica_path_suffixes_before_extension() {
        use std::path::Path;
        assert_eq!(replica_path(Path::new("m.json"), 2), Path::new("m.r2.json"));
        assert_eq!(replica_path(Path::new("out/trace.jsonl"), 0), Path::new("out/trace.r0.jsonl"));
        assert_eq!(replica_path(Path::new("prom"), 1), Path::new("prom.r1"));
    }
}
