//! On-the-fly dequantization — the deployment hot path (paper §6, Fig. 7).
//!
//! The six decode steps collapse into two table lookups and one FMA per
//! element: the per-code scaled-domain value (steps ①–③: slice fields,
//! remap the recycled code, apply sign) is precomputed into a 2^bits LUT per
//! format path, and the block scale (step ④: shared exponent + NanoMantissa,
//! step ⑤ padding is free in f32) multiplies the looked-up value (step ⑥
//! feeds the MAC). `gemv_packed` fuses the decode into a dot product so
//! weights stream from packed DRAM form straight into FLOPs, which is how
//! the paper deploys on off-the-shelf hardware. `gemm_packed` is the
//! batched multi-RHS sibling (gemv is its 1-column case): it unpacks each
//! row's codes once for all RHS columns, tiles the RHS columns for cache
//! locality, and parallelizes over row stripes — or over column tiles when
//! the row count can't feed every core (large-batch decode of a short
//! weight matrix).
//!
//! Code unpacking is branchless: one unaligned 8-byte little-endian load
//! yields a whole run of codes by shift+mask regardless of the bit phase,
//! so the 5/6-bit payloads (which almost never sit on byte boundaries) cost
//! the same per code as the 4-bit path instead of a bit-by-bit
//! `BitReader`-style loop.

use crate::formats::packed::{BitReader, PackedMatrix, E8M0_BIAS};
use crate::formats::{FormatTables, NxConfig};
use crate::tensor::Tensor2;
use crate::util::exp2i;

/// Precomputed signed decode tables for both adaptive paths.
#[derive(Clone, Debug)]
pub struct DequantLut {
    pub bits: u8,
    /// `mx[code]` = scaled-domain value for the minifloat path.
    pub mx: Vec<f32>,
    /// `bfp[code]` = scaled-domain value for the all-mantissa path.
    pub bfp: Vec<f32>,
    pub offset_mx: i32,
    pub offset_bfp: i32,
}

impl DequantLut {
    pub fn new(cfg: &NxConfig) -> Self {
        let tabs = cfg.tables();
        Self::from_tables(cfg.bits, &tabs)
    }

    pub fn from_tables(bits: u8, tabs: &FormatTables) -> Self {
        let n = 1usize << bits;
        let mx = (0..n).map(|c| tabs.mx.decode(c as u8)).collect();
        let bfp = (0..n).map(|c| tabs.bfp.decode(c as u8)).collect();
        DequantLut {
            bits,
            mx,
            bfp,
            offset_mx: tabs.mx.offset,
            offset_bfp: tabs.bfp.offset,
        }
    }

    #[inline]
    pub fn table(&self, fmt_mx: bool) -> (&[f32], i32) {
        if fmt_mx {
            (&self.mx, self.offset_mx)
        } else {
            (&self.bfp, self.offset_bfp)
        }
    }
}

/// Decode one block's packed metadata into `(scale_mx_or_bfp, fmt_mx)`.
#[inline]
fn block_scale(lut: &DequantLut, e_biased: u8, nano: u8, fmt_mx: bool) -> f32 {
    let e = e_biased as i32 - E8M0_BIAS;
    let offset = if fmt_mx { lut.offset_mx } else { lut.offset_bfp };
    (1.0 + nano as f32 / 4.0) * exp2i(e + offset)
}

/// Unpack `out.len()` consecutive `bits`-wide codes starting at `start_bit`
/// (LSB-first bit stream, bits ≤ 8). Three paths, fastest first:
///
/// * bits=4, byte-aligned, even count — two codes per byte, no shifts;
/// * **u64 window** — one unaligned 8-byte load yields
///   `per = (64-7)/bits` codes by shift+mask for *any* bit phase
///   (`off ≤ 7` so `off + per·bits ≤ 64` always holds). This is what makes
///   the 5/6-bit payloads branch-free even though their blocks almost never
///   start on byte boundaries;
/// * scalar two-byte-window tail for the last few codes (or when the
///   payload has fewer than 8 bytes left to load).
#[inline]
fn unpack_codes(payload: &[u8], start_bit: usize, bits: u32, out: &mut [u8]) {
    debug_assert!((1..=8).contains(&bits));
    // 4-bit byte-aligned fast path (the common case: k even, bits=4 —
    // every block starts on a byte boundary): two codes per byte.
    if bits == 4 && start_bit & 7 == 0 && out.len() & 1 == 0 {
        let base = start_bit >> 3;
        for (i, pair) in out.chunks_exact_mut(2).enumerate() {
            let b = payload[base + i];
            pair[0] = b & 0x0f;
            pair[1] = b >> 4;
        }
        return;
    }
    let mask64 = (1u64 << bits) - 1;
    let per = ((64 - 7) / bits) as usize;
    let mut bitpos = start_bit;
    let mut i = 0usize;
    while i + per <= out.len() && (bitpos >> 3) + 8 <= payload.len() {
        let byte = bitpos >> 3;
        let w = u64::from_le_bytes(payload[byte..byte + 8].try_into().unwrap()) >> (bitpos & 7);
        for (j, o) in out[i..i + per].iter_mut().enumerate() {
            *o = ((w >> (j as u32 * bits)) & mask64) as u8;
        }
        i += per;
        bitpos += per * bits as usize;
    }
    let mask = ((1u16 << bits) - 1) as u16;
    for o in out[i..].iter_mut() {
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u16;
        let lo = payload[byte] as u16;
        let hi = *payload.get(byte + 1).unwrap_or(&0) as u16;
        *o = (((lo | (hi << 8)) >> off) & mask) as u8;
        bitpos += bits as usize;
    }
}

/// Dequantize a full packed matrix into an f32 tensor (LUT hot path).
pub fn dequantize_packed(p: &PackedMatrix, lut: &DequantLut, base_fmt_mx: bool) -> Tensor2 {
    let mut out = Tensor2::zeros(p.rows, p.cols);
    let mut meta = BitReader::new(&p.meta);
    let bits = p.bits as u32;
    let mut codes = vec![0u8; p.block_size];
    let mut bitpos = 0usize;
    for r in 0..p.rows {
        let row = out.row_mut(r);
        for (bi, chunk) in row.chunks_mut(p.block_size).enumerate() {
            let flat = r * p.blocks_per_row + bi;
            let (nano, fmt_mx) = if p.has_meta {
                let m = meta.read(3);
                ((m & 3) as u8, m & 4 != 0)
            } else {
                (0u8, base_fmt_mx)
            };
            let scale = block_scale(lut, p.scales[flat], nano, fmt_mx);
            let (table, _) = lut.table(fmt_mx);
            let c = &mut codes[..chunk.len()];
            unpack_codes(&p.payload, bitpos, bits, c);
            bitpos += bits as usize * chunk.len();
            for (o, &ci) in chunk.iter_mut().zip(c.iter()) {
                *o = table[ci as usize] * scale;
            }
        }
    }
    out
}

/// Fused dequantize + GEMV: `y = W x` with `W` in packed quantized form.
/// The single-threaded 1-column case of [`gemm_packed`]: each block
/// contributes `scale * Σ lut[code]·x[c]`, so the per-element work is one
/// LUT load and one FMA — the weights never materialize in f32. Kept
/// single-threaded deliberately: this is the latency proxy for per-token
/// decode cost (and what the hotpath bench compares against a
/// single-threaded f32 GEMV); use [`gemm_packed`] when there are multiple
/// RHS columns to amortize threading over.
pub fn gemv_packed(
    p: &PackedMatrix,
    lut: &DequantLut,
    base_fmt_mx: bool,
    x: &[f32],
    y: &mut [f32],
) {
    assert_eq!(x.len(), p.cols);
    assert_eq!(y.len(), p.rows);
    gemm_tile(p, lut, base_fmt_mx, x, 1, 0, p.rows, 0, 1, y);
}

/// RHS column tile width: bounds the per-tile accumulator footprint
/// (`32·(8+4)` bytes) so the inner FMA loops stay register/L1-friendly for
/// arbitrarily large batches.
const COL_TILE: usize = 32;

/// Fused dequantize + multi-RHS GEMM: `Y = W X` with `W` packed
/// `[rows, cols]`, `X` row-major `[cols, n_rhs]`, `Y` row-major
/// `[rows, n_rhs]`. Each row's codes are unpacked **once** and reused by
/// every RHS column tile, so batched decode amortizes the bit-stream work
/// that a per-column [`gemv_packed`] loop would repeat.
///
/// Parallelization picks the dimension that can actually feed the cores:
/// row stripes by default (each thread seeks its own meta/payload cursors —
/// every row occupies exactly `cols·bits` payload bits and
/// `blocks_per_row·3` meta bits); when the RHS batch is wider than the
/// matrix is tall *and* there are more worthwhile threads than rows
/// (large-batch decode of a short matrix), RHS **column tiles** instead,
/// each thread producing a compact `[rows, tile]` buffer that is scattered
/// into `Y` after the join. Per-output work is identical in every split,
/// so all paths are bit-identical to the single-threaded one.
pub fn gemm_packed(
    p: &PackedMatrix,
    lut: &DequantLut,
    base_fmt_mx: bool,
    x: &[f32],
    n_rhs: usize,
    y: &mut [f32],
) {
    assert!(n_rhs > 0);
    assert_eq!(x.len(), p.cols * n_rhs);
    assert_eq!(y.len(), p.rows * n_rhs);
    let n_avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Stay single-threaded unless each spawned thread gets enough
    // element-ops to amortize its ~10-20us spawn/join cost (scoped threads
    // are created per call; there is no pool).
    const OPS_PER_THREAD: usize = 1 << 18;
    let mut n_threads = n_avail.min((p.rows * p.cols * n_rhs) / OPS_PER_THREAD);
    if n_threads > p.rows {
        // The column split makes every tile thread re-unpack the whole
        // bit-stream, so it only wins when it both offers more parallelism
        // than row stripes AND keeps each tile at least COL_TILE wide;
        // otherwise cap at one row stripe per row.
        let max_col_threads = n_rhs / COL_TILE;
        if max_col_threads > p.rows {
            n_threads = n_threads.min(max_col_threads);
        } else {
            n_threads = p.rows;
        }
    }
    if n_threads <= 1 {
        gemm_tile(p, lut, base_fmt_mx, x, n_rhs, 0, p.rows, 0, n_rhs, y);
        return;
    }
    if p.rows >= n_threads {
        let chunk_rows = p.rows.div_ceil(n_threads);
        std::thread::scope(|s| {
            for (ti, y_chunk) in y.chunks_mut(chunk_rows * n_rhs).enumerate() {
                let lo = ti * chunk_rows;
                let hi = (lo + chunk_rows).min(p.rows);
                s.spawn(move || {
                    gemm_tile(p, lut, base_fmt_mx, x, n_rhs, lo, hi, 0, n_rhs, y_chunk)
                });
            }
        });
        return;
    }
    // Fewer rows than worthwhile threads: split the RHS columns instead.
    let n_tiles = n_threads.min(n_rhs);
    let tile = n_rhs.div_ceil(n_tiles);
    let results: Vec<(usize, Vec<f32>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_tiles)
            .map(|ti| {
                s.spawn(move || {
                    // ceil-division tiling can leave trailing empty tiles
                    let lo = (ti * tile).min(n_rhs);
                    let hi = ((ti + 1) * tile).min(n_rhs);
                    let mut buf = vec![0.0f32; p.rows * (hi - lo)];
                    gemm_tile(p, lut, base_fmt_mx, x, n_rhs, 0, p.rows, lo, hi, &mut buf);
                    (lo, buf)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (lo, buf) in results {
        let w = buf.len() / p.rows.max(1);
        for r in 0..p.rows {
            y[r * n_rhs + lo..r * n_rhs + lo + w].copy_from_slice(&buf[r * w..(r + 1) * w]);
        }
    }
}

/// One GEMM tile: rows `row_lo..row_hi` × RHS columns `col_lo..col_hi`
/// into the compact row-major `y_out` (`[row_hi-row_lo, col_hi-col_lo]`).
/// Unpacks each row's codes and block scales once, then sweeps the column
/// range in [`COL_TILE`] chunks reusing them; per-output accumulation
/// order (blocks ascending, elements ascending within a block) is fixed,
/// so every tiling/threading split produces bit-identical results.
#[allow(clippy::too_many_arguments)]
fn gemm_tile(
    p: &PackedMatrix,
    lut: &DequantLut,
    base_fmt_mx: bool,
    x: &[f32],
    n_rhs: usize,
    row_lo: usize,
    row_hi: usize,
    col_lo: usize,
    col_hi: usize,
    y_out: &mut [f32],
) {
    let bits = p.bits as u32;
    let width = col_hi - col_lo;
    let bpr = p.blocks_per_row;
    debug_assert_eq!(y_out.len(), (row_hi - row_lo) * width);
    if width == 0 || row_lo == row_hi {
        return; // degenerate tile (uneven thread split)
    }
    let mut meta = BitReader::new(&p.meta);
    if p.has_meta {
        meta.seek(row_lo * bpr * 3);
    }
    let mut bitpos = row_lo * p.cols * bits as usize;
    let mut codes = vec![0u8; p.cols];
    let mut scales = vec![0.0f32; bpr];
    let mut fmts = vec![false; bpr];
    let mut acc = vec![0.0f64; width.min(COL_TILE)];
    let mut dot = vec![0.0f32; width.min(COL_TILE)];
    for r in row_lo..row_hi {
        // decode this row's metadata and unpack its codes once; every
        // column tile below reuses them
        unpack_codes(&p.payload, bitpos, bits, &mut codes);
        bitpos += bits as usize * p.cols;
        for (bi, (sc, fm)) in scales.iter_mut().zip(fmts.iter_mut()).enumerate() {
            let (nano, fmt_mx) = if p.has_meta {
                let m = meta.read(3);
                ((m & 3) as u8, m & 4 != 0)
            } else {
                (0u8, base_fmt_mx)
            };
            *sc = block_scale(lut, p.scales[r * bpr + bi], nano, fmt_mx);
            *fm = fmt_mx;
        }
        let y_row = &mut y_out[(r - row_lo) * width..(r - row_lo + 1) * width];
        if width == 1 {
            // scalar fast path: keeps the 1-column (gemv) decode at one
            // LUT load + one FMA per element, no per-element slicing
            let mut a = 0.0f64;
            for bi in 0..bpr {
                let (table, _) = lut.table(fmts[bi]);
                let start = bi * p.block_size;
                let len = p.block_size.min(p.cols - start);
                let mut d1 = 0.0f32;
                for (ci, &code) in codes[start..start + len].iter().enumerate() {
                    d1 += table[code as usize] * x[(start + ci) * n_rhs + col_lo];
                }
                a += (scales[bi] * d1) as f64;
            }
            y_row[0] = a as f32;
            continue;
        }
        let mut c0 = 0usize;
        while c0 < width {
            let cw = COL_TILE.min(width - c0);
            let acc = &mut acc[..cw];
            let dot = &mut dot[..cw];
            acc.fill(0.0);
            for bi in 0..bpr {
                let (table, _) = lut.table(fmts[bi]);
                let start = bi * p.block_size;
                let len = p.block_size.min(p.cols - start);
                dot.fill(0.0);
                for (ci, &code) in codes[start..start + len].iter().enumerate() {
                    let wv = table[code as usize];
                    let xb = (start + ci) * n_rhs + col_lo + c0;
                    for (d, &xj) in dot.iter_mut().zip(&x[xb..xb + cw]) {
                        *d += wv * xj;
                    }
                }
                let scale = scales[bi];
                for (a, &d) in acc.iter_mut().zip(dot.iter()) {
                    *a += (scale * d) as f64;
                }
            }
            for (o, &a) in y_row[c0..c0 + cw].iter_mut().zip(acc.iter()) {
                *o = a as f32;
            }
            c0 += cw;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::packed::PackedMatrix;
    use crate::formats::{BaseFormat, NxConfig};
    use crate::quant::quantize_matrix;
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;

    fn round_trip(cfg: &NxConfig, rows: usize, cols: usize, seed: u64) {
        let mut rng = Rng::seeded(seed);
        let t = Tensor2::random_normal(rows, cols, 1.0, &mut rng);
        let q = quantize_matrix(&t, cfg);
        let reference = q.dequantize(cfg);
        let packed = q.pack(cfg);
        let lut = DequantLut::new(cfg);
        let fast = dequantize_packed(&packed, &lut, cfg.base == BaseFormat::Mx);
        assert_eq!(reference.data, fast.data, "{} LUT path diverged", cfg.name());
    }

    #[test]
    fn lut_path_bit_identical_to_reference() {
        for (i, cfg) in [
            NxConfig::bfp(4),
            NxConfig::mxfp(4),
            NxConfig::mxfp(5),
            NxConfig::mxfp(6),
            NxConfig::nxfp(4),
            NxConfig::nxfp(5),
            NxConfig::nxfp(6),
        ]
        .iter()
        .enumerate()
        {
            round_trip(cfg, 16, 96, 40 + i as u64);
        }
    }

    #[test]
    fn lut_path_partial_tail_block() {
        round_trip(&NxConfig::nxfp(4), 4, 45, 50);
    }

    #[test]
    fn gemv_matches_dequant_then_matmul() {
        let mut rng = Rng::seeded(51);
        let cfg = NxConfig::nxfp(4);
        let t = Tensor2::random_normal(24, 128, 0.5, &mut rng);
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let q = quantize_matrix(&t, &cfg);
        let w = q.dequantize(&cfg);
        let mut want = vec![0.0f32; 24];
        for r in 0..24 {
            want[r] = w.row(r).iter().zip(&x).map(|(&a, &b)| a * b).sum();
        }
        let packed = q.pack(&cfg);
        let lut = DequantLut::new(&cfg);
        let mut got = vec![0.0f32; 24];
        gemv_packed(&packed, &lut, true, &x, &mut got);
        assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn unpack_codes_unaligned_start_bits() {
        // bits=5/6 blocks rarely start on byte boundaries; sweep start_bit
        // offsets 0..8 and odd lengths (incl. 1-element tails) against a
        // BitWriter-built stream. Lengths ≥ 13 exercise the u64-window
        // path plus its scalar tail.
        let mut rng = Rng::seeded(60);
        for bits in [3u32, 4, 5, 6] {
            for lead in 0..8usize {
                for len in [1usize, 2, 3, 7, 13, 31, 64] {
                    let want: Vec<u8> =
                        (0..len).map(|_| (rng.u32() & ((1u32 << bits) - 1)) as u8).collect();
                    let mut w = crate::formats::packed::BitWriter::new();
                    w.push(0, lead as u32); // misalign the stream start
                    for &c in &want {
                        w.push(c as u32, bits);
                    }
                    w.push(0b101, 3); // trailing bits must not leak in
                    let payload = w.into_bytes();
                    let mut got = vec![0u8; len];
                    unpack_codes(&payload, lead, bits, &mut got);
                    assert_eq!(got, want, "bits={bits} lead={lead} len={len}");
                }
            }
        }
    }

    #[test]
    fn unpack_codes_tight_payload_tail() {
        // the u64 window must never read past the payload: decode a long
        // stream whose final bytes can only be reached by the scalar tail
        let mut rng = Rng::seeded(63);
        for bits in [5u32, 6] {
            let len = 100usize;
            let want: Vec<u8> =
                (0..len).map(|_| (rng.u32() & ((1u32 << bits) - 1)) as u8).collect();
            let mut w = crate::formats::packed::BitWriter::new();
            for &c in &want {
                w.push(c as u32, bits);
            }
            let payload = w.into_bytes(); // exact-size buffer, no slack
            let mut got = vec![0u8; len];
            unpack_codes(&payload, 0, bits, &mut got);
            assert_eq!(got, want, "bits={bits}");
        }
    }

    #[test]
    fn unpack_codes_bits4_odd_tail_avoids_fast_path() {
        // byte-aligned 4-bit stream with an odd element count must fall
        // back to the windowed path and still decode the tail element
        let mut w = crate::formats::packed::BitWriter::new();
        let want = [0xFu8, 0x1, 0x7, 0x9, 0x3];
        for &c in &want {
            w.push(c as u32, 4);
        }
        let payload = w.into_bytes();
        let mut got = vec![0u8; 5];
        unpack_codes(&payload, 0, 4, &mut got);
        assert_eq!(got, want);
    }

    fn gemm_reference(w: &Tensor2, x: &[f32], n_rhs: usize) -> Vec<f32> {
        let mut want = vec![0.0f32; w.rows * n_rhs];
        for r in 0..w.rows {
            for (c, &wv) in w.row(r).iter().enumerate() {
                for j in 0..n_rhs {
                    want[r * n_rhs + j] += wv * x[c * n_rhs + j];
                }
            }
        }
        want
    }

    #[test]
    fn gemm_matches_dequant_then_matmul_all_formats() {
        // partial tail blocks (cols % 32 != 0) across every config family
        let mut rng = Rng::seeded(61);
        let (rows, cols, n_rhs) = (9, 77, 3);
        for bits in 4u8..=6 {
            for cfg in [NxConfig::bfp(bits), NxConfig::mxfp(bits), NxConfig::nxfp(bits)] {
                let t = Tensor2::random_normal(rows, cols, 0.8, &mut rng);
                let x: Vec<f32> = (0..cols * n_rhs).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let q = quantize_matrix(&t, &cfg);
                let want = gemm_reference(&q.dequantize(&cfg), &x, n_rhs);
                let packed = q.pack(&cfg);
                let lut = DequantLut::new(&cfg);
                let base_mx = cfg.base == BaseFormat::Mx;
                let mut got = vec![0.0f32; rows * n_rhs];
                gemm_packed(&packed, &lut, base_mx, &x, n_rhs, &mut got);
                assert_allclose(&got, &want, 1e-4, 1e-4)
                    .unwrap_or_else(|e| panic!("{}: {e}", cfg.name()));
            }
        }
    }

    #[test]
    fn gemm_threaded_stripes_match_single_thread() {
        // large enough to cross the threading threshold (>= 2 threads'
        // worth of element-ops); per-row work is independent so results
        // must be bit-identical to the single-threaded path
        let mut rng = Rng::seeded(62);
        let (rows, cols, n_rhs) = (96, 384, 16);
        let cfg = NxConfig::nxfp(4);
        let t = Tensor2::random_normal(rows, cols, 0.5, &mut rng);
        let x: Vec<f32> = (0..cols * n_rhs).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let q = quantize_matrix(&t, &cfg);
        let packed = q.pack(&cfg);
        let lut = DequantLut::new(&cfg);
        let mut got = vec![0.0f32; rows * n_rhs];
        gemm_packed(&packed, &lut, true, &x, n_rhs, &mut got);
        let mut single = vec![0.0f32; rows * n_rhs];
        gemm_tile(&packed, &lut, true, &x, n_rhs, 0, rows, 0, n_rhs, &mut single);
        assert_eq!(got, single);
        let want = gemm_reference(&q.dequantize(&cfg), &x, n_rhs);
        assert_allclose(&got, &want, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn gemm_column_parallel_matches_single_thread() {
        // rows too few to feed every core but a large RHS batch: the
        // column-tile split must kick in (needs n_threads > rows, i.e.
        // >= 4 worthwhile cores here) and stay bit-identical to the
        // single-threaded tile; on smaller machines it degrades to row
        // stripes / single-thread, which the assert also covers
        let mut rng = Rng::seeded(64);
        let (rows, cols, n_rhs) = (3, 2048, 256);
        let cfg = NxConfig::nxfp(5);
        let t = Tensor2::random_normal(rows, cols, 0.5, &mut rng);
        let x: Vec<f32> = (0..cols * n_rhs).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let q = quantize_matrix(&t, &cfg);
        let packed = q.pack(&cfg);
        let lut = DequantLut::new(&cfg);
        let mut got = vec![0.0f32; rows * n_rhs];
        gemm_packed(&packed, &lut, true, &x, n_rhs, &mut got);
        let mut single = vec![0.0f32; rows * n_rhs];
        gemm_tile(&packed, &lut, true, &x, n_rhs, 0, rows, 0, n_rhs, &mut single);
        assert_eq!(got, single);
        let want = gemm_reference(&q.dequantize(&cfg), &x, n_rhs);
        assert_allclose(&got, &want, 1e-3, 1e-3).unwrap();
    }

    #[test]
    fn lut_path_unaligned_bits5_and_6_tails() {
        // bits=5/6 with cols not a multiple of the block size: blocks and
        // rows start at non-byte-aligned payload offsets
        round_trip(&NxConfig::nxfp(5), 7, 45, 51);
        round_trip(&NxConfig::nxfp(6), 5, 37, 52);
        round_trip(&NxConfig::mxfp(5), 3, 33, 53);
    }

    #[test]
    fn recycled_code_survives_lut() {
        let cfg = NxConfig::nxfp(4);
        let lut = DequantLut::new(&cfg);
        // code 0b1000 (-0) must decode to the recycled value, not -0
        assert_eq!(lut.mx[0b1000], -0.25);
        assert_eq!(lut.bfp[0b1000], -0.5);
        // without CR the code decodes to 0
        let plain = DequantLut::new(&NxConfig::mxfp(4));
        assert_eq!(plain.mx[0b1000], 0.0);
    }
}
