//! On-the-fly dequantization — the deployment hot path (paper §6, Fig. 7).
//!
//! The six decode steps collapse into two table lookups and one FMA per
//! element: the per-code scaled-domain value (steps ①–③: slice fields,
//! remap the recycled code, apply sign) is precomputed into a 2^bits LUT per
//! format path, and the block scale (step ④: shared exponent + NanoMantissa,
//! step ⑤ padding is free in f32) multiplies the looked-up value (step ⑥
//! feeds the MAC). `gemv_packed` fuses the decode into a dot product so
//! weights stream from packed DRAM form straight into FLOPs, which is how
//! the paper deploys on off-the-shelf hardware.

use crate::formats::packed::{BitReader, PackedMatrix, E8M0_BIAS};
use crate::formats::{FormatTables, NxConfig};
use crate::tensor::Tensor2;
use crate::util::exp2i;

/// Precomputed signed decode tables for both adaptive paths.
#[derive(Clone, Debug)]
pub struct DequantLut {
    pub bits: u8,
    /// `mx[code]` = scaled-domain value for the minifloat path.
    pub mx: Vec<f32>,
    /// `bfp[code]` = scaled-domain value for the all-mantissa path.
    pub bfp: Vec<f32>,
    pub offset_mx: i32,
    pub offset_bfp: i32,
}

impl DequantLut {
    pub fn new(cfg: &NxConfig) -> Self {
        let tabs = cfg.tables();
        Self::from_tables(cfg.bits, &tabs)
    }

    pub fn from_tables(bits: u8, tabs: &FormatTables) -> Self {
        let n = 1usize << bits;
        let mx = (0..n).map(|c| tabs.mx.decode(c as u8)).collect();
        let bfp = (0..n).map(|c| tabs.bfp.decode(c as u8)).collect();
        DequantLut {
            bits,
            mx,
            bfp,
            offset_mx: tabs.mx.offset,
            offset_bfp: tabs.bfp.offset,
        }
    }

    #[inline]
    pub fn table(&self, fmt_mx: bool) -> (&[f32], i32) {
        if fmt_mx {
            (&self.mx, self.offset_mx)
        } else {
            (&self.bfp, self.offset_bfp)
        }
    }
}

/// Decode one block's packed metadata into `(scale_mx_or_bfp, fmt_mx)`.
#[inline]
fn block_scale(lut: &DequantLut, e_biased: u8, nano: u8, fmt_mx: bool) -> f32 {
    let e = e_biased as i32 - E8M0_BIAS;
    let offset = if fmt_mx { lut.offset_mx } else { lut.offset_bfp };
    (1.0 + nano as f32 / 4.0) * exp2i(e + offset)
}

/// Unpack `out.len()` consecutive `bits`-wide codes starting at `start_bit`
/// (LSB-first bit stream, bits ≤ 8). A two-byte window always covers one
/// code since `off ≤ 7` and `bits ≤ 8` → `off + bits ≤ 15`. This is the
/// perf-critical inner decode: branch-free, no per-element function calls.
#[inline]
fn unpack_codes(payload: &[u8], start_bit: usize, bits: u32, out: &mut [u8]) {
    // 4-bit byte-aligned fast path (the common case: k even, bits=4 —
    // every block starts on a byte boundary): two codes per byte, no
    // window shifts.
    if bits == 4 && start_bit & 7 == 0 && out.len() & 1 == 0 {
        let base = start_bit >> 3;
        for (i, pair) in out.chunks_exact_mut(2).enumerate() {
            let b = payload[base + i];
            pair[0] = b & 0x0f;
            pair[1] = b >> 4;
        }
        return;
    }
    let mask = ((1u16 << bits) - 1) as u16;
    let mut bitpos = start_bit;
    for o in out.iter_mut() {
        let byte = bitpos >> 3;
        let off = (bitpos & 7) as u16;
        let lo = payload[byte] as u16;
        let hi = *payload.get(byte + 1).unwrap_or(&0) as u16;
        *o = (((lo | (hi << 8)) >> off) & mask) as u8;
        bitpos += bits as usize;
    }
}

/// Dequantize a full packed matrix into an f32 tensor (LUT hot path).
pub fn dequantize_packed(p: &PackedMatrix, lut: &DequantLut, base_fmt_mx: bool) -> Tensor2 {
    let mut out = Tensor2::zeros(p.rows, p.cols);
    let mut meta = BitReader::new(&p.meta);
    let bits = p.bits as u32;
    let mut codes = vec![0u8; p.block_size];
    let mut bitpos = 0usize;
    for r in 0..p.rows {
        let row = out.row_mut(r);
        for (bi, chunk) in row.chunks_mut(p.block_size).enumerate() {
            let flat = r * p.blocks_per_row + bi;
            let (nano, fmt_mx) = if p.has_meta {
                let m = meta.read(3);
                ((m & 3) as u8, m & 4 != 0)
            } else {
                (0u8, base_fmt_mx)
            };
            let scale = block_scale(lut, p.scales[flat], nano, fmt_mx);
            let (table, _) = lut.table(fmt_mx);
            let c = &mut codes[..chunk.len()];
            unpack_codes(&p.payload, bitpos, bits, c);
            bitpos += bits as usize * chunk.len();
            for (o, &ci) in chunk.iter_mut().zip(c.iter()) {
                *o = table[ci as usize] * scale;
            }
        }
    }
    out
}

/// Fused dequantize + GEMV: `y = W x` with `W` in packed quantized form.
/// The inner dot runs in the scaled element domain; each block contributes
/// `scale * Σ lut[code]·x[c]`, so the per-element work is one LUT load and
/// one FMA — the weights never materialize in f32.
pub fn gemv_packed(
    p: &PackedMatrix,
    lut: &DequantLut,
    base_fmt_mx: bool,
    x: &[f32],
    y: &mut [f32],
) {
    assert_eq!(x.len(), p.cols);
    assert_eq!(y.len(), p.rows);
    let bits = p.bits as u32;
    let mut meta = BitReader::new(&p.meta);
    let mut codes = vec![0u8; p.block_size];
    let mut bitpos = 0usize;
    for (r, yr) in y.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for bi in 0..p.blocks_per_row {
            let flat = r * p.blocks_per_row + bi;
            let (nano, fmt_mx) = if p.has_meta {
                let m = meta.read(3);
                ((m & 3) as u8, m & 4 != 0)
            } else {
                (0u8, base_fmt_mx)
            };
            let scale = block_scale(lut, p.scales[flat], nano, fmt_mx);
            let (table, _) = lut.table(fmt_mx);
            let start = bi * p.block_size;
            let len = p.block_size.min(p.cols - start);
            let c = &mut codes[..len];
            unpack_codes(&p.payload, bitpos, bits, c);
            bitpos += bits as usize * len;
            let mut dot = 0.0f32;
            for (&xc, &ci) in x[start..start + len].iter().zip(c.iter()) {
                dot += table[ci as usize] * xc;
            }
            acc += (scale * dot) as f64;
        }
        *yr = acc as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::packed::PackedMatrix;
    use crate::formats::{BaseFormat, NxConfig};
    use crate::quant::quantize_matrix;
    use crate::util::proptest::assert_allclose;
    use crate::util::rng::Rng;

    fn round_trip(cfg: &NxConfig, rows: usize, cols: usize, seed: u64) {
        let mut rng = Rng::seeded(seed);
        let t = Tensor2::random_normal(rows, cols, 1.0, &mut rng);
        let q = quantize_matrix(&t, cfg);
        let reference = q.dequantize(cfg);
        let packed = PackedMatrix::pack(t.rows, t.cols, cfg, &q.blocks);
        let lut = DequantLut::new(cfg);
        let fast = dequantize_packed(&packed, &lut, cfg.base == BaseFormat::Mx);
        assert_eq!(reference.data, fast.data, "{} LUT path diverged", cfg.name());
    }

    #[test]
    fn lut_path_bit_identical_to_reference() {
        for (i, cfg) in [
            NxConfig::bfp(4),
            NxConfig::mxfp(4),
            NxConfig::mxfp(5),
            NxConfig::mxfp(6),
            NxConfig::nxfp(4),
            NxConfig::nxfp(5),
            NxConfig::nxfp(6),
        ]
        .iter()
        .enumerate()
        {
            round_trip(cfg, 16, 96, 40 + i as u64);
        }
    }

    #[test]
    fn lut_path_partial_tail_block() {
        round_trip(&NxConfig::nxfp(4), 4, 45, 50);
    }

    #[test]
    fn gemv_matches_dequant_then_matmul() {
        let mut rng = Rng::seeded(51);
        let cfg = NxConfig::nxfp(4);
        let t = Tensor2::random_normal(24, 128, 0.5, &mut rng);
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let q = quantize_matrix(&t, &cfg);
        let w = q.dequantize(&cfg);
        let mut want = vec![0.0f32; 24];
        for r in 0..24 {
            want[r] = w.row(r).iter().zip(&x).map(|(&a, &b)| a * b).sum();
        }
        let packed = PackedMatrix::pack(t.rows, t.cols, &cfg, &q.blocks);
        let lut = DequantLut::new(&cfg);
        let mut got = vec![0.0f32; 24];
        gemv_packed(&packed, &lut, true, &x, &mut got);
        assert_allclose(&got, &want, 1e-4, 1e-4).unwrap();
    }

    #[test]
    fn recycled_code_survives_lut() {
        let cfg = NxConfig::nxfp(4);
        let lut = DequantLut::new(&cfg);
        // code 0b1000 (-0) must decode to the recycled value, not -0
        assert_eq!(lut.mx[0b1000], -0.25);
        assert_eq!(lut.bfp[0b1000], -0.5);
        // without CR the code decodes to 0
        let plain = DequantLut::new(&NxConfig::mxfp(4));
        assert_eq!(plain.mx[0b1000], 0.0);
    }
}
