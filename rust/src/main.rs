//! `nxfp` CLI — the L3 entrypoint.
//!
//! ```text
//! nxfp train     --steps 300 --batch 16 --out ckpt.bin
//! nxfp eval      --ckpt ckpt.bin --format nxfp4 [--kv-format nxfp4]
//! nxfp reason    --ckpt ckpt.bin --format nxfp4 --probes 200
//! nxfp quantize  --ckpt ckpt.bin --format nxfp4
//! nxfp serve     --ckpt ckpt.bin --kv-format nxfp4 --requests 16
//! nxfp profile   --model Llama3-8B
//! nxfp info
//! ```

use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

use nxfp::coordinator::scheduler::SchedMode;
use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::GenRequest;
use nxfp::eval::{perplexity, quantize_checkpoint, reasoning_accuracy};
use nxfp::formats::NxConfig;
use nxfp::models::corpus::Probe;
use nxfp::models::{Checkpoint, Corpus, GrammarSpec, LmSpec, ModelProfile};
use nxfp::profile::profile_scaled;
use nxfp::runtime::Runtime;
use nxfp::train::{TrainConfig, Trainer};
use nxfp::util::cli::Args;

/// Parse a format name like `fp16`, `bfp4`, `mxfp4`, `nxfp5`, `nxfp4-nm`.
pub fn parse_format(s: &str) -> Result<Option<NxConfig>> {
    let s = s.to_lowercase();
    if s == "fp16" || s == "none" || s.is_empty() {
        return Ok(None);
    }
    let (base, suffix) = match s.split_once('-') {
        Some((b, s)) => (b.to_string(), Some(s.to_string())),
        None => (s.clone(), None),
    };
    let bits: u8 = base
        .trim_start_matches(|c: char| c.is_alphabetic())
        .parse()
        .map_err(|_| anyhow!("bad format {s}"))?;
    let cfg = if base.starts_with("bfp") {
        NxConfig::bfp(bits)
    } else if base.starts_with("mxfp") {
        NxConfig::mxfp(bits)
    } else if base.starts_with("nxfp") {
        match suffix.as_deref() {
            None | Some("nm+am+cr") => NxConfig::nxfp(bits),
            Some("nm") => NxConfig::nxfp_nm(bits),
            Some("nm+am") => NxConfig::nxfp_nm_am(bits),
            Some(other) => bail!("unknown NxFP variant {other}"),
        }
    } else {
        bail!("unknown format {s}");
    };
    Ok(Some(cfg))
}

/// `--prefill-budget` default as a CLI string (pinned to
/// `coordinator::DEFAULT_PREFILL_BUDGET` by a unit test).
const DEFAULT_BUDGET_STR: &str = "64";

/// Parse a per-step prefill token budget: a positive integer, or
/// `inf`/`max`/`unbounded` for whole-prompt-per-step chunking. 1 disables
/// chunking (the legacy per-token schedule, bit-for-bit).
pub fn parse_budget(s: &str) -> Result<usize> {
    match s.to_lowercase().as_str() {
        "inf" | "max" | "unbounded" => Ok(usize::MAX),
        t => t
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("bad prefill budget {s} (positive integer or 'inf')")),
    }
}

/// Name of the KV-fake-quant eval artifact for a config (see aot.py).
pub fn kvq_artifact_name(cfg: &NxConfig) -> String {
    let kind = if cfg.enable_nm || cfg.enable_am || cfg.enable_cr {
        "nxfp"
    } else {
        match cfg.base {
            nxfp::formats::BaseFormat::Mx => "mxfp",
            nxfp::formats::BaseFormat::Bfp => "bfp",
        }
    };
    format!("eval_step_kvq_{kind}{}", cfg.bits)
}

fn default_corpus() -> Corpus {
    Corpus::generate(GrammarSpec::default_for_vocab(512), 400_000, 40_000, 1234)
}

fn artifacts_dir(a: &Args) -> PathBuf {
    PathBuf::from(a.get("artifacts").unwrap_or("artifacts"))
}

fn cmd_train(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let cfg = TrainConfig {
        steps: a.get_parsed("steps")?,
        batch: a.get_usize("batch")?,
        log_every: a.get_parsed("log-every")?,
        seed: a.get_u64("seed")?,
    };
    let out = a.get("out").unwrap_or("artifacts/model.ckpt").to_string();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu(artifacts_dir(a))?;
    println!("platform: {}", rt.platform());
    println!("params:   {}", spec.param_count());
    let init = Checkpoint::init(&spec, cfg.seed);
    let mut trainer = Trainer::new(&mut rt, spec, &init, &cfg)?;
    trainer.train(&corpus, &cfg, |step, loss| {
        println!("step {step:>5}  loss {loss:.4}");
    })?;
    let ck = trainer.checkpoint()?;
    ck.save(Path::new(&out))?;
    println!("saved checkpoint to {out}");
    Ok(())
}

fn cmd_eval(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    ck.check_spec(&spec)?;
    let corpus = default_corpus();
    let mut rt = Runtime::cpu(artifacts_dir(a))?;
    let wfmt = parse_format(&a.get_str("format"))?;
    let kv = a.get("kv-format").map(parse_format).transpose()?.flatten();
    let eval_ck = match &wfmt {
        Some(cfg) => quantize_checkpoint(&ck, &spec.quantizable(), cfg),
        None => ck.clone(),
    };
    let step = match &kv {
        Some(cfg) => rt.load(&kvq_artifact_name(cfg))?,
        None => rt.load("eval_step")?,
    };
    let p = perplexity(&step, &eval_ck, &corpus, spec.seq_len, 8)?;
    println!(
        "format {:<18} kv {:<10} ppl {:.4}  ({} tokens)",
        wfmt.as_ref().map(|c| c.name()).unwrap_or("FP16".into()),
        kv.as_ref().map(|c| c.name()).unwrap_or("FP16".into()),
        p.ppl(),
        p.tokens
    );
    Ok(())
}

fn cmd_reason(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    let corpus = default_corpus();
    let probes = Probe::generate(&corpus.spec, a.get_usize("probes")?, 77);
    let mut rt = Runtime::cpu(artifacts_dir(a))?;
    let step = rt.load("score_step")?;
    let wfmt = parse_format(&a.get_str("format"))?;
    let eval_ck = match &wfmt {
        Some(cfg) => quantize_checkpoint(&ck, &spec.quantizable(), cfg),
        None => ck.clone(),
    };
    let acc = reasoning_accuracy(&step, &eval_ck, &probes, spec.seq_len, 8)?;
    println!(
        "format {:<18} reasoning accuracy {:.1}%  ({} probes)",
        wfmt.as_ref().map(|c| c.name()).unwrap_or("FP16".into()),
        acc * 100.0,
        probes.len()
    );
    Ok(())
}

fn cmd_quantize(a: &Args) -> Result<()> {
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    let cfg = parse_format(&a.get_str("format"))?
        .ok_or_else(|| anyhow!("--format must be a quantized format"))?;
    let spec = LmSpec::small();
    // fail loudly on a spec/checkpoint mismatch (direct_cast_packed
    // itself skips names it can't find)
    ck.check_spec(&spec)?;
    let mut total_fp16 = 0u64;
    let mut total_q = 0u64;
    for (name, packed) in ck.direct_cast_packed(&spec.quantizable(), &cfg) {
        total_fp16 += ck.get(&name).unwrap().len() as u64 * 2;
        total_q += packed.footprint_bytes() as u64;
    }
    println!(
        "{}: quantizable weights {} KiB -> {} KiB ({:.1}% of FP16)",
        cfg.name(),
        total_fp16 / 1024,
        total_q / 1024,
        100.0 * total_q as f64 / total_fp16 as f64
    );
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    let kv = parse_format(&a.get_str("kv-format"))?;
    let mode: SchedMode = a.get_parsed("sched")?;
    let n_req = a.get_usize("requests")?;
    let max_new = a.get_usize("max-new")?;
    let prefill_budget = parse_budget(&a.get_str("prefill-budget"))?;
    let corpus = default_corpus();
    let probes = Probe::generate(&corpus.spec, n_req, 99);
    let server = ServerHandle::spawn(
        artifacts_dir(a),
        spec,
        ck,
        kv.clone(),
        ServeOpts {
            max_batch: a.get_usize("max-batch")?,
            batch_window: Duration::from_millis(5),
            mode,
            prefill_budget,
        },
    );
    for (i, p) in probes.iter().enumerate() {
        server.submit(GenRequest { id: i as u64, prompt: p.prompt.clone(), max_new });
    }
    for _ in 0..n_req {
        let resp = server.recv().ok_or_else(|| anyhow!("server dropped"))?;
        println!("req {:>3}  {} tokens in {:?}", resp.id, resp.generated, resp.latency);
    }
    let report = server.shutdown()?;
    let m = report.metrics;
    let savings = if m.kv_bits_fp16 > 0 {
        format!(", kv savings {:.1}%", m.kv_savings() * 100.0)
    } else {
        String::new()
    };
    let budget = if prefill_budget == usize::MAX {
        "inf".to_string()
    } else {
        prefill_budget.to_string()
    };
    println!(
        "served {} reqs ({mode:?}, prefill budget {budget}), {} tokens, {:.1} tok/s{savings}",
        m.requests,
        m.tokens_generated,
        m.tokens_per_sec()
    );
    println!("{}", report.serving.summary());
    Ok(())
}

fn cmd_profile(a: &Args) -> Result<()> {
    let name = a.get("model").unwrap_or("Llama3-8B");
    let profile = ModelProfile::by_name(name)
        .ok_or_else(|| anyhow!("unknown model {name}; see `nxfp info`"))?;
    let w = nxfp::models::synth_weights(&profile, 256, 4096);
    let p = profile_scaled(&w, &NxConfig::mxfp(4));
    println!("model {name}: {} elements in scaled domain", p.n);
    println!(
        "above-top {:.3}%  vacant-band {:.3}%  near-zero {:.2}%",
        p.above_top * 100.0,
        p.vacant_band * 100.0,
        p.near_zero * 100.0
    );
    print!("{}", p.hist.render(60));
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("nxfp {} — Nanoscaling Floating-Point", env!("CARGO_PKG_VERSION"));
    println!("\nsynthetic model profiles:");
    for p in ModelProfile::all() {
        println!("  {}", p.name);
    }
    println!("\nformats: fp16 bfp<B> mxfp<B> nxfp<B>[-nm|-nm+am|-nm+am+cr]");
    println!("example: nxfp eval --ckpt artifacts/model.ckpt --format nxfp4");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_format_families() {
        assert!(parse_format("fp16").unwrap().is_none());
        assert!(parse_format("none").unwrap().is_none());
        let c = parse_format("bfp4").unwrap().unwrap();
        assert_eq!(c.name(), "BFP4");
        let c = parse_format("mxfp6").unwrap().unwrap();
        assert_eq!(c.name(), "MxFP6-E2M3");
        let c = parse_format("nxfp4").unwrap().unwrap();
        assert_eq!(c.name(), "NxFP4 (NM+AM+CR)");
        let c = parse_format("nxfp5-nm").unwrap().unwrap();
        assert_eq!(c.name(), "NxFP5 (NM)");
        let c = parse_format("NXFP4-NM+AM").unwrap().unwrap();
        assert_eq!(c.name(), "NxFP4 (NM+AM)");
        assert!(parse_format("zfp4").is_err());
        assert!(parse_format("nxfp4-zzz").is_err());
        assert!(parse_format("mxfpx").is_err());
    }

    use nxfp::coordinator::DEFAULT_PREFILL_BUDGET;

    #[test]
    fn parse_budget_values() {
        assert_eq!(parse_budget("1").unwrap(), 1);
        assert_eq!(parse_budget("64").unwrap(), 64);
        assert_eq!(parse_budget("inf").unwrap(), usize::MAX);
        assert_eq!(parse_budget("MAX").unwrap(), usize::MAX);
        assert_eq!(parse_budget("unbounded").unwrap(), usize::MAX);
        assert!(parse_budget("0").is_err());
        assert!(parse_budget("-3").is_err());
        assert!(parse_budget("lots").is_err());
        // the CLI default string tracks the library constant
        assert_eq!(parse_budget(DEFAULT_BUDGET_STR).unwrap(), DEFAULT_PREFILL_BUDGET);
    }

    #[test]
    fn kvq_artifact_names() {
        assert_eq!(kvq_artifact_name(&NxConfig::nxfp(4)), "eval_step_kvq_nxfp4");
        assert_eq!(kvq_artifact_name(&NxConfig::mxfp(5)), "eval_step_kvq_mxfp5");
        assert_eq!(kvq_artifact_name(&NxConfig::bfp(6)), "eval_step_kvq_bfp6");
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("usage: nxfp <train|eval|reason|quantize|serve|profile|info> [--help]");
        std::process::exit(2);
    };
    let common = |a: Args| a.opt("artifacts", Some("artifacts"), "artifacts directory");
    let result = match cmd.as_str() {
        "train" => common(Args::new("nxfp train", "train the in-repo LM via AOT train_step"))
            .opt("steps", Some("300"), "optimizer steps")
            .opt("batch", Some("16"), "batch size (must match artifact)")
            .opt("log-every", Some("10"), "loss log interval")
            .opt("seed", Some("42"), "init/data seed")
            .opt("out", Some("artifacts/model.ckpt"), "checkpoint output")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_train(&a)),
        "eval" => common(Args::new("nxfp eval", "held-out perplexity under a format"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("format", Some("fp16"), "weight format (fp16/bfp4/mxfp4/nxfp4…)")
            .opt("kv-format", None, "KV-cache format (uses the kvq artifact)")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_eval(&a)),
        "reason" => common(Args::new("nxfp reason", "multiple-choice reasoning accuracy"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("format", Some("fp16"), "weight format")
            .opt("probes", Some("200"), "number of probes")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_reason(&a)),
        "quantize" => common(Args::new("nxfp quantize", "pack a checkpoint, report footprint"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("format", Some("nxfp4"), "target format")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_quantize(&a)),
        "serve" => common(Args::new("nxfp serve", "batched decoding with quantized KV"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("kv-format", Some("nxfp4"), "KV-cache storage format")
            .opt("sched", Some("continuous"), "scheduler: continuous|wave")
            .opt(
                "prefill-budget",
                Some(DEFAULT_BUDGET_STR),
                "prefill tokens per step (or 'inf'; 1 = unchunked)",
            )
            .opt("requests", Some("16"), "number of requests")
            .opt("max-new", Some("32"), "tokens to generate per request")
            .opt("max-batch", Some("4"), "batch lanes (must match artifact)")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_serve(&a)),
        "profile" => common(Args::new("nxfp profile", "Fig.3-style scaled-weight profile"))
            .opt("model", Some("Llama3-8B"), "synthetic model profile")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_profile(&a)),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        if let Some(nxfp::util::cli::CliError::Help(h)) = e.downcast_ref() {
            println!("{h}");
            return;
        }
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
