//! `nxfp` CLI — the L3 entrypoint.
//!
//! ```text
//! nxfp train     --steps 300 --batch 16 --out ckpt.bin
//! nxfp eval      --ckpt ckpt.bin --format nxfp4 [--kv-format nxfp4]
//! nxfp reason    --ckpt ckpt.bin --format nxfp4 --probes 200
//! nxfp quantize  --ckpt ckpt.bin --quant "weights=nxfp4,layers.0-1.*=mxfp6"
//! nxfp serve     --ckpt ckpt.bin --quant "kv.k=nxfp5,kv.v=mxfp4" --requests 16
//! nxfp trace     check --in trace.jsonl
//! nxfp profile   --model Llama3-8B
//! nxfp info
//! ```
//!
//! Quantization formats are chosen by a [`QuantPolicy`]: `--quant` takes a
//! full policy spec (`weights=nxfp4,kv.k=nxfp5,kv.v=mxfp4`, first match
//! wins, unmatched classes stay FP16), while the legacy `--format` /
//! `--kv-format` flags remain as sugar that lowers to a `weights=…` /
//! `kv=…` rule. When `--quant` is given it wins.

use anyhow::{anyhow, Result};
use std::path::{Path, PathBuf};
use std::time::Duration;

use nxfp::coordinator::fault::FaultPlan;
use nxfp::coordinator::metrics::ServingMetrics;
use nxfp::coordinator::router::{replica_path, FleetHandle};
use nxfp::coordinator::scheduler::SchedMode;
use nxfp::coordinator::server::{ServeOpts, ServerHandle};
use nxfp::coordinator::{FinishReason, GenRequest, Metrics};
use nxfp::eval::{checkpoint_footprint, perplexity, quantize_checkpoint, reasoning_accuracy};
use nxfp::formats::{NxConfig, QuantPolicy};
use nxfp::models::corpus::Probe;
use nxfp::models::{Checkpoint, Corpus, GrammarSpec, LmSpec, ModelProfile};
use nxfp::obs::write_fleet_metrics;
use nxfp::profile::profile_scaled;
use nxfp::runtime::Runtime;
use nxfp::train::{TrainConfig, Trainer};
use nxfp::util::cli::Args;

/// The quantization policy a subcommand runs under: `--quant <spec>` when
/// given, else the legacy flag lowered to a single rule on `legacy_class`
/// (`weights` for `--format`, `kv` for `--kv-format`), so the old flags
/// keep their exact old meaning.
pub fn resolve_policy(a: &Args, legacy: &str, legacy_class: &str) -> Result<QuantPolicy> {
    let spec = a.get("quant").unwrap_or("");
    if !spec.trim().is_empty() {
        return QuantPolicy::parse(spec);
    }
    match a.get(legacy) {
        None | Some("") => Ok(QuantPolicy::fp16()),
        Some(fmt) => QuantPolicy::parse(&format!("{legacy_class}={fmt}")),
    }
}

/// `--prefill-budget` default as a CLI string (pinned to
/// `coordinator::DEFAULT_PREFILL_BUDGET` by a unit test).
const DEFAULT_BUDGET_STR: &str = "64";

/// `--kv-page-rows` default as a CLI string (pinned to
/// `quant::page::DEFAULT_KV_PAGE_ROWS` by a unit test).
const DEFAULT_PAGE_ROWS_STR: &str = "16";

/// `--retry-max` default as a CLI string (pinned to
/// `coordinator::DEFAULT_RETRY_MAX` by a unit test).
const DEFAULT_RETRY_STR: &str = "3";

/// `--replicas` default as a CLI string: one engine, no fleet tier.
const DEFAULT_REPLICAS_STR: &str = "1";

/// Parse an admission-queue cap: a positive integer, or
/// `unbounded`/`inf`/`max` for no cap (the default — arrivals never shed).
pub fn parse_queue_cap(s: &str) -> Result<usize> {
    match s.to_lowercase().as_str() {
        "unbounded" | "inf" | "max" => Ok(usize::MAX),
        t => t
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("bad queue cap {s} (positive integer or 'unbounded')")),
    }
}

/// Parse an `on`/`off` switch (`--prefix-cache`); `1`/`true`/`yes` and
/// `0`/`false`/`no` are accepted aliases.
pub fn parse_switch(s: &str) -> Result<bool> {
    match s.to_lowercase().as_str() {
        "on" | "1" | "true" | "yes" => Ok(true),
        "off" | "0" | "false" | "no" => Ok(false),
        other => Err(anyhow!("bad switch value {other} (want on|off)")),
    }
}

/// Parse a per-step prefill token budget: a positive integer, or
/// `inf`/`max`/`unbounded` for whole-prompt-per-step chunking. 1 disables
/// chunking (the legacy per-token schedule, bit-for-bit).
pub fn parse_budget(s: &str) -> Result<usize> {
    match s.to_lowercase().as_str() {
        "inf" | "max" | "unbounded" => Ok(usize::MAX),
        t => t
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0)
            .ok_or_else(|| anyhow!("bad prefill budget {s} (positive integer or 'inf')")),
    }
}

/// Name of the KV-fake-quant eval artifact for a config (see aot.py).
///
/// Keyed on family + bits **plus a config digest for non-default
/// configs**: two configs that differ only in NM/AM/CR toggles, element
/// format, block size, or recycle target used to collide on one artifact
/// name (e.g. `nxfp4` vs `nxfp4-nm` both mapped to `eval_step_kvq_nxfp4`,
/// so an `-nm` eval silently reused the full-NxFP artifact). Canonical
/// full-family configs keep the legacy name so existing artifact
/// directories still resolve.
pub fn kvq_artifact_name(cfg: &NxConfig) -> String {
    let base = format!("eval_step_kvq_{}{}", cfg.family(), cfg.bits);
    let canonical = cfg
        .spec_name()
        .map_or(false, |n| n == format!("{}{}", cfg.family(), cfg.bits));
    if canonical {
        base
    } else {
        format!("{base}_{}", cfg.digest())
    }
}

/// Name of the **layered** KV-fake-quant eval artifact for a per-layer
/// `(K, V)` resolution that is not uniform (see `QuantPolicy::kv_layers`).
///
/// The name hashes the comma-joined canonical spec-name tokens in layer
/// order, K before V, FP16 streams as `fp16` — e.g. 2 layers of
/// `kv.k=nxfp5,kv.v=mxfp4` hash `"nxfp5,mxfp4,nxfp5,mxfp4"`. aot.py's
/// `--kvq-layers` builds the identical name from the identical token
/// string (FNV-1a 64, truncated to 24 bits), so the CLI finds the
/// artifact the compiler emitted without sharing any Rust-side state.
/// Configs without a canonical spec name cannot cross the language
/// boundary and are rejected.
pub fn kvq_layered_artifact_name(
    layers: &[(Option<NxConfig>, Option<NxConfig>)],
) -> Result<String> {
    let mut tokens = Vec::with_capacity(layers.len() * 2);
    for (k, v) in layers {
        for cfg in [k, v] {
            tokens.push(match cfg {
                None => "fp16".to_string(),
                Some(c) => c.spec_name().ok_or_else(|| {
                    anyhow!(
                        "config {} has no canonical spec name; \
                         layered kvq artifacts need parseable formats",
                        c.name()
                    )
                })?,
            });
        }
    }
    let joined = tokens.join(",");
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for byte in joined.as_bytes() {
        h ^= *byte as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    Ok(format!("eval_step_kvq_layers_{:06x}", h & 0xff_ffff))
}

fn default_corpus() -> Corpus {
    Corpus::generate(GrammarSpec::default_for_vocab(512), 400_000, 40_000, 1234)
}

fn artifacts_dir(a: &Args) -> PathBuf {
    PathBuf::from(a.get("artifacts").unwrap_or("artifacts"))
}

fn cmd_train(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let cfg = TrainConfig {
        steps: a.get_parsed("steps")?,
        batch: a.get_usize("batch")?,
        log_every: a.get_parsed("log-every")?,
        seed: a.get_u64("seed")?,
    };
    let out = a.get("out").unwrap_or("artifacts/model.ckpt").to_string();
    let corpus = default_corpus();
    let mut rt = Runtime::cpu(artifacts_dir(a))?;
    println!("platform: {}", rt.platform());
    println!("params:   {}", spec.param_count());
    let init = Checkpoint::init(&spec, cfg.seed);
    let mut trainer = Trainer::new(&mut rt, spec, &init, &cfg)?;
    trainer.train(&corpus, &cfg, |step, loss| {
        println!("step {step:>5}  loss {loss:.4}");
    })?;
    let ck = trainer.checkpoint()?;
    ck.save(Path::new(&out))?;
    println!("saved checkpoint to {out}");
    Ok(())
}

fn cmd_eval(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    ck.check_spec(&spec)?;
    let corpus = default_corpus();
    let mut rt = Runtime::cpu(artifacts_dir(a))?;
    let policy = resolve_policy(a, "format", "weights")?;
    let kv_policy = resolve_policy(a, "kv-format", "kv")?;
    let eval_ck = quantize_checkpoint(&ck, &spec.quantizable(), &policy);
    // uniform KV policies keep the legacy per-format artifacts; mixed
    // policies (per-stream or per-layer) route to a layered artifact
    // whose name bakes the full per-layer resolution (see aot.py
    // --kvq-layers for the build side)
    let (step, kv_name) = match kv_policy.kv_uniform(spec.n_layers) {
        Ok(Some(cfg)) => (rt.load(&kvq_artifact_name(&cfg))?, cfg.name()),
        Ok(None) => (rt.load("eval_step")?, "FP16".to_string()),
        Err(_) => {
            let layers = kv_policy
                .kv_layers(spec.n_layers)
                .expect("mixed KV resolution implies a quantized stream");
            (rt.load(&kvq_layered_artifact_name(&layers)?)?, kv_policy.name())
        }
    };
    let p = perplexity(&step, &eval_ck, &corpus, spec.seq_len, 8)?;
    println!(
        "weights {:<18} kv {:<10} ppl {:.4}  ({} tokens)",
        policy.name(),
        kv_name,
        p.ppl(),
        p.tokens
    );
    Ok(())
}

fn cmd_reason(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    let corpus = default_corpus();
    let probes = Probe::generate(&corpus.spec, a.get_usize("probes")?, 77);
    let mut rt = Runtime::cpu(artifacts_dir(a))?;
    let step = rt.load("score_step")?;
    let policy = resolve_policy(a, "format", "weights")?;
    let eval_ck = quantize_checkpoint(&ck, &spec.quantizable(), &policy);
    let acc = reasoning_accuracy(&step, &eval_ck, &probes, spec.seq_len, 8)?;
    println!(
        "weights {:<18} reasoning accuracy {:.1}%  ({} probes)",
        policy.name(),
        acc * 100.0,
        probes.len()
    );
    Ok(())
}

fn cmd_quantize(a: &Args) -> Result<()> {
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    let policy = resolve_policy(a, "format", "weights")?;
    let spec = LmSpec::small();
    // fail loudly on a spec/checkpoint mismatch (direct_cast_packed
    // itself skips names it can't find)
    ck.check_spec(&spec)?;
    let quantizable = spec.quantizable();
    let packed_weights = ck.direct_cast_packed(&quantizable, &policy);
    // a policy can be non-FP16 yet quantize no *weights* (e.g. a KV-only
    // serve spec pasted here) — that's an error, not a 0/0 report
    if packed_weights.is_empty() {
        return Err(anyhow!(
            "policy `{}` quantizes no weights (every weight class resolves to FP16)",
            policy.render()
        ));
    }
    let mut total_fp16 = 0u64;
    let mut total_q = 0u64;
    for (name, _, packed) in packed_weights {
        total_fp16 += ck.get(&name).unwrap().len() as u64 * 2;
        total_q += packed.footprint_bytes() as u64;
    }
    println!(
        "{}: quantizable weights {} KiB -> {} KiB ({:.1}% of FP16)",
        policy.name(),
        total_fp16 / 1024,
        total_q / 1024,
        100.0 * total_q as f64 / total_fp16 as f64
    );
    // per-class effective-bits breakdown (one line per resolved config,
    // FP16 covering embeddings/norms and any fp16-resolved weights)
    let report = checkpoint_footprint(&ck, &quantizable, &policy);
    for c in &report.classes {
        println!(
            "  {:<20} {:>3} tensors  {:>8} KiB  {:.2} eff. bits/elem",
            c.label,
            c.tensors,
            c.bits / 8 / 1024,
            c.effective_bits()
        );
    }
    println!("  total checkpoint footprint {} KiB", report.total_bytes() / 1024);
    Ok(())
}

fn cmd_serve(a: &Args) -> Result<()> {
    let spec = LmSpec::small();
    let ck = Checkpoint::load(Path::new(a.get("ckpt").unwrap_or("artifacts/model.ckpt")))?;
    let kv = resolve_policy(a, "kv-format", "kv")?;
    let kv_name = kv.name();
    let mode: SchedMode = a.get_parsed("sched")?;
    let n_req = a.get_usize("requests")?;
    let max_new = a.get_usize("max-new")?;
    let prefill_budget = parse_budget(&a.get_str("prefill-budget"))?;
    let kv_page_rows = a.get_usize("kv-page-rows")?;
    if kv_page_rows == 0 {
        return Err(anyhow!("--kv-page-rows must be positive"));
    }
    let prefix_cache = parse_switch(&a.get_str("prefix-cache"))?;
    let queue_cap = parse_queue_cap(&a.get_str("queue-cap"))?;
    let deadline_ms = a.get_usize("deadline-ms")?;
    let deadline = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms as u64));
    let retry_max = a.get_usize("retry-max")? as u32;
    let fault = match a.get("fault-plan") {
        None | Some("") => None,
        Some(spec) => Some(FaultPlan::parse(spec)?),
    };
    let opt_path = |name: &str| {
        a.get(name).filter(|s| !s.trim().is_empty()).map(PathBuf::from)
    };
    let trace_out = opt_path("trace-out");
    let metrics_out = opt_path("metrics-out");
    let occupancy = parse_switch(&a.get_str("occupancy"))?;
    let replicas = a.get_usize("replicas")?;
    if replicas == 0 {
        return Err(anyhow!("--replicas must be positive"));
    }
    let spec_k = a.get_usize("spec-k")?;
    let spec_verify = a.get_str("spec-verify");
    if spec_k > 0 {
        if matches!(mode, SchedMode::Wave) {
            return Err(anyhow!("--spec-k requires --sched continuous"));
        }
        // fail fast on a bad --spec-verify instead of from the worker join
        QuantPolicy::parse(&spec_verify)?;
    }
    let opts = ServeOpts {
        max_batch: a.get_usize("max-batch")?,
        batch_window: Duration::from_millis(5),
        mode,
        prefill_budget,
        kv_page_rows,
        prefix_cache,
        queue_cap,
        deadline,
        max_queue_steps: None,
        retry_max,
        fault,
        trace_out,
        metrics_out,
        occupancy,
        spec_k,
        spec_verify,
        ..ServeOpts::default()
    };
    if replicas > 1 {
        return serve_fleet(a, spec, ck, kv, opts, replicas, n_req, max_new);
    }
    let corpus = default_corpus();
    let probes = Probe::generate(&corpus.spec, n_req, 99);
    let mut server = ServerHandle::spawn(artifacts_dir(a), spec, ck, kv, opts);
    for (i, p) in probes.iter().enumerate() {
        if !server.submit(GenRequest { id: i as u64, prompt: p.prompt.clone(), max_new }) {
            return Err(anyhow!("server dropped before request {i} was accepted"));
        }
    }
    for _ in 0..n_req {
        let resp = server.recv().ok_or_else(|| anyhow!("server dropped"))?;
        let note = if resp.reason == FinishReason::Completed {
            String::new()
        } else {
            format!("  [{:?}]", resp.reason)
        };
        println!("req {:>3}  {} tokens in {:?}{note}", resp.id, resp.generated, resp.latency);
    }
    let report = server.shutdown()?;
    let m = report.metrics;
    let savings = if m.kv_bits_fp16 > 0 {
        format!(", kv savings {:.1}%", m.kv_savings() * 100.0)
    } else {
        String::new()
    };
    let budget = if prefill_budget == usize::MAX {
        "inf".to_string()
    } else {
        prefill_budget.to_string()
    };
    println!(
        "served {} reqs (kv {kv_name}, {mode:?}, prefill budget {budget}), {} tokens, \
         {:.1} tok/s{savings}",
        m.requests,
        m.tokens_generated,
        m.tokens_per_sec()
    );
    if m.kv_bits_packed > 0 && m.kv_bits_packed_k != m.kv_bits_packed_v {
        println!(
            "kv packed split: K {} KiB, V {} KiB (per-class footprint)",
            m.kv_bits_packed_k / 8 / 1024,
            m.kv_bits_packed_v / 8 / 1024
        );
    }
    // dedup-aware footprint: with prefix sharing, pages adopted by later
    // requests were charged once — the factor is 1.0x on disjoint traffic
    if m.kv_bits_packed > 0 && m.kv_bits_packed_dedup() < m.kv_bits_packed {
        println!(
            "kv dedup: {} KiB charged -> {} KiB unique ({:.2}x, shared pages counted once)",
            m.kv_bits_packed / 8 / 1024,
            m.kv_bits_packed_dedup() / 8 / 1024,
            m.dedup_factor()
        );
    }
    println!("{}", report.serving.summary());
    for occ in &report.occupancy {
        println!("{}", occ.summary());
    }
    Ok(())
}

/// `nxfp serve --replicas N`: front N PJRT workers with the fleet
/// router. Each replica builds its own runtime and engine (suffixed
/// `.rN` observability exports); the original `--metrics-out` path gets
/// the fleet rollup with per-replica `{replica="i"}` series.
fn serve_fleet(
    a: &Args,
    spec: LmSpec,
    ck: Checkpoint,
    kv: QuantPolicy,
    opts: ServeOpts,
    n_replicas: usize,
    n_req: usize,
    max_new: usize,
) -> Result<()> {
    let fleet_metrics_out = opts.metrics_out.clone();
    let handles: Vec<ServerHandle> = (0..n_replicas)
        .map(|i| {
            let mut o = opts.clone();
            o.trace_out = o.trace_out.map(|p| replica_path(&p, i));
            o.metrics_out = o.metrics_out.map(|p| replica_path(&p, i));
            ServerHandle::spawn(artifacts_dir(a), spec, ck.clone(), kv.clone(), o)
        })
        .collect();
    let mut fleet = FleetHandle::from_handles(handles, opts.max_batch);
    let corpus = default_corpus();
    let probes = Probe::generate(&corpus.spec, n_req, 99);
    for (i, p) in probes.iter().enumerate() {
        if !fleet.submit(GenRequest { id: i as u64, prompt: p.prompt.clone(), max_new }) {
            return Err(anyhow!("fleet dropped before request {i} was accepted"));
        }
    }
    for _ in 0..n_req {
        let resp = fleet.recv().ok_or_else(|| anyhow!("fleet dropped"))?;
        let note = if resp.reason == FinishReason::Completed {
            String::new()
        } else {
            format!("  [{:?}]", resp.reason)
        };
        println!("req {:>3}  {} tokens in {:?}{note}", resp.id, resp.generated, resp.latency);
    }
    let report = fleet.shutdown()?;
    if let Some(path) = &fleet_metrics_out {
        let views: Vec<(&Metrics, &ServingMetrics)> =
            report.replicas.iter().map(|r| (&r.metrics, &r.serving)).collect();
        write_fleet_metrics(path, &report.metrics, &report.serving, &views, &report.merge_errors)?;
    }
    println!(
        "fleet of {n_replicas}: served {} reqs, {} tokens ({} re-dispatched)",
        report.metrics.requests,
        report.metrics.tokens_generated,
        report.redispatched
    );
    for (i, r) in report.replicas.iter().enumerate() {
        println!(
            "replica {i}: {} reqs, {} tokens, {:.1} tok/s, prefix hit rate {:.0}%",
            r.metrics.requests,
            r.metrics.tokens_generated,
            r.metrics.tokens_per_sec(),
            r.serving.prefix_hit_rate() * 100.0
        );
    }
    for e in &report.merge_errors {
        eprintln!("rollup merge error: {e}");
    }
    println!("{}", report.serving.summary());
    Ok(())
}

/// `nxfp trace <show|check> --in <trace.jsonl>` — reconstruct per-request
/// timelines from a serving trace, or validate it against the event-order
/// state machine and the embedded counter summary.
fn cmd_trace(a: &Args) -> Result<()> {
    let action = a.positional.first().map(String::as_str).unwrap_or("show");
    let path = PathBuf::from(a.get_str("in"));
    let trace = nxfp::obs::read_jsonl(&path)?;
    match action {
        "check" => {
            let violations = nxfp::obs::check_trace(&trace);
            if violations.is_empty() {
                println!("trace OK: {} entries", trace.entries.len());
                Ok(())
            } else {
                for v in &violations {
                    eprintln!("violation: {v}");
                }
                Err(anyhow!("{} trace violation(s) in {}", violations.len(), path.display()))
            }
        }
        "show" => {
            print!("{}", nxfp::obs::render_timelines(&nxfp::obs::timelines(&trace)));
            Ok(())
        }
        other => Err(anyhow!("unknown trace action `{other}` (want show|check)")),
    }
}

fn cmd_profile(a: &Args) -> Result<()> {
    let name = a.get("model").unwrap_or("Llama3-8B");
    let profile = ModelProfile::by_name(name)
        .ok_or_else(|| anyhow!("unknown model {name}; see `nxfp info`"))?;
    let w = nxfp::models::synth_weights(&profile, 256, 4096);
    let p = profile_scaled(&w, &NxConfig::mxfp(4));
    println!("model {name}: {} elements in scaled domain", p.n);
    println!(
        "above-top {:.3}%  vacant-band {:.3}%  near-zero {:.2}%",
        p.above_top * 100.0,
        p.vacant_band * 100.0,
        p.near_zero * 100.0
    );
    print!("{}", p.hist.render(60));
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("nxfp {} — Nanoscaling Floating-Point", env!("CARGO_PKG_VERSION"));
    println!("\nsynthetic model profiles:");
    for p in ModelProfile::all() {
        println!("  {}", p.name);
    }
    println!("\nformats: fp16 bfp<B> mxfp<B> nxfp<B>[-nm|-nm+am|-nm+am+cr]");
    println!(
        "policies: --quant takes selector=format rules, first match wins;\n\
         \x20 classes: *, weights[.<name|prefix*>], kv, kv.k, kv.v, layers.<a>[-<b>].<class>\n\
         \x20 unmatched classes stay FP16; a bare format is uniform shorthand"
    );
    println!("examples: nxfp eval --ckpt artifacts/model.ckpt --format nxfp4");
    println!("          nxfp serve --quant \"kv.k=nxfp5,kv.v=mxfp4\"");
    println!("          nxfp quantize --quant \"layers.0-1.weights=mxfp6,weights=nxfp4\"");
    println!(
        "          nxfp serve --trace-out trace.jsonl --metrics-out metrics.prom \
         --occupancy on"
    );
    println!(
        "          nxfp serve --replicas 4 --requests 64 --metrics-out fleet.prom"
    );
    println!("          nxfp serve --spec-k 4 --spec-verify fp16 --kv-format nxfp4");
    println!("          nxfp trace check --in trace.jsonl");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(raw: &[&str]) -> Args {
        Args::new("test", "test")
            .opt("format", Some("fp16"), "weight format")
            .opt("kv-format", Some("nxfp4"), "kv format")
            .opt("quant", None, "policy spec")
            .parse(&raw.iter().map(|s| s.to_string()).collect::<Vec<_>>())
            .unwrap()
    }

    #[test]
    fn legacy_flags_lower_to_single_rule_policies() {
        // --format fp16 default: weights stay fp16
        let a = args(&[]);
        assert!(resolve_policy(&a, "format", "weights").unwrap().is_fp16());
        // --kv-format default nxfp4: uniform KV policy, weights untouched
        let kv = resolve_policy(&a, "kv-format", "kv").unwrap();
        assert_eq!(kv.kv_uniform(4).unwrap().unwrap().name(), "NxFP4 (NM+AM+CR)");
        assert!(kv.resolve(nxfp::formats::TensorClass::weight("l0.wq")).is_none());
        // explicit legacy flag
        let a = args(&["--format", "mxfp6"]);
        let w = resolve_policy(&a, "format", "weights").unwrap();
        assert_eq!(w.resolve(nxfp::formats::TensorClass::weight("l0.wq")).unwrap().bits, 6);
    }

    #[test]
    fn quant_spec_overrides_legacy_flags() {
        let a = args(&["--kv-format", "nxfp4", "--quant", "kv.k=nxfp5,kv.v=mxfp4"]);
        let kv = resolve_policy(&a, "kv-format", "kv").unwrap();
        assert!(kv.kv_uniform(2).is_err(), "mixed spec should win over the legacy flag");
        assert!(resolve_policy(&args(&["--quant", "zfp=4"]), "format", "weights").is_err());
    }

    use nxfp::coordinator::DEFAULT_PREFILL_BUDGET;

    #[test]
    fn parse_budget_values() {
        assert_eq!(parse_budget("1").unwrap(), 1);
        assert_eq!(parse_budget("64").unwrap(), 64);
        assert_eq!(parse_budget("inf").unwrap(), usize::MAX);
        assert_eq!(parse_budget("MAX").unwrap(), usize::MAX);
        assert_eq!(parse_budget("unbounded").unwrap(), usize::MAX);
        assert!(parse_budget("0").is_err());
        assert!(parse_budget("-3").is_err());
        assert!(parse_budget("lots").is_err());
        // the CLI default string tracks the library constant
        assert_eq!(parse_budget(DEFAULT_BUDGET_STR).unwrap(), DEFAULT_PREFILL_BUDGET);
    }

    #[test]
    fn parse_switch_values() {
        for on in ["on", "ON", "1", "true", "yes"] {
            assert!(parse_switch(on).unwrap(), "{on}");
        }
        for off in ["off", "Off", "0", "false", "no"] {
            assert!(!parse_switch(off).unwrap(), "{off}");
        }
        assert!(parse_switch("maybe").is_err());
        assert!(parse_switch("").is_err());
    }

    #[test]
    fn kv_page_rows_default_tracks_library_constant() {
        assert_eq!(
            DEFAULT_PAGE_ROWS_STR.parse::<usize>().unwrap(),
            nxfp::quant::page::DEFAULT_KV_PAGE_ROWS
        );
    }

    #[test]
    fn parse_queue_cap_values() {
        assert_eq!(parse_queue_cap("8").unwrap(), 8);
        assert_eq!(parse_queue_cap("unbounded").unwrap(), usize::MAX);
        assert_eq!(parse_queue_cap("INF").unwrap(), usize::MAX);
        assert!(parse_queue_cap("0").is_err());
        assert!(parse_queue_cap("some").is_err());
    }

    #[test]
    fn retry_max_default_tracks_library_constant() {
        assert_eq!(
            DEFAULT_RETRY_STR.parse::<u32>().unwrap(),
            nxfp::coordinator::DEFAULT_RETRY_MAX
        );
    }

    #[test]
    fn replicas_default_is_single_engine() {
        assert_eq!(DEFAULT_REPLICAS_STR.parse::<usize>().unwrap(), 1);
    }

    #[test]
    fn layered_kvq_artifact_names_pin_the_token_hash() {
        use nxfp::formats::policy::KvStream;
        use nxfp::formats::TensorClass;
        let layers = |p: &QuantPolicy, n: usize| {
            (0..n)
                .map(|l| {
                    (
                        p.resolve(TensorClass::kv(l, KvStream::Key)).cloned(),
                        p.resolve(TensorClass::kv(l, KvStream::Value)).cloned(),
                    )
                })
                .collect::<Vec<_>>()
        };
        // hashes are pinned so aot.py's independent FNV implementation
        // must reproduce them from the same token strings (see
        // test_aot_manifest.py): "nxfp5,mxfp4,nxfp5,mxfp4" etc.
        let mixed = QuantPolicy::parse("kv.k=nxfp5,kv.v=mxfp4").unwrap();
        assert_eq!(
            kvq_layered_artifact_name(&layers(&mixed, 2)).unwrap(),
            "eval_step_kvq_layers_c83f63"
        );
        // per-layer mix with fp16 V streams: "mxfp6,fp16,nxfp4,fp16"
        let per_layer = QuantPolicy::parse("layers.0.kv.k=mxfp6,kv.v=fp16,kv=nxfp4").unwrap();
        assert_eq!(
            kvq_layered_artifact_name(&layers(&per_layer, 2)).unwrap(),
            "eval_step_kvq_layers_a4b3ae"
        );
        // one quantized layer: "nxfp4,nxfp4"
        let uni = QuantPolicy::parse("kv=nxfp4").unwrap();
        assert_eq!(
            kvq_layered_artifact_name(&layers(&uni, 1)).unwrap(),
            "eval_step_kvq_layers_619c6b"
        );
        // non-canonical configs can't cross the aot.py naming boundary
        let custom = vec![(Some(NxConfig::nxfp(4).with_block_size(16)), None)];
        assert!(kvq_layered_artifact_name(&custom).is_err());
    }

    #[test]
    fn kvq_artifact_names() {
        // default configs keep the legacy names (existing artifact
        // directories must still resolve)
        assert_eq!(kvq_artifact_name(&NxConfig::nxfp(4)), "eval_step_kvq_nxfp4");
        assert_eq!(kvq_artifact_name(&NxConfig::mxfp(5)), "eval_step_kvq_mxfp5");
        assert_eq!(kvq_artifact_name(&NxConfig::bfp(6)), "eval_step_kvq_bfp6");
    }

    #[test]
    fn kvq_artifact_names_do_not_collide_on_variants() {
        // regression: nxfp4 and nxfp4-nm used to share one artifact name
        let full = kvq_artifact_name(&NxConfig::nxfp(4));
        let nm = kvq_artifact_name(&NxConfig::nxfp_nm(4));
        let nm_am = kvq_artifact_name(&NxConfig::nxfp_nm_am(4));
        let blk16 = kvq_artifact_name(&NxConfig::nxfp(4).with_block_size(16));
        let names = [&full, &nm, &nm_am, &blk16];
        for (i, a) in names.iter().enumerate() {
            for b in &names[i + 1..] {
                assert_ne!(a, b, "artifact name collision");
            }
        }
        // variants keep the family prefix so aot.py can route them
        assert!(nm.starts_with("eval_step_kvq_nxfp4_"), "{nm}");
        assert!(blk16.starts_with("eval_step_kvq_nxfp4_"), "{blk16}");
        // custom block size on a plain MxFP keeps its family
        let mx_blk = kvq_artifact_name(&NxConfig::mxfp(4).with_block_size(16));
        assert!(mx_blk.starts_with("eval_step_kvq_mxfp4_"), "{mx_blk}");
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("usage: nxfp <train|eval|reason|quantize|serve|trace|profile|info> [--help]");
        std::process::exit(2);
    };
    let common = |a: Args| a.opt("artifacts", Some("artifacts"), "artifacts directory");
    let result = match cmd.as_str() {
        "train" => common(Args::new("nxfp train", "train the in-repo LM via AOT train_step"))
            .opt("steps", Some("300"), "optimizer steps")
            .opt("batch", Some("16"), "batch size (must match artifact)")
            .opt("log-every", Some("10"), "loss log interval")
            .opt("seed", Some("42"), "init/data seed")
            .opt("out", Some("artifacts/model.ckpt"), "checkpoint output")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_train(&a)),
        "eval" => common(Args::new("nxfp eval", "held-out perplexity under a format"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("format", Some("fp16"), "weight format (fp16/bfp4/mxfp4/nxfp4…)")
            .opt("kv-format", None, "KV-cache format (uses the kvq artifact)")
            .opt("quant", None, "policy spec, e.g. weights=nxfp4,kv=nxfp5 (overrides both)")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_eval(&a)),
        "reason" => common(Args::new("nxfp reason", "multiple-choice reasoning accuracy"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("format", Some("fp16"), "weight format")
            .opt("quant", None, "policy spec, e.g. layers.0-1.weights=mxfp6,weights=nxfp4")
            .opt("probes", Some("200"), "number of probes")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_reason(&a)),
        "quantize" => common(Args::new("nxfp quantize", "pack a checkpoint, report footprint"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("format", Some("nxfp4"), "target format")
            .opt("quant", None, "policy spec, e.g. weights.l0.*=nxfp6,weights=nxfp4")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_quantize(&a)),
        "serve" => common(Args::new("nxfp serve", "batched decoding with quantized KV"))
            .opt("ckpt", Some("artifacts/model.ckpt"), "checkpoint path")
            .opt("kv-format", Some("nxfp4"), "KV-cache storage format")
            .opt("quant", None, "KV policy spec, e.g. kv.k=nxfp5,kv.v=mxfp4 (overrides)")
            .opt("sched", Some("continuous"), "scheduler: continuous|wave")
            .opt(
                "prefill-budget",
                Some(DEFAULT_BUDGET_STR),
                "prefill tokens per step (or 'inf'; 1 = unchunked)",
            )
            .opt("requests", Some("16"), "number of requests")
            .opt("max-new", Some("32"), "tokens to generate per request")
            .opt("max-batch", Some("4"), "batch lanes (must match artifact)")
            .opt(
                "replicas",
                Some(DEFAULT_REPLICAS_STR),
                "decode-engine replicas; >1 serves through the prefix-affinity fleet router",
            )
            .opt(
                "kv-page-rows",
                Some(DEFAULT_PAGE_ROWS_STR),
                "rows per quantized-KV page (sharing granularity)",
            )
            .opt(
                "prefix-cache",
                Some("on"),
                "share packed KV across common prompt prefixes: on|off",
            )
            .opt(
                "queue-cap",
                Some("unbounded"),
                "admission queue depth; past it arrivals are shed",
            )
            .opt("deadline-ms", Some("0"), "per-request wall deadline in ms (0 = none)")
            .opt(
                "retry-max",
                Some(DEFAULT_RETRY_STR),
                "transient-fault retries per backend call",
            )
            .opt(
                "fault-plan",
                None,
                "seeded fault injection, e.g. seed=7,step=0.01,nan=0.005",
            )
            .opt("trace-out", None, "write a JSONL event trace here at shutdown")
            .opt(
                "metrics-out",
                None,
                "write metrics here at shutdown (.json = JSON, else Prometheus text)",
            )
            .opt(
                "occupancy",
                Some("off"),
                "live code-occupancy probes on the KV encode path: on|off",
            )
            .opt(
                "spec-k",
                Some("0"),
                "speculative draft depth per round (0 = off; continuous sched only)",
            )
            .opt(
                "spec-verify",
                Some("fp16"),
                "verifier-lane KV policy for --spec-k, e.g. fp16 or nxfp6",
            )
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_serve(&a)),
        "trace" => Args::new("nxfp trace", "inspect or validate a serving trace (show|check)")
            .opt("in", Some("trace.jsonl"), "JSONL trace written by serve --trace-out")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_trace(&a)),
        "profile" => common(Args::new("nxfp profile", "Fig.3-style scaled-weight profile"))
            .opt("model", Some("Llama3-8B"), "synthetic model profile")
            .parse(rest)
            .map_err(anyhow::Error::from)
            .and_then(|a| cmd_profile(&a)),
        "info" => cmd_info(),
        other => {
            eprintln!("unknown command {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        if let Some(nxfp::util::cli::CliError::Help(h)) = e.downcast_ref() {
            println!("{h}");
            return;
        }
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
