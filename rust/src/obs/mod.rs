//! Serving-tier observability: structured trace events, metrics export,
//! and live quantization-fidelity probes.
//!
//! Three pillars, all opt-in and all zero-cost when disabled:
//!
//! * **Structured traces** — a bounded [`TraceRing`] records typed
//!   request-lifecycle events ([`TraceEvent`]) and per-step span records
//!   ([`StepSpan`]), each stamped with the deterministic scheduler step
//!   clock *and* wall time. The engine, scheduler, and server all emit
//!   through a shared [`TraceSink`] handle; a disabled sink is a `None`
//!   behind an `Option` — no allocation, no clock reads, no branches
//!   beyond one null check. Traces serialize to JSONL
//!   ([`TraceSink::write_jsonl`]) and load back ([`read_jsonl`]) for the
//!   `nxfp trace` subcommand and the trace tests.
//! * **Metrics export** ([`export`]) — Prometheus-text and JSON renderers
//!   over the engine's `Metrics`/`ServingMetrics` (counters plus
//!   log-bucketed histograms with explicit bucket bounds).
//! * **Fidelity probes** ([`occupancy`]) — per-interned-config
//!   [`CodeOccupancy`] tables fed from the encode hot path, measuring the
//!   paper's three pathologies (outlier clipping, vacant levels, recycled
//!   −0 code) on live KV traffic.
//!
//! # Event-order contract
//!
//! Every event is emitted at the exact site where the matching
//! `ServingMetrics` counter increments, so a complete trace agrees with
//! the counters *exactly* — [`check_trace`] verifies both the per-request
//! lifecycle (state machine below) and, when the trailing summary record
//! is present and nothing was evicted from the ring, the counter
//! equalities. Legal per-request lifecycles:
//!
//! ```text
//! New ──Enqueued──► Queued ──Admitted──► Active ──Finished──► Done
//!  │                  │  ▲                 │ │
//!  │                  │  └────Requeued─────┘ ├─ Promoted / PrefixAdopted
//!  │                  │                      ├─ PrefillChunk
//!  │                  │                      └─ Draft / Verify / Rollback
//!  ├──Admitted──► Active            (wave mode skips the queue)
//!  └──Shed / Finished{…}──► …       (cap shed, drain shed, rejection)
//! ```
//!
//! `Retry{attempt}` is batch-scoped (`req == None`) and exempt from the
//! per-request machine; `DeadlineExpired` may fire from `Queued`
//! (admission-time expiry) or `Active` (in-flight expiry).

pub mod export;
pub mod occupancy;

pub use export::{
    render_fleet_json, render_fleet_prometheus, render_metrics_json, render_prometheus,
    write_fleet_metrics, write_metrics,
};
pub use occupancy::CodeOccupancy;

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use crate::coordinator::metrics::ServingMetrics;
use crate::coordinator::FinishReason;

/// Default [`TraceRing`] capacity (entries). Large enough that the CI
/// smoke workloads never evict; eviction is counted, not silent.
pub const DEFAULT_TRACE_CAP: usize = 1 << 16;

/// Typed request-lifecycle event. Each variant is emitted at the exact
/// site where the matching `ServingMetrics` counter increments (see the
/// module docs for the legality rules [`check_trace`] enforces).
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEvent {
    /// Accepted into the admission queue (`Scheduler::enqueue`).
    Enqueued,
    /// Placed into lane `lane` of the batch.
    Admitted { lane: usize },
    /// Fed `tokens` prompt tokens this step (phase-A chunk + step token).
    PrefillChunk { tokens: usize },
    /// Admission used the anti-starvation promotion rule.
    Promoted,
    /// One in-place retry of a faulted backend call (batch-scoped:
    /// `req == None`).
    Retry { attempt: u32 },
    /// Slot retired by a fault and pushed back to the queue front.
    Requeued,
    /// Admission adopted `rows` cached prefix rows.
    PrefixAdopted { rows: usize },
    /// Dropped by overload policy (queue cap or drain).
    Shed,
    /// Deadline enforcement dropped the request (admission or in-flight).
    DeadlineExpired,
    /// Speculative round drafted `k` provisional tokens on the
    /// low-precision lane (one event per verify round; `k` is the actual
    /// proposal count after tail clamping, not the configured target).
    Draft { k: usize },
    /// Verifier judged the drafted prefix: `accepted` proposals stood.
    /// Paired 1:1 with the preceding `Draft` for the same request.
    Verify { accepted: usize },
    /// Rejection rolled back `rows` draft KV rows (proposals past the
    /// first rejected position). Emitted only when a `Verify` rejected.
    Rollback { rows: usize },
    /// Response produced; `reason` matches the `GenResponse` exactly.
    Finished { reason: FinishReason },
}

impl TraceEvent {
    /// Stable wire name (the JSONL `event` field).
    pub fn name(&self) -> &'static str {
        match self {
            TraceEvent::Enqueued => "enqueued",
            TraceEvent::Admitted { .. } => "admitted",
            TraceEvent::PrefillChunk { .. } => "prefill_chunk",
            TraceEvent::Promoted => "promoted",
            TraceEvent::Retry { .. } => "retry",
            TraceEvent::Requeued => "requeued",
            TraceEvent::PrefixAdopted { .. } => "prefix_adopted",
            TraceEvent::Shed => "shed",
            TraceEvent::DeadlineExpired => "deadline_expired",
            TraceEvent::Draft { .. } => "draft",
            TraceEvent::Verify { .. } => "verify",
            TraceEvent::Rollback { .. } => "rollback",
            TraceEvent::Finished { .. } => "finished",
        }
    }
}

/// Stable wire name of a [`FinishReason`].
pub fn reason_name(r: FinishReason) -> &'static str {
    match r {
        FinishReason::Completed => "completed",
        FinishReason::Rejected => "rejected",
        FinishReason::Shed => "shed",
        FinishReason::Deadline => "deadline",
        FinishReason::BackendError => "backend_error",
    }
}

fn reason_from_name(s: &str) -> Option<FinishReason> {
    Some(match s {
        "completed" => FinishReason::Completed,
        "rejected" => FinishReason::Rejected,
        "shed" => FinishReason::Shed,
        "deadline" => FinishReason::Deadline,
        "backend_error" => FinishReason::BackendError,
        _ => return None,
    })
}

/// One recorded lifecycle event, stamped with both clocks.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Deterministic scheduler step clock at emission.
    pub step: u64,
    /// Microseconds since the ring's epoch (sink creation).
    pub wall_us: u64,
    /// Request id; `None` for batch-scoped events (`Retry`).
    pub req: Option<u64>,
    pub event: TraceEvent,
}

/// One per-step span record: what a continuous-batching step did.
#[derive(Clone, Debug, PartialEq)]
pub struct StepSpan {
    pub step: u64,
    pub wall_us: u64,
    /// Phase-A (chunked prefill) duration.
    pub phase_a_us: u64,
    /// Phase-B (batched decode step) duration.
    pub phase_b_us: u64,
    /// Lanes occupied after the step.
    pub occupancy: usize,
    /// Prompt tokens fed this step across all slots.
    pub prefill_tokens: usize,
    /// Decode (generation) tokens sampled this step.
    pub decode_tokens: usize,
}

/// One ring entry: an event or a span.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceEntry {
    Event(TraceRecord),
    Span(StepSpan),
}

impl TraceEntry {
    fn stamps(&self) -> (u64, u64) {
        match self {
            TraceEntry::Event(r) => (r.step, r.wall_us),
            TraceEntry::Span(s) => (s.step, s.wall_us),
        }
    }
}

/// Bounded in-memory trace sink. Oldest entries are evicted (and counted
/// in `dropped`) once `cap` is reached, so a long-running server has a
/// hard memory bound.
#[derive(Debug)]
pub struct TraceRing {
    buf: VecDeque<TraceEntry>,
    cap: usize,
    dropped: u64,
    epoch: Instant,
    step: u64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        TraceRing {
            buf: VecDeque::with_capacity(cap.min(4096)),
            cap,
            dropped: 0,
            epoch: Instant::now(),
            step: 0,
        }
    }

    fn wall_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    fn push(&mut self, entry: TraceEntry) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(entry);
    }
}

/// Cloneable handle to a shared [`TraceRing`], or a no-op when disabled.
/// The engine, scheduler, and server each hold a clone; a disabled sink
/// costs one `Option` discriminant check per call site and reads no
/// clocks.
#[derive(Clone, Debug, Default)]
pub struct TraceSink {
    ring: Option<Rc<RefCell<TraceRing>>>,
}

impl TraceSink {
    /// The no-op sink (also `Default`).
    pub fn disabled() -> Self {
        TraceSink { ring: None }
    }

    /// An enabled sink over a fresh ring of `cap` entries.
    pub fn enabled(cap: usize) -> Self {
        TraceSink { ring: Some(Rc::new(RefCell::new(TraceRing::new(cap)))) }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.ring.is_some()
    }

    /// Record one lifecycle event, stamped with the current step clock
    /// and wall time. No-op (no clock read) when disabled.
    #[inline]
    pub fn event(&self, req: Option<u64>, event: TraceEvent) {
        if let Some(ring) = &self.ring {
            let mut r = ring.borrow_mut();
            let (step, wall_us) = (r.step, r.wall_us());
            r.push(TraceEntry::Event(TraceRecord { step, wall_us, req, event }));
        }
    }

    /// Record one per-step span. The ring stamps step and wall time.
    #[inline]
    pub fn span(
        &self,
        phase_a_us: u64,
        phase_b_us: u64,
        occupancy: usize,
        prefill_tokens: usize,
        decode_tokens: usize,
    ) {
        if let Some(ring) = &self.ring {
            let mut r = ring.borrow_mut();
            let (step, wall_us) = (r.step, r.wall_us());
            r.push(TraceEntry::Span(StepSpan {
                step,
                wall_us,
                phase_a_us,
                phase_b_us,
                occupancy,
                prefill_tokens,
                decode_tokens,
            }));
        }
    }

    /// Advance the deterministic step clock (the scheduler's tick count).
    #[inline]
    pub fn set_step(&self, step: u64) {
        if let Some(ring) = &self.ring {
            ring.borrow_mut().step = step;
        }
    }

    /// Entries evicted from the ring so far (0 when disabled).
    pub fn dropped(&self) -> u64 {
        self.ring.as_ref().map_or(0, |r| r.borrow().dropped)
    }

    /// Number of entries currently held (0 when disabled).
    pub fn len(&self) -> usize {
        self.ring.as_ref().map_or(0, |r| r.borrow().buf.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot the current entries (for tests and in-process checks).
    pub fn entries(&self) -> Vec<TraceEntry> {
        self.ring.as_ref().map_or_else(Vec::new, |r| r.borrow().buf.iter().cloned().collect())
    }

    /// Serialize the ring to JSONL: one record per entry plus a trailing
    /// `summary` record carrying the server's counters, so
    /// [`check_trace`] can validate counter agreement from the file
    /// alone. No-op `Ok(())` when disabled.
    pub fn write_jsonl(&self, path: &Path, summary: &TraceSummary) -> Result<()> {
        let Some(ring) = &self.ring else { return Ok(()) };
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let r = ring.borrow();
        let mut out = String::new();
        for e in &r.buf {
            out.push_str(&entry_to_json(e));
            out.push('\n');
        }
        let mut s = summary.clone();
        s.dropped = r.dropped;
        out.push_str(&s.to_json());
        out.push('\n');
        std::fs::write(path, out).with_context(|| format!("writing trace {}", path.display()))
    }
}

/// The trailing JSONL record: the `ServingMetrics` counters the trace's
/// event counts must agree with, plus the ring's eviction count.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceSummary {
    pub admitted: u64,
    pub promoted: u64,
    pub rejected: u64,
    pub retries: u64,
    pub requeued: u64,
    pub backend_failed: u64,
    pub shed: u64,
    pub deadline_expired: u64,
    pub prefix_hits: u64,
    pub spec_rounds: u64,
    pub spec_rejected: u64,
    pub dropped: u64,
}

impl TraceSummary {
    pub fn from_serving(s: &ServingMetrics) -> Self {
        TraceSummary {
            admitted: s.admitted,
            promoted: s.promoted,
            rejected: s.rejected,
            retries: s.retries,
            requeued: s.requeued,
            backend_failed: s.backend_failed,
            shed: s.shed,
            deadline_expired: s.deadline_expired,
            prefix_hits: s.prefix_hits,
            spec_rounds: s.spec_rounds,
            spec_rejected: s.spec_rejected,
            dropped: 0,
        }
    }

    fn to_json(&self) -> String {
        format!(
            "{{\"type\":\"summary\",\"admitted\":{},\"promoted\":{},\"rejected\":{},\
             \"retries\":{},\"requeued\":{},\"backend_failed\":{},\"shed\":{},\
             \"deadline_expired\":{},\"prefix_hits\":{},\"spec_rounds\":{},\
             \"spec_rejected\":{},\"dropped\":{}}}",
            self.admitted,
            self.promoted,
            self.rejected,
            self.retries,
            self.requeued,
            self.backend_failed,
            self.shed,
            self.deadline_expired,
            self.prefix_hits,
            self.spec_rounds,
            self.spec_rejected,
            self.dropped
        )
    }
}

fn entry_to_json(e: &TraceEntry) -> String {
    match e {
        TraceEntry::Event(r) => {
            let mut s =
                format!("{{\"type\":\"event\",\"step\":{},\"wall_us\":{},", r.step, r.wall_us);
            match r.req {
                Some(id) => {
                    let _ = write!(s, "\"req\":{id},");
                }
                None => s.push_str("\"req\":null,"),
            }
            let _ = write!(s, "\"event\":\"{}\"", r.event.name());
            match &r.event {
                TraceEvent::Admitted { lane } => {
                    let _ = write!(s, ",\"lane\":{lane}");
                }
                TraceEvent::PrefillChunk { tokens } => {
                    let _ = write!(s, ",\"tokens\":{tokens}");
                }
                TraceEvent::Retry { attempt } => {
                    let _ = write!(s, ",\"attempt\":{attempt}");
                }
                TraceEvent::PrefixAdopted { rows } => {
                    let _ = write!(s, ",\"rows\":{rows}");
                }
                TraceEvent::Draft { k } => {
                    let _ = write!(s, ",\"k\":{k}");
                }
                TraceEvent::Verify { accepted } => {
                    let _ = write!(s, ",\"accepted\":{accepted}");
                }
                TraceEvent::Rollback { rows } => {
                    let _ = write!(s, ",\"rows\":{rows}");
                }
                TraceEvent::Finished { reason } => {
                    let _ = write!(s, ",\"reason\":\"{}\"", reason_name(*reason));
                }
                _ => {}
            }
            s.push('}');
            s
        }
        TraceEntry::Span(sp) => format!(
            "{{\"type\":\"span\",\"step\":{},\"wall_us\":{},\"phase_a_us\":{},\
             \"phase_b_us\":{},\"occupancy\":{},\"prefill_tokens\":{},\"decode_tokens\":{}}}",
            sp.step,
            sp.wall_us,
            sp.phase_a_us,
            sp.phase_b_us,
            sp.occupancy,
            sp.prefill_tokens,
            sp.decode_tokens
        ),
    }
}

/// A parsed trace file: entries in emission order plus the optional
/// trailing summary.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
    pub summary: Option<TraceSummary>,
}

/// Minimal flat-JSON value (the trace wire format never nests).
#[derive(Clone, Debug, PartialEq)]
enum Jv {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

/// Parse one flat JSON object (string/number/bool/null values only).
/// Returns `None` on malformed input — tolerant enough for hand-written
/// traces, strict enough to reject garbage.
fn parse_flat_json(line: &str) -> Option<Vec<(String, Jv)>> {
    let b: Vec<char> = line.trim().chars().collect();
    let mut i = 0usize;
    let skip_ws = |i: &mut usize| {
        while *i < b.len() && b[*i].is_whitespace() {
            *i += 1;
        }
    };
    let parse_str = |i: &mut usize| -> Option<String> {
        if b.get(*i) != Some(&'"') {
            return None;
        }
        *i += 1;
        let mut s = String::new();
        while *i < b.len() {
            match b[*i] {
                '"' => {
                    *i += 1;
                    return Some(s);
                }
                '\\' => {
                    *i += 1;
                    match b.get(*i)? {
                        '"' => s.push('"'),
                        '\\' => s.push('\\'),
                        '/' => s.push('/'),
                        'n' => s.push('\n'),
                        't' => s.push('\t'),
                        'u' => {
                            let hex: String = b.get(*i + 1..*i + 5)?.iter().collect();
                            let code = u32::from_str_radix(&hex, 16).ok()?;
                            s.push(char::from_u32(code)?);
                            *i += 4;
                        }
                        _ => return None,
                    }
                    *i += 1;
                }
                c => {
                    s.push(c);
                    *i += 1;
                }
            }
        }
        None
    };
    skip_ws(&mut i);
    if b.get(i) != Some(&'{') {
        return None;
    }
    i += 1;
    let mut out = Vec::new();
    skip_ws(&mut i);
    if b.get(i) == Some(&'}') {
        return Some(out);
    }
    loop {
        skip_ws(&mut i);
        let key = parse_str(&mut i)?;
        skip_ws(&mut i);
        if b.get(i) != Some(&':') {
            return None;
        }
        i += 1;
        skip_ws(&mut i);
        let val = match b.get(i)? {
            '"' => Jv::Str(parse_str(&mut i)?),
            't' if b.get(i..i + 4)?.iter().collect::<String>() == "true" => {
                i += 4;
                Jv::Bool(true)
            }
            'f' if b.get(i..i + 5)?.iter().collect::<String>() == "false" => {
                i += 5;
                Jv::Bool(false)
            }
            'n' if b.get(i..i + 4)?.iter().collect::<String>() == "null" => {
                i += 4;
                Jv::Null
            }
            _ => {
                let start = i;
                while i < b.len() && "+-0123456789.eE".contains(b[i]) {
                    i += 1;
                }
                let txt: String = b[start..i].iter().collect();
                Jv::Num(txt.parse().ok()?)
            }
        };
        out.push((key, val));
        skip_ws(&mut i);
        match b.get(i)? {
            ',' => i += 1,
            '}' => return Some(out),
            _ => return None,
        }
    }
}

fn field<'a>(obj: &'a [(String, Jv)], key: &str) -> Option<&'a Jv> {
    obj.iter().find(|(k, _)| k == key).map(|(_, v)| v)
}

fn num_field(obj: &[(String, Jv)], key: &str) -> Option<u64> {
    match field(obj, key)? {
        Jv::Num(n) => Some(*n as u64),
        _ => None,
    }
}

fn str_field<'a>(obj: &'a [(String, Jv)], key: &str) -> Option<&'a str> {
    match field(obj, key)? {
        Jv::Str(s) => Some(s.as_str()),
        _ => None,
    }
}

fn entry_from_fields(obj: &[(String, Jv)]) -> Option<TraceEntry> {
    let step = num_field(obj, "step")?;
    let wall_us = num_field(obj, "wall_us")?;
    match str_field(obj, "type")? {
        "span" => Some(TraceEntry::Span(StepSpan {
            step,
            wall_us,
            phase_a_us: num_field(obj, "phase_a_us")?,
            phase_b_us: num_field(obj, "phase_b_us")?,
            occupancy: num_field(obj, "occupancy")? as usize,
            prefill_tokens: num_field(obj, "prefill_tokens")? as usize,
            decode_tokens: num_field(obj, "decode_tokens")? as usize,
        })),
        "event" => {
            let req = match field(obj, "req")? {
                Jv::Num(n) => Some(*n as u64),
                Jv::Null => None,
                _ => return None,
            };
            let event = match str_field(obj, "event")? {
                "enqueued" => TraceEvent::Enqueued,
                "admitted" => TraceEvent::Admitted { lane: num_field(obj, "lane")? as usize },
                "prefill_chunk" => {
                    TraceEvent::PrefillChunk { tokens: num_field(obj, "tokens")? as usize }
                }
                "promoted" => TraceEvent::Promoted,
                "retry" => TraceEvent::Retry { attempt: num_field(obj, "attempt")? as u32 },
                "requeued" => TraceEvent::Requeued,
                "prefix_adopted" => {
                    TraceEvent::PrefixAdopted { rows: num_field(obj, "rows")? as usize }
                }
                "shed" => TraceEvent::Shed,
                "deadline_expired" => TraceEvent::DeadlineExpired,
                "draft" => TraceEvent::Draft { k: num_field(obj, "k")? as usize },
                "verify" => TraceEvent::Verify { accepted: num_field(obj, "accepted")? as usize },
                "rollback" => TraceEvent::Rollback { rows: num_field(obj, "rows")? as usize },
                "finished" => {
                    TraceEvent::Finished { reason: reason_from_name(str_field(obj, "reason")?)? }
                }
                _ => return None,
            };
            Some(TraceEntry::Event(TraceRecord { step, wall_us, req, event }))
        }
        _ => None,
    }
}

fn summary_from_fields(obj: &[(String, Jv)]) -> Option<TraceSummary> {
    let g = |k| num_field(obj, k).unwrap_or(0);
    Some(TraceSummary {
        admitted: g("admitted"),
        promoted: g("promoted"),
        rejected: g("rejected"),
        retries: g("retries"),
        requeued: g("requeued"),
        backend_failed: g("backend_failed"),
        shed: g("shed"),
        deadline_expired: g("deadline_expired"),
        prefix_hits: g("prefix_hits"),
        spec_rounds: g("spec_rounds"),
        spec_rejected: g("spec_rejected"),
        dropped: g("dropped"),
    })
}

/// Load a JSONL trace written by [`TraceSink::write_jsonl`].
pub fn read_jsonl(path: &Path) -> Result<Trace> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    parse_trace(&text)
}

/// Parse JSONL trace text (see [`read_jsonl`]).
pub fn parse_trace(text: &str) -> Result<Trace> {
    let mut trace = Trace::default();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let obj = parse_flat_json(line)
            .ok_or_else(|| anyhow!("trace line {}: malformed JSON", ln + 1))?;
        match str_field(&obj, "type") {
            Some("summary") => {
                trace.summary = summary_from_fields(&obj);
            }
            Some(_) => {
                let e = entry_from_fields(&obj)
                    .ok_or_else(|| anyhow!("trace line {}: bad record", ln + 1))?;
                trace.entries.push(e);
            }
            None => return Err(anyhow!("trace line {}: missing type", ln + 1)),
        }
    }
    Ok(trace)
}

/// Validate a trace: clock monotonicity, per-request lifecycle legality,
/// and (when the summary is present and the ring evicted nothing) exact
/// agreement between event counts and the `ServingMetrics` counters.
/// Returns human-readable violations; empty means the trace is legal.
pub fn check_trace(trace: &Trace) -> Vec<String> {
    let mut viol = Vec::new();
    let (mut last_step, mut last_wall) = (0u64, 0u64);
    for e in &trace.entries {
        let (s, w) = e.stamps();
        if s < last_step {
            viol.push(format!("step clock went backwards: {s} after {last_step}"));
        }
        if w < last_wall {
            viol.push(format!("wall clock went backwards: {w}us after {last_wall}us"));
        }
        (last_step, last_wall) = (s, w);
    }
    if trace.summary.as_ref().map_or(0, |s| s.dropped) > 0 {
        // evicted entries: per-request prefixes and counts are incomplete,
        // only the clock checks above are meaningful
        return viol;
    }

    #[derive(Clone, Copy, PartialEq, Debug)]
    enum St {
        New,
        Queued,
        Active,
        Done,
    }
    let mut states: BTreeMap<u64, St> = BTreeMap::new();
    for e in &trace.entries {
        let TraceEntry::Event(r) = e else { continue };
        let Some(id) = r.req else { continue };
        let st = states.entry(id).or_insert(St::New);
        match (&r.event, *st) {
            (TraceEvent::Enqueued, St::New) => *st = St::Queued,
            (TraceEvent::Requeued, St::Active) => *st = St::Queued,
            (TraceEvent::Admitted { .. }, St::New | St::Queued) => *st = St::Active,
            (
                TraceEvent::Promoted
                | TraceEvent::PrefixAdopted { .. }
                | TraceEvent::PrefillChunk { .. }
                | TraceEvent::Draft { .. }
                | TraceEvent::Verify { .. }
                | TraceEvent::Rollback { .. },
                St::Active,
            ) => {}
            (TraceEvent::Shed, St::New | St::Queued) => {}
            (TraceEvent::DeadlineExpired, St::New | St::Queued | St::Active) => {}
            (TraceEvent::Finished { .. }, St::Done) => {
                viol.push(format!("req {id}: Finished after Finished"));
            }
            (TraceEvent::Finished { .. }, _) => *st = St::Done,
            (ev, st) => viol.push(format!("req {id}: illegal {ev:?} in state {st:?}")),
        }
    }

    if let Some(sum) = &trace.summary {
        let mut by_name: BTreeMap<&'static str, u64> = BTreeMap::new();
        let mut finished: BTreeMap<&'static str, u64> = BTreeMap::new();
        for e in &trace.entries {
            if let TraceEntry::Event(r) = e {
                *by_name.entry(r.event.name()).or_default() += 1;
                if let TraceEvent::Finished { reason } = &r.event {
                    *finished.entry(reason_name(*reason)).or_default() += 1;
                }
            }
        }
        let c = |m: &BTreeMap<&'static str, u64>, k: &str| m.get(k).copied().unwrap_or(0);
        let checks = [
            ("admitted events", c(&by_name, "admitted"), sum.admitted),
            ("promoted events", c(&by_name, "promoted"), sum.promoted),
            ("retry events", c(&by_name, "retry"), sum.retries),
            ("requeued events", c(&by_name, "requeued"), sum.requeued),
            ("shed events", c(&by_name, "shed"), sum.shed),
            ("deadline events", c(&by_name, "deadline_expired"), sum.deadline_expired),
            ("prefix_adopted events", c(&by_name, "prefix_adopted"), sum.prefix_hits),
            // every verify round emits exactly one Draft and one Verify;
            // every rejecting round emits exactly one Rollback
            ("draft events", c(&by_name, "draft"), sum.spec_rounds),
            ("verify events", c(&by_name, "verify"), sum.spec_rounds),
            ("rollback events", c(&by_name, "rollback"), sum.spec_rejected),
            ("finished(rejected)", c(&finished, "rejected"), sum.rejected),
            ("finished(backend_error)", c(&finished, "backend_error"), sum.backend_failed),
            ("finished(shed)", c(&finished, "shed"), sum.shed),
            ("finished(deadline)", c(&finished, "deadline"), sum.deadline_expired),
        ];
        for (what, got, want) in checks {
            if got != want {
                viol.push(format!("{what}: trace has {got}, counters say {want}"));
            }
        }
    }
    viol
}

/// Per-request timeline reconstructed from a trace: the queue-wait /
/// prefill / decode breakdown `nxfp trace show` renders.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    pub req: u64,
    pub first_wall_us: u64,
    pub admitted_wall_us: Option<u64>,
    pub last_prefill_wall_us: Option<u64>,
    pub finished_wall_us: Option<u64>,
    pub enq_step: Option<u64>,
    pub admit_step: Option<u64>,
    pub finish_step: Option<u64>,
    pub prefill_tokens: usize,
    pub prefill_chunks: usize,
    pub prefix_rows: usize,
    pub requeues: u64,
    pub reason: Option<FinishReason>,
}

impl Timeline {
    /// First event → admission (µs); 0 when never admitted.
    pub fn queue_wait_us(&self) -> u64 {
        self.admitted_wall_us.map_or(0, |a| a.saturating_sub(self.first_wall_us))
    }

    /// Admission → last prefill chunk (µs).
    pub fn prefill_us(&self) -> u64 {
        match (self.admitted_wall_us, self.last_prefill_wall_us) {
            (Some(a), Some(p)) => p.saturating_sub(a),
            _ => 0,
        }
    }

    /// Last prefill chunk (or admission) → finish (µs).
    pub fn decode_us(&self) -> u64 {
        let start = self.last_prefill_wall_us.or(self.admitted_wall_us);
        match (start, self.finished_wall_us) {
            (Some(s), Some(f)) => f.saturating_sub(s),
            _ => 0,
        }
    }
}

/// Reconstruct one [`Timeline`] per request id, sorted by id.
pub fn timelines(trace: &Trace) -> Vec<Timeline> {
    let mut by_req: BTreeMap<u64, Timeline> = BTreeMap::new();
    for e in &trace.entries {
        let TraceEntry::Event(r) = e else { continue };
        let Some(id) = r.req else { continue };
        let t = by_req.entry(id).or_insert_with(|| Timeline {
            req: id,
            first_wall_us: r.wall_us,
            ..Timeline::default()
        });
        match &r.event {
            TraceEvent::Enqueued => t.enq_step = t.enq_step.or(Some(r.step)),
            TraceEvent::Admitted { .. } => {
                t.admitted_wall_us = Some(r.wall_us);
                t.admit_step = Some(r.step);
            }
            TraceEvent::PrefillChunk { tokens } => {
                t.prefill_tokens += tokens;
                t.prefill_chunks += 1;
                t.last_prefill_wall_us = Some(r.wall_us);
            }
            TraceEvent::PrefixAdopted { rows } => t.prefix_rows += rows,
            TraceEvent::Requeued => t.requeues += 1,
            TraceEvent::Finished { reason } => {
                t.finished_wall_us = Some(r.wall_us);
                t.finish_step = Some(r.step);
                t.reason = Some(*reason);
            }
            _ => {}
        }
    }
    by_req.into_values().collect()
}

/// Render timelines as the `nxfp trace show` table.
pub fn render_timelines(ts: &[Timeline]) -> String {
    let ms = |us: u64| us as f64 / 1e3;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:>6}  {:<13} {:>10} {:>10} {:>10}  {:>7} {:>7} {:>5} {:>4}",
        "req", "reason", "wait ms", "prefill ms", "decode ms", "pf tok", "chunks", "adopt", "rq"
    );
    for t in ts {
        let reason = t.reason.map_or("(in flight)".to_string(), |r| reason_name(r).to_string());
        let _ = writeln!(
            out,
            "{:>6}  {:<13} {:>10.3} {:>10.3} {:>10.3}  {:>7} {:>7} {:>5} {:>4}",
            t.req,
            reason,
            ms(t.queue_wait_us()),
            ms(t.prefill_us()),
            ms(t.decode_us()),
            t.prefill_tokens,
            t.prefill_chunks,
            t.prefix_rows,
            t.requeues
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> TraceSink {
        let sink = TraceSink::enabled(64);
        sink.event(Some(1), TraceEvent::Enqueued);
        sink.set_step(1);
        sink.event(Some(1), TraceEvent::Admitted { lane: 0 });
        sink.event(Some(1), TraceEvent::PrefillChunk { tokens: 8 });
        sink.span(5, 12, 1, 8, 0);
        sink.set_step(2);
        sink.event(None, TraceEvent::Retry { attempt: 1 });
        sink.event(Some(1), TraceEvent::Finished { reason: FinishReason::Completed });
        sink
    }

    fn summary_for_sample() -> TraceSummary {
        TraceSummary { admitted: 1, retries: 1, ..TraceSummary::default() }
    }

    #[test]
    fn disabled_sink_is_inert() {
        let sink = TraceSink::disabled();
        sink.event(Some(1), TraceEvent::Enqueued);
        sink.span(1, 2, 3, 4, 5);
        sink.set_step(9);
        assert!(!sink.is_enabled());
        assert!(sink.is_empty());
        assert_eq!(sink.dropped(), 0);
        assert!(TraceSink::default().entries().is_empty());
    }

    #[test]
    fn ring_bounds_and_counts_evictions() {
        let sink = TraceSink::enabled(4);
        for i in 0..10 {
            sink.event(Some(i), TraceEvent::Enqueued);
        }
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped(), 6);
        let first = match &sink.entries()[0] {
            TraceEntry::Event(r) => r.req,
            _ => None,
        };
        assert_eq!(first, Some(6), "oldest entries evicted first");
    }

    #[test]
    fn events_carry_both_clocks_monotonically() {
        let sink = sample_trace();
        let entries = sink.entries();
        assert_eq!(entries.len(), 6);
        let mut last = (0u64, 0u64);
        for e in &entries {
            let s = e.stamps();
            assert!(s.0 >= last.0 && s.1 >= last.1, "clocks must be monotone");
            last = s;
        }
        match &entries[1] {
            TraceEntry::Event(r) => assert_eq!(r.step, 1),
            _ => panic!("expected event"),
        }
    }

    #[test]
    fn jsonl_round_trips_bit_exactly() {
        let sink = sample_trace();
        let dir = std::env::temp_dir().join(format!("nxfp-obs-{}", std::process::id()));
        let path = dir.join("trace.jsonl");
        sink.write_jsonl(&path, &summary_for_sample()).unwrap();
        let trace = read_jsonl(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(trace.entries, sink.entries());
        let sum = trace.summary.expect("summary record");
        assert_eq!(sum.admitted, 1);
        assert_eq!(sum.retries, 1);
        assert_eq!(sum.dropped, 0);
        assert!(check_trace(&trace).is_empty(), "{:?}", check_trace(&trace));
    }

    #[test]
    fn parser_rejects_garbage_and_accepts_whitespace() {
        assert!(parse_trace("").unwrap().entries.is_empty());
        assert!(parse_trace("{\"type\":\"span\"}").is_err(), "span missing fields");
        assert!(parse_trace("not json").is_err());
        let line = "{ \"type\": \"event\", \"step\": 1, \"wall_us\": 2, \
                    \"req\": 7, \"event\": \"enqueued\" }";
        let t = parse_trace(line).unwrap();
        assert_eq!(t.entries.len(), 1);
    }

    #[test]
    fn check_catches_lifecycle_violations() {
        // Finished before Admitted is fine (rejection), but events after
        // Finished are not
        let sink = TraceSink::enabled(16);
        sink.event(Some(3), TraceEvent::Finished { reason: FinishReason::Rejected });
        sink.event(Some(3), TraceEvent::Admitted { lane: 0 });
        let trace = Trace { entries: sink.entries(), summary: None };
        let viol = check_trace(&trace);
        assert_eq!(viol.len(), 1, "{viol:?}");
        assert!(viol[0].contains("req 3"));
        // PrefillChunk without admission is illegal
        let sink = TraceSink::enabled(16);
        sink.event(Some(4), TraceEvent::PrefillChunk { tokens: 2 });
        let trace = Trace { entries: sink.entries(), summary: None };
        assert_eq!(check_trace(&trace).len(), 1);
        // double admission is illegal
        let sink = TraceSink::enabled(16);
        sink.event(Some(5), TraceEvent::Admitted { lane: 0 });
        sink.event(Some(5), TraceEvent::Admitted { lane: 1 });
        let trace = Trace { entries: sink.entries(), summary: None };
        assert_eq!(check_trace(&trace).len(), 1);
    }

    #[test]
    fn spec_events_round_trip_and_count_check() {
        let sink = TraceSink::enabled(64);
        sink.event(Some(9), TraceEvent::Admitted { lane: 0 });
        sink.event(Some(9), TraceEvent::Draft { k: 4 });
        sink.event(Some(9), TraceEvent::Verify { accepted: 2 });
        sink.event(Some(9), TraceEvent::Rollback { rows: 1 });
        sink.event(Some(9), TraceEvent::Draft { k: 4 });
        sink.event(Some(9), TraceEvent::Verify { accepted: 4 });
        sink.event(Some(9), TraceEvent::Finished { reason: FinishReason::Completed });
        let dir = std::env::temp_dir().join(format!("nxfp-obs-spec-{}", std::process::id()));
        let path = dir.join("trace.jsonl");
        let summary =
            TraceSummary { admitted: 1, spec_rounds: 2, spec_rejected: 1, ..TraceSummary::default() };
        sink.write_jsonl(&path, &summary).unwrap();
        let trace = read_jsonl(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        assert_eq!(trace.entries, sink.entries());
        assert_eq!(trace.summary.as_ref().unwrap().spec_rounds, 2);
        assert!(check_trace(&trace).is_empty(), "{:?}", check_trace(&trace));
        // a dropped Verify breaks the draft==verify==spec_rounds equality
        let mut pruned = trace.clone();
        pruned.entries.remove(5);
        let viol = check_trace(&pruned);
        assert!(viol.iter().any(|v| v.contains("verify events")), "{viol:?}");
    }

    #[test]
    fn spec_events_are_illegal_outside_active() {
        let sink = TraceSink::enabled(16);
        sink.event(Some(7), TraceEvent::Enqueued);
        sink.event(Some(7), TraceEvent::Draft { k: 2 });
        let trace = Trace { entries: sink.entries(), summary: None };
        let viol = check_trace(&trace);
        assert_eq!(viol.len(), 1, "{viol:?}");
        assert!(viol[0].contains("Draft"));
    }

    #[test]
    fn check_catches_counter_disagreement() {
        let sink = sample_trace();
        let trace = Trace {
            entries: sink.entries(),
            summary: Some(TraceSummary { admitted: 2, retries: 1, ..TraceSummary::default() }),
        };
        let viol = check_trace(&trace);
        assert!(viol.iter().any(|v| v.contains("admitted")), "{viol:?}");
    }

    #[test]
    fn check_skips_counts_when_ring_evicted() {
        let trace = Trace {
            entries: Vec::new(),
            summary: Some(TraceSummary {
                admitted: 5,
                dropped: 3,
                ..TraceSummary::default()
            }),
        };
        assert!(check_trace(&trace).is_empty(), "evicted traces can't be count-checked");
    }

    #[test]
    fn timelines_reconstruct_breakdown() {
        let sink = sample_trace();
        let trace = Trace { entries: sink.entries(), summary: None };
        let ts = timelines(&trace);
        assert_eq!(ts.len(), 1);
        let t = &ts[0];
        assert_eq!(t.req, 1);
        assert_eq!(t.prefill_tokens, 8);
        assert_eq!(t.prefill_chunks, 1);
        assert_eq!(t.reason, Some(FinishReason::Completed));
        assert_eq!(t.enq_step, Some(0));
        assert_eq!(t.admit_step, Some(1));
        assert_eq!(t.finish_step, Some(2));
        let rendered = render_timelines(&ts);
        assert!(rendered.contains("completed"));
        assert!(rendered.lines().count() >= 2);
    }
}
