//! Live quantization-fidelity probes: per-config code-occupancy tables.
//!
//! The paper motivates NanoMantissa, adaptive microexponents, and code
//! recycling with three measurable pathologies of block floating-point
//! direct casts: outliers the top representable level cannot track,
//! quantization levels no element ever lands on, and the wasted −0 code.
//! `profile/mod.rs` measures them offline on static tensors; this module
//! measures them *live*, on the exact codes the serving encode hot path
//! emits, one [`CodeOccupancy`] table per interned `NxConfig`.
//!
//! The probe re-derives the per-block scale from the metadata the
//! encoder already produced (`e_shared`, `nano`, `fmt_mx`) — the same
//! `(1 + nano/4) · 2^(e+offset)` arithmetic as `encode_candidate` — so
//! clip detection sees exactly the scaled magnitudes the winning
//! candidate saw, with zero change to encode results. Overhead is a
//! handful of mul/cmp per element, and only when a probe is attached.

use std::fmt::Write as _;

use crate::formats::encode::EncodePlan;
use crate::formats::NxConfig;
use crate::util::exp2i;

/// Occupancy counters for one block format config: one counter per code
/// point (2^bits) plus a clip counter.
#[derive(Clone, Debug, PartialEq)]
pub struct CodeOccupancy {
    /// `NxConfig::name()` of the config this table observes.
    pub config: String,
    /// Code width in bits; `counts.len() == 1 << bits`.
    pub bits: u8,
    /// Hits per code point, indexed by the packed code value.
    pub counts: Vec<u64>,
    /// Elements whose scaled magnitude exceeded the top level (strictly).
    pub clipped: u64,
    /// Elements observed in total.
    pub total: u64,
    /// Whether the config emits the recycled −0 code at all.
    pub recycle_enabled: bool,
}

impl CodeOccupancy {
    pub fn new(cfg: &NxConfig) -> Self {
        CodeOccupancy {
            config: cfg.name(),
            bits: cfg.bits,
            counts: vec![0; 1usize << cfg.bits],
            clipped: 0,
            total: 0,
            recycle_enabled: cfg.enable_cr,
        }
    }

    /// Observe one encoded row: `codes`/`e`/`nano`/`fmt` are exactly the
    /// outputs of `EncodePlan::quantize_row_into` for `v`. Counts every
    /// winning code and every clipped element (scaled `|a| > top`,
    /// strictly — NaN compares false and is projected, not clipped).
    pub fn observe_row(
        &mut self,
        plan: &EncodePlan,
        v: &[f32],
        codes: &[u8],
        e: &[i16],
        nano: &[u8],
        fmt: &[u8],
    ) {
        let k = plan.cfg.block_size;
        for (bi, chunk) in v.chunks(k).enumerate() {
            let bf = plan.tabs.get(fmt[bi] != 0);
            let scale = (1.0 + nano[bi] as f32 / 4.0) * exp2i(e[bi] as i32 + bf.offset);
            let inv = 1.0 / scale;
            let top = bf.top();
            for (j, &x) in chunk.iter().enumerate() {
                let a = x * inv;
                if a.abs() > top {
                    self.clipped += 1;
                }
                self.counts[codes[bi * k + j] as usize] += 1;
            }
            self.total += chunk.len() as u64;
        }
    }

    /// Fold another table (same config) into this one.
    pub fn merge(&mut self, other: &CodeOccupancy) {
        debug_assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.clipped += other.clipped;
        self.total += other.total;
    }

    /// The code value recycling repurposes (`1 << (bits-1)`, packed −0).
    pub fn recycle_code(&self) -> usize {
        1usize << (self.bits - 1)
    }

    /// Fraction of elements whose scaled magnitude exceeded the top
    /// level — the paper's outlier pathology.
    pub fn clip_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.clipped as f64 / self.total as f64
        }
    }

    /// Fraction of the 2^bits code points never emitted — the paper's
    /// vacant-level pathology. 1.0 until anything is observed.
    pub fn vacant_fraction(&self) -> f64 {
        let vacant = self.counts.iter().filter(|&&c| c == 0).count();
        vacant as f64 / self.counts.len() as f64
    }

    /// Fraction of elements that landed on the recycled −0 code. Always
    /// 0 when recycling is off (the encoder never emits that code).
    pub fn recycle_rate(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[self.recycle_code()] as f64 / self.total as f64
        }
    }

    /// One-line human summary for logs and bench banners.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{}: n={} clip={:.4} vacant={:.3} recycle={:.4}",
            self.config,
            self.total,
            self.clip_rate(),
            self.vacant_fraction(),
            self.recycle_rate()
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::encode::{EncodePlan, EncodeScratch};
    use crate::formats::quantize_block;

    fn observe_tensor(cfg: &NxConfig, v: &[f32]) -> CodeOccupancy {
        let plan = EncodePlan::new(cfg);
        let mut scratch = EncodeScratch::new();
        let blocks = v.len() / cfg.block_size;
        let mut codes = vec![0u8; v.len()];
        let mut e = vec![0i16; blocks];
        let mut nano = vec![0u8; blocks];
        let mut fmt = vec![0u8; blocks];
        plan.quantize_row_into(v, &mut scratch, &mut codes, &mut e, &mut nano, &mut fmt);
        let mut occ = CodeOccupancy::new(cfg);
        occ.observe_row(&plan, v, &codes, &e, &nano, &fmt);
        occ
    }

    /// Deterministic pseudo-random tensor (LCG — no external RNG dep).
    fn lcg_tensor(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        (0..n)
            .map(|_| {
                s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                ((s >> 33) as f32 / (1u64 << 31) as f32) * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn counts_cover_every_element_and_match_reference_encode() {
        let cfg = NxConfig::nxfp(4);
        let v = lcg_tensor(256, 7);
        let occ = observe_tensor(&cfg, &v);
        assert_eq!(occ.total, 256);
        assert_eq!(occ.counts.iter().sum::<u64>(), 256);
        // cross-check against the reference block quantizer's codes
        let tabs = cfg.tables();
        let mut ref_counts = vec![0u64; 1 << cfg.bits];
        for chunk in v.chunks(cfg.block_size) {
            let q = quantize_block(chunk, &cfg, &tabs);
            for &c in &q.codes {
                ref_counts[c as usize] += 1;
            }
        }
        assert_eq!(occ.counts, ref_counts);
    }

    #[test]
    fn outliers_clip_and_recycling_fires_only_when_enabled() {
        // one huge outlier per block forces the shared scale up, so the
        // outlier itself saturates exactly at top (not clipped) while a
        // tensor without headroom shows zero clips
        let cfg = NxConfig::nxfp(4);
        let mut v = lcg_tensor(128, 9);
        for b in 0..v.len() / cfg.block_size {
            v[b * cfg.block_size] = 300.0;
        }
        let occ = observe_tensor(&cfg, &v);
        assert_eq!(occ.total, 128);
        assert!(occ.clip_rate() < 1.0);
        assert!(occ.recycle_enabled);
        // recycling off: the −0 code never appears
        let mx = NxConfig::mxfp(4);
        let occ_mx = observe_tensor(&mx, &lcg_tensor(128, 9));
        assert!(!occ_mx.recycle_enabled);
        assert_eq!(occ_mx.counts[occ_mx.recycle_code()], 0);
        assert_eq!(occ_mx.recycle_rate(), 0.0);
    }

    #[test]
    fn vacant_fraction_and_empty_table_edge_cases() {
        let cfg = NxConfig::nxfp(4);
        let occ = CodeOccupancy::new(&cfg);
        assert_eq!(occ.clip_rate(), 0.0);
        assert_eq!(occ.recycle_rate(), 0.0);
        assert_eq!(occ.vacant_fraction(), 1.0);
        // an all-zero tensor lands every element on code 0
        let v = vec![0.0f32; cfg.block_size * 2];
        let occ = observe_tensor(&cfg, &v);
        assert_eq!(occ.counts[0], v.len() as u64);
        assert_eq!(occ.vacant_fraction(), (occ.counts.len() - 1) as f64 / occ.counts.len() as f64);
    }

    #[test]
    fn merge_sums_counters() {
        let cfg = NxConfig::nxfp(4);
        let v = lcg_tensor(128, 3);
        let mut a = observe_tensor(&cfg, &v);
        let b = observe_tensor(&cfg, &v);
        let clip = a.clipped;
        a.merge(&b);
        assert_eq!(a.total, 256);
        assert_eq!(a.clipped, clip * 2);
        assert_eq!(a.counts.iter().sum::<u64>(), 256);
    }
}
